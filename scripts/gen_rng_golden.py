#!/usr/bin/env python3
"""Regenerate the shared Threefry/Box-Muller golden vector table.

The table lives in ``rust/src/testing/golden_rng.rs`` and is asserted by
both the `util::rng` unit tests (scalar AND lane-batched generators) and
the batched-kernel differential suite (`rust/tests/pricing_batch.rs`).
The reference implementation here is a dependency-free transliteration of
``python/compile/kernels/rng.py`` (which is itself tested bit-for-bit
against ``jax._src.prng.threefry_2x32``): pure-int Threefry-2x32 plus an
IEEE-binary32 emulation of the uniform mapping, so every ``r``/``u`` value
is exact on any conforming platform. The Box-Muller normals are float64
references — transcendental libm calls (`ln`, `cos`) are not bit-pinned
across languages, so the rust side asserts them to 1e-5 and separately
asserts scalar == batched bit-for-bit within rust.

Usage: python3 scripts/gen_rng_golden.py   # prints the rust table body
"""

import math
import struct

MASK = 0xFFFFFFFF
ROTATIONS = (13, 15, 26, 6, 17, 29, 16, 24)
PARITY = 0x1BD11BDA
STEP_BITS = 20  # rust/src/pricing/mc.rs::STEP_BITS


def rotl(x, d):
    return ((x << d) | (x >> (32 - d))) & MASK


def threefry2x32(k0, k1, x0, x1):
    """Threefry-2x32, 20 rounds — mirrors kernels/rng.py::threefry2x32."""
    ks = (k0, k1, k0 ^ k1 ^ PARITY)
    x0 = (x0 + ks[0]) & MASK
    x1 = (x1 + ks[1]) & MASK
    for block in range(5):
        for r in range(4):
            x0 = (x0 + x1) & MASK
            x1 = rotl(x1, ROTATIONS[(4 * block + r) % 8])
            x1 ^= x0
        x0 = (x0 + ks[(block + 1) % 3]) & MASK
        x1 = (x1 + ks[(block + 2) % 3] + block + 1) & MASK
    return x0, x1


def f32(x):
    """Round a python float to IEEE binary32 (one rounding step)."""
    return struct.unpack("<f", struct.pack("<f", x))[0]


def f32_bits(x):
    return struct.unpack("<I", struct.pack("<f", x))[0]


def uniform(r):
    """kernels/rng.py::uniforms for one output word, exact binary32.

    ``(r >> 8) * 2^-24 + 2^-25`` is exact in float64 (25 significant bits),
    so a single terminal rounding reproduces the binary32 result of the
    f32 expression ``(r >> 8) as f32 * scale + half`` bit-for-bit.
    """
    return f32((r >> 8) * 2.0**-24 + 2.0**-25)


def normal_ref(u0, u1):
    """Box-Muller (cosine branch) in float64 on the binary32 uniforms."""
    two_pi_f32 = f32(2.0 * f32(math.pi))
    return math.sqrt(-2.0 * math.log(u0)) * math.cos(two_pi_f32 * u1)


def rows():
    cases = []
    # Group A — the legacy `threefry_matches_python_kernel` constants.
    for i in range(4):
        cases.append((123, 456, i, i + 7))
    # Group B — one European lane block: consecutive path counters, step 0.
    for i in range(8):
        cases.append((7, 42, i, 0))
    # Group C — paths above 2^32: the overflow folds into c1's high bits.
    for i in range(4):
        cases.append((9, 1, i, 1 << STEP_BITS))
    # Group D — the step word, up to the STEP_BITS boundary.
    for step in (0, 1, 255, (1 << STEP_BITS) - 1):
        cases.append((3, 2015, 5, (1 << STEP_BITS) | step))
    out = []
    for k0, k1, c0, c1 in cases:
        r0, r1 = threefry2x32(k0, k1, c0, c1)
        u0, u1 = uniform(r0), uniform(r1)
        out.append((k0, k1, c0, c1, r0, r1, f32_bits(u0), f32_bits(u1), normal_ref(u0, u1)))
    return out


def main():
    for k0, k1, c0, c1, r0, r1, u0b, u1b, z in rows():
        print(
            f"    GoldenRng {{ k0: {k0}, k1: {k1}, c0: {c0:#010x}, c1: {c1:#010x}, "
            f"r0: {r0:#010x}, r1: {r1:#010x}, u0_bits: {u0b:#010x}, u1_bits: {u1b:#010x}, "
            f"z_ref: {z!r} }},"
        )


if __name__ == "__main__":
    main()
