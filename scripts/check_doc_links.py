#!/usr/bin/env python3
"""Relative-link checker for the markdown docs.

Scans the given markdown files (default: docs/*.md and rust/README.md) for
inline links/images `[text](target)` and verifies that every RELATIVE
target resolves to an existing file or directory, relative to the file the
link appears in. External links (scheme://, mailto:) and pure in-page
anchors (#...) are skipped; `path#anchor` targets are checked for the path
part only. Exits non-zero listing every broken link, so docs rot fails CI.
"""

import glob
import os
import re
import sys

# Inline markdown links/images, excluding ``` fenced blocks handled below.
LINK_RE = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
SKIP_RE = re.compile(r"^(?:[a-zA-Z][a-zA-Z0-9+.-]*:|#)")


def iter_links(path):
    in_fence = False
    with open(path, encoding="utf-8") as fh:
        for lineno, line in enumerate(fh, 1):
            if line.lstrip().startswith("```"):
                in_fence = not in_fence
                continue
            if in_fence:
                continue
            for match in LINK_RE.finditer(line):
                yield lineno, match.group(1)


def check(paths):
    broken = []
    checked = 0
    for path in paths:
        base = os.path.dirname(os.path.abspath(path))
        for lineno, target in iter_links(path):
            if SKIP_RE.match(target):
                continue
            checked += 1
            rel = target.split("#", 1)[0]
            if not rel:
                continue
            resolved = os.path.normpath(os.path.join(base, rel))
            if not os.path.exists(resolved):
                broken.append(f"{path}:{lineno}: broken link '{target}' -> {resolved}")
    return checked, broken


def main(argv):
    paths = argv[1:] or sorted(glob.glob("docs/*.md")) + ["rust/README.md"]
    missing = [p for p in paths if not os.path.exists(p)]
    if missing:
        for p in missing:
            print(f"missing input file: {p}", file=sys.stderr)
        return 2
    checked, broken = check(paths)
    for b in broken:
        print(b, file=sys.stderr)
    print(f"checked {checked} relative links across {len(paths)} files: "
          f"{len(broken)} broken")
    return 1 if broken else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
