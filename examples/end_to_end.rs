//! END-TO-END driver — proves all three layers compose on a real workload:
//!
//! 1. loads the AOT artifacts (L1 Pallas kernels inside L2 JAX chunk graphs,
//!    lowered to HLO text) into the PJRT CPU runtime;
//! 2. builds a heterogeneous cluster = simulated Table II platforms + the
//!    REAL native platform executing those artifacts;
//! 3. runs the paper's §III.A benchmarking procedure on it (the native
//!    platform is benchmarked with real wall-clock executions) — this is
//!    `SessionBuilder::build`;
//! 4. partitions the workload with heuristic vs MILP at three budgets;
//! 5. EXECUTES every partition — the native platform really prices its
//!    slices — and reports predicted vs measured makespan/cost plus price
//!    accuracy against Black-Scholes.
//!
//! Results of a reference run are recorded in EXPERIMENTS.md.
//!
//! ```bash
//! make artifacts && cargo run --release --example end_to_end
//! ```

use cloudshapes::api::{CloudshapesError, SessionBuilder};
use cloudshapes::config::ExperimentConfig;
use cloudshapes::coordinator::partitioner::lower_cost_bound;
use cloudshapes::pricing::blackscholes;
use cloudshapes::workload::option::Payoff;

fn main() -> Result<(), CloudshapesError> {
    let cfg = ExperimentConfig::load(std::path::Path::new("configs/native.toml"))
        .unwrap_or_else(|_| {
            let mut c = ExperimentConfig::quick();
            c.cluster.with_native = true;
            c
        });
    println!("building session (simulated cluster + native PJRT platform)...");
    let session = SessionBuilder::from_config(cfg).build()?;
    let e = session.experiment();
    println!(
        "cluster: {} platforms ({} native), workload: {} tasks / {} sims",
        e.cluster.len(),
        e.cluster.specs().iter().filter(|s| s.name.contains("native")).count(),
        e.workload.len(),
        e.workload.total_sims()
    );

    let models = session.models();
    // Show what benchmarking learned about the native platform.
    let native_idx = (0..models.mu)
        .find(|&i| models.platform_names[i].contains("native"))
        .ok_or_else(|| CloudshapesError::platform("native platform missing"))?;
    println!("\nbenchmark-fitted native-platform models (real wall-clock):");
    for j in 0..models.tau.min(4) {
        let m = models.model(native_idx, j);
        println!(
            "  task {j}: beta {:.3e} s/path, gamma {:.4} s, R2 {:.4}",
            m.beta, m.gamma, m.r_squared
        );
    }

    let (c_l, _) = lower_cost_bound(models);
    let un = session.partition_with(Some("milp"), None)?;
    let budgets = [None, Some((c_l + un.predicted_cost) / 2.0), Some(c_l)];

    println!(
        "\n{:>12} {:>10} {:>24} {:>24}",
        "budget", "partnr", "predicted (s / $)", "measured (s / $)"
    );
    for budget in budgets {
        for name in ["milp", "heuristic"] {
            // Skip only infeasible budgets; execution failures must propagate.
            let p = match session.partition_with(Some(name), budget) {
                Ok(p) => p,
                Err(_) => continue,
            };
            let rep = session.execute_allocation(&p.alloc)?;
            println!(
                "{:>12} {:>10} {:>14.1} / {:<7.3} {:>14.1} / {:<7.3}  (native slice: {} sims)",
                budget.map(|b| format!("{b:.2}")).unwrap_or_else(|| "uncon".into()),
                p.partitioner,
                p.predicted_latency_s,
                p.predicted_cost,
                rep.makespan_secs,
                rep.cost,
                rep.platforms[native_idx].sims,
            );
            assert_eq!(rep.failures, 0, "platform failures during execution");
        }
    }

    // Price-correctness audit: every European task vs Black-Scholes.
    println!("\nprice audit (milp unconstrained partition):");
    let rep = session.evaluate_with(Some("milp"), None)?.execution;
    let mut audited = 0;
    for (t, price) in e.workload.tasks.iter().zip(&rep.prices) {
        let est = price
            .as_ref()
            .ok_or_else(|| CloudshapesError::runtime(format!("task {} missing price", t.id)))?;
        if t.payoff == Payoff::European {
            let bs = blackscholes::call(t.spot, t.strike, t.rate, t.sigma, t.maturity);
            let ok = (est.price - bs).abs() < 6.0 * est.std_error + 0.1;
            println!(
                "  task {:>2}: mc {:>8.4} ± {:<7.4} bs {:>8.4} {}",
                t.id,
                est.price,
                est.std_error,
                bs,
                if ok { "OK" } else { "MISMATCH" }
            );
            assert!(ok, "task {} price mismatch", t.id);
            audited += 1;
        }
    }
    println!("\nend_to_end OK ({audited} European prices verified against Black-Scholes)");
    Ok(())
}
