//! Coordinator-as-a-service demo: starts the JSON-over-TCP coordinator on a
//! free port, runs a scripted client session against it (ping, specs,
//! partition at several budgets, evaluate, shutdown), and prints the
//! round-trip results — the "long-running framework" usage mode.
//!
//! ```bash
//! cargo run --release --example cluster_serve
//! ```

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;
use std::thread;

use cloudshapes::cli::serve::serve_until_shutdown;
use cloudshapes::config::ExperimentConfig;
use cloudshapes::report::Experiment;
use cloudshapes::util::json::Json;

fn request(addr: &str, line: &str) -> Result<Json, String> {
    let mut stream = TcpStream::connect(addr).map_err(|e| e.to_string())?;
    stream
        .write_all(format!("{line}\n").as_bytes())
        .map_err(|e| e.to_string())?;
    let mut reader = BufReader::new(stream);
    let mut response = String::new();
    reader.read_line(&mut response).map_err(|e| e.to_string())?;
    Json::parse(response.trim()).map_err(|e| e.to_string())
}

fn main() -> Result<(), String> {
    let mut cfg = ExperimentConfig::quick();
    cfg.milp.time_limit_secs = 3.0;
    println!("building experiment + binding coordinator...");
    let experiment = Arc::new(Experiment::build(cfg)?);
    let listener = TcpListener::bind("127.0.0.1:0").map_err(|e| e.to_string())?;
    let addr = listener.local_addr().map_err(|e| e.to_string())?.to_string();
    println!("coordinator on {addr}");
    let server = thread::spawn(move || serve_until_shutdown(listener, experiment));

    // Scripted client session.
    let session = [
        r#"{"op":"ping"}"#.to_string(),
        r#"{"op":"specs"}"#.to_string(),
        r#"{"op":"partition","partitioner":"heuristic"}"#.to_string(),
        r#"{"op":"partition","partitioner":"milp"}"#.to_string(),
        r#"{"op":"partition","partitioner":"milp","budget":1.0}"#.to_string(),
        r#"{"op":"evaluate","partitioner":"milp"}"#.to_string(),
    ];
    for line in &session {
        let resp = request(&addr, line)?;
        assert_eq!(
            resp.get("ok"),
            Some(&Json::Bool(true)),
            "request failed: {line} -> {}",
            resp.to_string_compact()
        );
        println!("> {line}\n< {}", resp.to_string_compact());
    }
    // Model-vs-measured consistency from the evaluate round-trip.
    let eval = request(&addr, r#"{"op":"evaluate","partitioner":"heuristic"}"#)?;
    let pred = eval.get("predicted_latency_s").and_then(Json::as_f64).unwrap();
    let meas = eval.get("measured_latency_s").and_then(Json::as_f64).unwrap();
    println!("predicted {pred:.1}s vs measured {meas:.1}s");
    assert!((meas / pred - 1.0).abs() < 0.5, "prediction wildly off");

    let _ = request(&addr, r#"{"op":"shutdown"}"#);
    let _ = server.join();
    println!("cluster_serve OK");
    Ok(())
}
