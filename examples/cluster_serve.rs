//! Coordinator-as-a-service demo: starts the JSON-over-TCP coordinator
//! (protocol v1) on a free port, runs a scripted client session against it
//! (ping, specs, partition at several budgets, evaluate, a deliberately bad
//! request to show the structured error payload, shutdown), and prints the
//! round-trip results — the "long-running framework" usage mode.
//!
//! ```bash
//! cargo run --release --example cluster_serve
//! ```

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;
use std::thread;

use cloudshapes::api::{CloudshapesError, PROTOCOL_VERSION, SessionBuilder};
use cloudshapes::cli::serve::serve_until_shutdown;
use cloudshapes::coordinator::partitioner::MilpConfig;
use cloudshapes::util::json::Json;

fn request(addr: &str, line: &str) -> Result<Json, CloudshapesError> {
    let io = |e: std::io::Error| CloudshapesError::runtime(e.to_string());
    let mut stream = TcpStream::connect(addr).map_err(io)?;
    stream.write_all(format!("{line}\n").as_bytes()).map_err(io)?;
    let mut reader = BufReader::new(stream);
    let mut response = String::new();
    reader.read_line(&mut response).map_err(io)?;
    Ok(Json::parse(response.trim())?)
}

fn main() -> Result<(), CloudshapesError> {
    println!("building session + binding coordinator (protocol v{PROTOCOL_VERSION})...");
    let session = SessionBuilder::quick()
        .milp(MilpConfig { time_limit_secs: 3.0, ..Default::default() })
        .build()?;
    let listener = TcpListener::bind("127.0.0.1:0")
        .map_err(|e| CloudshapesError::runtime(e.to_string()))?;
    let addr = listener
        .local_addr()
        .map_err(|e| CloudshapesError::runtime(e.to_string()))?
        .to_string();
    println!("coordinator on {addr}");
    let server = thread::spawn(move || serve_until_shutdown(listener, Arc::new(session)));

    // Scripted client session (note the explicit budget: null = unconstrained).
    let session_lines = [
        r#"{"v":1,"op":"ping"}"#,
        r#"{"v":1,"op":"specs"}"#,
        r#"{"v":1,"op":"partition","partitioner":"heuristic","budget":null}"#,
        r#"{"v":1,"op":"partition","partitioner":"milp","budget":null}"#,
        r#"{"v":1,"op":"partition","partitioner":"milp","budget":1.0}"#,
        r#"{"v":1,"op":"evaluate","partitioner":"milp","budget":null}"#,
    ];
    for line in session_lines {
        let resp = request(&addr, line)?;
        assert_eq!(
            resp.get("ok"),
            Some(&Json::Bool(true)),
            "request failed: {line} -> {}",
            resp.to_string_compact()
        );
        println!("> {line}\n< {}", resp.to_string_compact());
    }

    // A bad request comes back as a typed error payload, not a dropped
    // connection.
    let bad = request(&addr, r#"{"v":1,"op":"partition"}"#)?;
    assert_eq!(bad.get("ok"), Some(&Json::Bool(false)));
    let kind = bad.get("error").and_then(|e| e.get("kind")).and_then(Json::as_str);
    assert_eq!(kind, Some("protocol"), "{}", bad.to_string_compact());
    println!("> (missing budget)\n< {}", bad.to_string_compact());

    // Model-vs-measured consistency from the evaluate round-trip.
    let eval =
        request(&addr, r#"{"v":1,"op":"evaluate","partitioner":"heuristic","budget":null}"#)?;
    let pred = eval.get("predicted_latency_s").and_then(Json::as_f64).unwrap();
    let meas = eval.get("measured_latency_s").and_then(Json::as_f64).unwrap();
    println!("predicted {pred:.1}s vs measured {meas:.1}s");
    assert!((meas / pred - 1.0).abs() < 0.5, "prediction wildly off");

    let _ = request(&addr, r#"{"v":1,"op":"shutdown"}"#);
    let _ = server.join();
    println!("cluster_serve OK");
    Ok(())
}
