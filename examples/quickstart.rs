//! Quickstart: price one option through the full AOT stack, then partition a
//! small workload across a heterogeneous cluster at two budgets — all
//! through the `api::TradeoffSession` front door.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use cloudshapes::api::{CloudshapesError, SessionBuilder};
use cloudshapes::pricing::{blackscholes, combine};
use cloudshapes::runtime::EngineHandle;
use cloudshapes::workload::option::{OptionTask, Payoff};

fn main() -> Result<(), CloudshapesError> {
    // --- 1. Price a European call on the PJRT CPU client (L1+L2 artifacts).
    println!("== pricing through the AOT Pallas kernel (PJRT CPU) ==");
    let engine = EngineHandle::spawn(std::path::Path::new("artifacts")).map_err(|e| {
        CloudshapesError::platform(format!("{e:#} — run `make artifacts` first"))
    })?;
    let task = OptionTask {
        id: 1,
        payoff: Payoff::European,
        spot: 100.0,
        strike: 105.0,
        rate: 0.05,
        sigma: 0.2,
        maturity: 1.0,
        barrier: 0.0,
        steps: 1,
        target_accuracy: 0.01,
        n_sims: 1 << 18,
        ..OptionTask::default()
    };
    let stats = engine
        .price(&task, task.n_sims, 42)
        .map_err(|e| CloudshapesError::runtime(e.to_string()))?;
    let est = combine(&stats, task.discount());
    let bs = blackscholes::call(task.spot, task.strike, task.rate, task.sigma, task.maturity);
    println!("  monte carlo: {:.4} ± {:.4}  ({} paths)", est.price, est.std_error, est.n);
    println!("  black-scholes: {bs:.4}");
    assert!((est.price - bs).abs() < 4.0 * est.std_error + 0.05);

    // --- 2. Partition a workload across a simulated heterogeneous cluster.
    //     One session = benchmark once, partition at any budget afterwards.
    println!("\n== partitioning 8 tasks across FPGA+GPU+CPU ==");
    let session = SessionBuilder::quick().build()?;
    for (label, budget) in [("unconstrained", None), ("tight budget", Some(0.8))] {
        println!("  -- {label} --");
        for name in ["milp", "heuristic"] {
            match session.partition_with(Some(name), budget) {
                Ok(p) => println!(
                    "  {:>9}: makespan {:>8.1}s  cost ${:<6.3} platforms {}",
                    p.partitioner,
                    p.predicted_latency_s,
                    p.predicted_cost,
                    p.alloc.used_platforms().len()
                ),
                Err(err) => println!("  {name:>9}: infeasible ({err})"),
            }
        }
    }
    println!("\nquickstart OK");
    Ok(())
}
