//! Ablation: the paper's divisible-task MILP vs the classic whole-task
//! mapping heuristics from Braun et al. [5] (OLB/MET/MCT/Min-Min/Max-Min/
//! Sufferage) on the same model data — quantifies how much of the win comes
//! from task divisibility + billing awareness vs plain good mapping.
//!
//! ```bash
//! cargo run --release --example baseline_ablation
//! ```

use cloudshapes::config::ExperimentConfig;
use cloudshapes::coordinator::partitioner::baselines::{Classic, ClassicPartitioner};
use cloudshapes::coordinator::{HeuristicPartitioner, MilpPartitioner, Partitioner};
use cloudshapes::report::Experiment;
use cloudshapes::util::table::{fnum, Align, Table};

fn main() -> Result<(), String> {
    let quick = std::env::args().any(|a| a == "quick");
    let cfg = if quick {
        ExperimentConfig::quick()
    } else {
        ExperimentConfig::load(std::path::Path::new("configs/paper.toml")).unwrap_or_default()
    };
    let e = Experiment::build(cfg.clone())?;
    let models = e.models();

    let mut t = Table::new(&["partitioner", "makespan (s)", "cost ($)", "platforms"])
        .aligns(&[Align::Left, Align::Right, Align::Right, Align::Right]);

    let mut results: Vec<(String, f64)> = Vec::new();
    for c in Classic::all() {
        let alloc = ClassicPartitioner(c).partition(models, None)?;
        let (lat, cost) = models.evaluate(&alloc);
        t.row(&[
            c.name().to_string(),
            fnum(lat, 1),
            fnum(cost, 3),
            alloc.used_platforms().len().to_string(),
        ]);
        results.push((c.name().to_string(), lat));
    }
    let h = HeuristicPartitioner::upper_bound_allocation(models);
    let (hl, hc) = models.evaluate(&h);
    t.row(&["paper-heuristic (C_U)".to_string(), fnum(hl, 1), fnum(hc, 3), h.used_platforms().len().to_string()]);

    let milp = MilpPartitioner::new(cfg.milp.clone()).solve(models, None)?;
    t.row(&[
        "milp (divisible)".to_string(),
        fnum(milp.makespan, 1),
        fnum(milp.cost, 3),
        milp.alloc.used_platforms().len().to_string(),
    ]);
    println!("{}", t.render());

    // The divisible MILP must dominate every whole-task mapper on makespan.
    for (name, lat) in &results {
        assert!(
            milp.makespan <= lat * 1.001,
            "milp ({}) slower than {name} ({lat})",
            milp.makespan
        );
    }
    println!("baseline_ablation OK (milp dominates all whole-task mappers)");
    Ok(())
}
