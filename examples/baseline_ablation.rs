//! Ablation: the paper's divisible-task MILP vs the classic whole-task
//! mapping heuristics from Braun et al. [5] (OLB/MET/MCT/Min-Min/Max-Min/
//! Sufferage) on the same model data — quantifies how much of the win comes
//! from task divisibility + billing awareness vs plain good mapping.
//!
//! Every strategy is resolved by name through the session's
//! `PartitionerRegistry`, so adding a strategy automatically adds a table
//! row.
//!
//! ```bash
//! cargo run --release --example baseline_ablation
//! ```

use cloudshapes::api::{CloudshapesError, SessionBuilder};
use cloudshapes::config::ExperimentConfig;
use cloudshapes::coordinator::HeuristicPartitioner;
use cloudshapes::util::table::{fnum, Align, Table};

fn main() -> Result<(), CloudshapesError> {
    let quick = std::env::args().any(|a| a == "quick");
    let cfg = if quick {
        ExperimentConfig::quick()
    } else {
        ExperimentConfig::load(std::path::Path::new("configs/paper.toml")).unwrap_or_default()
    };
    let session = SessionBuilder::from_config(cfg).build()?;
    let models = session.models();

    let mut t = Table::new(&["partitioner", "makespan (s)", "cost ($)", "platforms"])
        .aligns(&[Align::Left, Align::Right, Align::Right, Align::Right]);

    // Whole-task baselines, straight from the registry.
    let classics = ["olb", "met", "mct", "min-min", "max-min", "sufferage"];
    let mut results: Vec<(String, f64)> = Vec::new();
    for name in classics {
        let p = session.partition_with(Some(name), None)?;
        t.row(&[
            p.partitioner.clone(),
            fnum(p.predicted_latency_s, 1),
            fnum(p.predicted_cost, 3),
            p.alloc.used_platforms().len().to_string(),
        ]);
        results.push((p.partitioner, p.predicted_latency_s));
    }
    let h = HeuristicPartitioner::upper_bound_allocation(models);
    let (hl, hc) = models.evaluate(&h);
    t.row(&[
        "paper-heuristic (C_U)".to_string(),
        fnum(hl, 1),
        fnum(hc, 3),
        h.used_platforms().len().to_string(),
    ]);

    let milp = session.partition_with(Some("milp"), None)?;
    t.row(&[
        "milp (divisible)".to_string(),
        fnum(milp.predicted_latency_s, 1),
        fnum(milp.predicted_cost, 3),
        milp.alloc.used_platforms().len().to_string(),
    ]);
    println!("{}", t.render());

    // The divisible MILP must dominate every whole-task mapper on makespan.
    for (name, lat) in &results {
        assert!(
            milp.predicted_latency_s <= lat * 1.001,
            "milp ({}) slower than {name} ({lat})",
            milp.predicted_latency_s
        );
    }
    println!("baseline_ablation OK (milp dominates all whole-task mappers)");
    Ok(())
}
