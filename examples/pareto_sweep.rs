//! Pareto sweep at paper scale: regenerate the Fig. 1 trade-off for both
//! partitioners through one `TradeoffSession` and print the curves side by
//! side (ASCII + CSV on stdout).
//!
//! ```bash
//! cargo run --release --example pareto_sweep            # paper scale
//! cargo run --release --example pareto_sweep -- quick   # small preset
//! ```

use cloudshapes::api::{CloudshapesError, SessionBuilder};
use cloudshapes::config::ExperimentConfig;
use cloudshapes::util::plot::{Plot, Series};

fn main() -> Result<(), CloudshapesError> {
    let quick = std::env::args().any(|a| a == "quick");
    let cfg = if quick {
        ExperimentConfig::quick()
    } else {
        ExperimentConfig::load(std::path::Path::new("configs/paper.toml"))
            .unwrap_or_default()
    };
    let session = SessionBuilder::from_config(cfg)
        .budget_sweep(if quick { 5 } else { 9 })
        .build()?;

    let m_curve = session.pareto_frontier_with(Some("milp"))?;
    let h_curve = session.pareto_frontier_with(Some("heuristic"))?;

    let mut plot = Plot::new(
        "Latency vs Cost trade-off (model predictions)",
        "cost ($)",
        "makespan (s)",
    );
    let mut ms = Series::new("milp", 'o');
    for p in m_curve.pareto_front() {
        ms.push(p.cost, p.latency);
    }
    let mut hs = Series::new("heuristic", 'x');
    for p in h_curve.pareto_front() {
        hs.push(p.cost, p.latency);
    }
    plot.add(ms);
    plot.add(hs);
    println!("{}", plot.render());

    println!("budget,milp_latency,milp_cost,heuristic_latency,heuristic_cost");
    let pairs = m_curve.points.iter().zip(h_curve.points.iter());
    for (mp, hp) in pairs {
        println!(
            "{},{:.1},{:.3},{:.1},{:.3}",
            mp.budget.map(|b| format!("{b:.3}")).unwrap_or_else(|| "uncon".into()),
            mp.latency,
            mp.cost,
            hp.latency,
            hp.cost
        );
    }

    // The paper's dominance claim, checked across the curve.
    for (mp, hp) in m_curve.points.iter().zip(h_curve.points.iter()) {
        if let (Some(mb), Some(hb)) = (mp.budget, hp.budget) {
            if (mb - hb).abs() < 1e-6 {
                assert!(
                    mp.latency <= hp.latency * 1.001,
                    "milp slower at budget {mb}: {} vs {}",
                    mp.latency,
                    hp.latency
                );
            }
        }
    }
    println!("\npareto_sweep OK (milp <= heuristic at every shared budget)");
    Ok(())
}
