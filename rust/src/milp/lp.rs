//! Linear / mixed-integer problem model.
//!
//! A thin, allocation-friendly builder that both the generic solver
//! ([`super::simplex`], [`super::branch_bound`]) and the paper-specific
//! partitioning formulation (`coordinator::partitioner::milp`) target.

/// Variable kind. The simplex relaxes `Int`/`Bin` to `Cont`; branch & bound
/// restores integrality.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum VarKind {
    Cont,
    Int,
    Bin,
}

/// Handle to a variable in a [`Problem`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct VarId(pub usize);

/// Comparison operator of a linear constraint.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Cmp {
    Le,
    Eq,
    Ge,
}

/// One variable: bounds and kind. `lb`/`ub` may be ±infinity.
#[derive(Debug, Clone)]
pub struct Var {
    pub name: String,
    pub lb: f64,
    pub ub: f64,
    pub kind: VarKind,
}

/// A linear constraint `Σ coef·var  cmp  rhs`.
#[derive(Debug, Clone)]
pub struct Constraint {
    pub terms: Vec<(VarId, f64)>,
    pub cmp: Cmp,
    pub rhs: f64,
}

/// A minimization problem. (Maximize by negating the objective.)
#[derive(Debug, Clone, Default)]
pub struct Problem {
    pub vars: Vec<Var>,
    pub cons: Vec<Constraint>,
    /// Objective terms; duplicated VarIds are summed.
    pub objective: Vec<(VarId, f64)>,
    /// Constant added to the objective value.
    pub obj_const: f64,
}

impl Problem {
    pub fn new() -> Problem {
        Problem::default()
    }

    /// Add a continuous variable with bounds.
    pub fn cont(&mut self, name: &str, lb: f64, ub: f64) -> VarId {
        self.add_var(name, lb, ub, VarKind::Cont)
    }

    /// Add an integer variable with bounds.
    pub fn int(&mut self, name: &str, lb: f64, ub: f64) -> VarId {
        self.add_var(name, lb, ub, VarKind::Int)
    }

    /// Add a binary variable.
    pub fn bin(&mut self, name: &str) -> VarId {
        self.add_var(name, 0.0, 1.0, VarKind::Bin)
    }

    fn add_var(&mut self, name: &str, lb: f64, ub: f64, kind: VarKind) -> VarId {
        assert!(lb <= ub, "var '{name}': lb {lb} > ub {ub}");
        let id = VarId(self.vars.len());
        self.vars.push(Var { name: name.to_string(), lb, ub, kind });
        id
    }

    /// Add a constraint; returns its index.
    pub fn constrain(&mut self, terms: Vec<(VarId, f64)>, cmp: Cmp, rhs: f64) -> usize {
        for (v, _) in &terms {
            assert!(v.0 < self.vars.len(), "constraint references unknown var");
        }
        self.cons.push(Constraint { terms, cmp, rhs });
        self.cons.len() - 1
    }

    /// Set (replace) the linear objective to minimize.
    pub fn minimize(&mut self, terms: Vec<(VarId, f64)>) {
        self.objective = terms;
    }

    pub fn n_vars(&self) -> usize {
        self.vars.len()
    }

    pub fn n_cons(&self) -> usize {
        self.cons.len()
    }

    /// Indices of integer-constrained (Int or Bin) variables.
    pub fn int_vars(&self) -> Vec<usize> {
        self.vars
            .iter()
            .enumerate()
            .filter(|(_, v)| v.kind != VarKind::Cont)
            .map(|(i, _)| i)
            .collect()
    }

    /// Evaluate the objective at a point.
    pub fn objective_value(&self, x: &[f64]) -> f64 {
        self.obj_const + self.objective.iter().map(|(v, c)| c * x[v.0]).sum::<f64>()
    }

    /// Check primal feasibility of `x` within tolerance `tol`
    /// (bounds + constraints; integrality checked for Int/Bin vars).
    pub fn is_feasible(&self, x: &[f64], tol: f64) -> bool {
        if x.len() != self.vars.len() {
            return false;
        }
        for (i, v) in self.vars.iter().enumerate() {
            if x[i] < v.lb - tol || x[i] > v.ub + tol {
                return false;
            }
            if v.kind != VarKind::Cont && (x[i] - x[i].round()).abs() > tol {
                return false;
            }
        }
        for c in &self.cons {
            let lhs: f64 = c.terms.iter().map(|(v, a)| a * x[v.0]).sum();
            let ok = match c.cmp {
                Cmp::Le => lhs <= c.rhs + tol,
                Cmp::Ge => lhs >= c.rhs - tol,
                Cmp::Eq => (lhs - c.rhs).abs() <= tol,
            };
            if !ok {
                return false;
            }
        }
        true
    }

    /// Clone the problem with all Int/Bin kinds relaxed to Cont.
    pub fn relaxed(&self) -> Problem {
        let mut p = self.clone();
        for v in &mut p.vars {
            v.kind = VarKind::Cont;
        }
        p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_and_accessors() {
        let mut p = Problem::new();
        let x = p.cont("x", 0.0, f64::INFINITY);
        let y = p.bin("y");
        let z = p.int("z", 0.0, 10.0);
        p.constrain(vec![(x, 1.0), (y, 2.0)], Cmp::Le, 4.0);
        p.minimize(vec![(x, 1.0), (z, -1.0)]);
        assert_eq!(p.n_vars(), 3);
        assert_eq!(p.n_cons(), 1);
        assert_eq!(p.int_vars(), vec![1, 2]);
        assert_eq!(p.objective_value(&[2.0, 0.0, 3.0]), -1.0);
    }

    #[test]
    fn feasibility_checks_everything() {
        let mut p = Problem::new();
        let x = p.cont("x", 0.0, 5.0);
        let y = p.bin("y");
        p.constrain(vec![(x, 1.0), (y, 1.0)], Cmp::Eq, 3.0);
        assert!(p.is_feasible(&[2.0, 1.0], 1e-9));
        assert!(!p.is_feasible(&[2.5, 0.5], 1e-9)); // y fractional
        assert!(!p.is_feasible(&[6.0, 1.0], 1e-9)); // x above ub (and cons violated)
        assert!(!p.is_feasible(&[1.0, 1.0], 1e-9)); // eq violated
        assert!(!p.is_feasible(&[1.0], 1e-9)); // wrong arity
    }

    #[test]
    fn relaxed_drops_integrality() {
        let mut p = Problem::new();
        p.bin("b");
        let r = p.relaxed();
        assert!(r.int_vars().is_empty());
        assert!(r.is_feasible(&[0.5], 1e-9));
    }

    #[test]
    #[should_panic(expected = "lb")]
    fn inverted_bounds_panic() {
        Problem::new().cont("x", 2.0, 1.0);
    }

    #[test]
    #[should_panic(expected = "unknown var")]
    fn unknown_var_in_constraint_panics() {
        let mut p = Problem::new();
        p.constrain(vec![(VarId(3), 1.0)], Cmp::Le, 0.0);
    }
}
