//! Dense two-phase primal simplex.
//!
//! Solves the continuous relaxation of a [`Problem`]: variables are shifted /
//! negated / split to the `x ≥ 0` standard form, finite upper bounds become
//! explicit rows, slack/surplus/artificial columns are appended, phase 1
//! minimizes artificial infeasibility, phase 2 the real objective.
//!
//! Pivoting uses Dantzig's rule with a permanent switch to Bland's rule after
//! an iteration budget (anti-cycling). Suited to the dense, small-row-count
//! LPs this project generates (the reduced partitioning LP is ~160 rows —
//! see `coordinator::partitioner::milp`).

use super::lp::{Cmp, Problem};

const EPS: f64 = 1e-9;

/// Termination status of an LP solve.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LpStatus {
    Optimal,
    Infeasible,
    Unbounded,
    /// Iteration budget exhausted — treat as a solver failure.
    IterLimit,
}

/// LP solve result. `x` is in the original problem's variable space.
#[derive(Debug, Clone)]
pub struct LpSolution {
    pub status: LpStatus,
    pub x: Vec<f64>,
    pub obj: f64,
    pub iters: usize,
}

/// How an original variable maps into standard-form columns.
#[derive(Debug, Clone, Copy)]
enum Map {
    /// lb == ub: substituted constant.
    Fixed(f64),
    /// x = col + lb  (lb finite).
    Shifted { col: usize, lb: f64 },
    /// x = ub - col  (lb = -inf, ub finite).
    Negated { col: usize, ub: f64 },
    /// x = pos - neg (free variable).
    Split { pos: usize, neg: usize },
}

/// Solve the continuous relaxation of `p` (Int/Bin treated as Cont).
pub fn solve(p: &Problem) -> LpSolution {
    // ---- 1. Variable transformation to x' >= 0 ----------------------------
    let mut maps = Vec::with_capacity(p.vars.len());
    let mut n_cols = 0usize;
    // Rows for finite upper bounds of shifted vars: (col, bound).
    let mut ub_rows: Vec<(usize, f64)> = Vec::new();
    for v in &p.vars {
        debug_assert!(v.kind == v.kind); // silence unused-kind lint paths
        if v.lb == v.ub {
            maps.push(Map::Fixed(v.lb));
        } else if v.lb.is_finite() {
            let col = n_cols;
            n_cols += 1;
            maps.push(Map::Shifted { col, lb: v.lb });
            if v.ub.is_finite() {
                ub_rows.push((col, v.ub - v.lb));
            }
        } else if v.ub.is_finite() {
            let col = n_cols;
            n_cols += 1;
            maps.push(Map::Negated { col, ub: v.ub });
        } else {
            let pos = n_cols;
            let neg = n_cols + 1;
            n_cols += 2;
            maps.push(Map::Split { pos, neg });
        }
    }

    // ---- 2. Rewrite constraints over standard-form columns ----------------
    // Each row: (coeffs dense over n_cols, cmp, rhs).
    struct Row {
        a: Vec<f64>,
        cmp: Cmp,
        b: f64,
    }
    let mut rows: Vec<Row> = Vec::with_capacity(p.cons.len() + ub_rows.len());
    for c in &p.cons {
        let mut a = vec![0.0; n_cols];
        let mut b = c.rhs;
        for (vid, coef) in &c.terms {
            match maps[vid.0] {
                Map::Fixed(val) => b -= coef * val,
                Map::Shifted { col, lb } => {
                    a[col] += coef;
                    b -= coef * lb;
                }
                Map::Negated { col, ub } => {
                    a[col] -= coef;
                    b -= coef * ub;
                }
                Map::Split { pos, neg } => {
                    a[pos] += coef;
                    a[neg] -= coef;
                }
            }
        }
        rows.push(Row { a, cmp: c.cmp, b });
    }
    for (col, bound) in ub_rows {
        let mut a = vec![0.0; n_cols];
        a[col] = 1.0;
        rows.push(Row { a, cmp: Cmp::Le, b: bound });
    }

    // Normalize to b >= 0.
    for r in &mut rows {
        if r.b < 0.0 {
            for v in &mut r.a {
                *v = -*v;
            }
            r.b = -r.b;
            r.cmp = match r.cmp {
                Cmp::Le => Cmp::Ge,
                Cmp::Ge => Cmp::Le,
                Cmp::Eq => Cmp::Eq,
            };
        }
    }

    // ---- 3. Objective over standard-form columns ---------------------------
    let mut cost = vec![0.0; n_cols];
    let mut obj_const = p.obj_const;
    for (vid, coef) in &p.objective {
        match maps[vid.0] {
            Map::Fixed(val) => obj_const += coef * val,
            Map::Shifted { col, lb } => {
                cost[col] += coef;
                obj_const += coef * lb;
            }
            Map::Negated { col, ub } => {
                cost[col] -= coef;
                obj_const += coef * ub;
            }
            Map::Split { pos, neg } => {
                cost[pos] += coef;
                cost[neg] -= coef;
            }
        }
    }

    // ---- 4. Build tableau: slacks / surpluses / artificials ----------------
    let m = rows.len();
    // Column layout: [structural | slack+surplus | artificial | rhs]
    let n_slack = rows.iter().filter(|r| r.cmp != Cmp::Eq).count();
    let mut n_art = 0usize;
    let total = n_cols + n_slack + {
        // Count artificials: Ge and Eq rows need one.
        rows.iter().filter(|r| r.cmp != Cmp::Le).count()
    };
    let width = total + 1; // + rhs
    let mut t = vec![0.0; (m + 1) * width]; // last row = cost row
    let mut basis = vec![usize::MAX; m];
    let mut art_cols: Vec<usize> = Vec::new();

    let mut next_slack = n_cols;
    let mut next_art = n_cols + n_slack;
    for (i, r) in rows.iter().enumerate() {
        let off = i * width;
        t[off..off + n_cols].copy_from_slice(&r.a);
        t[off + total] = r.b;
        match r.cmp {
            Cmp::Le => {
                t[off + next_slack] = 1.0;
                basis[i] = next_slack;
                next_slack += 1;
            }
            Cmp::Ge => {
                t[off + next_slack] = -1.0;
                next_slack += 1;
                t[off + next_art] = 1.0;
                basis[i] = next_art;
                art_cols.push(next_art);
                next_art += 1;
                n_art += 1;
            }
            Cmp::Eq => {
                t[off + next_art] = 1.0;
                basis[i] = next_art;
                art_cols.push(next_art);
                next_art += 1;
                n_art += 1;
            }
        }
    }

    let mut iters = 0usize;
    let iter_limit = 200 * (m + total + 1);
    let bland_after = 20 * (m + total + 1);
    let is_art = |c: usize| c >= n_cols + n_slack && c < total;

    // ---- 5. Phase 1 ---------------------------------------------------------
    if n_art > 0 {
        // Cost row: minimize sum of artificials.
        let cost_off = m * width;
        for cell in t[cost_off..cost_off + width].iter_mut() {
            *cell = 0.0;
        }
        for &c in &art_cols {
            t[cost_off + c] = 1.0;
        }
        // Price out the (artificial) basis.
        for i in 0..m {
            if is_art(basis[i]) {
                for j in 0..width {
                    t[cost_off + j] -= t[i * width + j];
                }
            }
        }
        match pivot_loop(&mut t, &mut basis, m, total, width, &mut iters, iter_limit, bland_after, |_| true) {
            PivotOutcome::Optimal => {}
            PivotOutcome::Unbounded => {
                // Phase-1 objective is bounded below by 0; unbounded means bug.
                return fail(LpStatus::IterLimit, p, iters);
            }
            PivotOutcome::IterLimit => return fail(LpStatus::IterLimit, p, iters),
        }
        let phase1_obj = -t[m * width + total];
        if phase1_obj > 1e-7 {
            return fail(LpStatus::Infeasible, p, iters);
        }
        // Drive artificials out of the basis where possible.
        for i in 0..m {
            if is_art(basis[i]) {
                let off = i * width;
                if let Some(j) = (0..n_cols + n_slack).find(|&j| t[off + j].abs() > 1e-7) {
                    pivot(&mut t, &mut basis, m, width, i, j);
                } // else: redundant row; artificial stays basic at 0.
            }
        }
    }

    // ---- 6. Phase 2 ---------------------------------------------------------
    let cost_off = m * width;
    for cell in t[cost_off..cost_off + width].iter_mut() {
        *cell = 0.0;
    }
    t[cost_off..cost_off + n_cols].copy_from_slice(&cost);
    // Price out the current basis.
    for i in 0..m {
        let b = basis[i];
        if b < total {
            let cb = if b < n_cols { cost[b] } else { 0.0 };
            if cb != 0.0 {
                for j in 0..width {
                    t[cost_off + j] -= cb * t[i * width + j];
                }
            }
        }
    }
    let allow = |c: usize| !is_art(c); // artificials must not re-enter
    match pivot_loop(&mut t, &mut basis, m, total, width, &mut iters, iter_limit, bland_after, allow) {
        PivotOutcome::Optimal => {}
        PivotOutcome::Unbounded => return fail(LpStatus::Unbounded, p, iters),
        PivotOutcome::IterLimit => return fail(LpStatus::IterLimit, p, iters),
    }

    // ---- 7. Extract solution ------------------------------------------------
    let mut xs = vec![0.0; n_cols + n_slack + n_art];
    for i in 0..m {
        if basis[i] < xs.len() {
            xs[basis[i]] = t[i * width + total];
        }
    }
    let mut x = vec![0.0; p.vars.len()];
    for (vi, map) in maps.iter().enumerate() {
        x[vi] = match *map {
            Map::Fixed(v) => v,
            Map::Shifted { col, lb } => xs[col] + lb,
            Map::Negated { col, ub } => ub - xs[col],
            Map::Split { pos, neg } => xs[pos] - xs[neg],
        };
    }
    let obj = p.objective_value(&x);
    let _ = obj_const; // objective_value already includes the constant
    LpSolution { status: LpStatus::Optimal, x, obj, iters }
}

fn fail(status: LpStatus, p: &Problem, iters: usize) -> LpSolution {
    LpSolution { status, x: vec![0.0; p.vars.len()], obj: f64::NAN, iters }
}

enum PivotOutcome {
    Optimal,
    Unbounded,
    IterLimit,
}

/// Run pivots until optimality/unboundedness. `allow(col)` filters entering
/// candidates (used to lock artificials out in phase 2).
#[allow(clippy::too_many_arguments)]
fn pivot_loop<F: Fn(usize) -> bool>(
    t: &mut [f64],
    basis: &mut [usize],
    m: usize,
    total: usize,
    width: usize,
    iters: &mut usize,
    iter_limit: usize,
    bland_after: usize,
    allow: F,
) -> PivotOutcome {
    loop {
        if *iters >= iter_limit {
            return PivotOutcome::IterLimit;
        }
        let cost_off = m * width;
        // Entering column.
        let entering = if *iters < bland_after {
            // Dantzig: most negative reduced cost.
            let mut best = None;
            let mut best_val = -EPS;
            for j in 0..total {
                let rc = t[cost_off + j];
                if rc < best_val && allow(j) {
                    best_val = rc;
                    best = Some(j);
                }
            }
            best
        } else {
            // Bland: first negative.
            (0..total).find(|&j| t[cost_off + j] < -EPS && allow(j))
        };
        let Some(e) = entering else {
            return PivotOutcome::Optimal;
        };
        // Ratio test.
        let mut leave: Option<usize> = None;
        let mut best_ratio = f64::INFINITY;
        for i in 0..m {
            let a = t[i * width + e];
            if a > EPS {
                let ratio = t[i * width + total] / a;
                // Ties: prefer the row whose basic var has the smallest index
                // (lexicographic-ish anti-cycling).
                if ratio < best_ratio - EPS
                    || (ratio < best_ratio + EPS
                        && leave.map(|l| basis[i] < basis[l]).unwrap_or(false))
                {
                    best_ratio = ratio;
                    leave = Some(i);
                }
            }
        }
        let Some(l) = leave else {
            return PivotOutcome::Unbounded;
        };
        pivot(t, basis, m, width, l, e);
        *iters += 1;
    }
}

/// Gauss-pivot on (row, col), updating the cost row too.
///
/// The update only touches the pivot row's *non-zero* columns: early in a
/// solve the tableau is sparse (structural constraint matrices here have
/// ~3 entries per column), and skipping zeros cuts the dominant
/// m×width daxpy cost substantially before fill-in densifies the tableau
/// (≈2× on the 161×2227 partitioning root LP — EXPERIMENTS.md §Perf).
fn pivot(t: &mut [f64], basis: &mut [usize], _m: usize, width: usize, row: usize, col: usize) {
    let piv = t[row * width + col];
    debug_assert!(piv.abs() > 1e-12, "pivot on ~zero");
    let inv = 1.0 / piv;
    for j in 0..width {
        t[row * width + j] *= inv;
    }
    // Collect the pivot row's support once.
    let (before, from_row) = t.split_at_mut(row * width);
    let (pivot_row, after) = from_row.split_at_mut(width);
    let nonzero: Vec<usize> = (0..width).filter(|&j| pivot_row[j] != 0.0).collect();
    let update = |chunk: &mut [f64]| {
        for r in chunk.chunks_exact_mut(width) {
            let factor = r[col];
            if factor.abs() > 1e-13 {
                for &j in &nonzero {
                    r[j] -= factor * pivot_row[j];
                }
                r[col] = 0.0; // exact zero to stop drift
            }
        }
    };
    update(before);
    update(after);
    basis[row] = col;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::milp::lp::{Cmp, Problem};

    fn assert_opt(sol: &LpSolution, obj: f64, x: &[f64]) {
        assert_eq!(sol.status, LpStatus::Optimal, "{sol:?}");
        assert!((sol.obj - obj).abs() < 1e-6, "obj {} != {obj}", sol.obj);
        for (i, xi) in x.iter().enumerate() {
            assert!((sol.x[i] - xi).abs() < 1e-6, "x[{i}] {} != {xi}", sol.x[i]);
        }
    }

    #[test]
    fn textbook_max_problem() {
        // max 3x + 5y s.t. x<=4, 2y<=12, 3x+2y<=18 -> (2, 6), obj 36.
        let mut p = Problem::new();
        let x = p.cont("x", 0.0, f64::INFINITY);
        let y = p.cont("y", 0.0, f64::INFINITY);
        p.constrain(vec![(x, 1.0)], Cmp::Le, 4.0);
        p.constrain(vec![(y, 2.0)], Cmp::Le, 12.0);
        p.constrain(vec![(x, 3.0), (y, 2.0)], Cmp::Le, 18.0);
        p.minimize(vec![(x, -3.0), (y, -5.0)]);
        let sol = solve(&p);
        assert_opt(&sol, -36.0, &[2.0, 6.0]);
    }

    #[test]
    fn equality_and_ge_constraints() {
        // min x + y s.t. x + y = 10, x >= 3, y >= 2 -> obj 10.
        let mut p = Problem::new();
        let x = p.cont("x", 0.0, f64::INFINITY);
        let y = p.cont("y", 0.0, f64::INFINITY);
        p.constrain(vec![(x, 1.0), (y, 1.0)], Cmp::Eq, 10.0);
        p.constrain(vec![(x, 1.0)], Cmp::Ge, 3.0);
        p.constrain(vec![(y, 1.0)], Cmp::Ge, 2.0);
        p.minimize(vec![(x, 1.0), (y, 1.0)]);
        let sol = solve(&p);
        assert_eq!(sol.status, LpStatus::Optimal);
        assert!((sol.obj - 10.0).abs() < 1e-7);
        assert!(sol.x[0] >= 3.0 - 1e-7 && sol.x[1] >= 2.0 - 1e-7);
    }

    #[test]
    fn detects_infeasible() {
        let mut p = Problem::new();
        let x = p.cont("x", 0.0, f64::INFINITY);
        p.constrain(vec![(x, 1.0)], Cmp::Le, 1.0);
        p.constrain(vec![(x, 1.0)], Cmp::Ge, 2.0);
        p.minimize(vec![(x, 1.0)]);
        assert_eq!(solve(&p).status, LpStatus::Infeasible);
    }

    #[test]
    fn detects_unbounded() {
        let mut p = Problem::new();
        let x = p.cont("x", 0.0, f64::INFINITY);
        p.minimize(vec![(x, -1.0)]);
        assert_eq!(solve(&p).status, LpStatus::Unbounded);
    }

    #[test]
    fn respects_variable_bounds() {
        // min -x with x in [0, 7] -> x = 7.
        let mut p = Problem::new();
        let x = p.cont("x", 0.0, 7.0);
        p.minimize(vec![(x, -1.0)]);
        assert_opt(&solve(&p), -7.0, &[7.0]);
    }

    #[test]
    fn shifted_lower_bound() {
        // min x with x in [3, 10] -> 3.
        let mut p = Problem::new();
        let x = p.cont("x", 3.0, 10.0);
        p.minimize(vec![(x, 1.0)]);
        assert_opt(&solve(&p), 3.0, &[3.0]);
    }

    #[test]
    fn negative_lower_bound() {
        // min x with x in [-5, 5] -> -5.
        let mut p = Problem::new();
        let x = p.cont("x", -5.0, 5.0);
        p.minimize(vec![(x, 1.0)]);
        assert_opt(&solve(&p), -5.0, &[-5.0]);
    }

    #[test]
    fn free_variable_split() {
        // min x s.t. x >= -4 encoded as a constraint on a free var.
        let mut p = Problem::new();
        let x = p.cont("x", f64::NEG_INFINITY, f64::INFINITY);
        p.constrain(vec![(x, 1.0)], Cmp::Ge, -4.0);
        p.minimize(vec![(x, 1.0)]);
        assert_opt(&solve(&p), -4.0, &[-4.0]);
    }

    #[test]
    fn negated_upper_bounded_var() {
        // x in (-inf, 3], min -x -> 3.
        let mut p = Problem::new();
        let x = p.cont("x", f64::NEG_INFINITY, 3.0);
        p.minimize(vec![(x, -1.0)]);
        assert_opt(&solve(&p), -3.0, &[3.0]);
    }

    #[test]
    fn fixed_variable_substituted() {
        let mut p = Problem::new();
        let x = p.cont("x", 2.0, 2.0);
        let y = p.cont("y", 0.0, f64::INFINITY);
        p.constrain(vec![(x, 1.0), (y, 1.0)], Cmp::Le, 5.0);
        p.minimize(vec![(y, -1.0)]);
        assert_opt(&solve(&p), -3.0, &[2.0, 3.0]);
    }

    #[test]
    fn negative_rhs_row_normalized() {
        // -x <= -2  (i.e. x >= 2); min x -> 2.
        let mut p = Problem::new();
        let x = p.cont("x", 0.0, f64::INFINITY);
        p.constrain(vec![(x, -1.0)], Cmp::Le, -2.0);
        p.minimize(vec![(x, 1.0)]);
        assert_opt(&solve(&p), 2.0, &[2.0]);
    }

    #[test]
    fn degenerate_problem_terminates() {
        // Classic degeneracy: multiple identical constraints.
        let mut p = Problem::new();
        let x = p.cont("x", 0.0, f64::INFINITY);
        let y = p.cont("y", 0.0, f64::INFINITY);
        for _ in 0..5 {
            p.constrain(vec![(x, 1.0), (y, 1.0)], Cmp::Le, 1.0);
        }
        p.minimize(vec![(x, -1.0), (y, -2.0)]);
        let sol = solve(&p);
        assert_eq!(sol.status, LpStatus::Optimal);
        assert!((sol.obj + 2.0).abs() < 1e-7);
    }

    #[test]
    fn objective_constant_carried() {
        let mut p = Problem::new();
        let x = p.cont("x", 1.0, 2.0);
        p.obj_const = 100.0;
        p.minimize(vec![(x, 1.0)]);
        let sol = solve(&p);
        assert!((sol.obj - 101.0).abs() < 1e-9);
    }

    #[test]
    fn redundant_equality_rows() {
        // x + y = 4 twice plus x - y = 0 -> x = y = 2.
        let mut p = Problem::new();
        let x = p.cont("x", 0.0, f64::INFINITY);
        let y = p.cont("y", 0.0, f64::INFINITY);
        p.constrain(vec![(x, 1.0), (y, 1.0)], Cmp::Eq, 4.0);
        p.constrain(vec![(x, 1.0), (y, 1.0)], Cmp::Eq, 4.0);
        p.constrain(vec![(x, 1.0), (y, -1.0)], Cmp::Eq, 0.0);
        p.minimize(vec![(x, 1.0)]);
        let sol = solve(&p);
        assert_opt(&sol, 2.0, &[2.0, 2.0]);
    }

    #[test]
    fn moderately_sized_random_lp_solves() {
        // Transportation-style LP: 20 sources x 30 sinks.
        use crate::util::rng::Rng;
        let mut rng = Rng::new(42);
        let (ns, nd) = (20, 30);
        let mut p = Problem::new();
        let mut vars = vec![];
        for i in 0..ns {
            for j in 0..nd {
                vars.push(p.cont(&format!("x{i}_{j}"), 0.0, f64::INFINITY));
            }
        }
        // Each sink needs 1 unit; each source supplies at most 2.
        for j in 0..nd {
            let terms: Vec<_> = (0..ns).map(|i| (vars[i * nd + j], 1.0)).collect();
            p.constrain(terms, Cmp::Eq, 1.0);
        }
        for i in 0..ns {
            let terms: Vec<_> = (0..nd).map(|j| (vars[i * nd + j], 1.0)).collect();
            p.constrain(terms, Cmp::Le, 2.0);
        }
        let costs: Vec<f64> = (0..ns * nd).map(|_| rng.range_f64(1.0, 10.0)).collect();
        p.minimize(vars.iter().zip(&costs).map(|(v, c)| (*v, *c)).collect());
        let sol = solve(&p);
        assert_eq!(sol.status, LpStatus::Optimal);
        assert!(p.relaxed().is_feasible(&sol.x, 1e-6));
        // Objective can't beat assigning every sink its cheapest source.
        let lb: f64 = (0..nd)
            .map(|j| (0..ns).map(|i| costs[i * nd + j]).fold(f64::INFINITY, f64::min))
            .sum();
        assert!(sol.obj >= lb - 1e-6);
    }
}
