//! Mixed-Integer Linear Programming substrate — the project's stand-in for
//! SCIP (unavailable offline; see DESIGN.md §2).
//!
//! * [`lp`] — problem model (variables, bounds, constraints, objective);
//! * [`simplex`] — dense two-phase primal simplex for LP relaxations;
//! * [`branch_bound`] — generic best-first branch & bound with budgets,
//!   gap reporting, and an optional worker pool (`BnbLimits::workers`)
//!   sharing one frontier; parallel and sequential runs return identical
//!   objectives at `rel_gap = 0`.
//!
//! The paper-specific Eq. 4 partitioning MILP is formulated in
//! `coordinator::partitioner::milp` on top of these pieces (with a
//! structure-aware reduction for the 128×16 instance).

pub mod branch_bound;
pub mod lp;
pub mod simplex;

pub use branch_bound::{solve as solve_milp, BnbLimits, MilpSolution, MilpStatus};
pub use lp::{Cmp, Constraint, Problem, Var, VarId, VarKind};
pub use simplex::{solve as solve_lp, LpSolution, LpStatus};
