//! Generic best-first branch & bound over [`Problem`]s with Int/Bin vars.
//!
//! This is the "SCIP as a black box" role from the paper (§III.B): LP
//! relaxations from [`super::simplex`], most-fractional branching with bound
//! tightening, rounding-based incumbents, node/gap/time budgets. It is exact
//! on small/medium instances and *anytime* on large ones — it always returns
//! the best incumbent plus the proven lower bound and gap.
//!
//! The full-size 128×16 partitioning MILP is solved by the structure-aware
//! specialization in `coordinator::partitioner::milp`, which is validated
//! against this generic solver on small instances.

use std::cmp::Ordering;
use std::collections::BinaryHeap;
use std::time::Instant;

use super::lp::{Problem, VarKind};
use super::simplex::{self, LpStatus};

/// Integrality tolerance.
pub const INT_TOL: f64 = 1e-6;

/// Search limits. Defaults are generous for test-sized problems.
#[derive(Debug, Clone)]
pub struct BnbLimits {
    pub max_nodes: usize,
    /// Relative optimality gap at which the search stops.
    pub rel_gap: f64,
    pub time_limit_secs: f64,
}

impl Default for BnbLimits {
    fn default() -> Self {
        BnbLimits { max_nodes: 100_000, rel_gap: 1e-6, time_limit_secs: 60.0 }
    }
}

/// Outcome of a MILP solve.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MilpStatus {
    /// Incumbent proven optimal within `rel_gap`.
    Optimal,
    /// Stopped on a budget with a feasible incumbent (gap reported).
    Feasible,
    Infeasible,
    Unbounded,
    /// No incumbent found within the budget (and not proven infeasible).
    Unknown,
}

/// MILP solve result.
#[derive(Debug, Clone)]
pub struct MilpSolution {
    pub status: MilpStatus,
    /// Best integer-feasible point (valid when status is Optimal/Feasible).
    pub x: Vec<f64>,
    pub obj: f64,
    /// Proven lower bound on the optimum.
    pub bound: f64,
    /// Relative gap between incumbent and bound.
    pub gap: f64,
    pub nodes: usize,
}

struct Node {
    /// Lower bound inherited from the parent LP (priority key).
    bound: f64,
    /// (var index, new lb, new ub) deltas relative to the root problem.
    bounds: Vec<(usize, f64, f64)>,
    depth: usize,
}

impl PartialEq for Node {
    fn eq(&self, other: &Self) -> bool {
        self.bound == other.bound
    }
}
impl Eq for Node {}
impl PartialOrd for Node {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Node {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap; we want the *smallest* bound first.
        other.bound.total_cmp(&self.bound)
    }
}

/// Solve a mixed-integer problem by branch & bound.
pub fn solve(p: &Problem, limits: &BnbLimits) -> MilpSolution {
    let start = Instant::now();
    let int_vars = p.int_vars();

    // Root relaxation.
    let root = simplex::solve(&p.relaxed());
    match root.status {
        LpStatus::Infeasible => {
            return MilpSolution {
                status: MilpStatus::Infeasible,
                x: vec![],
                obj: f64::INFINITY,
                bound: f64::INFINITY,
                gap: 0.0,
                nodes: 1,
            }
        }
        LpStatus::Unbounded => {
            return MilpSolution {
                status: MilpStatus::Unbounded,
                x: vec![],
                obj: f64::NEG_INFINITY,
                bound: f64::NEG_INFINITY,
                gap: 0.0,
                nodes: 1,
            }
        }
        LpStatus::IterLimit => {
            return MilpSolution {
                status: MilpStatus::Unknown,
                x: vec![],
                obj: f64::INFINITY,
                bound: f64::NEG_INFINITY,
                gap: f64::INFINITY,
                nodes: 1,
            }
        }
        LpStatus::Optimal => {}
    }

    let mut incumbent: Option<(Vec<f64>, f64)> = None;
    let mut heap = BinaryHeap::new();
    heap.push(Node { bound: root.obj, bounds: vec![], depth: 0 });
    let mut nodes = 0usize;
    let mut best_bound = root.obj;

    while let Some(node) = heap.pop() {
        nodes += 1;
        best_bound = node.bound; // best-first: heap top is the global bound
        if let Some((_, inc_obj)) = &incumbent {
            if gap_of(*inc_obj, node.bound) <= limits.rel_gap {
                break; // proven within tolerance
            }
        }
        if nodes > limits.max_nodes || start.elapsed().as_secs_f64() > limits.time_limit_secs {
            break;
        }

        // Re-solve this node's LP (bounds applied to a clone of the root).
        let mut sub = p.relaxed();
        for &(vi, lb, ub) in &node.bounds {
            sub.vars[vi].lb = lb;
            sub.vars[vi].ub = ub;
        }
        let rel = simplex::solve(&sub);
        if rel.status != LpStatus::Optimal {
            continue; // infeasible subtree (or solver failure: safe to drop —
                      // bound-wise we only ever *under*-report progress)
        }
        if let Some((_, inc_obj)) = &incumbent {
            if rel.obj >= *inc_obj - limits.rel_gap * inc_obj.abs().max(1.0) {
                continue; // dominated
            }
        }

        // Find the most fractional integer variable.
        let frac = int_vars
            .iter()
            .map(|&vi| (vi, (rel.x[vi] - rel.x[vi].round()).abs()))
            .filter(|(_, f)| *f > INT_TOL)
            .max_by(|a, b| a.1.total_cmp(&b.1));

        match frac {
            None => {
                // Integer feasible: candidate incumbent.
                if incumbent.as_ref().map(|(_, o)| rel.obj < *o).unwrap_or(true) {
                    incumbent = Some((rel.x.clone(), rel.obj));
                }
            }
            Some((vi, _)) => {
                // Rounding heuristic for an early incumbent: fix ints to the
                // rounded LP values and re-solve the continuous rest.
                if incumbent.is_none() && node.depth == 0 {
                    if let Some(cand) = round_and_repair(p, &rel.x, &int_vars) {
                        let obj = p.objective_value(&cand);
                        incumbent = Some((cand, obj));
                    }
                }
                let xv = rel.x[vi];
                let (lb, ub) = (sub.vars[vi].lb, sub.vars[vi].ub);
                // Down child: x <= floor.
                if xv.floor() >= lb - INT_TOL {
                    let mut bs = node.bounds.clone();
                    bs.push((vi, lb, xv.floor()));
                    heap.push(Node { bound: rel.obj, bounds: bs, depth: node.depth + 1 });
                }
                // Up child: x >= ceil.
                if xv.ceil() <= ub + INT_TOL {
                    let mut bs = node.bounds.clone();
                    bs.push((vi, xv.ceil(), ub));
                    heap.push(Node { bound: rel.obj, bounds: bs, depth: node.depth + 1 });
                }
            }
        }
    }

    if heap.is_empty() {
        // Search exhausted: the bound equals the incumbent (or the problem
        // has no integer-feasible point).
        if let Some((_, obj)) = &incumbent {
            best_bound = *obj;
        }
    }

    match incumbent {
        Some((x, obj)) => {
            let gap = gap_of(obj, best_bound);
            let status = if gap <= limits.rel_gap {
                MilpStatus::Optimal
            } else {
                MilpStatus::Feasible
            };
            MilpSolution { status, x, obj, bound: best_bound, gap, nodes }
        }
        None => {
            let exhausted = heap.is_empty() && nodes <= limits.max_nodes;
            MilpSolution {
                status: if exhausted { MilpStatus::Infeasible } else { MilpStatus::Unknown },
                x: vec![],
                obj: f64::INFINITY,
                bound: best_bound,
                gap: f64::INFINITY,
                nodes,
            }
        }
    }
}

fn gap_of(incumbent: f64, bound: f64) -> f64 {
    if incumbent == bound {
        0.0
    } else {
        (incumbent - bound).abs() / incumbent.abs().max(1e-12)
    }
}

/// Fix all integer vars at rounded LP values, re-solve for the continuous
/// vars, and return the point if feasible. Tries round-to-nearest first and
/// falls back to floor (feasible by construction for packing-style `<=`
/// constraints with non-negative coefficients).
fn round_and_repair(p: &Problem, x: &[f64], int_vars: &[usize]) -> Option<Vec<f64>> {
    for round in [f64::round as fn(f64) -> f64, f64::floor as fn(f64) -> f64] {
        let mut sub = p.relaxed();
        for &vi in int_vars {
            let r = round(x[vi]).clamp(p.vars[vi].lb, p.vars[vi].ub);
            sub.vars[vi].lb = r;
            sub.vars[vi].ub = r;
        }
        let sol = simplex::solve(&sub);
        if sol.status == LpStatus::Optimal && p.is_feasible(&sol.x, 1e-6) {
            return Some(sol.x);
        }
    }
    None
}

/// True if every Int/Bin variable of `p` is integral in `x`.
pub fn is_integral(p: &Problem, x: &[f64]) -> bool {
    p.vars.iter().enumerate().all(|(i, v)| {
        v.kind == VarKind::Cont || (x[i] - x[i].round()).abs() <= INT_TOL
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::milp::lp::{Cmp, Problem};

    fn limits() -> BnbLimits {
        BnbLimits::default()
    }

    #[test]
    fn knapsack_small() {
        // max 10a + 13b + 7c, 3a + 4b + 2c <= 6, binary -> a=0? check:
        // options: a+b (w7 no), a+c (w5, v17), b+c (w6, v20) <- best.
        let mut p = Problem::new();
        let a = p.bin("a");
        let b = p.bin("b");
        let c = p.bin("c");
        p.constrain(vec![(a, 3.0), (b, 4.0), (c, 2.0)], Cmp::Le, 6.0);
        p.minimize(vec![(a, -10.0), (b, -13.0), (c, -7.0)]);
        let sol = solve(&p, &limits());
        assert_eq!(sol.status, MilpStatus::Optimal);
        assert!((sol.obj + 20.0).abs() < 1e-6, "{sol:?}");
        assert_eq!(sol.x[0].round() as i64, 0);
        assert_eq!(sol.x[1].round() as i64, 1);
        assert_eq!(sol.x[2].round() as i64, 1);
    }

    #[test]
    fn integer_rounding_is_not_assumed() {
        // Classic: LP optimum fractional, IP optimum far from rounding.
        // max y s.t. -x + y <= 0.5, x + y <= 3.5, x,y int >= 0.
        let mut p = Problem::new();
        let x = p.int("x", 0.0, 10.0);
        let y = p.int("y", 0.0, 10.0);
        p.constrain(vec![(x, -1.0), (y, 1.0)], Cmp::Le, 0.5);
        p.constrain(vec![(x, 1.0), (y, 1.0)], Cmp::Le, 3.5);
        p.minimize(vec![(y, -1.0)]);
        let sol = solve(&p, &limits());
        assert_eq!(sol.status, MilpStatus::Optimal);
        assert!((sol.obj + 1.0).abs() < 1e-6, "y*=1, got {sol:?}");
    }

    #[test]
    fn infeasible_ip_detected() {
        // 2x = 1 with x integer.
        let mut p = Problem::new();
        let x = p.int("x", 0.0, 10.0);
        p.constrain(vec![(x, 2.0)], Cmp::Eq, 1.0);
        p.minimize(vec![(x, 1.0)]);
        let sol = solve(&p, &limits());
        assert_eq!(sol.status, MilpStatus::Infeasible);
    }

    #[test]
    fn lp_infeasible_detected() {
        let mut p = Problem::new();
        let x = p.bin("x");
        p.constrain(vec![(x, 1.0)], Cmp::Ge, 2.0);
        p.minimize(vec![(x, 1.0)]);
        assert_eq!(solve(&p, &limits()).status, MilpStatus::Infeasible);
    }

    #[test]
    fn unbounded_detected() {
        let mut p = Problem::new();
        let x = p.int("x", 0.0, f64::INFINITY);
        p.minimize(vec![(x, -1.0)]);
        assert_eq!(solve(&p, &limits()).status, MilpStatus::Unbounded);
    }

    #[test]
    fn continuous_problem_solves_at_root() {
        let mut p = Problem::new();
        let x = p.cont("x", 0.0, 4.0);
        p.minimize(vec![(x, -1.0)]);
        let sol = solve(&p, &limits());
        assert_eq!(sol.status, MilpStatus::Optimal);
        assert_eq!(sol.nodes, 1);
        assert!((sol.obj + 4.0).abs() < 1e-9);
    }

    #[test]
    fn mixed_integer_with_continuous_part() {
        // min -x - 10 b, x <= 3 + 2b, x cont in [0,10], b bin.
        // b=1: x=5, obj -15. b=0: x=3, obj -3.
        let mut p = Problem::new();
        let x = p.cont("x", 0.0, 10.0);
        let b = p.bin("b");
        p.constrain(vec![(x, 1.0), (b, -2.0)], Cmp::Le, 3.0);
        p.minimize(vec![(x, -1.0), (b, -10.0)]);
        let sol = solve(&p, &limits());
        assert_eq!(sol.status, MilpStatus::Optimal);
        assert!((sol.obj + 15.0).abs() < 1e-6);
        assert!((sol.x[0] - 5.0).abs() < 1e-6);
    }

    #[test]
    fn node_budget_returns_feasible_with_gap() {
        // A 12-item knapsack; 1-node budget forces an early stop, but the
        // rounding heuristic should still give an incumbent.
        let mut p = Problem::new();
        let vars: Vec<_> = (0..12).map(|i| p.bin(&format!("b{i}"))).collect();
        let w: Vec<f64> = (0..12).map(|i| 2.0 + (i as f64 * 7.3) % 5.0).collect();
        let v: Vec<f64> = (0..12).map(|i| 1.0 + (i as f64 * 3.7) % 9.0).collect();
        p.constrain(vars.iter().zip(&w).map(|(b, w)| (*b, *w)).collect(), Cmp::Le, 20.0);
        p.minimize(vars.iter().zip(&v).map(|(b, v)| (*b, -*v)).collect());
        let lim = BnbLimits { max_nodes: 1, ..limits() };
        let sol = solve(&p, &lim);
        assert!(matches!(sol.status, MilpStatus::Feasible | MilpStatus::Optimal), "{sol:?}");
        assert!(p.is_feasible(&sol.x, 1e-6));
        assert!(sol.bound <= sol.obj + 1e-9);
    }

    #[test]
    fn exhaustive_matches_bruteforce_on_random_binaries() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(7);
        for trial in 0..10 {
            let n = 8;
            let mut p = Problem::new();
            let vars: Vec<_> = (0..n).map(|i| p.bin(&format!("b{i}"))).collect();
            let w: Vec<f64> = (0..n).map(|_| rng.range_f64(1.0, 5.0)).collect();
            let c: Vec<f64> = (0..n).map(|_| rng.range_f64(-5.0, 5.0)).collect();
            let cap = rng.range_f64(5.0, 12.0);
            p.constrain(vars.iter().zip(&w).map(|(b, w)| (*b, *w)).collect(), Cmp::Le, cap);
            p.minimize(vars.iter().zip(&c).map(|(b, c)| (*b, *c)).collect());
            let sol = solve(&p, &limits());
            // Brute force.
            let mut best = f64::INFINITY;
            for mask in 0..(1u32 << n) {
                let weight: f64 =
                    (0..n).filter(|i| mask >> i & 1 == 1).map(|i| w[i]).sum();
                if weight <= cap {
                    let cost: f64 =
                        (0..n).filter(|i| mask >> i & 1 == 1).map(|i| c[i]).sum();
                    best = best.min(cost);
                }
            }
            assert_eq!(sol.status, MilpStatus::Optimal, "trial {trial}");
            assert!((sol.obj - best).abs() < 1e-6, "trial {trial}: {} vs {best}", sol.obj);
        }
    }
}
