//! Generic best-first branch & bound over [`Problem`]s with Int/Bin vars —
//! sequential or multi-worker.
//!
//! This is the "SCIP as a black box" role from the paper (§III.B): LP
//! relaxations from [`super::simplex`], most-fractional branching with bound
//! tightening, rounding-based incumbents, node/gap/time budgets. It is exact
//! on small/medium instances and *anytime* on large ones — it always returns
//! the best incumbent plus the proven lower bound and gap.
//!
//! # Parallel search
//!
//! With [`BnbLimits::workers`] > 1 the search runs as a worker pool
//! (over [`crate::util::threadpool::ThreadPool`]) sharing
//!
//! * a **mutex-guarded best-bound frontier** (binary heap ordered by LP
//!   bound, ties broken by deterministic node id) plus per-worker in-flight
//!   bookkeeping, so the global lower bound is always
//!   `min(heap top, in-flight nodes)`;
//! * an **atomic incumbent objective** (`AtomicU64` of the f64 bits) that
//!   workers read lock-free when pruning — the full incumbent point sits
//!   behind its own mutex and is only locked on improvement;
//! * per-worker simplex solves: [`super::simplex`] state is built per node,
//!   so the LP layer needs no locking, only `Send` data.
//!
//! **Determinism.** Node ids are heap-numbering paths (root 1, down-child
//! `2·id`, up-child `2·id+1`), so a node's id depends only on its position
//! in the branching tree, never on thread scheduling. Incumbents are
//! accepted only when *strictly* better, with exact-tie acceptance going to
//! the smaller node id. With `rel_gap == 0` and budgets that don't bind,
//! every subtree that could hold a strictly better point has a bound below
//! the optimum and is explored under any schedule — so parallel and
//! sequential runs return **identical objectives (bit-for-bit)** and
//! statuses (verified by `rust/tests/solver_properties.rs`). The node-id
//! tie-break keeps the reported *point* stable across most schedules too,
//! but when several distinct points attain the same objective the chosen
//! one may vary; only the objective and status are guaranteed. With a
//! nonzero gap or binding node/time budgets, runs agree within the
//! configured tolerance but may differ in which within-gap incumbent they
//! report.
//!
//! The full-size 128×16 partitioning MILP is solved by the structure-aware
//! specialization in `coordinator::partitioner::milp`, which is validated
//! against this generic solver on small instances.

use std::cmp::Ordering;
use std::collections::BinaryHeap;
use std::sync::atomic::{AtomicU64, Ordering as AtOrd};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::util::threadpool::ThreadPool;

use super::lp::{Problem, VarKind};
use super::simplex::{self, LpStatus};

/// Integrality tolerance.
pub const INT_TOL: f64 = 1e-6;

/// Search limits. Defaults are generous for test-sized problems.
#[derive(Debug, Clone)]
pub struct BnbLimits {
    pub max_nodes: usize,
    /// Relative optimality gap at which the search stops.
    pub rel_gap: f64,
    pub time_limit_secs: f64,
    /// Worker threads exploring the frontier (1 = in-thread sequential;
    /// clamped to at least 1).
    pub workers: usize,
}

impl Default for BnbLimits {
    fn default() -> Self {
        BnbLimits { max_nodes: 100_000, rel_gap: 1e-6, time_limit_secs: 60.0, workers: 1 }
    }
}

/// Outcome of a MILP solve.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MilpStatus {
    /// Incumbent proven optimal within `rel_gap`.
    Optimal,
    /// Stopped on a budget with a feasible incumbent (gap reported).
    Feasible,
    Infeasible,
    Unbounded,
    /// No incumbent found within the budget (and not proven infeasible).
    Unknown,
}

/// MILP solve result.
#[derive(Debug, Clone)]
pub struct MilpSolution {
    pub status: MilpStatus,
    /// Best integer-feasible point (valid when status is Optimal/Feasible).
    pub x: Vec<f64>,
    pub obj: f64,
    /// Proven lower bound on the optimum.
    pub bound: f64,
    /// Relative gap between incumbent and bound.
    pub gap: f64,
    pub nodes: usize,
}

struct Node {
    /// Lower bound inherited from the parent LP (priority key).
    bound: f64,
    /// Deterministic heap-numbering id: root 1, children `2id` / `2id+1`.
    /// Depends only on the branching path, not on thread scheduling.
    id: u128,
    /// (var index, new lb, new ub) deltas relative to the root problem.
    bounds: Vec<(usize, f64, f64)>,
    depth: usize,
}

impl Node {
    /// Child id along branch direction `dir` (0 = down, 1 = up). Saturates
    /// at the parent id beyond 127 levels — ties then lose their
    /// deterministic order, but no real search goes that deep.
    fn child_id(&self, dir: u128) -> u128 {
        self.id.checked_mul(2).and_then(|i| i.checked_add(dir)).unwrap_or(self.id)
    }
}

impl PartialEq for Node {
    fn eq(&self, other: &Self) -> bool {
        self.bound == other.bound && self.id == other.id
    }
}
impl Eq for Node {}
impl PartialOrd for Node {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Node {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap; we want the *smallest* bound first,
        // ties broken toward the smallest node id (deterministic pops).
        other.bound.total_cmp(&self.bound).then(other.id.cmp(&self.id))
    }
}

/// Best integer-feasible point found so far.
struct Incumbent {
    x: Vec<f64>,
    obj: f64,
    /// Id of the node that produced it (deterministic tie-break).
    id: u128,
}

/// Why the search stopped before draining the frontier.
#[derive(Clone, Copy, PartialEq)]
enum Stop {
    /// Remaining frontier proven within `rel_gap` of the incumbent.
    Proven,
    /// Node/time budget exhausted.
    Budget,
}

/// Frontier + termination bookkeeping, all behind one mutex. The lock is
/// held only for heap operations — LP solves (the dominant cost) run
/// outside it.
struct Frontier {
    heap: BinaryHeap<Node>,
    /// Bound of the node each worker is currently expanding (`None` =
    /// idle). The global lower bound is min(heap top, these).
    in_flight: Vec<Option<f64>>,
    /// Nodes handed to workers so far (the `max_nodes` meter).
    nodes: usize,
    stop: Option<Stop>,
    /// Global lower bound captured at the moment the search stopped.
    stop_bound: f64,
    /// Smallest bound of any subtree dropped on a node-LP solver failure
    /// (`+inf` when none). Caps the reported bound and blocks the
    /// natural-drain paths from fabricating `Optimal` / `Infeasible` over
    /// unexplored mass.
    lost_bound: f64,
}

/// Everything the workers share.
struct Search {
    problem: Problem,
    relaxed: Problem,
    int_vars: Vec<usize>,
    limits: BnbLimits,
    start: Instant,
    frontier: Mutex<Frontier>,
    incumbent: Mutex<Option<Incumbent>>,
    /// f64 bits of the incumbent objective (`+inf` when none): the
    /// lock-free bound read workers prune against.
    incumbent_obj: AtomicU64,
}

impl Search {
    fn incumbent_obj(&self) -> f64 {
        f64::from_bits(self.incumbent_obj.load(AtOrd::Acquire))
    }

    /// Offer a candidate incumbent. Accepts strictly better objectives;
    /// exact ties go to the smaller node id so the chosen point is
    /// schedule-independent.
    fn offer_incumbent(&self, x: Vec<f64>, obj: f64, id: u128) {
        let mut inc = self.incumbent.lock().unwrap();
        let (better, improved) = match &*inc {
            None => (true, true),
            Some(cur) => (obj < cur.obj || (obj == cur.obj && id < cur.id), obj < cur.obj),
        };
        if better {
            *inc = Some(Incumbent { x, obj, id });
            self.incumbent_obj.store(obj.to_bits(), AtOrd::Release);
            if improved {
                // Time-to-incumbent-improvement from solve start — the
                // anytime profile of the search. Recorded via the
                // process-global registry (the solver has no session in
                // reach); purely observational, never steers the search.
                crate::obs::global().observe(
                    "bnb_incumbent_improvement_secs",
                    "",
                    self.start.elapsed().as_secs_f64(),
                );
            }
        }
    }

    /// Expand one node: solve its LP, update the incumbent or push
    /// children. Runs entirely outside the frontier lock.
    fn expand(&self, node: Node) {
        let mut sub = self.relaxed.clone();
        for &(vi, lb, ub) in &node.bounds {
            sub.vars[vi].lb = lb;
            sub.vars[vi].ub = ub;
        }
        let rel = simplex::solve(&sub);
        match rel.status {
            LpStatus::Optimal => {}
            LpStatus::Infeasible => return, // genuinely pruned subtree
            LpStatus::Unbounded | LpStatus::IterLimit => {
                // Solver failure: the subtree is dropped UNEXPLORED, so its
                // inherited bound must keep capping the reported bound —
                // otherwise a later natural drain would claim Optimal (or
                // Infeasible) over mass that was never searched.
                let mut f = self.frontier.lock().unwrap();
                f.lost_bound = f.lost_bound.min(node.bound);
                return;
            }
        }
        let inc_obj = self.incumbent_obj();
        if inc_obj.is_finite()
            && rel.obj >= inc_obj - self.limits.rel_gap * inc_obj.abs().max(1.0)
        {
            return; // dominated
        }

        // Find the most fractional integer variable.
        let frac = self
            .int_vars
            .iter()
            .map(|&vi| (vi, (rel.x[vi] - rel.x[vi].round()).abs()))
            .filter(|(_, f)| *f > INT_TOL)
            .max_by(|a, b| a.1.total_cmp(&b.1));

        match frac {
            None => {
                // Integer feasible: candidate incumbent.
                self.offer_incumbent(rel.x, rel.obj, node.id);
            }
            Some((vi, _)) => {
                // Rounding heuristic for an early incumbent: fix ints to the
                // rounded LP values and re-solve the continuous rest. Only
                // the root tries this, so it runs exactly once per solve.
                if node.depth == 0 && !self.incumbent_obj().is_finite() {
                    if let Some(cand) = round_and_repair(&self.problem, &rel.x, &self.int_vars) {
                        let obj = self.problem.objective_value(&cand);
                        self.offer_incumbent(cand, obj, node.id);
                    }
                }
                let xv = rel.x[vi];
                let (lb, ub) = (sub.vars[vi].lb, sub.vars[vi].ub);
                let mut children = Vec::with_capacity(2);
                // Down child: x <= floor.
                if xv.floor() >= lb - INT_TOL {
                    let mut bs = node.bounds.clone();
                    bs.push((vi, lb, xv.floor()));
                    children.push(Node {
                        bound: rel.obj,
                        id: node.child_id(0),
                        bounds: bs,
                        depth: node.depth + 1,
                    });
                }
                // Up child: x >= ceil.
                if xv.ceil() <= ub + INT_TOL {
                    let mut bs = node.bounds.clone();
                    bs.push((vi, xv.ceil(), ub));
                    children.push(Node {
                        bound: rel.obj,
                        id: node.child_id(1),
                        bounds: bs,
                        depth: node.depth + 1,
                    });
                }
                let mut f = self.frontier.lock().unwrap();
                for c in children {
                    f.heap.push(c);
                }
            }
        }
    }

    /// One worker: pop best-bound nodes until the frontier drains or a
    /// termination condition fires.
    fn worker_loop(&self, w: usize) {
        loop {
            let node = {
                let mut f = self.frontier.lock().unwrap();
                if f.stop.is_some() {
                    break;
                }
                let Some(node) = f.heap.pop() else {
                    if f.in_flight.iter().all(Option::is_none) {
                        break; // frontier fully drained: search exhausted
                    }
                    // Peer panics clear their marker (and stop the search)
                    // via the InFlight guard; the time limit is a last
                    // backstop so this wait can never spin forever even if
                    // a marker somehow fails to retire.
                    if self.start.elapsed().as_secs_f64() > self.limits.time_limit_secs {
                        let global_bound = f
                            .in_flight
                            .iter()
                            .flatten()
                            .fold(f64::INFINITY, |acc, &b| acc.min(b));
                        f.stop = Some(Stop::Budget);
                        f.stop_bound = global_bound;
                        break;
                    }
                    // Peers are still expanding nodes that may push new
                    // children; wait off-lock.
                    drop(f);
                    std::thread::sleep(Duration::from_micros(50));
                    continue;
                };
                // Global lower bound: the popped node (heap minimum) vs
                // whatever peers are still expanding.
                let global_bound = f
                    .in_flight
                    .iter()
                    .flatten()
                    .fold(node.bound, |acc, &b| acc.min(b));
                let inc_obj = self.incumbent_obj();
                if inc_obj.is_finite()
                    && (global_bound >= inc_obj
                        || gap_of(inc_obj, global_bound) <= self.limits.rel_gap)
                {
                    // Everything left is proven within tolerance.
                    f.stop = Some(Stop::Proven);
                    f.stop_bound = global_bound.min(inc_obj);
                    break;
                }
                if f.nodes >= self.limits.max_nodes
                    || self.start.elapsed().as_secs_f64() > self.limits.time_limit_secs
                {
                    f.stop = Some(Stop::Budget);
                    f.stop_bound = global_bound;
                    break;
                }
                f.nodes += 1;
                f.in_flight[w] = Some(node.bound);
                node
            };
            let _marker = InFlight { search: self, w };
            self.expand(node);
        }
    }
}

/// Clears a worker's in-flight marker when expansion finishes — including
/// by panic, so peers never wait on a bound that will not retire. A panic
/// also marks the whole search as failed: the node's subtree is lost, so a
/// clean "Optimal" from the natural-drain path would be a silent wrong
/// answer (the pool's `catch_unwind` keeps the worker alive, so nothing
/// else would surface it).
struct InFlight<'a> {
    search: &'a Search,
    w: usize,
}

impl Drop for InFlight<'_> {
    fn drop(&mut self) {
        let panicked = std::thread::panicking();
        if let Ok(mut f) = self.search.frontier.lock() {
            f.in_flight[self.w] = None;
            if panicked {
                // An abandoned subtree leaves nothing provable below the
                // incumbent: force a budget-style stop with a -inf bound so
                // the result reports Feasible/Unknown, never Optimal.
                f.stop = Some(Stop::Budget);
                f.stop_bound = f64::NEG_INFINITY;
            }
        }
    }
}

/// Solve a mixed-integer problem by branch & bound (sequential or
/// parallel per [`BnbLimits::workers`]).
pub fn solve(p: &Problem, limits: &BnbLimits) -> MilpSolution {
    let _span = crate::span!("bnb_solve");
    let start = Instant::now();
    let sol = solve_from(p, limits, start);
    let reg = crate::obs::global();
    reg.inc("bnb_nodes_total", "", sol.nodes as u64);
    reg.observe("bnb_solve_secs", "", start.elapsed().as_secs_f64());
    sol
}

fn solve_from(p: &Problem, limits: &BnbLimits, start: Instant) -> MilpSolution {
    let workers = limits.workers.max(1);

    // Root relaxation (solved on the caller thread: cheap early exits).
    let root = simplex::solve(&p.relaxed());
    match root.status {
        LpStatus::Infeasible => {
            return MilpSolution {
                status: MilpStatus::Infeasible,
                x: vec![],
                obj: f64::INFINITY,
                bound: f64::INFINITY,
                gap: 0.0,
                nodes: 1,
            }
        }
        LpStatus::Unbounded => {
            return MilpSolution {
                status: MilpStatus::Unbounded,
                x: vec![],
                obj: f64::NEG_INFINITY,
                bound: f64::NEG_INFINITY,
                gap: 0.0,
                nodes: 1,
            }
        }
        LpStatus::IterLimit => {
            return MilpSolution {
                status: MilpStatus::Unknown,
                x: vec![],
                obj: f64::INFINITY,
                bound: f64::NEG_INFINITY,
                gap: f64::INFINITY,
                nodes: 1,
            }
        }
        LpStatus::Optimal => {}
    }

    let mut heap = BinaryHeap::new();
    heap.push(Node { bound: root.obj, id: 1, bounds: vec![], depth: 0 });
    let search = Arc::new(Search {
        problem: p.clone(),
        relaxed: p.relaxed(),
        int_vars: p.int_vars(),
        limits: BnbLimits { workers, ..limits.clone() },
        start,
        frontier: Mutex::new(Frontier {
            heap,
            in_flight: vec![None; workers],
            nodes: 0,
            stop: None,
            stop_bound: root.obj,
            lost_bound: f64::INFINITY,
        }),
        incumbent: Mutex::new(None),
        incumbent_obj: AtomicU64::new(f64::INFINITY.to_bits()),
    });

    if workers == 1 {
        search.worker_loop(0);
    } else {
        let pool = ThreadPool::new(workers);
        for w in 0..workers {
            let s = Arc::clone(&search);
            pool.execute(move || s.worker_loop(w));
        }
        drop(pool); // join all workers
    }

    // Assemble the result from the final shared state.
    let frontier = search.frontier.lock().unwrap();
    let incumbent = search.incumbent.lock().unwrap().take();
    let nodes = frontier.nodes;
    match incumbent {
        Some(inc) => {
            let bound = match frontier.stop {
                // Natural drain: proven optimal, unless a subtree was lost.
                None => inc.obj,
                Some(_) => frontier.stop_bound.min(inc.obj),
            };
            let bound = bound.min(frontier.lost_bound);
            let gap = gap_of(inc.obj, bound);
            let status = if gap <= search.limits.rel_gap {
                MilpStatus::Optimal
            } else {
                MilpStatus::Feasible
            };
            MilpSolution { status, x: inc.x, obj: inc.obj, bound, gap, nodes }
        }
        None => {
            // Infeasibility is only proven by a drain with no lost subtrees.
            let exhausted = frontier.stop.is_none() && frontier.lost_bound == f64::INFINITY;
            MilpSolution {
                status: if exhausted { MilpStatus::Infeasible } else { MilpStatus::Unknown },
                x: vec![],
                obj: f64::INFINITY,
                bound: if exhausted {
                    f64::INFINITY
                } else if frontier.stop.is_none() {
                    frontier.lost_bound
                } else {
                    frontier.stop_bound.min(frontier.lost_bound)
                },
                gap: f64::INFINITY,
                nodes,
            }
        }
    }
}

fn gap_of(incumbent: f64, bound: f64) -> f64 {
    if incumbent == bound {
        0.0
    } else {
        (incumbent - bound).abs() / incumbent.abs().max(1e-12)
    }
}

/// Fix all integer vars at rounded LP values, re-solve for the continuous
/// vars, and return the point if feasible. Tries round-to-nearest first and
/// falls back to floor (feasible by construction for packing-style `<=`
/// constraints with non-negative coefficients).
fn round_and_repair(p: &Problem, x: &[f64], int_vars: &[usize]) -> Option<Vec<f64>> {
    for round in [f64::round as fn(f64) -> f64, f64::floor as fn(f64) -> f64] {
        let mut sub = p.relaxed();
        for &vi in int_vars {
            let r = round(x[vi]).clamp(p.vars[vi].lb, p.vars[vi].ub);
            sub.vars[vi].lb = r;
            sub.vars[vi].ub = r;
        }
        let sol = simplex::solve(&sub);
        if sol.status == LpStatus::Optimal && p.is_feasible(&sol.x, 1e-6) {
            return Some(sol.x);
        }
    }
    None
}

/// True if every Int/Bin variable of `p` is integral in `x`.
pub fn is_integral(p: &Problem, x: &[f64]) -> bool {
    p.vars.iter().enumerate().all(|(i, v)| {
        v.kind == VarKind::Cont || (x[i] - x[i].round()).abs() <= INT_TOL
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::milp::lp::{Cmp, Problem};

    fn limits() -> BnbLimits {
        BnbLimits::default()
    }

    #[test]
    fn knapsack_small() {
        // max 10a + 13b + 7c, 3a + 4b + 2c <= 6, binary -> a=0? check:
        // options: a+b (w7 no), a+c (w5, v17), b+c (w6, v20) <- best.
        let mut p = Problem::new();
        let a = p.bin("a");
        let b = p.bin("b");
        let c = p.bin("c");
        p.constrain(vec![(a, 3.0), (b, 4.0), (c, 2.0)], Cmp::Le, 6.0);
        p.minimize(vec![(a, -10.0), (b, -13.0), (c, -7.0)]);
        let sol = solve(&p, &limits());
        assert_eq!(sol.status, MilpStatus::Optimal);
        assert!((sol.obj + 20.0).abs() < 1e-6, "{sol:?}");
        assert_eq!(sol.x[0].round() as i64, 0);
        assert_eq!(sol.x[1].round() as i64, 1);
        assert_eq!(sol.x[2].round() as i64, 1);
    }

    #[test]
    fn knapsack_small_parallel_matches() {
        let mut p = Problem::new();
        let a = p.bin("a");
        let b = p.bin("b");
        let c = p.bin("c");
        p.constrain(vec![(a, 3.0), (b, 4.0), (c, 2.0)], Cmp::Le, 6.0);
        p.minimize(vec![(a, -10.0), (b, -13.0), (c, -7.0)]);
        let seq = solve(&p, &BnbLimits { rel_gap: 0.0, ..limits() });
        let par = solve(&p, &BnbLimits { rel_gap: 0.0, workers: 4, ..limits() });
        assert_eq!(seq.status, MilpStatus::Optimal);
        assert_eq!(par.status, MilpStatus::Optimal);
        assert_eq!(seq.obj.to_bits(), par.obj.to_bits(), "{} vs {}", seq.obj, par.obj);
        assert!(p.is_feasible(&par.x, 1e-6));
    }

    #[test]
    fn integer_rounding_is_not_assumed() {
        // Classic: LP optimum fractional, IP optimum far from rounding.
        // max y s.t. -x + y <= 0.5, x + y <= 3.5, x,y int >= 0.
        let mut p = Problem::new();
        let x = p.int("x", 0.0, 10.0);
        let y = p.int("y", 0.0, 10.0);
        p.constrain(vec![(x, -1.0), (y, 1.0)], Cmp::Le, 0.5);
        p.constrain(vec![(x, 1.0), (y, 1.0)], Cmp::Le, 3.5);
        p.minimize(vec![(y, -1.0)]);
        let sol = solve(&p, &limits());
        assert_eq!(sol.status, MilpStatus::Optimal);
        assert!((sol.obj + 1.0).abs() < 1e-6, "y*=1, got {sol:?}");
    }

    #[test]
    fn infeasible_ip_detected() {
        // 2x = 1 with x integer.
        let mut p = Problem::new();
        let x = p.int("x", 0.0, 10.0);
        p.constrain(vec![(x, 2.0)], Cmp::Eq, 1.0);
        p.minimize(vec![(x, 1.0)]);
        for workers in [1, 4] {
            let sol = solve(&p, &BnbLimits { workers, ..limits() });
            assert_eq!(sol.status, MilpStatus::Infeasible, "workers={workers}");
        }
    }

    #[test]
    fn lp_infeasible_detected() {
        let mut p = Problem::new();
        let x = p.bin("x");
        p.constrain(vec![(x, 1.0)], Cmp::Ge, 2.0);
        p.minimize(vec![(x, 1.0)]);
        assert_eq!(solve(&p, &limits()).status, MilpStatus::Infeasible);
    }

    #[test]
    fn unbounded_detected() {
        let mut p = Problem::new();
        let x = p.int("x", 0.0, f64::INFINITY);
        p.minimize(vec![(x, -1.0)]);
        assert_eq!(solve(&p, &limits()).status, MilpStatus::Unbounded);
    }

    #[test]
    fn continuous_problem_solves_at_root() {
        let mut p = Problem::new();
        let x = p.cont("x", 0.0, 4.0);
        p.minimize(vec![(x, -1.0)]);
        let sol = solve(&p, &limits());
        assert_eq!(sol.status, MilpStatus::Optimal);
        assert_eq!(sol.nodes, 1);
        assert!((sol.obj + 4.0).abs() < 1e-9);
    }

    #[test]
    fn mixed_integer_with_continuous_part() {
        // min -x - 10 b, x <= 3 + 2b, x cont in [0,10], b bin.
        // b=1: x=5, obj -15. b=0: x=3, obj -3.
        let mut p = Problem::new();
        let x = p.cont("x", 0.0, 10.0);
        let b = p.bin("b");
        p.constrain(vec![(x, 1.0), (b, -2.0)], Cmp::Le, 3.0);
        p.minimize(vec![(x, -1.0), (b, -10.0)]);
        for workers in [1, 3] {
            let sol = solve(&p, &BnbLimits { workers, ..limits() });
            assert_eq!(sol.status, MilpStatus::Optimal, "workers={workers}");
            assert!((sol.obj + 15.0).abs() < 1e-6);
            assert!((sol.x[0] - 5.0).abs() < 1e-6);
        }
    }

    #[test]
    fn node_budget_returns_feasible_with_gap() {
        // A 12-item knapsack; 1-node budget forces an early stop, but the
        // rounding heuristic should still give an incumbent.
        let mut p = Problem::new();
        let vars: Vec<_> = (0..12).map(|i| p.bin(&format!("b{i}"))).collect();
        let w: Vec<f64> = (0..12).map(|i| 2.0 + (i as f64 * 7.3) % 5.0).collect();
        let v: Vec<f64> = (0..12).map(|i| 1.0 + (i as f64 * 3.7) % 9.0).collect();
        p.constrain(vars.iter().zip(&w).map(|(b, w)| (*b, *w)).collect(), Cmp::Le, 20.0);
        p.minimize(vars.iter().zip(&v).map(|(b, v)| (*b, -*v)).collect());
        let lim = BnbLimits { max_nodes: 1, ..limits() };
        let sol = solve(&p, &lim);
        assert!(matches!(sol.status, MilpStatus::Feasible | MilpStatus::Optimal), "{sol:?}");
        assert!(p.is_feasible(&sol.x, 1e-6));
        assert!(sol.bound <= sol.obj + 1e-9);
    }

    #[test]
    fn exhaustive_matches_bruteforce_on_random_binaries() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(7);
        for trial in 0..10 {
            let n = 8;
            let mut p = Problem::new();
            let vars: Vec<_> = (0..n).map(|i| p.bin(&format!("b{i}"))).collect();
            let w: Vec<f64> = (0..n).map(|_| rng.range_f64(1.0, 5.0)).collect();
            let c: Vec<f64> = (0..n).map(|_| rng.range_f64(-5.0, 5.0)).collect();
            let cap = rng.range_f64(5.0, 12.0);
            p.constrain(vars.iter().zip(&w).map(|(b, w)| (*b, *w)).collect(), Cmp::Le, cap);
            p.minimize(vars.iter().zip(&c).map(|(b, c)| (*b, *c)).collect());
            let sol = solve(&p, &limits());
            // Brute force.
            let mut best = f64::INFINITY;
            for mask in 0..(1u32 << n) {
                let weight: f64 =
                    (0..n).filter(|i| mask >> i & 1 == 1).map(|i| w[i]).sum();
                if weight <= cap {
                    let cost: f64 =
                        (0..n).filter(|i| mask >> i & 1 == 1).map(|i| c[i]).sum();
                    best = best.min(cost);
                }
            }
            assert_eq!(sol.status, MilpStatus::Optimal, "trial {trial}");
            assert!((sol.obj - best).abs() < 1e-6, "trial {trial}: {} vs {best}", sol.obj);
        }
    }
}
