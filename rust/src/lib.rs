//! # cloudshapes
//!
//! Production-quality reproduction of *"Seeing Shapes in Clouds: On the
//! Performance-Cost trade-off for Heterogeneous Infrastructure-as-a-Service"*
//! (Inggs, Thomas, Constantinides, Luk — 2015).
//!
//! The library finds **Pareto-optimal latency↔cost trade-offs** for
//! workloads of atomic, divisible tasks (Monte Carlo option pricing)
//! partitioned across heterogeneous IaaS platforms (CPU / GPU / FPGA), by
//! solving a family of cost-constrained Mixed-ILP makespan problems
//! (ε-constraint method) and comparing against heuristic partitioners.
//!
//! ## Start here: the [`api`] facade
//!
//! [`api`] is the single public surface. Build a [`api::TradeoffSession`]
//! with the builder, then partition / sweep / execute through it:
//!
//! ```no_run
//! use cloudshapes::api::SessionBuilder;
//!
//! let session = SessionBuilder::quick().partitioner("milp").build()?;
//! let frontier = session.pareto_frontier()?;       // ε-constraint sweep
//! let run = session.evaluate(Some(2.5))?;          // partition + execute
//! # Ok::<(), cloudshapes::api::CloudshapesError>(())
//! ```
//!
//! - Errors: every fallible API returns the typed
//!   [`api::CloudshapesError`] (`Config` / `Workload` / `Solver` /
//!   `Platform` / `Runtime` / `Protocol`) — no stringly-typed results.
//! - Strategies: [`api::PartitionerRegistry`] maps names to factories;
//!   custom strategies plug in without touching the coordinator.
//! - Service mode: `cloudshapes serve` speaks the versioned
//!   [`api::protocol`] (`{"v":1,"op":...}`) over newline-delimited
//!   JSON/TCP (or negotiated length-prefixed `lp1` framing), with
//!   structured error payloads. The [`serve`] plane runs one
//!   readiness-driven event loop with consistent-hash worker shards and
//!   admission control.
//! - Online mode: `serve --scheduler` admits pricing jobs continuously —
//!   the [`coordinator::scheduler`] re-optimises the allocation every
//!   epoch and re-fits latency models from measured chunk latencies
//!   ([`models::online`]).
//!
//! Prose documentation lives in `docs/`: `ARCHITECTURE.md` (module map +
//! paper cross-reference), `PROTOCOL.md` (the full wire reference),
//! `CONFIG.md` (every TOML key) and `OBSERVABILITY.md` (the [`obs`] metric
//! catalogue and span taxonomy).
//!
//! ## Layers
//!
//! Architecture (see DESIGN.md):
//! - **L3** — this crate: benchmarking ([`coordinator`]), model fitting
//!   ([`models`]), MILP + heuristic partitioners ([`milp`],
//!   [`coordinator::partitioner`]), cluster execution ([`platforms`]);
//! - **L2/L1** — JAX/Pallas Monte Carlo pricing chunks, AOT-lowered to HLO
//!   text at build time (`make artifacts`), executed via PJRT from
//!   [`runtime`]. Python never runs on the request path.

pub mod api;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod milp;
pub mod models;
pub mod obs;
pub mod platforms;
pub mod pricing;
pub mod report;
pub mod runtime;
pub mod serve;
pub mod testing;
pub mod util;
pub mod workload;

pub use api::{
    CloudshapesError, PartitionerRegistry, Result, SessionBuilder, TradeoffSession,
};

/// Crate version (mirrors Cargo.toml).
pub const VERSION: &str = env!("CARGO_PKG_VERSION");
