//! # cloudshapes
//!
//! Production-quality reproduction of *"Seeing Shapes in Clouds: On the
//! Performance-Cost trade-off for Heterogeneous Infrastructure-as-a-Service"*
//! (Inggs, Thomas, Constantinides, Luk — 2015).
//!
//! The library finds **Pareto-optimal latency↔cost trade-offs** for
//! workloads of atomic, divisible tasks (Monte Carlo option pricing)
//! partitioned across heterogeneous IaaS platforms (CPU / GPU / FPGA), by
//! solving a family of cost-constrained Mixed-ILP makespan problems
//! (ε-constraint method) and comparing against heuristic partitioners.
//!
//! Architecture (see DESIGN.md):
//! - **L3** — this crate: benchmarking, model fitting, MILP + heuristic
//!   partitioners, cluster execution;
//! - **L2/L1** — JAX/Pallas Monte Carlo pricing chunks, AOT-lowered to HLO
//!   text at build time (`make artifacts`), executed via PJRT from
//!   [`runtime`]. Python never runs on the request path.

pub mod cli;
pub mod config;
pub mod coordinator;
pub mod milp;
pub mod report;
pub mod models;
pub mod platforms;
pub mod pricing;
pub mod runtime;
pub mod testing;
pub mod util;
pub mod workload;

/// Crate version (mirrors Cargo.toml).
pub const VERSION: &str = env!("CARGO_PKG_VERSION");
