//! The versioned serve wire protocol (v1).
//!
//! Requests are newline-delimited JSON objects that MUST carry the protocol
//! version:
//!
//! ```text
//! {"v":1,"op":"ping"}                               # liveness + cache stats
//! {"v":1,"op":"specs"}
//! {"v":1,"op":"partition","budget":2.5,"partitioner":"milp"}
//! {"v":1,"op":"partition","budget":null}            # null = unconstrained
//! {"v":1,"op":"evaluate","budget":2.5}              # partition + execute
//! {"v":1,"op":"pareto","partitioner":"heuristic"}   # trade-off curve
//! {"v":1,"op":"shape","deadline":3600}              # optimise the composition
//! {"v":1,"op":"shape","budget":2.5}                 # ...or for a budget
//! {"v":1,"op":"batch","budgets":[1.0,2.5,null]}     # one solve per budget
//! {"v":1,"op":"run","budget":2.5}                   # background execution
//! {"v":1,"op":"run","budget":2.5,"stream":true}     # inline event stream
//! {"v":1,"op":"status","run_id":3}                  # poll a background run
//! {"v":1,"op":"submit","tasks":4,"deadline":3600}   # online scheduler job
//! {"v":1,"op":"submit","tasks":1,"budget":2.5,"payoff":"asian"}
//! {"v":1,"op":"submit_batch","jobs":[{"tasks":2,"deadline":3600},...]}
//! {"v":1,"op":"jobs"}                               # every tracked job
//! {"v":1,"op":"jobs","job_id":3}                    # one job's status
//! {"v":1,"op":"cancel","job_id":3}
//! {"v":1,"op":"metrics"}                            # full telemetry snapshot
//! {"v":1,"op":"metrics","filter":"exec_"}           # substring-filtered
//! {"v":1,"op":"shutdown"}
//! ```
//!
//! Every response is one JSON object per line, `{"v":1,"ok":true,...}` on
//! success or a structured error payload on failure:
//!
//! ```text
//! {"v":1,"ok":false,"error":{"kind":"protocol","message":"unknown op 'frobnicate'"}}
//! ```
//!
//! `error.kind` is [`CloudshapesError::kind`] — clients dispatch on it
//! instead of parsing messages. `partition`/`evaluate` require the `budget`
//! key (JSON `null` for unconstrained) so a forgotten budget is a typed
//! error, not a silent unconstrained solve.
//!
//! Two transport-level concerns ride the same envelope (both are handled
//! by the serve plane, [`crate::serve`], before op dispatch):
//!
//! - **Framing**: requests are newline-delimited by default; any request
//!   may carry `"framing":"lp1"` to switch its connection to 4-byte
//!   big-endian length-prefixed frames (see `docs/PROTOCOL.md`). The key is
//!   ignored by op decoding.
//! - **Overload**: under admission-control pressure a well-formed request
//!   may be shed with `{"ok":false,"error":{"kind":"overload",...}}` —
//!   retryable with backoff, and never interleaved out of order with the
//!   connection's other responses.
//!
//! `batch` solves a list of budgets in one round trip (at most
//! [`MAX_BATCH_BUDGETS`]) and answers with one `results` array entry per
//! budget, in request order. Entries are independent: each is either
//! `{"ok":true,...partition fields...}` or `{"ok":false,"error":{...}}`,
//! so one infeasible budget never fails its neighbours:
//!
//! ```text
//! -> {"v":1,"op":"batch","partitioner":"milp","budgets":[2.5,1e-9]}
//! <- {"v":1,"ok":true,"results":[
//!      {"ok":true,"partitioner":"milp","budget":2.5,
//!       "predicted_latency_s":41.2,"predicted_cost":2.31,"platforms_used":3},
//!      {"ok":false,"error":{"kind":"solver","message":"MILP: no feasible ..."}}]}
//! ```
//!
//! `submit` enqueues a pricing job on the online scheduler (`serve
//! --scheduler`): `tasks` options (1..=[`MAX_JOB_TASKS`]) at `accuracy`,
//! optionally restricted to one `payoff` family, under exactly one of
//! `deadline` (cluster-virtual seconds) or `budget` ($). `jobs` snapshots
//! all jobs (or one with `job_id`); `cancel` releases a job's remaining
//! work back to the queue at the next epoch boundary. A `submit` with
//! `"stream":true` holds the connection and writes `{"v":1,"event":"job",
//! ...}` lines as the job progresses, terminated by the usual final
//! response. On sessions without the scheduler these ops answer a typed
//! `config` error.
//!
//! `submit_batch` enqueues many jobs in one round trip — a re-price storm
//! submitted as one request instead of thousands. `jobs` is an array of at
//! most [`MAX_BATCH_JOBS`] objects, each carrying the same fields as
//! `submit` (minus `stream`). Like `batch`, entries are independent: the
//! response's `results` array holds `{"ok":true,"job_id":N}` or
//! `{"ok":false,"error":{...}}` per entry, in request order, so one bad
//! book entry (or one shed admission) never fails its neighbours.
//!
//! `run` starts a chunked execution. Without `stream` it returns
//! immediately with a `run_id`; `status` polls the run's progress counters
//! (chunks done, retries, straggler migrations, tasks priced) and, once
//! done, its measured makespan/cost. With `"stream":true` the server
//! instead writes interim event lines — each `{"v":1,"event":...}`, never
//! carrying an `"ok"` key — on the same connection, terminated by the
//! normal `{"v":1,"ok":true,...}` result:
//!
//! ```text
//! -> {"v":1,"op":"run","budget":null,"stream":true}
//! <- {"v":1,"event":"started","chunks":24,"tasks":8}
//! <- {"v":1,"event":"progress","done":12,"total":24}
//! <- {"v":1,"event":"task_priced","task":3,"price":7.81,"std_error":0.04,"partial":false}
//! <- {"v":1,"ok":true,"measured_latency_s":41.2,"measured_cost":2.31,...}
//! ```

use crate::util::json::{obj, Json};

use super::error::{CloudshapesError, Result};

/// The protocol version this build speaks.
pub const PROTOCOL_VERSION: u64 = 1;

/// Upper bound on the `budgets` array of a `batch` request — keeps one
/// request line from monopolising the server with unbounded solve work.
pub const MAX_BATCH_BUDGETS: usize = 1024;

/// Upper bound on a `submit` request's `tasks` count — the scheduler's
/// [`JobSpec::MAX_TASKS`](crate::coordinator::scheduler::JobSpec::MAX_TASKS),
/// re-exported at the wire layer so the two can never diverge.
pub const MAX_JOB_TASKS: usize = crate::coordinator::scheduler::JobSpec::MAX_TASKS;

/// Upper bound on the `jobs` array of a `submit_batch` request — the same
/// one-line-of-work discipline as [`MAX_BATCH_BUDGETS`].
pub const MAX_BATCH_JOBS: usize = 1024;

/// One job of a `submit`/`submit_batch` request: the wire fields of a
/// scheduler submission (everything but the connection-level `stream`).
#[derive(Debug, Clone, PartialEq)]
pub struct SubmitEntry {
    pub tasks: usize,
    pub payoff: Option<String>,
    pub accuracy: Option<f64>,
    pub seed: Option<u64>,
    pub deadline: Option<f64>,
    pub budget: Option<f64>,
}

/// A parsed v1 request.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    Ping,
    /// Platform spec listing for the served cluster.
    Specs,
    /// Partition the workload; predictions only.
    Partition { partitioner: Option<String>, budget: Option<f64> },
    /// Partition AND execute on the cluster.
    Evaluate { partitioner: Option<String>, budget: Option<f64> },
    /// Generate the ε-constraint trade-off curve.
    Pareto { partitioner: Option<String> },
    /// Optimise the cluster composition for a deadline (seconds) or a
    /// budget ($) — exactly one of the two.
    Shape { partitioner: Option<String>, deadline: Option<f64>, budget: Option<f64> },
    /// Partition at every budget of a list; one result entry per budget.
    Batch { partitioner: Option<String>, budgets: Vec<Option<f64>> },
    /// Start a chunked execution: background (poll with `Status`) or, with
    /// `stream`, inline event lines on this connection.
    Run { partitioner: Option<String>, budget: Option<f64>, stream: bool },
    /// Poll a background run's progress / final result.
    Status { run_id: u64 },
    /// Submit a job to the online scheduler: `tasks` generated options at
    /// `accuracy` under exactly one of `deadline`/`budget`; with `stream`,
    /// job-progress event lines on this connection until terminal.
    Submit {
        tasks: usize,
        payoff: Option<String>,
        accuracy: Option<f64>,
        seed: Option<u64>,
        deadline: Option<f64>,
        budget: Option<f64>,
        stream: bool,
    },
    /// Submit many jobs at once; one `results` entry per job, in order.
    SubmitBatch { jobs: Vec<SubmitEntry> },
    /// Snapshot every scheduler job, or one when `job_id` is given.
    Jobs { job_id: Option<u64> },
    /// Cancel a scheduler job.
    Cancel { job_id: u64 },
    /// Snapshot the session's metrics registry, optionally restricted to
    /// names containing `filter`.
    Metrics { filter: Option<String> },
    /// Stop the server (the in-flight response is still delivered).
    Shutdown,
}

impl Request {
    /// The wire name of this request's op — the label used for per-op serve
    /// metrics, so every dispatch site agrees on the spelling.
    pub fn op(&self) -> &'static str {
        match self {
            Request::Ping => "ping",
            Request::Specs => "specs",
            Request::Partition { .. } => "partition",
            Request::Evaluate { .. } => "evaluate",
            Request::Pareto { .. } => "pareto",
            Request::Shape { .. } => "shape",
            Request::Batch { .. } => "batch",
            Request::Run { .. } => "run",
            Request::Status { .. } => "status",
            Request::Submit { .. } => "submit",
            Request::SubmitBatch { .. } => "submit_batch",
            Request::Jobs { .. } => "jobs",
            Request::Cancel { .. } => "cancel",
            Request::Metrics { .. } => "metrics",
            Request::Shutdown => "shutdown",
        }
    }

    /// Parse one request line. All failures are
    /// [`CloudshapesError::Protocol`] with context.
    pub fn parse(line: &str) -> Result<Request> {
        Request::from_json(&Json::parse(line)?)
    }

    /// Decode an already-parsed JSON value into a request. Split out from
    /// [`Request::parse`] so the serve event loop parses each frame exactly
    /// once — inspecting transport fields like `"framing"` on the same
    /// value it then decodes the op from.
    pub fn from_json(req: &Json) -> Result<Request> {
        if req.as_obj().is_none() {
            return Err(CloudshapesError::protocol("request must be a JSON object"));
        }
        let v = match req.get("v") {
            Some(v) => v.as_u64().ok_or_else(|| {
                CloudshapesError::protocol("'v' must be a non-negative integer")
            })?,
            None => {
                return Err(CloudshapesError::protocol(format!(
                    "missing protocol version: send {{\"v\":{PROTOCOL_VERSION},\"op\":...}}"
                )))
            }
        };
        if v != PROTOCOL_VERSION {
            return Err(CloudshapesError::protocol(format!(
                "unsupported protocol version {v} (this server speaks v{PROTOCOL_VERSION})"
            )));
        }
        let op = req
            .get("op")
            .ok_or_else(|| CloudshapesError::protocol("missing 'op'"))?
            .as_str()
            .ok_or_else(|| CloudshapesError::protocol("'op' must be a string"))?;
        match op {
            "ping" => Ok(Request::Ping),
            "specs" => Ok(Request::Specs),
            "partition" => {
                let (partitioner, budget) = partition_fields(&req, op)?;
                Ok(Request::Partition { partitioner, budget })
            }
            "evaluate" => {
                let (partitioner, budget) = partition_fields(&req, op)?;
                Ok(Request::Evaluate { partitioner, budget })
            }
            "pareto" => Ok(Request::Pareto { partitioner: partitioner_field(&req)? }),
            "shape" => {
                let partitioner = partitioner_field(&req)?;
                let num = |key: &str| -> Result<Option<f64>> {
                    match req.get(key) {
                        None | Some(Json::Null) => Ok(None),
                        Some(v) => v.as_f64().map(Some).ok_or_else(|| {
                            CloudshapesError::protocol(format!("'{key}' must be a number"))
                        }),
                    }
                };
                let (deadline, budget) = (num("deadline")?, num("budget")?);
                match (deadline, budget) {
                    (Some(_), Some(_)) | (None, None) => Err(CloudshapesError::protocol(
                        "op 'shape' requires exactly one of 'deadline' (seconds) or \
                         'budget' ($)",
                    )),
                    _ => Ok(Request::Shape { partitioner, deadline, budget }),
                }
            }
            "batch" => {
                let partitioner = partitioner_field(&req)?;
                let budgets = batch_budgets(&req)?;
                Ok(Request::Batch { partitioner, budgets })
            }
            "run" => {
                let (partitioner, budget) = partition_fields(&req, op)?;
                let stream = match req.get("stream") {
                    None | Some(Json::Null) => false,
                    Some(v) => v.as_bool().ok_or_else(|| {
                        CloudshapesError::protocol("'stream' must be a boolean")
                    })?,
                };
                Ok(Request::Run { partitioner, budget, stream })
            }
            "status" => {
                let run_id = req
                    .get("run_id")
                    .ok_or_else(|| {
                        CloudshapesError::protocol("op 'status' requires 'run_id' (an integer)")
                    })?
                    .as_u64()
                    .ok_or_else(|| {
                        CloudshapesError::protocol("'run_id' must be a non-negative integer")
                    })?;
                Ok(Request::Status { run_id })
            }
            "submit" => {
                let entry = submit_entry_fields(req, "op 'submit'")?;
                let stream = match req.get("stream") {
                    None | Some(Json::Null) => false,
                    Some(v) => v.as_bool().ok_or_else(|| {
                        CloudshapesError::protocol("'stream' must be a boolean")
                    })?,
                };
                let SubmitEntry { tasks, payoff, accuracy, seed, deadline, budget } = entry;
                Ok(Request::Submit { tasks, payoff, accuracy, seed, deadline, budget, stream })
            }
            "submit_batch" => {
                let arr = match req.get("jobs") {
                    None => {
                        return Err(CloudshapesError::protocol(
                            "op 'submit_batch' requires 'jobs' (an array of submit objects)",
                        ))
                    }
                    Some(v) => v.as_arr().ok_or_else(|| {
                        CloudshapesError::protocol("'jobs' must be an array of objects")
                    })?,
                };
                if arr.is_empty() {
                    return Err(CloudshapesError::protocol("'jobs' must not be empty"));
                }
                if arr.len() > MAX_BATCH_JOBS {
                    return Err(CloudshapesError::protocol(format!(
                        "'jobs' has {} entries (max {MAX_BATCH_JOBS} per request)",
                        arr.len()
                    )));
                }
                let jobs = arr
                    .iter()
                    .enumerate()
                    .map(|(k, entry)| {
                        if entry.as_obj().is_none() {
                            return Err(CloudshapesError::protocol(format!(
                                "'jobs[{k}]' must be an object"
                            )));
                        }
                        submit_entry_fields(entry, &format!("'jobs[{k}]'"))
                    })
                    .collect::<Result<Vec<SubmitEntry>>>()?;
                Ok(Request::SubmitBatch { jobs })
            }
            "jobs" => {
                let job_id = match req.get("job_id") {
                    None | Some(Json::Null) => None,
                    Some(v) => Some(v.as_u64().ok_or_else(|| {
                        CloudshapesError::protocol("'job_id' must be a non-negative integer")
                    })?),
                };
                Ok(Request::Jobs { job_id })
            }
            "cancel" => {
                let job_id = req
                    .get("job_id")
                    .ok_or_else(|| {
                        CloudshapesError::protocol("op 'cancel' requires 'job_id' (an integer)")
                    })?
                    .as_u64()
                    .ok_or_else(|| {
                        CloudshapesError::protocol("'job_id' must be a non-negative integer")
                    })?;
                Ok(Request::Cancel { job_id })
            }
            "metrics" => {
                let filter = match req.get("filter") {
                    None | Some(Json::Null) => None,
                    Some(v) => Some(
                        v.as_str()
                            .ok_or_else(|| {
                                CloudshapesError::protocol("'filter' must be a string")
                            })?
                            .to_string(),
                    ),
                };
                Ok(Request::Metrics { filter })
            }
            "shutdown" => Ok(Request::Shutdown),
            other => Err(CloudshapesError::protocol(format!(
                "unknown op '{other}' (ops: ping, specs, partition, evaluate, pareto, shape, \
                 batch, run, status, submit, submit_batch, jobs, cancel, metrics, shutdown)"
            ))),
        }
    }
}

/// Parse the shared job fields of `submit`/`submit_batch` from `req` —
/// `ctx` labels whose fields a failure message blames (`"op 'submit'"` vs
/// `"'jobs[3]'"`).
fn submit_entry_fields(req: &Json, ctx: &str) -> Result<SubmitEntry> {
    let tasks = match req.get("tasks") {
        None | Some(Json::Null) => 1,
        Some(v) => v.as_u64().ok_or_else(|| {
            CloudshapesError::protocol(format!("{ctx}: 'tasks' must be a positive integer"))
        })? as usize,
    };
    if tasks == 0 || tasks > MAX_JOB_TASKS {
        return Err(CloudshapesError::protocol(format!(
            "{ctx}: 'tasks' must be 1..={MAX_JOB_TASKS}, got {tasks}"
        )));
    }
    let payoff = match req.get("payoff") {
        None | Some(Json::Null) => None,
        Some(v) => Some(
            v.as_str()
                .ok_or_else(|| {
                    CloudshapesError::protocol(format!("{ctx}: 'payoff' must be a string"))
                })?
                .to_string(),
        ),
    };
    let num = |key: &str| -> Result<Option<f64>> {
        match req.get(key) {
            None | Some(Json::Null) => Ok(None),
            Some(v) => v.as_f64().map(Some).ok_or_else(|| {
                CloudshapesError::protocol(format!("{ctx}: '{key}' must be a number"))
            }),
        }
    };
    let accuracy = num("accuracy")?;
    let seed = match req.get("seed") {
        None | Some(Json::Null) => None,
        Some(v) => Some(v.as_u64().ok_or_else(|| {
            CloudshapesError::protocol(format!(
                "{ctx}: 'seed' must be a non-negative integer"
            ))
        })?),
    };
    let (deadline, budget) = (num("deadline")?, num("budget")?);
    if matches!((deadline, budget), (Some(_), Some(_)) | (None, None)) {
        return Err(CloudshapesError::protocol(format!(
            "{ctx} requires exactly one of 'deadline' (virtual seconds) or 'budget' ($) \
             as the job's SLO"
        )));
    }
    Ok(SubmitEntry { tasks, payoff, accuracy, seed, deadline, budget })
}

fn partitioner_field(req: &Json) -> Result<Option<String>> {
    match req.get("partitioner") {
        None | Some(Json::Null) => Ok(None),
        Some(v) => v
            .as_str()
            .map(|s| Some(s.to_string()))
            .ok_or_else(|| CloudshapesError::protocol("'partitioner' must be a string")),
    }
}

fn batch_budgets(req: &Json) -> Result<Vec<Option<f64>>> {
    let arr = match req.get("budgets") {
        None => {
            return Err(CloudshapesError::protocol(
                "op 'batch' requires 'budgets' (an array of numbers, null = unconstrained)",
            ))
        }
        Some(v) => v.as_arr().ok_or_else(|| {
            CloudshapesError::protocol("'budgets' must be an array of numbers/null")
        })?,
    };
    if arr.is_empty() {
        return Err(CloudshapesError::protocol("'budgets' must not be empty"));
    }
    if arr.len() > MAX_BATCH_BUDGETS {
        return Err(CloudshapesError::protocol(format!(
            "'budgets' has {} entries (max {MAX_BATCH_BUDGETS} per request)",
            arr.len()
        )));
    }
    arr.iter()
        .map(|v| match v {
            Json::Null => Ok(None),
            other => other.as_f64().map(Some).ok_or_else(|| {
                CloudshapesError::protocol("each batch budget must be a number or null")
            }),
        })
        .collect()
}

fn partition_fields(req: &Json, op: &str) -> Result<(Option<String>, Option<f64>)> {
    let partitioner = partitioner_field(req)?;
    let budget = match req.get("budget") {
        None => {
            return Err(CloudshapesError::protocol(format!(
                "op '{op}' requires 'budget' (a number, or null for unconstrained)"
            )))
        }
        Some(Json::Null) => None,
        Some(v) => Some(v.as_f64().ok_or_else(|| {
            CloudshapesError::protocol("'budget' must be a number or null")
        })?),
    };
    Ok((partitioner, budget))
}

/// Wrap success fields into the `{"v":1,"ok":true,...}` envelope.
pub fn ok_response(mut fields: Vec<(&str, Json)>) -> Json {
    let mut all = vec![("v", Json::Num(PROTOCOL_VERSION as f64)), ("ok", Json::Bool(true))];
    all.append(&mut fields);
    obj(all)
}

/// Map an error to the structured `{"v":1,"ok":false,"error":{...}}`
/// payload.
pub fn error_response(err: &CloudshapesError) -> Json {
    obj(vec![
        ("v", Json::Num(PROTOCOL_VERSION as f64)),
        ("ok", Json::Bool(false)),
        (
            "error",
            obj(vec![
                ("kind", err.kind().into()),
                ("message", err.message().into()),
            ]),
        ),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_all_ops() {
        assert_eq!(Request::parse(r#"{"v":1,"op":"ping"}"#).unwrap(), Request::Ping);
        assert_eq!(Request::parse(r#"{"v":1,"op":"specs"}"#).unwrap(), Request::Specs);
        assert_eq!(
            Request::parse(r#"{"v":1,"op":"partition","budget":2.5,"partitioner":"milp"}"#)
                .unwrap(),
            Request::Partition { partitioner: Some("milp".into()), budget: Some(2.5) }
        );
        assert_eq!(
            Request::parse(r#"{"v":1,"op":"evaluate","budget":null}"#).unwrap(),
            Request::Evaluate { partitioner: None, budget: None }
        );
        assert_eq!(
            Request::parse(r#"{"v":1,"op":"pareto"}"#).unwrap(),
            Request::Pareto { partitioner: None }
        );
        assert_eq!(
            Request::parse(r#"{"v":1,"op":"shape","deadline":3600}"#).unwrap(),
            Request::Shape { partitioner: None, deadline: Some(3600.0), budget: None }
        );
        assert_eq!(
            Request::parse(r#"{"v":1,"op":"shape","budget":2.5,"partitioner":"milp"}"#)
                .unwrap(),
            Request::Shape {
                partitioner: Some("milp".into()),
                deadline: None,
                budget: Some(2.5),
            }
        );
        assert_eq!(
            Request::parse(r#"{"v":1,"op":"batch","budgets":[1.5,null,2],"partitioner":"milp"}"#)
                .unwrap(),
            Request::Batch {
                partitioner: Some("milp".into()),
                budgets: vec![Some(1.5), None, Some(2.0)],
            }
        );
        assert_eq!(
            Request::parse(r#"{"v":1,"op":"run","budget":2.5}"#).unwrap(),
            Request::Run { partitioner: None, budget: Some(2.5), stream: false }
        );
        assert_eq!(
            Request::parse(r#"{"v":1,"op":"run","budget":null,"stream":true}"#).unwrap(),
            Request::Run { partitioner: None, budget: None, stream: true }
        );
        assert_eq!(
            Request::parse(r#"{"v":1,"op":"status","run_id":7}"#).unwrap(),
            Request::Status { run_id: 7 }
        );
        assert_eq!(
            Request::parse(r#"{"v":1,"op":"metrics"}"#).unwrap(),
            Request::Metrics { filter: None }
        );
        assert_eq!(
            Request::parse(r#"{"v":1,"op":"metrics","filter":"exec_"}"#).unwrap(),
            Request::Metrics { filter: Some("exec_".into()) }
        );
        assert_eq!(Request::parse(r#"{"v":1,"op":"shutdown"}"#).unwrap(), Request::Shutdown);
    }

    #[test]
    fn metrics_filter_validation() {
        let e = Request::parse(r#"{"v":1,"op":"metrics","filter":7}"#).unwrap_err();
        assert_eq!(e.kind(), "protocol", "{e}");
        // Explicit null behaves like an absent filter.
        assert_eq!(
            Request::parse(r#"{"v":1,"op":"metrics","filter":null}"#).unwrap(),
            Request::Metrics { filter: None }
        );
    }

    #[test]
    fn op_names_round_trip() {
        for (line, name) in [
            (r#"{"v":1,"op":"ping"}"#, "ping"),
            (r#"{"v":1,"op":"evaluate","budget":null}"#, "evaluate"),
            (r#"{"v":1,"op":"metrics"}"#, "metrics"),
            (r#"{"v":1,"op":"shutdown"}"#, "shutdown"),
        ] {
            assert_eq!(Request::parse(line).unwrap().op(), name);
        }
    }

    #[test]
    fn shape_requires_exactly_one_constraint() {
        for bad in [
            r#"{"v":1,"op":"shape"}"#,                               // neither
            r#"{"v":1,"op":"shape","deadline":1,"budget":2}"#,       // both
            r#"{"v":1,"op":"shape","deadline":"soon"}"#,             // bad type
            r#"{"v":1,"op":"shape","budget":1,"partitioner":7}"#,    // bad name
        ] {
            let e = Request::parse(bad).unwrap_err();
            assert_eq!(e.kind(), "protocol", "{bad} -> {e}");
        }
    }

    #[test]
    fn parses_scheduler_ops() {
        assert_eq!(
            Request::parse(r#"{"v":1,"op":"submit","tasks":4,"deadline":3600}"#).unwrap(),
            Request::Submit {
                tasks: 4,
                payoff: None,
                accuracy: None,
                seed: None,
                deadline: Some(3600.0),
                budget: None,
                stream: false,
            }
        );
        assert_eq!(
            Request::parse(
                r#"{"v":1,"op":"submit","budget":2.5,"payoff":"asian","accuracy":0.05,"seed":9,"stream":true}"#
            )
            .unwrap(),
            Request::Submit {
                tasks: 1,
                payoff: Some("asian".into()),
                accuracy: Some(0.05),
                seed: Some(9),
                deadline: None,
                budget: Some(2.5),
                stream: true,
            }
        );
        assert_eq!(
            Request::parse(r#"{"v":1,"op":"jobs"}"#).unwrap(),
            Request::Jobs { job_id: None }
        );
        assert_eq!(
            Request::parse(r#"{"v":1,"op":"jobs","job_id":3}"#).unwrap(),
            Request::Jobs { job_id: Some(3) }
        );
        assert_eq!(
            Request::parse(r#"{"v":1,"op":"cancel","job_id":3}"#).unwrap(),
            Request::Cancel { job_id: 3 }
        );
    }

    #[test]
    fn parses_submit_batch() {
        assert_eq!(
            Request::parse(
                r#"{"v":1,"op":"submit_batch","jobs":[{"tasks":2,"deadline":3600},{"budget":2.5,"payoff":"asian","accuracy":0.05,"seed":9}]}"#
            )
            .unwrap(),
            Request::SubmitBatch {
                jobs: vec![
                    SubmitEntry {
                        tasks: 2,
                        payoff: None,
                        accuracy: None,
                        seed: None,
                        deadline: Some(3600.0),
                        budget: None,
                    },
                    SubmitEntry {
                        tasks: 1,
                        payoff: Some("asian".into()),
                        accuracy: Some(0.05),
                        seed: Some(9),
                        deadline: None,
                        budget: Some(2.5),
                    },
                ],
            }
        );
        assert_eq!(
            Request::parse(r#"{"v":1,"op":"submit_batch","jobs":[{"deadline":1}]}"#)
                .unwrap()
                .op(),
            "submit_batch"
        );
    }

    #[test]
    fn submit_batch_validation() {
        for bad in [
            r#"{"v":1,"op":"submit_batch"}"#,                    // missing jobs
            r#"{"v":1,"op":"submit_batch","jobs":[]}"#,          // empty
            r#"{"v":1,"op":"submit_batch","jobs":7}"#,           // not an array
            r#"{"v":1,"op":"submit_batch","jobs":[7]}"#,         // entry not object
            r#"{"v":1,"op":"submit_batch","jobs":[{}]}"#,        // entry without SLO
            r#"{"v":1,"op":"submit_batch","jobs":[{"deadline":1,"budget":2}]}"#, // both
            r#"{"v":1,"op":"submit_batch","jobs":[{"deadline":1,"tasks":0}]}"#,  // bad tasks
        ] {
            let e = Request::parse(bad).unwrap_err();
            assert_eq!(e.kind(), "protocol", "{bad} -> {e}");
        }
        // Entry-indexed messages point at the offending job.
        let e = Request::parse(
            r#"{"v":1,"op":"submit_batch","jobs":[{"deadline":1},{"budget":"x"}]}"#,
        )
        .unwrap_err();
        assert!(e.message().contains("jobs[1]"), "{e}");
        let huge = format!(
            r#"{{"v":1,"op":"submit_batch","jobs":[{}]}}"#,
            vec![r#"{"deadline":1}"#; MAX_BATCH_JOBS + 1].join(",")
        );
        let e = Request::parse(&huge).unwrap_err();
        assert!(e.message().contains("max"), "{e}");
    }

    #[test]
    fn submit_and_cancel_validation() {
        for bad in [
            r#"{"v":1,"op":"submit"}"#,                           // no SLO
            r#"{"v":1,"op":"submit","deadline":1,"budget":2}"#,   // both SLOs
            r#"{"v":1,"op":"submit","deadline":"soon"}"#,         // bad type
            r#"{"v":1,"op":"submit","budget":1,"tasks":0}"#,      // zero tasks
            r#"{"v":1,"op":"submit","budget":1,"tasks":100000}"#, // too many
            r#"{"v":1,"op":"submit","budget":1,"payoff":7}"#,     // bad payoff type
            r#"{"v":1,"op":"submit","budget":1,"stream":3}"#,     // bad stream
            r#"{"v":1,"op":"submit","budget":1,"seed":-1}"#,      // bad seed
            r#"{"v":1,"op":"jobs","job_id":"x"}"#,                // bad job_id
            r#"{"v":1,"op":"cancel"}"#,                           // missing job_id
            r#"{"v":1,"op":"cancel","job_id":"x"}"#,              // bad job_id
        ] {
            let e = Request::parse(bad).unwrap_err();
            assert_eq!(e.kind(), "protocol", "{bad} -> {e}");
        }
        // An unknown payoff NAME parses at the protocol layer — it becomes
        // a typed workload error at dispatch, where the valid families are
        // known.
        assert!(Request::parse(r#"{"v":1,"op":"submit","budget":1,"payoff":"swaption"}"#)
            .is_ok());
    }

    #[test]
    fn run_and_status_validation() {
        for bad in [
            r#"{"v":1,"op":"run"}"#,                        // missing budget
            r#"{"v":1,"op":"run","budget":1,"stream":3}"#,  // bad stream type
            r#"{"v":1,"op":"status"}"#,                     // missing run_id
            r#"{"v":1,"op":"status","run_id":"x"}"#,        // bad run_id type
        ] {
            let e = Request::parse(bad).unwrap_err();
            assert_eq!(e.kind(), "protocol", "{bad} -> {e}");
        }
    }

    #[test]
    fn batch_budget_validation() {
        for bad in [
            r#"{"v":1,"op":"batch"}"#,                      // missing budgets
            r#"{"v":1,"op":"batch","budgets":[]}"#,         // empty
            r#"{"v":1,"op":"batch","budgets":2.5}"#,        // not an array
            r#"{"v":1,"op":"batch","budgets":["x"]}"#,      // bad element
        ] {
            let e = Request::parse(bad).unwrap_err();
            assert_eq!(e.kind(), "protocol", "{bad} -> {e}");
        }
        let huge = format!(
            r#"{{"v":1,"op":"batch","budgets":[{}]}}"#,
            vec!["1"; MAX_BATCH_BUDGETS + 1].join(",")
        );
        let e = Request::parse(&huge).unwrap_err();
        assert_eq!(e.kind(), "protocol");
        assert!(e.message().contains("max"), "{e}");
    }

    #[test]
    fn protocol_errors_are_typed() {
        for bad in [
            "not json",
            r#"{"op":"ping"}"#,                       // missing v
            r#"{"v":2,"op":"ping"}"#,                 // wrong version
            r#"{"v":1}"#,                             // missing op
            r#"{"v":1,"op":"frobnicate"}"#,           // unknown op
            r#"{"v":1,"op":"partition"}"#,            // missing budget
            r#"{"v":1,"op":"partition","budget":"x"}"#, // bad budget type
            r#"{"v":1,"op":"evaluate","budget":1,"partitioner":7}"#, // bad name type
            "[1,2]",                                  // not an object
        ] {
            let e = Request::parse(bad).unwrap_err();
            assert_eq!(e.kind(), "protocol", "{bad} -> {e}");
        }
    }

    #[test]
    fn envelopes_carry_version() {
        let ok = ok_response(vec![("pong", true.into())]);
        assert_eq!(ok.get("v").unwrap().as_u64(), Some(1));
        assert_eq!(ok.get("ok"), Some(&Json::Bool(true)));
        let err = error_response(&CloudshapesError::solver("infeasible"));
        assert_eq!(err.get("ok"), Some(&Json::Bool(false)));
        let payload = err.get("error").unwrap();
        assert_eq!(payload.get("kind").unwrap().as_str(), Some("solver"));
        assert_eq!(payload.get("message").unwrap().as_str(), Some("infeasible"));
    }
}
