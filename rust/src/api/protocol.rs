//! The versioned serve wire protocol (v1).
//!
//! Requests are newline-delimited JSON objects that MUST carry the protocol
//! version:
//!
//! ```text
//! {"v":1,"op":"ping"}
//! {"v":1,"op":"specs"}
//! {"v":1,"op":"partition","budget":2.5,"partitioner":"milp"}
//! {"v":1,"op":"partition","budget":null}            # null = unconstrained
//! {"v":1,"op":"evaluate","budget":2.5}              # partition + execute
//! {"v":1,"op":"pareto","partitioner":"heuristic"}   # trade-off curve
//! {"v":1,"op":"shutdown"}
//! ```
//!
//! Every response is one JSON object per line, `{"v":1,"ok":true,...}` on
//! success or a structured error payload on failure:
//!
//! ```text
//! {"v":1,"ok":false,"error":{"kind":"protocol","message":"unknown op 'frobnicate'"}}
//! ```
//!
//! `error.kind` is [`CloudshapesError::kind`] — clients dispatch on it
//! instead of parsing messages. `partition`/`evaluate` require the `budget`
//! key (JSON `null` for unconstrained) so a forgotten budget is a typed
//! error, not a silent unconstrained solve.

use crate::util::json::{obj, Json};

use super::error::{CloudshapesError, Result};

/// The protocol version this build speaks.
pub const PROTOCOL_VERSION: u64 = 1;

/// A parsed v1 request.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    Ping,
    /// Platform spec listing for the served cluster.
    Specs,
    /// Partition the workload; predictions only.
    Partition { partitioner: Option<String>, budget: Option<f64> },
    /// Partition AND execute on the cluster.
    Evaluate { partitioner: Option<String>, budget: Option<f64> },
    /// Generate the ε-constraint trade-off curve.
    Pareto { partitioner: Option<String> },
    /// Stop the server (the in-flight response is still delivered).
    Shutdown,
}

impl Request {
    /// Parse one request line. All failures are
    /// [`CloudshapesError::Protocol`] with context.
    pub fn parse(line: &str) -> Result<Request> {
        let req = Json::parse(line)?;
        if req.as_obj().is_none() {
            return Err(CloudshapesError::protocol("request must be a JSON object"));
        }
        let v = match req.get("v") {
            Some(v) => v.as_u64().ok_or_else(|| {
                CloudshapesError::protocol("'v' must be a non-negative integer")
            })?,
            None => {
                return Err(CloudshapesError::protocol(format!(
                    "missing protocol version: send {{\"v\":{PROTOCOL_VERSION},\"op\":...}}"
                )))
            }
        };
        if v != PROTOCOL_VERSION {
            return Err(CloudshapesError::protocol(format!(
                "unsupported protocol version {v} (this server speaks v{PROTOCOL_VERSION})"
            )));
        }
        let op = req
            .get("op")
            .ok_or_else(|| CloudshapesError::protocol("missing 'op'"))?
            .as_str()
            .ok_or_else(|| CloudshapesError::protocol("'op' must be a string"))?;
        match op {
            "ping" => Ok(Request::Ping),
            "specs" => Ok(Request::Specs),
            "partition" => {
                let (partitioner, budget) = partition_fields(&req, op)?;
                Ok(Request::Partition { partitioner, budget })
            }
            "evaluate" => {
                let (partitioner, budget) = partition_fields(&req, op)?;
                Ok(Request::Evaluate { partitioner, budget })
            }
            "pareto" => Ok(Request::Pareto { partitioner: partitioner_field(&req)? }),
            "shutdown" => Ok(Request::Shutdown),
            other => Err(CloudshapesError::protocol(format!(
                "unknown op '{other}' (ops: ping, specs, partition, evaluate, pareto, shutdown)"
            ))),
        }
    }
}

fn partitioner_field(req: &Json) -> Result<Option<String>> {
    match req.get("partitioner") {
        None | Some(Json::Null) => Ok(None),
        Some(v) => v
            .as_str()
            .map(|s| Some(s.to_string()))
            .ok_or_else(|| CloudshapesError::protocol("'partitioner' must be a string")),
    }
}

fn partition_fields(req: &Json, op: &str) -> Result<(Option<String>, Option<f64>)> {
    let partitioner = partitioner_field(req)?;
    let budget = match req.get("budget") {
        None => {
            return Err(CloudshapesError::protocol(format!(
                "op '{op}' requires 'budget' (a number, or null for unconstrained)"
            )))
        }
        Some(Json::Null) => None,
        Some(v) => Some(v.as_f64().ok_or_else(|| {
            CloudshapesError::protocol("'budget' must be a number or null")
        })?),
    };
    Ok((partitioner, budget))
}

/// Wrap success fields into the `{"v":1,"ok":true,...}` envelope.
pub fn ok_response(mut fields: Vec<(&str, Json)>) -> Json {
    let mut all = vec![("v", Json::Num(PROTOCOL_VERSION as f64)), ("ok", Json::Bool(true))];
    all.append(&mut fields);
    obj(all)
}

/// Map an error to the structured `{"v":1,"ok":false,"error":{...}}`
/// payload.
pub fn error_response(err: &CloudshapesError) -> Json {
    obj(vec![
        ("v", Json::Num(PROTOCOL_VERSION as f64)),
        ("ok", Json::Bool(false)),
        (
            "error",
            obj(vec![
                ("kind", err.kind().into()),
                ("message", err.message().into()),
            ]),
        ),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_all_ops() {
        assert_eq!(Request::parse(r#"{"v":1,"op":"ping"}"#).unwrap(), Request::Ping);
        assert_eq!(Request::parse(r#"{"v":1,"op":"specs"}"#).unwrap(), Request::Specs);
        assert_eq!(
            Request::parse(r#"{"v":1,"op":"partition","budget":2.5,"partitioner":"milp"}"#)
                .unwrap(),
            Request::Partition { partitioner: Some("milp".into()), budget: Some(2.5) }
        );
        assert_eq!(
            Request::parse(r#"{"v":1,"op":"evaluate","budget":null}"#).unwrap(),
            Request::Evaluate { partitioner: None, budget: None }
        );
        assert_eq!(
            Request::parse(r#"{"v":1,"op":"pareto"}"#).unwrap(),
            Request::Pareto { partitioner: None }
        );
        assert_eq!(Request::parse(r#"{"v":1,"op":"shutdown"}"#).unwrap(), Request::Shutdown);
    }

    #[test]
    fn protocol_errors_are_typed() {
        for bad in [
            "not json",
            r#"{"op":"ping"}"#,                       // missing v
            r#"{"v":2,"op":"ping"}"#,                 // wrong version
            r#"{"v":1}"#,                             // missing op
            r#"{"v":1,"op":"frobnicate"}"#,           // unknown op
            r#"{"v":1,"op":"partition"}"#,            // missing budget
            r#"{"v":1,"op":"partition","budget":"x"}"#, // bad budget type
            r#"{"v":1,"op":"evaluate","budget":1,"partitioner":7}"#, // bad name type
            "[1,2]",                                  // not an object
        ] {
            let e = Request::parse(bad).unwrap_err();
            assert_eq!(e.kind(), "protocol", "{bad} -> {e}");
        }
    }

    #[test]
    fn envelopes_carry_version() {
        let ok = ok_response(vec![("pong", true.into())]);
        assert_eq!(ok.get("v").unwrap().as_u64(), Some(1));
        assert_eq!(ok.get("ok"), Some(&Json::Bool(true)));
        let err = error_response(&CloudshapesError::solver("infeasible"));
        assert_eq!(err.get("ok"), Some(&Json::Bool(false)));
        let payload = err.get("error").unwrap();
        assert_eq!(payload.get("kind").unwrap().as_str(), Some("solver"));
        assert_eq!(payload.get("message").unwrap().as_str(), Some("infeasible"));
    }
}
