//! The crate-wide typed error: every fallible public API returns
//! [`CloudshapesError`] (via the [`Result`] alias) instead of bare strings,
//! so callers can dispatch on *what* failed — and the serve protocol can map
//! failures to structured wire payloads — without parsing messages.

use std::fmt;

use crate::util::json::JsonError;
use crate::util::toml::TomlError;

/// What went wrong, with human-readable context.
///
/// Variants mirror the system's layers:
/// - [`Config`](CloudshapesError::Config) — experiment configuration, CLI
///   arguments, session-builder misuse (missing cluster/workload, unknown
///   partitioner name);
/// - [`Workload`](CloudshapesError::Workload) — workload construction or
///   validation (empty workloads, duplicate task ids, implausible options);
/// - [`Solver`](CloudshapesError::Solver) — partitioner failures (infeasible
///   budgets, invalid allocations, LP breakdowns);
/// - [`Platform`](CloudshapesError::Platform) — cluster construction or a
///   platform backend (e.g. the native PJRT engine failing to start);
/// - [`Runtime`](CloudshapesError::Runtime) — execution of an allocation on
///   a cluster;
/// - [`Protocol`](CloudshapesError::Protocol) — the versioned serve wire
///   protocol (malformed JSON, unsupported versions, bad requests);
/// - [`Overload`](CloudshapesError::Overload) — the serve plane shed a
///   well-formed request under admission control (in-flight budget or a
///   shard queue at its depth cap); retryable with backoff.
#[derive(Debug, Clone, PartialEq)]
pub enum CloudshapesError {
    Config(String),
    Workload(String),
    Solver(String),
    Platform(String),
    Runtime(String),
    Protocol(String),
    Overload(String),
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, CloudshapesError>;

impl CloudshapesError {
    pub fn config(msg: impl Into<String>) -> CloudshapesError {
        CloudshapesError::Config(msg.into())
    }

    pub fn workload(msg: impl Into<String>) -> CloudshapesError {
        CloudshapesError::Workload(msg.into())
    }

    pub fn solver(msg: impl Into<String>) -> CloudshapesError {
        CloudshapesError::Solver(msg.into())
    }

    pub fn platform(msg: impl Into<String>) -> CloudshapesError {
        CloudshapesError::Platform(msg.into())
    }

    pub fn runtime(msg: impl Into<String>) -> CloudshapesError {
        CloudshapesError::Runtime(msg.into())
    }

    pub fn protocol(msg: impl Into<String>) -> CloudshapesError {
        CloudshapesError::Protocol(msg.into())
    }

    pub fn overload(msg: impl Into<String>) -> CloudshapesError {
        CloudshapesError::Overload(msg.into())
    }

    /// Stable lowercase kind tag — the `error.kind` field of serve error
    /// payloads; also useful for metrics.
    pub fn kind(&self) -> &'static str {
        match self {
            CloudshapesError::Config(_) => "config",
            CloudshapesError::Workload(_) => "workload",
            CloudshapesError::Solver(_) => "solver",
            CloudshapesError::Platform(_) => "platform",
            CloudshapesError::Runtime(_) => "runtime",
            CloudshapesError::Protocol(_) => "protocol",
            CloudshapesError::Overload(_) => "overload",
        }
    }

    /// The context message without the kind prefix.
    pub fn message(&self) -> &str {
        match self {
            CloudshapesError::Config(m)
            | CloudshapesError::Workload(m)
            | CloudshapesError::Solver(m)
            | CloudshapesError::Platform(m)
            | CloudshapesError::Runtime(m)
            | CloudshapesError::Protocol(m)
            | CloudshapesError::Overload(m) => m,
        }
    }
}

impl fmt::Display for CloudshapesError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{} error: {}", self.kind(), self.message())
    }
}

impl std::error::Error for CloudshapesError {}

impl From<TomlError> for CloudshapesError {
    fn from(e: TomlError) -> Self {
        CloudshapesError::Config(e.to_string())
    }
}

impl From<JsonError> for CloudshapesError {
    fn from(e: JsonError) -> Self {
        CloudshapesError::Protocol(format!("malformed json: {e}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kinds_and_display() {
        let e = CloudshapesError::solver("budget infeasible");
        assert_eq!(e.kind(), "solver");
        assert_eq!(e.message(), "budget infeasible");
        assert_eq!(e.to_string(), "solver error: budget infeasible");
    }

    #[test]
    fn conversions() {
        let te = TomlError { msg: "bad".into(), line: 3 };
        assert_eq!(CloudshapesError::from(te).kind(), "config");
        let je = crate::util::json::Json::parse("{").unwrap_err();
        assert_eq!(CloudshapesError::from(je).kind(), "protocol");
    }
}
