//! [`TradeoffSession`]: the one front door to the trade-off engine.
//!
//! A session owns the whole pipeline the paper describes — benchmark the
//! cluster (§III.A), fit latency/cost models, partition under budgets
//! (§III.B-C), execute allocations — behind a builder:
//!
//! ```no_run
//! use cloudshapes::api::SessionBuilder;
//! use cloudshapes::config::ExperimentConfig;
//!
//! let cfg = ExperimentConfig::quick();
//! let session = SessionBuilder::new()
//!     .cluster(cfg.cluster.clone())
//!     .workload(cfg.workload.clone())
//!     .partitioner("milp")
//!     .budget_sweep(7)
//!     .build()?;
//! let frontier = session.pareto_frontier()?;
//! let run = session.evaluate(Some(2.5))?;
//! println!(
//!     "measured {:.1}s for ${:.3}",
//!     run.execution.makespan_secs, run.execution.cost
//! );
//! # Ok::<(), cloudshapes::api::CloudshapesError>(())
//! ```
//!
//! The CLI, the serve protocol, the examples and the benches all go through
//! this type; nothing else in the crate wires clusters to partitioners by
//! hand.

use std::collections::{BTreeMap, HashMap};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::config::{ClusterConfig, ExperimentConfig};
use crate::coordinator::executor::{
    execute_shared, execute_with, ExecEvent, ExecutionReport, ExecutorConfig,
};
use crate::coordinator::partitioner::MilpConfig;
use crate::coordinator::scheduler::{
    JobSpec, JobStatus, OnlineScheduler, SchedulerConfig, SchedulerStats,
};
use crate::coordinator::shape::{ShapeObjective, ShapeOutcome, ShapeSearch};
use crate::coordinator::{sweep, Allocation, ModelSet, Partitioner, SweepConfig, TradeoffCurve};
use crate::milp::branch_bound::BnbLimits;
use crate::models::online::PlatformPrior;
use crate::obs::{self, Counter, ExecCounters, MetricsRegistry};
use crate::report::Experiment;
use crate::serve::shard::{quantize, BudgetKey, ShardMap};
use crate::util::json::Json;
use crate::workload::{GeneratorConfig, Workload};

use super::error::{CloudshapesError, Result};
use super::registry::PartitionerRegistry;

/// A partitioning decision plus its model predictions.
#[derive(Debug, Clone)]
pub struct PartitionSummary {
    /// Strategy that produced the allocation.
    pub partitioner: String,
    /// The budget C_k it was solved under (`None` = unconstrained).
    pub budget: Option<f64>,
    pub alloc: Allocation,
    /// Model-predicted makespan, seconds.
    pub predicted_latency_s: f64,
    /// Model-predicted billed cost, $.
    pub predicted_cost: f64,
}

/// A partition that was also executed on the cluster.
#[derive(Debug)]
pub struct Evaluation {
    pub partition: PartitionSummary,
    /// What actually happened when the allocation ran.
    pub execution: ExecutionReport,
}

/// A shape-optimisation result: the winning composition plus its predicted
/// objectives (see [`TradeoffSession::optimize_shape`]).
#[derive(Debug, Clone)]
pub struct ShapeSummary {
    /// Strategy that solved the inner per-composition partitions.
    pub partitioner: String,
    /// The objective the shape was optimised for.
    pub objective: ShapeObjective,
    /// Catalogue type names, aligned with `counts`.
    pub type_names: Vec<String>,
    /// The full outcome (counts, instance names, allocation, objectives).
    pub outcome: ShapeOutcome,
}

impl ShapeSummary {
    /// (type name, count) pairs of the winning composition, rented types
    /// only.
    pub fn composition(&self) -> Vec<(String, usize)> {
        self.type_names
            .iter()
            .zip(&self.outcome.point.counts)
            .filter(|(_, &c)| c > 0)
            .map(|(n, &c)| (n.clone(), c))
            .collect()
    }
}

/// Counters of the session's solution cache (exposed by the serve
/// protocol's `ping` op).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Partition/pareto requests answered from the cache.
    pub hits: u64,
    /// Requests that had to run the solver.
    pub misses: u64,
    /// Distinct (partitioner, quantized budget) partitions stored.
    pub partition_entries: usize,
    /// Distinct memoized trade-off curves.
    pub pareto_entries: usize,
}

/// Lifecycle of a background run started with
/// [`TradeoffSession::start_run`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum RunState {
    Running,
    Done,
    /// The executor errored; the message is the typed error's display.
    Failed(String),
}

/// Progress snapshot of a background run (the serve `status` op's payload).
#[derive(Debug, Clone)]
pub struct RunStatus {
    pub id: u64,
    pub state: RunState,
    pub partitioner: String,
    pub budget: Option<f64>,
    pub chunks_done: usize,
    pub chunks_total: usize,
    pub tasks_priced: usize,
    pub tasks_total: usize,
    pub failures: usize,
    pub retries: usize,
    pub migrations: usize,
    pub preemptions: usize,
    /// Final measurements, present once `state` is `Done`.
    pub makespan_secs: Option<f64>,
    pub cost: Option<f64>,
}

/// Mutable slot a background run's executor thread reports into. The
/// retry/migration/preemption/failure and chunks-done numbers are NOT stored
/// here — they live in the run's shared [`ExecCounters`] (the same tally the
/// executor increments and the final report reads), so a `status` poll and
/// the finished report can never disagree.
struct RunSlot {
    status: RunStatus,
    counters: Arc<ExecCounters>,
}

/// Background runs keyed by id. Finished runs are evicted oldest-first past
/// [`MAX_TRACKED_RUNS`]; when the cap is reached with every tracked run
/// still executing, new runs are refused — a serve client hammering `run`
/// cannot grow the thread count or the map without bound.
struct RunManager {
    runs: Mutex<HashMap<u64, Arc<Mutex<RunSlot>>>>,
    next_id: AtomicU64,
}

/// Upper bound on tracked runs (running ones are never evicted).
const MAX_TRACKED_RUNS: usize = 64;

impl RunManager {
    fn new() -> RunManager {
        RunManager { runs: Mutex::new(HashMap::new()), next_id: AtomicU64::new(1) }
    }

    fn insert(&self, slot: Arc<Mutex<RunSlot>>) -> Result<u64> {
        let mut runs = self.runs.lock().unwrap();
        if runs.len() >= MAX_TRACKED_RUNS {
            // Evict the oldest finished run (ids are monotone); with
            // nothing finished the cap is a hard concurrency limit.
            let victim = runs
                .iter()
                .filter(|(_, s)| s.lock().unwrap().status.state != RunState::Running)
                .map(|(id, _)| *id)
                .min();
            match victim {
                Some(v) => {
                    runs.remove(&v);
                }
                None => {
                    return Err(CloudshapesError::runtime(format!(
                        "too many concurrent runs (max {MAX_TRACKED_RUNS}): poll 'status' \
                         and retry once one finishes"
                    )))
                }
            }
        }
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        slot.lock().unwrap().status.id = id;
        runs.insert(id, slot);
        Ok(id)
    }

    fn get(&self, id: u64) -> Option<Arc<Mutex<RunSlot>>> {
        self.runs.lock().unwrap().get(&id).cloned()
    }
}

/// Hard cap on stored partitions, summed across every cache shard (each
/// shard caps at its share). A long-running `serve` process fed
/// ever-changing budgets (one `batch` request can carry 1024 of them) must
/// not grow without bound: past the cap, fresh keys are solved but not
/// stored, while existing entries keep hitting. The pareto maps need no cap
/// — their keys are registry strategy names, a fixed set.
const MAX_PARTITION_ENTRIES: usize = 4096;

/// Concurrent solution cache: solved partitions keyed by
/// `(strategy, quantized budget)` plus memoized trade-off curves per
/// strategy, partitioned into `[serve] shards` slices by the same
/// consistent-hash [`ShardMap`] the serve plane routes requests with — so
/// on the serve hot path each slice is only ever locked by the one worker
/// shard that owns it. Solves run *outside* the slice locks, so concurrent
/// misses on the same key may each solve once — the partitioners are
/// deterministic, so every caller still observes the same allocation
/// (first insert wins per slice). With one shard this is exactly the
/// legacy single-map cache.
struct SolutionCache {
    map: ShardMap,
    partitions: Vec<Mutex<HashMap<(String, Option<BudgetKey>), Arc<PartitionSummary>>>>,
    paretos: Vec<Mutex<HashMap<String, Arc<TradeoffCurve>>>>,
    /// Per-slice entry cap: the global bound split across shards.
    per_shard_cap: usize,
    /// Registry-backed tallies (`cache_hits_total` / `cache_misses_total`) —
    /// the single source both [`TradeoffSession::cache_stats`] (hence the
    /// serve `ping` op) and the `metrics` op read, so the two can never
    /// disagree. Handle-addressed counters count even when `[obs]` is
    /// disabled, keeping `ping` complete either way.
    hits: Arc<Counter>,
    misses: Arc<Counter>,
}

impl SolutionCache {
    fn new(reg: &MetricsRegistry, shards: usize) -> SolutionCache {
        let map = ShardMap::new(shards.max(1));
        SolutionCache {
            partitions: (0..map.shards()).map(|_| Mutex::new(HashMap::new())).collect(),
            paretos: (0..map.shards()).map(|_| Mutex::new(HashMap::new())).collect(),
            per_shard_cap: (MAX_PARTITION_ENTRIES / map.shards()).max(1),
            map,
            hits: reg.counter("cache_hits_total", ""),
            misses: reg.counter("cache_misses_total", ""),
        }
    }

    /// The partition slice owning `(strategy, quantized budget)`.
    fn partition_shard(
        &self,
        strategy: &str,
        budget: Option<BudgetKey>,
    ) -> &Mutex<HashMap<(String, Option<BudgetKey>), Arc<PartitionSummary>>> {
        &self.partitions[self.map.shard_for(strategy, budget)]
    }

    /// The pareto slice owning `strategy` (curves key on strategy alone).
    fn pareto_shard(&self, strategy: &str) -> &Mutex<HashMap<String, Arc<TradeoffCurve>>> {
        &self.paretos[self.map.shard_for(strategy, None)]
    }

    fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits.value(),
            misses: self.misses.value(),
            partition_entries: self.partitions.iter().map(|m| m.lock().unwrap().len()).sum(),
            pareto_entries: self.paretos.iter().map(|m| m.lock().unwrap().len()).sum(),
        }
    }
}

/// Builder for [`TradeoffSession`]. `cluster` and `workload` are mandatory;
/// everything else has paper-scale defaults.
pub struct SessionBuilder {
    base: ExperimentConfig,
    cluster: Option<ClusterConfig>,
    workload: Option<GeneratorConfig>,
    partitioner: String,
    sweep: Option<SweepConfig>,
    registry: PartitionerRegistry,
}

impl SessionBuilder {
    /// An empty builder: cluster and workload must be supplied before
    /// [`build`](SessionBuilder::build).
    pub fn new() -> SessionBuilder {
        SessionBuilder {
            base: ExperimentConfig::default(),
            cluster: None,
            workload: None,
            partitioner: "milp".to_string(),
            sweep: None,
            registry: PartitionerRegistry::with_builtins(),
        }
    }

    /// A builder pre-filled from a complete [`ExperimentConfig`] (TOML file
    /// or preset) — the path the CLI takes.
    pub fn from_config(cfg: ExperimentConfig) -> SessionBuilder {
        SessionBuilder {
            cluster: Some(cfg.cluster.clone()),
            workload: Some(cfg.workload.clone()),
            sweep: Some(cfg.sweep.clone()),
            base: cfg,
            partitioner: "milp".to_string(),
            registry: PartitionerRegistry::with_builtins(),
        }
    }

    /// The quick preset: 3 platforms, 8 small tasks, coarse sweep.
    pub fn quick() -> SessionBuilder {
        SessionBuilder::from_config(ExperimentConfig::quick())
    }

    /// Set the cluster to benchmark and execute on.
    pub fn cluster(mut self, cluster: ClusterConfig) -> SessionBuilder {
        self.cluster = Some(cluster);
        self
    }

    /// Set the workload to partition.
    pub fn workload(mut self, workload: GeneratorConfig) -> SessionBuilder {
        self.workload = Some(workload);
        self
    }

    /// Pick the default partitioning strategy by registered name.
    pub fn partitioner(mut self, name: &str) -> SessionBuilder {
        self.partitioner = name.to_string();
        self
    }

    /// Number of budget levels the ε-constraint sweep evaluates.
    pub fn budget_sweep(mut self, levels: usize) -> SessionBuilder {
        self.sweep = Some(SweepConfig { levels });
        self
    }

    /// Override the MILP search budgets.
    pub fn milp(mut self, cfg: MilpConfig) -> SessionBuilder {
        self.base.milp = cfg;
        self
    }

    /// Override execution controls (seed, worker threads).
    pub fn executor(mut self, cfg: ExecutorConfig) -> SessionBuilder {
        self.base.executor = cfg;
        self
    }

    /// Configure (and usually enable) the online job scheduler — the
    /// `[scheduler]` TOML section's programmatic twin. The scheduler thread
    /// starts lazily on the first [`TradeoffSession::submit_job`].
    pub fn scheduler(mut self, cfg: SchedulerConfig) -> SessionBuilder {
        self.base.scheduler = cfg;
        self
    }

    /// Configure the serve plane (shard count, admission limits, framing
    /// timeouts) — the `[serve]` TOML section's programmatic twin. The
    /// shard count also fixes the solution-cache partitioning, so it takes
    /// effect even when the session is used purely as a library.
    pub fn serve(mut self, cfg: crate::serve::ServeConfig) -> SessionBuilder {
        self.base.serve = cfg;
        self
    }

    /// Replace the whole strategy registry.
    pub fn registry(mut self, registry: PartitionerRegistry) -> SessionBuilder {
        self.registry = registry;
        self
    }

    /// Register one extra strategy on top of the current registry.
    pub fn register<F>(mut self, name: &str, factory: F) -> SessionBuilder
    where
        F: Fn(&ExperimentConfig) -> Box<dyn Partitioner> + Send + Sync + 'static,
    {
        self.registry.register(name, factory);
        self
    }

    /// Materialise the session: validates the builder, then benchmarks the
    /// cluster and fits models (the expensive step).
    pub fn build(self) -> Result<TradeoffSession> {
        let cluster = self.cluster.ok_or_else(|| {
            CloudshapesError::config(
                "session has no cluster: call SessionBuilder::cluster(...) \
                 or SessionBuilder::from_config(...)",
            )
        })?;
        let workload = self.workload.ok_or_else(|| {
            CloudshapesError::config(
                "session has no workload: call SessionBuilder::workload(...) \
                 or SessionBuilder::from_config(...)",
            )
        })?;
        self.registry.ensure(&self.partitioner)?;
        self.base.scheduler.validate()?;
        let sweep = self.sweep.unwrap_or_else(|| self.base.sweep.clone());
        let config = ExperimentConfig { cluster, workload, sweep, ..self.base };
        config.obs.validate()?;
        config.serve.validate()?;
        let experiment = Experiment::build(config)?;
        let obs = experiment.config.obs.build_registry();
        Ok(TradeoffSession {
            cache: SolutionCache::new(&obs, experiment.config.serve.shards),
            obs,
            experiment,
            registry: Arc::new(self.registry),
            default_partitioner: self.partitioner,
            runs: RunManager::new(),
            scheduler: Mutex::new(None),
        })
    }
}

impl Default for SessionBuilder {
    fn default() -> Self {
        SessionBuilder::new()
    }
}

/// A benchmarked, model-fitted trade-off engine over one cluster + workload.
///
/// Construction (via [`SessionBuilder`]) runs the benchmarking procedure
/// once; afterwards partitioning, sweeping and executing are all cheap to
/// repeat at different budgets — the intended long-running-service shape.
///
/// Repeated solves are cached: [`partition_with`](Self::partition_with)
/// (and everything built on it, including `evaluate` and the serve ops)
/// memoizes each `(strategy, quantized budget)` allocation, and
/// [`pareto_frontier_with`](Self::pareto_frontier_with) memoizes each
/// strategy's trade-off curve. The cache is safe to share across threads
/// (`serve` handles every connection on its own thread against one
/// session); [`cache_stats`](Self::cache_stats) reports hit/miss counters.
pub struct TradeoffSession {
    experiment: Experiment,
    registry: Arc<PartitionerRegistry>,
    default_partitioner: String,
    cache: SolutionCache,
    /// The session's private metrics registry (`[obs]`-configured); merged
    /// with the process-global one by [`metrics`](Self::metrics).
    obs: Arc<MetricsRegistry>,
    runs: RunManager,
    /// The online job scheduler, started lazily on the first
    /// [`submit_job`](Self::submit_job) (and only when `[scheduler]`
    /// enables it).
    scheduler: Mutex<Option<Arc<OnlineScheduler>>>,
}

impl Drop for TradeoffSession {
    fn drop(&mut self) {
        if let Some(s) = self.scheduler.lock().unwrap().take() {
            s.shutdown();
        }
    }
}

impl TradeoffSession {
    /// The underlying experiment (cluster, workload, benchmark report,
    /// fitted + nominal models) for report generators.
    pub fn experiment(&self) -> &Experiment {
        &self.experiment
    }

    /// The benchmark-fitted models the partitioners consume.
    pub fn models(&self) -> &ModelSet {
        self.experiment.models()
    }

    pub fn workload(&self) -> &Workload {
        &self.experiment.workload
    }

    pub fn config(&self) -> &ExperimentConfig {
        &self.experiment.config
    }

    /// Name of the session's default strategy.
    pub fn default_partitioner(&self) -> &str {
        &self.default_partitioner
    }

    /// All registered strategy names.
    pub fn partitioner_names(&self) -> Vec<&str> {
        self.registry.names()
    }

    /// Instantiate a strategy: `None` = the session default.
    pub fn make_partitioner(&self, name: Option<&str>) -> Result<Box<dyn Partitioner>> {
        self.registry.create(
            name.unwrap_or(&self.default_partitioner),
            &self.experiment.config,
        )
    }

    /// Partition the workload at `budget` with the default strategy.
    pub fn partition(&self, budget: Option<f64>) -> Result<PartitionSummary> {
        self.partition_with(None, budget)
    }

    /// Partition with a named strategy (`None` = session default). Solved
    /// allocations are cached per `(strategy, quantized budget)`; repeat
    /// requests — including through `evaluate` and the serve `partition` /
    /// `evaluate` / `batch` ops — skip the solver entirely.
    pub fn partition_with(
        &self,
        name: Option<&str>,
        budget: Option<f64>,
    ) -> Result<PartitionSummary> {
        let strategy = name.unwrap_or(&self.default_partitioner).to_string();
        let key = (strategy, quantize(budget));
        let shard = self.cache.partition_shard(&key.0, key.1);
        if let Some(hit) = shard.lock().unwrap().get(&key) {
            self.cache.hits.inc();
            return Ok((**hit).clone());
        }
        self.cache.misses.inc();
        let _span = crate::span!("solve", key.0);
        let started = Instant::now();
        let part = self.registry.create(&key.0, &self.experiment.config)?;
        let alloc = part.partition(self.models(), budget)?;
        self.obs.observe(
            "solve_latency_secs",
            &format!("strategy={}", key.0),
            started.elapsed().as_secs_f64(),
        );
        let (predicted_latency_s, predicted_cost) = self.models().evaluate(&alloc);
        let summary = PartitionSummary {
            partitioner: part.name().to_string(),
            budget,
            alloc,
            predicted_latency_s,
            predicted_cost,
        };
        // First insert wins so all readers observe one allocation even if
        // concurrent misses raced on the solve; at capacity the result is
        // served without being stored.
        let summary = Arc::new(summary);
        let cached = {
            let mut map = shard.lock().unwrap();
            if map.len() >= self.cache.per_shard_cap && !map.contains_key(&key) {
                Arc::clone(&summary)
            } else {
                Arc::clone(map.entry(key).or_insert_with(|| Arc::clone(&summary)))
            }
        };
        Ok((*cached).clone())
    }

    /// Generate the ε-constraint latency-cost trade-off curve with the
    /// default strategy.
    pub fn pareto_frontier(&self) -> Result<TradeoffCurve> {
        self.pareto_frontier_with(None)
    }

    /// Trade-off curve for a named strategy (`None` = session default).
    /// Memoized per strategy: the sweep config is fixed at build time, so
    /// the curve is solved at most once per strategy per session.
    pub fn pareto_frontier_with(&self, name: Option<&str>) -> Result<TradeoffCurve> {
        let strategy = name.unwrap_or(&self.default_partitioner).to_string();
        let shard = self.cache.pareto_shard(&strategy);
        if let Some(hit) = shard.lock().unwrap().get(&strategy) {
            self.cache.hits.inc();
            return Ok((**hit).clone());
        }
        self.cache.misses.inc();
        let _span = crate::span!("pareto_sweep", strategy);
        let part = self.registry.create(&strategy, &self.experiment.config)?;
        let curve = sweep(part.as_ref(), self.models(), &self.experiment.config.sweep)?;
        let cached = Arc::clone(
            shard.lock().unwrap().entry(strategy).or_insert_with(|| Arc::new(curve)),
        );
        Ok((*cached).clone())
    }

    /// Hit/miss counters and entry counts of the solution cache.
    pub fn cache_stats(&self) -> CacheStats {
        self.cache.stats()
    }

    /// The session cluster's composition: (type name, instance count).
    pub fn composition(&self) -> Vec<(String, usize)> {
        self.experiment.cluster.composition()
    }

    /// Optimise the *cluster shape* for `objective`: search instance-count
    /// compositions of the session's catalogue (outer branch & bound over
    /// per-type fitted models) around the named inner partitioner (`None`
    /// = session default). The outer search reuses the `[milp]` budgets.
    ///
    /// Returns predictions only — the winning composition is a rental plan,
    /// not this session's benchmarked cluster; re-build a session with
    /// [`ClusterConfig::counts`] pinned to the returned shape to execute it.
    pub fn optimize_shape(
        &self,
        name: Option<&str>,
        objective: ShapeObjective,
    ) -> Result<ShapeSummary> {
        let inner = self.make_partitioner(name)?;
        let types = self.experiment.type_models();
        let avail = self.experiment.catalogue.availability();
        // The `[milp]` budgets govern the outer search too — one knob caps
        // all solver work. (Its branch & bound is anytime: node/time limits
        // stop it on the best incumbent found, never on nothing.)
        let milp = &self.experiment.config.milp;
        let limits = BnbLimits {
            max_nodes: milp.max_nodes,
            rel_gap: milp.rel_gap,
            time_limit_secs: milp.time_limit_secs,
            workers: milp.workers,
        };
        let _span = crate::span!("shape_solve", inner.name());
        let started = Instant::now();
        let search = ShapeSearch::new(&types, &avail, inner.as_ref(), limits)?;
        let outcome = search.optimize(objective)?;
        self.obs.observe("shape_solve_secs", "", started.elapsed().as_secs_f64());
        self.obs.inc("shape_nodes_total", "", outcome.nodes as u64);
        Ok(ShapeSummary {
            partitioner: inner.name().to_string(),
            objective,
            type_names: types.platform_names.clone(),
            outcome,
        })
    }

    /// Partition at `budget` AND execute the allocation on the cluster.
    pub fn evaluate(&self, budget: Option<f64>) -> Result<Evaluation> {
        self.evaluate_with(None, budget)
    }

    /// As [`evaluate`](TradeoffSession::evaluate) with a named strategy.
    pub fn evaluate_with(&self, name: Option<&str>, budget: Option<f64>) -> Result<Evaluation> {
        self.evaluate_with_events(name, budget, &mut |_| {})
    }

    /// As [`evaluate_with`](TradeoffSession::evaluate_with), streaming the
    /// chunked executor's [`ExecEvent`]s to `on_event` (called on the
    /// caller's thread) — the CLI `--watch` view and the serve protocol's
    /// streaming `run` op consume this.
    pub fn evaluate_with_events(
        &self,
        name: Option<&str>,
        budget: Option<f64>,
        on_event: &mut dyn FnMut(&ExecEvent),
    ) -> Result<Evaluation> {
        let partition = self.partition_with(name, budget)?;
        let execution = self.execute_allocation_with(&partition.alloc, on_event)?;
        Ok(Evaluation { partition, execution })
    }

    /// Execute an externally-produced allocation (report generators use
    /// this to measure sweep points).
    pub fn execute_allocation(&self, alloc: &Allocation) -> Result<ExecutionReport> {
        self.execute_allocation_with(alloc, &mut |_| {})
    }

    /// As [`execute_allocation`](Self::execute_allocation) with an event
    /// observer. The session's benchmark-fitted models guide the executor's
    /// straggler detection.
    pub fn execute_allocation_with(
        &self,
        alloc: &Allocation,
        on_event: &mut dyn FnMut(&ExecEvent),
    ) -> Result<ExecutionReport> {
        let _span = crate::span!("execute");
        let models = self.models();
        // Tee the event stream through the registry bridge: chunk latency,
        // queue depth and model error land in the session metrics without
        // the executor knowing telemetry exists.
        let mut tee = |ev: &ExecEvent| {
            obs::record_exec_event(&self.obs, Some(models), ev);
            on_event(ev);
        };
        execute_with(
            &self.experiment.cluster,
            &self.experiment.workload,
            alloc,
            &self.experiment.config.executor,
            Some(models),
            &mut tee,
        )
    }

    /// Start a background execution: partition at `budget` (solved inline so
    /// infeasible budgets fail fast), then execute on a detached thread.
    /// Returns the run id to poll with [`run_status`](Self::run_status) —
    /// the serve protocol's `run`/`status` op pair.
    pub fn start_run(&self, name: Option<&str>, budget: Option<f64>) -> Result<u64> {
        let partition = self.partition_with(name, budget)?;
        // One tally for the whole run: the executor increments it, the final
        // report is derived from it, and `run_status` reads it live.
        let counters = Arc::new(ExecCounters::default());
        let slot = Arc::new(Mutex::new(RunSlot {
            status: RunStatus {
                id: 0,
                state: RunState::Running,
                partitioner: partition.partitioner.clone(),
                budget: partition.budget,
                chunks_done: 0,
                chunks_total: 0,
                tasks_priced: 0,
                tasks_total: self.experiment.workload.len(),
                failures: 0,
                retries: 0,
                migrations: 0,
                preemptions: 0,
                makespan_secs: None,
                cost: None,
            },
            counters: Arc::clone(&counters),
        }));
        let id = self.runs.insert(Arc::clone(&slot))?;
        // The executor thread owns clones of everything it needs (platforms
        // are `Arc`-shared inside the cluster), so the session itself need
        // not be `'static`.
        let cluster = self.experiment.cluster.clone();
        let workload = self.experiment.workload.clone();
        let models = self.models().clone();
        let cfg = self.experiment.config.executor.clone();
        let alloc = partition.alloc;
        let reg = Arc::clone(&self.obs);
        std::thread::Builder::new()
            .name(format!("cloudshapes-run-{id}"))
            .spawn(move || {
                let on_event = &mut |ev: &ExecEvent| {
                    obs::record_exec_event(&reg, Some(&models), ev);
                    let mut slot = slot.lock().unwrap();
                    let s = &mut slot.status;
                    match ev {
                        ExecEvent::Started { chunks, .. } => s.chunks_total = *chunks,
                        ExecEvent::TaskPriced { .. } => s.tasks_priced += 1,
                        // Chunk/retry/migration/preemption/failure tallies
                        // come from the shared counters, not re-counted here.
                        _ => {}
                    }
                };
                let result = execute_shared(
                    &cluster,
                    &workload,
                    &alloc,
                    &cfg,
                    Some(&models),
                    &counters,
                    on_event,
                );
                let mut slot = slot.lock().unwrap();
                match result {
                    Ok(rep) => {
                        slot.status.state = RunState::Done;
                        slot.status.makespan_secs = Some(rep.makespan_secs);
                        slot.status.cost = Some(rep.cost);
                    }
                    Err(e) => slot.status.state = RunState::Failed(e.to_string()),
                }
            })
            .map_err(|e| CloudshapesError::runtime(format!("spawning run thread: {e}")))?;
        Ok(id)
    }

    /// Progress snapshot of a background run (None for unknown/evicted ids).
    /// The chunk/retry/migration/preemption/failure numbers are read from
    /// the run's shared executor tally, so they always agree with the
    /// eventual [`ExecutionReport`].
    pub fn run_status(&self, id: u64) -> Option<RunStatus> {
        self.runs.get(id).map(|slot| {
            let slot = slot.lock().unwrap();
            let mut status = slot.status.clone();
            status.chunks_done = slot.counters.chunks();
            status.retries = slot.counters.retries();
            status.migrations = slot.counters.migrations();
            status.preemptions = slot.counters.preemptions();
            status.failures = slot.counters.failures();
            status
        })
    }

    /// Merged metrics snapshot (optionally filtered to names containing
    /// `filter`): the process-global registry (solver-level metrics)
    /// overlaid with this session's. Backs the serve protocol's `metrics`
    /// op and the `cloudshapes metrics` command.
    pub fn metrics(&self, filter: Option<&str>) -> Json {
        let mut out = BTreeMap::new();
        obs::global().snapshot_into(&mut out, filter);
        self.obs.snapshot_into(&mut out, filter);
        Json::Obj(out)
    }

    /// The session's private metrics registry (profiling hooks and the
    /// serve loop record into it).
    pub fn metrics_registry(&self) -> &Arc<MetricsRegistry> {
        &self.obs
    }

    /// Submit a pricing job to the online scheduler (started lazily on the
    /// first submit). Requires the scheduler to be enabled — via
    /// `[scheduler] enabled = true`, [`SessionBuilder::scheduler`], or
    /// `serve --scheduler`; disabled sessions answer with a typed config
    /// error. Returns the job id to poll with
    /// [`job_status`](Self::job_status):
    ///
    /// ```no_run
    /// use cloudshapes::api::SessionBuilder;
    /// use cloudshapes::coordinator::scheduler::{JobSpec, SchedulerConfig, Slo};
    ///
    /// let session = SessionBuilder::quick()
    ///     .partitioner("heuristic")
    ///     .scheduler(SchedulerConfig { enabled: true, ..Default::default() })
    ///     .build()?;
    /// let id = session.submit_job(JobSpec::generate(
    ///     None,                  // any payoff family
    ///     4,                     // tasks
    ///     0.05,                  // accuracy, $
    ///     7,                     // seed
    ///     Slo::Deadline(3600.0), // finish within an hour of virtual time
    /// )?)?;
    /// let status = session.job_status(id)?.expect("job is tracked");
    /// println!("job {id} is {}", status.state.name());
    /// # Ok::<(), cloudshapes::api::CloudshapesError>(())
    /// ```
    pub fn submit_job(&self, spec: JobSpec) -> Result<u64> {
        self.scheduler()?.submit(spec)
    }

    /// Submit many jobs at once (the `submit_batch` op's path): one
    /// scheduler handle lookup, then one independent submit per spec —
    /// entry `k` of the result is spec `k`'s job id or its typed error
    /// (e.g. an `overload` shed), so one refused job never fails the rest
    /// of the book. The outer error covers only a disabled scheduler.
    pub fn submit_jobs(&self, specs: Vec<JobSpec>) -> Result<Vec<Result<u64>>> {
        let s = self.scheduler()?;
        Ok(specs.into_iter().map(|spec| s.submit(spec)).collect())
    }

    /// Snapshot one job (`Ok(None)` for unknown ids; an error when the
    /// scheduler is disabled).
    pub fn job_status(&self, id: u64) -> Result<Option<JobStatus>> {
        Ok(self.try_scheduler()?.and_then(|s| s.job_status(id)))
    }

    /// Snapshot every tracked job, in submission order.
    pub fn jobs(&self) -> Result<Vec<JobStatus>> {
        Ok(self.try_scheduler()?.map(|s| s.jobs()).unwrap_or_default())
    }

    /// Cancel a job: `Some(true)` if it transitioned to cancelled (its
    /// capacity returns to the queue at the next epoch boundary),
    /// `Some(false)` if already terminal, `None` for unknown ids.
    pub fn cancel_job(&self, id: u64) -> Result<Option<bool>> {
        Ok(self.try_scheduler()?.and_then(|s| s.cancel(id)))
    }

    /// Scheduler counters (defaults before the first submit). The
    /// epoch-record ring is left empty here — it exists for diagnostics
    /// and tests on [`OnlineScheduler::stats`] directly; cloning it on
    /// every `ping` would tax a liveness probe.
    pub fn scheduler_stats(&self) -> Result<SchedulerStats> {
        Ok(self.try_scheduler()?.map(|s| s.counters()).unwrap_or_default())
    }

    /// The started scheduler when one exists; a typed config error when the
    /// session has job scheduling disabled. Query paths use this so they
    /// never spin the thread up as a side effect.
    fn try_scheduler(&self) -> Result<Option<Arc<OnlineScheduler>>> {
        if !self.experiment.config.scheduler.enabled {
            return Err(CloudshapesError::config(
                "the online scheduler is disabled: set [scheduler] enabled = true \
                 (or start `serve --scheduler`) before using job ops",
            ));
        }
        Ok(self.scheduler.lock().unwrap().clone())
    }

    /// Get-or-start the scheduler (submit path).
    fn scheduler(&self) -> Result<Arc<OnlineScheduler>> {
        if let Some(s) = self.try_scheduler()? {
            return Ok(s);
        }
        let mut guard = self.scheduler.lock().unwrap();
        if let Some(s) = &*guard {
            return Ok(Arc::clone(s));
        }
        // Priors: per-platform effective throughput and setup, averaged
        // over the benchmark-fitted (platform, task) models — the best
        // estimate of each platform the session owns.
        let m = self.models();
        let tasks = &self.experiment.workload.tasks;
        let priors: Vec<PlatformPrior> = (0..m.mu)
            .map(|i| {
                let n = m.tau as f64;
                let throughput = (0..m.tau)
                    .map(|j| tasks[j].flops_per_path() / m.model(i, j).beta)
                    .sum::<f64>()
                    / n;
                let setup =
                    (0..m.tau).map(|j| m.model(i, j).gamma).sum::<f64>() / n;
                PlatformPrior {
                    throughput_flops: throughput.max(1e-9),
                    setup_secs: setup.max(0.0),
                }
            })
            .collect();
        let registry = Arc::clone(&self.registry);
        let config = self.experiment.config.clone();
        let name = self.default_partitioner.clone();
        let scheduler = OnlineScheduler::start_instrumented(
            self.experiment.cluster.clone(),
            priors,
            self.experiment.config.executor.clone(),
            self.experiment.config.scheduler.clone(),
            Some(Arc::clone(&self.obs)),
            move || registry.create(&name, &config),
        )?;
        let scheduler = Arc::new(scheduler);
        *guard = Some(Arc::clone(&scheduler));
        Ok(scheduler)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::scheduler::{JobState, Slo};
    use crate::workload::Payoff;

    #[test]
    fn job_ops_require_the_scheduler_enabled() {
        let session = SessionBuilder::quick().partitioner("heuristic").build().unwrap();
        let spec = JobSpec::generate(None, 1, 0.05, 1, Slo::Deadline(10.0)).unwrap();
        let e = session.submit_job(spec).unwrap_err();
        assert_eq!(e.kind(), "config");
        assert!(e.message().contains("scheduler"), "{e}");
        assert!(session.jobs().is_err());
        assert!(session.job_status(1).is_err());
        assert!(session.cancel_job(1).is_err());
        assert!(session.scheduler_stats().is_err());
    }

    #[test]
    fn submitted_job_runs_to_completion_through_the_session() {
        let session = SessionBuilder::quick()
            .partitioner("heuristic")
            .scheduler(SchedulerConfig { enabled: true, ..Default::default() })
            .build()
            .unwrap();
        // Enabled but not yet started: queries answer empties, not errors.
        assert!(session.jobs().unwrap().is_empty());
        assert!(session.job_status(1).unwrap().is_none());
        assert_eq!(session.scheduler_stats().unwrap().epochs, 0);
        let spec = JobSpec::generate(
            Some(Payoff::European),
            2,
            0.05,
            3,
            Slo::Budget(1000.0),
        )
        .unwrap();
        let id = session.submit_job(spec).unwrap();
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(60);
        let status = loop {
            let s = session.job_status(id).unwrap().expect("job tracked");
            if s.state.is_terminal() {
                break s;
            }
            assert!(std::time::Instant::now() < deadline, "job never finished");
            std::thread::sleep(std::time::Duration::from_millis(5));
        };
        assert_eq!(status.state, JobState::Done);
        assert_eq!(status.slo_met, Some(true));
        assert!(status.cost > 0.0);
        assert!(session.scheduler_stats().unwrap().epochs >= 1);
        assert_eq!(session.jobs().unwrap().len(), 1);
    }

    #[test]
    fn missing_cluster_is_a_config_error() {
        let e = SessionBuilder::new()
            .workload(GeneratorConfig::small(4, 0.05, 1))
            .build()
            .unwrap_err();
        assert_eq!(e.kind(), "config");
        assert!(e.message().contains("cluster"), "{e}");
    }

    #[test]
    fn missing_workload_is_a_config_error() {
        let e = SessionBuilder::new()
            .cluster(ExperimentConfig::quick().cluster)
            .build()
            .unwrap_err();
        assert_eq!(e.kind(), "config");
        assert!(e.message().contains("workload"), "{e}");
    }

    #[test]
    fn unregistered_partitioner_is_a_config_error() {
        let e = SessionBuilder::quick().partitioner("quantum-annealer").build().unwrap_err();
        assert_eq!(e.kind(), "config");
        assert!(e.message().contains("quantum-annealer"), "{e}");
    }

    #[test]
    fn partition_cache_hits_on_repeat_budgets() {
        let session = SessionBuilder::quick().partitioner("heuristic").build().unwrap();
        assert_eq!(session.cache_stats(), CacheStats::default());
        let a = session.partition(None).unwrap();
        let s = session.cache_stats();
        assert_eq!((s.hits, s.misses, s.partition_entries), (0, 1, 1));
        // Same key again — including spelling the default strategy out.
        let b = session.partition(None).unwrap();
        let c = session.partition_with(Some("heuristic"), None).unwrap();
        assert_eq!(session.cache_stats().hits, 2);
        assert_eq!(session.cache_stats().misses, 1);
        assert_eq!(a.alloc, b.alloc);
        assert_eq!(a.alloc, c.alloc);
        // A different quantized budget is a fresh entry.
        let _ = session.partition(Some(1e6)).unwrap();
        let s = session.cache_stats();
        assert_eq!((s.misses, s.partition_entries), (2, 2));
    }

    #[test]
    fn budget_cache_keys_quantize_but_never_collide() {
        // Float jitter below the quantum folds to one key...
        assert_eq!(quantize(Some(2.5)), quantize(Some(2.5 + 1e-12)));
        // ...distinct budgets do not...
        assert_ne!(quantize(Some(2.5)), quantize(Some(2.6)));
        // ...and budgets beyond the quantizable range stay distinct instead
        // of collapsing onto the saturated key.
        assert_ne!(quantize(Some(1e10)), quantize(Some(2e10)));
        assert_eq!(quantize(None), None);
    }

    #[test]
    fn pareto_curve_is_memoized_per_strategy() {
        let session = SessionBuilder::quick()
            .partitioner("heuristic")
            .budget_sweep(3)
            .build()
            .unwrap();
        let a = session.pareto_frontier().unwrap();
        let misses = session.cache_stats().misses;
        let b = session.pareto_frontier().unwrap();
        let s = session.cache_stats();
        assert_eq!(s.misses, misses, "second sweep must not re-solve");
        assert_eq!(s.hits, 1);
        assert_eq!(s.pareto_entries, 1);
        assert_eq!(a.points.len(), b.points.len());
    }

    #[test]
    fn failed_solves_are_not_cached() {
        let session = SessionBuilder::quick().partitioner("milp").build().unwrap();
        // An impossibly tight budget is a solver error; it must not poison
        // the cache with an entry.
        assert!(session.partition(Some(1e-9)).is_err());
        let s = session.cache_stats();
        assert_eq!(s.partition_entries, 0);
        assert_eq!(s.misses, 1);
    }

    #[test]
    fn background_run_reports_progress_then_results() {
        let session = SessionBuilder::quick().partitioner("heuristic").build().unwrap();
        let id = session.start_run(None, None).unwrap();
        let mut status = session.run_status(id).expect("run is tracked");
        assert_eq!(status.partitioner, "heuristic");
        assert_eq!(status.tasks_total, 8);
        // Poll to completion (the quick workload executes in well under a
        // second of wall-clock; the deadline only guards CI hiccups).
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(30);
        while status.state == RunState::Running {
            assert!(std::time::Instant::now() < deadline, "run never finished");
            std::thread::sleep(std::time::Duration::from_millis(10));
            status = session.run_status(id).unwrap();
        }
        assert_eq!(status.state, RunState::Done);
        assert!(status.chunks_total > 0);
        assert_eq!(status.chunks_done, status.chunks_total);
        assert_eq!(status.tasks_priced, 8);
        assert!(status.makespan_secs.unwrap() > 0.0);
        assert!(status.cost.unwrap() > 0.0);
        // Unknown ids are None, infeasible budgets fail fast.
        assert!(session.run_status(10_000).is_none());
        assert!(session.start_run(Some("milp"), Some(1e-9)).is_err());
    }

    #[test]
    fn optimize_shape_returns_a_composition() {
        let session = SessionBuilder::quick().partitioner("heuristic").build().unwrap();
        // A deadline twice the unconstrained testbed makespan is loose:
        // every inner solve is cheap and the search must succeed.
        let p = session.partition(None).unwrap();
        let shape = session
            .optimize_shape(None, ShapeObjective::Deadline(p.predicted_latency_s * 2.0))
            .unwrap();
        assert_eq!(shape.partitioner, "heuristic");
        assert_eq!(shape.type_names.len(), 3);
        let total: usize = shape.outcome.point.counts.iter().sum();
        assert!(total >= 1);
        assert!(!shape.composition().is_empty());
        assert!(shape.outcome.point.latency <= p.predicted_latency_s * 2.0 + 1e-9);
        assert!(shape.outcome.point.cost > 0.0);
        // Unknown inner strategies fail fast.
        assert!(session
            .optimize_shape(Some("nope"), ShapeObjective::Deadline(1000.0))
            .is_err());
    }

    #[test]
    fn quick_session_partitions_and_sweeps() {
        let session = SessionBuilder::quick()
            .partitioner("heuristic")
            .budget_sweep(4)
            .build()
            .unwrap();
        let p = session.partition(None).unwrap();
        assert_eq!(p.partitioner, "heuristic");
        assert!(p.predicted_latency_s > 0.0 && p.predicted_cost > 0.0);
        assert!(p.alloc.validate().is_ok());
        let curve = session.pareto_frontier().unwrap();
        assert!(curve.points.len() >= 2);
    }
}
