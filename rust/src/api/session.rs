//! [`TradeoffSession`]: the one front door to the trade-off engine.
//!
//! A session owns the whole pipeline the paper describes — benchmark the
//! cluster (§III.A), fit latency/cost models, partition under budgets
//! (§III.B-C), execute allocations — behind a builder:
//!
//! ```no_run
//! use cloudshapes::api::SessionBuilder;
//! use cloudshapes::config::ExperimentConfig;
//!
//! let cfg = ExperimentConfig::quick();
//! let session = SessionBuilder::new()
//!     .cluster(cfg.cluster.clone())
//!     .workload(cfg.workload.clone())
//!     .partitioner("milp")
//!     .budget_sweep(7)
//!     .build()?;
//! let frontier = session.pareto_frontier()?;
//! let run = session.evaluate(Some(2.5))?;
//! println!(
//!     "measured {:.1}s for ${:.3}",
//!     run.execution.makespan_secs, run.execution.cost
//! );
//! # Ok::<(), cloudshapes::api::CloudshapesError>(())
//! ```
//!
//! The CLI, the serve protocol, the examples and the benches all go through
//! this type; nothing else in the crate wires clusters to partitioners by
//! hand.

use crate::config::{ClusterConfig, ExperimentConfig};
use crate::coordinator::executor::{execute, ExecutionReport, ExecutorConfig};
use crate::coordinator::partitioner::MilpConfig;
use crate::coordinator::{sweep, Allocation, ModelSet, Partitioner, SweepConfig, TradeoffCurve};
use crate::report::Experiment;
use crate::workload::{GeneratorConfig, Workload};

use super::error::{CloudshapesError, Result};
use super::registry::PartitionerRegistry;

/// A partitioning decision plus its model predictions.
#[derive(Debug, Clone)]
pub struct PartitionSummary {
    /// Strategy that produced the allocation.
    pub partitioner: String,
    /// The budget C_k it was solved under (`None` = unconstrained).
    pub budget: Option<f64>,
    pub alloc: Allocation,
    /// Model-predicted makespan, seconds.
    pub predicted_latency_s: f64,
    /// Model-predicted billed cost, $.
    pub predicted_cost: f64,
}

/// A partition that was also executed on the cluster.
#[derive(Debug)]
pub struct Evaluation {
    pub partition: PartitionSummary,
    /// What actually happened when the allocation ran.
    pub execution: ExecutionReport,
}

/// Builder for [`TradeoffSession`]. `cluster` and `workload` are mandatory;
/// everything else has paper-scale defaults.
pub struct SessionBuilder {
    base: ExperimentConfig,
    cluster: Option<ClusterConfig>,
    workload: Option<GeneratorConfig>,
    partitioner: String,
    sweep: Option<SweepConfig>,
    registry: PartitionerRegistry,
}

impl SessionBuilder {
    /// An empty builder: cluster and workload must be supplied before
    /// [`build`](SessionBuilder::build).
    pub fn new() -> SessionBuilder {
        SessionBuilder {
            base: ExperimentConfig::default(),
            cluster: None,
            workload: None,
            partitioner: "milp".to_string(),
            sweep: None,
            registry: PartitionerRegistry::with_builtins(),
        }
    }

    /// A builder pre-filled from a complete [`ExperimentConfig`] (TOML file
    /// or preset) — the path the CLI takes.
    pub fn from_config(cfg: ExperimentConfig) -> SessionBuilder {
        SessionBuilder {
            cluster: Some(cfg.cluster.clone()),
            workload: Some(cfg.workload.clone()),
            sweep: Some(cfg.sweep.clone()),
            base: cfg,
            partitioner: "milp".to_string(),
            registry: PartitionerRegistry::with_builtins(),
        }
    }

    /// The quick preset: 3 platforms, 8 small tasks, coarse sweep.
    pub fn quick() -> SessionBuilder {
        SessionBuilder::from_config(ExperimentConfig::quick())
    }

    /// Set the cluster to benchmark and execute on.
    pub fn cluster(mut self, cluster: ClusterConfig) -> SessionBuilder {
        self.cluster = Some(cluster);
        self
    }

    /// Set the workload to partition.
    pub fn workload(mut self, workload: GeneratorConfig) -> SessionBuilder {
        self.workload = Some(workload);
        self
    }

    /// Pick the default partitioning strategy by registered name.
    pub fn partitioner(mut self, name: &str) -> SessionBuilder {
        self.partitioner = name.to_string();
        self
    }

    /// Number of budget levels the ε-constraint sweep evaluates.
    pub fn budget_sweep(mut self, levels: usize) -> SessionBuilder {
        self.sweep = Some(SweepConfig { levels });
        self
    }

    /// Override the MILP search budgets.
    pub fn milp(mut self, cfg: MilpConfig) -> SessionBuilder {
        self.base.milp = cfg;
        self
    }

    /// Override execution controls (seed, worker threads).
    pub fn executor(mut self, cfg: ExecutorConfig) -> SessionBuilder {
        self.base.executor = cfg;
        self
    }

    /// Replace the whole strategy registry.
    pub fn registry(mut self, registry: PartitionerRegistry) -> SessionBuilder {
        self.registry = registry;
        self
    }

    /// Register one extra strategy on top of the current registry.
    pub fn register<F>(mut self, name: &str, factory: F) -> SessionBuilder
    where
        F: Fn(&ExperimentConfig) -> Box<dyn Partitioner> + Send + Sync + 'static,
    {
        self.registry.register(name, factory);
        self
    }

    /// Materialise the session: validates the builder, then benchmarks the
    /// cluster and fits models (the expensive step).
    pub fn build(self) -> Result<TradeoffSession> {
        let cluster = self.cluster.ok_or_else(|| {
            CloudshapesError::config(
                "session has no cluster: call SessionBuilder::cluster(...) \
                 or SessionBuilder::from_config(...)",
            )
        })?;
        let workload = self.workload.ok_or_else(|| {
            CloudshapesError::config(
                "session has no workload: call SessionBuilder::workload(...) \
                 or SessionBuilder::from_config(...)",
            )
        })?;
        self.registry.ensure(&self.partitioner)?;
        let sweep = self.sweep.unwrap_or_else(|| self.base.sweep.clone());
        let config = ExperimentConfig { cluster, workload, sweep, ..self.base };
        let experiment = Experiment::build(config)?;
        Ok(TradeoffSession {
            experiment,
            registry: self.registry,
            default_partitioner: self.partitioner,
        })
    }
}

impl Default for SessionBuilder {
    fn default() -> Self {
        SessionBuilder::new()
    }
}

/// A benchmarked, model-fitted trade-off engine over one cluster + workload.
///
/// Construction (via [`SessionBuilder`]) runs the benchmarking procedure
/// once; afterwards partitioning, sweeping and executing are all cheap to
/// repeat at different budgets — the intended long-running-service shape.
pub struct TradeoffSession {
    experiment: Experiment,
    registry: PartitionerRegistry,
    default_partitioner: String,
}

impl TradeoffSession {
    /// The underlying experiment (cluster, workload, benchmark report,
    /// fitted + nominal models) for report generators.
    pub fn experiment(&self) -> &Experiment {
        &self.experiment
    }

    /// The benchmark-fitted models the partitioners consume.
    pub fn models(&self) -> &ModelSet {
        self.experiment.models()
    }

    pub fn workload(&self) -> &Workload {
        &self.experiment.workload
    }

    pub fn config(&self) -> &ExperimentConfig {
        &self.experiment.config
    }

    /// Name of the session's default strategy.
    pub fn default_partitioner(&self) -> &str {
        &self.default_partitioner
    }

    /// All registered strategy names.
    pub fn partitioner_names(&self) -> Vec<&str> {
        self.registry.names()
    }

    /// Instantiate a strategy: `None` = the session default.
    pub fn make_partitioner(&self, name: Option<&str>) -> Result<Box<dyn Partitioner>> {
        self.registry.create(
            name.unwrap_or(&self.default_partitioner),
            &self.experiment.config,
        )
    }

    /// Partition the workload at `budget` with the default strategy.
    pub fn partition(&self, budget: Option<f64>) -> Result<PartitionSummary> {
        self.partition_with(None, budget)
    }

    /// Partition with a named strategy (`None` = session default).
    pub fn partition_with(
        &self,
        name: Option<&str>,
        budget: Option<f64>,
    ) -> Result<PartitionSummary> {
        let part = self.make_partitioner(name)?;
        let alloc = part.partition(self.models(), budget)?;
        let (predicted_latency_s, predicted_cost) = self.models().evaluate(&alloc);
        Ok(PartitionSummary {
            partitioner: part.name().to_string(),
            budget,
            alloc,
            predicted_latency_s,
            predicted_cost,
        })
    }

    /// Generate the ε-constraint latency-cost trade-off curve with the
    /// default strategy.
    pub fn pareto_frontier(&self) -> Result<TradeoffCurve> {
        self.pareto_frontier_with(None)
    }

    /// Trade-off curve for a named strategy (`None` = session default).
    pub fn pareto_frontier_with(&self, name: Option<&str>) -> Result<TradeoffCurve> {
        let part = self.make_partitioner(name)?;
        sweep(part.as_ref(), self.models(), &self.experiment.config.sweep)
    }

    /// Partition at `budget` AND execute the allocation on the cluster.
    pub fn evaluate(&self, budget: Option<f64>) -> Result<Evaluation> {
        self.evaluate_with(None, budget)
    }

    /// As [`evaluate`](TradeoffSession::evaluate) with a named strategy.
    pub fn evaluate_with(&self, name: Option<&str>, budget: Option<f64>) -> Result<Evaluation> {
        let partition = self.partition_with(name, budget)?;
        let execution = execute(
            &self.experiment.cluster,
            &self.experiment.workload,
            &partition.alloc,
            &self.experiment.config.executor,
        )?;
        Ok(Evaluation { partition, execution })
    }

    /// Execute an externally-produced allocation (report generators use
    /// this to measure sweep points).
    pub fn execute_allocation(&self, alloc: &Allocation) -> Result<ExecutionReport> {
        execute(
            &self.experiment.cluster,
            &self.experiment.workload,
            alloc,
            &self.experiment.config.executor,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn missing_cluster_is_a_config_error() {
        let e = SessionBuilder::new()
            .workload(GeneratorConfig::small(4, 0.05, 1))
            .build()
            .unwrap_err();
        assert_eq!(e.kind(), "config");
        assert!(e.message().contains("cluster"), "{e}");
    }

    #[test]
    fn missing_workload_is_a_config_error() {
        let e = SessionBuilder::new()
            .cluster(ExperimentConfig::quick().cluster)
            .build()
            .unwrap_err();
        assert_eq!(e.kind(), "config");
        assert!(e.message().contains("workload"), "{e}");
    }

    #[test]
    fn unregistered_partitioner_is_a_config_error() {
        let e = SessionBuilder::quick().partitioner("quantum-annealer").build().unwrap_err();
        assert_eq!(e.kind(), "config");
        assert!(e.message().contains("quantum-annealer"), "{e}");
    }

    #[test]
    fn quick_session_partitions_and_sweeps() {
        let session = SessionBuilder::quick()
            .partitioner("heuristic")
            .budget_sweep(4)
            .build()
            .unwrap();
        let p = session.partition(None).unwrap();
        assert_eq!(p.partitioner, "heuristic");
        assert!(p.predicted_latency_s > 0.0 && p.predicted_cost > 0.0);
        assert!(p.alloc.validate().is_ok());
        let curve = session.pareto_frontier().unwrap();
        assert!(curve.points.len() >= 2);
    }
}
