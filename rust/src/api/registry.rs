//! Pluggable partitioner registry: name → factory.
//!
//! Every partitioning strategy — the paper's MILP and heuristic, the Braun
//! et al. whole-task baselines, and any user-supplied strategy — registers
//! under a name; the CLI, the serve protocol and [`TradeoffSession`] resolve
//! strategies exclusively through the registry, so adding a strategy never
//! touches the coordinator.
//!
//! [`TradeoffSession`]: super::session::TradeoffSession

use std::collections::BTreeMap;

use crate::config::ExperimentConfig;
use crate::coordinator::partitioner::baselines::{Classic, ClassicPartitioner};
use crate::coordinator::{HeuristicPartitioner, MilpPartitioner, Partitioner};

use super::error::{CloudshapesError, Result};

/// Builds a partitioner from the experiment configuration (strategies read
/// their knobs — e.g. [`MilpConfig`](crate::coordinator::MilpConfig) — from
/// it).
pub type PartitionerFactory = Box<dyn Fn(&ExperimentConfig) -> Box<dyn Partitioner> + Send + Sync>;

/// Name → factory map. `BTreeMap` keeps `names()` deterministic.
pub struct PartitionerRegistry {
    factories: BTreeMap<String, PartitionerFactory>,
}

impl PartitionerRegistry {
    /// A registry with no strategies (for fully custom setups).
    pub fn empty() -> PartitionerRegistry {
        PartitionerRegistry { factories: BTreeMap::new() }
    }

    /// A registry with every built-in strategy: `milp`, `heuristic`, and the
    /// classic whole-task mappers (`olb`, `met`, `mct`, `min-min`,
    /// `max-min`, `sufferage`).
    pub fn with_builtins() -> PartitionerRegistry {
        let mut r = PartitionerRegistry::empty();
        r.register("milp", |cfg| Box::new(MilpPartitioner::new(cfg.milp.clone())));
        r.register("heuristic", |_| Box::new(HeuristicPartitioner::default()));
        for c in Classic::all() {
            r.register(c.name(), move |_| Box::new(ClassicPartitioner(c)));
        }
        r
    }

    /// Register (or replace) a strategy under `name`.
    pub fn register<F>(&mut self, name: &str, factory: F)
    where
        F: Fn(&ExperimentConfig) -> Box<dyn Partitioner> + Send + Sync + 'static,
    {
        self.factories.insert(name.to_string(), Box::new(factory));
    }

    pub fn contains(&self, name: &str) -> bool {
        self.factories.contains_key(name)
    }

    /// Registered names, sorted.
    pub fn names(&self) -> Vec<&str> {
        self.factories.keys().map(String::as_str).collect()
    }

    /// Fail with the canonical unknown-strategy error unless `name` is
    /// registered (shared by [`create`](Self::create) and the session
    /// builder so the wording never diverges).
    pub fn ensure(&self, name: &str) -> Result<()> {
        if self.contains(name) {
            Ok(())
        } else {
            Err(CloudshapesError::config(format!(
                "unknown partitioner '{name}' (registered: {})",
                self.names().join(", ")
            )))
        }
    }

    /// Instantiate the strategy registered under `name`.
    pub fn create(&self, name: &str, cfg: &ExperimentConfig) -> Result<Box<dyn Partitioner>> {
        self.ensure(name)?;
        Ok(self.factories[name](cfg))
    }
}

impl Default for PartitionerRegistry {
    fn default() -> Self {
        PartitionerRegistry::with_builtins()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtins_are_registered() {
        let r = PartitionerRegistry::with_builtins();
        for name in ["milp", "heuristic", "olb", "met", "mct", "min-min", "max-min", "sufferage"]
        {
            assert!(r.contains(name), "{name} missing");
        }
        assert_eq!(r.names().len(), 8);
    }

    #[test]
    fn create_resolves_and_errors() {
        let r = PartitionerRegistry::with_builtins();
        let cfg = ExperimentConfig::quick();
        let p = r.create("heuristic", &cfg).unwrap();
        assert_eq!(p.name(), "heuristic");
        let e = r.create("simulated-annealing", &cfg).unwrap_err();
        assert_eq!(e.kind(), "config");
        assert!(e.message().contains("simulated-annealing"));
        assert!(e.message().contains("milp"), "lists available: {e}");
    }

    #[test]
    fn custom_strategies_plug_in() {
        let mut r = PartitionerRegistry::empty();
        r.register("cheapest", |_| {
            struct Cheapest;
            impl Partitioner for Cheapest {
                fn name(&self) -> &str {
                    "cheapest"
                }
                fn partition(
                    &self,
                    models: &crate::coordinator::ModelSet,
                    _budget: Option<f64>,
                ) -> Result<crate::coordinator::Allocation> {
                    Ok(crate::coordinator::partitioner::lower_cost_bound(models).1)
                }
            }
            Box::new(Cheapest)
        });
        let cfg = ExperimentConfig::quick();
        assert_eq!(r.create("cheapest", &cfg).unwrap().name(), "cheapest");
        assert_eq!(r.names(), vec!["cheapest"]);
    }
}
