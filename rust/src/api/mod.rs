//! The public API facade: everything a consumer of the trade-off engine
//! needs, in one place.
//!
//! - [`CloudshapesError`] / [`Result`] — the crate-wide typed error every
//!   fallible API returns;
//! - [`SessionBuilder`] → [`TradeoffSession`] — the builder-style front door
//!   that owns benchmarking, model fitting, partitioning, execution, and
//!   (when enabled) the online job scheduler
//!   ([`submit_job`](TradeoffSession::submit_job) /
//!   [`job_status`](TradeoffSession::job_status) /
//!   [`cancel_job`](TradeoffSession::cancel_job));
//! - [`PartitionerRegistry`] — pluggable name → strategy factories;
//! - [`protocol`] — the versioned (`{"v":1,...}`) serve wire protocol.
//!
//! The CLI (`cloudshapes <cmd>`), the TCP coordinator (`cloudshapes serve`)
//! and every example route through this module; see `rust/README.md` for a
//! quickstart and `docs/` for the architecture, protocol and config
//! references.

pub mod error;
pub mod protocol;
pub mod registry;
pub mod session;

pub use error::{CloudshapesError, Result};
pub use protocol::PROTOCOL_VERSION;
pub use registry::{PartitionerFactory, PartitionerRegistry};
pub use session::{
    CacheStats, Evaluation, PartitionSummary, RunState, RunStatus, SessionBuilder,
    ShapeSummary, TradeoffSession,
};
