//! Report generators: every table and figure of the paper's evaluation,
//! regenerated from this implementation (DESIGN.md §4 experiment index).

pub mod context;
pub mod figures;
pub mod tables;

pub use context::Experiment;
pub use figures::{fig1, fig2, fig3, fig3_csv, Fig2Point, Fig3Point};
pub use tables::{table1, table2, table3, table4, table4_rows, Table4Row};
