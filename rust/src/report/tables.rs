//! Regenerate the paper's Tables I–IV.

use crate::api::error::Result;
use crate::coordinator::partitioner::{lower_cost_bound, Partitioner};
use crate::coordinator::{HeuristicPartitioner, MilpPartitioner, ModelSet};
use crate::models::tco::{self, DatacentreModel};
use crate::platforms::spec::{table1_offerings, Category};
use crate::platforms::Cluster;
use crate::util::table::{fnum, Align, Table};
use crate::workload::Workload;

use super::context::Experiment;

/// Table I: IaaS offering comparison (static published data).
pub fn table1() -> Table {
    let mut t = Table::new(&[
        "Provider",
        "Instance Type",
        "Instance Name",
        "Quantum (min)",
        "Peak GFLOPS",
        "Rate ($/hr)",
    ])
    .aligns(&[Align::Left, Align::Left, Align::Left, Align::Right, Align::Right, Align::Right]);
    for o in table1_offerings() {
        t.row(&[
            o.provider.to_string(),
            o.instance_type.to_string(),
            o.instance_name.to_string(),
            o.quantum_minutes.to_string(),
            fnum(o.peak_gflops, 0),
            fnum(o.rate_per_hour, 3),
        ]);
    }
    t
}

/// Table II: the experimental cluster — spec data plus the *measured*
/// application performance achieved on this run's benchmark executions.
pub fn table2(cluster: &Cluster, workload: &Workload, models: &ModelSet) -> Table {
    let mut t = Table::new(&[
        "Platform",
        "Provider",
        "Device",
        "Standard (Tool)",
        "Clock (GHz)",
        "Spec GFLOPS",
        "Measured GFLOPS",
        "Rate ($/hr)",
        "Quantum (s)",
    ])
    .aligns(&[
        Align::Left,
        Align::Left,
        Align::Left,
        Align::Left,
        Align::Right,
        Align::Right,
        Align::Right,
        Align::Right,
        Align::Right,
    ]);
    for (i, spec) in cluster.specs().iter().enumerate() {
        // Achieved GFLOPS from the fitted β on the largest task: the paper
        // measures application performance the same way (benchmark, not
        // datasheet).
        let j = (0..workload.len())
            .max_by(|&a, &b| {
                workload.tasks[a].total_flops().total_cmp(&workload.tasks[b].total_flops())
            })
            .unwrap();
        let beta = models.model(i, j).beta;
        let measured = workload.tasks[j].flops_per_path() / beta / 1e9;
        t.row(&[
            spec.name.clone(),
            spec.provider.unwrap_or("-").to_string(),
            spec.device.to_string(),
            spec.standard.to_string(),
            fnum(spec.clock_ghz, 2),
            fnum(spec.app_gflops, 3),
            fnum(measured, 3),
            fnum(spec.rate_per_hour, 3),
            fnum(spec.quantum_secs, 0),
        ]);
    }
    t
}

/// Table III: the TCO cost model applied to CPUs, GPUs and FPGAs.
pub fn table3() -> Table {
    let dc = DatacentreModel::default();
    let rows: [(&str, tco::TcoInputs, Option<f64>); 3] = [
        ("FPGA", tco::table3::FPGA, None),
        ("GPU", tco::table3::GPU, Some(tco::table3::OBSERVED_GPU)),
        ("CPU", tco::table3::CPU, Some(tco::table3::OBSERVED_CPU)),
    ];
    let mut t = Table::new(&[
        "Parameter",
        "FPGA Model",
        "GPU Model",
        "CPU Model",
    ])
    .aligns(&[Align::Left, Align::Right, Align::Right, Align::Right]);
    let g = |f: &dyn Fn(&tco::TcoInputs) -> String| -> Vec<String> {
        rows.iter().map(|(_, i, _)| f(i)).collect()
    };
    let add = |t: &mut Table, name: &str, vals: Vec<String>| {
        t.row(&[name.to_string(), vals[0].clone(), vals[1].clone(), vals[2].clone()]);
    };
    add(&mut t, "Device Capital Cost", g(&|i| format!("${:.0}", i.capital_cost)));
    add(&mut t, "Energy Use", g(&|i| format!("{:.0}W", i.energy_watts)));
    add(&mut t, "Capital Recovery Period", g(&|i| format!("{:.0} years", i.recovery_years)));
    add(&mut t, "Charged Usage", g(&|i| format!("{:.0}%", i.charged_usage * 100.0)));
    add(&mut t, "Profit Margin", g(&|i| format!("{:.0}%", i.profit_margin * 100.0)));
    add(
        &mut t,
        "Calculated Device Rate",
        g(&|i| format!("${:.2}/hour", i.device_base_rate(&dc))),
    );
    let observed: Vec<String> = rows
        .iter()
        .map(|(_, _, o)| o.map(|r| format!("${r:.2}/hour")).unwrap_or_else(|| "-".into()))
        .collect();
    add(&mut t, "Observed Device Rate", observed);
    t
}

/// One row-pair of Table IV.
#[derive(Debug, Clone)]
pub struct Table4Row {
    pub level: &'static str,
    pub heuristic_cost: f64,
    pub heuristic_latency: f64,
    pub milp_cost: f64,
    pub milp_latency: f64,
    pub milp_gap: f64,
}

/// Table IV: the latency-cost trade-off, heuristic vs MILP, at the three
/// cost levels the paper reports (C_L, median C_k, C_U).
pub fn table4_rows(
    models: &ModelSet,
    milp_cfg: &crate::coordinator::partitioner::MilpConfig,
) -> Result<Vec<Table4Row>> {
    let heuristic = HeuristicPartitioner::default();
    let milp = MilpPartitioner::new(milp_cfg.clone());

    // Bounds (§III.C): C_U from each approach's own unconstrained solution,
    // C_L shared (cheapest single platform).
    let h_fast = heuristic.partition(models, None)?;
    let (h_fast_lat, h_cu) = models.evaluate(&h_fast);
    let m_fast = milp.solve(models, None)?;
    let (c_l, cheap_alloc) = lower_cost_bound(models);
    let (cheap_lat, _) = models.evaluate(&cheap_alloc);

    // Median budget: midpoint of the shared [C_L, max(C_U)] range.
    let c_med = (c_l + h_cu.max(m_fast.cost)) / 2.0;
    let h_med = heuristic.partition(models, Some(c_med))?;
    let (h_med_lat, h_med_cost) = models.evaluate(&h_med);
    let m_med = milp.solve(models, Some(c_med))?;

    Ok(vec![
        Table4Row {
            level: "Cheapest (C_L)",
            heuristic_cost: c_l,
            heuristic_latency: cheap_lat,
            milp_cost: c_l,
            milp_latency: cheap_lat,
            milp_gap: 0.0,
        },
        Table4Row {
            level: "Median (C_k)",
            heuristic_cost: h_med_cost,
            heuristic_latency: h_med_lat,
            milp_cost: m_med.cost,
            milp_latency: m_med.makespan,
            milp_gap: m_med.gap,
        },
        Table4Row {
            level: "Fastest (C_U)",
            heuristic_cost: h_cu,
            heuristic_latency: h_fast_lat,
            milp_cost: m_fast.cost,
            milp_latency: m_fast.makespan,
            milp_gap: m_fast.gap,
        },
    ])
}

/// Render Table IV in the paper's layout (plus the honesty column: the
/// MILP's proven optimality gap).
pub fn table4(
    models: &ModelSet,
    milp_cfg: &crate::coordinator::partitioner::MilpConfig,
) -> Result<Table> {
    let rows = table4_rows(models, milp_cfg)?;
    let mut t = Table::new(&[
        "Cost Level",
        "Metric",
        "Heuristic",
        "ILP",
        "Heuristic/ILP",
        "ILP gap",
    ])
    .aligns(&[Align::Left, Align::Left, Align::Right, Align::Right, Align::Right, Align::Right]);
    for r in rows {
        t.row(&[
            r.level.to_string(),
            "Cost ($)".to_string(),
            fnum(r.heuristic_cost, 3),
            fnum(r.milp_cost, 3),
            fnum(r.heuristic_cost / r.milp_cost.max(1e-12), 2),
            String::new(),
        ]);
        t.row(&[
            String::new(),
            "Latency (s)".to_string(),
            fnum(r.heuristic_latency, 3),
            fnum(r.milp_latency, 3),
            fnum(r.heuristic_latency / r.milp_latency.max(1e-12), 2),
            format!("{:.1}%", r.milp_gap * 100.0),
        ]);
    }
    Ok(t)
}

/// Convenience: Table II straight from an [`Experiment`].
pub fn table2_for(e: &Experiment) -> Table {
    table2(&e.cluster, &e.workload, e.models())
}

/// Category summary used by several reports.
pub fn category_counts(cluster: &Cluster) -> Vec<(Category, usize)> {
    let specs = cluster.specs();
    [Category::Fpga, Category::Gpu, Category::Cpu]
        .into_iter()
        .map(|c| (c, specs.iter().filter(|s| s.category == c).count()))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentConfig;
    use crate::coordinator::partitioner::MilpConfig;

    #[test]
    fn table1_renders_all_offerings() {
        let t = table1();
        assert_eq!(t.n_rows(), 4);
        let s = t.render();
        assert!(s.contains("g2.2xlarge"));
        assert!(s.contains("0.650"));
    }

    #[test]
    fn table3_matches_paper_rates() {
        let s = table3().render();
        assert!(s.contains("$0.46/hour"), "{s}");
        assert!(s.contains("$0.64/hour"), "{s}");
        assert!(s.contains("$0.50/hour"), "{s}");
        assert!(s.contains("$0.65/hour")); // observed GPU
    }

    #[test]
    fn table4_shows_milp_dominance() {
        let e = Experiment::build(ExperimentConfig::quick()).unwrap();
        let cfg = MilpConfig { time_limit_secs: 5.0, ..Default::default() };
        let rows = table4_rows(e.models(), &cfg).unwrap();
        assert_eq!(rows.len(), 3);
        // C_L row: identical by construction.
        assert!((rows[0].heuristic_latency - rows[0].milp_latency).abs() < 1e-9);
        // ILP never worse anywhere.
        for r in &rows {
            assert!(
                r.milp_latency <= r.heuristic_latency * 1.001,
                "{}: milp {} vs heuristic {}",
                r.level,
                r.milp_latency,
                r.heuristic_latency
            );
        }
    }

    #[test]
    fn table2_includes_measured_column() {
        let e = Experiment::build(ExperimentConfig::quick()).unwrap();
        let t = table2_for(&e);
        assert_eq!(t.n_rows(), 3);
        let s = t.render();
        assert!(s.contains("Measured GFLOPS"));
    }
}
