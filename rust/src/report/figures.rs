//! Regenerate the paper's Figures 1–3.

use crate::api::error::Result;
use crate::coordinator::executor::{execute, ExecutorConfig};
use crate::coordinator::partitioner::Partitioner;
use crate::coordinator::{sweep, HeuristicPartitioner, MilpPartitioner, TradeoffCurve};
use crate::models::LatencyModel;
use crate::util::plot::{Plot, Series};

use super::context::Experiment;

/// Figure 1: the latency-vs-cost trade-off for the full workload on the
/// heterogeneous cluster (MILP curve, as the paper's headline figure).
pub fn fig1(e: &Experiment) -> Result<(Plot, TradeoffCurve)> {
    let milp = MilpPartitioner::new(e.config.milp.clone());
    let curve = sweep(&milp, e.models(), &e.config.sweep)?;
    let mut plot = Plot::new(
        "Fig. 1: Latency vs Cost trade-off (MILP, model predictions)",
        "cost ($)",
        "makespan (s)",
    );
    let mut s = Series::new("milp", 'o');
    for p in curve.pareto_front() {
        s.push(p.cost, p.latency);
    }
    plot.add(s);
    Ok((plot, curve))
}

/// Figure 2 data point: relative latency-prediction error at a scale
/// multiple of the largest benchmarked N.
#[derive(Debug, Clone)]
pub struct Fig2Point {
    pub platform: usize,
    pub task: usize,
    /// Predicted-at N / largest-benchmarked N.
    pub scale: f64,
    pub rel_error: f64,
}

/// Figure 2: latency model prediction error characterisation — benchmark on
/// small N, predict at growing multiples, compare against fresh executions.
pub fn fig2(e: &Experiment, multiples: &[f64]) -> (Plot, Vec<Fig2Point>) {
    let models = e.models();
    let mut points = Vec::new();
    for s in &e.bench.samples {
        let Some(&(n_max, _)) = s.samples.iter().max_by_key(|(n, _)| *n) else {
            continue;
        };
        let model: &LatencyModel = models.model(s.platform, s.task);
        let task = &e.workload.tasks[s.task];
        for &mult in multiples {
            let n = (n_max as f64 * mult) as u64;
            if n == 0 || n > task.n_sims * 4 {
                continue;
            }
            // Average a few fresh observations as "reality".
            let mut lat = 0.0;
            const REPS: usize = 3;
            for r in 0..REPS {
                lat += e
                    .cluster
                    .platform(s.platform)
                    .benchmark_execute(task, n, 0xF16_2 + r as u32)
                    .latency_secs;
            }
            lat /= REPS as f64;
            points.push(Fig2Point {
                platform: s.platform,
                task: s.task,
                scale: mult,
                rel_error: model.relative_error(n, lat),
            });
        }
    }
    let mut plot = Plot::new(
        "Fig. 2: Latency model prediction error vs problem scale",
        "N / largest benchmarked N",
        "relative error",
    );
    let mut series = Series::new("pairs", '.');
    for p in &points {
        series.push(p.scale, p.rel_error);
    }
    plot.add(series);
    (plot, points)
}

/// One Fig. 3 record: a partition's model prediction vs measured execution.
#[derive(Debug, Clone)]
pub struct Fig3Point {
    pub partitioner: String,
    pub budget: Option<f64>,
    pub model_latency: f64,
    pub model_cost: f64,
    pub measured_latency: f64,
    pub measured_cost: f64,
}

/// Figure 3: generate both partitioners' trade-off curves from model data,
/// run every partition on the cluster, and report model vs measured.
pub fn fig3(e: &Experiment) -> Result<(Plot, Vec<Fig3Point>)> {
    let mut records = Vec::new();
    let heuristic = HeuristicPartitioner::default();
    let milp = MilpPartitioner::new(e.config.milp.clone());
    let partitioners: [(&str, &dyn Partitioner); 2] = [("heuristic", &heuristic), ("milp", &milp)];
    let mut plot = Plot::new(
        "Fig. 3: Partitioner model predictions vs measured",
        "cost ($)",
        "makespan (s)",
    );
    for (idx, (name, part)) in partitioners.iter().enumerate() {
        let curve = sweep(*part, e.models(), &e.config.sweep)?;
        let mut model_series = Series::new(&format!("{name}-model"), ['o', 'x'][idx]);
        let mut measured_series = Series::new(&format!("{name}-measured"), ['*', '+'][idx]);
        for p in curve.pareto_front() {
            let exec = execute(
                &e.cluster,
                &e.workload,
                &p.alloc,
                &ExecutorConfig { seed: 0xF1_6_3, ..e.config.executor.clone() },
            )?;
            model_series.push(p.cost, p.latency);
            measured_series.push(exec.cost, exec.makespan_secs);
            records.push(Fig3Point {
                partitioner: name.to_string(),
                budget: p.budget,
                model_latency: p.latency,
                model_cost: p.cost,
                measured_latency: exec.makespan_secs,
                measured_cost: exec.cost,
            });
        }
        plot.add(model_series);
        plot.add(measured_series);
    }
    Ok((plot, records))
}

/// CSV emission for the Fig. 3 records.
pub fn fig3_csv(points: &[Fig3Point]) -> String {
    let mut out = String::from(
        "partitioner,budget,model_latency_s,model_cost,measured_latency_s,measured_cost\n",
    );
    for p in points {
        out.push_str(&format!(
            "{},{},{},{},{},{}\n",
            p.partitioner,
            p.budget.map(|b| format!("{b:.4}")).unwrap_or_else(|| "unconstrained".into()),
            p.model_latency,
            p.model_cost,
            p.measured_latency,
            p.measured_cost
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentConfig;
    use crate::report::context::Experiment;

    fn quick() -> Experiment {
        let mut cfg = ExperimentConfig::quick();
        cfg.milp.time_limit_secs = 3.0;
        cfg.sweep.levels = 4;
        Experiment::build(cfg).unwrap()
    }

    #[test]
    fn fig1_produces_monotone_front() {
        let e = quick();
        let (plot, curve) = fig1(&e).unwrap();
        assert!(!curve.points.is_empty());
        let front = curve.pareto_front();
        for w in front.windows(2) {
            assert!(w[0].cost <= w[1].cost && w[0].latency >= w[1].latency);
        }
        assert!(plot.render().contains("Fig. 1"));
    }

    #[test]
    fn fig2_errors_are_mostly_small() {
        let e = quick();
        let (_, points) = fig2(&e, &[2.0, 5.0, 10.0]);
        assert!(!points.is_empty());
        let median = {
            let mut errs: Vec<f64> = points.iter().map(|p| p.rel_error).collect();
            errs.sort_by(|a, b| a.total_cmp(b));
            errs[errs.len() / 2]
        };
        assert!(median < 0.10, "median error {median}");
    }

    #[test]
    fn fig3_model_tracks_measured() {
        let e = quick();
        let (_, points) = fig3(&e).unwrap();
        assert!(points.len() >= 4);
        for p in &points {
            let lat_err = (p.measured_latency - p.model_latency).abs() / p.model_latency;
            assert!(lat_err < 0.5, "{p:?}");
        }
        let csv = fig3_csv(&points);
        assert!(csv.lines().count() == points.len() + 1);
    }
}
