//! Experiment context: builds the cluster + workload + fitted models that
//! every table/figure generator consumes, from one [`ExperimentConfig`].

use std::path::Path;
use std::sync::Arc;

use crate::api::error::{CloudshapesError, Result};
use crate::config::{ClusterKind, ExperimentConfig};
use crate::coordinator::{benchmark, BenchmarkReport, ModelSet};
use crate::platforms::native::NativePlatform;
use crate::platforms::spec::{paper_cluster, small_cluster};
use crate::platforms::Cluster;
use crate::runtime::EngineHandle;
use crate::workload::{generate, Workload};

/// A fully-materialised experiment: cluster, workload, benchmark-fitted
/// models (plus raw samples) and the nominal spec-derived models.
pub struct Experiment {
    pub config: ExperimentConfig,
    pub cluster: Cluster,
    pub workload: Workload,
    /// Models fitted by the §III.A benchmarking procedure.
    pub bench: BenchmarkReport,
    /// Nominal models straight from the specs (ablation reference).
    pub nominal: ModelSet,
}

impl Experiment {
    /// Build everything. Benchmarking runs here (simulated platforms make
    /// it cheap; the native platform, if enabled, costs real seconds).
    pub fn build(config: ExperimentConfig) -> Result<Experiment> {
        let specs = match config.cluster.kind {
            ClusterKind::Paper => paper_cluster(),
            ClusterKind::Small => small_cluster(),
        };
        let mut cluster = Cluster::simulated(&specs, &config.cluster.sim, config.cluster.seed);
        if config.cluster.with_native {
            let engine = EngineHandle::spawn(Path::new(&config.artifact_dir))
                .map_err(|e| CloudshapesError::platform(format!("starting PJRT engine: {e:#}")))?;
            cluster.push(Arc::new(NativePlatform::new(engine)));
        }
        let workload = generate(&config.workload);
        workload.validate()?;
        let bench = benchmark(&cluster, &workload, &config.benchmark);
        let specs_all = cluster.specs();
        let nominal = ModelSet::from_specs(&specs_all, &workload);
        Ok(Experiment { config, cluster, workload, bench, nominal })
    }

    /// The fitted models (what the partitioners should consume).
    pub fn models(&self) -> &ModelSet {
        &self.bench.models
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_experiment_builds() {
        let e = Experiment::build(ExperimentConfig::quick()).unwrap();
        assert_eq!(e.cluster.len(), 3);
        assert_eq!(e.workload.len(), 8);
        assert_eq!(e.models().mu, 3);
        assert_eq!(e.models().tau, 8);
        assert_eq!(e.nominal.mu, 3);
    }
}
