//! Experiment context: builds the cluster + workload + fitted models that
//! every table/figure generator consumes, from one [`ExperimentConfig`].

use std::path::Path;
use std::sync::Arc;

use crate::api::error::{CloudshapesError, Result};
use crate::config::{ClusterKind, ExperimentConfig};
use crate::coordinator::{benchmark, BenchmarkReport, ModelSet};
use crate::platforms::catalogue::Catalogue;
use crate::platforms::native::NativePlatform;
use crate::platforms::Cluster;
use crate::runtime::EngineHandle;
use crate::workload::{try_generate, Workload};

/// A fully-materialised experiment: cluster, workload, benchmark-fitted
/// models (plus raw samples) and the nominal spec-derived models.
pub struct Experiment {
    pub config: ExperimentConfig,
    pub cluster: Cluster,
    pub workload: Workload,
    /// Models fitted by the §III.A benchmarking procedure.
    pub bench: BenchmarkReport,
    /// Nominal models straight from the specs (ablation reference).
    pub nominal: ModelSet,
    /// The catalogue the cluster was instantiated from.
    pub catalogue: Catalogue,
    /// Instances rented per catalogue offer.
    pub counts: Vec<usize>,
    /// Cluster index → catalogue offer index (`None` for appended
    /// out-of-catalogue platforms such as the native one).
    pub instance_offer: Vec<Option<usize>>,
}

impl Experiment {
    /// Build everything. Benchmarking runs here (simulated platforms make
    /// it cheap; the native platform, if enabled, costs real seconds).
    pub fn build(config: ExperimentConfig) -> Result<Experiment> {
        let catalogue = match config.cluster.kind {
            ClusterKind::Paper => Catalogue::paper(),
            ClusterKind::Small => Catalogue::small(),
        };
        let counts = config
            .cluster
            .counts
            .clone()
            .unwrap_or_else(|| catalogue.testbed_counts());
        let specs = catalogue.instantiate(&counts, config.cluster.spot)?;
        let mut cluster = Cluster::simulated(&specs, &config.cluster.sim, config.cluster.seed)?;
        let mut instance_offer: Vec<Option<usize>> =
            catalogue.instance_offers(&counts).into_iter().map(Some).collect();
        if config.cluster.with_native {
            let engine = EngineHandle::spawn(Path::new(&config.artifact_dir))
                .map_err(|e| CloudshapesError::platform(format!("starting PJRT engine: {e:#}")))?;
            cluster.push(Arc::new(NativePlatform::new(engine)))?;
            instance_offer.push(None);
        }
        let workload = try_generate(&config.workload)?;
        workload.validate()?;
        let bench = benchmark(&cluster, &workload, &config.benchmark);
        let specs_all = cluster.specs();
        let nominal = ModelSet::from_specs(&specs_all, &workload);
        Ok(Experiment {
            config,
            cluster,
            workload,
            bench,
            nominal,
            catalogue,
            counts,
            instance_offer,
        })
    }

    /// The fitted models (what the partitioners should consume).
    pub fn models(&self) -> &ModelSet {
        &self.bench.models
    }

    /// Per-*type* models derived from the benchmark fits: each catalogue
    /// offer's β/γ rows are the mean over its instances in the cluster;
    /// offers with no rented instance fall back to nominal spec-derived
    /// models. Billing terms match how this session rents: spot rates when
    /// the session is a spot one (so shape predictions agree with
    /// `evaluate` billing), on-demand rates otherwise. This is the input
    /// the shape optimiser searches over.
    pub fn type_models(&self) -> ModelSet {
        use crate::models::LatencyModel;
        let fitted = self.models();
        let tau = self.workload.len();
        let mut latency = Vec::with_capacity(self.catalogue.len() * tau);
        for (t, offer) in self.catalogue.offers().iter().enumerate() {
            let members: Vec<usize> = self
                .instance_offer
                .iter()
                .enumerate()
                .filter(|(_, o)| **o == Some(t))
                .map(|(i, _)| i)
                .collect();
            for j in 0..tau {
                if members.is_empty() {
                    // Nominal fallback for un-rented types.
                    let beta = self.workload.tasks[j].flops_per_path()
                        / (offer.spec.app_gflops.max(1e-9) * 1e9);
                    latency.push(LatencyModel::new(beta, offer.spec.setup_secs));
                } else {
                    let n = members.len() as f64;
                    let beta =
                        members.iter().map(|&i| fitted.model(i, j).beta).sum::<f64>() / n;
                    let gamma =
                        members.iter().map(|&i| fitted.model(i, j).gamma).sum::<f64>() / n;
                    latency.push(LatencyModel::new(beta.max(1e-15), gamma.max(0.0)));
                }
            }
        }
        ModelSet::new(
            latency,
            self.catalogue
                .offers()
                .iter()
                .map(|o| {
                    let mut cm = o.spec.cost_model();
                    if self.config.cluster.spot {
                        if let Some(s) = o.spot {
                            cm.rate_per_hour = s.rate_per_hour;
                        }
                    }
                    cm
                })
                .collect(),
            self.workload.tasks.iter().map(|t| t.n_sims).collect(),
            self.catalogue.offers().iter().map(|o| o.spec.name.clone()).collect(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_experiment_builds() {
        let e = Experiment::build(ExperimentConfig::quick()).unwrap();
        assert_eq!(e.cluster.len(), 3);
        assert_eq!(e.workload.len(), 8);
        assert_eq!(e.models().mu, 3);
        assert_eq!(e.models().tau, 8);
        assert_eq!(e.nominal.mu, 3);
        assert_eq!(e.counts, vec![1, 1, 1]);
        assert_eq!(e.instance_offer, vec![Some(0), Some(1), Some(2)]);
    }

    #[test]
    fn catalogue_counts_override_composition() {
        let mut cfg = ExperimentConfig::quick();
        cfg.cluster.counts = Some(vec![2, 1, 0]);
        let e = Experiment::build(cfg).unwrap();
        assert_eq!(e.cluster.len(), 3);
        assert_eq!(e.instance_offer, vec![Some(0), Some(0), Some(1)]);
        let names: Vec<String> =
            e.cluster.specs().iter().map(|s| s.name.clone()).collect();
        assert_eq!(names, vec!["virtex6#0", "virtex6#1", "gk104"]);
        // Wrong arity is a config error.
        let mut cfg = ExperimentConfig::quick();
        cfg.cluster.counts = Some(vec![1, 1]);
        assert!(Experiment::build(cfg).is_err());
    }

    #[test]
    fn type_models_cover_every_offer() {
        let mut cfg = ExperimentConfig::quick();
        cfg.cluster.counts = Some(vec![2, 1, 0]);
        let e = Experiment::build(cfg).unwrap();
        let types = e.type_models();
        assert_eq!(types.mu, 3);
        assert_eq!(types.tau, 8);
        // Rented types average their instances' fits; the un-rented CPU
        // falls back to nominal (positive, finite coefficients either way).
        for t in 0..types.mu {
            for j in 0..types.tau {
                let m = types.model(t, j);
                assert!(m.beta > 0.0 && m.beta.is_finite());
                assert!(m.gamma >= 0.0 && m.gamma.is_finite());
            }
        }
        assert_eq!(types.platform_names, vec!["virtex6", "gk104", "xeon-e5-2660"]);
    }

    #[test]
    fn spot_sessions_price_types_at_spot_rates() {
        let mut cfg = ExperimentConfig::quick();
        cfg.cluster.spot = true;
        let e = Experiment::build(cfg).unwrap();
        let types = e.type_models();
        // gk104 (offer 1) has spot terms; virtex6 (offer 0) does not.
        assert!(types.cost[1].rate_per_hour < e.catalogue.offer(1).spec.rate_per_hour);
        assert_eq!(types.cost[0].rate_per_hour, e.catalogue.offer(0).spec.rate_per_hour);
    }
}
