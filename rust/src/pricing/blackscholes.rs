//! Closed-form Black-Scholes pricing — the end-to-end numerical oracle.
//!
//! Used to validate that the whole stack (Pallas kernel → AOT HLO → PJRT
//! execution → coordinator aggregation) produces correct option prices.

/// Error function via the Abramowitz & Stegun 7.1.26 rational approximation
/// (|ε| < 1.5e-7 — far below Monte Carlo noise).
pub fn erf(x: f64) -> f64 {
    let sign = if x < 0.0 { -1.0 } else { 1.0 };
    let x = x.abs();
    let t = 1.0 / (1.0 + 0.3275911 * x);
    let y = 1.0
        - (((((1.061405429 * t - 1.453152027) * t) + 1.421413741) * t - 0.284496736) * t
            + 0.254829592)
            * t
            * (-x * x).exp();
    sign * y
}

/// Standard normal CDF.
pub fn norm_cdf(x: f64) -> f64 {
    0.5 * (1.0 + erf(x / std::f64::consts::SQRT_2))
}

/// Standard normal density.
pub fn norm_pdf(x: f64) -> f64 {
    (-0.5 * x * x).exp() / (2.0 * std::f64::consts::PI).sqrt()
}

/// Black-Scholes European call price (discounted).
pub fn call(s0: f64, k: f64, r: f64, sigma: f64, t: f64) -> f64 {
    assert!(s0 > 0.0 && k > 0.0 && sigma > 0.0 && t > 0.0);
    let d1 = ((s0 / k).ln() + (r + 0.5 * sigma * sigma) * t) / (sigma * t.sqrt());
    let d2 = d1 - sigma * t.sqrt();
    s0 * norm_cdf(d1) - k * (-r * t).exp() * norm_cdf(d2)
}

/// Black-Scholes European put price (via put-call parity).
pub fn put(s0: f64, k: f64, r: f64, sigma: f64, t: f64) -> f64 {
    call(s0, k, r, sigma, t) - s0 + k * (-r * t).exp()
}

/// Black-Scholes European call delta, `N(d1)` — the closed-form oracle the
/// pathwise Monte Carlo delta is tested against.
pub fn call_delta(s0: f64, k: f64, r: f64, sigma: f64, t: f64) -> f64 {
    let d1 = ((s0 / k).ln() + (r + 0.5 * sigma * sigma) * t) / (sigma * t.sqrt());
    norm_cdf(d1)
}

/// Black-Scholes European call vega, `S·φ(d1)·√T`.
pub fn call_vega(s0: f64, k: f64, r: f64, sigma: f64, t: f64) -> f64 {
    let d1 = ((s0 / k).ln() + (r + 0.5 * sigma * sigma) * t) / (sigma * t.sqrt());
    s0 * norm_pdf(d1) * t.sqrt()
}

/// Black formula on a lognormal forward: `df·(F·N(d1) − K·N(d2))` with
/// `d1 = (ln(F/K) + s²/2)/s`, `s` the total log-volatility to expiry.
fn black(fwd: f64, k: f64, s: f64, df: f64) -> f64 {
    assert!(fwd > 0.0 && k > 0.0 && s > 0.0);
    let d1 = ((fwd / k).ln() + 0.5 * s * s) / s;
    let d2 = d1 - s;
    df * (fwd * norm_cdf(d1) - k * norm_cdf(d2))
}

/// Closed-form call on the *geometric* mean of `d` identical lognormal
/// assets (spot `s0`, vol `sigma`) under pairwise equicorrelation `rho` —
/// a strict lower bound for the arithmetic-basket call the MC kernel
/// prices (AM–GM), exact in the `rho → 1` limit.
pub fn geometric_basket_call(
    s0: f64,
    k: f64,
    r: f64,
    sigma: f64,
    t: f64,
    d: u32,
    rho: f64,
) -> f64 {
    assert!(d >= 1);
    let df = d as f64;
    // Var[(1/d)·Σ ln Sᵢ] = σ²t·(1 + (d−1)ρ)/d.
    let var_g = sigma * sigma * t * (1.0 + (df - 1.0) * rho) / df;
    assert!(var_g > 0.0, "degenerate basket variance");
    // ln G has the single-asset drift (r − σ²/2)t; the forward of G picks
    // up the +var_g/2 Itô correction of *its own* (smaller) variance.
    let fwd = s0 * ((r - 0.5 * sigma * sigma) * t + 0.5 * var_g).exp();
    black(fwd, k, var_g.sqrt(), (-r * t).exp())
}

/// Moment-matched (Lévy) lognormal approximation of the *arithmetic*
/// equally-weighted basket call: matches the basket's first two moments,
/// accurate to a few tenths of a percent at moderate vols — the
/// independent oracle `pricing::basket` is tested against.
pub fn basket_call_moment_matched(
    s0: f64,
    k: f64,
    r: f64,
    sigma: f64,
    t: f64,
    d: u32,
    rho: f64,
) -> f64 {
    assert!(d >= 1);
    let df = d as f64;
    let m1 = s0 * (r * t).exp();
    let v = sigma * sigma * t;
    // E[B²] = (s0² e^{2rt}/d²)·(d·e^{σ²t} + d(d−1)·e^{ρσ²t}).
    let m2 = (s0 * s0 * (2.0 * r * t).exp() / (df * df))
        * (df * v.exp() + df * (df - 1.0) * (rho * v).exp());
    let s_eff = (m2 / (m1 * m1)).ln().max(1e-30).sqrt();
    black(m1, k, s_eff, (-r * t).exp())
}

/// American put via a Cox-Ross-Rubinstein binomial tree with `n` time
/// steps — the dependency-free early-exercise oracle the LSMC kernel is
/// tested against. O(n²) time, O(n) space; converges O(1/n).
pub fn american_put_binomial(s0: f64, k: f64, r: f64, sigma: f64, t: f64, n: u32) -> f64 {
    assert!(s0 > 0.0 && k > 0.0 && sigma > 0.0 && t > 0.0 && n > 0);
    let nf = n as usize;
    let dt = t / n as f64;
    let u = (sigma * dt.sqrt()).exp();
    let d = 1.0 / u;
    let disc = (-r * dt).exp();
    let p = ((r * dt).exp() - d) / (u - d);
    assert!((0.0..=1.0).contains(&p), "CRR risk-neutral prob {p} outside [0,1]");
    // Terminal layer: node j holds S = s0·u^j·d^(n-j).
    let mut values: Vec<f64> = (0..=nf)
        .map(|j| {
            let s = s0 * u.powi(j as i32) * d.powi((nf - j) as i32);
            (k - s).max(0.0)
        })
        .collect();
    for layer in (0..nf).rev() {
        for j in 0..=layer {
            let s = s0 * u.powi(j as i32) * d.powi((layer - j) as i32);
            let cont = disc * (p * values[j + 1] + (1.0 - p) * values[j]);
            values[j] = cont.max(k - s);
        }
    }
    values[0]
}

/// Kemna-Vorst geometric-average Asian call with `m` discrete fixings —
/// a lower bound for the arithmetic Asian call the MC kernels price.
pub fn geometric_asian_call(s0: f64, k: f64, r: f64, sigma: f64, t: f64, m: u32) -> f64 {
    assert!(m > 0);
    let mf = m as f64;
    let dt = t / mf;
    let mu = (r - 0.5 * sigma * sigma) * dt * (mf + 1.0) / 2.0;
    let var = sigma * sigma * dt * (mf + 1.0) * (2.0 * mf + 1.0) / (6.0 * mf);
    let sig_g = var.sqrt();
    let d1 = ((s0 / k).ln() + mu + var) / sig_g;
    let d2 = d1 - sig_g;
    let fwd = s0 * (mu + 0.5 * var).exp();
    (-r * t).exp() * (fwd * norm_cdf(d1) - k * norm_cdf(d2))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn erf_known_values() {
        assert!((erf(0.0)).abs() < 1e-7); // A&S 7.1.26 is ~1.5e-7 accurate
        assert!((erf(1.0) - 0.8427007929).abs() < 1e-6);
        assert!((erf(-1.0) + 0.8427007929).abs() < 1e-6);
        assert!((erf(3.0) - 0.9999779095).abs() < 1e-6);
    }

    #[test]
    fn norm_cdf_symmetry() {
        for x in [-2.5, -1.0, 0.0, 0.7, 3.1] {
            assert!((norm_cdf(x) + norm_cdf(-x) - 1.0).abs() < 1e-7);
        }
        assert!((norm_cdf(0.0) - 0.5).abs() < 1e-7);
        assert!((norm_cdf(1.96) - 0.975).abs() < 1e-4);
    }

    #[test]
    fn call_reference_value() {
        // Hull's textbook example: S=42, K=40, r=10%, sigma=20%, T=0.5 -> 4.76.
        let c = call(42.0, 40.0, 0.10, 0.20, 0.5);
        assert!((c - 4.76).abs() < 0.01, "{c}");
    }

    #[test]
    fn put_call_parity_holds() {
        let (s0, k, r, sigma, t) = (100.0, 105.0, 0.05, 0.2, 1.0);
        let lhs = call(s0, k, r, sigma, t) - put(s0, k, r, sigma, t);
        let rhs = s0 - k * (-r * t as f64).exp();
        assert!((lhs - rhs).abs() < 1e-9);
    }

    #[test]
    fn call_monotone_in_spot_and_vol() {
        let base = call(100.0, 100.0, 0.03, 0.2, 1.0);
        assert!(call(110.0, 100.0, 0.03, 0.2, 1.0) > base);
        assert!(call(100.0, 100.0, 0.03, 0.3, 1.0) > base);
    }

    #[test]
    fn call_bounds() {
        // max(S - K e^{-rT}, 0) <= C <= S.
        let (s0, k, r, sigma, t) = (100.0, 90.0, 0.05, 0.25, 2.0);
        let c = call(s0, k, r, sigma, t);
        let intrinsic = s0 - k * (-r * t as f64).exp();
        assert!(c >= intrinsic && c <= s0);
    }

    #[test]
    fn geometric_asian_below_european() {
        let e = call(100.0, 100.0, 0.05, 0.25, 1.0);
        let g = geometric_asian_call(100.0, 100.0, 0.05, 0.25, 1.0, 64);
        assert!(g < e);
        assert!(g > 0.0);
    }

    #[test]
    fn delta_and_vega_match_finite_differences() {
        let (s0, k, r, sigma, t) = (100.0, 105.0, 0.05, 0.2, 1.0);
        let h = 1e-4;
        let fd_delta = (call(s0 + h, k, r, sigma, t) - call(s0 - h, k, r, sigma, t)) / (2.0 * h);
        assert!((call_delta(s0, k, r, sigma, t) - fd_delta).abs() < 1e-6);
        let fd_vega = (call(s0, k, r, sigma + h, t) - call(s0, k, r, sigma - h, t)) / (2.0 * h);
        assert!((call_vega(s0, k, r, sigma, t) - fd_vega).abs() < 1e-4);
    }

    #[test]
    fn geometric_basket_degenerates_to_single_asset() {
        // d = 1, and d > 1 at rho = 1, are both just one lognormal asset.
        let e = call(100.0, 95.0, 0.05, 0.3, 1.0);
        let g1 = geometric_basket_call(100.0, 95.0, 0.05, 0.3, 1.0, 1, 0.0);
        assert!((e - g1).abs() < 1e-9, "{e} vs {g1}");
        let g4 = geometric_basket_call(100.0, 95.0, 0.05, 0.3, 1.0, 4, 0.999999);
        assert!((e - g4).abs() < 1e-3, "{e} vs {g4}");
    }

    #[test]
    fn basket_oracles_are_ordered() {
        // Geometric <= arithmetic (AM-GM), and lower correlation shrinks
        // basket variance hence the OTM call price.
        let (s0, k, r, sigma, t) = (100.0, 105.0, 0.05, 0.25, 1.0);
        let geo = geometric_basket_call(s0, k, r, sigma, t, 4, 0.5);
        let arith = basket_call_moment_matched(s0, k, r, sigma, t, 4, 0.5);
        assert!(geo < arith, "{geo} vs {arith}");
        let lo = basket_call_moment_matched(s0, k, r, sigma, t, 4, 0.1);
        assert!(lo < arith, "{lo} vs {arith}");
        // Both collapse to the European call in the rho -> 1 limit.
        let e = call(s0, k, r, sigma, t);
        assert!((basket_call_moment_matched(s0, k, r, sigma, t, 4, 0.999999) - e).abs() < 1e-3);
    }

    #[test]
    fn binomial_put_converges_to_european_without_early_exercise() {
        // r = 0 kills the early-exercise premium of an American put, so the
        // CRR tree must converge to the European closed form. (The pricer
        // accepts r = 0 even though workload validation wants r > 0.)
        let (s0, k, sigma, t) = (100.0, 105.0, 0.2, 1.0);
        let amer = american_put_binomial(s0, k, 1e-12, sigma, t, 2000);
        let eur = put(s0, k, 1e-12, sigma, t);
        assert!((amer - eur).abs() < 0.02, "{amer} vs {eur}");
    }

    #[test]
    fn binomial_put_carries_early_exercise_premium() {
        let (s0, k, r, sigma, t) = (100.0, 110.0, 0.05, 0.2, 1.0);
        let amer = american_put_binomial(s0, k, r, sigma, t, 1000);
        let eur = put(s0, k, r, sigma, t);
        assert!(amer > eur + 0.05, "premium missing: {amer} vs {eur}");
        // And it is bounded by intrinsic + European (crude upper bound).
        assert!(amer < eur + (k - s0).max(0.0) + 5.0);
        // Refinement is stable to the third decimal by n=1000.
        let finer = american_put_binomial(s0, k, r, sigma, t, 2000);
        assert!((amer - finer).abs() < 5e-3, "{amer} vs {finer}");
    }

    #[test]
    fn geometric_asian_approaches_terminal_with_one_fixing() {
        // m = 1: the "average" is just the terminal value.
        let e = call(100.0, 95.0, 0.05, 0.3, 1.0);
        let g = geometric_asian_call(100.0, 95.0, 0.05, 0.3, 1.0, 1);
        assert!((e - g).abs() < 1e-9, "{e} vs {g}");
    }
}
