//! Closed-form Black-Scholes pricing — the end-to-end numerical oracle.
//!
//! Used to validate that the whole stack (Pallas kernel → AOT HLO → PJRT
//! execution → coordinator aggregation) produces correct option prices.

/// Error function via the Abramowitz & Stegun 7.1.26 rational approximation
/// (|ε| < 1.5e-7 — far below Monte Carlo noise).
pub fn erf(x: f64) -> f64 {
    let sign = if x < 0.0 { -1.0 } else { 1.0 };
    let x = x.abs();
    let t = 1.0 / (1.0 + 0.3275911 * x);
    let y = 1.0
        - (((((1.061405429 * t - 1.453152027) * t) + 1.421413741) * t - 0.284496736) * t
            + 0.254829592)
            * t
            * (-x * x).exp();
    sign * y
}

/// Standard normal CDF.
pub fn norm_cdf(x: f64) -> f64 {
    0.5 * (1.0 + erf(x / std::f64::consts::SQRT_2))
}

/// Black-Scholes European call price (discounted).
pub fn call(s0: f64, k: f64, r: f64, sigma: f64, t: f64) -> f64 {
    assert!(s0 > 0.0 && k > 0.0 && sigma > 0.0 && t > 0.0);
    let d1 = ((s0 / k).ln() + (r + 0.5 * sigma * sigma) * t) / (sigma * t.sqrt());
    let d2 = d1 - sigma * t.sqrt();
    s0 * norm_cdf(d1) - k * (-r * t).exp() * norm_cdf(d2)
}

/// Black-Scholes European put price (via put-call parity).
pub fn put(s0: f64, k: f64, r: f64, sigma: f64, t: f64) -> f64 {
    call(s0, k, r, sigma, t) - s0 + k * (-r * t).exp()
}

/// Kemna-Vorst geometric-average Asian call with `m` discrete fixings —
/// a lower bound for the arithmetic Asian call the MC kernels price.
pub fn geometric_asian_call(s0: f64, k: f64, r: f64, sigma: f64, t: f64, m: u32) -> f64 {
    assert!(m > 0);
    let mf = m as f64;
    let dt = t / mf;
    let mu = (r - 0.5 * sigma * sigma) * dt * (mf + 1.0) / 2.0;
    let var = sigma * sigma * dt * (mf + 1.0) * (2.0 * mf + 1.0) / (6.0 * mf);
    let sig_g = var.sqrt();
    let d1 = ((s0 / k).ln() + mu + var) / sig_g;
    let d2 = d1 - sig_g;
    let fwd = s0 * (mu + 0.5 * var).exp();
    (-r * t).exp() * (fwd * norm_cdf(d1) - k * norm_cdf(d2))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn erf_known_values() {
        assert!((erf(0.0)).abs() < 1e-7); // A&S 7.1.26 is ~1.5e-7 accurate
        assert!((erf(1.0) - 0.8427007929).abs() < 1e-6);
        assert!((erf(-1.0) + 0.8427007929).abs() < 1e-6);
        assert!((erf(3.0) - 0.9999779095).abs() < 1e-6);
    }

    #[test]
    fn norm_cdf_symmetry() {
        for x in [-2.5, -1.0, 0.0, 0.7, 3.1] {
            assert!((norm_cdf(x) + norm_cdf(-x) - 1.0).abs() < 1e-7);
        }
        assert!((norm_cdf(0.0) - 0.5).abs() < 1e-7);
        assert!((norm_cdf(1.96) - 0.975).abs() < 1e-4);
    }

    #[test]
    fn call_reference_value() {
        // Hull's textbook example: S=42, K=40, r=10%, sigma=20%, T=0.5 -> 4.76.
        let c = call(42.0, 40.0, 0.10, 0.20, 0.5);
        assert!((c - 4.76).abs() < 0.01, "{c}");
    }

    #[test]
    fn put_call_parity_holds() {
        let (s0, k, r, sigma, t) = (100.0, 105.0, 0.05, 0.2, 1.0);
        let lhs = call(s0, k, r, sigma, t) - put(s0, k, r, sigma, t);
        let rhs = s0 - k * (-r * t as f64).exp();
        assert!((lhs - rhs).abs() < 1e-9);
    }

    #[test]
    fn call_monotone_in_spot_and_vol() {
        let base = call(100.0, 100.0, 0.03, 0.2, 1.0);
        assert!(call(110.0, 100.0, 0.03, 0.2, 1.0) > base);
        assert!(call(100.0, 100.0, 0.03, 0.3, 1.0) > base);
    }

    #[test]
    fn call_bounds() {
        // max(S - K e^{-rT}, 0) <= C <= S.
        let (s0, k, r, sigma, t) = (100.0, 90.0, 0.05, 0.25, 2.0);
        let c = call(s0, k, r, sigma, t);
        let intrinsic = s0 - k * (-r * t as f64).exp();
        assert!(c >= intrinsic && c <= s0);
    }

    #[test]
    fn geometric_asian_below_european() {
        let e = call(100.0, 100.0, 0.05, 0.25, 1.0);
        let g = geometric_asian_call(100.0, 100.0, 0.05, 0.25, 1.0, 64);
        assert!(g < e);
        assert!(g > 0.0);
    }

    #[test]
    fn geometric_asian_approaches_terminal_with_one_fixing() {
        // m = 1: the "average" is just the terminal value.
        let e = call(100.0, 95.0, 0.05, 0.3, 1.0);
        let g = geometric_asian_call(100.0, 95.0, 0.05, 0.3, 1.0, 1);
        assert!((e - g).abs() < 1e-9, "{e} vs {g}");
    }
}
