//! Option pricing: closed-form oracles and the native Monte Carlo mirror of
//! the L1 kernels — scalar ([`mc`], the differential oracle) and batched
//! ([`batch`], the vectorisation-ready hot path; bit-identical results).
//!
//! Exotic payoff families have dedicated kernels — [`lsmc`] (American,
//! Longstaff-Schwartz regression MC), [`basket`] (correlated multi-asset,
//! Cholesky-factored paths) and [`heston`] (stochastic volatility,
//! full-truncation Euler) — all sharing the counter-based Threefry
//! discipline, so every price stays seed-deterministic and chunk-additive.

pub mod basket;
pub mod batch;
pub mod blackscholes;
pub mod heston;
pub mod lsmc;
pub mod mc;

pub use batch::{simulate_batch, KernelConfig, LANES, SUPPORTED_LANES};
pub use mc::{combine, combine_greeks, simulate, GreekEstimate, PayoffStats, PriceEstimate};
