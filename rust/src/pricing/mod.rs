//! Option pricing: closed-form oracles and the native Monte Carlo mirror of
//! the L1 kernels — scalar ([`mc`], the differential oracle) and batched
//! ([`batch`], the vectorisation-ready hot path; bit-identical results).

pub mod batch;
pub mod blackscholes;
pub mod mc;

pub use batch::{simulate_batch, KernelConfig, LANES, SUPPORTED_LANES};
pub use mc::{combine, simulate, PayoffStats, PriceEstimate};
