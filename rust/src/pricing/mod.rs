//! Option pricing: closed-form oracles and the native Monte Carlo mirror of
//! the L1 kernels.

pub mod blackscholes;
pub mod mc;

pub use mc::{combine, simulate, PayoffStats, PriceEstimate};
