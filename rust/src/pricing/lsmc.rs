//! American put pricing via Longstaff-Schwartz regression Monte Carlo.
//!
//! The classic LSMC algorithm regresses continuation values on in-the-money
//! path states and exercises where intrinsic beats the fit. Done naively it
//! breaks the executor's chunking contract: the regression couples every
//! path in a chunk, so two half-chunks would price a *different* option
//! than one whole chunk.
//!
//! This kernel restores chunk-additivity by splitting policy from pricing:
//!
//! 1. **Pilot regression** — a fixed block of [`PILOT_PATHS`] paths drawn
//!    under a *salted* key (`seed ^ PILOT_SALT`, counters from 0) fits the
//!    per-date continuation polynomials. The policy is therefore a pure
//!    function of `(task, seed)`: every chunk of the same task recomputes
//!    bit-identical coefficients, wherever its counter range starts.
//! 2. **Out-of-sample pricing** — the requested `[offset, offset+n)` paths
//!    walk forward under the ordinary key and *apply* the frozen policy.
//!    Using paths disjoint from the regression set also removes the classic
//!    in-sample look-ahead bias (Longstaff & Schwartz 2001 §1).
//!
//! Exercised payoffs are stored forward-compounded to maturity
//! (`intrinsic·e^{r(T−τ)}`), so the caller's uniform `e^{−rT}` discount in
//! [`combine`](super::mc::combine) nets to the correct `e^{−rτ}`.
//!
//! Greeks use likelihood-ratio estimators (the exercise boundary makes the
//! payoff non-differentiable pathwise): delta score `z₁/(S₀σ√dt)`, vega
//! score `Σ_{j≤τ}[(z_j²−1)/σ − z_j√dt]` accumulated up to the exercise date.

use crate::util::rng::threefry_normal;
use crate::workload::option::{OptionTask, Payoff};

use super::mc::{PayoffStats, STEP_BITS};

/// Pilot paths behind the regression. Fixed (not a config knob): the policy
/// must be a pure function of `(task, seed)` for chunk-additivity.
pub const PILOT_PATHS: u32 = 4096;

/// Key salt separating the pilot stream from the pricing stream — the
/// out-of-sample split that removes LSMC's in-sample bias.
const PILOT_SALT: u32 = 0xA5A5_5A5A;

/// Quadratic regression basis in moneyness `x = S/K`: `[1, x, x²]`.
const BASIS: usize = 3;

/// Per-exercise-date continuation-value fit; `None` where too few ITM pilot
/// paths existed to regress (continuation then wins by default — never
/// exercising on no evidence is the conservative choice).
type Policy = Vec<Option<[f64; BASIS]>>;

#[inline]
fn basis_eval(c: &[f64; BASIS], x: f64) -> f64 {
    c[0] + c[1] * x + c[2] * x * x
}

/// Solve the 3×3 normal equations `A·c = b` by Gaussian elimination with
/// partial pivoting; `None` on (near-)singular systems.
fn solve3(mut a: [[f64; BASIS]; BASIS], mut b: [f64; BASIS]) -> Option<[f64; BASIS]> {
    for col in 0..BASIS {
        let pivot = (col..BASIS).max_by(|&i, &j| {
            a[i][col].abs().partial_cmp(&a[j][col].abs()).unwrap()
        })?;
        if a[pivot][col].abs() < 1e-12 {
            return None;
        }
        a.swap(col, pivot);
        b.swap(col, pivot);
        for row in col + 1..BASIS {
            let f = a[row][col] / a[col][col];
            for c in col..BASIS {
                a[row][c] -= f * a[col][c];
            }
            b[row] -= f * b[col];
        }
    }
    let mut x = [0.0; BASIS];
    for row in (0..BASIS).rev() {
        let mut acc = b[row];
        for c in row + 1..BASIS {
            acc -= a[row][c] * x[c];
        }
        x[row] = acc / a[row][row];
    }
    if x.iter().all(|v| v.is_finite()) {
        Some(x)
    } else {
        None
    }
}

/// Fit the exercise policy from the salted pilot stream — deterministic in
/// `(task, seed)`, independent of the pricing chunk's counter range.
fn fit_policy(task: &OptionTask, seed: u32) -> Policy {
    let k0 = task.id as u32;
    let k1 = seed ^ PILOT_SALT;
    let steps = task.steps as usize;
    let (s0, k, r, sigma, t) = (
        task.spot as f32,
        task.strike as f32,
        task.rate as f32,
        task.sigma as f32,
        task.maturity as f32,
    );
    let dt = t / task.steps as f32;
    let drift = (r - 0.5 * sigma * sigma) * dt;
    let vol = sigma * dt.sqrt();
    // Pilot path matrix: spot at every exercise date (dates 1..=steps map
    // to rows 0..steps).
    let np = PILOT_PATHS as usize;
    let mut spots = vec![0.0f32; np * steps];
    for p in 0..PILOT_PATHS {
        let mut log_s = s0.ln();
        for step in 0..task.steps {
            let z = threefry_normal(k0, k1, p, step);
            log_s += drift + vol * z;
            spots[p as usize * steps + step as usize] = log_s.exp();
        }
    }
    let kf = task.strike;
    let disc = (-(task.rate) * (task.maturity / task.steps as f64)).exp();
    // Backward induction in f64: `value[p]` holds the option value at the
    // current date under the policy fitted so far.
    let mut value: Vec<f64> = (0..np)
        .map(|p| (kf - spots[p * steps + steps - 1] as f64).max(0.0))
        .collect();
    let mut policy: Policy = vec![None; steps + 1];
    for date in (1..steps).rev() {
        // Discount one date back: value of continuing, seen from `date`.
        for v in value.iter_mut() {
            *v *= disc;
        }
        // Regress continuation on the ITM pilot states.
        let mut a = [[0.0f64; BASIS]; BASIS];
        let mut b = [0.0f64; BASIS];
        let mut itm = 0usize;
        for p in 0..np {
            let s = spots[p * steps + (date - 1)] as f64;
            if s >= kf {
                continue;
            }
            itm += 1;
            let x = s / kf;
            let phi = [1.0, x, x * x];
            for i in 0..BASIS {
                for j in 0..BASIS {
                    a[i][j] += phi[i] * phi[j];
                }
                b[i] += phi[i] * value[p];
            }
        }
        let coeffs = if itm >= 2 * BASIS { solve3(a, b) } else { None };
        if let Some(c) = coeffs {
            // Apply the exercise decision to the pilot values so earlier
            // dates regress against the improved policy.
            for p in 0..np {
                let s = spots[p * steps + (date - 1)] as f64;
                if s < kf {
                    let intrinsic = kf - s;
                    if intrinsic > basis_eval(&c, s / kf) {
                        value[p] = intrinsic;
                    }
                }
            }
        }
        policy[date] = coeffs;
    }
    policy
}

/// Simulate `n` pricing paths of the American put at counter `offset` —
/// same counter bijection as [`mc::simulate`](super::mc::simulate), so
/// chunked execution composes to identical statistics.
pub fn simulate(task: &OptionTask, seed: u32, offset: u64, n: u32) -> PayoffStats {
    assert_eq!(task.payoff, Payoff::American, "lsmc kernel requires an American task");
    assert!(
        task.steps < (1 << STEP_BITS),
        "task {}: {} steps exceed the counter layout's 2^{STEP_BITS} budget",
        task.id,
        task.steps
    );
    let policy = fit_policy(task, seed);
    let k0 = task.id as u32;
    let k1 = seed;
    let ctr = |p: u32| -> (u32, u32) {
        let g = offset.wrapping_add(p as u64);
        (g as u32, ((g >> 32) as u32) << STEP_BITS)
    };
    let steps = task.steps;
    let (s0, k, r, sigma, t) = (
        task.spot as f32,
        task.strike as f32,
        task.rate as f32,
        task.sigma as f32,
        task.maturity as f32,
    );
    let dt = t / steps as f32;
    let drift = (r - 0.5 * sigma * sigma) * dt;
    let vol = sigma * dt.sqrt();
    let sqrt_dt = dt.sqrt();
    let lr_denom = s0 * sigma * sqrt_dt;
    let kf = task.strike;
    // Forward-compounding factor per remaining date (f64 — payoff algebra
    // below the accumulators is f64 like the other kernels' casts).
    let dtf = task.maturity / steps as f64;
    let mut sum = 0.0f64;
    let mut sum_sq = 0.0f64;
    let mut delta_sum = 0.0f64;
    let mut vega_sum = 0.0f64;
    for p in 0..n {
        let (c0, hi) = ctr(p);
        let mut log_s = s0.ln();
        let mut z1 = 0.0f32;
        let mut score_v = 0.0f32;
        let mut payoff = 0.0f64;
        for step in 0..steps {
            let z = threefry_normal(k0, k1, c0, hi | step);
            if step == 0 {
                z1 = z;
            }
            score_v += (z * z - 1.0) / sigma - z * sqrt_dt;
            log_s += drift + vol * z;
            let date = step as usize + 1;
            let s = log_s.exp() as f64;
            if date == steps as usize {
                payoff = (kf - s).max(0.0);
                break;
            }
            if s < kf {
                if let Some(c) = &policy[date] {
                    let intrinsic = kf - s;
                    if intrinsic > basis_eval(c, s / kf) {
                        // Forward-compound to maturity so the caller's
                        // e^{−rT} discount nets to e^{−rτ}.
                        payoff = intrinsic * (task.rate * dtf * (steps as usize - date) as f64).exp();
                        break;
                    }
                }
            }
        }
        sum += payoff;
        sum_sq += payoff * payoff;
        delta_sum += payoff * (z1 / lr_denom) as f64;
        vega_sum += payoff * score_v as f64;
    }
    PayoffStats { sum, sum_sq, delta_sum, vega_sum, n: n as u64 }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pricing::blackscholes;
    use crate::pricing::mc::combine;

    fn american() -> OptionTask {
        OptionTask {
            id: 3,
            payoff: Payoff::American,
            spot: 100.0,
            strike: 110.0,
            rate: 0.05,
            sigma: 0.2,
            maturity: 1.0,
            steps: 32,
            ..OptionTask::default()
        }
    }

    #[test]
    fn chunking_is_exactly_additive() {
        let t = american();
        let whole = simulate(&t, 5, 0, 4096);
        let lo = simulate(&t, 5, 0, 1536);
        let hi = simulate(&t, 5, 1536, 2560);
        let merged = lo.merge(&hi);
        assert!((whole.sum - merged.sum).abs() < 1e-9 * whole.sum.abs().max(1.0));
        assert!((whole.sum_sq - merged.sum_sq).abs() < 1e-9 * whole.sum_sq.abs().max(1.0));
        assert_eq!(whole.n, merged.n);
    }

    #[test]
    fn policy_is_independent_of_chunk_offset() {
        // The same path priced from two different chunk layouts must see
        // the same exercise policy: a path at global counter g contributes
        // identically wherever the chunk boundary falls.
        let t = american();
        let a = simulate(&t, 7, 1000, 64);
        let b0 = simulate(&t, 7, 1000, 32);
        let b1 = simulate(&t, 7, 1032, 32);
        assert_eq!(a, b0.merge(&b1));
    }

    #[test]
    fn price_brackets_european_and_binomial() {
        let t = american();
        let est = combine(&simulate(&t, 42, 0, 1 << 16), t.discount());
        let eur = blackscholes::put(t.spot, t.strike, t.rate, t.sigma, t.maturity);
        let crr = blackscholes::american_put_binomial(
            t.spot, t.strike, t.rate, t.sigma, t.maturity, 2000,
        );
        // Early-exercise premium strictly positive…
        assert!(
            est.price > eur + 2.0 * est.std_error,
            "no premium: mc {} ± {} vs eur {eur}",
            est.price,
            est.std_error
        );
        // …and the suboptimal-policy estimate cannot beat the true price.
        assert!(
            est.price <= crr + 3.0 * est.std_error,
            "above binomial: mc {} ± {} vs crr {crr}",
            est.price,
            est.std_error
        );
    }

    #[test]
    fn seeds_decorrelate_but_agree() {
        let t = american();
        let a = combine(&simulate(&t, 1, 0, 1 << 14), t.discount());
        let b = combine(&simulate(&t, 2, 0, 1 << 14), t.discount());
        assert_ne!(a.price, b.price);
        assert!((a.price - b.price).abs() < 4.0 * (a.std_error + b.std_error));
    }
}
