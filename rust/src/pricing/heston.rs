//! European call under Heston stochastic volatility, priced by a
//! full-truncation Euler scheme (Lord, Koekkoek & van Dijk 2010 — the
//! discretisation with the smallest bias among the simple Euler fixes):
//!
//! ```text
//! v⁺    = max(v, 0)
//! ln S += (r − v⁺/2)·dt + √(v⁺·dt)·z_s
//! v    += κ(θ − v⁺)·dt + ξ·√(v⁺·dt)·z_v ,   z_v = ρ·z_s + √(1−ρ²)·z₂
//! ```
//!
//! Each step draws two Threefry normals (counter sub-indices `2·step` and
//! `2·step+1` — twice the counter-word budget of the single-factor
//! families, validated per task). At `ξ = 0, v₀ = θ` the variance
//! recursion is exactly constant, so the scheme degenerates to
//! constant-vol GBM on the `z_s` stream — the independent oracle
//! `rust/tests/pricing_exotics.rs` replays to 1e-12 and checks against the
//! Black-Scholes closed form.
//!
//! Greeks are pathwise: delta `1{Sᴛ>K}·Sᴛ/S₀` (v is independent of S₀);
//! vega is taken with respect to the *initial vol* `σ₀ = √v₀` via the
//! chain-rule accumulators `D = ∂v/∂v₀` and `G = ∂lnS/∂v₀`:
//! `vega = 1{Sᴛ>K}·Sᴛ·G·2√v₀`.

use crate::util::rng::threefry_normal;
use crate::workload::option::{OptionTask, Payoff};

use super::mc::{PayoffStats, STEP_BITS};

/// Simulate `n` Heston paths at counter `offset` — same counter bijection
/// as [`mc::simulate`](super::mc::simulate) with sub-draws `2·step` /
/// `2·step+1`, so chunked execution composes to identical statistics.
pub fn simulate(task: &OptionTask, seed: u32, offset: u64, n: u32) -> PayoffStats {
    assert_eq!(task.payoff, Payoff::Heston, "heston kernel requires a Heston task");
    let words = 2 * task.steps as u64;
    assert!(
        words < (1 << STEP_BITS),
        "task {}: {words} counter words per path exceed the 2^{STEP_BITS} budget",
        task.id
    );
    let k0 = task.id as u32;
    let k1 = seed;
    let ctr = |p: u32| -> (u32, u32) {
        let g = offset.wrapping_add(p as u64);
        (g as u32, ((g >> 32) as u32) << STEP_BITS)
    };
    let steps = task.steps;
    let (s0, k, r, t) = (
        task.spot as f32,
        task.strike as f32,
        task.rate as f32,
        task.maturity as f32,
    );
    let (kappa, theta, xi, v0, rho) = (
        task.kappa as f32,
        task.theta as f32,
        task.xi as f32,
        task.v0 as f32,
        task.correlation as f32,
    );
    let dt = t / steps as f32;
    let rho_perp = (1.0 - rho * rho).sqrt();
    let mut sum = 0.0f64;
    let mut sum_sq = 0.0f64;
    let mut delta_sum = 0.0f64;
    let mut vega_sum = 0.0f64;
    for p in 0..n {
        let (c0, hi) = ctr(p);
        let mut log_s = s0.ln();
        let mut v = v0;
        // Chain-rule state for vega: D = ∂v/∂v₀, G = ∂lnS/∂v₀.
        let mut dv = 1.0f32;
        let mut g = 0.0f32;
        for step in 0..steps {
            let z_s = threefry_normal(k0, k1, c0, hi | (2 * step));
            let z2 = threefry_normal(k0, k1, c0, hi | (2 * step + 1));
            let z_v = rho * z_s + rho_perp * z2;
            let vp = v.max(0.0);
            let sq = (vp * dt).sqrt();
            // ∂√(v⁺dt)/∂v₀ (0 at the truncation boundary — subgradient).
            let ind = if v > 0.0 { 1.0f32 } else { 0.0 };
            let dsq = if sq > 0.0 { ind * dv * dt / (2.0 * sq) } else { 0.0 };
            log_s += (r - 0.5 * vp) * dt + sq * z_s;
            g += -0.5 * ind * dv * dt + z_s * dsq;
            v += kappa * (theta - vp) * dt + xi * sq * z_v;
            dv += -kappa * ind * dv * dt + xi * z_v * dsq;
        }
        let st = log_s.exp();
        let payoff = (st - k).max(0.0) as f64;
        sum += payoff;
        sum_sq += payoff * payoff;
        if st > k {
            delta_sum += (st / s0) as f64;
            vega_sum += (st * g * 2.0 * v0.sqrt()) as f64;
        }
    }
    PayoffStats { sum, sum_sq, delta_sum, vega_sum, n: n as u64 }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pricing::blackscholes;
    use crate::pricing::mc::combine;

    fn heston() -> OptionTask {
        OptionTask {
            id: 9,
            payoff: Payoff::Heston,
            spot: 100.0,
            strike: 105.0,
            rate: 0.05,
            sigma: 0.2,
            maturity: 1.0,
            steps: 64,
            kappa: 1.5,
            theta: 0.04,
            xi: 0.5,
            v0: 0.04,
            correlation: -0.7,
            ..OptionTask::default()
        }
    }

    #[test]
    fn chunking_is_exactly_additive() {
        let t = heston();
        let whole = simulate(&t, 1, 0, 4096);
        let lo = simulate(&t, 1, 0, 2000);
        let hi = simulate(&t, 1, 2000, 2096);
        let merged = lo.merge(&hi);
        assert!((whole.sum - merged.sum).abs() < 1e-9 * whole.sum.abs().max(1.0));
        assert!((whole.sum_sq - merged.sum_sq).abs() < 1e-9 * whole.sum_sq.abs().max(1.0));
        assert_eq!(whole.n, merged.n);
    }

    #[test]
    fn zero_vol_of_vol_matches_black_scholes() {
        // ξ = 0, v₀ = θ: variance is exactly constant, log-Euler GBM is
        // exact in distribution — the MC estimate must agree with the
        // closed form at √θ vol within pure sampling noise.
        let mut t = heston();
        t.xi = 0.0;
        t.v0 = t.theta;
        let est = combine(&simulate(&t, 42, 0, 1 << 15), t.discount());
        let bs = blackscholes::call(t.spot, t.strike, t.rate, t.theta.sqrt(), t.maturity);
        assert!(
            (est.price - bs).abs() < 4.0 * est.std_error + 0.02,
            "mc {} ± {} vs bs {bs}",
            est.price,
            est.std_error
        );
    }

    #[test]
    fn negative_correlation_skews_the_smile() {
        // With equity-like ρ < 0 the left tail fattens: relative to the
        // flat-vol price, OTM calls cheapen (finite-sample: just require a
        // sane, finite price that moves with ξ).
        let t = heston();
        let with_vol_of_vol = combine(&simulate(&t, 7, 0, 1 << 15), t.discount()).price;
        let mut flat = t.clone();
        flat.xi = 0.0;
        flat.v0 = flat.theta;
        let flat_price = combine(&simulate(&flat, 7, 0, 1 << 15), flat.discount()).price;
        assert!(with_vol_of_vol.is_finite() && with_vol_of_vol > 0.0);
        assert_ne!(with_vol_of_vol, flat_price);
    }

    #[test]
    fn variance_process_stays_sane_at_high_vol_of_vol() {
        let mut t = heston();
        t.xi = 1.5;
        t.steps = 128;
        let est = combine(&simulate(&t, 3, 0, 1 << 14), t.discount());
        assert!(est.price.is_finite() && est.price >= 0.0 && est.price < t.spot);
    }
}
