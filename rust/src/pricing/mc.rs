//! Native rust Monte Carlo pricer.
//!
//! Bit-for-bit mirror of the L1 Pallas kernels (`python/compile/kernels/
//! mc.py`): same Threefry-2x32 counter layout (path `p`, step `s` under key
//! `(task_id, seed)`), same Box-Muller transform, same payoff recursions in
//! f32. It serves as (a) the CPU fall-back when artifacts are not built,
//! (b) a cross-check oracle on the PJRT path, and (c) the workhorse of the
//! pure-simulation benchmarks where numerical payoffs don't matter but
//! realistic statistics do.
//!
//! The exotic families live in their own modules and are dispatched from
//! [`simulate`]: [`lsmc`](super::lsmc) (American), [`basket`](super::basket)
//! (correlated multi-asset) and [`heston`](super::heston) (stochastic vol).
//!
//! Besides price statistics every kernel accumulates first-order **Greeks**
//! (delta, vega): pathwise estimators where the payoff is a.s. differentiable
//! in the parameter (European, Asian, Basket, Heston), likelihood-ratio
//! estimators where it is not (Barrier's knock-out indicator, American's
//! exercise boundary). The Greek accumulators are additive exactly like the
//! price sums, so chunked execution merges Greeks for free — and they are
//! appended *after* the price accumulation of each path, keeping `sum` /
//! `sum_sq` bit-identical to the pre-Greeks kernels (asserted by
//! `rust/tests/pricing_greeks.rs`).

use crate::util::rng::threefry_normal;
use crate::workload::option::{OptionTask, Payoff};

/// Raw (undiscounted) payoff statistics of a batch of simulated paths.
///
/// `delta_sum` / `vega_sum` hold the per-path Greek estimator sums
/// (pathwise or likelihood-ratio depending on family — see module docs);
/// like `sum` they are undiscounted and combine additively across chunks.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct PayoffStats {
    pub sum: f64,
    pub sum_sq: f64,
    pub delta_sum: f64,
    pub vega_sum: f64,
    pub n: u64,
}

impl PayoffStats {
    pub fn merge(&self, other: &PayoffStats) -> PayoffStats {
        PayoffStats {
            sum: self.sum + other.sum,
            sum_sq: self.sum_sq + other.sum_sq,
            delta_sum: self.delta_sum + other.delta_sum,
            vega_sum: self.vega_sum + other.vega_sum,
            n: self.n + other.n,
        }
    }
}

/// A discounted price estimate with its standard error.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PriceEstimate {
    pub price: f64,
    pub std_error: f64,
    pub n: u64,
}

/// First-order sensitivities of the discounted price.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GreekEstimate {
    /// ∂price/∂spot.
    pub delta: f64,
    /// ∂price/∂vol (initial vol √v₀ for Heston).
    pub vega: f64,
    pub n: u64,
}

/// Combine payoff statistics into a discounted estimate — mirrors
/// `python/compile/model.py::mc_estimate` (tested for agreement there).
pub fn combine(stats: &PayoffStats, discount: f64) -> PriceEstimate {
    assert!(stats.n > 0, "no paths simulated");
    let nf = stats.n as f64;
    let mean = stats.sum / nf;
    let var = (stats.sum_sq / nf - mean * mean).max(0.0);
    PriceEstimate {
        price: discount * mean,
        std_error: discount * (var / nf).sqrt(),
        n: stats.n,
    }
}

/// Combine the Greek accumulators into discounted sensitivities — same
/// discounting as [`combine`] (the estimators are stored undiscounted).
pub fn combine_greeks(stats: &PayoffStats, discount: f64) -> GreekEstimate {
    assert!(stats.n > 0, "no paths simulated");
    let nf = stats.n as f64;
    GreekEstimate {
        delta: discount * stats.delta_sum / nf,
        vega: discount * stats.vega_sum / nf,
        n: stats.n,
    }
}

/// How far the step counter reaches into the second Threefry word: the low
/// [`STEP_BITS`] bits of `c1` carry the path step, the high bits carry the
/// overflow (bits 32+) of the 64-bit path counter. For paths below `2^32`
/// the layout is bit-identical to the original 32-bit scheme (`c1 = step`),
/// so golden values and artifact cross-checks are unaffected; beyond it the
/// counter space extends to `2^(32 + 32 - STEP_BITS)` paths without any
/// (path, step) collision as long as each path draws fewer than
/// `2^STEP_BITS` counter words (families with several draws per step —
/// basket assets, Heston's two factors — consume the budget faster; see
/// [`Payoff::counter_words_per_path`]).
pub const STEP_BITS: u32 = 20;

/// Simulate `n` paths of `task` starting at (64-bit) path counter `offset`
/// under `(task.id, seed)`. Matches the kernels' counter bijection, so
/// chunked / partitioned execution composes to identical statistics.
///
/// `offset` is 64-bit because tasks are sized up to `1 << 34` simulations;
/// a 32-bit offset would wrap and overlap slices (see [`STEP_BITS`] for how
/// the extra bits are folded into the counter pair).
pub fn simulate(task: &OptionTask, seed: u32, offset: u64, n: u32) -> PayoffStats {
    // Exotic families have their own kernels (same counter discipline, own
    // per-step draw layout).
    match task.payoff {
        Payoff::American => return super::lsmc::simulate(task, seed, offset, n),
        Payoff::Basket => return super::basket::simulate(task, seed, offset, n),
        Payoff::Heston => return super::heston::simulate(task, seed, offset, n),
        Payoff::European | Payoff::Asian | Payoff::Barrier => {}
    }
    let k0 = task.id as u32;
    let k1 = seed;
    // A hard check, not a debug_assert: in release builds a `steps` beyond
    // the layout would silently alias (path, step) counter pairs and bias
    // every merged price. Workload validation rejects such tasks with a
    // typed error long before execution (`OptionTask::validate`); this is
    // the kernel-level backstop for callers that skip it.
    assert!(
        task.steps < (1 << STEP_BITS),
        "task {}: {} steps exceed the counter layout's 2^{STEP_BITS} budget",
        task.id,
        task.steps
    );
    // Split the 64-bit path index into the first counter word plus a c1
    // high-bits overflow (zero for paths < 2^32 — bit-compatible with the
    // original u32 layout).
    let ctr = |p: u32| -> (u32, u32) {
        let g = offset.wrapping_add(p as u64);
        (g as u32, ((g >> 32) as u32) << STEP_BITS)
    };
    let (s0, k, r, sigma, t) = (
        task.spot as f32,
        task.strike as f32,
        task.rate as f32,
        task.sigma as f32,
        task.maturity as f32,
    );
    let mut sum = 0.0f64;
    let mut sum_sq = 0.0f64;
    let mut delta_sum = 0.0f64;
    let mut vega_sum = 0.0f64;
    match task.payoff {
        Payoff::European => {
            let drift = (r - 0.5 * sigma * sigma) * t;
            let vol = sigma * t.sqrt();
            let sqrt_t = t.sqrt();
            for p in 0..n {
                let (c0, hi) = ctr(p);
                let z = threefry_normal(k0, k1, c0, hi);
                let st = s0 * (drift + vol * z).exp();
                let payoff = (st - k).max(0.0) as f64;
                sum += payoff;
                sum_sq += payoff * payoff;
                // Pathwise: ∂(Sᴛ−K)⁺/∂S₀ = 1{Sᴛ>K}·Sᴛ/S₀,
                //           ∂Sᴛ/∂σ = Sᴛ·(√T·z − σT).
                if st > k {
                    delta_sum += (st / s0) as f64;
                    vega_sum += (st * (sqrt_t * z - sigma * t)) as f64;
                }
            }
        }
        Payoff::Asian => {
            let steps = task.steps;
            let dt = t / steps as f32;
            let drift = (r - 0.5 * sigma * sigma) * dt;
            let vol = sigma * dt.sqrt();
            let sqrt_dt = dt.sqrt();
            for p in 0..n {
                let (c0, hi) = ctr(p);
                let mut log_s = s0.ln();
                let mut acc = 0.0f32;
                // Pathwise vega state: running normal sum W_j and
                // Σ_j S_j·(√dt·W_j − σ·t_j) (= ∂(Σ S_j)/∂σ).
                let mut w = 0.0f32;
                let mut vacc = 0.0f32;
                for step in 0..steps {
                    let z = threefry_normal(k0, k1, c0, hi | step);
                    log_s += drift + vol * z;
                    acc += log_s.exp();
                    w += z;
                    vacc += log_s.exp() * (sqrt_dt * w - sigma * (dt * (step + 1) as f32));
                }
                let avg = acc / steps as f32;
                let payoff = (avg - k).max(0.0) as f64;
                sum += payoff;
                sum_sq += payoff * payoff;
                if avg > k {
                    delta_sum += (avg / s0) as f64;
                    vega_sum += (vacc / steps as f32) as f64;
                }
            }
        }
        Payoff::Barrier => {
            let steps = task.steps;
            let barrier = task.barrier as f32;
            let dt = t / steps as f32;
            let drift = (r - 0.5 * sigma * sigma) * dt;
            let vol = sigma * dt.sqrt();
            let sqrt_dt = dt.sqrt();
            // Likelihood-ratio scores (the knock-out indicator kills the
            // pathwise derivative): delta score z₁/(S₀σ√dt), vega score
            // Σ_j[(z_j²−1)/σ − z_j√dt].
            let lr_denom = s0 * sigma * sqrt_dt;
            for p in 0..n {
                let (c0, hi) = ctr(p);
                let mut log_s = s0.ln();
                let mut alive = s0 < barrier;
                let mut z1 = 0.0f32;
                let mut score_v = 0.0f32;
                for step in 0..steps {
                    let z = threefry_normal(k0, k1, c0, hi | step);
                    if step == 0 {
                        z1 = z;
                    }
                    score_v += (z * z - 1.0) / sigma - z * sqrt_dt;
                    log_s += drift + vol * z;
                    alive = alive && log_s.exp() < barrier;
                }
                let payoff = if alive { (log_s.exp() - k).max(0.0) as f64 } else { 0.0 };
                sum += payoff;
                sum_sq += payoff * payoff;
                delta_sum += payoff * (z1 / lr_denom) as f64;
                vega_sum += payoff * score_v as f64;
            }
        }
        Payoff::American | Payoff::Basket | Payoff::Heston => unreachable!("dispatched above"),
    }
    PayoffStats { sum, sum_sq, delta_sum, vega_sum, n: n as u64 }
}

/// Price a task natively with `n` paths (convenience wrapper).
pub fn price(task: &OptionTask, seed: u32, n: u32) -> PriceEstimate {
    combine(&simulate(task, seed, 0, n), task.discount())
}

/// Greeks of a task natively with `n` paths (convenience wrapper).
pub fn greeks(task: &OptionTask, seed: u32, n: u32) -> GreekEstimate {
    combine_greeks(&simulate(task, seed, 0, n), task.discount())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pricing::blackscholes;
    use crate::workload::{generate, GeneratorConfig};

    fn european() -> OptionTask {
        OptionTask {
            id: 7,
            payoff: Payoff::European,
            spot: 100.0,
            strike: 105.0,
            rate: 0.05,
            sigma: 0.2,
            maturity: 1.0,
            barrier: 0.0,
            steps: 1,
            target_accuracy: 0.01,
            n_sims: 1 << 18,
            ..OptionTask::default()
        }
    }

    #[test]
    fn european_matches_black_scholes() {
        let t = european();
        let est = price(&t, 42, 1 << 18);
        let bs = blackscholes::call(t.spot, t.strike, t.rate, t.sigma, t.maturity);
        assert!(
            (est.price - bs).abs() < 4.0 * est.std_error + 0.03,
            "mc {} ± {} vs bs {bs}",
            est.price,
            est.std_error
        );
    }

    #[test]
    fn chunking_is_exactly_additive() {
        let t = european();
        let whole = simulate(&t, 1, 0, 4096);
        let lo = simulate(&t, 1, 0, 2048);
        let hi = simulate(&t, 1, 2048, 2048);
        let merged = lo.merge(&hi);
        assert!((whole.sum - merged.sum).abs() < 1e-9 * whole.sum.abs().max(1.0));
        assert!((whole.sum_sq - merged.sum_sq).abs() < 1e-9 * whole.sum_sq.abs().max(1.0));
        assert!(
            (whole.delta_sum - merged.delta_sum).abs() < 1e-9 * whole.delta_sum.abs().max(1.0)
        );
        assert!((whole.vega_sum - merged.vega_sum).abs() < 1e-9 * whole.vega_sum.abs().max(1.0));
        assert_eq!(whole.n, merged.n);
    }

    #[test]
    fn chunking_is_additive_across_the_u32_boundary() {
        // The offsets that used to wrap at 32 bits: a slice straddling
        // 2^32 must merge exactly like any other contiguous pair.
        let t = european();
        let base = (1u64 << 32) - 1024;
        let whole = simulate(&t, 1, base, 4096);
        let lo = simulate(&t, 1, base, 1024);
        let hi = simulate(&t, 1, base + 1024, 3072);
        let merged = lo.merge(&hi);
        assert!((whole.sum - merged.sum).abs() < 1e-9 * whole.sum.abs().max(1.0));
        assert_eq!(whole.n, merged.n);
    }

    #[test]
    fn high_offsets_are_fresh_unbiased_streams() {
        // Slices above 2^32 must neither repeat the low-offset stream (the
        // old truncation bug) nor drift from the true price.
        let t = european();
        let lo = simulate(&t, 1, 0, 1 << 14);
        let hi = simulate(&t, 1, 1u64 << 33, 1 << 14);
        assert_ne!(lo.sum, hi.sum, "high offsets replayed the low stream");
        let pl = combine(&lo, t.discount());
        let ph = combine(&hi, t.discount());
        assert!(
            (pl.price - ph.price).abs() < 4.0 * (pl.std_error + ph.std_error),
            "{pl:?} vs {ph:?}"
        );
    }

    #[test]
    fn path_dependent_counters_survive_high_offsets() {
        // Asian payoffs use the step word; the folded high bits must not
        // collide with steps (and the estimate must stay sane).
        let mut t = european();
        t.payoff = Payoff::Asian;
        t.steps = 32;
        let a = simulate(&t, 9, 1u64 << 33, 1 << 12);
        let b = simulate(&t, 9, 0, 1 << 12);
        assert_ne!(a.sum, b.sum);
        let est = combine(&a, t.discount());
        assert!(est.price >= 0.0 && est.price < t.spot);
    }

    #[test]
    fn seeds_decorrelate() {
        let t = european();
        let a = simulate(&t, 1, 0, 8192);
        let b = simulate(&t, 2, 0, 8192);
        assert_ne!(a.sum, b.sum);
        let pa = combine(&a, t.discount()).price;
        let pb = combine(&b, t.discount()).price;
        assert!((pa - pb).abs() < 0.5, "both near the true price");
    }

    #[test]
    fn asian_bracketed_by_geometric_and_european() {
        let mut t = european();
        t.payoff = Payoff::Asian;
        t.steps = 32;
        t.strike = 100.0;
        let est = price(&t, 9, 1 << 16);
        let geo = blackscholes::geometric_asian_call(t.spot, t.strike, t.rate, t.sigma, t.maturity, 32);
        let eur = blackscholes::call(t.spot, t.strike, t.rate, t.sigma, t.maturity);
        assert!(est.price > geo - 4.0 * est.std_error - 0.05, "{est:?} vs geo {geo}");
        assert!(est.price < eur + 4.0 * est.std_error, "{est:?} vs eur {eur}");
    }

    #[test]
    fn barrier_below_european_and_monotone() {
        let mut t = european();
        t.payoff = Payoff::Barrier;
        t.steps = 32;
        t.barrier = 130.0;
        let tight = price(&t, 3, 1 << 16).price;
        t.barrier = 160.0;
        let loose = price(&t, 3, 1 << 16).price;
        let eur = blackscholes::call(t.spot, t.strike, t.rate, t.sigma, t.maturity);
        assert!(tight <= loose + 1e-9);
        assert!(loose < eur);
    }

    #[test]
    fn std_error_shrinks_like_sqrt_n() {
        let t = european();
        let small = price(&t, 5, 1 << 12).std_error;
        let big = price(&t, 5, 1 << 16).std_error;
        let ratio = small / big;
        assert!((2.8..5.7).contains(&ratio), "expected ~4, got {ratio}");
    }

    #[test]
    fn whole_generated_workload_prices_sanely() {
        let w = generate(&GeneratorConfig::small(6, 0.1, 11));
        for t in &w.tasks {
            let est = price(t, 1, 1 << 14);
            assert!(est.price >= 0.0, "negative price for {t:?}");
            assert!(est.price < t.spot, "call above spot for {t:?}");
        }
    }

    #[test]
    fn every_family_simulates_through_the_dispatcher() {
        // `simulate` must route every Payoff variant to a working kernel —
        // the exhaustiveness backstop at the pricing layer.
        for p in Payoff::ALL {
            let mut t = european();
            t.payoff = p;
            t.steps = if p == Payoff::European { 1 } else { 16 };
            t.barrier = 150.0;
            t.assets = if p == Payoff::Basket { 4 } else { 1 };
            t.correlation = match p {
                Payoff::Basket => 0.5,
                Payoff::Heston => -0.7,
                _ => 0.0,
            };
            let stats = simulate(&t, 11, 0, 2048);
            assert_eq!(stats.n, 2048, "{p:?}");
            let est = combine(&stats, t.discount());
            assert!(est.price.is_finite() && est.price >= 0.0, "{p:?}: {est:?}");
            assert!(est.price < 2.0 * t.spot, "{p:?}: {est:?}");
        }
    }

    #[test]
    fn european_greeks_match_closed_form() {
        let t = european();
        let g = greeks(&t, 42, 1 << 17);
        let delta = blackscholes::call_delta(t.spot, t.strike, t.rate, t.sigma, t.maturity);
        let vega = blackscholes::call_vega(t.spot, t.strike, t.rate, t.sigma, t.maturity);
        assert!((g.delta - delta).abs() < 0.01, "mc delta {} vs bs {delta}", g.delta);
        assert!((g.vega - vega).abs() / vega < 0.05, "mc vega {} vs bs {vega}", g.vega);
    }

    #[test]
    #[should_panic(expected = "no paths")]
    fn combine_rejects_empty() {
        combine(&PayoffStats::default(), 1.0);
    }
}
