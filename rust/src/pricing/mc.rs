//! Native rust Monte Carlo pricer.
//!
//! Bit-for-bit mirror of the L1 Pallas kernels (`python/compile/kernels/
//! mc.py`): same Threefry-2x32 counter layout (path `p`, step `s` under key
//! `(task_id, seed)`), same Box-Muller transform, same payoff recursions in
//! f32. It serves as (a) the CPU fall-back when artifacts are not built,
//! (b) a cross-check oracle on the PJRT path, and (c) the workhorse of the
//! pure-simulation benchmarks where numerical payoffs don't matter but
//! realistic statistics do.

use crate::util::rng::threefry_normal;
use crate::workload::option::{OptionTask, Payoff};

/// Raw (undiscounted) payoff statistics of a batch of simulated paths.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct PayoffStats {
    pub sum: f64,
    pub sum_sq: f64,
    pub n: u64,
}

impl PayoffStats {
    pub fn merge(&self, other: &PayoffStats) -> PayoffStats {
        PayoffStats {
            sum: self.sum + other.sum,
            sum_sq: self.sum_sq + other.sum_sq,
            n: self.n + other.n,
        }
    }
}

/// A discounted price estimate with its standard error.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PriceEstimate {
    pub price: f64,
    pub std_error: f64,
    pub n: u64,
}

/// Combine payoff statistics into a discounted estimate — mirrors
/// `python/compile/model.py::mc_estimate` (tested for agreement there).
pub fn combine(stats: &PayoffStats, discount: f64) -> PriceEstimate {
    assert!(stats.n > 0, "no paths simulated");
    let nf = stats.n as f64;
    let mean = stats.sum / nf;
    let var = (stats.sum_sq / nf - mean * mean).max(0.0);
    PriceEstimate {
        price: discount * mean,
        std_error: discount * (var / nf).sqrt(),
        n: stats.n,
    }
}

/// How far the step counter reaches into the second Threefry word: the low
/// [`STEP_BITS`] bits of `c1` carry the path step, the high bits carry the
/// overflow (bits 32+) of the 64-bit path counter. For paths below `2^32`
/// the layout is bit-identical to the original 32-bit scheme (`c1 = step`),
/// so golden values and artifact cross-checks are unaffected; beyond it the
/// counter space extends to `2^(32 + 32 - STEP_BITS)` paths without any
/// (path, step) collision as long as `steps < 2^STEP_BITS`.
pub const STEP_BITS: u32 = 20;

/// Simulate `n` paths of `task` starting at (64-bit) path counter `offset`
/// under `(task.id, seed)`. Matches the kernels' counter bijection, so
/// chunked / partitioned execution composes to identical statistics.
///
/// `offset` is 64-bit because tasks are sized up to `1 << 34` simulations;
/// a 32-bit offset would wrap and overlap slices (see [`STEP_BITS`] for how
/// the extra bits are folded into the counter pair).
pub fn simulate(task: &OptionTask, seed: u32, offset: u64, n: u32) -> PayoffStats {
    let k0 = task.id as u32;
    let k1 = seed;
    // A hard check, not a debug_assert: in release builds a `steps` beyond
    // the layout would silently alias (path, step) counter pairs and bias
    // every merged price. Workload validation rejects such tasks with a
    // typed error long before execution (`OptionTask::validate`); this is
    // the kernel-level backstop for callers that skip it.
    assert!(
        task.steps < (1 << STEP_BITS),
        "task {}: {} steps exceed the counter layout's 2^{STEP_BITS} budget",
        task.id,
        task.steps
    );
    // Split the 64-bit path index into the first counter word plus a c1
    // high-bits overflow (zero for paths < 2^32 — bit-compatible with the
    // original u32 layout).
    let ctr = |p: u32| -> (u32, u32) {
        let g = offset.wrapping_add(p as u64);
        (g as u32, ((g >> 32) as u32) << STEP_BITS)
    };
    let (s0, k, r, sigma, t) = (
        task.spot as f32,
        task.strike as f32,
        task.rate as f32,
        task.sigma as f32,
        task.maturity as f32,
    );
    let mut sum = 0.0f64;
    let mut sum_sq = 0.0f64;
    match task.payoff {
        Payoff::European => {
            let drift = (r - 0.5 * sigma * sigma) * t;
            let vol = sigma * t.sqrt();
            for p in 0..n {
                let (c0, hi) = ctr(p);
                let z = threefry_normal(k0, k1, c0, hi);
                let st = s0 * (drift + vol * z).exp();
                let payoff = (st - k).max(0.0) as f64;
                sum += payoff;
                sum_sq += payoff * payoff;
            }
        }
        Payoff::Asian => {
            let steps = task.steps;
            let dt = t / steps as f32;
            let drift = (r - 0.5 * sigma * sigma) * dt;
            let vol = sigma * dt.sqrt();
            for p in 0..n {
                let (c0, hi) = ctr(p);
                let mut log_s = s0.ln();
                let mut acc = 0.0f32;
                for step in 0..steps {
                    let z = threefry_normal(k0, k1, c0, hi | step);
                    log_s += drift + vol * z;
                    acc += log_s.exp();
                }
                let payoff = ((acc / steps as f32) - k).max(0.0) as f64;
                sum += payoff;
                sum_sq += payoff * payoff;
            }
        }
        Payoff::Barrier => {
            let steps = task.steps;
            let barrier = task.barrier as f32;
            let dt = t / steps as f32;
            let drift = (r - 0.5 * sigma * sigma) * dt;
            let vol = sigma * dt.sqrt();
            for p in 0..n {
                let (c0, hi) = ctr(p);
                let mut log_s = s0.ln();
                let mut alive = s0 < barrier;
                for step in 0..steps {
                    let z = threefry_normal(k0, k1, c0, hi | step);
                    log_s += drift + vol * z;
                    alive = alive && log_s.exp() < barrier;
                }
                let payoff = if alive { (log_s.exp() - k).max(0.0) as f64 } else { 0.0 };
                sum += payoff;
                sum_sq += payoff * payoff;
            }
        }
    }
    PayoffStats { sum, sum_sq, n: n as u64 }
}

/// Price a task natively with `n` paths (convenience wrapper).
pub fn price(task: &OptionTask, seed: u32, n: u32) -> PriceEstimate {
    combine(&simulate(task, seed, 0, n), task.discount())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pricing::blackscholes;
    use crate::workload::{generate, GeneratorConfig};

    fn european() -> OptionTask {
        OptionTask {
            id: 7,
            payoff: Payoff::European,
            spot: 100.0,
            strike: 105.0,
            rate: 0.05,
            sigma: 0.2,
            maturity: 1.0,
            barrier: 0.0,
            steps: 1,
            target_accuracy: 0.01,
            n_sims: 1 << 18,
        }
    }

    #[test]
    fn european_matches_black_scholes() {
        let t = european();
        let est = price(&t, 42, 1 << 18);
        let bs = blackscholes::call(t.spot, t.strike, t.rate, t.sigma, t.maturity);
        assert!(
            (est.price - bs).abs() < 4.0 * est.std_error + 0.03,
            "mc {} ± {} vs bs {bs}",
            est.price,
            est.std_error
        );
    }

    #[test]
    fn chunking_is_exactly_additive() {
        let t = european();
        let whole = simulate(&t, 1, 0, 4096);
        let lo = simulate(&t, 1, 0, 2048);
        let hi = simulate(&t, 1, 2048, 2048);
        let merged = lo.merge(&hi);
        assert!((whole.sum - merged.sum).abs() < 1e-9 * whole.sum.abs().max(1.0));
        assert!((whole.sum_sq - merged.sum_sq).abs() < 1e-9 * whole.sum_sq.abs().max(1.0));
        assert_eq!(whole.n, merged.n);
    }

    #[test]
    fn chunking_is_additive_across_the_u32_boundary() {
        // The offsets that used to wrap at 32 bits: a slice straddling
        // 2^32 must merge exactly like any other contiguous pair.
        let t = european();
        let base = (1u64 << 32) - 1024;
        let whole = simulate(&t, 1, base, 4096);
        let lo = simulate(&t, 1, base, 1024);
        let hi = simulate(&t, 1, base + 1024, 3072);
        let merged = lo.merge(&hi);
        assert!((whole.sum - merged.sum).abs() < 1e-9 * whole.sum.abs().max(1.0));
        assert_eq!(whole.n, merged.n);
    }

    #[test]
    fn high_offsets_are_fresh_unbiased_streams() {
        // Slices above 2^32 must neither repeat the low-offset stream (the
        // old truncation bug) nor drift from the true price.
        let t = european();
        let lo = simulate(&t, 1, 0, 1 << 14);
        let hi = simulate(&t, 1, 1u64 << 33, 1 << 14);
        assert_ne!(lo.sum, hi.sum, "high offsets replayed the low stream");
        let pl = combine(&lo, t.discount());
        let ph = combine(&hi, t.discount());
        assert!(
            (pl.price - ph.price).abs() < 4.0 * (pl.std_error + ph.std_error),
            "{pl:?} vs {ph:?}"
        );
    }

    #[test]
    fn path_dependent_counters_survive_high_offsets() {
        // Asian payoffs use the step word; the folded high bits must not
        // collide with steps (and the estimate must stay sane).
        let mut t = european();
        t.payoff = Payoff::Asian;
        t.steps = 32;
        let a = simulate(&t, 9, 1u64 << 33, 1 << 12);
        let b = simulate(&t, 9, 0, 1 << 12);
        assert_ne!(a.sum, b.sum);
        let est = combine(&a, t.discount());
        assert!(est.price >= 0.0 && est.price < t.spot);
    }

    #[test]
    fn seeds_decorrelate() {
        let t = european();
        let a = simulate(&t, 1, 0, 8192);
        let b = simulate(&t, 2, 0, 8192);
        assert_ne!(a.sum, b.sum);
        let pa = combine(&a, t.discount()).price;
        let pb = combine(&b, t.discount()).price;
        assert!((pa - pb).abs() < 0.5, "both near the true price");
    }

    #[test]
    fn asian_bracketed_by_geometric_and_european() {
        let mut t = european();
        t.payoff = Payoff::Asian;
        t.steps = 32;
        t.strike = 100.0;
        let est = price(&t, 9, 1 << 16);
        let geo = blackscholes::geometric_asian_call(t.spot, t.strike, t.rate, t.sigma, t.maturity, 32);
        let eur = blackscholes::call(t.spot, t.strike, t.rate, t.sigma, t.maturity);
        assert!(est.price > geo - 4.0 * est.std_error - 0.05, "{est:?} vs geo {geo}");
        assert!(est.price < eur + 4.0 * est.std_error, "{est:?} vs eur {eur}");
    }

    #[test]
    fn barrier_below_european_and_monotone() {
        let mut t = european();
        t.payoff = Payoff::Barrier;
        t.steps = 32;
        t.barrier = 130.0;
        let tight = price(&t, 3, 1 << 16).price;
        t.barrier = 160.0;
        let loose = price(&t, 3, 1 << 16).price;
        let eur = blackscholes::call(t.spot, t.strike, t.rate, t.sigma, t.maturity);
        assert!(tight <= loose + 1e-9);
        assert!(loose < eur);
    }

    #[test]
    fn std_error_shrinks_like_sqrt_n() {
        let t = european();
        let small = price(&t, 5, 1 << 12).std_error;
        let big = price(&t, 5, 1 << 16).std_error;
        let ratio = small / big;
        assert!((2.8..5.7).contains(&ratio), "expected ~4, got {ratio}");
    }

    #[test]
    fn whole_generated_workload_prices_sanely() {
        let w = generate(&GeneratorConfig::small(6, 0.1, 11));
        for t in &w.tasks {
            let est = price(t, 1, 1 << 14);
            assert!(est.price >= 0.0, "negative price for {t:?}");
            assert!(est.price < t.spot, "call above spot for {t:?}");
        }
    }

    #[test]
    #[should_panic(expected = "no paths")]
    fn combine_rejects_empty() {
        combine(&PayoffStats::default(), 1.0);
    }
}
