//! Correlated multi-asset basket call via Cholesky-factored paths.
//!
//! Prices an equally-weighted call on `d = task.assets` identical lognormal
//! assets (common spot/vol) under pairwise equicorrelation
//! `ρ = task.correlation`. Each step draws `d` independent Threefry normals
//! (counter sub-index `step·d + a`, staying inside the [`STEP_BITS`]
//! budget — validated per task) and correlates them through the
//! lower-triangular Cholesky factor `L` of the equicorrelation matrix:
//! `z = L·ε` is standard normal per asset with the required cross-asset
//! correlation.
//!
//! Greeks are pathwise (the basket payoff is a.s. differentiable): delta
//! `1{B>K}·B/S₀` (every asset scales with the common spot), vega
//! `1{B>K}·(1/d)·Σ_a Sᵀ_a·(√dt·W_a − σT)` with `W_a` the running sum of
//! asset `a`'s correlated normals.

use crate::util::rng::threefry_normal;
use crate::workload::option::{OptionTask, Payoff, MAX_BASKET_ASSETS};

use super::mc::{PayoffStats, STEP_BITS};

const MAX_D: usize = MAX_BASKET_ASSETS as usize;

/// Lower-triangular Cholesky factor of the `d×d` equicorrelation matrix
/// (ones on the diagonal, `rho` off it), computed in f64 and rounded to the
/// kernels' f32 once. Panics on infeasible `rho` (validation rejects
/// `rho <= -1/(d-1)` long before execution).
pub(crate) fn equicorrelation_cholesky(d: usize, rho: f64) -> [[f32; MAX_D]; MAX_D] {
    assert!(d >= 1 && d <= MAX_D);
    let mut a = [[0.0f64; MAX_D]; MAX_D];
    for (i, row) in a.iter_mut().enumerate().take(d) {
        for (j, v) in row.iter_mut().enumerate().take(d) {
            *v = if i == j { 1.0 } else { rho };
        }
    }
    let mut l = [[0.0f64; MAX_D]; MAX_D];
    for i in 0..d {
        for j in 0..=i {
            let mut s = a[i][j];
            for k in 0..j {
                s -= l[i][k] * l[j][k];
            }
            if i == j {
                assert!(s > 0.0, "equicorrelation rho={rho} not positive-definite for d={d}");
                l[i][j] = s.sqrt();
            } else {
                l[i][j] = s / l[j][j];
            }
        }
    }
    let mut lf = [[0.0f32; MAX_D]; MAX_D];
    for i in 0..d {
        for j in 0..=i {
            lf[i][j] = l[i][j] as f32;
        }
    }
    lf
}

/// Simulate `n` basket paths at counter `offset` — same counter bijection
/// as [`mc::simulate`](super::mc::simulate) with per-path sub-draws
/// `step·d + a`, so chunked execution composes to identical statistics.
pub fn simulate(task: &OptionTask, seed: u32, offset: u64, n: u32) -> PayoffStats {
    assert_eq!(task.payoff, Payoff::Basket, "basket kernel requires a Basket task");
    let d = task.assets as usize;
    assert!((2..=MAX_D).contains(&d), "task {}: basket dimension {d}", task.id);
    let words = task.steps as u64 * task.assets as u64;
    assert!(
        words < (1 << STEP_BITS),
        "task {}: {words} counter words per path exceed the 2^{STEP_BITS} budget",
        task.id
    );
    let chol = equicorrelation_cholesky(d, task.correlation);
    let k0 = task.id as u32;
    let k1 = seed;
    let ctr = |p: u32| -> (u32, u32) {
        let g = offset.wrapping_add(p as u64);
        (g as u32, ((g >> 32) as u32) << STEP_BITS)
    };
    let steps = task.steps;
    let (s0, k, r, sigma, t) = (
        task.spot as f32,
        task.strike as f32,
        task.rate as f32,
        task.sigma as f32,
        task.maturity as f32,
    );
    let dt = t / steps as f32;
    let drift = (r - 0.5 * sigma * sigma) * dt;
    let vol = sigma * dt.sqrt();
    let sqrt_dt = dt.sqrt();
    let df = d as f32;
    let mut sum = 0.0f64;
    let mut sum_sq = 0.0f64;
    let mut delta_sum = 0.0f64;
    let mut vega_sum = 0.0f64;
    for p in 0..n {
        let (c0, hi) = ctr(p);
        let mut log_s = [s0.ln(); MAX_D];
        // Pathwise-vega state: running correlated-normal sum per asset.
        let mut w = [0.0f32; MAX_D];
        let mut eps = [0.0f32; MAX_D];
        for step in 0..steps {
            for (a, e) in eps.iter_mut().enumerate().take(d) {
                *e = threefry_normal(k0, k1, c0, hi | (step * d as u32 + a as u32));
            }
            for a in 0..d {
                let mut z = 0.0f32;
                for b in 0..=a {
                    z += chol[a][b] * eps[b];
                }
                log_s[a] += drift + vol * z;
                w[a] += z;
            }
        }
        let mut basket = 0.0f32;
        let mut vacc = 0.0f32;
        for a in 0..d {
            let st = log_s[a].exp();
            basket += st;
            vacc += st * (sqrt_dt * w[a] - sigma * t);
        }
        basket /= df;
        let payoff = (basket - k).max(0.0) as f64;
        sum += payoff;
        sum_sq += payoff * payoff;
        if basket > k {
            delta_sum += (basket / s0) as f64;
            vega_sum += (vacc / df) as f64;
        }
    }
    PayoffStats { sum, sum_sq, delta_sum, vega_sum, n: n as u64 }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pricing::blackscholes;
    use crate::pricing::mc::combine;

    fn basket() -> OptionTask {
        OptionTask {
            id: 5,
            payoff: Payoff::Basket,
            spot: 100.0,
            strike: 105.0,
            rate: 0.05,
            sigma: 0.25,
            maturity: 1.0,
            steps: 16,
            assets: 4,
            correlation: 0.5,
            ..OptionTask::default()
        }
    }

    #[test]
    fn cholesky_reconstructs_equicorrelation() {
        for (d, rho) in [(2, 0.8), (4, 0.5), (8, -0.1)] {
            let l = equicorrelation_cholesky(d, rho);
            for i in 0..d {
                for j in 0..d {
                    let mut v = 0.0f64;
                    for k in 0..d {
                        v += l[i][k] as f64 * l[j][k] as f64;
                    }
                    let want = if i == j { 1.0 } else { rho };
                    assert!((v - want).abs() < 1e-6, "d={d} rho={rho} [{i}][{j}]: {v}");
                }
            }
        }
    }

    #[test]
    fn chunking_is_exactly_additive() {
        let t = basket();
        let whole = simulate(&t, 1, 0, 4096);
        let lo = simulate(&t, 1, 0, 1000);
        let hi = simulate(&t, 1, 1000, 3096);
        let merged = lo.merge(&hi);
        assert!((whole.sum - merged.sum).abs() < 1e-9 * whole.sum.abs().max(1.0));
        assert!((whole.sum_sq - merged.sum_sq).abs() < 1e-9 * whole.sum_sq.abs().max(1.0));
        assert_eq!(whole.n, merged.n);
    }

    #[test]
    fn full_correlation_degenerates_to_single_asset() {
        // rho -> 1: every asset follows the same path, so the basket call
        // is just a European call (cross-checked against Black-Scholes).
        let mut t = basket();
        t.correlation = 0.999_999;
        let est = combine(&simulate(&t, 9, 0, 1 << 15), t.discount());
        let eur = blackscholes::call(t.spot, t.strike, t.rate, t.sigma, t.maturity);
        assert!(
            (est.price - eur).abs() < 4.0 * est.std_error + 0.05,
            "mc {} ± {} vs eur {eur}",
            est.price,
            est.std_error
        );
    }

    #[test]
    fn diversification_cheapens_the_otm_call() {
        // Lower correlation shrinks basket variance, cheapening the OTM
        // call — the qualitative ordering the closed forms predict.
        let mut t = basket();
        t.correlation = 0.1;
        let lo = combine(&simulate(&t, 3, 0, 1 << 15), t.discount()).price;
        t.correlation = 0.8;
        let hi = combine(&simulate(&t, 3, 0, 1 << 15), t.discount()).price;
        assert!(lo < hi, "rho=0.1 {lo} vs rho=0.8 {hi}");
    }
}
