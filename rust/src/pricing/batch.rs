//! Batched, vectorisation-ready Monte Carlo kernel.
//!
//! The scalar pricer ([`mc::simulate`]) advances one path at a time through
//! one [`threefry_normal`](crate::util::rng::threefry_normal) call per step
//! — correct, but the hot loop the whole performance-cost trade-off is
//! forecast against (paper §IV: the kernel *is* the unit of work whose
//! per-platform throughput the models predict). This module restructures
//! the same computation around the Pallas kernels' batched formulation
//! (`python/compile/kernels/mc.py`): a block of `N` independent paths
//! advances through the step loop together, with the Threefry counters,
//! Box-Muller normals and payoff state (log-spot, Asian accumulator,
//! Barrier alive-mask, basket asset vector, Heston variance) held in
//! fixed-size per-lane arrays the compiler can autovectorise. Randomness
//! dominates the work (§IV.A.1), and Threefry is embarrassingly
//! SIMD-friendly — lanes share a key and differ only in counters.
//!
//! **Bit-parity contract.** Batched results are *bit-identical* to the
//! scalar path, not merely close:
//!
//! * same counter bijection — lane `i` of the block at `base` uses the
//!   global path index `base + i`, split into `(c0, c1-high-bits)` exactly
//!   as [`mc::simulate`] does (see [`STEP_BITS`]);
//! * same per-path f32 rounding — each lane applies the identical sequence
//!   of f32 operations the scalar loop applies to that path;
//! * same merge order — block payoffs (and Greek estimators) reduce into
//!   the f64 [`PayoffStats`] accumulators in ascending path order, so the
//!   f64 additions happen in exactly the scalar loop's sequence.
//!
//! A ragged tail (`n` not a multiple of the lane width) computes a full
//! block but folds only the live lanes into the sums; the dead lanes'
//! counters belong to neighbouring chunks, and their discarded samples
//! cannot bias anything (counter-based RNG carries no state).
//!
//! **Family coverage.** European/Asian/Barrier/Basket/Heston have lane
//! formulations (independent paths, per-lane state). American (LSMC) does
//! not — its regression pass couples paths across the chunk — so the
//! batched entry points route it to the scalar kernel, which is the oracle
//! anyway; results stay bit-identical by construction.
//!
//! The scalar path is kept as the differential oracle:
//! `rust/tests/pricing_batch.rs` holds `simulate_batch == simulate`
//! bit-for-bit across every payoff family, ragged tails, offsets
//! straddling `2^32` and `steps` at the counter-layout boundary, and
//! `perf_executor`'s kernel bench gates batched throughput ≥ scalar in CI
//! (`BENCH_kernel.json`).

use crate::api::error::{CloudshapesError, Result};
use crate::util::rng::threefry_normal_lanes;
use crate::workload::option::{OptionTask, Payoff, MAX_BASKET_ASSETS};

use super::basket::equicorrelation_cholesky;
use super::mc::{self, PayoffStats, STEP_BITS};

/// Default lane width. 8 × u32 fills a 256-bit vector register — wide
/// enough to saturate AVX2-class VPUs while the per-block payoff state
/// (≤ 4 live f32 arrays) stays register-resident; narrower/wider targets
/// pick another [`SUPPORTED_LANES`] width via `[kernel] lanes`.
pub const LANES: usize = 8;

/// Lane widths the runtime dispatcher monomorphises. Powers of two only:
/// they map onto 128/256/512-bit vector registers (and multiples), and the
/// config parser rejects anything else at load time.
pub const SUPPORTED_LANES: [usize; 4] = [4, 8, 16, 32];

/// Kernel selection knobs (`[kernel]` in the TOML schema).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KernelConfig {
    /// Route simulation through the batched kernel (`false` is the escape
    /// hatch back to the scalar oracle — results are bit-identical either
    /// way, so this only trades speed).
    pub batch: bool,
    /// Paths per block; must be one of [`SUPPORTED_LANES`].
    pub lanes: usize,
}

impl Default for KernelConfig {
    fn default() -> Self {
        KernelConfig { batch: true, lanes: LANES }
    }
}

impl KernelConfig {
    /// The scalar-oracle configuration (the pre-batching behaviour).
    pub fn scalar() -> KernelConfig {
        KernelConfig { batch: false, ..Default::default() }
    }

    /// Reject unsupported lane widths with a typed config error.
    pub fn validate(&self) -> Result<()> {
        if !SUPPORTED_LANES.contains(&self.lanes) {
            return Err(CloudshapesError::config(format!(
                "kernel.lanes must be one of {SUPPORTED_LANES:?}, got {}",
                self.lanes
            )));
        }
        Ok(())
    }

    /// Simulate through the configured kernel: the batched path at the
    /// configured lane width, or the scalar oracle when `batch = false`.
    /// Bit-identical results either way — this is purely a speed knob.
    pub fn simulate(&self, task: &OptionTask, seed: u32, offset: u64, n: u32) -> PayoffStats {
        if !self.batch {
            return mc::simulate(task, seed, offset, n);
        }
        match self.lanes {
            4 => simulate_lanes::<4>(task, seed, offset, n),
            8 => simulate_lanes::<8>(task, seed, offset, n),
            16 => simulate_lanes::<16>(task, seed, offset, n),
            32 => simulate_lanes::<32>(task, seed, offset, n),
            // validate() rejects other widths; tolerate a hand-built
            // config by falling back to the oracle rather than panicking.
            _ => mc::simulate(task, seed, offset, n),
        }
    }
}

/// Batched [`mc::simulate`] at the default lane width — same signature,
/// same counter bijection, bit-identical [`PayoffStats`].
pub fn simulate_batch(task: &OptionTask, seed: u32, offset: u64, n: u32) -> PayoffStats {
    simulate_lanes::<LANES>(task, seed, offset, n)
}

/// Lane counters for the block whose first global path index is `base`:
/// the scalar pricer's `(c0, c1-high-bits)` split applied per lane.
fn lane_counters<const N: usize>(base: u64) -> ([u32; N], [u32; N]) {
    let mut c0 = [0u32; N];
    let mut hi = [0u32; N];
    for i in 0..N {
        let g = base.wrapping_add(i as u64);
        c0[i] = g as u32;
        hi[i] = ((g >> 32) as u32) << STEP_BITS;
    }
    (c0, hi)
}

/// Accumulator quartet the lane blocks fold into.
#[derive(Default)]
struct Acc {
    sum: f64,
    sum_sq: f64,
    delta: f64,
    vega: f64,
}

/// Fold the first `live` lanes of a block's payoffs and per-path Greek
/// estimators into the f64 sums in ascending path order — the exact
/// addition sequence of the scalar loop. (The scalar loop skips the Greek
/// add for OTM paths; adding the `0.0` the dead branch would have added is
/// bit-identical for finite accumulators.)
#[inline]
fn reduce(pay: &[f32], del: &[f64], veg: &[f64], live: usize, acc: &mut Acc) {
    for i in 0..live {
        let x = pay[i] as f64;
        acc.sum += x;
        acc.sum_sq += x * x;
        acc.delta += del[i];
        acc.vega += veg[i];
    }
}

/// Simulate `n` paths of `task` at counter `offset` in blocks of `N`
/// lanes. See the module docs for the bit-parity contract with
/// [`mc::simulate`].
pub fn simulate_lanes<const N: usize>(
    task: &OptionTask,
    seed: u32,
    offset: u64,
    n: u32,
) -> PayoffStats {
    // LSMC's cross-path regression has no independent-lane formulation;
    // the scalar kernel is the (only, and oracle) implementation.
    if task.payoff == Payoff::American {
        return mc::simulate(task, seed, offset, n);
    }
    let k0 = task.id as u32;
    let k1 = seed;
    // Same hard counter-layout check as the scalar oracle (workload
    // validation rejects such tasks long before execution; this is the
    // kernel-level backstop).
    let words = task.payoff.counter_words_per_path(task.steps, task.assets);
    assert!(
        words < (1 << STEP_BITS),
        "task {}: {words} counter words per path exceed the 2^{STEP_BITS} budget",
        task.id
    );
    let (s0, k, r, sigma, t) = (
        task.spot as f32,
        task.strike as f32,
        task.rate as f32,
        task.sigma as f32,
        task.maturity as f32,
    );
    let mut acc2 = Acc::default();
    let acc = &mut acc2;
    let mut done: u32 = 0;
    match task.payoff {
        Payoff::European => {
            let drift = (r - 0.5 * sigma * sigma) * t;
            let vol = sigma * t.sqrt();
            let sqrt_t = t.sqrt();
            while done < n {
                let live = ((n - done) as usize).min(N);
                let (c0, hi) = lane_counters::<N>(offset.wrapping_add(done as u64));
                let z = threefry_normal_lanes(k0, k1, c0, hi);
                let mut pay = [0.0f32; N];
                let mut del = [0.0f64; N];
                let mut veg = [0.0f64; N];
                for i in 0..N {
                    let st = s0 * (drift + vol * z[i]).exp();
                    pay[i] = (st - k).max(0.0);
                    if st > k {
                        del[i] = (st / s0) as f64;
                        veg[i] = (st * (sqrt_t * z[i] - sigma * t)) as f64;
                    }
                }
                reduce(&pay, &del, &veg, live, acc);
                done += live as u32;
            }
        }
        Payoff::Asian => {
            let steps = task.steps;
            let dt = t / steps as f32;
            let drift = (r - 0.5 * sigma * sigma) * dt;
            let vol = sigma * dt.sqrt();
            let sqrt_dt = dt.sqrt();
            while done < n {
                let live = ((n - done) as usize).min(N);
                let (c0, hi) = lane_counters::<N>(offset.wrapping_add(done as u64));
                let mut log_s = [s0.ln(); N];
                let mut acc_s = [0.0f32; N];
                let mut w = [0.0f32; N];
                let mut vacc = [0.0f32; N];
                for step in 0..steps {
                    let mut c1 = [0u32; N];
                    for i in 0..N {
                        c1[i] = hi[i] | step;
                    }
                    let z = threefry_normal_lanes(k0, k1, c0, c1);
                    for i in 0..N {
                        log_s[i] += drift + vol * z[i];
                        acc_s[i] += log_s[i].exp();
                        w[i] += z[i];
                        vacc[i] +=
                            log_s[i].exp() * (sqrt_dt * w[i] - sigma * (dt * (step + 1) as f32));
                    }
                }
                let mut pay = [0.0f32; N];
                let mut del = [0.0f64; N];
                let mut veg = [0.0f64; N];
                for i in 0..N {
                    let avg = acc_s[i] / steps as f32;
                    pay[i] = (avg - k).max(0.0);
                    if avg > k {
                        del[i] = (avg / s0) as f64;
                        veg[i] = (vacc[i] / steps as f32) as f64;
                    }
                }
                reduce(&pay, &del, &veg, live, acc);
                done += live as u32;
            }
        }
        Payoff::Barrier => {
            let steps = task.steps;
            let barrier = task.barrier as f32;
            let dt = t / steps as f32;
            let drift = (r - 0.5 * sigma * sigma) * dt;
            let vol = sigma * dt.sqrt();
            let sqrt_dt = dt.sqrt();
            let lr_denom = s0 * sigma * sqrt_dt;
            while done < n {
                let live = ((n - done) as usize).min(N);
                let (c0, hi) = lane_counters::<N>(offset.wrapping_add(done as u64));
                let mut log_s = [s0.ln(); N];
                let mut alive = [s0 < barrier; N];
                let mut z1 = [0.0f32; N];
                let mut score_v = [0.0f32; N];
                for step in 0..steps {
                    let mut c1 = [0u32; N];
                    for i in 0..N {
                        c1[i] = hi[i] | step;
                    }
                    let z = threefry_normal_lanes(k0, k1, c0, c1);
                    for i in 0..N {
                        if step == 0 {
                            z1[i] = z[i];
                        }
                        score_v[i] += (z[i] * z[i] - 1.0) / sigma - z[i] * sqrt_dt;
                        log_s[i] += drift + vol * z[i];
                        // `&` (not `&&`): branch-free per lane; value-equal
                        // to the scalar short-circuit since exp() is pure.
                        alive[i] &= log_s[i].exp() < barrier;
                    }
                }
                let mut pay = [0.0f32; N];
                let mut del = [0.0f64; N];
                let mut veg = [0.0f64; N];
                for i in 0..N {
                    pay[i] = if alive[i] { (log_s[i].exp() - k).max(0.0) } else { 0.0 };
                    let payoff = pay[i] as f64;
                    del[i] = payoff * (z1[i] / lr_denom) as f64;
                    veg[i] = payoff * score_v[i] as f64;
                }
                reduce(&pay, &del, &veg, live, acc);
                done += live as u32;
            }
        }
        Payoff::Basket => {
            const MAX_D: usize = MAX_BASKET_ASSETS as usize;
            let d = task.assets as usize;
            let chol = equicorrelation_cholesky(d, task.correlation);
            let steps = task.steps;
            let dt = t / steps as f32;
            let drift = (r - 0.5 * sigma * sigma) * dt;
            let vol = sigma * dt.sqrt();
            let sqrt_dt = dt.sqrt();
            let df = d as f32;
            while done < n {
                let live = ((n - done) as usize).min(N);
                let (c0, hi) = lane_counters::<N>(offset.wrapping_add(done as u64));
                let mut log_s = [[s0.ln(); MAX_D]; N];
                let mut w = [[0.0f32; MAX_D]; N];
                let mut eps = [[0.0f32; MAX_D]; N];
                for step in 0..steps {
                    for a in 0..d {
                        let mut c1 = [0u32; N];
                        for i in 0..N {
                            c1[i] = hi[i] | (step * d as u32 + a as u32);
                        }
                        let z = threefry_normal_lanes(k0, k1, c0, c1);
                        for i in 0..N {
                            eps[i][a] = z[i];
                        }
                    }
                    for i in 0..N {
                        for a in 0..d {
                            let mut z = 0.0f32;
                            for b in 0..=a {
                                z += chol[a][b] * eps[i][b];
                            }
                            log_s[i][a] += drift + vol * z;
                            w[i][a] += z;
                        }
                    }
                }
                let mut pay = [0.0f32; N];
                let mut del = [0.0f64; N];
                let mut veg = [0.0f64; N];
                for i in 0..N {
                    let mut basket = 0.0f32;
                    let mut vacc = 0.0f32;
                    for a in 0..d {
                        let st = log_s[i][a].exp();
                        basket += st;
                        vacc += st * (sqrt_dt * w[i][a] - sigma * t);
                    }
                    basket /= df;
                    pay[i] = (basket - k).max(0.0);
                    if basket > k {
                        del[i] = (basket / s0) as f64;
                        veg[i] = (vacc / df) as f64;
                    }
                }
                reduce(&pay, &del, &veg, live, acc);
                done += live as u32;
            }
        }
        Payoff::Heston => {
            let steps = task.steps;
            let (kappa, theta, xi, v0, rho) = (
                task.kappa as f32,
                task.theta as f32,
                task.xi as f32,
                task.v0 as f32,
                task.correlation as f32,
            );
            let dt = t / steps as f32;
            let rho_perp = (1.0 - rho * rho).sqrt();
            while done < n {
                let live = ((n - done) as usize).min(N);
                let (c0, hi) = lane_counters::<N>(offset.wrapping_add(done as u64));
                let mut log_s = [s0.ln(); N];
                let mut v = [v0; N];
                let mut dv = [1.0f32; N];
                let mut g = [0.0f32; N];
                for step in 0..steps {
                    let mut c1a = [0u32; N];
                    let mut c1b = [0u32; N];
                    for i in 0..N {
                        c1a[i] = hi[i] | (2 * step);
                        c1b[i] = hi[i] | (2 * step + 1);
                    }
                    let zs = threefry_normal_lanes(k0, k1, c0, c1a);
                    let z2 = threefry_normal_lanes(k0, k1, c0, c1b);
                    for i in 0..N {
                        let z_v = rho * zs[i] + rho_perp * z2[i];
                        let vp = v[i].max(0.0);
                        let sq = (vp * dt).sqrt();
                        let ind = if v[i] > 0.0 { 1.0f32 } else { 0.0 };
                        let dsq = if sq > 0.0 { ind * dv[i] * dt / (2.0 * sq) } else { 0.0 };
                        log_s[i] += (r - 0.5 * vp) * dt + sq * zs[i];
                        g[i] += -0.5 * ind * dv[i] * dt + zs[i] * dsq;
                        v[i] += kappa * (theta - vp) * dt + xi * sq * z_v;
                        dv[i] += -kappa * ind * dv[i] * dt + xi * z_v * dsq;
                    }
                }
                let mut pay = [0.0f32; N];
                let mut del = [0.0f64; N];
                let mut veg = [0.0f64; N];
                for i in 0..N {
                    let st = log_s[i].exp();
                    pay[i] = (st - k).max(0.0);
                    if st > k {
                        del[i] = (st / s0) as f64;
                        veg[i] = (st * g[i] * 2.0 * v0.sqrt()) as f64;
                    }
                }
                reduce(&pay, &del, &veg, live, acc);
                done += live as u32;
            }
        }
        Payoff::American => unreachable!("routed to the scalar kernel above"),
    }
    PayoffStats {
        sum: acc2.sum,
        sum_sq: acc2.sum_sq,
        delta_sum: acc2.delta,
        vega_sum: acc2.vega,
        n: n as u64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{generate, GeneratorConfig};

    fn task(payoff: Payoff) -> OptionTask {
        OptionTask {
            id: 7,
            payoff,
            spot: 100.0,
            strike: 105.0,
            rate: 0.05,
            sigma: 0.2,
            maturity: 1.0,
            barrier: 140.0,
            steps: if payoff == Payoff::European { 1 } else { 16 },
            assets: if payoff == Payoff::Basket { 4 } else { 1 },
            correlation: match payoff {
                Payoff::Basket => 0.5,
                Payoff::Heston => -0.7,
                _ => 0.0,
            },
            ..OptionTask::default()
        }
    }

    #[test]
    fn batch_is_bitwise_scalar_per_family() {
        for payoff in Payoff::ALL {
            let t = task(payoff);
            let a = mc::simulate(&t, 42, 0, 4096);
            let b = simulate_batch(&t, 42, 0, 4096);
            assert_eq!(a, b, "{payoff:?}");
        }
    }

    #[test]
    fn ragged_tails_are_bitwise_scalar() {
        for payoff in [Payoff::Asian, Payoff::Basket, Payoff::Heston] {
            let t = task(payoff);
            for n in [1u32, 3, 7, 8, 9, 100, 1023] {
                assert_eq!(
                    mc::simulate(&t, 1, 5, n),
                    simulate_batch(&t, 1, 5, n),
                    "{payoff:?} n={n}"
                );
            }
        }
    }

    #[test]
    fn every_supported_lane_width_agrees() {
        for payoff in [Payoff::Barrier, Payoff::Basket, Payoff::Heston] {
            let t = task(payoff);
            let oracle = mc::simulate(&t, 9, 100, 333);
            assert_eq!(simulate_lanes::<4>(&t, 9, 100, 333), oracle, "{payoff:?}");
            assert_eq!(simulate_lanes::<8>(&t, 9, 100, 333), oracle, "{payoff:?}");
            assert_eq!(simulate_lanes::<16>(&t, 9, 100, 333), oracle, "{payoff:?}");
            assert_eq!(simulate_lanes::<32>(&t, 9, 100, 333), oracle, "{payoff:?}");
        }
    }

    #[test]
    fn config_routes_and_validates() {
        let t = task(Payoff::European);
        let oracle = mc::simulate(&t, 3, 0, 1000);
        assert_eq!(KernelConfig::default().simulate(&t, 3, 0, 1000), oracle);
        assert_eq!(KernelConfig::scalar().simulate(&t, 3, 0, 1000), oracle);
        let wide = KernelConfig { lanes: 32, ..Default::default() };
        assert_eq!(wide.simulate(&t, 3, 0, 1000), oracle);
        assert!(KernelConfig::default().validate().is_ok());
        let bad = KernelConfig { lanes: 7, ..Default::default() };
        let e = bad.validate().unwrap_err();
        assert_eq!(e.kind(), "config");
        assert!(e.message().contains('7'), "{e}");
        // Unvalidated odd widths still price correctly via the fallback.
        assert_eq!(bad.simulate(&t, 3, 0, 1000), oracle);
    }

    #[test]
    fn american_routes_to_the_scalar_oracle() {
        // No lane formulation exists (cross-path regression); the batched
        // entry points must return the scalar kernel's exact stats.
        let t = task(Payoff::American);
        let oracle = mc::simulate(&t, 4, 64, 777);
        assert_eq!(simulate_batch(&t, 4, 64, 777), oracle);
        assert_eq!(KernelConfig::default().simulate(&t, 4, 64, 777), oracle);
    }

    #[test]
    fn zero_paths_is_empty_stats() {
        let t = task(Payoff::European);
        assert_eq!(simulate_batch(&t, 1, 0, 0), PayoffStats::default());
    }

    #[test]
    fn generated_workload_is_bitwise_scalar() {
        for t in &generate(&GeneratorConfig::small(6, 0.1, 11)).tasks {
            assert_eq!(mc::simulate(t, 1, 0, 2048), simulate_batch(t, 1, 0, 2048), "{t:?}");
        }
        // And for an all-exotics mix, which the default config never draws.
        let cfg = GeneratorConfig {
            payoff_mix: [0.0, 0.0, 0.0, 1.0, 1.0, 1.0],
            ..GeneratorConfig::small(6, 0.1, 13)
        };
        for t in &generate(&cfg).tasks {
            assert_eq!(mc::simulate(t, 1, 0, 512), simulate_batch(t, 1, 0, 512), "{t:?}");
        }
    }
}
