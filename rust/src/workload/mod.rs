//! Workloads: sets of atomic, divisible option-pricing tasks (§IV.A.1).

pub mod kaiserslautern;
pub mod option;

pub use kaiserslautern::{generate, try_generate, GeneratorConfig};
pub use option::{OptionTask, Payoff};

use crate::api::error::{CloudshapesError, Result};

/// An ordered set of tasks to partition across a cluster.
#[derive(Debug, Clone, PartialEq)]
pub struct Workload {
    pub tasks: Vec<OptionTask>,
}

impl Workload {
    pub fn new(tasks: Vec<OptionTask>) -> Workload {
        Workload { tasks }
    }

    pub fn len(&self) -> usize {
        self.tasks.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tasks.is_empty()
    }

    /// Total simulations across all tasks.
    pub fn total_sims(&self) -> u64 {
        self.tasks.iter().map(|t| t.n_sims).sum()
    }

    /// Total floating-point work across all tasks.
    pub fn total_flops(&self) -> f64 {
        self.tasks.iter().map(|t| t.total_flops()).sum()
    }

    /// Validate every task.
    pub fn validate(&self) -> Result<()> {
        if self.tasks.is_empty() {
            return Err(CloudshapesError::workload("empty workload"));
        }
        for t in &self.tasks {
            t.validate()?;
        }
        // Task ids must be unique (they key the RNG streams).
        let mut ids: Vec<usize> = self.tasks.iter().map(|t| t.id).collect();
        ids.sort();
        ids.dedup();
        if ids.len() != self.tasks.len() {
            return Err(CloudshapesError::workload("duplicate task ids"));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn totals_and_validation() {
        let w = generate(&GeneratorConfig::small(4, 0.05, 1));
        assert!(w.validate().is_ok());
        assert_eq!(w.len(), 4);
        assert_eq!(w.total_sims(), w.tasks.iter().map(|t| t.n_sims).sum::<u64>());
        assert!(w.total_flops() > 0.0);
    }

    #[test]
    fn duplicate_ids_rejected() {
        let mut w = generate(&GeneratorConfig::small(2, 0.05, 1));
        w.tasks[1].id = w.tasks[0].id;
        assert!(w.validate().is_err());
    }

    #[test]
    fn empty_rejected() {
        assert!(Workload::new(vec![]).validate().is_err());
    }
}
