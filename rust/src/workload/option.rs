//! Option-pricing task definitions — the paper's atomic, divisible tasks.
//!
//! The parameter-vector layout (`to_params`) is the wire format shared with
//! the L1 Pallas kernels (`python/compile/kernels/mc.py`): any change must
//! be made in both places and re-AOT'd.

use crate::api::error::CloudshapesError;

/// Payoff family — one per AOT kernel variant.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Payoff {
    European,
    Asian,
    Barrier,
}

impl Payoff {
    pub fn name(&self) -> &'static str {
        match self {
            Payoff::European => "european",
            Payoff::Asian => "asian",
            Payoff::Barrier => "barrier",
        }
    }

    /// Every payoff family name, in declaration order.
    pub const NAMES: [&'static str; 3] = ["european", "asian", "barrier"];

    pub fn from_name(s: &str) -> Option<Payoff> {
        match s {
            "european" => Some(Payoff::European),
            "asian" => Some(Payoff::Asian),
            "barrier" => Some(Payoff::Barrier),
            _ => None,
        }
    }

    /// As [`from_name`](Payoff::from_name), but unknown names surface as a
    /// typed [`CloudshapesError::Workload`] listing the valid families —
    /// use this at config-parse and wire boundaries instead of silently
    /// dropping the `None`.
    pub fn parse(s: &str) -> crate::api::error::Result<Payoff> {
        Payoff::from_name(s).ok_or_else(|| {
            CloudshapesError::workload(format!(
                "unknown payoff '{s}' (valid: {})",
                Payoff::NAMES.join(", ")
            ))
        })
    }

    /// The generator mix weights that select exactly this family — shared
    /// by every "single-payoff workload" surface (`[workload] payoff`, the
    /// serve `submit` op) so the mapping lives in one place.
    pub fn one_hot_mix(&self) -> (f64, f64, f64) {
        match self {
            Payoff::European => (1.0, 0.0, 0.0),
            Payoff::Asian => (0.0, 1.0, 0.0),
            Payoff::Barrier => (0.0, 0.0, 1.0),
        }
    }

    /// Approximate floating-point operations per simulated path, used to
    /// translate device GFLOPS into a Monte Carlo throughput (β). Counts the
    /// Threefry rounds (~`steps`×90 ALU ops), Box-Muller, and path update.
    pub fn flops_per_path(&self, steps: u32) -> f64 {
        const RNG_FLOPS: f64 = 130.0; // threefry-20rounds + box-muller
        const STEP_FLOPS: f64 = 12.0; // exp/log-spot update, accumulate
        match self {
            Payoff::European => RNG_FLOPS + 25.0,
            Payoff::Asian | Payoff::Barrier => steps as f64 * (RNG_FLOPS + STEP_FLOPS) + 25.0,
        }
    }
}

/// One option-pricing task. Monetary values in $, times in years.
#[derive(Debug, Clone, PartialEq)]
pub struct OptionTask {
    pub id: usize,
    pub payoff: Payoff,
    pub spot: f64,
    pub strike: f64,
    pub rate: f64,
    pub sigma: f64,
    pub maturity: f64,
    /// Knock-out level (Barrier payoff only; ignored otherwise).
    pub barrier: f64,
    /// Fixing/monitoring dates for path-dependent payoffs.
    pub steps: u32,
    /// Half-width of the 95% confidence interval the task must reach, $.
    pub target_accuracy: f64,
    /// Simulations required to reach `target_accuracy` (the task's N).
    pub n_sims: u64,
}

impl OptionTask {
    /// Size a task's N from its accuracy target via the CLT:
    /// `N = (z·σ_payoff / ε)²` with z = 1.96.
    ///
    /// The payoff standard deviation is approximated analytically (ATM
    /// lognormal dispersion `s0·σ√T` scaled by a payoff-family factor);
    /// the paper sizes N "so as to achieve an accuracy of $0.001" the same
    /// way — from pre-run estimates, not pilot runs.
    pub fn size_n(payoff: Payoff, spot: f64, sigma: f64, maturity: f64, accuracy: f64) -> u64 {
        let family_factor = match payoff {
            Payoff::European => 0.8,
            Payoff::Asian => 0.5,   // averaging shrinks dispersion
            Payoff::Barrier => 0.9, // knock-out adds dispersion near the barrier
        };
        let payoff_std = family_factor * spot * sigma * maturity.sqrt();
        let z = 1.96;
        let n = ((z * payoff_std / accuracy).powi(2)).ceil() as u64;
        n.clamp(1 << 16, 1 << 34)
    }

    /// The f32[8] parameter vector the AOT kernels take.
    pub fn to_params(&self) -> [f32; 8] {
        [
            self.spot as f32,
            self.strike as f32,
            self.rate as f32,
            self.sigma as f32,
            self.maturity as f32,
            self.barrier as f32,
            0.0,
            0.0,
        ]
    }

    /// Discount factor for this task's payoff statistics.
    pub fn discount(&self) -> f64 {
        (-self.rate * self.maturity).exp()
    }

    /// FLOPs of one simulated path of this task.
    pub fn flops_per_path(&self) -> f64 {
        self.payoff.flops_per_path(self.steps)
    }

    /// Total FLOPs of the whole task.
    pub fn total_flops(&self) -> f64 {
        self.flops_per_path() * self.n_sims as f64
    }

    /// Validate economic sanity (positive prices, vol, maturity, ...).
    pub fn validate(&self) -> crate::api::error::Result<()> {
        let pos = [
            ("spot", self.spot),
            ("strike", self.strike),
            ("sigma", self.sigma),
            ("maturity", self.maturity),
        ];
        for (name, v) in pos {
            if !(v > 0.0 && v.is_finite()) {
                return Err(CloudshapesError::workload(format!(
                    "task {}: {name} must be positive, got {v}",
                    self.id
                )));
            }
        }
        if self.rate < 0.0 || self.rate > 0.5 {
            return Err(CloudshapesError::workload(format!(
                "task {}: implausible rate {}",
                self.id, self.rate
            )));
        }
        if self.payoff == Payoff::Barrier && self.barrier <= self.spot {
            return Err(CloudshapesError::workload(format!(
                "task {}: up-and-out barrier {} must exceed spot {}",
                self.id, self.barrier, self.spot
            )));
        }
        if self.n_sims == 0 {
            return Err(CloudshapesError::workload(format!(
                "task {}: zero simulations",
                self.id
            )));
        }
        if self.payoff != Payoff::European && self.steps == 0 {
            return Err(CloudshapesError::workload(format!(
                "task {}: path-dependent payoff needs steps",
                self.id
            )));
        }
        // The RNG counter layout reserves STEP_BITS of the second Threefry
        // word for the step index; more steps than that would alias
        // (path, step) counter pairs and bias every merged price. Checked
        // here — at workload validation time — so the kernels' hard assert
        // is never the first thing to notice.
        let step_cap = 1u32 << crate::pricing::mc::STEP_BITS;
        if self.steps >= step_cap {
            return Err(CloudshapesError::workload(format!(
                "task {}: {} steps exceed the RNG counter layout's budget of {step_cap} \
                 (2^{} — see pricing::mc::STEP_BITS)",
                self.id,
                self.steps,
                crate::pricing::mc::STEP_BITS
            )));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn task() -> OptionTask {
        OptionTask {
            id: 0,
            payoff: Payoff::European,
            spot: 100.0,
            strike: 105.0,
            rate: 0.05,
            sigma: 0.2,
            maturity: 1.0,
            barrier: 150.0,
            steps: 1,
            target_accuracy: 0.001,
            n_sims: 1 << 20,
        }
    }

    #[test]
    fn payoff_names_roundtrip() {
        for p in [Payoff::European, Payoff::Asian, Payoff::Barrier] {
            assert_eq!(Payoff::from_name(p.name()), Some(p));
            assert_eq!(Payoff::parse(p.name()).unwrap(), p);
            assert!(Payoff::NAMES.contains(&p.name()));
        }
        assert_eq!(Payoff::from_name("swaption"), None);
    }

    #[test]
    fn parse_rejects_unknown_names_with_a_typed_error() {
        let e = Payoff::parse("swaption").unwrap_err();
        assert_eq!(e.kind(), "workload");
        for name in Payoff::NAMES {
            assert!(e.message().contains(name), "error must list '{name}': {e}");
        }
        assert!(e.message().contains("swaption"), "{e}");
    }

    #[test]
    fn sizing_scales_inverse_square_with_accuracy() {
        let n1 = OptionTask::size_n(Payoff::European, 100.0, 0.2, 1.0, 0.01);
        let n2 = OptionTask::size_n(Payoff::European, 100.0, 0.2, 1.0, 0.005);
        // Halving accuracy quadruples N (modulo clamping).
        assert!((n2 as f64 / n1 as f64 - 4.0).abs() < 0.01, "{n1} {n2}");
    }

    #[test]
    fn sizing_at_paper_accuracy_is_large() {
        // $0.001 on an ATM option needs ~1e9 paths — the paper's tasks run
        // for thousands of seconds, consistent with Table IV.
        let n = OptionTask::size_n(Payoff::European, 100.0, 0.2, 1.0, 0.001);
        assert!(n > 100_000_000, "{n}");
    }

    #[test]
    fn params_layout_matches_kernel_contract() {
        let t = task();
        let p = t.to_params();
        assert_eq!(p[0], 100.0);
        assert_eq!(p[1], 105.0);
        assert_eq!(p[2], 0.05);
        assert_eq!(p[3], 0.2);
        assert_eq!(p[4], 1.0);
        assert_eq!(p[5], 150.0);
        assert_eq!(p[6], 0.0);
        assert_eq!(p[7], 0.0);
    }

    #[test]
    fn flops_scale_with_steps_for_path_dependent() {
        let e = Payoff::European.flops_per_path(1);
        let a64 = Payoff::Asian.flops_per_path(64);
        let a128 = Payoff::Asian.flops_per_path(128);
        assert!(a64 > 10.0 * e);
        assert!((a128 / a64 - 2.0).abs() < 0.05);
    }

    #[test]
    fn validation_catches_nonsense() {
        let mut t = task();
        t.sigma = -0.1;
        assert!(t.validate().is_err());

        let mut t = task();
        t.payoff = Payoff::Barrier;
        t.barrier = 90.0;
        assert!(t.validate().is_err());

        let mut t = task();
        t.n_sims = 0;
        assert!(t.validate().is_err());

        assert!(task().validate().is_ok());
    }

    #[test]
    fn steps_beyond_the_counter_layout_are_a_typed_workload_error() {
        // Regression: this used to be a debug_assert deep in the pricer —
        // release builds silently allowed (path, step) counter collisions.
        use crate::pricing::mc::STEP_BITS;
        let mut t = task();
        t.payoff = Payoff::Asian;
        t.steps = 1 << STEP_BITS;
        let e = t.validate().unwrap_err();
        assert_eq!(e.kind(), "workload");
        assert!(e.message().contains("steps"), "{e}");
        // The boundary itself is the last valid value.
        t.steps = (1 << STEP_BITS) - 1;
        assert!(t.validate().is_ok());
    }

    #[test]
    fn discount_factor() {
        assert!((task().discount() - (-0.05f64).exp()).abs() < 1e-12);
    }
}
