//! Option-pricing task definitions — the paper's atomic, divisible tasks.
//!
//! The parameter-vector layout (`to_params`) is the wire format shared with
//! the L1 Pallas kernels (`python/compile/kernels/mc.py`): any change must
//! be made in both places and re-AOT'd.

use crate::api::error::CloudshapesError;

/// Payoff family — one per kernel variant.
///
/// The first three are the paper's original workload (all of which share a
/// single FLOP-per-step cost line); the exotic families deliberately break
/// that line — LSMC's regression pass, the basket's d-dimensional
/// correlation, Heston's two-factor stepping — so per-family latency models
/// have something to earn their keep on (ROADMAP item 3).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Payoff {
    European,
    Asian,
    Barrier,
    /// American put via Longstaff-Schwartz regression Monte Carlo.
    American,
    /// Equally-weighted call on a correlated multi-asset basket.
    Basket,
    /// European call under Heston stochastic volatility (full-truncation
    /// Euler).
    Heston,
}

impl Payoff {
    pub fn name(&self) -> &'static str {
        match self {
            Payoff::European => "european",
            Payoff::Asian => "asian",
            Payoff::Barrier => "barrier",
            Payoff::American => "american",
            Payoff::Basket => "basket",
            Payoff::Heston => "heston",
        }
    }

    /// Number of payoff families.
    pub const COUNT: usize = 6;

    /// Every payoff family, in declaration order. Derive family lists from
    /// this (never a hand-written array) so new families cannot silently
    /// miss storm/CLI/bench coverage.
    pub const ALL: [Payoff; Payoff::COUNT] = [
        Payoff::European,
        Payoff::Asian,
        Payoff::Barrier,
        Payoff::American,
        Payoff::Basket,
        Payoff::Heston,
    ];

    /// Every payoff family name, in declaration order.
    pub const NAMES: [&'static str; Payoff::COUNT] =
        ["european", "asian", "barrier", "american", "basket", "heston"];

    /// Position in [`ALL`](Payoff::ALL)/[`NAMES`](Payoff::NAMES) — the index
    /// used by per-family model tables and mix-weight arrays.
    pub fn index(&self) -> usize {
        *self as usize
    }

    pub fn from_name(s: &str) -> Option<Payoff> {
        Payoff::ALL.into_iter().find(|p| p.name() == s)
    }

    /// As [`from_name`](Payoff::from_name), but unknown names surface as a
    /// typed [`CloudshapesError::Workload`] listing the valid families —
    /// use this at config-parse and wire boundaries instead of silently
    /// dropping the `None`.
    pub fn parse(s: &str) -> crate::api::error::Result<Payoff> {
        Payoff::from_name(s).ok_or_else(|| {
            CloudshapesError::workload(format!(
                "unknown payoff '{s}' (valid: {})",
                Payoff::NAMES.join(", ")
            ))
        })
    }

    /// The generator mix weights that select exactly this family — shared
    /// by every "single-payoff workload" surface (`[workload] payoff`, the
    /// serve `submit` op) so the mapping lives in one place.
    pub fn one_hot_mix(&self) -> [f64; Payoff::COUNT] {
        let mut mix = [0.0; Payoff::COUNT];
        mix[self.index()] = 1.0;
        mix
    }

    /// Threefry counter words one path consumes in the second-word step
    /// field: the kernels index sub-draws as `hi | sub` with
    /// `sub < 2^STEP_BITS`, so this must stay under the layout budget
    /// (checked by [`OptionTask::validate`]).
    pub fn counter_words_per_path(&self, steps: u32, assets: u32) -> u64 {
        match self {
            Payoff::European => 1,
            Payoff::Asian | Payoff::Barrier | Payoff::American => steps as u64,
            Payoff::Basket => steps as u64 * assets as u64,
            Payoff::Heston => 2 * steps as u64,
        }
    }

    /// Approximate floating-point operations per simulated path, used to
    /// translate device GFLOPS into a Monte Carlo throughput (β). Counts the
    /// Threefry rounds (~90 ALU ops per draw), Box-Muller, and the
    /// family-specific path update: LSMC adds the per-step regression
    /// evaluation and exercise test, the basket pays `assets` draws plus an
    /// O(assets²) Cholesky correlation per step, Heston draws two normals
    /// and advances two factors per step.
    pub fn flops_per_path(&self, steps: u32, assets: u32) -> f64 {
        const RNG_FLOPS: f64 = 130.0; // threefry-20rounds + box-muller
        const STEP_FLOPS: f64 = 12.0; // exp/log-spot update, accumulate
        let m = steps as f64;
        let d = assets as f64;
        match self {
            Payoff::European => RNG_FLOPS + 25.0,
            Payoff::Asian | Payoff::Barrier => m * (RNG_FLOPS + STEP_FLOPS) + 25.0,
            // Regression basis evaluation + exercise test per date, plus the
            // (amortised) pilot regression pass.
            Payoff::American => m * (RNG_FLOPS + STEP_FLOPS + 18.0) + 90.0,
            // d draws per step plus the O(d²) lower-triangular correlation.
            Payoff::Basket => m * d * (RNG_FLOPS + STEP_FLOPS) + m * 2.0 * d * d + 25.0,
            // Two draws and two factor updates (spot, variance) per step.
            Payoff::Heston => m * (2.0 * RNG_FLOPS + 40.0) + 25.0,
        }
    }
}

/// One option-pricing task. Monetary values in $, times in years.
#[derive(Debug, Clone, PartialEq)]
pub struct OptionTask {
    pub id: usize,
    pub payoff: Payoff,
    pub spot: f64,
    pub strike: f64,
    pub rate: f64,
    pub sigma: f64,
    pub maturity: f64,
    /// Knock-out level (Barrier payoff only; ignored otherwise).
    pub barrier: f64,
    /// Fixing/monitoring/exercise dates for path-dependent payoffs.
    pub steps: u32,
    /// Basket dimension (Basket payoff only; 1 otherwise).
    pub assets: u32,
    /// Pairwise asset correlation (Basket) or spot–variance correlation ρ
    /// (Heston); ignored by the single-factor lognormal families.
    pub correlation: f64,
    /// Heston mean-reversion speed κ.
    pub kappa: f64,
    /// Heston long-run variance θ.
    pub theta: f64,
    /// Heston vol-of-vol ξ.
    pub xi: f64,
    /// Heston initial variance v₀.
    pub v0: f64,
    /// Half-width of the 95% confidence interval the task must reach, $.
    pub target_accuracy: f64,
    /// Simulations required to reach `target_accuracy` (the task's N).
    pub n_sims: u64,
}

impl Default for OptionTask {
    /// A valid ATM European call — the `..OptionTask::default()` base that
    /// keeps task literals short now that exotic families carry extra
    /// parameters most tasks never read.
    fn default() -> Self {
        OptionTask {
            id: 0,
            payoff: Payoff::European,
            spot: 100.0,
            strike: 100.0,
            rate: 0.05,
            sigma: 0.2,
            maturity: 1.0,
            barrier: 0.0,
            steps: 1,
            assets: 1,
            correlation: 0.0,
            kappa: 1.5,
            theta: 0.04,
            xi: 0.5,
            v0: 0.04,
            target_accuracy: 0.01,
            n_sims: 1 << 16,
        }
    }
}

/// Largest supported basket dimension (per-step scratch arrays are
/// stack-sized to this in the kernels).
pub const MAX_BASKET_ASSETS: u32 = 8;

impl OptionTask {
    /// Size a task's N from its accuracy target via the CLT:
    /// `N = (z·σ_payoff / ε)²` with z = 1.96.
    ///
    /// The payoff standard deviation is approximated analytically (ATM
    /// lognormal dispersion `s0·σ√T` scaled by a payoff-family factor);
    /// the paper sizes N "so as to achieve an accuracy of $0.001" the same
    /// way — from pre-run estimates, not pilot runs.
    pub fn size_n(payoff: Payoff, spot: f64, sigma: f64, maturity: f64, accuracy: f64) -> u64 {
        let family_factor = match payoff {
            Payoff::European => 0.8,
            Payoff::Asian => 0.5,   // averaging shrinks dispersion
            Payoff::Barrier => 0.9, // knock-out adds dispersion near the barrier
            Payoff::American => 0.9, // early exercise truncates the left tail only
            Payoff::Basket => 0.6,  // cross-asset averaging shrinks dispersion
            Payoff::Heston => 1.0,  // stochastic vol fattens the tails
        };
        let payoff_std = family_factor * spot * sigma * maturity.sqrt();
        let z = 1.96;
        let n = ((z * payoff_std / accuracy).powi(2)).ceil() as u64;
        n.clamp(1 << 16, 1 << 34)
    }

    /// The f32[8] parameter vector the AOT kernels take (original three
    /// families only — the exotic families have no AOT variants yet and are
    /// priced by the native kernels).
    pub fn to_params(&self) -> [f32; 8] {
        [
            self.spot as f32,
            self.strike as f32,
            self.rate as f32,
            self.sigma as f32,
            self.maturity as f32,
            self.barrier as f32,
            0.0,
            0.0,
        ]
    }

    /// Discount factor for this task's payoff statistics.
    pub fn discount(&self) -> f64 {
        (-self.rate * self.maturity).exp()
    }

    /// FLOPs of one simulated path of this task.
    pub fn flops_per_path(&self) -> f64 {
        self.payoff.flops_per_path(self.steps, self.assets)
    }

    /// Total FLOPs of the whole task.
    pub fn total_flops(&self) -> f64 {
        self.flops_per_path() * self.n_sims as f64
    }

    /// Validate economic sanity (positive prices, vol, maturity, ...).
    pub fn validate(&self) -> crate::api::error::Result<()> {
        let pos = [
            ("spot", self.spot),
            ("strike", self.strike),
            ("sigma", self.sigma),
            ("maturity", self.maturity),
        ];
        for (name, v) in pos {
            if !(v > 0.0 && v.is_finite()) {
                return Err(CloudshapesError::workload(format!(
                    "task {}: {name} must be positive, got {v}",
                    self.id
                )));
            }
        }
        if self.rate < 0.0 || self.rate > 0.5 {
            return Err(CloudshapesError::workload(format!(
                "task {}: implausible rate {}",
                self.id, self.rate
            )));
        }
        if self.payoff == Payoff::Barrier && self.barrier <= self.spot {
            return Err(CloudshapesError::workload(format!(
                "task {}: up-and-out barrier {} must exceed spot {}",
                self.id, self.barrier, self.spot
            )));
        }
        if self.payoff == Payoff::Basket {
            if !(2..=MAX_BASKET_ASSETS).contains(&self.assets) {
                return Err(CloudshapesError::workload(format!(
                    "task {}: basket needs 2..={MAX_BASKET_ASSETS} assets, got {}",
                    self.id, self.assets
                )));
            }
            // Equicorrelation matrices are positive-definite only above
            // -1/(d-1); at or below it the Cholesky factorisation fails.
            let rho_min = -1.0 / (self.assets as f64 - 1.0);
            if !(self.correlation > rho_min && self.correlation < 1.0) {
                return Err(CloudshapesError::workload(format!(
                    "task {}: basket correlation {} outside ({rho_min:.4}, 1) \
                     for {} assets",
                    self.id, self.correlation, self.assets
                )));
            }
        }
        if self.payoff == Payoff::Heston {
            let pos_h = [("kappa", self.kappa), ("theta", self.theta), ("v0", self.v0)];
            for (name, v) in pos_h {
                if !(v > 0.0 && v.is_finite()) {
                    return Err(CloudshapesError::workload(format!(
                        "task {}: heston {name} must be positive, got {v}",
                        self.id
                    )));
                }
            }
            if !(self.xi >= 0.0 && self.xi.is_finite()) {
                return Err(CloudshapesError::workload(format!(
                    "task {}: heston xi must be non-negative, got {}",
                    self.id, self.xi
                )));
            }
            if !(self.correlation > -1.0 && self.correlation < 1.0) {
                return Err(CloudshapesError::workload(format!(
                    "task {}: heston correlation {} outside (-1, 1)",
                    self.id, self.correlation
                )));
            }
        }
        if self.n_sims == 0 {
            return Err(CloudshapesError::workload(format!(
                "task {}: zero simulations",
                self.id
            )));
        }
        if self.payoff != Payoff::European && self.steps == 0 {
            return Err(CloudshapesError::workload(format!(
                "task {}: path-dependent payoff needs steps",
                self.id
            )));
        }
        // The RNG counter layout reserves STEP_BITS of the second Threefry
        // word for the per-path sub-draw index; more draws than that would
        // alias (path, draw) counter pairs and bias every merged price.
        // Families with several draws per step (basket assets, Heston's two
        // factors) consume the budget proportionally faster — checked here,
        // at workload validation time, so the kernels' hard assert is never
        // the first thing to notice.
        let step_cap = 1u64 << crate::pricing::mc::STEP_BITS;
        let words = self.payoff.counter_words_per_path(self.steps, self.assets);
        if words >= step_cap {
            return Err(CloudshapesError::workload(format!(
                "task {}: {} counter words per path ({} steps) exceed the RNG \
                 counter layout's budget of {step_cap} (2^{} — see \
                 pricing::mc::STEP_BITS)",
                self.id,
                words,
                self.steps,
                crate::pricing::mc::STEP_BITS
            )));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn task() -> OptionTask {
        OptionTask {
            id: 0,
            payoff: Payoff::European,
            spot: 100.0,
            strike: 105.0,
            rate: 0.05,
            sigma: 0.2,
            maturity: 1.0,
            barrier: 150.0,
            steps: 1,
            target_accuracy: 0.001,
            n_sims: 1 << 20,
            ..OptionTask::default()
        }
    }

    #[test]
    fn payoff_names_roundtrip() {
        for p in Payoff::ALL {
            assert_eq!(Payoff::from_name(p.name()), Some(p));
            assert_eq!(Payoff::parse(p.name()).unwrap(), p);
            assert!(Payoff::NAMES.contains(&p.name()));
        }
        assert_eq!(Payoff::from_name("swaption"), None);
    }

    /// Compile-time-ish exhaustiveness: this match has no wildcard arm, so
    /// adding a `Payoff` variant without growing `ALL`/`NAMES`/`index` (and
    /// every per-family table keyed by them) fails to compile here first.
    #[test]
    fn family_tables_are_exhaustive() {
        for (i, p) in Payoff::ALL.into_iter().enumerate() {
            let expected_name = match p {
                Payoff::European => "european",
                Payoff::Asian => "asian",
                Payoff::Barrier => "barrier",
                Payoff::American => "american",
                Payoff::Basket => "basket",
                Payoff::Heston => "heston",
            };
            assert_eq!(p.name(), expected_name);
            assert_eq!(p.index(), i, "ALL order must match index()");
            assert_eq!(Payoff::NAMES[i], p.name(), "NAMES order must match ALL");
            let mix = p.one_hot_mix();
            assert_eq!(mix[i], 1.0);
            assert_eq!(mix.iter().sum::<f64>(), 1.0);
        }
        assert_eq!(Payoff::ALL.len(), Payoff::COUNT);
        assert_eq!(Payoff::NAMES.len(), Payoff::COUNT);
    }

    #[test]
    fn parse_rejects_unknown_names_with_a_typed_error() {
        let e = Payoff::parse("swaption").unwrap_err();
        assert_eq!(e.kind(), "workload");
        for name in Payoff::NAMES {
            assert!(e.message().contains(name), "error must list '{name}': {e}");
        }
        assert!(e.message().contains("swaption"), "{e}");
    }

    #[test]
    fn sizing_scales_inverse_square_with_accuracy() {
        let n1 = OptionTask::size_n(Payoff::European, 100.0, 0.2, 1.0, 0.01);
        let n2 = OptionTask::size_n(Payoff::European, 100.0, 0.2, 1.0, 0.005);
        // Halving accuracy quadruples N (modulo clamping).
        assert!((n2 as f64 / n1 as f64 - 4.0).abs() < 0.01, "{n1} {n2}");
    }

    #[test]
    fn sizing_at_paper_accuracy_is_large() {
        // $0.001 on an ATM option needs ~1e9 paths — the paper's tasks run
        // for thousands of seconds, consistent with Table IV.
        let n = OptionTask::size_n(Payoff::European, 100.0, 0.2, 1.0, 0.001);
        assert!(n > 100_000_000, "{n}");
    }

    #[test]
    fn params_layout_matches_kernel_contract() {
        let t = task();
        let p = t.to_params();
        assert_eq!(p[0], 100.0);
        assert_eq!(p[1], 105.0);
        assert_eq!(p[2], 0.05);
        assert_eq!(p[3], 0.2);
        assert_eq!(p[4], 1.0);
        assert_eq!(p[5], 150.0);
        assert_eq!(p[6], 0.0);
        assert_eq!(p[7], 0.0);
    }

    #[test]
    fn flops_scale_with_steps_for_path_dependent() {
        let e = Payoff::European.flops_per_path(1, 1);
        let a64 = Payoff::Asian.flops_per_path(64, 1);
        let a128 = Payoff::Asian.flops_per_path(128, 1);
        assert!(a64 > 10.0 * e);
        assert!((a128 / a64 - 2.0).abs() < 0.05);
    }

    #[test]
    fn exotic_families_break_the_single_cost_line() {
        // At the same step count, each exotic family's per-path cost sits on
        // its own line — this spread is exactly what per-family latency
        // models exist to capture.
        let barrier = Payoff::Barrier.flops_per_path(64, 1);
        let american = Payoff::American.flops_per_path(64, 1);
        let basket4 = Payoff::Basket.flops_per_path(64, 4);
        let heston = Payoff::Heston.flops_per_path(64, 1);
        assert!(american > barrier);
        assert!(heston > 1.5 * barrier, "{heston} vs {barrier}");
        assert!(basket4 > 3.5 * barrier, "{basket4} vs {barrier}");
        // Basket cost grows with dimension.
        assert!(
            Payoff::Basket.flops_per_path(64, 8) > 1.9 * Payoff::Basket.flops_per_path(64, 4)
        );
    }

    #[test]
    fn validation_catches_nonsense() {
        let mut t = task();
        t.sigma = -0.1;
        assert!(t.validate().is_err());

        let mut t = task();
        t.payoff = Payoff::Barrier;
        t.barrier = 90.0;
        assert!(t.validate().is_err());

        let mut t = task();
        t.n_sims = 0;
        assert!(t.validate().is_err());

        assert!(task().validate().is_ok());
    }

    #[test]
    fn validation_checks_exotic_parameters() {
        // Basket: dimension bounds and correlation feasibility.
        let mut t = task();
        t.payoff = Payoff::Basket;
        t.steps = 16;
        t.assets = 1;
        assert!(t.validate().is_err(), "basket of one asset");
        t.assets = MAX_BASKET_ASSETS + 1;
        assert!(t.validate().is_err(), "basket too wide");
        t.assets = 4;
        t.correlation = -0.5; // below -1/(d-1) = -1/3: not positive-definite
        assert!(t.validate().is_err(), "infeasible equicorrelation");
        t.correlation = 1.0;
        assert!(t.validate().is_err(), "degenerate rho = 1");
        t.correlation = 0.5;
        assert!(t.validate().is_ok());

        // Heston: positive variance parameters, correlation in (-1, 1).
        let mut t = task();
        t.payoff = Payoff::Heston;
        t.steps = 64;
        t.correlation = -0.7;
        assert!(t.validate().is_ok());
        t.v0 = 0.0;
        assert!(t.validate().is_err(), "zero initial variance");
        t.v0 = 0.04;
        t.xi = -0.1;
        assert!(t.validate().is_err(), "negative vol-of-vol");
        t.xi = 0.5;
        t.correlation = -1.0;
        assert!(t.validate().is_err(), "perfect anti-correlation");
    }

    #[test]
    fn steps_beyond_the_counter_layout_are_a_typed_workload_error() {
        // Regression: this used to be a debug_assert deep in the pricer —
        // release builds silently allowed (path, step) counter collisions.
        use crate::pricing::mc::STEP_BITS;
        let mut t = task();
        t.payoff = Payoff::Asian;
        t.steps = 1 << STEP_BITS;
        let e = t.validate().unwrap_err();
        assert_eq!(e.kind(), "workload");
        assert!(e.message().contains("steps"), "{e}");
        // The boundary itself is the last valid value.
        t.steps = (1 << STEP_BITS) - 1;
        assert!(t.validate().is_ok());
    }

    #[test]
    fn counter_budget_scales_with_draws_per_step() {
        use crate::pricing::mc::STEP_BITS;
        // Heston consumes two counter words per step, so its step budget is
        // half the single-factor one.
        let mut t = task();
        t.payoff = Payoff::Heston;
        t.correlation = -0.5;
        t.steps = 1 << (STEP_BITS - 1);
        assert!(t.validate().is_err());
        t.steps = (1 << (STEP_BITS - 1)) - 1;
        assert!(t.validate().is_ok());
        // A 4-asset basket consumes four words per step.
        let mut t = task();
        t.payoff = Payoff::Basket;
        t.assets = 4;
        t.correlation = 0.3;
        t.steps = 1 << (STEP_BITS - 2);
        assert!(t.validate().is_err());
        t.steps = (1 << (STEP_BITS - 2)) - 1;
        assert!(t.validate().is_ok());
    }

    #[test]
    fn discount_factor() {
        assert!((task().discount() - (-0.05f64).exp()).abs() < 1e-12);
    }
}
