//! Reproducible workload generator modelled on the Kaiserslautern option
//! pricing benchmark (the paper's task source, §IV.A.1).
//!
//! The benchmark's public URL is dead; what the paper uses it for is a
//! realistic *spread* of task parameters ("generated from within the values
//! from the Kaiserslautern option pricing benchmark") and the $0.001
//! accuracy target that sizes each task's N. This generator reproduces those
//! properties deterministically from a seed — see DESIGN.md §2.
//!
//! Exotic families (american/basket/heston) draw their extra parameters
//! *conditionally*: a config whose mix gives them zero weight consumes the
//! exact RNG stream the original three-family generator consumed, so every
//! seed-pinned legacy workload stays bit-identical.

use crate::api::error::{CloudshapesError, Result};
use crate::util::rng::Rng;

use super::option::{OptionTask, Payoff, MAX_BASKET_ASSETS};
use super::Workload;

/// Generation parameters. Defaults reproduce the paper's setup: 128 tasks,
/// $0.001 accuracy, payoff mix dominated by path-dependent options with
/// daily-ish fixing grids.
#[derive(Debug, Clone)]
pub struct GeneratorConfig {
    pub n_tasks: usize,
    pub seed: u64,
    /// CI half-width each task must reach, $.
    pub accuracy: f64,
    /// Mix weights, indexed by [`Payoff::index`] (declaration order of
    /// [`Payoff::ALL`]); need not be normalised.
    pub payoff_mix: [f64; Payoff::COUNT],
    /// Fixing-date choices for path-dependent payoffs.
    pub step_choices: Vec<u32>,
    /// Basket dimension for basket tasks.
    pub basket_assets: u32,
    /// Pairwise asset correlation for basket tasks.
    pub basket_rho: f64,
    /// Heston mean-reversion speed κ.
    pub heston_kappa: f64,
    /// Heston long-run variance θ.
    pub heston_theta: f64,
    /// Heston vol-of-vol ξ.
    pub heston_xi: f64,
    /// Heston spot–variance correlation ρ (equity-like: negative).
    pub heston_rho: f64,
}

impl Default for GeneratorConfig {
    fn default() -> Self {
        GeneratorConfig {
            n_tasks: 128,
            seed: 2015,
            accuracy: 0.001,
            payoff_mix: [0.25, 0.45, 0.30, 0.0, 0.0, 0.0],
            step_choices: vec![256, 512],
            basket_assets: 4,
            basket_rho: 0.5,
            heston_kappa: 1.5,
            heston_theta: 0.04,
            heston_xi: 0.5,
            heston_rho: -0.7,
        }
    }
}

impl GeneratorConfig {
    /// A paper-scale workload scaled down for quick runs / native execution.
    pub fn small(n_tasks: usize, accuracy: f64, seed: u64) -> GeneratorConfig {
        GeneratorConfig {
            n_tasks,
            seed,
            accuracy,
            step_choices: vec![64],
            ..GeneratorConfig::default()
        }
    }

    /// Validate the generation parameters. Negative or non-finite payoff
    /// weights, and an all-zero mix, would silently skew (or wedge) the
    /// sampling below — reject them as typed workload errors instead.
    pub fn validate(&self) -> Result<()> {
        for (name, w) in Payoff::NAMES.iter().zip(self.payoff_mix) {
            if !(w >= 0.0 && w.is_finite()) {
                return Err(CloudshapesError::workload(format!(
                    "payoff_mix: {name} weight must be a non-negative finite number, got {w}"
                )));
            }
        }
        if self.payoff_mix.iter().sum::<f64>() <= 0.0 {
            return Err(CloudshapesError::workload(
                "payoff_mix must have positive total weight (all weights are zero)",
            ));
        }
        if self.step_choices.is_empty() {
            return Err(CloudshapesError::workload(
                "step_choices must offer at least one fixing grid",
            ));
        }
        if !(self.accuracy > 0.0 && self.accuracy.is_finite()) {
            return Err(CloudshapesError::workload(format!(
                "accuracy must be a positive CI half-width, got {}",
                self.accuracy
            )));
        }
        // Exotic parameters are validated only when the mix can produce the
        // family — a legacy config with a nonsense (unused) basket knob must
        // not start failing.
        if self.payoff_mix[Payoff::Basket.index()] > 0.0 {
            if !(2..=MAX_BASKET_ASSETS).contains(&self.basket_assets) {
                return Err(CloudshapesError::workload(format!(
                    "basket_assets must be 2..={MAX_BASKET_ASSETS}, got {}",
                    self.basket_assets
                )));
            }
            let rho_min = -1.0 / (self.basket_assets as f64 - 1.0);
            if !(self.basket_rho > rho_min && self.basket_rho < 1.0) {
                return Err(CloudshapesError::workload(format!(
                    "basket_rho {} outside ({rho_min:.4}, 1) for {} assets",
                    self.basket_rho, self.basket_assets
                )));
            }
        }
        if self.payoff_mix[Payoff::Heston.index()] > 0.0 {
            for (name, v) in [
                ("heston_kappa", self.heston_kappa),
                ("heston_theta", self.heston_theta),
            ] {
                if !(v > 0.0 && v.is_finite()) {
                    return Err(CloudshapesError::workload(format!(
                        "{name} must be positive, got {v}"
                    )));
                }
            }
            if !(self.heston_xi >= 0.0 && self.heston_xi.is_finite()) {
                return Err(CloudshapesError::workload(format!(
                    "heston_xi must be non-negative, got {}",
                    self.heston_xi
                )));
            }
            if !(self.heston_rho > -1.0 && self.heston_rho < 1.0) {
                return Err(CloudshapesError::workload(format!(
                    "heston_rho {} outside (-1, 1)",
                    self.heston_rho
                )));
            }
        }
        Ok(())
    }
}

/// As [`generate`], validating the config first — the library-boundary
/// entry point ([`Experiment::build`](crate::report::Experiment) and the
/// config parser route through the same validation).
pub fn try_generate(cfg: &GeneratorConfig) -> Result<Workload> {
    cfg.validate()?;
    Ok(generate(cfg))
}

/// Generate a workload. Deterministic in the config (same seed, same tasks).
/// Panics on invalid configs — use [`try_generate`] (or
/// [`GeneratorConfig::validate`]) on untrusted input.
pub fn generate(cfg: &GeneratorConfig) -> Workload {
    cfg.validate().expect("invalid generator config");
    let mut rng = Rng::new(cfg.seed);
    let total_w: f64 = cfg.payoff_mix.iter().sum();
    // Fall-through family when fp rounding pushes the draw past the last
    // positive cumulative weight: the last family with positive weight
    // (matches the old three-family `else` branch exactly).
    let last_positive = Payoff::ALL
        .into_iter()
        .rev()
        .find(|p| cfg.payoff_mix[p.index()] > 0.0)
        .expect("validated mix has positive weight");
    let mut tasks = Vec::with_capacity(cfg.n_tasks);
    for id in 0..cfg.n_tasks {
        let draw = rng.f64() * total_w;
        let mut payoff = last_positive;
        let mut acc = 0.0;
        for p in Payoff::ALL {
            acc += cfg.payoff_mix[p.index()];
            if draw < acc {
                payoff = p;
                break;
            }
        }
        // Kaiserslautern-style market parameter ranges.
        let spot = rng.range_f64(80.0, 120.0);
        let strike = spot * rng.range_f64(0.8, 1.2);
        let rate = rng.range_f64(0.01, 0.05);
        let mut sigma = rng.range_f64(0.10, 0.45);
        let maturity = rng.range_f64(0.25, 2.0);
        let barrier = spot * rng.range_f64(1.15, 1.6);
        let steps = if payoff == Payoff::European {
            1
        } else {
            *rng.choose(&cfg.step_choices)
        };
        // Exotic parameters — drawn *conditionally* so legacy mixes consume
        // the identical RNG stream (see module docs).
        let mut task = OptionTask {
            id,
            payoff,
            spot,
            strike,
            rate,
            sigma,
            maturity,
            barrier,
            steps,
            target_accuracy: cfg.accuracy,
            n_sims: 0,
            ..OptionTask::default()
        };
        match payoff {
            Payoff::Basket => {
                task.assets = cfg.basket_assets;
                task.correlation = cfg.basket_rho;
                // Keep every basket path inside the counter-word budget
                // regardless of the configured fixing grid.
                let word_cap = (1u64 << crate::pricing::mc::STEP_BITS) - 1;
                let step_cap = (word_cap / cfg.basket_assets as u64).max(1) as u32;
                task.steps = steps.min(step_cap);
            }
            Payoff::Heston => {
                task.kappa = cfg.heston_kappa;
                task.theta = cfg.heston_theta;
                task.xi = cfg.heston_xi;
                task.correlation = cfg.heston_rho;
                task.v0 = cfg.heston_theta * rng.range_f64(0.5, 1.5);
                // Heston's vol comes from v₀/θ, not the lognormal draw;
                // keep `sigma` as the effective initial vol so N-sizing and
                // FLOP accounting see the right dispersion scale.
                sigma = task.v0.sqrt();
                task.sigma = sigma;
                let step_cap = ((1u64 << crate::pricing::mc::STEP_BITS) / 2 - 1) as u32;
                task.steps = steps.min(step_cap);
            }
            _ => {}
        }
        task.n_sims = OptionTask::size_n(payoff, spot, sigma, maturity, cfg.accuracy);
        debug_assert!(task.validate().is_ok(), "{:?}", task.validate());
        tasks.push(task);
    }
    Workload::new(tasks)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_in_seed() {
        let a = generate(&GeneratorConfig::default());
        let b = generate(&GeneratorConfig::default());
        assert_eq!(a.tasks, b.tasks);
        let c = generate(&GeneratorConfig { seed: 1, ..GeneratorConfig::default() });
        assert_ne!(a.tasks, c.tasks);
    }

    #[test]
    fn default_matches_paper_shape() {
        let w = generate(&GeneratorConfig::default());
        assert_eq!(w.tasks.len(), 128);
        for t in &w.tasks {
            assert!(t.validate().is_ok());
        }
        // The paper's three payoff families present (the default mix gives
        // the exotics zero weight — legacy seed streams stay bit-identical).
        for p in [Payoff::European, Payoff::Asian, Payoff::Barrier] {
            assert!(w.tasks.iter().any(|t| t.payoff == p), "missing {p:?}");
        }
        assert!(w.tasks.iter().all(|t| {
            !matches!(t.payoff, Payoff::American | Payoff::Basket | Payoff::Heston)
        }));
        // Work sizes spread over at least an order of magnitude.
        let flops: Vec<f64> = w.tasks.iter().map(|t| t.total_flops()).collect();
        let max = flops.iter().cloned().fold(0.0, f64::max);
        let min = flops.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(max / min > 10.0, "spread {max}/{min}");
    }

    #[test]
    fn small_config_is_cheap() {
        let w = generate(&GeneratorConfig::small(8, 0.05, 3));
        assert_eq!(w.tasks.len(), 8);
        for t in &w.tasks {
            assert!(t.n_sims <= 1 << 23, "task too big for native runs: {}", t.n_sims);
        }
    }

    #[test]
    fn mix_weights_respected() {
        let cfg = GeneratorConfig {
            payoff_mix: Payoff::European.one_hot_mix(),
            ..GeneratorConfig::default()
        };
        let w = generate(&cfg);
        assert!(w.tasks.iter().all(|t| t.payoff == Payoff::European));
    }

    #[test]
    fn every_family_generates_valid_tasks() {
        for p in Payoff::ALL {
            let cfg = GeneratorConfig {
                payoff_mix: p.one_hot_mix(),
                ..GeneratorConfig::small(6, 0.05, 17)
            };
            let w = try_generate(&cfg).unwrap();
            assert_eq!(w.tasks.len(), 6);
            for t in &w.tasks {
                assert_eq!(t.payoff, p);
                assert!(t.validate().is_ok(), "{:?}", t.validate());
            }
        }
    }

    #[test]
    fn uniform_mix_produces_every_family() {
        let cfg = GeneratorConfig {
            payoff_mix: [1.0; Payoff::COUNT],
            ..GeneratorConfig::small(96, 0.05, 5)
        };
        let w = generate(&cfg);
        for p in Payoff::ALL {
            assert!(w.tasks.iter().any(|t| t.payoff == p), "missing {p:?}");
        }
    }

    #[test]
    fn legacy_mixes_are_stream_compatible() {
        // Adding zero-weight exotic families must not perturb the tasks a
        // legacy three-family config generates (seed-pinned goldens, Table
        // II reproduction and the differential harness all rely on this).
        let legacy = generate(&GeneratorConfig::default());
        let padded = generate(&GeneratorConfig {
            basket_assets: 5,
            heston_xi: 0.9,
            ..GeneratorConfig::default()
        });
        assert_eq!(legacy.tasks, padded.tasks);
    }

    #[test]
    fn bad_payoff_mixes_are_workload_errors() {
        let mixes: [[f64; Payoff::COUNT]; 3] = [
            [0.0; Payoff::COUNT],
            [-1.0, 0.5, 0.5, 0.0, 0.0, 0.0],
            [f64::NAN, 1.0, 1.0, 0.0, 0.0, 0.0],
        ];
        for mix in mixes {
            let cfg = GeneratorConfig { payoff_mix: mix, ..GeneratorConfig::default() };
            let e = try_generate(&cfg).unwrap_err();
            assert_eq!(e.kind(), "workload", "{mix:?} -> {e}");
        }
        let cfg = GeneratorConfig { step_choices: vec![], ..GeneratorConfig::default() };
        assert_eq!(try_generate(&cfg).unwrap_err().kind(), "workload");
        let cfg = GeneratorConfig { accuracy: 0.0, ..GeneratorConfig::default() };
        assert_eq!(try_generate(&cfg).unwrap_err().kind(), "workload");
        assert!(try_generate(&GeneratorConfig::default()).is_ok());
    }

    #[test]
    fn bad_exotic_knobs_error_only_when_reachable() {
        // Nonsense basket knobs are ignored while the mix can't reach them…
        let cfg = GeneratorConfig { basket_assets: 1, ..GeneratorConfig::default() };
        assert!(try_generate(&cfg).is_ok());
        // …and typed workload errors once it can.
        let cfg = GeneratorConfig {
            basket_assets: 1,
            payoff_mix: Payoff::Basket.one_hot_mix(),
            ..GeneratorConfig::default()
        };
        assert_eq!(try_generate(&cfg).unwrap_err().kind(), "workload");
        let cfg = GeneratorConfig {
            heston_rho: 1.5,
            payoff_mix: Payoff::Heston.one_hot_mix(),
            ..GeneratorConfig::default()
        };
        assert_eq!(try_generate(&cfg).unwrap_err().kind(), "workload");
    }
}
