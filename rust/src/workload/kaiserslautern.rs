//! Reproducible workload generator modelled on the Kaiserslautern option
//! pricing benchmark (the paper's task source, §IV.A.1).
//!
//! The benchmark's public URL is dead; what the paper uses it for is a
//! realistic *spread* of task parameters ("generated from within the values
//! from the Kaiserslautern option pricing benchmark") and the $0.001
//! accuracy target that sizes each task's N. This generator reproduces those
//! properties deterministically from a seed — see DESIGN.md §2.

use crate::util::rng::Rng;

use super::option::{OptionTask, Payoff};
use super::Workload;

/// Generation parameters. Defaults reproduce the paper's setup: 128 tasks,
/// $0.001 accuracy, payoff mix dominated by path-dependent options with
/// daily-ish fixing grids.
#[derive(Debug, Clone)]
pub struct GeneratorConfig {
    pub n_tasks: usize,
    pub seed: u64,
    /// CI half-width each task must reach, $.
    pub accuracy: f64,
    /// Mix weights (european, asian, barrier); need not be normalised.
    pub payoff_mix: (f64, f64, f64),
    /// Fixing-date choices for path-dependent payoffs.
    pub step_choices: Vec<u32>,
}

impl Default for GeneratorConfig {
    fn default() -> Self {
        GeneratorConfig {
            n_tasks: 128,
            seed: 2015,
            accuracy: 0.001,
            payoff_mix: (0.25, 0.45, 0.30),
            step_choices: vec![256, 512],
        }
    }
}

impl GeneratorConfig {
    /// A paper-scale workload scaled down for quick runs / native execution.
    pub fn small(n_tasks: usize, accuracy: f64, seed: u64) -> GeneratorConfig {
        GeneratorConfig {
            n_tasks,
            seed,
            accuracy,
            step_choices: vec![64],
            ..GeneratorConfig::default()
        }
    }
}

/// Generate a workload. Deterministic in the config (same seed, same tasks).
pub fn generate(cfg: &GeneratorConfig) -> Workload {
    let mut rng = Rng::new(cfg.seed);
    let (we, wa, wb) = cfg.payoff_mix;
    let total_w = we + wa + wb;
    assert!(total_w > 0.0, "payoff mix must have positive weight");
    let mut tasks = Vec::with_capacity(cfg.n_tasks);
    for id in 0..cfg.n_tasks {
        let draw = rng.f64() * total_w;
        let payoff = if draw < we {
            Payoff::European
        } else if draw < we + wa {
            Payoff::Asian
        } else {
            Payoff::Barrier
        };
        // Kaiserslautern-style market parameter ranges.
        let spot = rng.range_f64(80.0, 120.0);
        let strike = spot * rng.range_f64(0.8, 1.2);
        let rate = rng.range_f64(0.01, 0.05);
        let sigma = rng.range_f64(0.10, 0.45);
        let maturity = rng.range_f64(0.25, 2.0);
        let barrier = spot * rng.range_f64(1.15, 1.6);
        let steps = if payoff == Payoff::European {
            1
        } else {
            *rng.choose(&cfg.step_choices)
        };
        let n_sims = OptionTask::size_n(payoff, spot, sigma, maturity, cfg.accuracy);
        let task = OptionTask {
            id,
            payoff,
            spot,
            strike,
            rate,
            sigma,
            maturity,
            barrier,
            steps,
            target_accuracy: cfg.accuracy,
            n_sims,
        };
        debug_assert!(task.validate().is_ok(), "{:?}", task.validate());
        tasks.push(task);
    }
    Workload::new(tasks)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_in_seed() {
        let a = generate(&GeneratorConfig::default());
        let b = generate(&GeneratorConfig::default());
        assert_eq!(a.tasks, b.tasks);
        let c = generate(&GeneratorConfig { seed: 1, ..GeneratorConfig::default() });
        assert_ne!(a.tasks, c.tasks);
    }

    #[test]
    fn default_matches_paper_shape() {
        let w = generate(&GeneratorConfig::default());
        assert_eq!(w.tasks.len(), 128);
        for t in &w.tasks {
            assert!(t.validate().is_ok());
        }
        // All three payoff families present.
        for p in [Payoff::European, Payoff::Asian, Payoff::Barrier] {
            assert!(w.tasks.iter().any(|t| t.payoff == p), "missing {p:?}");
        }
        // Work sizes spread over at least an order of magnitude.
        let flops: Vec<f64> = w.tasks.iter().map(|t| t.total_flops()).collect();
        let max = flops.iter().cloned().fold(0.0, f64::max);
        let min = flops.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(max / min > 10.0, "spread {max}/{min}");
    }

    #[test]
    fn small_config_is_cheap() {
        let w = generate(&GeneratorConfig::small(8, 0.05, 3));
        assert_eq!(w.tasks.len(), 8);
        for t in &w.tasks {
            assert!(t.n_sims <= 1 << 23, "task too big for native runs: {}", t.n_sims);
        }
    }

    #[test]
    fn mix_weights_respected() {
        let cfg = GeneratorConfig {
            payoff_mix: (1.0, 0.0, 0.0),
            ..GeneratorConfig::default()
        };
        let w = generate(&cfg);
        assert!(w.tasks.iter().all(|t| t.payoff == Payoff::European));
    }
}
