//! Reproducible workload generator modelled on the Kaiserslautern option
//! pricing benchmark (the paper's task source, §IV.A.1).
//!
//! The benchmark's public URL is dead; what the paper uses it for is a
//! realistic *spread* of task parameters ("generated from within the values
//! from the Kaiserslautern option pricing benchmark") and the $0.001
//! accuracy target that sizes each task's N. This generator reproduces those
//! properties deterministically from a seed — see DESIGN.md §2.

use crate::api::error::{CloudshapesError, Result};
use crate::util::rng::Rng;

use super::option::{OptionTask, Payoff};
use super::Workload;

/// Generation parameters. Defaults reproduce the paper's setup: 128 tasks,
/// $0.001 accuracy, payoff mix dominated by path-dependent options with
/// daily-ish fixing grids.
#[derive(Debug, Clone)]
pub struct GeneratorConfig {
    pub n_tasks: usize,
    pub seed: u64,
    /// CI half-width each task must reach, $.
    pub accuracy: f64,
    /// Mix weights (european, asian, barrier); need not be normalised.
    pub payoff_mix: (f64, f64, f64),
    /// Fixing-date choices for path-dependent payoffs.
    pub step_choices: Vec<u32>,
}

impl Default for GeneratorConfig {
    fn default() -> Self {
        GeneratorConfig {
            n_tasks: 128,
            seed: 2015,
            accuracy: 0.001,
            payoff_mix: (0.25, 0.45, 0.30),
            step_choices: vec![256, 512],
        }
    }
}

impl GeneratorConfig {
    /// A paper-scale workload scaled down for quick runs / native execution.
    pub fn small(n_tasks: usize, accuracy: f64, seed: u64) -> GeneratorConfig {
        GeneratorConfig {
            n_tasks,
            seed,
            accuracy,
            step_choices: vec![64],
            ..GeneratorConfig::default()
        }
    }

    /// Validate the generation parameters. Negative or non-finite payoff
    /// weights, and an all-zero mix, would silently skew (or wedge) the
    /// sampling below — reject them as typed workload errors instead.
    pub fn validate(&self) -> Result<()> {
        let (we, wa, wb) = self.payoff_mix;
        for (name, w) in [("european", we), ("asian", wa), ("barrier", wb)] {
            if !(w >= 0.0 && w.is_finite()) {
                return Err(CloudshapesError::workload(format!(
                    "payoff_mix: {name} weight must be a non-negative finite number, got {w}"
                )));
            }
        }
        if we + wa + wb <= 0.0 {
            return Err(CloudshapesError::workload(
                "payoff_mix must have positive total weight (all three weights are zero)",
            ));
        }
        if self.step_choices.is_empty() {
            return Err(CloudshapesError::workload(
                "step_choices must offer at least one fixing grid",
            ));
        }
        if !(self.accuracy > 0.0 && self.accuracy.is_finite()) {
            return Err(CloudshapesError::workload(format!(
                "accuracy must be a positive CI half-width, got {}",
                self.accuracy
            )));
        }
        Ok(())
    }
}

/// As [`generate`], validating the config first — the library-boundary
/// entry point ([`Experiment::build`](crate::report::Experiment) and the
/// config parser route through the same validation).
pub fn try_generate(cfg: &GeneratorConfig) -> Result<Workload> {
    cfg.validate()?;
    Ok(generate(cfg))
}

/// Generate a workload. Deterministic in the config (same seed, same tasks).
/// Panics on invalid configs — use [`try_generate`] (or
/// [`GeneratorConfig::validate`]) on untrusted input.
pub fn generate(cfg: &GeneratorConfig) -> Workload {
    cfg.validate().expect("invalid generator config");
    let mut rng = Rng::new(cfg.seed);
    let (we, wa, wb) = cfg.payoff_mix;
    let total_w = we + wa + wb;
    let mut tasks = Vec::with_capacity(cfg.n_tasks);
    for id in 0..cfg.n_tasks {
        let draw = rng.f64() * total_w;
        let payoff = if draw < we {
            Payoff::European
        } else if draw < we + wa {
            Payoff::Asian
        } else {
            Payoff::Barrier
        };
        // Kaiserslautern-style market parameter ranges.
        let spot = rng.range_f64(80.0, 120.0);
        let strike = spot * rng.range_f64(0.8, 1.2);
        let rate = rng.range_f64(0.01, 0.05);
        let sigma = rng.range_f64(0.10, 0.45);
        let maturity = rng.range_f64(0.25, 2.0);
        let barrier = spot * rng.range_f64(1.15, 1.6);
        let steps = if payoff == Payoff::European {
            1
        } else {
            *rng.choose(&cfg.step_choices)
        };
        let n_sims = OptionTask::size_n(payoff, spot, sigma, maturity, cfg.accuracy);
        let task = OptionTask {
            id,
            payoff,
            spot,
            strike,
            rate,
            sigma,
            maturity,
            barrier,
            steps,
            target_accuracy: cfg.accuracy,
            n_sims,
        };
        debug_assert!(task.validate().is_ok(), "{:?}", task.validate());
        tasks.push(task);
    }
    Workload::new(tasks)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_in_seed() {
        let a = generate(&GeneratorConfig::default());
        let b = generate(&GeneratorConfig::default());
        assert_eq!(a.tasks, b.tasks);
        let c = generate(&GeneratorConfig { seed: 1, ..GeneratorConfig::default() });
        assert_ne!(a.tasks, c.tasks);
    }

    #[test]
    fn default_matches_paper_shape() {
        let w = generate(&GeneratorConfig::default());
        assert_eq!(w.tasks.len(), 128);
        for t in &w.tasks {
            assert!(t.validate().is_ok());
        }
        // All three payoff families present.
        for p in [Payoff::European, Payoff::Asian, Payoff::Barrier] {
            assert!(w.tasks.iter().any(|t| t.payoff == p), "missing {p:?}");
        }
        // Work sizes spread over at least an order of magnitude.
        let flops: Vec<f64> = w.tasks.iter().map(|t| t.total_flops()).collect();
        let max = flops.iter().cloned().fold(0.0, f64::max);
        let min = flops.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(max / min > 10.0, "spread {max}/{min}");
    }

    #[test]
    fn small_config_is_cheap() {
        let w = generate(&GeneratorConfig::small(8, 0.05, 3));
        assert_eq!(w.tasks.len(), 8);
        for t in &w.tasks {
            assert!(t.n_sims <= 1 << 23, "task too big for native runs: {}", t.n_sims);
        }
    }

    #[test]
    fn mix_weights_respected() {
        let cfg = GeneratorConfig {
            payoff_mix: (1.0, 0.0, 0.0),
            ..GeneratorConfig::default()
        };
        let w = generate(&cfg);
        assert!(w.tasks.iter().all(|t| t.payoff == Payoff::European));
    }

    #[test]
    fn bad_payoff_mixes_are_workload_errors() {
        for mix in [(0.0, 0.0, 0.0), (-1.0, 0.5, 0.5), (f64::NAN, 1.0, 1.0)] {
            let cfg = GeneratorConfig { payoff_mix: mix, ..GeneratorConfig::default() };
            let e = try_generate(&cfg).unwrap_err();
            assert_eq!(e.kind(), "workload", "{mix:?} -> {e}");
        }
        let cfg = GeneratorConfig { step_choices: vec![], ..GeneratorConfig::default() };
        assert_eq!(try_generate(&cfg).unwrap_err().kind(), "workload");
        let cfg = GeneratorConfig { accuracy: 0.0, ..GeneratorConfig::default() };
        assert_eq!(try_generate(&cfg).unwrap_err().kind(), "workload");
        assert!(try_generate(&GeneratorConfig::default()).is_ok());
    }
}
