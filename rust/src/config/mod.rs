//! Experiment configuration: one TOML file describes the workload, the
//! cluster, the partitioner budgets and the sweep — the knobs every CLI
//! subcommand, example and bench shares. See `configs/*.toml`.

use std::path::Path;

use crate::api::error::{CloudshapesError, Result};
use crate::coordinator::executor::ExecutorConfig;
use crate::coordinator::partitioner::MilpConfig;
use crate::coordinator::scheduler::SchedulerConfig;
use crate::coordinator::{BenchmarkConfig, SweepConfig};
use crate::models::market::StormConfig;
use crate::obs::ObsConfig;
use crate::platforms::sim::SimConfig;
use crate::serve::ServeConfig;
use crate::util::json::Json;
use crate::util::toml;
use crate::workload::{GeneratorConfig, Payoff};

/// Which spec set the cluster uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ClusterKind {
    /// The paper's 16-platform Table II testbed.
    Paper,
    /// One platform per category (fast runs).
    Small,
}

/// Cluster construction settings.
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    pub kind: ClusterKind,
    pub seed: u64,
    pub sim: SimConfig,
    /// Append the native PJRT platform (needs `make artifacts`).
    pub with_native: bool,
    /// Composition override: instances rented per catalogue offer (`None` =
    /// the pinned paper-testbed counts). Arity is validated against the
    /// kind's catalogue when the experiment is built.
    pub counts: Option<Vec<usize>>,
    /// Rent spot variants (discounted rate + preemption hazard) of offers
    /// that have spot terms.
    pub spot: bool,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            kind: ClusterKind::Paper,
            seed: 42,
            // Paper scale: cap the per-execute payoff simulation so running
            // a 128-task / 16-platform partition stays fast (prices from
            // 2048-path slices are coarse but unbiased; quick/native
            // presets raise the cap).
            sim: SimConfig { stats_cap: 2048, ..SimConfig::default() },
            with_native: false,
            counts: None,
            spot: false,
        }
    }
}

/// Top-level experiment configuration.
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    pub workload: GeneratorConfig,
    pub cluster: ClusterConfig,
    pub benchmark: BenchmarkConfig,
    pub sweep: SweepConfig,
    pub milp: MilpConfig,
    pub executor: ExecutorConfig,
    /// Online job scheduler knobs (`[scheduler]`; disabled by default).
    /// The nested `[forecast]` section (predictive autoscaling) maps onto
    /// `scheduler.forecast`.
    pub scheduler: SchedulerConfig,
    /// Market-storm tick-stream knobs (`[storm]`; drives the storm bench
    /// and any burst-arrival harness).
    pub storm: StormConfig,
    /// Telemetry knobs (`[obs]`; enabled by default).
    pub obs: ObsConfig,
    /// Serve-plane knobs (`[serve]`: worker/cache shards, read deadline,
    /// request size limit, in-flight budget).
    pub serve: ServeConfig,
    /// Directory holding the AOT artifacts (manifest.json).
    pub artifact_dir: String,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            workload: GeneratorConfig::default(),
            cluster: ClusterConfig::default(),
            benchmark: BenchmarkConfig::default(),
            sweep: SweepConfig::default(),
            milp: MilpConfig::default(),
            executor: ExecutorConfig::default(),
            scheduler: SchedulerConfig::default(),
            storm: StormConfig::default(),
            obs: ObsConfig::default(),
            serve: ServeConfig::default(),
            artifact_dir: "artifacts".to_string(),
        }
    }
}

impl ExperimentConfig {
    /// A configuration sized for CI / quick demos: 3 platforms, 8 small
    /// tasks, coarse sweep.
    pub fn quick() -> ExperimentConfig {
        ExperimentConfig {
            workload: GeneratorConfig::small(8, 0.02, 7),
            cluster: ClusterConfig {
                kind: ClusterKind::Small,
                sim: SimConfig::default(), // full 32k-path statistics
                ..Default::default()
            },
            sweep: SweepConfig { levels: 5 },
            ..Default::default()
        }
    }

    /// Load from a TOML file.
    pub fn load(path: &Path) -> Result<ExperimentConfig> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| CloudshapesError::config(format!("reading {path:?}: {e}")))?;
        Self::parse(&text)
    }

    /// Parse from TOML text; unspecified keys keep their defaults.
    pub fn parse(text: &str) -> Result<ExperimentConfig> {
        let root = toml::parse(text)?;
        let mut cfg = ExperimentConfig::default();

        if let Some(w) = root.get("workload") {
            set_usize(w, "n_tasks", &mut cfg.workload.n_tasks)?;
            set_u64(w, "seed", &mut cfg.workload.seed)?;
            set_f64(w, "accuracy", &mut cfg.workload.accuracy)?;
            if let Some(steps) = w.get("step_choices") {
                let arr = steps.as_arr().ok_or_else(|| {
                    CloudshapesError::config("workload.step_choices must be an array")
                })?;
                cfg.workload.step_choices = arr
                    .iter()
                    .map(|v| {
                        v.as_u64()
                            .map(|u| u as u32)
                            .ok_or_else(|| CloudshapesError::config("bad step value"))
                    })
                    .collect::<Result<_>>()?;
            }
            if let Some(mix) = w.get("payoff_mix") {
                let arr = mix.as_arr().ok_or_else(|| {
                    CloudshapesError::config("workload.payoff_mix must be an array")
                })?;
                // Pre-exotics configs list 3 weights; missing trailing
                // families get weight 0 (never drawn). More than one weight
                // per family is a config error.
                if arr.len() < 3 || arr.len() > Payoff::COUNT {
                    return Err(CloudshapesError::config(format!(
                        "payoff_mix needs 3..={} weights ({}), got {}",
                        Payoff::COUNT,
                        Payoff::NAMES.join(", "),
                        arr.len()
                    )));
                }
                let mut weights = [0.0f64; Payoff::COUNT];
                for (k, v) in arr.iter().enumerate() {
                    weights[k] = v
                        .as_f64()
                        .ok_or_else(|| CloudshapesError::config("bad mix weight"))?;
                }
                cfg.workload.payoff_mix = weights;
            }
            // A single payoff family by name overrides the mix weights;
            // unknown names are typed workload errors listing the valid
            // families (never a silent None).
            if let Some(p) = w.get("payoff") {
                let name = p.as_str().ok_or_else(|| {
                    CloudshapesError::config("workload.payoff must be a string")
                })?;
                cfg.workload.payoff_mix = Payoff::parse(name)?.one_hot_mix();
            }
            // Exotic-family knobs (only validated when the mix can reach
            // the family they parameterise).
            let mut assets = cfg.workload.basket_assets as u64;
            set_u64(w, "basket_assets", &mut assets)?;
            cfg.workload.basket_assets = assets as u32;
            set_f64(w, "basket_rho", &mut cfg.workload.basket_rho)?;
            set_f64(w, "heston_kappa", &mut cfg.workload.heston_kappa)?;
            set_f64(w, "heston_theta", &mut cfg.workload.heston_theta)?;
            set_f64(w, "heston_xi", &mut cfg.workload.heston_xi)?;
            set_f64(w, "heston_rho", &mut cfg.workload.heston_rho)?;
            // Reject bad generator parameters (negative/all-zero payoff
            // mixes) at parse time, before they flow into sampling.
            cfg.workload.validate()?;
        }
        if let Some(c) = root.get("cluster") {
            if let Some(kind) = c.get("kind").and_then(Json::as_str) {
                cfg.cluster.kind = match kind {
                    "paper" => ClusterKind::Paper,
                    "small" => ClusterKind::Small,
                    other => {
                        return Err(CloudshapesError::config(format!(
                            "unknown cluster kind '{other}'"
                        )))
                    }
                };
            }
            set_u64(c, "seed", &mut cfg.cluster.seed)?;
            set_f64(c, "noise_sigma", &mut cfg.cluster.sim.noise_sigma)?;
            set_f64(c, "hidden_spread", &mut cfg.cluster.sim.hidden_spread)?;
            set_f64(c, "failure_rate", &mut cfg.cluster.sim.failure_rate)?;
            set_bool(c, "with_native", &mut cfg.cluster.with_native)?;
            let mut cap = cfg.cluster.sim.stats_cap as u64;
            set_u64(c, "stats_cap", &mut cap)?;
            cfg.cluster.sim.stats_cap = cap as u32;
        }
        if let Some(k) = root.get("kernel") {
            set_bool(k, "batch", &mut cfg.cluster.sim.kernel.batch)?;
            set_usize(k, "lanes", &mut cfg.cluster.sim.kernel.lanes)?;
            // Unsupported lane widths are config errors at parse time, not
            // a silent scalar fallback at execution time.
            cfg.cluster.sim.kernel.validate()?;
        }
        if let Some(cat) = root.get("catalogue") {
            if let Some(counts) = cat.get("counts") {
                let arr = counts.as_arr().ok_or_else(|| {
                    CloudshapesError::config(
                        "catalogue.counts must be an array of instance counts",
                    )
                })?;
                cfg.cluster.counts = Some(
                    arr.iter()
                        .map(|v| {
                            v.as_u64().map(|u| u as usize).ok_or_else(|| {
                                CloudshapesError::config(
                                    "catalogue.counts entries must be non-negative integers",
                                )
                            })
                        })
                        .collect::<Result<_>>()?,
                );
            }
            set_bool(cat, "spot", &mut cfg.cluster.spot)?;
        }
        if let Some(b) = root.get("benchmark") {
            set_usize(b, "reps", &mut cfg.benchmark.reps)?;
            set_f64(b, "rung_budget_secs", &mut cfg.benchmark.rung_budget_secs)?;
            set_usize(b, "threads", &mut cfg.benchmark.threads)?;
        }
        if let Some(s) = root.get("sweep") {
            set_usize(s, "levels", &mut cfg.sweep.levels)?;
        }
        // One shared `workers` knob governs solver AND executor parallelism;
        // the `[milp]` / `[executor]` sections can still override it
        // individually (they are parsed after this).
        if root.get("workers").is_some() {
            let mut workers = cfg.milp.workers as u64;
            set_u64(&root, "workers", &mut workers)?;
            if workers == 0 {
                return Err(CloudshapesError::config("workers must be >= 1"));
            }
            cfg.milp.workers = workers as usize;
            cfg.executor.workers = workers as usize;
        }
        if let Some(m) = root.get("milp") {
            set_usize(m, "max_nodes", &mut cfg.milp.max_nodes)?;
            set_f64(m, "rel_gap", &mut cfg.milp.rel_gap)?;
            set_f64(m, "time_limit_secs", &mut cfg.milp.time_limit_secs)?;
            set_usize(m, "workers", &mut cfg.milp.workers)?;
            if cfg.milp.workers == 0 {
                return Err(CloudshapesError::config("milp.workers must be >= 1"));
            }
        }
        if let Some(e) = root.get("executor") {
            let mut seed64 = cfg.executor.seed as u64;
            set_u64(e, "seed", &mut seed64)?;
            cfg.executor.seed = seed64 as u32;
            // `threads` is the legacy spelling of `workers`.
            set_usize(e, "threads", &mut cfg.executor.workers)?;
            set_usize(e, "workers", &mut cfg.executor.workers)?;
            if cfg.executor.workers == 0 {
                return Err(CloudshapesError::config("executor.workers must be >= 1"));
            }
            set_u64(e, "chunk_sims", &mut cfg.executor.chunk_sims)?;
            let mut attempts = cfg.executor.retry.max_attempts as u64;
            set_u64(e, "max_attempts", &mut attempts)?;
            if attempts == 0 {
                return Err(CloudshapesError::config("executor.max_attempts must be >= 1"));
            }
            cfg.executor.retry.max_attempts = attempts as u32;
            set_bool(e, "rehome", &mut cfg.executor.retry.rehome)?;
            set_bool(e, "rebalance", &mut cfg.executor.rebalance.enabled)?;
            set_f64(e, "rebalance_tolerance", &mut cfg.executor.rebalance.tolerance)?;
            if cfg.executor.rebalance.tolerance <= 0.0 {
                return Err(CloudshapesError::config(
                    "executor.rebalance_tolerance must be positive",
                ));
            }
        }
        if let Some(s) = root.get("scheduler") {
            set_bool(s, "enabled", &mut cfg.scheduler.enabled)?;
            set_f64(s, "epoch_secs", &mut cfg.scheduler.epoch_secs)?;
            set_usize(s, "max_in_flight", &mut cfg.scheduler.max_in_flight)?;
            set_usize(s, "refit_window", &mut cfg.scheduler.refit_window)?;
            set_bool(s, "family_refit", &mut cfg.scheduler.family_refit)?;
            set_f64(s, "resolve_drift", &mut cfg.scheduler.resolve_drift)?;
            set_f64(s, "repair_quality", &mut cfg.scheduler.repair_quality)?;
            set_usize(s, "plan_memo", &mut cfg.scheduler.plan_memo)?;
            cfg.scheduler.validate()?;
        }
        // Predictive autoscaling rides the scheduler (its own section for
        // readability; programmatically it is `scheduler.forecast`).
        if let Some(f) = root.get("forecast") {
            set_bool(f, "enabled", &mut cfg.scheduler.forecast.enabled)?;
            set_f64(f, "alpha", &mut cfg.scheduler.forecast.alpha)?;
            set_usize(f, "season_len", &mut cfg.scheduler.forecast.season_len)?;
            set_f64(f, "safety", &mut cfg.scheduler.forecast.safety)?;
            set_usize(f, "drain_epochs", &mut cfg.scheduler.forecast.drain_epochs)?;
            set_usize(f, "min_rented", &mut cfg.scheduler.forecast.min_rented)?;
            set_f64(f, "rent_lead_secs", &mut cfg.scheduler.forecast.rent_lead_secs)?;
            cfg.scheduler.forecast.validate()?;
        }
        if let Some(s) = root.get("storm") {
            set_u64(s, "seed", &mut cfg.storm.seed)?;
            set_usize(s, "ticks", &mut cfg.storm.ticks)?;
            set_usize(s, "base_jobs", &mut cfg.storm.base_jobs)?;
            set_usize(s, "storm_every", &mut cfg.storm.storm_every)?;
            set_usize(s, "storm_jobs", &mut cfg.storm.storm_jobs)?;
            set_usize(s, "tasks_per_job", &mut cfg.storm.tasks_per_job)?;
            set_f64(s, "accuracy", &mut cfg.storm.accuracy)?;
            set_f64(s, "deadline_secs", &mut cfg.storm.deadline_secs)?;
            set_f64(s, "spot_volatility", &mut cfg.storm.spot_volatility)?;
            cfg.storm.validate()?;
        }
        if let Some(o) = root.get("obs") {
            set_bool(o, "enabled", &mut cfg.obs.enabled)?;
            set_usize(o, "hist_buckets", &mut cfg.obs.hist_buckets)?;
            set_usize(o, "trace_ring", &mut cfg.obs.trace_ring)?;
            cfg.obs.validate()?;
        }
        if let Some(s) = root.get("serve") {
            set_usize(s, "shards", &mut cfg.serve.shards)?;
            set_f64(s, "read_timeout_secs", &mut cfg.serve.read_timeout_secs)?;
            set_f64(s, "idle_timeout_secs", &mut cfg.serve.idle_timeout_secs)?;
            set_usize(s, "max_request_bytes", &mut cfg.serve.max_request_bytes)?;
            set_usize(s, "max_inflight", &mut cfg.serve.max_inflight)?;
            cfg.serve.validate()?;
        }
        if let Some(a) = root.get("artifact_dir").and_then(Json::as_str) {
            cfg.artifact_dir = a.to_string();
        }
        Ok(cfg)
    }
}

fn set_f64(obj: &Json, key: &str, out: &mut f64) -> Result<()> {
    if let Some(v) = obj.get(key) {
        *out = v
            .as_f64()
            .ok_or_else(|| CloudshapesError::config(format!("{key} must be a number")))?;
    }
    Ok(())
}

fn set_u64(obj: &Json, key: &str, out: &mut u64) -> Result<()> {
    if let Some(v) = obj.get(key) {
        *out = v.as_u64().ok_or_else(|| {
            CloudshapesError::config(format!("{key} must be a non-negative integer"))
        })?;
    }
    Ok(())
}

fn set_usize(obj: &Json, key: &str, out: &mut usize) -> Result<()> {
    let mut v = *out as u64;
    set_u64(obj, key, &mut v)?;
    *out = v as usize;
    Ok(())
}

fn set_bool(obj: &Json, key: &str, out: &mut bool) -> Result<()> {
    if let Some(v) = obj.get(key) {
        *out = v
            .as_bool()
            .ok_or_else(|| CloudshapesError::config(format!("{key} must be a boolean")))?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_paper_scale() {
        let c = ExperimentConfig::default();
        assert_eq!(c.workload.n_tasks, 128);
        assert_eq!(c.cluster.kind, ClusterKind::Paper);
        assert_eq!(c.sweep.levels, 11);
    }

    #[test]
    fn parses_full_config() {
        let text = r#"
            artifact_dir = "artifacts"

            [workload]
            n_tasks = 16
            seed = 5
            accuracy = 0.01
            step_choices = [64, 128]
            payoff_mix = [1.0, 0.5, 0.5]

            [cluster]
            kind = "small"
            seed = 9
            noise_sigma = 0.02
            failure_rate = 0.1
            with_native = true

            [sweep]
            levels = 7

            [milp]
            max_nodes = 50
            rel_gap = 0.01
            time_limit_secs = 2.5
            workers = 3

            [executor]
            seed = 3
            workers = 4
            chunk_sims = 1048576
            max_attempts = 5
            rehome = false
            rebalance = false
            rebalance_tolerance = 0.5
        "#;
        let c = ExperimentConfig::parse(text).unwrap();
        assert_eq!(c.workload.n_tasks, 16);
        assert_eq!(c.workload.step_choices, vec![64, 128]);
        assert_eq!(c.workload.payoff_mix, [1.0, 0.5, 0.5, 0.0, 0.0, 0.0]);
        assert_eq!(c.cluster.kind, ClusterKind::Small);
        assert!((c.cluster.sim.failure_rate - 0.1).abs() < 1e-12);
        assert!(c.cluster.with_native);
        assert_eq!(c.sweep.levels, 7);
        assert_eq!(c.milp.max_nodes, 50);
        assert!((c.milp.time_limit_secs - 2.5).abs() < 1e-12);
        assert_eq!(c.milp.workers, 3);
        assert_eq!(c.executor.workers, 4);
        assert_eq!(c.executor.chunk_sims, 1 << 20);
        assert_eq!(c.executor.retry.max_attempts, 5);
        assert!(!c.executor.retry.rehome);
        assert!(!c.executor.rebalance.enabled);
        assert!((c.executor.rebalance.tolerance - 0.5).abs() < 1e-12);
    }

    #[test]
    fn shared_workers_knob_governs_solver_and_executor() {
        let c = ExperimentConfig::parse("workers = 6").unwrap();
        assert_eq!(c.milp.workers, 6);
        assert_eq!(c.executor.workers, 6);
        // Section-level overrides still win.
        let c = ExperimentConfig::parse("workers = 6\n[executor]\nworkers = 2").unwrap();
        assert_eq!(c.milp.workers, 6);
        assert_eq!(c.executor.workers, 2);
        // Legacy spelling keeps parsing.
        let c = ExperimentConfig::parse("[executor]\nthreads = 3").unwrap();
        assert_eq!(c.executor.workers, 3);
        assert!(ExperimentConfig::parse("workers = 0").is_err());
        assert!(ExperimentConfig::parse("[executor]\nmax_attempts = 0").is_err());
    }

    #[test]
    fn partial_config_keeps_defaults() {
        let c = ExperimentConfig::parse("[sweep]\nlevels = 3").unwrap();
        assert_eq!(c.sweep.levels, 3);
        assert_eq!(c.workload.n_tasks, 128);
        assert_eq!(c.cluster.counts, None);
        assert!(!c.cluster.spot);
    }

    #[test]
    fn kernel_section_parses_and_validates() {
        use crate::pricing::{KernelConfig, LANES, SUPPORTED_LANES};
        let c = ExperimentConfig::parse("[kernel]\nbatch = false\nlanes = 16").unwrap();
        assert_eq!(c.cluster.sim.kernel, KernelConfig { batch: false, lanes: 16 });
        // Defaults: batched at the default lane width.
        let c = ExperimentConfig::parse("").unwrap();
        assert!(c.cluster.sim.kernel.batch);
        assert_eq!(c.cluster.sim.kernel.lanes, LANES);
        // Every supported width parses; anything else is a config error
        // naming the valid set.
        for lanes in SUPPORTED_LANES {
            let text = format!("[kernel]\nlanes = {lanes}");
            assert_eq!(ExperimentConfig::parse(&text).unwrap().cluster.sim.kernel.lanes, lanes);
        }
        let e = ExperimentConfig::parse("[kernel]\nlanes = 7").unwrap_err();
        assert_eq!(e.kind(), "config");
        assert!(e.message().contains("lanes"), "{e}");
        assert!(ExperimentConfig::parse("[kernel]\nlanes = 0").is_err());
        assert!(ExperimentConfig::parse("[kernel]\nbatch = \"fast\"").is_err());
    }

    #[test]
    fn catalogue_section_pins_composition_and_spot() {
        let c = ExperimentConfig::parse(
            "[catalogue]\ncounts = [4, 8, 1, 1, 1, 1]\nspot = true",
        )
        .unwrap();
        assert_eq!(c.cluster.counts, Some(vec![4, 8, 1, 1, 1, 1]));
        assert!(c.cluster.spot);
        assert!(ExperimentConfig::parse("[catalogue]\ncounts = 3").is_err());
        assert!(ExperimentConfig::parse("[catalogue]\ncounts = [1, -2]").is_err());
        assert!(ExperimentConfig::parse("[catalogue]\nspot = \"yes\"").is_err());
    }

    #[test]
    fn scheduler_section_parses_and_validates() {
        let c = ExperimentConfig::parse(
            "[scheduler]\nenabled = true\nepoch_secs = 120.0\nmax_in_flight = 4\n\
             refit_window = 32\nresolve_drift = 0.2",
        )
        .unwrap();
        assert!(c.scheduler.enabled);
        assert!((c.scheduler.epoch_secs - 120.0).abs() < 1e-12);
        assert_eq!(c.scheduler.max_in_flight, 4);
        assert_eq!(c.scheduler.refit_window, 32);
        assert!((c.scheduler.resolve_drift - 0.2).abs() < 1e-12);
        // Defaults: present but disabled.
        let c = ExperimentConfig::parse("").unwrap();
        assert!(!c.scheduler.enabled);
        assert_eq!(c.scheduler.max_in_flight, 8);
        // Bad values are config errors.
        assert!(ExperimentConfig::parse("[scheduler]\nepoch_secs = 0").is_err());
        assert!(ExperimentConfig::parse("[scheduler]\nmax_in_flight = 0").is_err());
        assert!(ExperimentConfig::parse("[scheduler]\nresolve_drift = -0.5").is_err());
        // The re-plan fast-path knobs ride the same section.
        let c = ExperimentConfig::parse("[scheduler]\nrepair_quality = 1.5\nplan_memo = 64")
            .unwrap();
        assert!((c.scheduler.repair_quality - 1.5).abs() < 1e-12);
        assert_eq!(c.scheduler.plan_memo, 64);
        assert!(ExperimentConfig::parse("[scheduler]\nrepair_quality = 0.5").is_err());
    }

    #[test]
    fn forecast_section_parses_and_validates() {
        let c = ExperimentConfig::parse(
            "[forecast]\nenabled = true\nalpha = 0.5\nseason_len = 12\nsafety = 1.5\n\
             drain_epochs = 3\nmin_rented = 2\nrent_lead_secs = 45.0",
        )
        .unwrap();
        let f = &c.scheduler.forecast;
        assert!(f.enabled);
        assert!((f.alpha - 0.5).abs() < 1e-12);
        assert_eq!(f.season_len, 12);
        assert!((f.safety - 1.5).abs() < 1e-12);
        assert_eq!(f.drain_epochs, 3);
        assert_eq!(f.min_rented, 2);
        assert!((f.rent_lead_secs - 45.0).abs() < 1e-12);
        // Defaults: present but disabled (the static baseline).
        let c = ExperimentConfig::parse("").unwrap();
        assert!(!c.scheduler.forecast.enabled);
        // Bad values are config errors.
        assert!(ExperimentConfig::parse("[forecast]\nalpha = 0").is_err());
        assert!(ExperimentConfig::parse("[forecast]\nalpha = 1.5").is_err());
        assert!(ExperimentConfig::parse("[forecast]\nsafety = 0.5").is_err());
        assert!(ExperimentConfig::parse("[forecast]\ndrain_epochs = 0").is_err());
        assert!(ExperimentConfig::parse("[forecast]\nrent_lead_secs = -1").is_err());
    }

    #[test]
    fn storm_section_parses_and_validates() {
        let c = ExperimentConfig::parse(
            "[storm]\nseed = 11\nticks = 96\nbase_jobs = 2\nstorm_every = 24\n\
             storm_jobs = 32\ntasks_per_job = 4\naccuracy = 0.1\ndeadline_secs = 7200\n\
             spot_volatility = 0.3",
        )
        .unwrap();
        assert_eq!(c.storm.seed, 11);
        assert_eq!(c.storm.ticks, 96);
        assert_eq!(c.storm.base_jobs, 2);
        assert_eq!(c.storm.storm_every, 24);
        assert_eq!(c.storm.storm_jobs, 32);
        assert_eq!(c.storm.tasks_per_job, 4);
        assert!((c.storm.accuracy - 0.1).abs() < 1e-12);
        assert!((c.storm.deadline_secs - 7200.0).abs() < 1e-12);
        assert!((c.storm.spot_volatility - 0.3).abs() < 1e-12);
        // Defaults survive an absent section.
        let c = ExperimentConfig::parse("").unwrap();
        assert_eq!(c.storm.ticks, 48);
        // Bad values are config errors.
        assert!(ExperimentConfig::parse("[storm]\nticks = 0").is_err());
        assert!(ExperimentConfig::parse("[storm]\nstorm_jobs = 0").is_err());
        assert!(ExperimentConfig::parse("[storm]\naccuracy = 0").is_err());
        assert!(ExperimentConfig::parse("[storm]\nspot_volatility = 1.0").is_err());
    }

    #[test]
    fn obs_section_parses_and_validates() {
        let c = ExperimentConfig::parse(
            "[obs]\nenabled = false\nhist_buckets = 12\ntrace_ring = 256",
        )
        .unwrap();
        assert!(!c.obs.enabled);
        assert_eq!(c.obs.hist_buckets, 12);
        assert_eq!(c.obs.trace_ring, 256);
        // Defaults: on, with the registry's standard bucket count.
        let c = ExperimentConfig::parse("").unwrap();
        assert!(c.obs.enabled);
        assert_eq!(c.obs.hist_buckets, crate::obs::DEFAULT_HIST_BUCKETS);
        // Bad values are config errors.
        assert!(ExperimentConfig::parse("[obs]\nhist_buckets = 1").is_err());
        assert!(ExperimentConfig::parse("[obs]\ntrace_ring = 2").is_err());
        assert!(ExperimentConfig::parse("[obs]\nenabled = \"on\"").is_err());
    }

    #[test]
    fn serve_section_parses_and_validates() {
        let c = ExperimentConfig::parse(
            "[serve]\nshards = 8\nread_timeout_secs = 2.5\nidle_timeout_secs = 120\n\
             max_request_bytes = 65536\nmax_inflight = 512",
        )
        .unwrap();
        assert_eq!(c.serve.shards, 8);
        assert!((c.serve.read_timeout_secs - 2.5).abs() < 1e-12);
        assert!((c.serve.idle_timeout_secs - 120.0).abs() < 1e-12);
        assert_eq!(c.serve.max_request_bytes, 65536);
        assert_eq!(c.serve.max_inflight, 512);
        // The per-shard queue cap splits the in-flight budget.
        assert_eq!(c.serve.queue_cap(), 64);
        // Defaults: 4 shards, 30s read deadline, idle reaping off, 1 MiB
        // frames, 256 in flight.
        let c = ExperimentConfig::parse("").unwrap();
        assert_eq!(c.serve.shards, 4);
        assert!((c.serve.read_timeout_secs - 30.0).abs() < 1e-12);
        assert!(c.serve.idle_timeout_secs.abs() < 1e-12);
        assert_eq!(c.serve.max_request_bytes, 1 << 20);
        assert_eq!(c.serve.max_inflight, 256);
        // Bad values are config errors.
        assert!(ExperimentConfig::parse("[serve]\nshards = 0").is_err());
        assert!(ExperimentConfig::parse("[serve]\nshards = 1000").is_err());
        assert!(ExperimentConfig::parse("[serve]\nread_timeout_secs = 0").is_err());
        assert!(ExperimentConfig::parse("[serve]\nidle_timeout_secs = -1").is_err());
        assert!(ExperimentConfig::parse("[serve]\nmax_request_bytes = 8").is_err());
        assert!(ExperimentConfig::parse("[serve]\nmax_inflight = 0").is_err());
    }

    #[test]
    fn workload_payoff_key_picks_one_family_or_errors_with_names() {
        let c = ExperimentConfig::parse("[workload]\npayoff = \"asian\"").unwrap();
        assert_eq!(c.workload.payoff_mix, Payoff::Asian.one_hot_mix());
        let c = ExperimentConfig::parse("[workload]\npayoff = \"heston\"").unwrap();
        assert_eq!(c.workload.payoff_mix, Payoff::Heston.one_hot_mix());
        // The unknown-name bugfix: a typed workload error listing the
        // valid families, not a silent default.
        let e = ExperimentConfig::parse("[workload]\npayoff = \"swaption\"").unwrap_err();
        assert_eq!(e.kind(), "workload");
        for name in Payoff::NAMES {
            assert!(e.message().contains(name), "{e} missing {name}");
        }
        assert!(ExperimentConfig::parse("[workload]\npayoff = 3").is_err());
    }

    #[test]
    fn payoff_mix_accepts_legacy_and_full_length_arrays() {
        // 3 weights (pre-exotics configs): trailing families get weight 0.
        let c = ExperimentConfig::parse("[workload]\npayoff_mix = [0.2, 0.3, 0.5]").unwrap();
        assert_eq!(c.workload.payoff_mix, [0.2, 0.3, 0.5, 0.0, 0.0, 0.0]);
        // Full-length arrays reach the exotic families.
        let c = ExperimentConfig::parse(
            "[workload]\npayoff_mix = [0.0, 0.0, 0.0, 0.4, 0.3, 0.3]",
        )
        .unwrap();
        assert_eq!(c.workload.payoff_mix, [0.0, 0.0, 0.0, 0.4, 0.3, 0.3]);
        // Too many weights is a config error naming the families.
        let e = ExperimentConfig::parse(
            "[workload]\npayoff_mix = [1.0, 0, 0, 0, 0, 0, 0]",
        )
        .unwrap_err();
        assert_eq!(e.kind(), "config");
        assert!(e.message().contains("heston"), "{e}");
    }

    #[test]
    fn exotic_workload_knobs_parse_and_validate() {
        let c = ExperimentConfig::parse(
            "[workload]\npayoff = \"basket\"\nbasket_assets = 6\nbasket_rho = 0.3",
        )
        .unwrap();
        assert_eq!(c.workload.basket_assets, 6);
        assert!((c.workload.basket_rho - 0.3).abs() < 1e-12);
        let c = ExperimentConfig::parse(
            "[workload]\npayoff = \"heston\"\nheston_kappa = 2.0\nheston_theta = 0.09\nheston_xi = 0.3\nheston_rho = -0.5",
        )
        .unwrap();
        assert!((c.workload.heston_kappa - 2.0).abs() < 1e-12);
        assert!((c.workload.heston_theta - 0.09).abs() < 1e-12);
        assert!((c.workload.heston_xi - 0.3).abs() < 1e-12);
        assert!((c.workload.heston_rho + 0.5).abs() < 1e-12);
        // Unreachable nonsense knobs don't fail legacy configs…
        assert!(ExperimentConfig::parse("[workload]\nbasket_assets = 1").is_ok());
        // …but reachable ones are validated at parse time.
        let e = ExperimentConfig::parse(
            "[workload]\npayoff = \"basket\"\nbasket_assets = 1",
        )
        .unwrap_err();
        assert_eq!(e.kind(), "workload");
    }

    #[test]
    fn scheduler_family_refit_knob_parses() {
        let c = ExperimentConfig::parse("[scheduler]\nfamily_refit = false").unwrap();
        assert!(!c.scheduler.family_refit);
        assert!(ExperimentConfig::default().scheduler.family_refit);
    }

    #[test]
    fn bad_values_are_rejected() {
        assert!(ExperimentConfig::parse("[cluster]\nkind = \"mainframe\"").is_err());
        assert!(ExperimentConfig::parse("[sweep]\nlevels = \"many\"").is_err());
        assert!(ExperimentConfig::parse("[workload]\npayoff_mix = [1.0]").is_err());
        assert!(ExperimentConfig::parse("[milp]\nworkers = 0").is_err());
        // Generator-level validation runs at parse time too.
        let e = ExperimentConfig::parse("[workload]\npayoff_mix = [0.0, 0.0, 0.0]")
            .unwrap_err();
        assert_eq!(e.kind(), "workload");
        assert!(ExperimentConfig::parse("[workload]\npayoff_mix = [1.0, -0.5, 0.5]").is_err());
    }
}
