//! PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt`) produced by
//! `make artifacts` and executes them from the rust request path.
//!
//! HLO *text* is the interchange format (aot_recipe / xla-example gotcha:
//! the crate's xla_extension 0.5.1 rejects jax≥0.5's 64-bit-id serialized
//! protos; the text parser reassigns ids). One compiled executable per
//! (payoff, chunk-size) variant, compile-once-execute-many.

pub mod artifact;
pub mod engine;
pub mod service;

pub use artifact::{Manifest, Variant};
pub use engine::Engine;
pub use service::EngineHandle;
