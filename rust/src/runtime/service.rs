//! Engine service: the `xla` crate's PJRT types are not `Send`/`Sync`
//! (internal `Rc`s), so the engine lives on a dedicated owner thread and the
//! rest of the system talks to it through a cloneable [`EngineHandle`].
//! The CPU PJRT client is a single device anyway — serializing executions
//! through one thread costs nothing and gives a clean ownership story.

use std::path::Path;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

use anyhow::{anyhow, Result};

use crate::pricing::mc::PayoffStats;
use crate::workload::option::{OptionTask, Payoff};

use super::engine::Engine;

enum Request {
    Price { task: OptionTask, n: u64, seed: u32, reply: mpsc::Sender<Result<PayoffStats>> },
    Supported { reply: mpsc::Sender<Vec<Payoff>> },
    Platform { reply: mpsc::Sender<String> },
    Warmup { reply: mpsc::Sender<Result<()>> },
    Shutdown,
}

/// Cloneable, thread-safe handle to the engine owner thread.
#[derive(Clone)]
pub struct EngineHandle {
    tx: Arc<Mutex<mpsc::Sender<Request>>>,
}

impl EngineHandle {
    /// Spawn the owner thread and load the engine from `artifact_dir`.
    /// Fails fast if the manifest or PJRT client can't be created.
    pub fn spawn(artifact_dir: &Path) -> Result<EngineHandle> {
        let dir = artifact_dir.to_path_buf();
        let (tx, rx) = mpsc::channel::<Request>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        thread::Builder::new()
            .name("cloudshapes-engine".to_string())
            .spawn(move || {
                let engine = match Engine::load(&dir) {
                    Ok(e) => {
                        let _ = ready_tx.send(Ok(()));
                        e
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                for req in rx {
                    match req {
                        Request::Price { task, n, seed, reply } => {
                            let _ = reply.send(engine.price(&task, n, seed));
                        }
                        Request::Supported { reply } => {
                            let _ = reply.send(engine.supported_payoffs());
                        }
                        Request::Platform { reply } => {
                            let _ = reply.send(engine.platform_name());
                        }
                        Request::Warmup { reply } => {
                            let _ = reply.send(engine.warmup());
                        }
                        Request::Shutdown => break,
                    }
                }
            })
            .expect("spawn engine thread");
        ready_rx
            .recv()
            .map_err(|_| anyhow!("engine thread died during startup"))??;
        Ok(EngineHandle { tx: Arc::new(Mutex::new(tx)) })
    }

    fn send(&self, req: Request) {
        self.tx.lock().unwrap().send(req).expect("engine thread gone");
    }

    /// Price `n` paths of `task` (see [`Engine::price`] for semantics).
    pub fn price(&self, task: &OptionTask, n: u64, seed: u32) -> Result<PayoffStats> {
        let (reply, rx) = mpsc::channel();
        self.send(Request::Price { task: task.clone(), n, seed, reply });
        rx.recv().map_err(|_| anyhow!("engine thread dropped request"))?
    }

    /// Payoff families with artifacts available.
    pub fn supported_payoffs(&self) -> Vec<Payoff> {
        let (reply, rx) = mpsc::channel();
        self.send(Request::Supported { reply });
        rx.recv().unwrap_or_default()
    }

    /// PJRT platform name (e.g. "cpu").
    pub fn platform_name(&self) -> String {
        let (reply, rx) = mpsc::channel();
        self.send(Request::Platform { reply });
        rx.recv().unwrap_or_else(|_| "unknown".to_string())
    }

    /// Compile all variants now.
    pub fn warmup(&self) -> Result<()> {
        let (reply, rx) = mpsc::channel();
        self.send(Request::Warmup { reply });
        rx.recv().map_err(|_| anyhow!("engine thread dropped request"))?
    }

    /// Stop the owner thread (handles become inert).
    pub fn shutdown(&self) {
        self.send(Request::Shutdown);
    }
}
