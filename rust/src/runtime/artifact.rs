//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! rust runtime. Parsed with the in-tree JSON substrate.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;
use crate::workload::option::Payoff;

/// One AOT-lowered chunk variant.
#[derive(Debug, Clone, PartialEq)]
pub struct Variant {
    pub name: String,
    pub payoff: Payoff,
    /// Paths simulated per execution.
    pub n: u64,
    /// Fixing dates baked into the variant (1 for European).
    pub steps: u32,
    /// Pallas block size (informational; execution doesn't depend on it).
    pub block: u64,
    /// HLO text file, relative to the manifest directory.
    pub file: PathBuf,
    pub sha256: String,
}

/// Parsed `manifest.json`.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub jax_version: String,
    pub variants: Vec<Variant>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        Self::parse(dir, &text)
    }

    /// Parse manifest text (split out for tests).
    pub fn parse(dir: &Path, text: &str) -> Result<Manifest> {
        let root = Json::parse(text).map_err(|e| anyhow!("manifest: {e}"))?;
        let schema = root.get("schema").and_then(Json::as_u64).unwrap_or(0);
        if schema != 1 {
            bail!("unsupported manifest schema {schema}");
        }
        let jax_version = root
            .get("jax_version")
            .and_then(Json::as_str)
            .unwrap_or("unknown")
            .to_string();
        let vs = root
            .get("variants")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest: missing variants"))?;
        let mut variants = Vec::with_capacity(vs.len());
        for v in vs {
            let get_str = |k: &str| {
                v.get(k)
                    .and_then(Json::as_str)
                    .map(str::to_string)
                    .ok_or_else(|| anyhow!("variant missing '{k}'"))
            };
            let get_u64 =
                |k: &str| v.get(k).and_then(Json::as_u64).ok_or_else(|| anyhow!("variant missing '{k}'"));
            let payoff_name = get_str("payoff")?;
            let payoff = Payoff::from_name(&payoff_name).ok_or_else(|| {
                anyhow!("unknown payoff '{payoff_name}' (valid: {})", Payoff::NAMES.join(", "))
            })?;
            variants.push(Variant {
                name: get_str("name")?,
                payoff,
                n: get_u64("n")?,
                steps: get_u64("steps")? as u32,
                block: get_u64("block")?,
                file: PathBuf::from(get_str("file")?),
                sha256: get_str("sha256")?,
            });
        }
        if variants.is_empty() {
            bail!("manifest lists no variants");
        }
        Ok(Manifest { dir: dir.to_path_buf(), jax_version, variants })
    }

    /// Variants of a payoff family, sorted by chunk size ascending.
    pub fn variants_for(&self, payoff: Payoff) -> Vec<&Variant> {
        let mut vs: Vec<&Variant> =
            self.variants.iter().filter(|v| v.payoff == payoff).collect();
        vs.sort_by_key(|v| v.n);
        vs
    }

    /// Absolute path of a variant's HLO text.
    pub fn hlo_path(&self, v: &Variant) -> PathBuf {
        self.dir.join(&v.file)
    }

    /// Verify the HLO files exist and match their recorded hashes.
    pub fn verify(&self) -> Result<()> {
        for v in &self.variants {
            let path = self.hlo_path(v);
            let text = std::fs::read_to_string(&path)
                .with_context(|| format!("missing artifact {path:?}"))?;
            let digest = sha256_hex(text.as_bytes());
            if digest != v.sha256 {
                bail!("artifact {} hash mismatch (stale artifacts/ — re-run make artifacts)", v.name);
            }
        }
        Ok(())
    }
}

/// Minimal SHA-256 (FIPS 180-4) — used only to verify artifact integrity.
pub fn sha256_hex(data: &[u8]) -> String {
    const K: [u32; 64] = [
        0x428a2f98, 0x71374491, 0xb5c0fbcf, 0xe9b5dba5, 0x3956c25b, 0x59f111f1, 0x923f82a4,
        0xab1c5ed5, 0xd807aa98, 0x12835b01, 0x243185be, 0x550c7dc3, 0x72be5d74, 0x80deb1fe,
        0x9bdc06a7, 0xc19bf174, 0xe49b69c1, 0xefbe4786, 0x0fc19dc6, 0x240ca1cc, 0x2de92c6f,
        0x4a7484aa, 0x5cb0a9dc, 0x76f988da, 0x983e5152, 0xa831c66d, 0xb00327c8, 0xbf597fc7,
        0xc6e00bf3, 0xd5a79147, 0x06ca6351, 0x14292967, 0x27b70a85, 0x2e1b2138, 0x4d2c6dfc,
        0x53380d13, 0x650a7354, 0x766a0abb, 0x81c2c92e, 0x92722c85, 0xa2bfe8a1, 0xa81a664b,
        0xc24b8b70, 0xc76c51a3, 0xd192e819, 0xd6990624, 0xf40e3585, 0x106aa070, 0x19a4c116,
        0x1e376c08, 0x2748774c, 0x34b0bcb5, 0x391c0cb3, 0x4ed8aa4a, 0x5b9cca4f, 0x682e6ff3,
        0x748f82ee, 0x78a5636f, 0x84c87814, 0x8cc70208, 0x90befffa, 0xa4506ceb, 0xbef9a3f7,
        0xc67178f2,
    ];
    let mut h: [u32; 8] = [
        0x6a09e667, 0xbb67ae85, 0x3c6ef372, 0xa54ff53a, 0x510e527f, 0x9b05688c, 0x1f83d9ab,
        0x5be0cd19,
    ];
    let bit_len = (data.len() as u64) * 8;
    let mut msg = data.to_vec();
    msg.push(0x80);
    while msg.len() % 64 != 56 {
        msg.push(0);
    }
    msg.extend_from_slice(&bit_len.to_be_bytes());
    for chunk in msg.chunks_exact(64) {
        let mut w = [0u32; 64];
        for (i, word) in chunk.chunks_exact(4).enumerate() {
            w[i] = u32::from_be_bytes([word[0], word[1], word[2], word[3]]);
        }
        for i in 16..64 {
            let s0 = w[i - 15].rotate_right(7) ^ w[i - 15].rotate_right(18) ^ (w[i - 15] >> 3);
            let s1 = w[i - 2].rotate_right(17) ^ w[i - 2].rotate_right(19) ^ (w[i - 2] >> 10);
            w[i] = w[i - 16]
                .wrapping_add(s0)
                .wrapping_add(w[i - 7])
                .wrapping_add(s1);
        }
        let (mut a, mut b, mut c, mut d, mut e, mut f, mut g, mut hh) =
            (h[0], h[1], h[2], h[3], h[4], h[5], h[6], h[7]);
        for i in 0..64 {
            let s1 = e.rotate_right(6) ^ e.rotate_right(11) ^ e.rotate_right(25);
            let ch = (e & f) ^ (!e & g);
            let t1 = hh
                .wrapping_add(s1)
                .wrapping_add(ch)
                .wrapping_add(K[i])
                .wrapping_add(w[i]);
            let s0 = a.rotate_right(2) ^ a.rotate_right(13) ^ a.rotate_right(22);
            let maj = (a & b) ^ (a & c) ^ (b & c);
            let t2 = s0.wrapping_add(maj);
            hh = g;
            g = f;
            f = e;
            e = d.wrapping_add(t1);
            d = c;
            c = b;
            b = a;
            a = t1.wrapping_add(t2);
        }
        h[0] = h[0].wrapping_add(a);
        h[1] = h[1].wrapping_add(b);
        h[2] = h[2].wrapping_add(c);
        h[3] = h[3].wrapping_add(d);
        h[4] = h[4].wrapping_add(e);
        h[5] = h[5].wrapping_add(f);
        h[6] = h[6].wrapping_add(g);
        h[7] = h[7].wrapping_add(hh);
    }
    h.iter().map(|x| format!("{x:08x}")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "schema": 1,
      "jax_version": "0.8.2",
      "param_layout": ["s0","strike","rate","sigma","maturity","barrier","_r6","_r7"],
      "variants": [
        {"name": "mc_european_n4096_s1", "payoff": "european", "n": 4096,
         "steps": 1, "block": 4096, "file": "mc_european_n4096_s1.hlo.txt",
         "sha256": "deadbeef", "inputs": [], "outputs": []},
        {"name": "mc_european_n16384_s1", "payoff": "european", "n": 16384,
         "steps": 1, "block": 4096, "file": "mc_european_n16384_s1.hlo.txt",
         "sha256": "deadbeef", "inputs": [], "outputs": []}
      ]
    }"#;

    #[test]
    fn parses_sample_manifest() {
        let m = Manifest::parse(Path::new("/tmp/a"), SAMPLE).unwrap();
        assert_eq!(m.variants.len(), 2);
        assert_eq!(m.jax_version, "0.8.2");
        assert_eq!(m.variants[0].payoff, Payoff::European);
        assert_eq!(m.variants[1].n, 16384);
    }

    #[test]
    fn variants_for_sorts_ascending() {
        let m = Manifest::parse(Path::new("/tmp/a"), SAMPLE).unwrap();
        let vs = m.variants_for(Payoff::European);
        assert_eq!(vs.len(), 2);
        assert!(vs[0].n < vs[1].n);
        assert!(m.variants_for(Payoff::Asian).is_empty());
    }

    #[test]
    fn rejects_bad_schema() {
        let bad = SAMPLE.replace("\"schema\": 1", "\"schema\": 99");
        assert!(Manifest::parse(Path::new("/tmp/a"), &bad).is_err());
    }

    #[test]
    fn rejects_unknown_payoff() {
        let bad = SAMPLE.replace("european", "swaption");
        assert!(Manifest::parse(Path::new("/tmp/a"), &bad).is_err());
    }

    #[test]
    fn rejects_empty_variants() {
        let bad = r#"{"schema": 1, "variants": []}"#;
        assert!(Manifest::parse(Path::new("/tmp/a"), bad).is_err());
    }

    #[test]
    fn sha256_known_vectors() {
        assert_eq!(
            sha256_hex(b""),
            "e3b0c44298fc1c149afbf4c8996fb92427ae41e4649b934ca495991b7852b855"
        );
        assert_eq!(
            sha256_hex(b"abc"),
            "ba7816bf8f01cfea414140de5dae2223b00361a396177a9cb410ff61f20015ad"
        );
        // Multi-block message (>64 bytes).
        assert_eq!(
            sha256_hex(b"abcdbcdecdefdefgefghfghighijhijkijkljklmklmnlmnomnopnopq"),
            "248d6a61d20638b8e5c026930c3e6039a33ce45964ff2167f6ecedd419db06c1"
        );
    }
}
