//! PJRT execution engine: compile-once, execute-many chunk pricing.

use std::collections::HashMap;
use std::path::Path;
use std::sync::Mutex;

use anyhow::{anyhow, bail, Context, Result};

use crate::pricing::mc::PayoffStats;
use crate::workload::option::{OptionTask, Payoff};

use super::artifact::{Manifest, Variant};

/// A compiled chunk executable plus its metadata.
struct Compiled {
    variant: Variant,
    exe: xla::PjRtLoadedExecutable,
}

/// The PJRT engine. One per process; `execute` is serialized internally
/// (the CPU PJRT client is itself single-device).
pub struct Engine {
    manifest: Manifest,
    client: xla::PjRtClient,
    /// Compiled executables by variant name, built lazily.
    compiled: Mutex<HashMap<String, Compiled>>,
}

impl Engine {
    /// Create an engine over an artifact directory (runs `Manifest::load`).
    pub fn load(artifact_dir: &Path) -> Result<Engine> {
        let manifest = Manifest::load(artifact_dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Engine { manifest, client, compiled: Mutex::new(HashMap::new()) })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform_name(&self) -> String {
        self.client.platform_name()
    }

    /// Compile every variant up front (otherwise compilation is lazy).
    pub fn warmup(&self) -> Result<()> {
        for v in self.manifest.variants.clone() {
            self.ensure_compiled(&v)?;
        }
        Ok(())
    }

    fn ensure_compiled(&self, v: &Variant) -> Result<()> {
        let mut map = self.compiled.lock().unwrap();
        if map.contains_key(&v.name) {
            return Ok(());
        }
        let path = self.manifest.hlo_path(v);
        let path_str = path
            .to_str()
            .ok_or_else(|| anyhow!("non-utf8 artifact path {path:?}"))?;
        let proto = xla::HloModuleProto::from_text_file(path_str)
            .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", v.name))?;
        map.insert(v.name.clone(), Compiled { variant: v.clone(), exe });
        Ok(())
    }

    /// Execute one chunk of `variant` for `task` at path-counter `offset`.
    pub fn execute_chunk(
        &self,
        variant_name: &str,
        task: &OptionTask,
        seed: u32,
        offset: u32,
    ) -> Result<PayoffStats> {
        let (n, sum, sum_sq) = {
            let map = self.compiled.lock().unwrap();
            let c = map
                .get(variant_name)
                .ok_or_else(|| anyhow!("variant {variant_name} not compiled"))?;
            let params = xla::Literal::vec1(&task.to_params());
            let key = xla::Literal::vec1(&[task.id as u32, seed]);
            let off = xla::Literal::vec1(&[offset]);
            let result = c
                .exe
                .execute::<xla::Literal>(&[params, key, off])
                .with_context(|| format!("executing {variant_name}"))?[0][0]
                .to_literal_sync()?;
            // aot.py lowers with return_tuple=True: (sum, sum_sq).
            let (sum_l, sq_l) = result.to_tuple2()?;
            (
                c.variant.n,
                sum_l.to_vec::<f32>()?[0] as f64,
                sq_l.to_vec::<f32>()?[0] as f64,
            )
        };
        // AOT artifacts predate the Greek accumulators; price-only stats.
        Ok(PayoffStats { sum, sum_sq, n, ..Default::default() })
    }

    /// Price `n` paths of `task` by looping chunk executions with advancing
    /// counter offsets. Greedy large-chunk-first cover; the trailing partial
    /// chunk is rounded *up* to the smallest available variant, so the
    /// returned `stats.n` may slightly exceed the requested `n` (documented
    /// behaviour — extra unbiased paths only tighten the estimate).
    pub fn price(&self, task: &OptionTask, n: u64, seed: u32) -> Result<PayoffStats> {
        let variants = self.manifest.variants_for(task.payoff);
        if variants.is_empty() {
            bail!("no artifacts for payoff {}", task.payoff.name());
        }
        for v in &variants {
            self.ensure_compiled(v)?;
        }
        let mut stats = PayoffStats::default();
        let mut offset: u64 = 0;
        while stats.n < n {
            let remaining = n - stats.n;
            // Largest variant that fits, else the smallest (overshoot).
            let v = variants
                .iter()
                .rev()
                .find(|v| v.n <= remaining)
                .unwrap_or(&variants[0]);
            if offset + v.n > u32::MAX as u64 {
                bail!("path counter overflow: task {} needs > 2^32 paths per (seed) stream", task.id);
            }
            let chunk = self.execute_chunk(&v.name, task, seed, offset as u32)?;
            offset += chunk.n;
            stats = stats.merge(&chunk);
        }
        Ok(stats)
    }

    /// Names of the payoff families with at least one artifact.
    pub fn supported_payoffs(&self) -> Vec<Payoff> {
        let mut out = vec![];
        for p in [Payoff::European, Payoff::Asian, Payoff::Barrier] {
            if !self.manifest.variants_for(p).is_empty() {
                out.push(p);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    //! Engine tests live in `rust/tests/runtime_integration.rs` — they need
    //! built artifacts, which unit tests must not depend on. Kept here:
    //! pure logic tests of the chunk-cover planner.

    use super::*;

    #[test]
    fn chunk_cover_plan_shapes() {
        // Simulate the greedy cover: variants 4096/16384/65536 covering
        // n = 70_000 -> 65536 + 4096 + (overshoot) 4096 = 73_728? No:
        // 65536 <= 70000, then remaining 4464 -> 4096, then remaining 368
        // -> smallest 4096 overshoot. Total 73728.
        let sizes = [4096u64, 16384, 65536];
        let mut covered = 0u64;
        let n = 70_000u64;
        let mut executions = 0;
        while covered < n {
            let remaining = n - covered;
            let v = sizes.iter().rev().find(|s| **s <= remaining).unwrap_or(&sizes[0]);
            covered += v;
            executions += 1;
        }
        assert_eq!(covered, 73_728);
        assert_eq!(executions, 3);
    }
}
