//! Per-connection state for the serve event loop: frame extraction (newline
//! and `lp1` length-prefixed modes), the sequence-ordered response slots
//! that keep pipelined responses in request order even when requests fan
//! out across shards, and the bounded non-blocking write queue.

use std::collections::BTreeMap;
use std::io::{self, Read, Write};
use std::net::TcpStream;
use std::time::Instant;

/// Wire framing of one connection direction (reads and writes switch
/// together at the `"framing":"lp1"` negotiation point).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Framing {
    /// Newline-delimited JSON — the protocol v1 default, byte-compatible
    /// with every pre-lp1 client.
    Newline,
    /// `lp1`: a 4-byte big-endian u32 payload length, then exactly that
    /// many bytes of JSON. No trailing newline.
    Lp1,
}

/// Encode one JSON text as an `lp1` frame (client helpers and the write
/// path share this so the wire layout has a single definition).
pub fn lp1_frame(json_text: &str) -> Vec<u8> {
    let payload = json_text.as_bytes();
    let mut frame = Vec::with_capacity(4 + payload.len());
    frame.extend_from_slice(&(payload.len() as u32).to_be_bytes());
    frame.extend_from_slice(payload);
    frame
}

/// Read one `lp1` frame from a blocking reader — the client-side twin of
/// [`lp1_frame`], used by tests and the `perf_serve` bench.
pub fn lp1_read(reader: &mut impl Read) -> io::Result<String> {
    let mut len = [0u8; 4];
    reader.read_exact(&mut len)?;
    let len = u32::from_be_bytes(len) as usize;
    let mut payload = vec![0u8; len];
    reader.read_exact(&mut payload)?;
    String::from_utf8(payload)
        .map_err(|e| io::Error::new(io::ErrorKind::InvalidData, format!("lp1 payload: {e}")))
}

/// Why frame extraction failed; both cases answer with a typed protocol
/// error and close the connection after the error flushes.
#[derive(Debug)]
pub enum FrameError {
    /// The accumulated request exceeds `[serve] max_request_bytes`.
    TooLarge { limit: usize },
    /// An `lp1` header announced a zero or over-limit length.
    BadLength { len: usize, limit: usize },
}

/// One response slot: wire-ready bytes accumulate here until the slot is
/// both finished and at the front of the connection's sequence order.
struct Slot {
    framing: Framing,
    bytes: Vec<u8>,
    done: bool,
}

/// Cap on a connection's total buffered output (slots + flush buffer). A
/// client that streams a run but never reads would otherwise buffer without
/// bound; past the cap the connection is dropped as a slow consumer.
pub const MAX_CONN_BUFFER: usize = 4 << 20;

/// Read chunk size per readiness event.
const READ_CHUNK: usize = 16 * 1024;

/// Most bytes drained from the socket per [`Conn::fill`] call. The poller
/// is level-triggered, so leftover bytes re-surface as readiness on the
/// next wait — capping the burst keeps one firehose client from starving
/// every other connection for the duration of its backlog.
const FILL_BURST: usize = 8 * READ_CHUNK;

/// One live connection owned by the event loop.
pub struct Conn {
    pub stream: TcpStream,
    pub token: u64,
    /// Read-side framing for the *next* frame (negotiation switches it
    /// mid-buffer; already-buffered bytes are re-interpreted in the new
    /// mode, which is exactly what a pipelining negotiator wants).
    pub framing: Framing,
    read_buf: Vec<u8>,
    /// Sequence number assigned to the next decoded request.
    next_seq: u64,
    /// Sequence currently (or next) being written out.
    next_write: u64,
    slots: BTreeMap<u64, Slot>,
    out: Vec<u8>,
    out_pos: usize,
    /// Requests decoded but not yet answered (streamers count until their
    /// final line).
    pub inflight: usize,
    /// When the connection last completed a frame or finished flushing all
    /// output — the idle-timeout clock.
    pub idle_since: Instant,
    /// Set while `read_buf` holds an incomplete frame: the slow-loris
    /// deadline measures from the first byte of the partial frame, so a
    /// byte-per-second drip never resets it.
    pub frame_started: Option<Instant>,
    /// Peer half-closed its write side; serve remaining responses, then
    /// drop.
    pub eof: bool,
    /// Close as soon as every queued and in-flight response has flushed
    /// (set after fatal protocol errors and timeouts). Set it via
    /// [`Conn::begin_close`] so the grace clock is stamped.
    pub closing: bool,
    /// When `closing` was first set: bounds how long a closing connection
    /// may wait for in-flight responses before being torn down regardless.
    pub closing_since: Option<Instant>,
}

impl Conn {
    pub fn new(stream: TcpStream, token: u64, now: Instant) -> Conn {
        Conn {
            stream,
            token,
            framing: Framing::Newline,
            read_buf: Vec::new(),
            next_seq: 0,
            next_write: 0,
            slots: BTreeMap::new(),
            out: Vec::new(),
            out_pos: 0,
            inflight: 0,
            idle_since: now,
            frame_started: None,
            eof: false,
            closing: false,
            closing_since: None,
        }
    }

    /// Mark the connection for close-once-drained, stamping the grace
    /// clock on the first call (repeat calls keep the original deadline).
    pub fn begin_close(&mut self) {
        if !self.closing {
            self.closing = true;
            self.closing_since = Some(Instant::now());
        }
    }

    /// Non-blocking read until `WouldBlock`/EOF — bounded per call by
    /// [`FILL_BURST`] and by `max_buffered` bytes already queued (a
    /// newline frame past the request-size limit errors in `next_frame`
    /// without buffering the rest of the burst; level-triggered polling
    /// re-delivers whatever stayed in the kernel buffer). Returns
    /// `Ok(true)` if any bytes arrived; EOF sets `self.eof`. Errors mean
    /// the connection is gone.
    pub fn fill(&mut self, max_buffered: usize) -> io::Result<bool> {
        let mut any = false;
        let mut total = 0usize;
        let mut chunk = [0u8; READ_CHUNK];
        // The 4-byte headroom is the lp1 header: a frame of exactly
        // `max_buffered` payload bytes needs `4 + max_buffered` in the
        // buffer, so whenever this loop refuses to read, `next_frame` is
        // guaranteed to extract a frame or raise a typed error — refusal
        // can never strand a legitimate frame.
        while total < FILL_BURST && self.read_buf.len() <= max_buffered.saturating_add(4) {
            match self.stream.read(&mut chunk) {
                Ok(0) => {
                    self.eof = true;
                    break;
                }
                Ok(n) => {
                    if self.read_buf.is_empty() {
                        self.frame_started = Some(Instant::now());
                    }
                    self.read_buf.extend_from_slice(&chunk[..n]);
                    total += n;
                    any = true;
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(e) => return Err(e),
            }
        }
        Ok(any)
    }

    /// Extract the next complete frame as JSON text, in the current
    /// framing. `Ok(None)` = need more bytes.
    pub fn next_frame(&mut self, max_request_bytes: usize) -> Result<Option<String>, FrameError> {
        let frame = match self.framing {
            Framing::Newline => {
                match self.read_buf.iter().position(|&b| b == b'\n') {
                    Some(pos) => {
                        let mut line: Vec<u8> = self.read_buf.drain(..=pos).collect();
                        line.pop(); // the newline
                        if line.last() == Some(&b'\r') {
                            line.pop();
                        }
                        Some(line)
                    }
                    None if self.read_buf.len() > max_request_bytes => {
                        return Err(FrameError::TooLarge { limit: max_request_bytes });
                    }
                    None => None,
                }
            }
            Framing::Lp1 => {
                if self.read_buf.len() < 4 {
                    None
                } else {
                    let len = u32::from_be_bytes([
                        self.read_buf[0],
                        self.read_buf[1],
                        self.read_buf[2],
                        self.read_buf[3],
                    ]) as usize;
                    if len == 0 || len > max_request_bytes {
                        return Err(FrameError::BadLength { len, limit: max_request_bytes });
                    }
                    if self.read_buf.len() < 4 + len {
                        None
                    } else {
                        self.read_buf.drain(..4);
                        let payload: Vec<u8> = self.read_buf.drain(..len).collect();
                        Some(payload)
                    }
                }
            }
        };
        match frame {
            Some(bytes) => {
                let now = Instant::now();
                self.idle_since = now;
                self.frame_started = if self.read_buf.is_empty() { None } else { Some(now) };
                // Lossy decode: invalid UTF-8 becomes a JSON parse error at
                // the request layer, not a dropped connection.
                Ok(Some(String::from_utf8_lossy(&bytes).into_owned()))
            }
            None => Ok(None),
        }
    }

    /// Whether a partial frame is pending (the slow-loris clock is armed).
    pub fn has_partial_frame(&self) -> bool {
        !self.read_buf.is_empty()
    }

    /// Open the next response slot, recording the framing its bytes must be
    /// encoded with. Returns the slot's sequence number.
    pub fn open_slot(&mut self, framing: Framing) -> u64 {
        let seq = self.next_seq;
        self.next_seq += 1;
        self.slots.insert(seq, Slot { framing, bytes: Vec::new(), done: false });
        seq
    }

    /// Append one JSON line to a slot (interim streaming event lines use
    /// this repeatedly before `finish`). Unknown seqs are ignored — the
    /// connection may have been reset while a streamer was still running.
    pub fn append(&mut self, seq: u64, json_text: &str) {
        if let Some(slot) = self.slots.get_mut(&seq) {
            match slot.framing {
                Framing::Newline => {
                    slot.bytes.extend_from_slice(json_text.as_bytes());
                    slot.bytes.push(b'\n');
                }
                Framing::Lp1 => slot.bytes.extend_from_slice(&lp1_frame(json_text)),
            }
        }
    }

    /// Append the slot's final line and mark it complete.
    pub fn finish(&mut self, seq: u64, json_text: &str) {
        self.append(seq, json_text);
        if let Some(slot) = self.slots.get_mut(&seq) {
            slot.done = true;
        }
    }

    /// Move ready bytes from in-order slots into the flush buffer. A slot
    /// releases bytes as they arrive (streaming), but the cursor only
    /// advances past a slot once it is done — later sequences wait.
    pub fn pump(&mut self) {
        loop {
            let Some(slot) = self.slots.get_mut(&self.next_write) else { break };
            self.out.append(&mut slot.bytes);
            if !slot.done {
                break;
            }
            self.slots.remove(&self.next_write);
            self.next_write += 1;
        }
    }

    /// Non-blocking flush. Returns `Ok(true)` while bytes remain queued
    /// (write interest should stay registered). Errors mean the connection
    /// is gone.
    pub fn flush(&mut self) -> io::Result<bool> {
        while self.out_pos < self.out.len() {
            match self.stream.write(&self.out[self.out_pos..]) {
                Ok(0) => {
                    return Err(io::Error::new(io::ErrorKind::WriteZero, "socket write of 0"))
                }
                Ok(n) => self.out_pos += n,
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => return Ok(true),
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(e) => return Err(e),
            }
        }
        self.out.clear();
        self.out_pos = 0;
        if self.inflight == 0 {
            self.idle_since = Instant::now();
        }
        Ok(false)
    }

    /// Bytes queued anywhere on the write side (unflushed buffer or slots
    /// still waiting their turn).
    pub fn has_pending_output(&self) -> bool {
        self.out_pos < self.out.len() || self.slots.values().any(|s| !s.bytes.is_empty() || s.done)
    }

    /// Total buffered output, for the slow-consumer cap.
    pub fn buffered_bytes(&self) -> usize {
        (self.out.len() - self.out_pos) + self.slots.values().map(|s| s.bytes.len()).sum::<usize>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::TcpListener;

    fn conn_pair() -> (Conn, TcpStream) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = TcpStream::connect(addr).unwrap();
        let (server, _) = listener.accept().unwrap();
        server.set_nonblocking(true).unwrap();
        (Conn::new(server, 2, Instant::now()), client)
    }

    #[test]
    fn newline_frames_split_and_strip_cr() {
        let (mut conn, mut client) = conn_pair();
        client.write_all(b"{\"a\":1}\r\n{\"b\":2}\npartial").unwrap();
        std::thread::sleep(std::time::Duration::from_millis(50));
        assert!(conn.fill(1024).unwrap());
        assert_eq!(conn.next_frame(1024).unwrap().as_deref(), Some("{\"a\":1}"));
        assert_eq!(conn.next_frame(1024).unwrap().as_deref(), Some("{\"b\":2}"));
        assert_eq!(conn.next_frame(1024).unwrap(), None);
        assert!(conn.has_partial_frame());
    }

    #[test]
    fn oversized_newline_request_is_a_frame_error() {
        let (mut conn, mut client) = conn_pair();
        client.write_all(&vec![b'x'; 200]).unwrap();
        std::thread::sleep(std::time::Duration::from_millis(50));
        conn.fill(100).unwrap();
        assert!(matches!(conn.next_frame(100), Err(FrameError::TooLarge { limit: 100 })));
    }

    #[test]
    fn lp1_frames_roundtrip_and_validate_length() {
        let (mut conn, mut client) = conn_pair();
        conn.framing = Framing::Lp1;
        client.write_all(&lp1_frame("{\"op\":\"ping\"}")).unwrap();
        client.write_all(&[0, 0, 0, 0]).unwrap(); // zero-length header
        std::thread::sleep(std::time::Duration::from_millis(50));
        conn.fill(1024).unwrap();
        assert_eq!(conn.next_frame(1024).unwrap().as_deref(), Some("{\"op\":\"ping\"}"));
        assert!(matches!(conn.next_frame(1024), Err(FrameError::BadLength { len: 0, .. })));
    }

    #[test]
    fn slots_reorder_responses_into_sequence_order() {
        let (mut conn, _client) = conn_pair();
        let a = conn.open_slot(Framing::Newline);
        let b = conn.open_slot(Framing::Newline);
        // Finish out of order: b first.
        conn.finish(b, "{\"second\":true}");
        conn.pump();
        assert!(conn.out.is_empty(), "b must wait for a");
        assert!(conn.has_pending_output(), "b's bytes are queued behind a");
        conn.finish(a, "{\"first\":true}");
        conn.pump();
        let queued = String::from_utf8(conn.out.clone()).unwrap();
        assert_eq!(queued, "{\"first\":true}\n{\"second\":true}\n");
    }

    #[test]
    fn streaming_slot_releases_interim_lines_before_done() {
        let (mut conn, _client) = conn_pair();
        let a = conn.open_slot(Framing::Newline);
        conn.append(a, "{\"event\":\"started\"}");
        conn.pump();
        let queued = String::from_utf8(conn.out.clone()).unwrap();
        assert_eq!(queued, "{\"event\":\"started\"}\n");
        // Not done yet: a later slot must not jump the queue.
        let b = conn.open_slot(Framing::Newline);
        conn.finish(b, "{\"b\":1}");
        conn.pump();
        assert!(!String::from_utf8(conn.out.clone()).unwrap().contains("\"b\""));
        conn.finish(a, "{\"ok\":true}");
        conn.pump();
        let queued = String::from_utf8(conn.out.clone()).unwrap();
        assert_eq!(queued, "{\"event\":\"started\"}\n{\"ok\":true}\n{\"b\":1}\n");
    }

    #[test]
    fn fill_caps_the_bytes_read_per_call() {
        let (mut conn, client) = conn_pair();
        // A writer thread pushes well past FILL_BURST (write_all would
        // deadlock a single thread once the socket buffers fill).
        let payload = vec![b'x'; FILL_BURST * 2];
        let writer = std::thread::spawn(move || {
            let mut client = client;
            client.write_all(&payload).unwrap();
            client.flush().unwrap();
        });
        // Drain in bounded bites: no single call may exceed the burst cap.
        let mut got = 0usize;
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
        while got < FILL_BURST * 2 {
            assert!(std::time::Instant::now() < deadline, "stalled at {got} bytes");
            conn.fill(usize::MAX).unwrap();
            // The cap is checked before each chunk read, so one call can
            // overshoot by at most a chunk.
            assert!(
                conn.read_buf.len() < FILL_BURST + READ_CHUNK,
                "one fill buffered {} bytes (cap {FILL_BURST})",
                conn.read_buf.len()
            );
            got += conn.read_buf.len();
            conn.read_buf.clear();
        }
        writer.join().unwrap();

        // And the buffered-bytes bail-out: once read_buf is past the cap
        // handed in, fill stops growing it (modulo one final chunk).
        let (mut conn, mut client) = conn_pair();
        client.write_all(&vec![b'y'; 4 * READ_CHUNK]).unwrap();
        std::thread::sleep(std::time::Duration::from_millis(50));
        conn.fill(64).unwrap();
        assert!(
            conn.read_buf.len() <= 64 + READ_CHUNK,
            "fill kept reading past its buffer cap: {}",
            conn.read_buf.len()
        );
    }

    #[test]
    fn lp1_encode_decode_roundtrip() {
        let frame = lp1_frame("{\"v\":1}");
        assert_eq!(&frame[..4], &[0, 0, 0, 7]);
        let mut cursor = std::io::Cursor::new(frame);
        assert_eq!(lp1_read(&mut cursor).unwrap(), "{\"v\":1}");
    }
}
