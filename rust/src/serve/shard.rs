//! Consistent-hash shard map for the serve plane.
//!
//! The shard map assigns `(strategy, quantized budget)` keys to one of N
//! shards via a consistent-hash ring (64 virtual nodes per shard, FNV-1a
//! points). Two independent consumers share the same map so their notions
//! of ownership can never drift:
//!
//! - the `SolutionCache` inside [`TradeoffSession`](crate::api::TradeoffSession)
//!   partitions its stored solutions by it, making each cache slice
//!   single-writer on the serve hot path;
//! - the serve event loop routes decoded `partition`/`evaluate`/`pareto`/
//!   `batch` requests to the worker shard that owns the same slice, so a
//!   cache line is only ever touched from one worker.
//!
//! Consistent hashing (rather than `hash % N`) keeps resharding cheap: when
//! `[serve] shards` grows from N to N+1, only ~1/(N+1) of the keys move —
//! the property test in `rust/tests/serve_plane.rs` pins this down.

/// Cache keys quantize budgets to this resolution (dollars): budgets closer
/// than a nano-dollar share an entry, so repeated float-level jitter of the
/// same budget still hits.
pub const BUDGET_QUANTUM: f64 = 1e-9;

/// `(quantized, disambiguator)`. The second word is 0 for every budget in
/// the quantizable range; budgets too large to quantize (≳ $9.2e9) carry
/// their exact bit pattern instead, so distinct huge budgets never collide
/// on the saturated first word.
pub type BudgetKey = (i64, u64);

/// Quantize a budget for cache keying and shard routing. `None` (an
/// unconstrained solve) stays `None` — it is its own key.
pub fn quantize(budget: Option<f64>) -> Option<BudgetKey> {
    budget.map(|b| {
        let q = (b / BUDGET_QUANTUM).round();
        if q.is_finite() && q.abs() < i64::MAX as f64 {
            (q as i64, 0)
        } else {
            (i64::MAX, b.to_bits())
        }
    })
}

/// 64-bit FNV-1a — the repo-idiomatic no-deps hash; good avalanche for ring
/// points and stable across platforms and sessions (routing must be
/// deterministic for the differential tests to hold).
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Virtual nodes per shard. 64 points keeps the per-shard key share within
/// a few percent of 1/N while the ring stays tiny (N*64 u64 pairs).
const VNODES: usize = 64;

/// A consistent-hash ring mapping solve keys to shard indices `0..shards`.
#[derive(Debug, Clone)]
pub struct ShardMap {
    shards: usize,
    /// Sorted `(ring point, shard)` pairs, VNODES per shard.
    ring: Vec<(u64, usize)>,
}

impl ShardMap {
    /// Build the ring for `shards` shards (>= 1; the config layer enforces
    /// the bound, this asserts it).
    pub fn new(shards: usize) -> ShardMap {
        assert!(shards >= 1, "ShardMap requires at least one shard");
        let mut ring = Vec::with_capacity(shards * VNODES);
        for shard in 0..shards {
            for vnode in 0..VNODES {
                let mut key = [0u8; 16];
                key[..8].copy_from_slice(&(shard as u64).to_le_bytes());
                key[8..].copy_from_slice(&(vnode as u64).to_le_bytes());
                ring.push((fnv1a(&key), shard));
            }
        }
        ring.sort_unstable();
        ShardMap { shards, ring }
    }

    /// Number of shards this map distributes over.
    pub fn shards(&self) -> usize {
        self.shards
    }

    /// Shard owning a raw key hash: the first ring point at or clockwise of
    /// the hash, wrapping at the top.
    pub fn shard_of_hash(&self, hash: u64) -> usize {
        match self.ring.binary_search_by(|probe| probe.0.cmp(&hash)) {
            Ok(i) => self.ring[i].1,
            Err(i) => self.ring[i % self.ring.len()].1,
        }
    }

    /// Shard owning a `(strategy, quantized budget)` solve key — the cache
    /// slice and worker that request must land on.
    pub fn shard_for(&self, strategy: &str, budget: Option<BudgetKey>) -> usize {
        let mut bytes = Vec::with_capacity(strategy.len() + 18);
        bytes.extend_from_slice(strategy.as_bytes());
        match budget {
            // A distinct marker byte keeps (s, None) from colliding with
            // (s, Some(0)) on identical byte strings.
            None => bytes.push(0xfe),
            Some((q, d)) => {
                bytes.push(0x01);
                bytes.extend_from_slice(&q.to_le_bytes());
                bytes.extend_from_slice(&d.to_le_bytes());
            }
        }
        self.shard_of_hash(fnv1a(&bytes))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_key_maps_to_one_valid_shard() {
        for shards in [1usize, 2, 3, 8] {
            let map = ShardMap::new(shards);
            for i in 0..500 {
                let s = map.shard_for("milp", quantize(Some(i as f64 * 0.37)));
                assert!(s < shards, "{s} out of range for {shards} shards");
                // Deterministic: the same key always routes identically.
                assert_eq!(s, map.shard_for("milp", quantize(Some(i as f64 * 0.37))));
            }
            assert!(map.shard_for("heuristic", None) < shards);
        }
    }

    #[test]
    fn single_shard_maps_everything_to_zero() {
        let map = ShardMap::new(1);
        for i in 0..100 {
            assert_eq!(map.shard_for("x", quantize(Some(i as f64))), 0);
        }
    }

    #[test]
    fn quantize_folds_jitter_but_never_collides() {
        assert_eq!(quantize(Some(2.5)), quantize(Some(2.5 + 1e-12)));
        assert_ne!(quantize(Some(2.5)), quantize(Some(2.6)));
        assert_ne!(quantize(Some(1e10)), quantize(Some(2e10)));
        assert_eq!(quantize(None), None);
    }

    #[test]
    fn distinct_budget_and_none_keys_do_not_alias() {
        // The marker byte separates (s, None) from (s, Some(0)) even though
        // a zero budget's key bytes are all zeros.
        let map = ShardMap::new(7);
        let mut seen = std::collections::HashSet::new();
        seen.insert(("milp", quantize(None)));
        seen.insert(("milp", quantize(Some(0.0))));
        assert_eq!(seen.len(), 2);
        // Both still route deterministically (possibly to the same shard —
        // that is allowed, aliasing of the *keys* is not).
        let _ = map.shard_for("milp", quantize(None));
        let _ = map.shard_for("milp", quantize(Some(0.0)));
    }
}
