//! Hand-rolled readiness polling for the serve event loop.
//!
//! No external crates, per the repo's no-deps idiom: the syscalls are
//! declared directly against the C library that `std` already links. Linux
//! gets epoll (level-triggered — O(ready) wakeups at 10k connections);
//! every other unix gets a portable poll(2) backend behind the same
//! [`Poller`] API. Non-unix targets don't compile this module at all — the
//! serve plane returns a typed runtime error there (see `serve/mod.rs`).
//!
//! The [`Waker`] is the classic self-pipe trick: shard workers (and the
//! shutdown path) write one byte into a non-blocking pipe registered with
//! the poller, turning cross-thread events into first-class readiness —
//! this is what fixes the PR 1 shutdown race where a poke connection could
//! be accepted by a worker before the stop flag was observed.

use std::io;
use std::os::raw::{c_int, c_void};
use std::os::unix::io::RawFd;
use std::sync::Arc;
use std::time::Duration;

/// One readiness event: the registered token plus what the fd is ready for.
#[derive(Debug, Clone, Copy)]
pub struct PollEvent {
    pub token: u64,
    pub readable: bool,
    pub writable: bool,
    /// Peer hangup or socket error — the connection is done either way.
    pub hangup: bool,
}

extern "C" {
    fn pipe(fds: *mut c_int) -> c_int;
    fn fcntl(fd: c_int, cmd: c_int, arg: c_int) -> c_int;
    fn read(fd: c_int, buf: *mut c_void, count: usize) -> isize;
    fn write(fd: c_int, buf: *const c_void, count: usize) -> isize;
    fn close(fd: c_int) -> c_int;
}

const F_GETFL: c_int = 3;
const F_SETFL: c_int = 4;
#[cfg(target_os = "linux")]
const O_NONBLOCK: c_int = 0o4000;
#[cfg(not(target_os = "linux"))]
const O_NONBLOCK: c_int = 0x0004;

fn set_nonblocking(fd: RawFd) -> io::Result<()> {
    // SAFETY: plain fcntl on an fd we own; no pointers involved.
    unsafe {
        let flags = fcntl(fd, F_GETFL, 0);
        if flags < 0 {
            return Err(io::Error::last_os_error());
        }
        if fcntl(fd, F_SETFL, flags | O_NONBLOCK) < 0 {
            return Err(io::Error::last_os_error());
        }
    }
    Ok(())
}

/// Write end of the self-pipe; clone freely across threads. Dropping the
/// last clone closes the fd.
#[derive(Clone)]
pub struct Waker {
    inner: Arc<WakerFd>,
}

struct WakerFd(RawFd);

impl Drop for WakerFd {
    fn drop(&mut self) {
        // SAFETY: we own this fd exclusively.
        unsafe {
            close(self.0);
        }
    }
}

impl Waker {
    /// Make the poller's next (or current) wait return. A full pipe means a
    /// wakeup is already pending — EAGAIN is success here.
    pub fn wake(&self) {
        let byte = 1u8;
        // SAFETY: one-byte write to a pipe fd we own; EAGAIN/EPIPE ignored
        // by design (a pending wakeup or a closed poller both mean "no
        // further action needed").
        unsafe {
            let _ = write(self.inner.0, &byte as *const u8 as *const c_void, 1);
        }
    }
}

/// Drain every pending byte from the pipe's read end so level-triggered
/// polling doesn't spin on an already-delivered wakeup.
fn drain_pipe(fd: RawFd) {
    let mut buf = [0u8; 64];
    // SAFETY: bounded reads into a stack buffer from a non-blocking fd.
    unsafe {
        while read(fd, buf.as_mut_ptr() as *mut c_void, buf.len()) > 0 {}
    }
}

/// Reserved token for the self-pipe; the event loop never sees it — pipe
/// readiness is drained internally and surfaces as a plain (possibly
/// event-less) return from [`Poller::wait`].
const WAKE_TOKEN: u64 = u64::MAX;

fn new_pipe() -> io::Result<(RawFd, RawFd)> {
    let mut fds = [0 as c_int; 2];
    // SAFETY: pipe() writes two fds into the array we hand it.
    if unsafe { pipe(fds.as_mut_ptr()) } != 0 {
        return Err(io::Error::last_os_error());
    }
    let (r, w) = (fds[0], fds[1]);
    if let Err(e) = set_nonblocking(r).and_then(|()| set_nonblocking(w)) {
        // SAFETY: closing the two fds we just created.
        unsafe {
            close(r);
            close(w);
        }
        return Err(e);
    }
    Ok((r, w))
}

// ---------------------------------------------------------------------------
// Linux backend: epoll.
// ---------------------------------------------------------------------------
#[cfg(target_os = "linux")]
mod sys {
    use super::*;

    pub const EPOLLIN: u32 = 0x001;
    pub const EPOLLOUT: u32 = 0x004;
    pub const EPOLLERR: u32 = 0x008;
    pub const EPOLLHUP: u32 = 0x010;
    pub const EPOLLRDHUP: u32 = 0x2000;
    const EPOLL_CTL_ADD: c_int = 1;
    const EPOLL_CTL_DEL: c_int = 2;
    const EPOLL_CTL_MOD: c_int = 3;

    /// Kernel ABI: packed on x86-64, natural alignment elsewhere (mirrors
    /// glibc's declaration).
    #[cfg_attr(target_arch = "x86_64", repr(C, packed))]
    #[cfg_attr(not(target_arch = "x86_64"), repr(C))]
    #[derive(Clone, Copy)]
    pub struct EpollEvent {
        pub events: u32,
        pub data: u64,
    }

    extern "C" {
        fn epoll_create1(flags: c_int) -> c_int;
        fn epoll_ctl(epfd: c_int, op: c_int, fd: c_int, event: *mut EpollEvent) -> c_int;
        fn epoll_wait(
            epfd: c_int,
            events: *mut EpollEvent,
            maxevents: c_int,
            timeout_ms: c_int,
        ) -> c_int;
    }

    pub struct Backend {
        epfd: RawFd,
        buf: Vec<EpollEvent>,
    }

    impl Drop for Backend {
        fn drop(&mut self) {
            // SAFETY: closing the epoll fd we created.
            unsafe {
                close(self.epfd);
            }
        }
    }

    // RDHUP rides read interest only: once a connection stops reading (eof
    // observed, drain mode), a level-triggered RDHUP would otherwise wake
    // every wait until the fd closes. EPOLLHUP/EPOLLERR are unmaskable, so
    // true hangups still surface.
    fn interest_mask(readable: bool, writable: bool) -> u32 {
        let mut events = 0;
        if readable {
            events |= EPOLLIN | EPOLLRDHUP;
        }
        if writable {
            events |= EPOLLOUT;
        }
        events
    }

    impl Backend {
        pub fn new() -> io::Result<Backend> {
            // SAFETY: epoll_create1 takes no pointers.
            let epfd = unsafe { epoll_create1(0) };
            if epfd < 0 {
                return Err(io::Error::last_os_error());
            }
            Ok(Backend { epfd, buf: vec![EpollEvent { events: 0, data: 0 }; 1024] })
        }

        fn ctl(&self, op: c_int, fd: RawFd, events: u32, token: u64) -> io::Result<()> {
            let mut ev = EpollEvent { events, data: token };
            // SAFETY: a valid epoll fd and a live event struct.
            if unsafe { epoll_ctl(self.epfd, op, fd, &mut ev) } != 0 {
                return Err(io::Error::last_os_error());
            }
            Ok(())
        }

        pub fn register(
            &mut self,
            fd: RawFd,
            token: u64,
            readable: bool,
            writable: bool,
        ) -> io::Result<()> {
            self.ctl(EPOLL_CTL_ADD, fd, interest_mask(readable, writable), token)
        }

        pub fn modify(
            &mut self,
            fd: RawFd,
            token: u64,
            readable: bool,
            writable: bool,
        ) -> io::Result<()> {
            self.ctl(EPOLL_CTL_MOD, fd, interest_mask(readable, writable), token)
        }

        pub fn deregister(&mut self, fd: RawFd) -> io::Result<()> {
            self.ctl(EPOLL_CTL_DEL, fd, 0, 0)
        }

        pub fn wait(
            &mut self,
            timeout: Option<Duration>,
            out: &mut Vec<PollEvent>,
        ) -> io::Result<()> {
            let timeout_ms = timeout
                .map(|d| d.as_millis().min(c_int::MAX as u128) as c_int)
                .unwrap_or(-1);
            // SAFETY: buf is a live, correctly-sized array for the kernel
            // to fill; n caps how much of it we read back.
            let n = unsafe {
                epoll_wait(self.epfd, self.buf.as_mut_ptr(), self.buf.len() as c_int, timeout_ms)
            };
            if n < 0 {
                let e = io::Error::last_os_error();
                if e.kind() == io::ErrorKind::Interrupted {
                    return Ok(());
                }
                return Err(e);
            }
            for ev in &self.buf[..n as usize] {
                // Copy out of the (possibly packed) struct before use.
                let events = ev.events;
                let token = ev.data;
                out.push(PollEvent {
                    token,
                    readable: events & EPOLLIN != 0,
                    writable: events & EPOLLOUT != 0,
                    hangup: events & (EPOLLHUP | EPOLLERR | EPOLLRDHUP) != 0,
                });
            }
            Ok(())
        }
    }
}

// ---------------------------------------------------------------------------
// Portable unix backend: poll(2).
// ---------------------------------------------------------------------------
#[cfg(not(target_os = "linux"))]
mod sys {
    use super::*;
    use std::collections::BTreeMap;
    use std::os::raw::c_ulong;

    const POLLIN: i16 = 0x001;
    const POLLOUT: i16 = 0x004;
    const POLLERR: i16 = 0x008;
    const POLLHUP: i16 = 0x010;

    #[repr(C)]
    #[derive(Clone, Copy)]
    struct PollFd {
        fd: c_int,
        events: i16,
        revents: i16,
    }

    extern "C" {
        fn poll(fds: *mut PollFd, nfds: c_ulong, timeout_ms: c_int) -> c_int;
    }

    pub struct Backend {
        /// fd -> (token, readable, writable); rebuilt into a PollFd array
        /// every wait. O(n) per call — acceptable for the fallback path.
        registry: BTreeMap<RawFd, (u64, bool, bool)>,
    }

    impl Backend {
        pub fn new() -> io::Result<Backend> {
            Ok(Backend { registry: BTreeMap::new() })
        }

        pub fn register(
            &mut self,
            fd: RawFd,
            token: u64,
            readable: bool,
            writable: bool,
        ) -> io::Result<()> {
            self.registry.insert(fd, (token, readable, writable));
            Ok(())
        }

        pub fn modify(
            &mut self,
            fd: RawFd,
            token: u64,
            readable: bool,
            writable: bool,
        ) -> io::Result<()> {
            self.registry.insert(fd, (token, readable, writable));
            Ok(())
        }

        pub fn deregister(&mut self, fd: RawFd) -> io::Result<()> {
            self.registry.remove(&fd);
            Ok(())
        }

        pub fn wait(
            &mut self,
            timeout: Option<Duration>,
            out: &mut Vec<PollEvent>,
        ) -> io::Result<()> {
            let mut fds: Vec<PollFd> = self
                .registry
                .iter()
                .map(|(&fd, &(_, r, w))| PollFd {
                    fd,
                    events: (if r { POLLIN } else { 0 }) | (if w { POLLOUT } else { 0 }),
                    revents: 0,
                })
                .collect();
            let timeout_ms = timeout
                .map(|d| d.as_millis().min(c_int::MAX as u128) as c_int)
                .unwrap_or(-1);
            // SAFETY: fds is a live array sized to its length.
            let n = unsafe { poll(fds.as_mut_ptr(), fds.len() as c_ulong, timeout_ms) };
            if n < 0 {
                let e = io::Error::last_os_error();
                if e.kind() == io::ErrorKind::Interrupted {
                    return Ok(());
                }
                return Err(e);
            }
            for pfd in &fds {
                if pfd.revents == 0 {
                    continue;
                }
                let (token, _, _) = self.registry[&pfd.fd];
                out.push(PollEvent {
                    token,
                    readable: pfd.revents & POLLIN != 0,
                    writable: pfd.revents & POLLOUT != 0,
                    hangup: pfd.revents & (POLLERR | POLLHUP) != 0,
                });
            }
            Ok(())
        }
    }
}

/// The readiness poller: register fds under u64 tokens, wait for events.
/// Owns the self-pipe's read end; [`Poller::waker`] hands out write ends.
pub struct Poller {
    backend: sys::Backend,
    pipe_r: RawFd,
    waker: Waker,
}

impl Drop for Poller {
    fn drop(&mut self) {
        // SAFETY: closing the pipe read end we own (the backend closes its
        // own fd in its Drop).
        unsafe {
            close(self.pipe_r);
        }
    }
}

impl Poller {
    pub fn new() -> io::Result<Poller> {
        let mut backend = sys::Backend::new()?;
        let (pipe_r, pipe_w) = new_pipe()?;
        backend.register(pipe_r, WAKE_TOKEN, true, false)?;
        Ok(Poller { backend, pipe_r, waker: Waker { inner: Arc::new(WakerFd(pipe_w)) } })
    }

    /// A cloneable cross-thread wakeup handle.
    pub fn waker(&self) -> Waker {
        self.waker.clone()
    }

    pub fn register(
        &mut self,
        fd: RawFd,
        token: u64,
        readable: bool,
        writable: bool,
    ) -> io::Result<()> {
        assert_ne!(token, WAKE_TOKEN, "token u64::MAX is reserved for the waker");
        self.backend.register(fd, token, readable, writable)
    }

    pub fn modify(
        &mut self,
        fd: RawFd,
        token: u64,
        readable: bool,
        writable: bool,
    ) -> io::Result<()> {
        self.backend.modify(fd, token, readable, writable)
    }

    pub fn deregister(&mut self, fd: RawFd) -> io::Result<()> {
        self.backend.deregister(fd)
    }

    /// Wait for readiness (or a wakeup, or `timeout`), appending events to
    /// `out`. Waker events are drained internally and never surface.
    pub fn wait(&mut self, timeout: Option<Duration>, out: &mut Vec<PollEvent>) -> io::Result<()> {
        let mut raw = Vec::new();
        self.backend.wait(timeout, &mut raw)?;
        for ev in raw {
            if ev.token == WAKE_TOKEN {
                drain_pipe(self.pipe_r);
            } else {
                out.push(ev);
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write as _;
    use std::net::{TcpListener, TcpStream};
    use std::os::unix::io::AsRawFd;

    #[test]
    fn waker_wakes_an_idle_wait() {
        let mut poller = Poller::new().unwrap();
        let waker = poller.waker();
        let started = std::time::Instant::now();
        let handle = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(30));
            waker.wake();
        });
        let mut events = Vec::new();
        // Without the wake this would block for the full 5 seconds.
        poller.wait(Some(Duration::from_secs(5)), &mut events).unwrap();
        assert!(started.elapsed() < Duration::from_secs(4), "wait did not wake early");
        assert!(events.is_empty(), "waker must not surface as an event");
        handle.join().unwrap();
    }

    #[test]
    fn socket_readability_surfaces_with_its_token() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let mut poller = Poller::new().unwrap();
        listener.set_nonblocking(true).unwrap();
        poller.register(listener.as_raw_fd(), 7, true, false).unwrap();

        let mut client = TcpStream::connect(addr).unwrap();
        let mut events = Vec::new();
        poller.wait(Some(Duration::from_secs(5)), &mut events).unwrap();
        assert!(
            events.iter().any(|e| e.token == 7 && e.readable),
            "listener readiness missing: {events:?}"
        );

        // Accept, register the server socket, and observe data readiness.
        let (server, _) = listener.accept().unwrap();
        server.set_nonblocking(true).unwrap();
        poller.register(server.as_raw_fd(), 9, true, false).unwrap();
        client.write_all(b"hi").unwrap();
        let mut events = Vec::new();
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        while !events.iter().any(|e: &PollEvent| e.token == 9 && e.readable) {
            assert!(std::time::Instant::now() < deadline, "no data readiness: {events:?}");
            poller.wait(Some(Duration::from_millis(100)), &mut events).unwrap();
        }
        poller.deregister(server.as_raw_fd()).unwrap();
    }
}
