//! The serve plane's worker shard pool (unix-only, like the event loop).
//!
//! Each shard owns a bounded job queue and a worker thread; decoded
//! requests are routed to the shard that owns their cache slice (see
//! [`ShardMap`](super::shard::ShardMap)), so cache writes on the hot path
//! are single-writer. Workers push results back through the
//! [`CompletionQueue`], whose waker turns "a result is ready" into a
//! first-class event-loop wakeup.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

use crate::api::protocol::Request;
use crate::obs::{Gauge, MetricsRegistry};

use super::shard::{quantize, ShardMap};

/// One decoded request in flight: which connection/sequence slot its
/// response must land in, and when it was decoded (for the
/// accept-to-response latency histogram).
pub(crate) struct Job {
    pub conn: u64,
    pub seq: u64,
    pub req: Request,
    pub started: Instant,
}

/// What a shard worker reports back to the event loop.
pub(crate) enum Completion {
    /// An interim streaming line (`{"v":1,"event":...}`) for slot
    /// `(conn, seq)`; more lines (or `Done`) follow.
    Event { conn: u64, seq: u64, line: String },
    /// The final response line for slot `(conn, seq)`.
    Done { conn: u64, seq: u64, line: String, op: &'static str, started: Instant },
}

/// The worker→event-loop channel: a mutex-guarded batch plus the poller
/// waker, so the loop wakes exactly when results are ready instead of
/// polling.
pub(crate) struct CompletionQueue {
    items: Mutex<Vec<Completion>>,
    waker: super::poller::Waker,
}

impl CompletionQueue {
    pub fn new(waker: super::poller::Waker) -> CompletionQueue {
        CompletionQueue { items: Mutex::new(Vec::new()), waker }
    }

    pub fn push(&self, c: Completion) {
        self.items.lock().unwrap().push(c);
        self.waker.wake();
    }

    /// Move all pending completions into `out` (the event loop's drain).
    pub fn drain_into(&self, out: &mut Vec<Completion>) {
        out.append(&mut self.items.lock().unwrap());
    }
}

struct ShardQueue {
    jobs: Mutex<VecDeque<Job>>,
    ready: Condvar,
}

/// N worker shards, each popping jobs from its own bounded queue and
/// pushing completions back through the [`CompletionQueue`]. Streaming ops
/// (`run`/`submit` with `"stream":true`) move to a dedicated thread so a
/// long execution never blocks the shard's cache-hot traffic; the slot's
/// in-flight accounting covers the streamer until its final line.
pub(crate) struct ShardPool {
    queues: Vec<Arc<ShardQueue>>,
    depth_gauges: Vec<Arc<Gauge>>,
    queue_cap: usize,
    /// Round-robin cursor for requests without a cache affinity.
    rr: AtomicUsize,
    closed: Arc<AtomicBool>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl ShardPool {
    pub fn start(
        shards: usize,
        queue_cap: usize,
        session: Arc<crate::api::TradeoffSession>,
        stop: Arc<AtomicBool>,
        completions: Arc<CompletionQueue>,
        registry: &MetricsRegistry,
    ) -> ShardPool {
        let closed = Arc::new(AtomicBool::new(false));
        let queues: Vec<Arc<ShardQueue>> = (0..shards)
            .map(|_| {
                Arc::new(ShardQueue {
                    jobs: Mutex::new(VecDeque::new()),
                    ready: Condvar::new(),
                })
            })
            .collect();
        let depth_gauges: Vec<Arc<Gauge>> = (0..shards)
            .map(|i| registry.gauge("serve_shard_queue_depth", &format!("shard={i}")))
            .collect();
        let handles = (0..shards)
            .map(|i| {
                let queue = Arc::clone(&queues[i]);
                let gauge = Arc::clone(&depth_gauges[i]);
                let session = Arc::clone(&session);
                let stop = Arc::clone(&stop);
                let completions = Arc::clone(&completions);
                let closed = Arc::clone(&closed);
                std::thread::Builder::new()
                    .name(format!("cloudshapes-shard-{i}"))
                    .spawn(move || {
                        shard_worker(&queue, &gauge, &session, &stop, &completions, &closed)
                    })
                    .expect("spawning shard worker thread")
            })
            .collect();
        ShardPool { queues, depth_gauges, queue_cap, rr: AtomicUsize::new(0), closed, handles }
    }

    /// Which shard a request belongs on: solve ops go to the owner of their
    /// cache key (single-writer cache slices), everything else round-robins.
    pub fn route(&self, req: &Request, map: &ShardMap, default_strategy: &str) -> usize {
        let strategy =
            |name: &Option<String>| -> &str { name.as_deref().unwrap_or(default_strategy) };
        match req {
            Request::Partition { partitioner, budget }
            | Request::Evaluate { partitioner, budget } => {
                map.shard_for(strategy(partitioner), quantize(*budget))
            }
            // Pareto curves and whole batches key on the strategy alone:
            // the curve cache is per-strategy, and a batch's entries all
            // land in the strategy's cache slices via the same map.
            Request::Pareto { partitioner } | Request::Batch { partitioner, .. } => {
                map.shard_for(strategy(partitioner), None)
            }
            _ => self.rr.fetch_add(1, Ordering::Relaxed) % self.queues.len(),
        }
    }

    /// Enqueue a job on `shard`, or hand it back when the shard's queue is
    /// at its depth cap (the caller sheds it with an `overload` error).
    pub fn try_dispatch(&self, shard: usize, job: Job) -> Result<(), Job> {
        let mut q = self.queues[shard].jobs.lock().unwrap();
        if q.len() >= self.queue_cap {
            return Err(job);
        }
        q.push_back(job);
        self.depth_gauges[shard].set(q.len() as f64);
        drop(q);
        self.queues[shard].ready.notify_one();
        Ok(())
    }

    /// Ask every worker to exit once its queue drains, then join them.
    pub fn shutdown(mut self) {
        self.closed.store(true, Ordering::SeqCst);
        for q in &self.queues {
            q.ready.notify_all();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn shard_worker(
    queue: &ShardQueue,
    gauge: &Gauge,
    session: &Arc<crate::api::TradeoffSession>,
    stop: &Arc<AtomicBool>,
    completions: &Arc<CompletionQueue>,
    closed: &AtomicBool,
) {
    loop {
        let job = {
            let mut jobs = queue.jobs.lock().unwrap();
            loop {
                if let Some(job) = jobs.pop_front() {
                    gauge.set(jobs.len() as f64);
                    break job;
                }
                if closed.load(Ordering::SeqCst) {
                    return;
                }
                jobs = queue.ready.wait(jobs).unwrap();
            }
        };
        let Job { conn, seq, req, started } = job;
        let op = req.op();
        if is_streaming(&req) {
            // Dedicated thread per stream: the shard stays responsive while
            // the execution emits event lines. The (conn, seq) slot keeps
            // the stream's place in the connection's response order, and
            // admission control bounds how many can exist at once.
            let fallback = req.clone();
            let session_c = Arc::clone(session);
            let stop_c = Arc::clone(stop);
            let completions_c = Arc::clone(completions);
            let spawned = std::thread::Builder::new()
                .name(format!("cloudshapes-stream-{conn}-{seq}"))
                .spawn(move || {
                    run_one(&session_c, req, &stop_c, &completions_c, conn, seq, op, started)
                });
            if spawned.is_err() {
                // Thread exhaustion: degrade to inline execution rather
                // than dropping the request.
                run_one(session, fallback, stop, completions, conn, seq, op, started);
            }
        } else {
            run_one(session, req, stop, completions, conn, seq, op, started);
        }
    }
}

/// `run`/`submit` with `"stream":true` hold their slot open across interim
/// event lines.
fn is_streaming(req: &Request) -> bool {
    matches!(
        req,
        Request::Run { stream: true, .. } | Request::Submit { stream: true, .. }
    )
}

#[allow(clippy::too_many_arguments)]
fn run_one(
    session: &crate::api::TradeoffSession,
    req: Request,
    stop: &AtomicBool,
    completions: &CompletionQueue,
    conn: u64,
    seq: u64,
    op: &'static str,
    started: Instant,
) {
    let mut emit = |line: String| {
        completions.push(Completion::Event { conn, seq, line });
    };
    let response = crate::cli::serve::execute_request(session, req, stop, &mut emit);
    completions.push(Completion::Done {
        conn,
        seq,
        line: response.to_string_compact(),
        op,
        started,
    });
}
