//! The async sharded serve plane.
//!
//! One readiness-driven event loop (hand-rolled epoll on Linux, poll(2) on
//! other unix — no external deps) owns accept, read/write readiness and
//! frame decoding for every connection; decoded requests are dispatched to
//! N worker shards selected by a consistent hash of `(strategy, quantized
//! budget)` — the same [`ShardMap`] that partitions the session's solution
//! cache, so each cache slice is written by exactly one worker and the
//! global cache mutex leaves the hot path. Responses flow back through
//! per-connection bounded write queues that preserve request order even
//! when requests fan out across shards.
//!
//! Two wire framings per connection, switchable mid-stream:
//!
//! - newline-delimited JSON (the protocol v1 default — byte-compatible with
//!   every pre-existing client);
//! - `lp1` length-prefixed framing (4-byte big-endian u32 payload length,
//!   then the JSON payload), negotiated by sending `"framing":"lp1"` on any
//!   request. The negotiating request's own response is already lp1-framed.
//!
//! Admission control sheds rather than stalls: a global in-flight budget
//! (`[serve] max_inflight`) plus per-shard queue depth caps answer
//! `{"ok":false,"error":{"kind":"overload",...}}` when exceeded, keeping
//! reads (and `shutdown`) responsive under load. Slow-loris and oversized
//! requests are bounded by `[serve] read_timeout_secs` and
//! `[serve] max_request_bytes`; fully idle connections stay open forever
//! unless `[serve] idle_timeout_secs` opts into reaping them (the legacy
//! server kept them open, so the default is 0 = disabled). Everything is
//! observable through the
//! metrics registry: `serve_connections`, `serve_shard_queue_depth`,
//! `serve_shed_total{reason=}` and `serve_request_latency_secs{op=}`.
//!
//! With `[serve] shards = 1` the plane degenerates to a single worker and
//! one cache slice — byte-for-byte the legacy single-cache behaviour.

mod conn;
#[cfg(unix)]
mod poller;
#[cfg(unix)]
pub(crate) mod pool;
pub mod shard;

pub use conn::{lp1_frame, lp1_read, Framing};
pub use shard::{fnv1a, quantize, BudgetKey, ShardMap, BUDGET_QUANTUM};

use crate::api::error::{CloudshapesError, Result};

/// `[serve]` section of the experiment config: the serve plane's knobs.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Worker shards (and solution-cache slices). 1 reproduces the legacy
    /// single-cache behaviour bit-for-bit.
    pub shards: usize,
    /// Per-connection read deadline, seconds: an incomplete request frame
    /// older than this is answered with a typed protocol error and the
    /// connection closed (the slow-loris guard).
    pub read_timeout_secs: f64,
    /// Close fully idle connections (no partial frame, nothing in flight,
    /// nothing to flush) after this many seconds. `0` — the default —
    /// keeps idle connections open indefinitely, matching the legacy
    /// thread-per-connection server.
    pub idle_timeout_secs: f64,
    /// Maximum bytes of one request frame, in both framing modes.
    pub max_request_bytes: usize,
    /// Global in-flight request budget; excess requests are shed with an
    /// `overload` error instead of queueing without bound.
    pub max_inflight: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            shards: 4,
            read_timeout_secs: 30.0,
            idle_timeout_secs: 0.0,
            max_request_bytes: 1 << 20,
            max_inflight: 256,
        }
    }
}

/// Most shards a serve plane may run (each is a worker thread + cache
/// slice; past this, coordination costs dwarf any concurrency win).
pub const MAX_SHARDS: usize = 64;

impl ServeConfig {
    /// Validate the knobs; called by the config parser and the session
    /// builder so a bad `[serve]` section fails before a socket is bound.
    pub fn validate(&self) -> Result<()> {
        if self.shards == 0 || self.shards > MAX_SHARDS {
            return Err(CloudshapesError::config(format!(
                "serve.shards must be 1..={MAX_SHARDS}, got {}",
                self.shards
            )));
        }
        if !self.read_timeout_secs.is_finite() || self.read_timeout_secs <= 0.0 {
            return Err(CloudshapesError::config(
                "serve.read_timeout_secs must be a positive number of seconds",
            ));
        }
        if !self.idle_timeout_secs.is_finite() || self.idle_timeout_secs < 0.0 {
            return Err(CloudshapesError::config(
                "serve.idle_timeout_secs must be a non-negative number of seconds (0 disables)",
            ));
        }
        if self.max_request_bytes < 64 {
            return Err(CloudshapesError::config(
                "serve.max_request_bytes must be at least 64",
            ));
        }
        if self.max_inflight == 0 {
            return Err(CloudshapesError::config("serve.max_inflight must be >= 1"));
        }
        Ok(())
    }

    /// Depth cap of each shard's job queue: the in-flight budget split
    /// across shards, floored so a many-shard config still queues a little.
    pub fn queue_cap(&self) -> usize {
        (self.max_inflight / self.shards).max(4)
    }
}

#[cfg(unix)]
pub use event_loop::serve;

/// Non-unix targets have no readiness backend; the serve plane is a typed
/// runtime error there instead of a compile failure.
#[cfg(not(unix))]
pub fn serve(
    _listener: std::net::TcpListener,
    _session: std::sync::Arc<crate::api::TradeoffSession>,
    _cfg: &ServeConfig,
) -> Result<()> {
    Err(CloudshapesError::runtime(
        "the serve event loop requires a unix platform (epoll/poll backend)",
    ))
}

#[cfg(unix)]
mod event_loop {
    use std::collections::{BTreeSet, HashMap};
    use std::net::TcpListener;
    use std::os::unix::io::AsRawFd;
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::Arc;
    use std::time::{Duration, Instant};

    use crate::api::error::{CloudshapesError, Result};
    use crate::api::protocol::{error_response, Request};
    use crate::api::TradeoffSession;
    use crate::obs::{Counter, MetricsRegistry};
    use crate::util::json::Json;

    use super::conn::{Conn, FrameError, Framing, MAX_CONN_BUFFER};
    use super::poller::Poller;
    use super::pool::{Completion, CompletionQueue, Job, ShardPool};
    use super::shard::ShardMap;
    use super::ServeConfig;

    /// Token of the listening socket; connection tokens start above it and
    /// are never reused (a late completion can never land on a new
    /// connection that recycled the token).
    const LISTENER_TOKEN: u64 = 0;
    const FIRST_CONN_TOKEN: u64 = 2;

    /// Hard ceiling on the post-shutdown drain: in-flight responses get
    /// this long to finish and flush before the loop gives up on them.
    const DRAIN_DEADLINE_SECS: u64 = 10;

    /// Hard ceiling on a single connection's close: a connection marked
    /// `closing` still waits for its in-flight responses to finish and
    /// flush (the in-order flush-before-close guarantee), but a stuck job
    /// cannot pin the connection past this grace period.
    const CLOSE_GRACE_SECS: u64 = 10;

    /// Everything the frame/admission path needs besides the connection
    /// table and the poller (which the loop keeps separate so `&mut Conn`
    /// and `&mut Ctx` can coexist).
    struct Ctx<'a> {
        cfg: &'a ServeConfig,
        session: &'a Arc<TradeoffSession>,
        stop: &'a Arc<AtomicBool>,
        pool: &'a ShardPool,
        map: &'a ShardMap,
        default_strategy: &'a str,
        registry: &'a MetricsRegistry,
        shed_inflight: Arc<Counter>,
        shed_queue: Arc<Counter>,
        /// Requests dispatched to shards and not yet answered, across all
        /// connections (the event loop is single-threaded, so a plain
        /// counter suffices).
        inflight: usize,
        /// Shutdown observed: no new accepts, no new frames; drain only.
        draining: bool,
    }

    /// Run the serve plane on an already-bound listener until a `shutdown`
    /// request arrives, then drain in-flight responses and join the shard
    /// workers before returning.
    pub fn serve(
        listener: TcpListener,
        session: Arc<TradeoffSession>,
        cfg: &ServeConfig,
    ) -> Result<()> {
        cfg.validate()?;
        listener
            .set_nonblocking(true)
            .map_err(|e| CloudshapesError::runtime(format!("listener nonblocking: {e}")))?;
        let mut poller = Poller::new()
            .map_err(|e| CloudshapesError::runtime(format!("readiness poller: {e}")))?;
        poller
            .register(listener.as_raw_fd(), LISTENER_TOKEN, true, false)
            .map_err(|e| CloudshapesError::runtime(format!("registering listener: {e}")))?;

        let stop = Arc::new(AtomicBool::new(false));
        let completions = Arc::new(CompletionQueue::new(poller.waker()));
        let registry = Arc::clone(session.metrics_registry());
        let pool = ShardPool::start(
            cfg.shards,
            cfg.queue_cap(),
            Arc::clone(&session),
            Arc::clone(&stop),
            Arc::clone(&completions),
            &registry,
        );
        let map = ShardMap::new(cfg.shards);
        let default_strategy = session.default_partitioner().to_string();
        let connections_gauge = registry.gauge("serve_connections", "");
        let mut ctx = Ctx {
            cfg,
            session: &session,
            stop: &stop,
            pool: &pool,
            map: &map,
            default_strategy: &default_strategy,
            registry: &registry,
            shed_inflight: registry.counter("serve_shed_total", "reason=inflight"),
            shed_queue: registry.counter("serve_shed_total", "reason=shard_queue"),
            inflight: 0,
            draining: false,
        };

        let mut conns: HashMap<u64, Conn> = HashMap::new();
        let mut next_token = FIRST_CONN_TOKEN;
        let mut events = Vec::new();
        let mut batch: Vec<Completion> = Vec::new();
        // Sweep timeouts at least twice per deadline, but never busier than
        // every 10ms (tests run with sub-second deadlines).
        let sweep_every =
            Duration::from_secs_f64((cfg.read_timeout_secs / 2.0).clamp(0.01, 0.1));
        let tick = sweep_every.min(Duration::from_millis(250));
        let mut last_sweep = Instant::now();
        let mut drain_deadline: Option<Instant> = None;

        // The loop breaks with its Result instead of `?`-returning so every
        // exit — clean drain or a poller failure — runs the same teardown:
        // connections dropped (closing their fds) and the shard workers
        // joined, never left parked on their condvars.
        let loop_result: Result<()> = loop {
            events.clear();
            if let Err(e) = poller.wait(Some(tick), &mut events) {
                break Err(CloudshapesError::runtime(format!("poll wait: {e}")));
            }
            // Connections that changed this iteration and need their output
            // pumped/flushed and their poller interest refreshed.
            let mut dirty: BTreeSet<u64> = BTreeSet::new();

            for i in 0..events.len() {
                let ev = events[i];
                if ev.token == LISTENER_TOKEN {
                    if !ctx.draining {
                        accept_all(&listener, &mut poller, &mut conns, &mut next_token);
                        connections_gauge.set(conns.len() as f64);
                    }
                    continue;
                }
                let Some(conn) = conns.get_mut(&ev.token) else { continue };
                if (ev.readable || ev.hangup) && !ctx.draining {
                    if conn.fill(ctx.cfg.max_request_bytes).is_err() {
                        conn.begin_close();
                        conn.eof = true;
                    }
                    process_frames(conn, &mut ctx);
                } else if ev.hangup {
                    conn.eof = true;
                }
                dirty.insert(ev.token);
            }

            // Shard workers report in: interim stream lines and finals.
            completions.drain_into(&mut batch);
            for c in batch.drain(..) {
                match c {
                    Completion::Event { conn: token, seq, line } => {
                        if let Some(conn) = conns.get_mut(&token) {
                            conn.append(seq, &line);
                            dirty.insert(token);
                        }
                    }
                    Completion::Done { conn: token, seq, line, op, started } => {
                        ctx.inflight = ctx.inflight.saturating_sub(1);
                        ctx.registry.observe(
                            "serve_request_latency_secs",
                            &format!("op={op}"),
                            started.elapsed().as_secs_f64(),
                        );
                        if let Some(conn) = conns.get_mut(&token) {
                            conn.finish(seq, &line);
                            conn.inflight = conn.inflight.saturating_sub(1);
                            dirty.insert(token);
                        }
                    }
                }
            }

            // Shutdown is a first-class wakeup: the flag is set inline by
            // the `shutdown` dispatch above (or by a shard worker, whose
            // completion wakes this loop through the self-pipe), so it is
            // observed here on the same iteration — no poke connection, no
            // accept race.
            if ctx.stop.load(Ordering::SeqCst) && !ctx.draining {
                ctx.draining = true;
                drain_deadline =
                    Some(Instant::now() + Duration::from_secs(DRAIN_DEADLINE_SECS));
                let _ = poller.deregister(listener.as_raw_fd());
                // Stop reading everywhere; remaining responses still flush.
                dirty.extend(conns.keys().copied());
            }

            // Deadline sweep: slow-loris partial frames and idle timeouts.
            if last_sweep.elapsed() >= sweep_every {
                last_sweep = Instant::now();
                sweep_deadlines(&mut conns, &mut ctx, &mut dirty);
            }

            // Pump slots, flush sockets, refresh interest, close what's done.
            for token in dirty {
                finalize(token, &mut conns, &mut poller, &mut ctx);
            }
            connections_gauge.set(conns.len() as f64);

            if ctx.draining {
                let flushed =
                    ctx.inflight == 0 && conns.values().all(|c| !c.has_pending_output());
                let expired = drain_deadline.is_some_and(|d| Instant::now() >= d);
                if flushed || expired {
                    break Ok(());
                }
            }
        };

        // In-flight responses have flushed (or the drain deadline passed,
        // or the poller failed): only now does the listener close and the
        // pool join its workers.
        drop(listener);
        drop(conns);
        connections_gauge.set(0.0);
        pool.shutdown();
        loop_result
    }

    fn accept_all(
        listener: &TcpListener,
        poller: &mut Poller,
        conns: &mut HashMap<u64, Conn>,
        next_token: &mut u64,
    ) {
        loop {
            match listener.accept() {
                Ok((stream, _addr)) => {
                    if stream.set_nonblocking(true).is_err() {
                        continue; // drop the connection; accept the rest
                    }
                    let token = *next_token;
                    *next_token += 1;
                    if poller.register(stream.as_raw_fd(), token, true, false).is_ok() {
                        conns.insert(token, Conn::new(stream, token, Instant::now()));
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                // Transient per-connection accept failures (ECONNABORTED,
                // EMFILE...): skip this round, the next readiness retries.
                Err(_) => break,
            }
        }
    }

    /// Decode every complete frame buffered on `conn` and admit each one.
    fn process_frames(conn: &mut Conn, ctx: &mut Ctx<'_>) {
        while !conn.closing && !ctx.draining {
            match conn.next_frame(ctx.cfg.max_request_bytes) {
                Ok(Some(text)) => process_request(conn, &text, ctx),
                Ok(None) => break,
                Err(FrameError::TooLarge { limit }) => {
                    frame_fatal(
                        conn,
                        format!(
                            "request exceeds the {limit}-byte limit \
                             ([serve] max_request_bytes)"
                        ),
                    );
                }
                Err(FrameError::BadLength { len, limit }) => {
                    frame_fatal(
                        conn,
                        format!(
                            "lp1 frame length {len} out of range (must be \
                             1..={limit}, [serve] max_request_bytes)"
                        ),
                    );
                }
            }
        }
    }

    /// Answer a fatal framing error in-order, then close once every
    /// earlier pipelined response (in flight or queued) and the error
    /// itself have flushed.
    fn frame_fatal(conn: &mut Conn, message: String) {
        let framing = conn.framing;
        let seq = conn.open_slot(framing);
        let e = CloudshapesError::protocol(message);
        conn.finish(seq, &error_response(&e).to_string_compact());
        conn.begin_close();
    }

    fn process_request(conn: &mut Conn, text: &str, ctx: &mut Ctx<'_>) {
        if text.trim().is_empty() {
            return; // blank keep-alive lines, as the legacy reader allowed
        }
        let json = match Json::parse(text).map_err(CloudshapesError::from) {
            Ok(j) => j,
            Err(e) => {
                let framing = conn.framing;
                let seq = conn.open_slot(framing);
                conn.finish(seq, &error_response(&e).to_string_compact());
                return;
            }
        };
        // Framing negotiation rides any request: `"framing":"lp1"` switches
        // this connection's reads AND this response (idempotent). Unknown
        // values answer a typed error without changing modes.
        match json.get("framing") {
            None | Some(Json::Null) => {}
            Some(v) => match v.as_str() {
                Some("lp1") => conn.framing = Framing::Lp1,
                _ => {
                    let framing = conn.framing;
                    let seq = conn.open_slot(framing);
                    let e = CloudshapesError::protocol(format!(
                        "unknown framing {} (supported: \"lp1\"; omit the key for \
                         newline-delimited JSON)",
                        v.to_string_compact()
                    ));
                    conn.finish(seq, &error_response(&e).to_string_compact());
                    return;
                }
            },
        }
        let framing = conn.framing;
        let seq = conn.open_slot(framing);
        let req = match Request::from_json(&json) {
            Ok(r) => r,
            Err(e) => {
                conn.finish(seq, &error_response(&e).to_string_compact());
                return;
            }
        };
        if matches!(req, Request::Shutdown) {
            // Always admitted and answered inline: shutdown must never be
            // shed by the very overload it is sent to resolve.
            let resp =
                crate::cli::serve::execute_request(ctx.session, req, ctx.stop, &mut |_| {});
            conn.finish(seq, &resp.to_string_compact());
            return;
        }
        if ctx.inflight >= ctx.cfg.max_inflight {
            ctx.shed_inflight.inc();
            let e = CloudshapesError::overload(format!(
                "server at its in-flight budget ({} requests); retry with backoff",
                ctx.cfg.max_inflight
            ));
            conn.finish(seq, &error_response(&e).to_string_compact());
            return;
        }
        let shard = ctx.pool.route(&req, ctx.map, ctx.default_strategy);
        let job = Job { conn: conn.token, seq, req, started: Instant::now() };
        match ctx.pool.try_dispatch(shard, job) {
            Ok(()) => {
                ctx.inflight += 1;
                conn.inflight += 1;
            }
            Err(_job) => {
                ctx.shed_queue.inc();
                let e = CloudshapesError::overload(format!(
                    "shard {shard} queue full ({} deep); retry with backoff",
                    ctx.cfg.queue_cap()
                ));
                conn.finish(seq, &error_response(&e).to_string_compact());
            }
        }
    }

    /// Enforce `[serve] read_timeout_secs`: an incomplete frame older than
    /// the deadline gets a typed error then close (slow-loris — the clock
    /// starts at the frame's FIRST byte, so a trickle never resets it).
    /// Fully idle connections close silently after
    /// `[serve] idle_timeout_secs`, if that knob is enabled. Closing
    /// connections are re-checked against their drain grace period so a
    /// stuck in-flight job cannot pin one forever.
    fn sweep_deadlines(
        conns: &mut HashMap<u64, Conn>,
        ctx: &mut Ctx<'_>,
        dirty: &mut BTreeSet<u64>,
    ) {
        let now = Instant::now();
        let deadline = Duration::from_secs_f64(ctx.cfg.read_timeout_secs);
        let idle_after = (ctx.cfg.idle_timeout_secs > 0.0)
            .then(|| Duration::from_secs_f64(ctx.cfg.idle_timeout_secs));
        let grace = Duration::from_secs(CLOSE_GRACE_SECS);
        for (&token, conn) in conns.iter_mut() {
            if conn.closing {
                // No new events may arrive for a closing connection that is
                // waiting on in-flight responses; marking it dirty lets
                // `finalize` enforce the grace deadline.
                if conn.closing_since.is_some_and(|t| now.duration_since(t) >= grace) {
                    dirty.insert(token);
                }
                continue;
            }
            if let Some(started) = conn.frame_started {
                if now.duration_since(started) >= deadline {
                    frame_fatal(
                        conn,
                        format!(
                            "read timed out after {}s with an incomplete request \
                             frame ([serve] read_timeout_secs)",
                            ctx.cfg.read_timeout_secs
                        ),
                    );
                    dirty.insert(token);
                    continue;
                }
            }
            let Some(idle_after) = idle_after else { continue };
            let idle = conn.inflight == 0
                && !conn.has_partial_frame()
                && !conn.has_pending_output();
            if idle && now.duration_since(conn.idle_since) >= idle_after {
                conn.begin_close(); // nothing queued: closes immediately
                dirty.insert(token);
            }
        }
    }

    /// Pump/flush one connection, refresh its poller interest, and close it
    /// when its lifecycle says so. Deregistration before drop makes
    /// teardown deterministic — no fd survives its entry in the table.
    fn finalize(
        token: u64,
        conns: &mut HashMap<u64, Conn>,
        poller: &mut Poller,
        ctx: &mut Ctx<'_>,
    ) {
        let Some(conn) = conns.get_mut(&token) else { return };
        conn.pump();
        let write_pending = match conn.flush() {
            Ok(pending) => pending,
            Err(_) => {
                close_conn(token, conns, poller);
                return;
            }
        };
        // A peer that stops reading while responses accumulate is a slow
        // consumer; past the cap the connection is dropped, not buffered.
        if conn.buffered_bytes() > MAX_CONN_BUFFER {
            close_conn(token, conns, poller);
            return;
        }
        // A closing connection still owes its in-flight and reorder-slot
        // responses (a frame error or timeout on a pipelined connection
        // queues its error BEHIND earlier requests): close only once
        // nothing remains to deliver, or the grace period expires.
        let drained = !write_pending && !conn.has_pending_output() && conn.inflight == 0;
        let grace_expired = conn
            .closing_since
            .is_some_and(|t| t.elapsed() >= Duration::from_secs(CLOSE_GRACE_SECS));
        let done_closing = conn.closing && (drained || grace_expired);
        let done_eof = conn.eof && conn.inflight == 0 && !conn.has_pending_output();
        if done_closing || done_eof {
            close_conn(token, conns, poller);
            return;
        }
        let readable = !conn.closing && !conn.eof && !ctx.draining;
        let _ = poller.modify(conn.stream.as_raw_fd(), token, readable, write_pending);
    }

    fn close_conn(token: u64, conns: &mut HashMap<u64, Conn>, poller: &mut Poller) {
        if let Some(conn) = conns.remove(&token) {
            let _ = poller.deregister(conn.stream.as_raw_fd());
            // `conn.stream` drops here, closing the fd.
        }
    }
}
