//! Predictive runtime-characteristic models (§III.A of the paper):
//! latency `L(N) = βN + γ`, quantised IaaS cost `C = ⌈L/ρ⌉π`, the
//! TCO-based rate derivation for devices without market prices (Eq. 2),
//! [`online`] incremental re-fitting of the latency models from latencies
//! measured while a long-running scheduler executes, [`forecast`] arrival
//! prediction + autoscaling, and the [`market`] storm-tick simulator.

pub mod cost;
pub mod forecast;
pub mod latency;
pub mod market;
pub mod online;
pub mod tco;

pub use cost::CostModel;
pub use forecast::{ArrivalForecaster, Autoscaler, ForecastConfig, PlatformEcon};
pub use latency::{FamilyLatencyFit, LatencyModel};
pub use market::{MarketSim, MarketTick, StormConfig};
pub use online::{OnlineLatencyFit, PlatformPrior};
pub use tco::{DatacentreModel, TcoInputs};

/// The latency + cost models of one (task, platform) pairing, the unit the
/// partitioners consume.
#[derive(Debug, Clone, Copy)]
pub struct TaskPlatformModel {
    pub latency: LatencyModel,
    pub cost: CostModel,
}

impl TaskPlatformModel {
    /// Predicted latency of running `n` simulations.
    pub fn latency_secs(&self, n: u64) -> f64 {
        self.latency.predict(n)
    }

    /// Billed cost of running `n` simulations in isolation.
    pub fn cost_usd(&self, n: u64) -> f64 {
        self.cost.cost(self.latency.predict(n))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn task_platform_model_composes() {
        let m = TaskPlatformModel {
            latency: LatencyModel::new(1e-3, 10.0),
            cost: CostModel::new(60.0, 3.6).unwrap(),
        };
        // 50_000 sims -> 60 s -> 1 quantum -> $0.06.
        assert!((m.latency_secs(50_000) - 60.0).abs() < 1e-9);
        assert!((m.cost_usd(50_000) - 0.06).abs() < 1e-12);
    }
}
