//! Total-cost-of-ownership pricing — Equation 2 + Table III of the paper.
//!
//! In the absence of market prices for IaaS FPGAs, the paper derives a rate:
//!
//! ```text
//! π   = DBR × RDP
//! DBR = (TCO + PM) · ρ / P
//! ```
//!
//! where DBR is the Device Base Rate (cost per device per time quantum) from
//! an Uptime-Institute-style datacentre TCO model, and RDP is the Relative
//! Device Performance — device performance relative to the (count-weighted)
//! mean of the devices *of the same type* in the datacentre, mirroring how
//! the market prices within a device category (§II.A).
//!
//! The datacentre overhead coefficients below are calibrated so the model
//! reproduces Table III's calculated rates ($0.46 FPGA / $0.64 GPU /
//! $0.50 CPU per hour) from its published inputs; they absorb energy,
//! cooling, facility amortisation, and staffing at 2015 prices.

/// Hours per year used throughout the paper's tables.
pub const HOURS_PER_YEAR: f64 = 8760.0;

/// Datacentre-wide overhead coefficients (Uptime Institute simple model,
/// collapsed to per-device terms; see module docs).
#[derive(Debug, Clone, Copy)]
pub struct DatacentreModel {
    /// $/W/year: energy + cooling + power-infrastructure amortisation.
    pub per_watt_annual: f64,
    /// $/device/year: space, network, staffing.
    pub fixed_annual: f64,
}

impl Default for DatacentreModel {
    fn default() -> Self {
        // Calibrated against Table III (see module docs + tests).
        DatacentreModel { per_watt_annual: 6.6, fixed_annual: 1280.0 }
    }
}

/// Per-device-type TCO inputs — the rows of Table III.
#[derive(Debug, Clone, Copy)]
pub struct TcoInputs {
    /// Device capital cost, $.
    pub capital_cost: f64,
    /// Device draw in watts.
    pub energy_watts: f64,
    /// Capital recovery period in years.
    pub recovery_years: f64,
    /// Fraction of wall-clock hours actually billed to customers.
    pub charged_usage: f64,
    /// Provider profit margin (0.20 = 20%).
    pub profit_margin: f64,
}

impl TcoInputs {
    /// Annual total cost of ownership for one device, $.
    pub fn annual_tco(&self, dc: &DatacentreModel) -> f64 {
        self.capital_cost / self.recovery_years
            + self.energy_watts * dc.per_watt_annual
            + dc.fixed_annual
    }

    /// Device Base Rate in $/hour: `(TCO + PM) · ρ/P` with ρ = 1 hour,
    /// amortised over the *charged* hours only.
    pub fn device_base_rate(&self, dc: &DatacentreModel) -> f64 {
        self.annual_tco(dc) * (1.0 + self.profit_margin)
            / (HOURS_PER_YEAR * self.charged_usage)
    }
}

/// Relative Device Performance: performance of a device relative to the
/// count-weighted mean performance of the same-type population (the
/// weighting Table II's FPGA rates imply — verified in tests).
pub fn relative_device_performance(perf: f64, population: &[(f64, usize)]) -> f64 {
    assert!(!population.is_empty(), "empty device population");
    let (sum, count) = population
        .iter()
        .fold((0.0, 0usize), |(s, c), (p, n)| (s + p * *n as f64, c + n));
    assert!(count > 0 && sum > 0.0, "degenerate device population");
    perf / (sum / count as f64)
}

/// π = DBR × RDP (Eq. 2), in $/hour.
pub fn device_rate(inputs: &TcoInputs, dc: &DatacentreModel, rdp: f64) -> f64 {
    inputs.device_base_rate(dc) * rdp
}

/// The paper's Table III input rows (2015 prices).
pub mod table3 {
    use super::TcoInputs;

    pub const FPGA: TcoInputs = TcoInputs {
        capital_cost: 5370.0,
        energy_watts: 50.0,
        recovery_years: 5.0,
        charged_usage: 0.80,
        profit_margin: 0.20,
    };
    pub const GPU: TcoInputs = TcoInputs {
        capital_cost: 3120.0,
        energy_watts: 135.0,
        recovery_years: 2.0,
        charged_usage: 0.80,
        profit_margin: 0.20,
    };
    pub const CPU: TcoInputs = TcoInputs {
        capital_cost: 2530.0,
        energy_watts: 115.0,
        recovery_years: 2.0,
        charged_usage: 0.90,
        profit_margin: 0.20,
    };

    /// Observed market rates the paper compares against (AWS, April 2015).
    pub const OBSERVED_GPU: f64 = 0.65;
    pub const OBSERVED_CPU: f64 = 0.53;
    /// Rates the paper's model calculates (Table III bottom row).
    pub const CALCULATED_FPGA: f64 = 0.46;
    pub const CALCULATED_GPU: f64 = 0.64;
    pub const CALCULATED_CPU: f64 = 0.50;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reproduces_table3_calculated_rates() {
        let dc = DatacentreModel::default();
        let fpga = table3::FPGA.device_base_rate(&dc);
        let gpu = table3::GPU.device_base_rate(&dc);
        let cpu = table3::CPU.device_base_rate(&dc);
        assert!((fpga - table3::CALCULATED_FPGA).abs() < 0.005, "fpga {fpga}");
        assert!((gpu - table3::CALCULATED_GPU).abs() < 0.005, "gpu {gpu}");
        assert!((cpu - table3::CALCULATED_CPU).abs() < 0.005, "cpu {cpu}");
    }

    #[test]
    fn calculated_rates_slightly_below_observed() {
        // §IV.C.1: "both are several percent below those seen in the market".
        let dc = DatacentreModel::default();
        let gpu = table3::GPU.device_base_rate(&dc);
        let cpu = table3::CPU.device_base_rate(&dc);
        assert!(gpu < table3::OBSERVED_GPU && gpu > 0.9 * table3::OBSERVED_GPU);
        assert!(cpu < table3::OBSERVED_CPU && cpu > 0.9 * table3::OBSERVED_CPU);
    }

    #[test]
    fn rdp_weights_by_population_count() {
        // Table II FPGA fleet: 4x Virtex (111.978), 8x GSD8 (112.949),
        // 1x GSD5 (176.871). RDP x $0.46 must give the table's rates.
        let pop = [(111.978, 4usize), (112.949, 8), (176.871, 1)];
        let dbr = 0.46;
        let rates: Vec<f64> = pop
            .iter()
            .map(|(p, _)| dbr * relative_device_performance(*p, &pop))
            .collect();
        assert!((rates[0] - 0.438).abs() < 0.002, "virtex {:.4}", rates[0]);
        assert!((rates[1] - 0.442).abs() < 0.002, "gsd8 {:.4}", rates[1]);
        assert!((rates[2] - 0.692).abs() < 0.002, "gsd5 {:.4}", rates[2]);
    }

    #[test]
    fn rdp_of_mean_device_is_one() {
        let pop = [(100.0, 3usize)];
        assert!((relative_device_performance(100.0, &pop) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn higher_usage_lowers_rate() {
        let dc = DatacentreModel::default();
        let mut busy = table3::FPGA;
        busy.charged_usage = 0.95;
        assert!(busy.device_base_rate(&dc) < table3::FPGA.device_base_rate(&dc));
    }

    #[test]
    fn margin_scales_rate_linearly() {
        let dc = DatacentreModel::default();
        let mut cheap = table3::GPU;
        cheap.profit_margin = 0.0;
        let with_margin = table3::GPU.device_base_rate(&dc);
        let without = cheap.device_base_rate(&dc);
        assert!((with_margin / without - 1.2).abs() < 1e-9);
    }

    #[test]
    #[should_panic(expected = "empty device population")]
    fn empty_population_panics() {
        relative_device_performance(1.0, &[]);
    }
}
