//! Cost model — Equation 1b of the paper: `C(L) = ⌈L/ρ⌉ · π`.
//!
//! IaaS billing is quantised: usage is rounded *up* to whole time quanta ρ
//! (1 min for Azure, 10 min for GCE, 60 min for AWS — Table I) and charged
//! at the platform rate π. The non-linearity this ceiling introduces is one
//! of the two effects (with γ setup time) that the paper's MILP exploits and
//! the heuristic misses (§IV.C.2).

use crate::api::error::{CloudshapesError, Result};

/// Billing terms of one platform.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CostModel {
    /// Time quantum ρ in seconds.
    pub quantum_secs: f64,
    /// Rate π in $ per *hour* (the industry quote unit, Table I/II).
    pub rate_per_hour: f64,
}

impl CostModel {
    /// Build billing terms; bad user config (non-positive quantum, negative
    /// or non-finite rate) is a typed error, never a panic.
    pub fn new(quantum_secs: f64, rate_per_hour: f64) -> Result<CostModel> {
        if !(quantum_secs > 0.0 && quantum_secs.is_finite()) {
            return Err(CloudshapesError::config(format!(
                "billing quantum must be positive and finite, got {quantum_secs}"
            )));
        }
        if !(rate_per_hour >= 0.0 && rate_per_hour.is_finite()) {
            return Err(CloudshapesError::config(format!(
                "billing rate must be non-negative and finite, got {rate_per_hour}"
            )));
        }
        Ok(CostModel { quantum_secs, rate_per_hour })
    }

    /// Number of quanta billed for a latency (the integer `D` of Eq. 4).
    pub fn quanta(&self, latency_secs: f64) -> u64 {
        if latency_secs <= 0.0 {
            return 0;
        }
        (latency_secs / self.quantum_secs).ceil() as u64
    }

    /// $ per quantum.
    pub fn rate_per_quantum(&self) -> f64 {
        self.rate_per_hour * self.quantum_secs / 3600.0
    }

    /// Billed cost in $ for a latency (Eq. 1b).
    pub fn cost(&self, latency_secs: f64) -> f64 {
        self.quanta(latency_secs) as f64 * self.rate_per_quantum()
    }

    /// Un-quantised cost — the continuous relaxation used by LP bounds.
    /// Always a lower bound on [`Self::cost`].
    pub fn cost_relaxed(&self, latency_secs: f64) -> f64 {
        latency_secs.max(0.0) / 3600.0 * self.rate_per_hour
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop::{prop_assert, prop_check};

    #[test]
    fn billing_rounds_up() {
        // AWS-style 60-min quantum at $0.65/h.
        let m = CostModel::new(3600.0, 0.65).unwrap();
        assert_eq!(m.quanta(1.0), 1);
        assert_eq!(m.quanta(3600.0), 1);
        assert_eq!(m.quanta(3601.0), 2);
        assert!((m.cost(1.0) - 0.65).abs() < 1e-12);
        assert!((m.cost(7200.0) - 1.30).abs() < 1e-12);
    }

    #[test]
    fn bad_billing_terms_are_typed_errors() {
        for (quantum, rate) in [
            (0.0, 0.5),
            (-60.0, 0.5),
            (f64::NAN, 0.5),
            (f64::INFINITY, 0.5),
            (60.0, -0.1),
            (60.0, f64::NAN),
        ] {
            let e = CostModel::new(quantum, rate).unwrap_err();
            assert_eq!(e.kind(), "config", "({quantum}, {rate}) -> {e}");
        }
    }

    #[test]
    fn zero_latency_costs_nothing() {
        let m = CostModel::new(60.0, 0.592).unwrap();
        assert_eq!(m.quanta(0.0), 0);
        assert_eq!(m.cost(0.0), 0.0);
    }

    #[test]
    fn short_quantum_bills_finer() {
        // Azure 1-min vs AWS 60-min quantum, same hourly rate: for a 5-min
        // job Azure bills 5 minutes, AWS bills the full hour.
        let azure = CostModel::new(60.0, 0.60).unwrap();
        let aws = CostModel::new(3600.0, 0.60).unwrap();
        let latency = 300.0;
        assert!((azure.cost(latency) - 0.05).abs() < 1e-12);
        assert!((aws.cost(latency) - 0.60).abs() < 1e-12);
    }

    #[test]
    fn relaxed_cost_is_a_lower_bound() {
        prop_check("relaxed cost <= billed cost", 300, |g| {
            let m = CostModel::new(g.f64(1.0, 7200.0), g.f64(0.0, 5.0)).unwrap();
            let latency = g.f64(0.0, 100_000.0);
            prop_assert(
                m.cost_relaxed(latency) <= m.cost(latency) + 1e-9,
                "relaxation exceeded billed cost",
            )
        });
    }

    #[test]
    fn cost_is_a_step_function_dominating_the_relaxation() {
        // The billing staircase: cost is piecewise constant on quantum
        // intervals (flat between a latency and its quantum ceiling),
        // monotone non-decreasing, and everywhere >= the relaxed cost.
        prop_check("billed cost is a quantum staircase", 300, |g| {
            let m = CostModel::new(g.f64(1.0, 7200.0), g.f64(0.01, 5.0)).unwrap();
            let latency = g.f64(0.001, 100_000.0);
            let k = m.quanta(latency) as f64;
            // Flat within the quantum: the interval's midpoint bills the
            // same k quanta as `latency` itself.
            prop_assert(
                (m.cost(latency) - m.cost((k - 0.5) * m.quantum_secs)).abs() < 1e-9,
                "cost not constant within a quantum interval",
            )?;
            // One full step up in the next interval.
            prop_assert(
                (m.cost((k + 0.5) * m.quantum_secs) - m.cost(latency) - m.rate_per_quantum())
                    .abs()
                    < 1e-9,
                "no step at the quantum boundary",
            )?;
            // Monotone: more latency never bills less.
            let later = latency + g.f64(0.0, 10_000.0);
            prop_assert(m.cost(later) >= m.cost(latency) - 1e-12, "cost not monotone")?;
            // Dominates the relaxation.
            prop_assert(
                m.cost(latency) >= m.cost_relaxed(latency) - 1e-12,
                "staircase dipped below the relaxation",
            )
        });
    }

    #[test]
    fn billed_cost_within_one_quantum_of_relaxed() {
        prop_check("billed - relaxed <= one quantum", 300, |g| {
            let m = CostModel::new(g.f64(1.0, 7200.0), g.f64(0.01, 5.0)).unwrap();
            let latency = g.f64(0.001, 100_000.0);
            prop_assert(
                m.cost(latency) - m.cost_relaxed(latency) <= m.rate_per_quantum() + 1e-9,
                "quantisation overshoot beyond one quantum",
            )
        });
    }

    #[test]
    fn rate_per_quantum_scales_with_quantum() {
        let m = CostModel::new(600.0, 0.352).unwrap(); // GCE: 10-min quantum
        assert!((m.rate_per_quantum() - 0.352 / 6.0).abs() < 1e-12);
    }
}
