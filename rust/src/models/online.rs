//! Incremental latency-model re-fitting from observed chunk latencies.
//!
//! The §III.A benchmark fits latency models once, up front. A long-running
//! scheduler keeps receiving *measured* chunk latencies from the executor's
//! event stream; this module folds them into per-platform throughput
//! estimates so the next epoch solves against what the platforms are
//! actually doing (a hidden straggler, a noisy neighbour) rather than what
//! the benchmark saw.
//!
//! [`OnlineLatencyFit`] keeps a bounded window of work samples per
//! platform. The throughput estimate is total work over total time across
//! the window — the work-weighted harmonic mean, which is robust to mixed
//! chunk sizes — and it degrades gracefully to the prior while a platform
//! has produced too few samples to trust.

use std::collections::VecDeque;

use crate::models::LatencyModel;

/// Per-platform prior the fit falls back to before observations arrive:
/// effective throughput (FLOP/s) and per-stream setup seconds, usually
/// derived from the benchmark-fitted models.
#[derive(Debug, Clone, Copy)]
pub struct PlatformPrior {
    /// Effective application throughput, FLOP/s.
    pub throughput_flops: f64,
    /// Per-(platform, task)-stream setup seconds (the γ term).
    pub setup_secs: f64,
}

/// Fewest window samples before the windowed estimate replaces the prior.
const MIN_SAMPLES: usize = 2;

/// Windowed per-platform throughput re-fit.
#[derive(Debug, Clone)]
pub struct OnlineLatencyFit {
    /// Samples kept per platform; 0 disables re-fitting entirely (the
    /// priors are then authoritative forever).
    window: usize,
    priors: Vec<PlatformPrior>,
    /// Per-platform ring of `(work_flops, work_secs)` observations.
    samples: Vec<VecDeque<(f64, f64)>>,
}

impl OnlineLatencyFit {
    /// A fit seeded with one prior per platform. Priors must carry positive
    /// finite throughput (asserted: they come from fitted or nominal
    /// models, both of which guarantee it).
    pub fn new(priors: Vec<PlatformPrior>, window: usize) -> OnlineLatencyFit {
        for (i, p) in priors.iter().enumerate() {
            assert!(
                p.throughput_flops > 0.0 && p.throughput_flops.is_finite(),
                "platform {i}: non-positive prior throughput {}",
                p.throughput_flops
            );
            assert!(
                p.setup_secs >= 0.0 && p.setup_secs.is_finite(),
                "platform {i}: invalid prior setup {}",
                p.setup_secs
            );
        }
        let samples = priors.iter().map(|_| VecDeque::new()).collect();
        OnlineLatencyFit { window, priors, samples }
    }

    pub fn len(&self) -> usize {
        self.priors.len()
    }

    pub fn is_empty(&self) -> bool {
        self.priors.is_empty()
    }

    /// Record one successful chunk: `flops` of work observed to take `secs`
    /// of *work time* (callers subtract the setup γ from cold chunks).
    /// Non-positive or non-finite samples are ignored rather than poisoning
    /// the window.
    pub fn observe(&mut self, platform: usize, flops: f64, secs: f64) {
        if self.window == 0 {
            return;
        }
        if !(flops > 0.0 && flops.is_finite() && secs > 0.0 && secs.is_finite()) {
            return;
        }
        let ring = &mut self.samples[platform];
        ring.push_back((flops, secs));
        while ring.len() > self.window {
            ring.pop_front();
        }
    }

    /// Current throughput estimate for `platform`, FLOP/s: windowed when
    /// enough samples exist, the prior otherwise.
    pub fn throughput(&self, platform: usize) -> f64 {
        let ring = &self.samples[platform];
        if ring.len() < MIN_SAMPLES {
            return self.priors[platform].throughput_flops;
        }
        let (flops, secs) = ring
            .iter()
            .fold((0.0f64, 0.0f64), |(f, s), (df, ds)| (f + df, s + ds));
        if secs > 0.0 {
            flops / secs
        } else {
            self.priors[platform].throughput_flops
        }
    }

    /// The (prior) per-stream setup estimate for `platform`, seconds.
    pub fn setup_secs(&self, platform: usize) -> f64 {
        self.priors[platform].setup_secs
    }

    /// Latency model for a task with `flops_per_path` FLOPs per simulated
    /// path on `platform`, under the current throughput estimate.
    pub fn model(&self, platform: usize, flops_per_path: f64) -> LatencyModel {
        let beta = (flops_per_path / self.throughput(platform)).max(1e-15);
        LatencyModel::new(beta, self.setup_secs(platform))
    }

    /// All current throughputs — snapshot this at solve time, then compare
    /// with [`drift`](Self::drift) to decide when a re-solve is due.
    pub fn snapshot(&self) -> Vec<f64> {
        (0..self.len()).map(|i| self.throughput(i)).collect()
    }

    /// Largest relative throughput shift of any platform vs a prior
    /// [`snapshot`](Self::snapshot) (0.0 = models unchanged).
    pub fn drift(&self, snapshot: &[f64]) -> f64 {
        debug_assert_eq!(snapshot.len(), self.len());
        (0..self.len())
            .map(|i| {
                let then = snapshot[i].max(1e-15);
                (self.throughput(i) / then - 1.0).abs()
            })
            .fold(0.0, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn priors() -> Vec<PlatformPrior> {
        vec![
            PlatformPrior { throughput_flops: 1e9, setup_secs: 2.0 },
            PlatformPrior { throughput_flops: 4e9, setup_secs: 0.5 },
        ]
    }

    #[test]
    fn falls_back_to_prior_until_samples_arrive() {
        let mut fit = OnlineLatencyFit::new(priors(), 8);
        assert_eq!(fit.throughput(0), 1e9);
        fit.observe(0, 1e9, 2.0); // one sample is not enough
        assert_eq!(fit.throughput(0), 1e9);
        fit.observe(0, 1e9, 2.0);
        assert!((fit.throughput(0) - 5e8).abs() / 5e8 < 1e-12);
        // Platform 1 untouched.
        assert_eq!(fit.throughput(1), 4e9);
    }

    #[test]
    fn window_bounds_memory_and_tracks_drift() {
        let mut fit = OnlineLatencyFit::new(priors(), 4);
        // Fill with on-prior samples, then shift to half speed: the window
        // forgets the old regime.
        for _ in 0..4 {
            fit.observe(0, 1e9, 1.0);
        }
        let snap = fit.snapshot();
        assert!((fit.throughput(0) - 1e9).abs() < 1.0);
        for _ in 0..4 {
            fit.observe(0, 1e9, 2.0);
        }
        assert!((fit.throughput(0) - 5e8).abs() < 1.0);
        assert!((fit.drift(&snap) - 0.5).abs() < 1e-9);
    }

    #[test]
    fn window_zero_disables_refit() {
        let mut fit = OnlineLatencyFit::new(priors(), 0);
        for _ in 0..10 {
            fit.observe(0, 1e9, 10.0);
        }
        assert_eq!(fit.throughput(0), 1e9);
        assert_eq!(fit.drift(&fit.snapshot()), 0.0);
    }

    #[test]
    fn bad_samples_are_ignored() {
        let mut fit = OnlineLatencyFit::new(priors(), 4);
        fit.observe(0, -1.0, 1.0);
        fit.observe(0, 1.0, 0.0);
        fit.observe(0, f64::NAN, 1.0);
        fit.observe(0, 1.0, f64::INFINITY);
        assert_eq!(fit.throughput(0), 1e9);
    }

    #[test]
    fn models_scale_with_observed_throughput() {
        let mut fit = OnlineLatencyFit::new(priors(), 4);
        let before = fit.model(0, 1000.0);
        assert!((before.beta - 1e-6).abs() < 1e-15);
        assert_eq!(before.gamma, 2.0);
        // A 5x straggler doubles nothing but beta.
        for _ in 0..4 {
            fit.observe(0, 1e9, 5.0);
        }
        let after = fit.model(0, 1000.0);
        assert!((after.beta - 5e-6).abs() < 1e-12);
        assert_eq!(after.gamma, 2.0);
    }
}
