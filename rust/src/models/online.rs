//! Incremental latency-model re-fitting from observed chunk latencies.
//!
//! The §III.A benchmark fits latency models once, up front. A long-running
//! scheduler keeps receiving *measured* chunk latencies from the executor's
//! event stream; this module folds them into per-platform throughput
//! estimates so the next epoch solves against what the platforms are
//! actually doing (a hidden straggler, a noisy neighbour) rather than what
//! the benchmark saw.
//!
//! [`OnlineLatencyFit`] keeps a bounded window of work samples per
//! *(platform, payoff family)*. Exotic kernels realise very different
//! effective FLOP rates on the same silicon (LSMC's regression pass and
//! basket's Cholesky correlate poorly with the RNG-bound families), so a
//! single per-platform scalar systematically mis-prices a mixed queue; the
//! per-family window captures each family's realised rate. The estimate is
//! total work over total time within the window — the work-weighted
//! harmonic mean, robust to mixed chunk sizes — and degrades gracefully:
//! family window (when it has enough samples) → pooled across the
//! platform's families → the benchmark-derived prior. The
//! [`single_line`](OnlineLatencyFit::single_line) constructor disables the
//! family level, reproducing the pre-per-family behaviour for ablation
//! (`[scheduler] family_refit = false`).

use std::collections::VecDeque;

use crate::models::LatencyModel;
use crate::workload::option::Payoff;

/// Per-platform prior the fit falls back to before observations arrive:
/// effective throughput (FLOP/s) and per-stream setup seconds, usually
/// derived from the benchmark-fitted models.
#[derive(Debug, Clone, Copy)]
pub struct PlatformPrior {
    /// Effective application throughput, FLOP/s.
    pub throughput_flops: f64,
    /// Per-(platform, task)-stream setup seconds (the γ term).
    pub setup_secs: f64,
}

/// Fewest window samples before a windowed estimate replaces its fallback.
const MIN_SAMPLES: usize = 2;

/// Windowed per-(platform, family) throughput re-fit.
#[derive(Debug, Clone)]
pub struct OnlineLatencyFit {
    /// Samples kept per (platform, family) ring; 0 disables re-fitting
    /// entirely (the priors are then authoritative forever).
    window: usize,
    /// When false, the family level is bypassed: every estimate is the
    /// platform-pooled one (the legacy single-line behaviour).
    per_family: bool,
    priors: Vec<PlatformPrior>,
    /// `samples[platform][family]`: ring of `(work_flops, work_secs)`.
    samples: Vec<[VecDeque<(f64, f64)>; Payoff::COUNT]>,
}

impl OnlineLatencyFit {
    /// A per-family fit seeded with one prior per platform. Priors must
    /// carry positive finite throughput (asserted: they come from fitted or
    /// nominal models, both of which guarantee it).
    pub fn new(priors: Vec<PlatformPrior>, window: usize) -> OnlineLatencyFit {
        Self::build(priors, window, true)
    }

    /// The ablation constructor: identical bookkeeping, but every model
    /// collapses to the platform-pooled single line.
    pub fn single_line(priors: Vec<PlatformPrior>, window: usize) -> OnlineLatencyFit {
        Self::build(priors, window, false)
    }

    fn build(priors: Vec<PlatformPrior>, window: usize, per_family: bool) -> OnlineLatencyFit {
        for (i, p) in priors.iter().enumerate() {
            assert!(
                p.throughput_flops > 0.0 && p.throughput_flops.is_finite(),
                "platform {i}: non-positive prior throughput {}",
                p.throughput_flops
            );
            assert!(
                p.setup_secs >= 0.0 && p.setup_secs.is_finite(),
                "platform {i}: invalid prior setup {}",
                p.setup_secs
            );
        }
        let samples = priors
            .iter()
            .map(|_| std::array::from_fn(|_| VecDeque::new()))
            .collect();
        OnlineLatencyFit { window, per_family, priors, samples }
    }

    pub fn len(&self) -> usize {
        self.priors.len()
    }

    pub fn is_empty(&self) -> bool {
        self.priors.is_empty()
    }

    /// Whether the family level is active (false under
    /// [`single_line`](Self::single_line)).
    pub fn is_per_family(&self) -> bool {
        self.per_family
    }

    /// Record one successful chunk of `family` work: `flops` observed to
    /// take `secs` of *work time* (callers subtract the setup γ from cold
    /// chunks). Non-positive or non-finite samples are ignored rather than
    /// poisoning the window.
    pub fn observe(&mut self, platform: usize, family: Payoff, flops: f64, secs: f64) {
        if self.window == 0 {
            return;
        }
        if !(flops > 0.0 && flops.is_finite() && secs > 0.0 && secs.is_finite()) {
            return;
        }
        let ring = &mut self.samples[platform][family.index()];
        ring.push_back((flops, secs));
        while ring.len() > self.window {
            ring.pop_front();
        }
    }

    /// Windowed throughput of one ring, `None` below [`MIN_SAMPLES`].
    fn ring_throughput(ring: &VecDeque<(f64, f64)>) -> Option<f64> {
        if ring.len() < MIN_SAMPLES {
            return None;
        }
        let (flops, secs) = ring
            .iter()
            .fold((0.0f64, 0.0f64), |(f, s), (df, ds)| (f + df, s + ds));
        (secs > 0.0).then(|| flops / secs)
    }

    /// Platform-pooled throughput across every family's window, falling
    /// back to the prior — the legacy single-line estimate, and what
    /// [`snapshot`](Self::snapshot)/[`drift`](Self::drift) key on.
    pub fn throughput(&self, platform: usize) -> f64 {
        let (flops, secs, count) = self.samples[platform].iter().fold(
            (0.0f64, 0.0f64, 0usize),
            |(f, s, c), ring| {
                let (df, ds) = ring
                    .iter()
                    .fold((0.0f64, 0.0f64), |(f2, s2), (a, b)| (f2 + a, s2 + b));
                (f + df, s + ds, c + ring.len())
            },
        );
        if count >= MIN_SAMPLES && secs > 0.0 {
            flops / secs
        } else {
            self.priors[platform].throughput_flops
        }
    }

    /// `family`'s realised throughput on `platform` under the fallback
    /// chain: family window → platform-pooled → prior. Under
    /// [`single_line`](Self::single_line) the family level is skipped.
    pub fn family_throughput(&self, platform: usize, family: Payoff) -> f64 {
        if self.per_family {
            if let Some(tp) = Self::ring_throughput(&self.samples[platform][family.index()]) {
                return tp;
            }
        }
        self.throughput(platform)
    }

    /// The (prior) per-stream setup estimate for `platform`, seconds.
    pub fn setup_secs(&self, platform: usize) -> f64 {
        self.priors[platform].setup_secs
    }

    /// Latency model for a `family` task with `flops_per_path` FLOPs per
    /// simulated path on `platform`, under the current estimates.
    pub fn model(&self, platform: usize, family: Payoff, flops_per_path: f64) -> LatencyModel {
        let beta = (flops_per_path / self.family_throughput(platform, family)).max(1e-15);
        LatencyModel::new(beta, self.setup_secs(platform))
    }

    /// All current pooled throughputs — snapshot this at solve time, then
    /// compare with [`drift`](Self::drift) to decide when a re-solve is
    /// due.
    pub fn snapshot(&self) -> Vec<f64> {
        (0..self.len()).map(|i| self.throughput(i)).collect()
    }

    /// Largest relative pooled-throughput shift of any platform vs a prior
    /// [`snapshot`](Self::snapshot) (0.0 = models unchanged).
    pub fn drift(&self, snapshot: &[f64]) -> f64 {
        debug_assert_eq!(snapshot.len(), self.len());
        (0..self.len())
            .map(|i| {
                let then = snapshot[i].max(1e-15);
                (self.throughput(i) / then - 1.0).abs()
            })
            .fold(0.0, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn priors() -> Vec<PlatformPrior> {
        vec![
            PlatformPrior { throughput_flops: 1e9, setup_secs: 2.0 },
            PlatformPrior { throughput_flops: 4e9, setup_secs: 0.5 },
        ]
    }

    #[test]
    fn falls_back_to_prior_until_samples_arrive() {
        let mut fit = OnlineLatencyFit::new(priors(), 8);
        assert_eq!(fit.throughput(0), 1e9);
        fit.observe(0, Payoff::European, 1e9, 2.0); // one sample is not enough
        assert_eq!(fit.throughput(0), 1e9);
        fit.observe(0, Payoff::European, 1e9, 2.0);
        assert!((fit.throughput(0) - 5e8).abs() / 5e8 < 1e-12);
        // Platform 1 untouched.
        assert_eq!(fit.throughput(1), 4e9);
    }

    #[test]
    fn window_bounds_memory_and_tracks_drift() {
        let mut fit = OnlineLatencyFit::new(priors(), 4);
        // Fill with on-prior samples, then shift to half speed: the window
        // forgets the old regime.
        for _ in 0..4 {
            fit.observe(0, Payoff::European, 1e9, 1.0);
        }
        let snap = fit.snapshot();
        assert!((fit.throughput(0) - 1e9).abs() < 1.0);
        for _ in 0..4 {
            fit.observe(0, Payoff::European, 1e9, 2.0);
        }
        assert!((fit.throughput(0) - 5e8).abs() < 1.0);
        assert!((fit.drift(&snap) - 0.5).abs() < 1e-9);
    }

    #[test]
    fn window_zero_disables_refit() {
        let mut fit = OnlineLatencyFit::new(priors(), 0);
        for _ in 0..10 {
            fit.observe(0, Payoff::European, 1e9, 10.0);
        }
        assert_eq!(fit.throughput(0), 1e9);
        assert_eq!(fit.drift(&fit.snapshot()), 0.0);
    }

    #[test]
    fn bad_samples_are_ignored() {
        let mut fit = OnlineLatencyFit::new(priors(), 4);
        fit.observe(0, Payoff::European, -1.0, 1.0);
        fit.observe(0, Payoff::European, 1.0, 0.0);
        fit.observe(0, Payoff::European, f64::NAN, 1.0);
        fit.observe(0, Payoff::European, 1.0, f64::INFINITY);
        assert_eq!(fit.throughput(0), 1e9);
    }

    #[test]
    fn models_scale_with_observed_throughput() {
        let mut fit = OnlineLatencyFit::new(priors(), 4);
        let before = fit.model(0, Payoff::European, 1000.0);
        assert!((before.beta - 1e-6).abs() < 1e-15);
        assert_eq!(before.gamma, 2.0);
        // A 5x straggler changes nothing but beta.
        for _ in 0..4 {
            fit.observe(0, Payoff::European, 1e9, 5.0);
        }
        let after = fit.model(0, Payoff::European, 1000.0);
        assert!((after.beta - 5e-6).abs() < 1e-12);
        assert_eq!(after.gamma, 2.0);
    }

    #[test]
    fn families_are_tracked_independently() {
        // Barrier runs on-prior; basket realises a quarter of the FLOP rate
        // (4x cost per path). The family estimates must separate while the
        // pooled one blends.
        let mut fit = OnlineLatencyFit::new(priors(), 8);
        for _ in 0..4 {
            fit.observe(0, Payoff::Barrier, 1e9, 1.0);
            fit.observe(0, Payoff::Basket, 1e9, 4.0);
        }
        assert!((fit.family_throughput(0, Payoff::Barrier) - 1e9).abs() < 1.0);
        assert!((fit.family_throughput(0, Payoff::Basket) - 2.5e8).abs() < 1.0);
        let pooled = fit.throughput(0);
        assert!(pooled > 2.5e8 && pooled < 1e9, "pooled {pooled}");
        // Unsampled families fall back to the pooled estimate.
        assert_eq!(fit.family_throughput(0, Payoff::Heston), pooled);
        // And the per-family models price the same FLOPs differently.
        let cheap = fit.model(0, Payoff::Barrier, 1000.0);
        let dear = fit.model(0, Payoff::Basket, 1000.0);
        assert!((dear.beta / cheap.beta - 4.0).abs() < 1e-9);
    }

    #[test]
    fn single_line_mode_ignores_family_distinctions() {
        let mut fit = OnlineLatencyFit::single_line(priors(), 8);
        assert!(!fit.is_per_family());
        for _ in 0..4 {
            fit.observe(0, Payoff::Barrier, 1e9, 1.0);
            fit.observe(0, Payoff::Basket, 1e9, 4.0);
        }
        let pooled = fit.throughput(0);
        for family in Payoff::ALL {
            assert_eq!(fit.family_throughput(0, family), pooled, "{family:?}");
        }
        let a = fit.model(0, Payoff::Barrier, 1000.0);
        let b = fit.model(0, Payoff::Basket, 1000.0);
        assert_eq!(a.beta, b.beta);
    }
}
