//! Seeded market-tick simulator: correlated burst re-pricing storms.
//!
//! The paper's algorithmic-trading case study (§V) prices a book once; the
//! real workload it gestures at is a *tick stream* — market moves trigger
//! portfolio-wide re-pricing storms, thousands of near-identical jobs
//! clustered in time. [`MarketSim`] generates that stream deterministically
//! from a seed: a steady base load of mixed-book jobs every tick, plus a
//! storm every `storm_every` ticks in which the whole portfolio of one
//! payoff family re-prices at once (correlated: one market move, one asset
//! class). The stream drives the online scheduler in
//! `rust/benches/perf_storm.rs` and anywhere else a reproducible burst
//! arrival pattern is needed.
//!
//! Everything is counter-based (SplitMix64 over `(seed, tick, job)`), the
//! same no-global-RNG discipline as the pricing kernels: tick `t` has the
//! same jobs no matter how many times or in what order it is generated.

use crate::api::error::{CloudshapesError, Result};
use crate::coordinator::scheduler::{JobSpec, Slo};
use crate::workload::Payoff;

/// `[storm]` configuration keys (see `docs/CONFIG.md`).
#[derive(Debug, Clone)]
pub struct StormConfig {
    /// Seed of the whole stream (jobs, families, clustering).
    pub seed: u64,
    /// Ticks in the simulated trading day.
    pub ticks: usize,
    /// Mixed-book jobs submitted every tick (the base load; 0 = quiet
    /// between storms).
    pub base_jobs: usize,
    /// A storm fires every this many ticks (0 = never).
    pub storm_every: usize,
    /// Correlated re-price jobs per storm.
    pub storm_jobs: usize,
    /// Option tasks per job.
    pub tasks_per_job: usize,
    /// CI half-width accuracy target sizing each task's N.
    pub accuracy: f64,
    /// Deadline SLO attached to every job, cluster-virtual seconds.
    pub deadline_secs: f64,
    /// Daily spot-price swing amplitude handed to
    /// [`Catalogue::spot_rate_at`](crate::platforms::Catalogue::spot_rate_at),
    /// in [0, 1).
    pub spot_volatility: f64,
}

impl Default for StormConfig {
    fn default() -> Self {
        StormConfig {
            seed: 7,
            ticks: 48,
            base_jobs: 1,
            storm_every: 12,
            storm_jobs: 64,
            tasks_per_job: 2,
            accuracy: 0.2,
            deadline_secs: 14_400.0,
            spot_volatility: 0.2,
        }
    }
}

impl StormConfig {
    /// Validate the knobs (the config parser and [`MarketSim::new`] both
    /// route through this).
    pub fn validate(&self) -> Result<()> {
        if self.ticks == 0 {
            return Err(CloudshapesError::config("storm.ticks must be >= 1"));
        }
        if self.storm_every > 0 && self.storm_jobs == 0 {
            return Err(CloudshapesError::config(
                "storm.storm_jobs must be >= 1 when storms fire (storm_every > 0)",
            ));
        }
        if self.tasks_per_job == 0 || self.tasks_per_job > JobSpec::MAX_TASKS {
            return Err(CloudshapesError::config(format!(
                "storm.tasks_per_job must be in 1..={}, got {}",
                JobSpec::MAX_TASKS,
                self.tasks_per_job
            )));
        }
        if !(self.accuracy > 0.0 && self.accuracy.is_finite()) {
            return Err(CloudshapesError::config(format!(
                "storm.accuracy must be positive and finite, got {}",
                self.accuracy
            )));
        }
        if !(self.deadline_secs > 0.0 && self.deadline_secs.is_finite()) {
            return Err(CloudshapesError::config(format!(
                "storm.deadline_secs must be positive and finite, got {}",
                self.deadline_secs
            )));
        }
        if !(self.spot_volatility >= 0.0 && self.spot_volatility < 1.0) {
            return Err(CloudshapesError::config(format!(
                "storm.spot_volatility must be in [0, 1), got {}",
                self.spot_volatility
            )));
        }
        Ok(())
    }
}

/// One tick's submissions.
#[derive(Debug, Clone)]
pub struct MarketTick {
    pub index: usize,
    pub is_storm: bool,
    /// The payoff family the storm's correlated portfolio re-prices
    /// (`None` on base-load ticks: a mixed book).
    pub family: Option<Payoff>,
    pub jobs: Vec<JobSpec>,
}

/// Deterministic tick-stream generator over a [`StormConfig`].
#[derive(Debug, Clone)]
pub struct MarketSim {
    cfg: StormConfig,
}

impl MarketSim {
    pub fn new(cfg: StormConfig) -> Result<MarketSim> {
        cfg.validate()?;
        Ok(MarketSim { cfg })
    }

    pub fn config(&self) -> &StormConfig {
        &self.cfg
    }

    /// Ticks in the stream.
    pub fn ticks(&self) -> usize {
        self.cfg.ticks
    }

    fn is_storm(&self, t: usize) -> bool {
        self.cfg.storm_every > 0 && (t + 1) % self.cfg.storm_every == 0
    }

    fn jobs_at(&self, t: usize) -> usize {
        self.cfg.base_jobs + if self.is_storm(t) { self.cfg.storm_jobs } else { 0 }
    }

    /// Total jobs across the whole stream (for sizing harnesses).
    pub fn total_jobs(&self) -> usize {
        (0..self.cfg.ticks).map(|t| self.jobs_at(t)).sum()
    }

    /// Total simulation paths across the whole stream — the "~1M option
    /// re-prices" scale knob the storm bench reports.
    pub fn total_sims(&self) -> Result<u64> {
        let mut sims = 0u64;
        for t in 0..self.cfg.ticks {
            for job in self.tick(t)?.jobs {
                sims += job.tasks.iter().map(|x| x.n_sims).sum::<u64>();
            }
        }
        Ok(sims)
    }

    /// Generate tick `t` (out-of-range is a config error). Storm ticks
    /// submit `storm_jobs` correlated jobs — one payoff family, clustered
    /// seeds — on top of the base load; every job carries the deadline SLO.
    pub fn tick(&self, t: usize) -> Result<MarketTick> {
        if t >= self.cfg.ticks {
            return Err(CloudshapesError::config(format!(
                "tick {t} out of range (stream has {} ticks)",
                self.cfg.ticks
            )));
        }
        let storm = self.is_storm(t);
        let family = if storm {
            // Every family the workload layer knows, not a hard-coded
            // subset — new payoff families join the storm rotation
            // automatically.
            let pick = mix(self.cfg.seed ^ (t as u64)) % Payoff::ALL.len() as u64;
            Some(Payoff::ALL[pick as usize])
        } else {
            None
        };
        let n = self.jobs_at(t);
        let mut jobs = Vec::with_capacity(n);
        for k in 0..n {
            let seed = mix(self
                .cfg
                .seed
                .wrapping_mul(0x9e37_79b9_7f4a_7c15)
                .wrapping_add((t as u64) << 20)
                .wrapping_add(k as u64));
            jobs.push(JobSpec::generate(
                family,
                self.cfg.tasks_per_job,
                self.cfg.accuracy,
                seed,
                Slo::Deadline(self.cfg.deadline_secs),
            )?);
        }
        Ok(MarketTick { index: t, is_storm: storm, family, jobs })
    }
}

/// SplitMix64 finaliser — the counter-based mixer behind tick determinism.
fn mix(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9e37_79b9_7f4a_7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_validation() {
        assert!(StormConfig::default().validate().is_ok());
        assert!(StormConfig { ticks: 0, ..Default::default() }.validate().is_err());
        assert!(StormConfig { storm_jobs: 0, ..Default::default() }.validate().is_err());
        // No storms -> storm_jobs unconstrained.
        assert!(StormConfig { storm_every: 0, storm_jobs: 0, ..Default::default() }
            .validate()
            .is_ok());
        assert!(StormConfig { tasks_per_job: 0, ..Default::default() }.validate().is_err());
        assert!(StormConfig { accuracy: 0.0, ..Default::default() }.validate().is_err());
        assert!(StormConfig { deadline_secs: -1.0, ..Default::default() }
            .validate()
            .is_err());
        assert!(StormConfig { spot_volatility: 1.0, ..Default::default() }
            .validate()
            .is_err());
        assert!(MarketSim::new(StormConfig { ticks: 0, ..Default::default() }).is_err());
    }

    #[test]
    fn storms_fire_on_cadence_with_correlated_families() {
        let cfg = StormConfig {
            ticks: 24,
            base_jobs: 2,
            storm_every: 8,
            storm_jobs: 5,
            ..Default::default()
        };
        let sim = MarketSim::new(cfg).unwrap();
        let mut storms = 0;
        for t in 0..sim.ticks() {
            let tick = sim.tick(t).unwrap();
            assert_eq!(tick.index, t);
            if tick.is_storm {
                storms += 1;
                assert_eq!(tick.jobs.len(), 7);
                let fam = tick.family.expect("storm ticks name a family");
                // Correlated: every storm job re-prices the same family.
                for job in &tick.jobs[2..] {
                    assert!(job.tasks.iter().all(|x| x.payoff == fam), "mixed storm");
                }
            } else {
                assert_eq!(tick.jobs.len(), 2);
                assert!(tick.family.is_none());
            }
        }
        assert_eq!(storms, 3); // ticks 7, 15, 23
        assert_eq!(sim.total_jobs(), 24 * 2 + 3 * 5);
        assert!(sim.tick(24).is_err());
    }

    #[test]
    fn stream_is_deterministic_and_seed_sensitive() {
        let sim = MarketSim::new(StormConfig::default()).unwrap();
        let a = sim.tick(11).unwrap();
        let b = sim.tick(11).unwrap();
        assert_eq!(a.jobs.len(), b.jobs.len());
        for (ja, jb) in a.jobs.iter().zip(&b.jobs) {
            assert_eq!(ja.tasks.len(), jb.tasks.len());
            for (ta, tb) in ja.tasks.iter().zip(&jb.tasks) {
                assert_eq!(ta.payoff, tb.payoff);
                assert_eq!(ta.n_sims, tb.n_sims);
                assert_eq!(ta.spot, tb.spot);
            }
        }
        // A different seed reshuffles the book.
        let other =
            MarketSim::new(StormConfig { seed: 1234, ..Default::default() }).unwrap();
        let c = other.tick(11).unwrap();
        let differs = a
            .jobs
            .iter()
            .zip(&c.jobs)
            .any(|(ja, jc)| {
                ja.tasks.iter().zip(&jc.tasks).any(|(x, y)| x.spot != y.spot)
            });
        assert!(differs, "seed change left tick 11 identical");
        assert!(sim.total_sims().unwrap() > 0);
    }
}
