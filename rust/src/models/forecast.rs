//! Arrival forecasting and predictive autoscaling (ROADMAP item 5: rent
//! capacity *before* the storm, drain it after).
//!
//! The online scheduler sees arrival work per epoch. [`ArrivalForecaster`]
//! fits that series with an EWMA level plus an additive seasonal term
//! (Holt–Winters without trend), so a market that storms every N epochs is
//! predicted one epoch ahead. [`Autoscaler`] turns the prediction into a
//! rent/drain decision over the cluster's instances: expansion (pre-rent)
//! applies immediately, shrinking waits out a hysteresis window so one
//! quiet epoch mid-storm does not churn the fleet. The autoscaler only
//! *steers* — un-rented platforms stay usable at a rent-lead setup penalty
//! (see `coordinator::scheduler`), so a wrong forecast costs money, never
//! correctness.
//!
//! Quota discipline: the autoscaler operates over an already-instantiated
//! cluster, and [`Catalogue::instantiate`](crate::platforms::Catalogue::instantiate)
//! refuses compositions beyond per-type `available` caps — so the rented
//! set can never exceed catalogue quotas by construction.

use crate::api::error::{CloudshapesError, Result};

/// `[forecast]` configuration keys (see `docs/CONFIG.md`).
#[derive(Debug, Clone)]
pub struct ForecastConfig {
    /// Whether predictive autoscaling runs at all. Disabled (the default),
    /// every instance stays rented — the static over-provisioned baseline.
    pub enabled: bool,
    /// EWMA smoothing factor for the level, seasonal and error terms, in
    /// (0, 1]; higher adapts faster, lower smooths harder.
    pub alpha: f64,
    /// Seasonal buckets (epochs per period); 0 fits a level-only EWMA.
    pub season_len: usize,
    /// Capacity head-room multiplier on the predicted demand, >= 1.
    pub safety: f64,
    /// Consecutive low-demand epochs required before rentals shrink (the
    /// drain hysteresis), >= 1.
    pub drain_epochs: usize,
    /// Instances kept rented even at zero predicted demand.
    pub min_rented: usize,
    /// Extra setup seconds the planner charges work placed on un-rented
    /// platforms (API/boot lead time), >= 0.
    pub rent_lead_secs: f64,
}

impl Default for ForecastConfig {
    fn default() -> Self {
        ForecastConfig {
            enabled: false,
            alpha: 0.3,
            season_len: 8,
            safety: 1.25,
            drain_epochs: 2,
            min_rented: 1,
            rent_lead_secs: 30.0,
        }
    }
}

impl ForecastConfig {
    /// Validate the knobs (the config parser and the scheduler both route
    /// through this).
    pub fn validate(&self) -> Result<()> {
        if !(self.alpha > 0.0 && self.alpha <= 1.0) {
            return Err(CloudshapesError::config(format!(
                "forecast.alpha must be in (0, 1], got {}",
                self.alpha
            )));
        }
        if !(self.safety >= 1.0 && self.safety.is_finite()) {
            return Err(CloudshapesError::config(format!(
                "forecast.safety must be >= 1 and finite, got {}",
                self.safety
            )));
        }
        if self.drain_epochs == 0 {
            return Err(CloudshapesError::config("forecast.drain_epochs must be >= 1"));
        }
        if !(self.rent_lead_secs >= 0.0 && self.rent_lead_secs.is_finite()) {
            return Err(CloudshapesError::config(format!(
                "forecast.rent_lead_secs must be non-negative, got {}",
                self.rent_lead_secs
            )));
        }
        Ok(())
    }
}

/// EWMA level + additive seasonal fit over per-epoch arrival work.
///
/// Feed one [`observe`](Self::observe) per epoch; ask
/// [`forecast_next`](Self::forecast_next) for the next epoch's prediction.
/// Each issued forecast is scored against the next observation into a
/// relative-error EWMA ([`error`](Self::error)) — the
/// `scheduler_forecast_error` gauge.
#[derive(Debug, Clone)]
pub struct ArrivalForecaster {
    alpha: f64,
    /// Additive seasonal offsets, one per bucket (empty = level only).
    season: Vec<f64>,
    level: Option<f64>,
    /// Observations consumed so far (indexes the seasonal bucket).
    epoch: usize,
    /// The forecast issued for the epoch now being observed.
    pending: Option<f64>,
    /// EWMA of the relative |forecast − actual| error.
    err: Option<f64>,
}

impl ArrivalForecaster {
    pub fn new(alpha: f64, season_len: usize) -> ArrivalForecaster {
        assert!(alpha > 0.0 && alpha <= 1.0, "forecaster alpha must be in (0, 1]: {alpha}");
        ArrivalForecaster {
            alpha,
            season: vec![0.0; season_len],
            level: None,
            epoch: 0,
            pending: None,
            err: None,
        }
    }

    /// Observations consumed so far.
    pub fn len(&self) -> usize {
        self.epoch
    }

    pub fn is_empty(&self) -> bool {
        self.epoch == 0
    }

    /// Feed one epoch's observed arrival work. Non-finite or negative
    /// observations are ignored (same discipline as `OnlineLatencyFit`).
    pub fn observe(&mut self, actual: f64) {
        if !actual.is_finite() || actual < 0.0 {
            return;
        }
        if let Some(f) = self.pending.take() {
            // Score the forecast issued for this epoch. Normalising by
            // max(actual, forecast, 1) bounds the error in [0, 1] even on
            // zero-arrival epochs.
            let rel = (f - actual).abs() / actual.max(f).max(1.0);
            self.err = Some(match self.err {
                Some(e) => self.alpha * rel + (1.0 - self.alpha) * e,
                None => rel,
            });
        }
        let bucket = if self.season.is_empty() {
            None
        } else {
            Some(self.epoch % self.season.len())
        };
        match self.level {
            None => self.level = Some(actual),
            Some(l) => {
                let deseason = actual - bucket.map_or(0.0, |b| self.season[b]);
                self.level = Some(self.alpha * deseason + (1.0 - self.alpha) * l);
            }
        }
        if let (Some(b), Some(l)) = (bucket, self.level) {
            self.season[b] = self.alpha * (actual - l) + (1.0 - self.alpha) * self.season[b];
        }
        self.epoch += 1;
    }

    /// Predicted arrival work for the next epoch (never negative). The
    /// prediction is recorded so the next [`observe`](Self::observe) can
    /// score it.
    pub fn forecast_next(&mut self) -> f64 {
        let level = self.level.unwrap_or(0.0);
        let seasonal = if self.season.is_empty() {
            0.0
        } else {
            self.season[self.epoch % self.season.len()]
        };
        let f = (level + seasonal).max(0.0);
        self.pending = Some(f);
        f
    }

    /// EWMA of the relative |forecast − actual| error (`None` until the
    /// first scored forecast).
    pub fn error(&self) -> Option<f64> {
        self.err
    }
}

/// The economics of one rentable instance the autoscaler chooses between.
#[derive(Debug, Clone, Copy)]
pub struct PlatformEcon {
    /// Sustained throughput prior, flops/s.
    pub throughput_flops: f64,
    /// Holding rate while rented, $/hour.
    pub rate_per_hour: f64,
}

/// Forecast-driven rent/drain policy over a fixed instance fleet.
///
/// Each epoch boundary, [`plan`](Self::plan) observes that epoch's arrival
/// work, forecasts the next, and greedily rents instances in descending
/// cost-efficiency (throughput per dollar) until the predicted demand rate
/// (with `safety` head-room) is covered. Pre-renting is immediate;
/// draining waits for `drain_epochs` consecutive low-demand epochs and
/// never goes below `min_rented`.
#[derive(Debug)]
pub struct Autoscaler {
    cfg: ForecastConfig,
    forecaster: ArrivalForecaster,
    econ: Vec<PlatformEcon>,
    /// Instance indices in rent order (descending throughput per dollar).
    order: Vec<usize>,
    rented: Vec<bool>,
    low_streak: usize,
}

impl Autoscaler {
    pub fn new(cfg: ForecastConfig, econ: Vec<PlatformEcon>) -> Autoscaler {
        for e in &econ {
            assert!(
                e.throughput_flops > 0.0 && e.throughput_flops.is_finite(),
                "autoscaler throughput prior must be positive: {e:?}"
            );
            assert!(
                e.rate_per_hour >= 0.0 && e.rate_per_hour.is_finite(),
                "autoscaler rate must be non-negative: {e:?}"
            );
        }
        let mut order: Vec<usize> = (0..econ.len()).collect();
        order.sort_by(|&a, &b| {
            let ea = econ[a].throughput_flops / econ[a].rate_per_hour.max(1e-12);
            let eb = econ[b].throughput_flops / econ[b].rate_per_hour.max(1e-12);
            eb.partial_cmp(&ea).unwrap_or(std::cmp::Ordering::Equal).then(a.cmp(&b))
        });
        let n = econ.len();
        let forecaster = ArrivalForecaster::new(cfg.alpha, cfg.season_len);
        Autoscaler { cfg, forecaster, econ, order, rented: vec![true; n], low_streak: 0 }
    }

    /// One planning step at an epoch boundary: observe `arrived_flops` (new
    /// work submitted during the epoch just ended), forecast the next
    /// epoch, and re-decide the rented set given the outstanding
    /// `backlog_flops`. Returns the rented mask, instance-index aligned.
    pub fn plan(&mut self, arrived_flops: f64, backlog_flops: f64, epoch_secs: f64) -> &[bool] {
        if !self.cfg.enabled {
            for r in &mut self.rented {
                *r = true;
            }
            return &self.rented;
        }
        self.forecaster.observe(arrived_flops);
        let predicted = self.forecaster.forecast_next();
        let demand =
            (predicted + backlog_flops.max(0.0)) * self.cfg.safety / epoch_secs.max(1e-9);
        let mut target = vec![false; self.econ.len()];
        let mut capacity = 0.0f64;
        let mut count = 0usize;
        for &i in &self.order {
            if count >= self.cfg.min_rented && capacity >= demand {
                break;
            }
            target[i] = true;
            capacity += self.econ[i].throughput_flops;
            count += 1;
        }
        let current = self.rented.iter().filter(|r| **r).count();
        if count < current {
            self.low_streak += 1;
            if self.low_streak < self.cfg.drain_epochs {
                return &self.rented; // hold: not drained long enough yet
            }
            self.low_streak = 0;
        } else {
            self.low_streak = 0;
        }
        self.rented = target;
        &self.rented
    }

    /// The current rented mask (instance-index aligned).
    pub fn rented(&self) -> &[bool] {
        &self.rented
    }

    pub fn rented_count(&self) -> usize {
        self.rented.iter().filter(|r| **r).count()
    }

    /// The forecaster's relative-error EWMA.
    pub fn forecast_error(&self) -> Option<f64> {
        self.forecaster.error()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platforms::Catalogue;

    #[test]
    fn config_validation() {
        assert!(ForecastConfig::default().validate().is_ok());
        let bad = ForecastConfig { alpha: 0.0, ..Default::default() };
        assert!(bad.validate().is_err());
        let bad = ForecastConfig { alpha: 1.5, ..Default::default() };
        assert!(bad.validate().is_err());
        let bad = ForecastConfig { safety: 0.5, ..Default::default() };
        assert!(bad.validate().is_err());
        let bad = ForecastConfig { drain_epochs: 0, ..Default::default() };
        assert!(bad.validate().is_err());
        let bad = ForecastConfig { rent_lead_secs: -1.0, ..Default::default() };
        assert!(bad.validate().is_err());
    }

    #[test]
    fn seasonal_fit_converges_on_periodic_trace() {
        // Period-4 arrivals: three quiet epochs, then a spike.
        let trace = [0.0, 0.0, 0.0, 400.0];
        let mut fc = ArrivalForecaster::new(0.5, 4);
        for k in 0..60 {
            let _ = fc.forecast_next();
            fc.observe(trace[k % 4]);
        }
        // epoch = 60 -> next bucket 0 (quiet), then walk to the spike.
        assert!(fc.forecast_next() < 60.0, "quiet bucket over-forecast");
        fc.observe(trace[0]);
        fc.observe(trace[1]);
        fc.observe(trace[2]);
        // Next bucket is 63 % 4 == 3: the spike.
        let spike = fc.forecast_next();
        assert!(spike > 250.0, "spike bucket under-forecast: {spike}");
        // Converged forecasts score well.
        let err = fc.error().expect("forecasts were scored");
        assert!(err < 0.5, "error EWMA failed to converge: {err}");
    }

    #[test]
    fn level_only_fit_tracks_the_mean() {
        let mut fc = ArrivalForecaster::new(0.5, 0);
        for _ in 0..20 {
            fc.observe(100.0);
        }
        let f = fc.forecast_next();
        assert!((f - 100.0).abs() < 1e-6, "level-only forecast: {f}");
        // Garbage observations are ignored.
        fc.observe(f64::NAN);
        fc.observe(-5.0);
        assert_eq!(fc.len(), 20);
    }

    fn flat_econ(n: usize) -> Vec<PlatformEcon> {
        vec![PlatformEcon { throughput_flops: 100.0, rate_per_hour: 1.0 }; n]
    }

    #[test]
    fn disabled_keeps_everything_rented() {
        let cfg = ForecastConfig { enabled: false, ..Default::default() };
        let mut asc = Autoscaler::new(cfg, flat_econ(4));
        for _ in 0..10 {
            asc.plan(0.0, 0.0, 1.0);
        }
        assert_eq!(asc.rented_count(), 4);
        assert!(asc.forecast_error().is_none());
    }

    #[test]
    fn pre_rents_before_predicted_spike_and_drains_after() {
        let cfg = ForecastConfig {
            enabled: true,
            alpha: 0.5,
            season_len: 4,
            safety: 1.25,
            drain_epochs: 2,
            min_rented: 1,
            rent_lead_secs: 30.0,
        };
        let mut asc = Autoscaler::new(cfg, flat_econ(4));
        let trace = [0.0, 0.0, 0.0, 400.0];
        for k in 0..14 {
            asc.plan(trace[k % 4], 0.0, 1.0);
        }
        // The 15th call observes a QUIET epoch (index 14, bucket 2) but
        // forecasts the spike bucket — pre-renting must fire on the
        // forecast, ahead of any arrival.
        asc.plan(trace[14 % 4], 0.0, 1.0);
        assert!(
            asc.rented_count() >= 3,
            "no pre-rent ahead of the spike: {} rented",
            asc.rented_count()
        );
        // Post-storm: a long run of quiet epochs drains back to the floor
        // (the seasonal ghost of the spike takes a few periods to decay,
        // and every shrink waits out the hysteresis window).
        for _ in 0..32 {
            asc.plan(0.0, 0.0, 1.0);
        }
        assert_eq!(asc.rented_count(), 1, "drain did not trim to min_rented");
    }

    #[test]
    fn rent_order_prefers_throughput_per_dollar() {
        let econ = vec![
            PlatformEcon { throughput_flops: 100.0, rate_per_hour: 10.0 }, // 10 flops/$
            PlatformEcon { throughput_flops: 50.0, rate_per_hour: 1.0 },   // 50 flops/$
        ];
        let cfg = ForecastConfig {
            enabled: true,
            season_len: 0,
            min_rented: 1,
            drain_epochs: 1,
            ..Default::default()
        };
        let mut asc = Autoscaler::new(cfg, econ);
        // Tiny steady demand: only the efficient instance stays rented.
        for _ in 0..6 {
            asc.plan(10.0, 0.0, 1.0);
        }
        assert_eq!(asc.rented(), &[false, true]);
    }

    #[test]
    fn pre_rent_never_exceeds_catalogue_quotas() {
        // The fleet the autoscaler scales over is an instantiated
        // composition, which the catalogue bounds by `available` — so even
        // unbounded demand can only rent what the quota admitted.
        let cat = Catalogue::small();
        let counts = cat.availability();
        let specs = cat.instantiate(&counts, false).unwrap();
        let econ: Vec<PlatformEcon> = specs
            .iter()
            .map(|s| PlatformEcon {
                throughput_flops: s.app_gflops.max(1e-9) * 1e9,
                rate_per_hour: s.rate_per_hour,
            })
            .collect();
        let cfg = ForecastConfig { enabled: true, ..Default::default() };
        let mut asc = Autoscaler::new(cfg, econ);
        asc.plan(1e18, 1e18, 1.0); // storm far beyond total capacity
        let offer_of = cat.instance_offers(&counts);
        for (t, cap) in cat.availability().iter().enumerate() {
            let rented_of_type = asc
                .rented()
                .iter()
                .zip(&offer_of)
                .filter(|(r, o)| **r && **o == t)
                .count();
            assert!(rented_of_type <= *cap, "type {t}: {rented_of_type} > quota {cap}");
        }
        // And a composition beyond quota is refused before the autoscaler
        // ever sees it.
        let mut over = counts.clone();
        over[0] += 1;
        assert!(cat.instantiate(&over, false).is_err());
    }
}
