//! Latency model — Equation 1a of the paper: `L(N) = β·N + γ`.
//!
//! The proportional term β reflects O(N) Monte Carlo work; the constant γ
//! the task-initiation overhead (communication, FPGA configuration, …).
//! Coefficients are fitted from benchmark samples with *weighted* least
//! squares (§III.A); we use 1/L² weights so relative error is what's
//! minimised — matching the paper's Fig. 2 evaluation metric.

use crate::util::stats::{self, LinearFit};

/// `L(N) = beta*N + gamma`, latencies in seconds, N in simulations.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LatencyModel {
    pub beta: f64,
    pub gamma: f64,
    /// R² of the fit on the benchmark data (1.0 for exact models).
    pub r_squared: f64,
}

impl LatencyModel {
    pub fn new(beta: f64, gamma: f64) -> LatencyModel {
        assert!(beta > 0.0 && beta.is_finite(), "beta must be positive: {beta}");
        assert!(gamma >= 0.0 && gamma.is_finite(), "gamma must be non-negative: {gamma}");
        LatencyModel { beta, gamma, r_squared: 1.0 }
    }

    /// Predicted latency for `n` simulations (n = 0 ⇒ no work ⇒ 0, not γ).
    pub fn predict(&self, n: u64) -> f64 {
        if n == 0 {
            0.0
        } else {
            self.beta * n as f64 + self.gamma
        }
    }

    /// Largest `n` whose predicted latency fits within `budget_secs`
    /// (0 if even the setup time doesn't fit).
    pub fn max_n_within(&self, budget_secs: f64) -> u64 {
        if budget_secs <= self.gamma {
            return 0;
        }
        ((budget_secs - self.gamma) / self.beta).floor() as u64
    }

    /// Fit from benchmark samples `(n, latency_secs)` using WLS with 1/L²
    /// (relative-error) weights. Returns `None` for degenerate inputs.
    /// Negative fitted coefficients are clamped to tiny positive values —
    /// they arise only from noise on near-degenerate sample sets.
    pub fn fit(samples: &[(u64, f64)]) -> Option<LatencyModel> {
        if samples.len() < 2 {
            return None;
        }
        let xs: Vec<f64> = samples.iter().map(|(n, _)| *n as f64).collect();
        let ys: Vec<f64> = samples.iter().map(|(_, l)| *l).collect();
        if ys.iter().any(|l| *l <= 0.0) {
            return None;
        }
        let ws: Vec<f64> = ys.iter().map(|l| 1.0 / (l * l)).collect();
        let LinearFit { slope, intercept, r_squared } =
            stats::weighted_least_squares(&xs, &ys, &ws)?;
        Some(LatencyModel {
            beta: slope.max(1e-15),
            gamma: intercept.max(0.0),
            r_squared,
        })
    }

    /// Relative prediction error vs an observed latency (Fig. 2 metric).
    pub fn relative_error(&self, n: u64, observed_secs: f64) -> f64 {
        stats::relative_error(self.predict(n), observed_secs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn predict_is_linear_with_setup() {
        let m = LatencyModel::new(1e-6, 2.0);
        assert!((m.predict(1_000_000) - 3.0).abs() < 1e-12);
        assert_eq!(m.predict(0), 0.0);
    }

    #[test]
    fn fit_recovers_exact_model() {
        let truth = LatencyModel::new(5e-7, 1.5);
        let samples: Vec<(u64, f64)> =
            (1..20).map(|i| (i * 100_000, truth.predict(i * 100_000))).collect();
        let fit = LatencyModel::fit(&samples).unwrap();
        assert!((fit.beta - truth.beta).abs() / truth.beta < 1e-9);
        assert!((fit.gamma - truth.gamma).abs() < 1e-6);
        assert!(fit.r_squared > 0.999999);
    }

    #[test]
    fn fit_under_noise_extrapolates_within_10pct() {
        // The paper's Fig. 2 claim: <=10% error at many times the benchmark
        // size. Benchmark at n <= 1e6, predict at 3e7 (30x extrapolation).
        let truth = LatencyModel::new(2e-6, 5.0);
        let mut rng = Rng::new(17);
        let samples: Vec<(u64, f64)> = (1..=30)
            .map(|i| {
                let n = i * 33_000;
                (n, truth.predict(n) * rng.lognormal_noise(0.05))
            })
            .collect();
        let fit = LatencyModel::fit(&samples).unwrap();
        let err = fit.relative_error(30_000_000, truth.predict(30_000_000));
        assert!(err < 0.10, "extrapolation error {err}");
    }

    #[test]
    fn fit_rejects_degenerate() {
        assert!(LatencyModel::fit(&[]).is_none());
        assert!(LatencyModel::fit(&[(10, 1.0)]).is_none());
        assert!(LatencyModel::fit(&[(10, 1.0), (10, 1.1)]).is_none()); // same n
        assert!(LatencyModel::fit(&[(10, 0.0), (20, 1.0)]).is_none()); // zero latency
    }

    #[test]
    fn max_n_within_budget() {
        let m = LatencyModel::new(1e-3, 2.0);
        assert_eq!(m.max_n_within(1.0), 0); // can't even set up
        assert_eq!(m.max_n_within(3.0), 1000);
        assert_eq!(m.max_n_within(2.0005), 0);
    }

    #[test]
    #[should_panic(expected = "beta")]
    fn zero_beta_rejected() {
        LatencyModel::new(0.0, 1.0);
    }
}
