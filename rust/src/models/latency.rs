//! Latency model — Equation 1a of the paper: `L(N) = β·N + γ`.
//!
//! The proportional term β reflects O(N) Monte Carlo work; the constant γ
//! the task-initiation overhead (communication, FPGA configuration, …).
//! Coefficients are fitted from benchmark samples with *weighted* least
//! squares (§III.A); we use 1/L² weights so relative error is what's
//! minimised — matching the paper's Fig. 2 evaluation metric.
//!
//! [`FamilyLatencyFit`] extends the single line to *per-payoff-family*
//! coefficients: exotic kernels (LSMC regression, d-asset baskets, Heston's
//! two draws per step) have per-path costs that differ by large constant
//! factors a single `L(N)` line cannot express — the β it fits is a
//! mix-weighted average that over-predicts cheap families and
//! under-predicts expensive ones. Fitting one line per family (with the
//! pooled line as fallback for families the benchmark never sampled)
//! recovers the Fig. 2 error levels on heterogeneous workloads.

use crate::util::stats::{self, LinearFit};
use crate::workload::option::Payoff;

/// `L(N) = beta*N + gamma`, latencies in seconds, N in simulations.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LatencyModel {
    pub beta: f64,
    pub gamma: f64,
    /// R² of the fit on the benchmark data (1.0 for exact models).
    pub r_squared: f64,
}

impl LatencyModel {
    pub fn new(beta: f64, gamma: f64) -> LatencyModel {
        assert!(beta > 0.0 && beta.is_finite(), "beta must be positive: {beta}");
        assert!(gamma >= 0.0 && gamma.is_finite(), "gamma must be non-negative: {gamma}");
        LatencyModel { beta, gamma, r_squared: 1.0 }
    }

    /// Predicted latency for `n` simulations (n = 0 ⇒ no work ⇒ 0, not γ).
    pub fn predict(&self, n: u64) -> f64 {
        if n == 0 {
            0.0
        } else {
            self.beta * n as f64 + self.gamma
        }
    }

    /// Largest `n` whose predicted latency fits within `budget_secs`
    /// (0 if even the setup time doesn't fit).
    pub fn max_n_within(&self, budget_secs: f64) -> u64 {
        if budget_secs <= self.gamma {
            return 0;
        }
        ((budget_secs - self.gamma) / self.beta).floor() as u64
    }

    /// Fit from benchmark samples `(n, latency_secs)` using WLS with 1/L²
    /// (relative-error) weights. Returns `None` for degenerate inputs.
    /// Negative fitted coefficients are clamped to tiny positive values —
    /// they arise only from noise on near-degenerate sample sets.
    pub fn fit(samples: &[(u64, f64)]) -> Option<LatencyModel> {
        if samples.len() < 2 {
            return None;
        }
        let xs: Vec<f64> = samples.iter().map(|(n, _)| *n as f64).collect();
        let ys: Vec<f64> = samples.iter().map(|(_, l)| *l).collect();
        if ys.iter().any(|l| *l <= 0.0) {
            return None;
        }
        let ws: Vec<f64> = ys.iter().map(|l| 1.0 / (l * l)).collect();
        let LinearFit { slope, intercept, r_squared } =
            stats::weighted_least_squares(&xs, &ys, &ws)?;
        Some(LatencyModel {
            beta: slope.max(1e-15),
            gamma: intercept.max(0.0),
            r_squared,
        })
    }

    /// Relative prediction error vs an observed latency (Fig. 2 metric).
    pub fn relative_error(&self, n: u64, observed_secs: f64) -> f64 {
        stats::relative_error(self.predict(n), observed_secs)
    }
}

/// Per-payoff-family latency coefficients with a pooled fallback line.
///
/// Fitted from `(family, n, latency_secs)` benchmark samples: one WLS line
/// per family that has enough samples, plus the pooled single line over
/// everything. [`model`](Self::model) answers with the family line when one
/// exists and the pooled line otherwise, so callers never lose coverage by
/// switching to the per-family fit.
#[derive(Debug, Clone)]
pub struct FamilyLatencyFit {
    per_family: [Option<LatencyModel>; Payoff::COUNT],
    pooled: Option<LatencyModel>,
}

impl FamilyLatencyFit {
    /// Fit from `(family, n, latency_secs)` samples. Returns `None` only
    /// when *no* line — pooled or per-family — is fittable.
    pub fn fit(samples: &[(Payoff, u64, f64)]) -> Option<FamilyLatencyFit> {
        let all: Vec<(u64, f64)> = samples.iter().map(|&(_, n, l)| (n, l)).collect();
        let pooled = LatencyModel::fit(&all);
        let mut per_family = [None; Payoff::COUNT];
        for family in Payoff::ALL {
            let fam: Vec<(u64, f64)> = samples
                .iter()
                .filter(|&&(p, _, _)| p == family)
                .map(|&(_, n, l)| (n, l))
                .collect();
            per_family[family.index()] = LatencyModel::fit(&fam);
        }
        if pooled.is_none() && per_family.iter().all(Option::is_none) {
            return None;
        }
        Some(FamilyLatencyFit { per_family, pooled })
    }

    /// The model for `family`: its own fitted line, else the pooled line.
    pub fn model(&self, family: Payoff) -> Option<&LatencyModel> {
        self.per_family[family.index()].as_ref().or(self.pooled.as_ref())
    }

    /// The pooled single-line fit over every sample (the pre-per-family
    /// behaviour; `None` when the pooled sample set was degenerate).
    pub fn pooled(&self) -> Option<&LatencyModel> {
        self.pooled.as_ref()
    }

    /// Mean relative prediction error over `samples` using the per-family
    /// models (the Fig. 2 metric, per-family edition). NaN-free: empty
    /// input or no usable model yields `f64::INFINITY`.
    pub fn mean_relative_error(&self, samples: &[(Payoff, u64, f64)]) -> f64 {
        mean_error(samples, |family| self.model(family))
    }

    /// Mean relative prediction error over `samples` under the pooled
    /// single line — the baseline the per-family fit is judged against.
    pub fn pooled_mean_relative_error(&self, samples: &[(Payoff, u64, f64)]) -> f64 {
        mean_error(samples, |_| self.pooled())
    }
}

fn mean_error<'a, F>(samples: &[(Payoff, u64, f64)], model: F) -> f64
where
    F: Fn(Payoff) -> Option<&'a LatencyModel>,
{
    let mut total = 0.0f64;
    let mut count = 0usize;
    for &(family, n, observed) in samples {
        match model(family) {
            Some(m) => {
                total += m.relative_error(n, observed);
                count += 1;
            }
            None => return f64::INFINITY,
        }
    }
    if count == 0 {
        f64::INFINITY
    } else {
        total / count as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn predict_is_linear_with_setup() {
        let m = LatencyModel::new(1e-6, 2.0);
        assert!((m.predict(1_000_000) - 3.0).abs() < 1e-12);
        assert_eq!(m.predict(0), 0.0);
    }

    #[test]
    fn fit_recovers_exact_model() {
        let truth = LatencyModel::new(5e-7, 1.5);
        let samples: Vec<(u64, f64)> =
            (1..20).map(|i| (i * 100_000, truth.predict(i * 100_000))).collect();
        let fit = LatencyModel::fit(&samples).unwrap();
        assert!((fit.beta - truth.beta).abs() / truth.beta < 1e-9);
        assert!((fit.gamma - truth.gamma).abs() < 1e-6);
        assert!(fit.r_squared > 0.999999);
    }

    #[test]
    fn fit_under_noise_extrapolates_within_10pct() {
        // The paper's Fig. 2 claim: <=10% error at many times the benchmark
        // size. Benchmark at n <= 1e6, predict at 3e7 (30x extrapolation).
        let truth = LatencyModel::new(2e-6, 5.0);
        let mut rng = Rng::new(17);
        let samples: Vec<(u64, f64)> = (1..=30)
            .map(|i| {
                let n = i * 33_000;
                (n, truth.predict(n) * rng.lognormal_noise(0.05))
            })
            .collect();
        let fit = LatencyModel::fit(&samples).unwrap();
        let err = fit.relative_error(30_000_000, truth.predict(30_000_000));
        assert!(err < 0.10, "extrapolation error {err}");
    }

    #[test]
    fn fit_rejects_degenerate() {
        assert!(LatencyModel::fit(&[]).is_none());
        assert!(LatencyModel::fit(&[(10, 1.0)]).is_none());
        assert!(LatencyModel::fit(&[(10, 1.0), (10, 1.1)]).is_none()); // same n
        assert!(LatencyModel::fit(&[(10, 0.0), (20, 1.0)]).is_none()); // zero latency
    }

    #[test]
    fn max_n_within_budget() {
        let m = LatencyModel::new(1e-3, 2.0);
        assert_eq!(m.max_n_within(1.0), 0); // can't even set up
        assert_eq!(m.max_n_within(3.0), 1000);
        assert_eq!(m.max_n_within(2.0005), 0);
    }

    #[test]
    #[should_panic(expected = "beta")]
    fn zero_beta_rejected() {
        LatencyModel::new(0.0, 1.0);
    }

    /// Synthetic two-family cluster where basket paths cost 4x barrier
    /// paths (same setup): the deterministic ground truth the per-family
    /// fit must recover and the single line must not.
    fn mixed_family_samples() -> Vec<(Payoff, u64, f64)> {
        let barrier = LatencyModel::new(1e-6, 1.0);
        let basket = LatencyModel::new(4e-6, 1.0);
        let mut samples = Vec::new();
        for i in 1..=12u64 {
            let n = i * 50_000;
            samples.push((Payoff::Barrier, n, barrier.predict(n)));
            samples.push((Payoff::Basket, n, basket.predict(n)));
        }
        samples
    }

    #[test]
    fn per_family_fit_beats_the_single_line_on_heterogeneous_cost() {
        let samples = mixed_family_samples();
        let fit = FamilyLatencyFit::fit(&samples).unwrap();
        // Each family's line recovers its true beta almost exactly...
        let barrier = fit.model(Payoff::Barrier).unwrap();
        let basket = fit.model(Payoff::Basket).unwrap();
        assert!((barrier.beta - 1e-6).abs() / 1e-6 < 1e-6, "barrier beta {}", barrier.beta);
        assert!((basket.beta - 4e-6).abs() / 4e-6 < 1e-6, "basket beta {}", basket.beta);
        // ...while the pooled line is forced between them.
        let pooled = fit.pooled().unwrap();
        assert!(pooled.beta > 1.2e-6 && pooled.beta < 3.8e-6, "pooled beta {}", pooled.beta);
        // The headline claim: per-family mean relative error is far below
        // the single-line fit's on the same noiseless samples.
        let per_family_err = fit.mean_relative_error(&samples);
        let pooled_err = fit.pooled_mean_relative_error(&samples);
        assert!(per_family_err < 1e-6, "per-family error {per_family_err}");
        assert!(pooled_err > 0.20, "pooled error {pooled_err}");
        assert!(per_family_err < pooled_err / 100.0);
    }

    #[test]
    fn unsampled_families_fall_back_to_the_pooled_line() {
        let samples = mixed_family_samples();
        let fit = FamilyLatencyFit::fit(&samples).unwrap();
        let heston = fit.model(Payoff::Heston).unwrap();
        let pooled = fit.pooled().unwrap();
        assert_eq!(heston.beta, pooled.beta);
        assert_eq!(heston.gamma, pooled.gamma);
    }

    #[test]
    fn family_fit_rejects_fully_degenerate_input() {
        assert!(FamilyLatencyFit::fit(&[]).is_none());
        assert!(FamilyLatencyFit::fit(&[(Payoff::European, 10, 1.0)]).is_none());
        // One fittable family is enough, and it also feeds the pooled line.
        let ok = FamilyLatencyFit::fit(&[
            (Payoff::European, 10, 1.0),
            (Payoff::European, 20, 1.5),
        ])
        .unwrap();
        assert!(ok.model(Payoff::European).is_some());
        assert!(ok.model(Payoff::Heston).is_some()); // via pooled fallback
    }
}
