//! The typed metrics registry: counters, gauges and fixed-bucket
//! histograms, keyed by `(name, label)`.
//!
//! Layout follows the "lock-striped map of atomic cells" pattern: the
//! registry holds a small fixed number of shards, each a mutex over a
//! `BTreeMap` from key to metric cell. The mutex is only taken to *resolve*
//! a cell (first use per key, or a snapshot); every update after that is a
//! relaxed atomic on the cell itself, so hot paths — per-chunk events, per
//! serve request — never serialise against each other beyond one cache
//! line. Callers that own a key for its lifetime (e.g. the session's
//! solution cache) resolve the `Arc` handle once and skip the map entirely.
//!
//! Labels are a single pre-formatted string (`platform=cpu-sim`,
//! `strategy=milp`, `op=evaluate`); `docs/OBSERVABILITY.md` catalogues the
//! names and label schemes in use.

use std::collections::hash_map::DefaultHasher;
use std::collections::BTreeMap;
use std::hash::{Hash, Hasher};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::obs::histogram::{default_bounds, Histogram};
use crate::util::json::{obj, Json};

const SHARDS: usize = 8;

/// Bucket count for registries built without an `[obs]` config.
pub const DEFAULT_HIST_BUCKETS: usize = 24;

/// A monotonically increasing u64. Counting is unconditional — views like
/// the session's cache stats depend on it even when telemetry is disabled;
/// the registry's `enabled` flag gates only the name-addressed record
/// helpers.
#[derive(Default)]
pub struct Counter {
    value: AtomicU64,
}

impl Counter {
    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, v: u64) {
        self.value.fetch_add(v, Ordering::Relaxed);
    }

    pub fn value(&self) -> u64 {
        self.value.load(Ordering::Relaxed)
    }
}

/// A last-write-wins f64 cell; `value()` is `None` until first set.
#[derive(Default)]
pub struct Gauge {
    bits: AtomicU64,
    set: AtomicBool,
}

impl Gauge {
    pub fn set(&self, v: f64) {
        self.bits.store(v.to_bits(), Ordering::Relaxed);
        self.set.store(true, Ordering::Release);
    }

    pub fn value(&self) -> Option<f64> {
        if self.set.load(Ordering::Acquire) {
            Some(f64::from_bits(self.bits.load(Ordering::Relaxed)))
        } else {
            None
        }
    }
}

enum Cell {
    Counter(Arc<Counter>),
    Gauge(Arc<Gauge>),
    Histogram(Arc<Histogram>),
}

impl Cell {
    fn kind(&self) -> &'static str {
        match self {
            Cell::Counter(_) => "counter",
            Cell::Gauge(_) => "gauge",
            Cell::Histogram(_) => "histogram",
        }
    }

    fn value_json(&self) -> Json {
        match self {
            Cell::Counter(c) => Json::Num(c.value() as f64),
            Cell::Gauge(g) => g.value().map(Json::Num).unwrap_or(Json::Null),
            Cell::Histogram(h) => h.to_json(),
        }
    }
}

type Shard = Mutex<BTreeMap<(String, String), Cell>>;

/// See the module docs. One registry is process-global ([`super::global`]);
/// each [`TradeoffSession`](crate::api::TradeoffSession) additionally owns
/// a private one so concurrent sessions never mix their counts.
pub struct MetricsRegistry {
    enabled: AtomicBool,
    bounds: Arc<Vec<f64>>,
    shards: Vec<Shard>,
}

impl Default for MetricsRegistry {
    fn default() -> Self {
        MetricsRegistry::new(true, default_bounds(DEFAULT_HIST_BUCKETS))
    }
}

impl MetricsRegistry {
    /// A registry whose histograms all share the `bounds` ladder. `enabled`
    /// gates the name-addressed record helpers ([`inc`](Self::inc),
    /// [`observe`](Self::observe), [`set_gauge`](Self::set_gauge)); handle
    /// reads and snapshots work regardless.
    pub fn new(enabled: bool, bounds: Vec<f64>) -> MetricsRegistry {
        assert!(!bounds.is_empty(), "registry needs at least one histogram bound");
        MetricsRegistry {
            enabled: AtomicBool::new(enabled),
            bounds: Arc::new(bounds),
            shards: (0..SHARDS).map(|_| Mutex::new(BTreeMap::new())).collect(),
        }
    }

    pub fn enabled(&self) -> bool {
        self.enabled.load(Ordering::Relaxed)
    }

    pub fn set_enabled(&self, enabled: bool) {
        self.enabled.store(enabled, Ordering::Relaxed);
    }

    fn shard(&self, name: &str, label: &str) -> &Shard {
        let mut h = DefaultHasher::new();
        name.hash(&mut h);
        label.hash(&mut h);
        &self.shards[(h.finish() as usize) % SHARDS]
    }

    /// Resolve (or create) the counter cell for `(name, label)`. Panics if
    /// the key is already registered as a different metric type — metric
    /// names are static program text, so a clash is a programming error.
    pub fn counter(&self, name: &str, label: &str) -> Arc<Counter> {
        let mut g = self.shard(name, label).lock().unwrap();
        let cell = g
            .entry((name.to_string(), label.to_string()))
            .or_insert_with(|| Cell::Counter(Arc::new(Counter::default())));
        match cell {
            Cell::Counter(c) => c.clone(),
            other => panic!("metric '{name}' is a {}, not a counter", other.kind()),
        }
    }

    pub fn gauge(&self, name: &str, label: &str) -> Arc<Gauge> {
        let mut g = self.shard(name, label).lock().unwrap();
        let cell = g
            .entry((name.to_string(), label.to_string()))
            .or_insert_with(|| Cell::Gauge(Arc::new(Gauge::default())));
        match cell {
            Cell::Gauge(c) => c.clone(),
            other => panic!("metric '{name}' is a {}, not a gauge", other.kind()),
        }
    }

    pub fn histogram(&self, name: &str, label: &str) -> Arc<Histogram> {
        let mut g = self.shard(name, label).lock().unwrap();
        let bounds = self.bounds.clone();
        let cell = g
            .entry((name.to_string(), label.to_string()))
            .or_insert_with(|| Cell::Histogram(Arc::new(Histogram::new(bounds))));
        match cell {
            Cell::Histogram(c) => c.clone(),
            other => panic!("metric '{name}' is a {}, not a histogram", other.kind()),
        }
    }

    // -- name-addressed record helpers (no-ops when disabled) ---------------

    pub fn inc(&self, name: &str, label: &str, v: u64) {
        if self.enabled() {
            self.counter(name, label).add(v);
        }
    }

    pub fn set_gauge(&self, name: &str, label: &str, v: f64) {
        if self.enabled() {
            self.gauge(name, label).set(v);
        }
    }

    pub fn observe(&self, name: &str, label: &str, v: f64) {
        if self.enabled() {
            self.histogram(name, label).observe(v);
        }
    }

    // -- reads --------------------------------------------------------------

    /// Current value of a counter; 0 when the key was never registered.
    pub fn counter_value(&self, name: &str, label: &str) -> u64 {
        let g = self.shard(name, label).lock().unwrap();
        match g.get(&(name.to_string(), label.to_string())) {
            Some(Cell::Counter(c)) => c.value(),
            _ => 0,
        }
    }

    /// Current value of a gauge; `None` when never registered or never set.
    pub fn gauge_value(&self, name: &str, label: &str) -> Option<f64> {
        let g = self.shard(name, label).lock().unwrap();
        match g.get(&(name.to_string(), label.to_string())) {
            Some(Cell::Gauge(c)) => c.value(),
            _ => None,
        }
    }

    /// Serialise every metric (optionally only names containing `filter`)
    /// as `{name: {type, values: {label: value}}}` — deterministic order
    /// via `BTreeMap`, numbers guarded finite by the cells themselves.
    pub fn snapshot(&self, filter: Option<&str>) -> Json {
        let mut out: BTreeMap<String, Json> = BTreeMap::new();
        self.snapshot_into(&mut out, filter);
        Json::Obj(out)
    }

    /// As [`snapshot`](Self::snapshot), merging into `out` (the session
    /// snapshot overlays the process-global one this way).
    pub fn snapshot_into(&self, out: &mut BTreeMap<String, Json>, filter: Option<&str>) {
        // Group shard entries by name first so each name serialises with a
        // complete label map even though labels stripe across shards.
        let mut grouped: BTreeMap<String, (&'static str, BTreeMap<String, Json>)> =
            BTreeMap::new();
        for shard in &self.shards {
            let g = shard.lock().unwrap();
            for ((name, label), cell) in g.iter() {
                if let Some(f) = filter {
                    if !name.contains(f) {
                        continue;
                    }
                }
                let entry = grouped
                    .entry(name.clone())
                    .or_insert_with(|| (cell.kind(), BTreeMap::new()));
                entry.1.insert(label.clone(), cell.value_json());
            }
        }
        for (name, (kind, values)) in grouped {
            out.insert(
                name,
                obj(vec![("type", kind.into()), ("values", Json::Obj(values))]),
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_gauges_histograms_record_and_snapshot() {
        let r = MetricsRegistry::default();
        r.inc("requests_total", "op=ping", 2);
        r.inc("requests_total", "op=evaluate", 1);
        r.set_gauge("depth", "", 3.5);
        r.observe("latency_secs", "op=ping", 0.25);
        let snap = r.snapshot(None);
        let reqs = snap.get("requests_total").unwrap();
        assert_eq!(reqs.get("type").unwrap().as_str(), Some("counter"));
        assert_eq!(
            reqs.get("values").unwrap().get("op=ping").unwrap().as_u64(),
            Some(2)
        );
        assert_eq!(
            reqs.get("values").unwrap().get("op=evaluate").unwrap().as_u64(),
            Some(1)
        );
        assert_eq!(snap.get("depth").unwrap().get("type").unwrap().as_str(), Some("gauge"));
        let hist = snap.get("latency_secs").unwrap().get("values").unwrap();
        assert_eq!(hist.get("op=ping").unwrap().get("count").unwrap().as_u64(), Some(1));
    }

    #[test]
    fn disabled_registry_records_nothing_by_name() {
        let r = MetricsRegistry::new(false, vec![1.0]);
        r.inc("c", "", 5);
        r.observe("h", "", 1.0);
        r.set_gauge("g", "", 1.0);
        assert_eq!(r.snapshot(None), Json::Obj(BTreeMap::new()));
        assert_eq!(r.counter_value("c", ""), 0);
        // Handle-addressed counters keep working (cache stats path).
        let c = r.counter("always", "");
        c.inc();
        assert_eq!(r.counter_value("always", ""), 1);
    }

    #[test]
    fn filter_selects_by_name_substring() {
        let r = MetricsRegistry::default();
        r.inc("exec_retries_total", "", 1);
        r.inc("serve_requests_total", "op=ping", 1);
        let snap = r.snapshot(Some("exec_"));
        assert!(snap.get("exec_retries_total").is_some());
        assert!(snap.get("serve_requests_total").is_none());
    }

    #[test]
    fn handles_are_shared_across_lookups_and_threads() {
        let r = Arc::new(MetricsRegistry::default());
        let c = r.counter("n", "x=1");
        std::thread::scope(|s| {
            for _ in 0..4 {
                let r = r.clone();
                s.spawn(move || {
                    for _ in 0..1000 {
                        r.counter("n", "x=1").inc();
                    }
                });
            }
        });
        assert_eq!(c.value(), 4000);
        assert_eq!(r.counter_value("n", "x=1"), 4000);
    }

    #[test]
    #[should_panic(expected = "not a gauge")]
    fn type_clash_panics() {
        let r = MetricsRegistry::default();
        r.counter("dual", "");
        r.gauge("dual", "");
    }

    #[test]
    fn snapshot_serialises_through_util_json() {
        let r = MetricsRegistry::default();
        r.observe("h", "platform=a", 3.0);
        r.inc("c", "", u64::MAX / 2);
        let text = r.snapshot(None).to_string_pretty();
        let back = crate::util::json::Json::parse(&text).expect("valid json");
        assert!(back.get("h").is_some());
    }
}
