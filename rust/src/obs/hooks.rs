//! Profiling hooks at the seams the engine already has.
//!
//! Two pieces live here:
//!
//! - [`ExecCounters`] — the single tally of one chunked run's
//!   retry/migration/preemption/failure counts. The executor's event loop
//!   increments it, the final [`ExecutionReport`] reads it, and the
//!   session's run tracker holds the same `Arc` so live `status` queries
//!   and the finished report can never disagree (previously each re-counted
//!   independently from the event stream).
//! - [`record_exec_event`] — the [`ExecEvent`] → registry bridge. Session
//!   entry points tee their event observers through it, so chunk latency
//!   per platform, queue depth, predicted-vs-measured latency error,
//!   retries/migrations/preemptions and task pricing all land in the
//!   session's [`MetricsRegistry`] without the executor knowing telemetry
//!   exists.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::coordinator::objectives::ModelSet;
use crate::coordinator::ExecEvent;
use crate::obs::registry::MetricsRegistry;

/// Atomic per-run execution counters; see the module docs.
#[derive(Debug, Default)]
pub struct ExecCounters {
    chunks: AtomicU64,
    retries: AtomicU64,
    migrations: AtomicU64,
    preemptions: AtomicU64,
    failures: AtomicU64,
}

impl ExecCounters {
    pub fn add_chunk(&self) {
        self.chunks.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add_retry(&self) {
        self.retries.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add_migration(&self) {
        self.migrations.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add_preemption(&self) {
        self.preemptions.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add_failure(&self) {
        self.failures.fetch_add(1, Ordering::Relaxed);
    }

    pub fn chunks(&self) -> usize {
        self.chunks.load(Ordering::Relaxed) as usize
    }

    pub fn retries(&self) -> usize {
        self.retries.load(Ordering::Relaxed) as usize
    }

    pub fn migrations(&self) -> usize {
        self.migrations.load(Ordering::Relaxed) as usize
    }

    pub fn preemptions(&self) -> usize {
        self.preemptions.load(Ordering::Relaxed) as usize
    }

    pub fn failures(&self) -> usize {
        self.failures.load(Ordering::Relaxed) as usize
    }
}

/// `platform=<name>` when the model set knows the platform, `platform=<i>`
/// otherwise — kept consistent with the `specs` op ordering.
fn platform_label(models: Option<&ModelSet>, i: usize) -> String {
    match models.and_then(|m| m.platform_names.get(i)) {
        Some(name) => format!("platform={name}"),
        None => format!("platform={i}"),
    }
}

/// Fold one executor event into `reg`. Purely additive: it never touches
/// the event, so instrumented runs stay bit-identical to uninstrumented
/// ones. No-op when the registry is disabled.
pub fn record_exec_event(reg: &MetricsRegistry, models: Option<&ModelSet>, ev: &ExecEvent) {
    if !reg.enabled() {
        return;
    }
    match ev {
        ExecEvent::Started { chunks, .. } => {
            reg.inc("exec_runs_total", "", 1);
            reg.set_gauge("exec_chunks_outstanding", "", *chunks as f64);
        }
        ExecEvent::ChunkDone { platform, task, n, latency_secs, cold, done, total, .. } => {
            reg.observe(
                "exec_chunk_latency_secs",
                &platform_label(models, *platform),
                *latency_secs,
            );
            reg.set_gauge("exec_chunks_outstanding", "", (*total - *done) as f64);
            if let Some(m) = models {
                // The predicted-vs-measured loop as a first-class
                // histogram: relative error of the fitted latency model on
                // this (platform, task) chunk.
                let lm = m.model(*platform, *task);
                let predicted =
                    lm.beta * *n as f64 + if *cold { lm.gamma } else { 0.0 };
                if *latency_secs > 0.0 {
                    reg.observe(
                        "exec_model_error_rel",
                        &format!(
                            "{},task={task}",
                            platform_label(models, *platform)
                        ),
                        (predicted - latency_secs).abs() / latency_secs,
                    );
                }
            }
        }
        ExecEvent::ChunkFailed { will_retry, .. } => {
            if *will_retry {
                reg.inc("exec_retries_total", "", 1);
            } else {
                reg.inc("exec_failures_total", "", 1);
            }
        }
        ExecEvent::ChunkMigrated { .. } => {
            reg.inc("exec_migrations_total", "", 1);
        }
        ExecEvent::LanePreempted { .. } => {
            reg.inc("exec_preemptions_total", "", 1);
        }
        ExecEvent::TaskPriced { .. } => {
            reg.inc("exec_tasks_priced_total", "", 1);
        }
        ExecEvent::Finished { makespan_secs, .. } => {
            reg.observe("exec_makespan_secs", "", *makespan_secs);
            reg.set_gauge("exec_chunks_outstanding", "", 0.0);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate_atomically() {
        let c = ExecCounters::default();
        std::thread::scope(|s| {
            for _ in 0..4 {
                s.spawn(|| {
                    for _ in 0..100 {
                        c.add_chunk();
                        c.add_retry();
                    }
                });
            }
        });
        assert_eq!(c.chunks(), 400);
        assert_eq!(c.retries(), 400);
        assert_eq!(c.failures(), 0);
    }

    #[test]
    fn events_land_in_the_registry() {
        let reg = MetricsRegistry::default();
        record_exec_event(&reg, None, &ExecEvent::Started { chunks: 4, tasks: 2 });
        record_exec_event(
            &reg,
            None,
            &ExecEvent::ChunkDone {
                platform: 1,
                task: 0,
                offset: 0,
                n: 100,
                latency_secs: 0.5,
                cold: true,
                done: 1,
                total: 4,
            },
        );
        record_exec_event(
            &reg,
            None,
            &ExecEvent::ChunkFailed {
                platform: 0,
                task: 0,
                offset: 0,
                n: 10,
                attempt: 1,
                error: "boom".into(),
                will_retry: true,
                rehomed_to: None,
            },
        );
        record_exec_event(
            &reg,
            None,
            &ExecEvent::Finished { makespan_secs: 1.0, cost: 2.0, failures: 0 },
        );
        assert_eq!(reg.counter_value("exec_runs_total", ""), 1);
        assert_eq!(reg.counter_value("exec_retries_total", ""), 1);
        let snap = reg.snapshot(Some("exec_chunk_latency_secs"));
        let values = snap.get("exec_chunk_latency_secs").unwrap().get("values").unwrap();
        assert_eq!(
            values.get("platform=1").unwrap().get("count").unwrap().as_u64(),
            Some(1)
        );
        assert_eq!(reg.gauge_value("exec_chunks_outstanding", ""), Some(0.0));
    }
}
