//! Lightweight tracing spans with Chrome-trace export.
//!
//! A [`Span`] is an RAII guard created by the [`span!`](crate::span) macro:
//! entering records the start time and pushes the span onto a per-thread
//! parent stack (so nested spans carry parent ids); dropping computes the
//! duration and appends one completed-span event to the thread's ring
//! buffer. Rings are bounded (oldest events evicted), registered globally
//! on first use per thread, and drained by [`chrome_trace`] into the Chrome
//! `about://tracing` / Perfetto JSON object format — one `"ph": "X"`
//! complete event per span, microsecond timestamps relative to the first
//! span of the process.
//!
//! Tracing is process-global and cheap: a disabled check is one relaxed
//! atomic load, and span frequency in this codebase is per solve / per
//! epoch / per request, never per chunk.

use std::cell::RefCell;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::util::json::{obj, Json};

static ENABLED: AtomicBool = AtomicBool::new(true);
static RING_CAP: AtomicUsize = AtomicUsize::new(4096);
static NEXT_SPAN_ID: AtomicU64 = AtomicU64::new(1);
static NEXT_TID: AtomicU64 = AtomicU64::new(1);
/// All per-thread rings ever registered (threads may exit; their events
/// remain exportable).
static RINGS: Mutex<Vec<Arc<Ring>>> = Mutex::new(Vec::new());
/// Lazily pinned process epoch all timestamps are relative to.
static EPOCH: Mutex<Option<Instant>> = Mutex::new(None);

pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

/// Cap each thread's ring at `cap` completed spans (oldest evicted).
pub fn set_ring_capacity(cap: usize) {
    RING_CAP.store(cap.max(1), Ordering::Relaxed);
}

fn now_us() -> u64 {
    let mut g = EPOCH.lock().unwrap();
    let t0 = g.get_or_insert_with(Instant::now);
    t0.elapsed().as_micros() as u64
}

#[derive(Clone)]
struct SpanEvent {
    name: &'static str,
    arg: Option<String>,
    id: u64,
    parent: Option<u64>,
    tid: u64,
    start_us: u64,
    dur_us: u64,
}

struct Ring {
    events: Mutex<VecDeque<SpanEvent>>,
}

struct ThreadCtx {
    tid: u64,
    ring: Arc<Ring>,
    /// Open span ids, innermost last — the parent chain.
    stack: Vec<u64>,
}

thread_local! {
    static LOCAL: RefCell<Option<ThreadCtx>> = const { RefCell::new(None) };
}

fn with_ctx<T>(f: impl FnOnce(&mut ThreadCtx) -> T) -> T {
    LOCAL.with(|cell| {
        let mut slot = cell.borrow_mut();
        let ctx = slot.get_or_insert_with(|| {
            let ring = Arc::new(Ring { events: Mutex::new(VecDeque::new()) });
            RINGS.lock().unwrap().push(ring.clone());
            ThreadCtx { tid: NEXT_TID.fetch_add(1, Ordering::Relaxed), ring, stack: Vec::new() }
        });
        f(ctx)
    })
}

/// An open span; dropping it records the completed event. Created through
/// the [`span!`](crate::span) macro.
pub struct Span(Option<ActiveSpan>);

struct ActiveSpan {
    name: &'static str,
    arg: Option<String>,
    id: u64,
    parent: Option<u64>,
    tid: u64,
    start_us: u64,
}

impl Span {
    /// A no-op span (tracing disabled).
    pub fn disabled() -> Span {
        Span(None)
    }

    pub fn enter(name: &'static str, arg: Option<String>) -> Span {
        if !enabled() {
            return Span(None);
        }
        let id = NEXT_SPAN_ID.fetch_add(1, Ordering::Relaxed);
        let start_us = now_us();
        let (tid, parent) = with_ctx(|ctx| {
            let parent = ctx.stack.last().copied();
            ctx.stack.push(id);
            (ctx.tid, parent)
        });
        Span(Some(ActiveSpan { name, arg, id, parent, tid, start_us }))
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        let Some(active) = self.0.take() else { return };
        let dur_us = now_us().saturating_sub(active.start_us);
        with_ctx(|ctx| {
            // Spans are scope-bound, so the innermost open span closes
            // first; tolerate a mismatch anyway (a span moved across a
            // thread boundary) rather than corrupt the stack.
            if ctx.stack.last() == Some(&active.id) {
                ctx.stack.pop();
            } else if let Some(pos) = ctx.stack.iter().rposition(|&id| id == active.id) {
                ctx.stack.remove(pos);
            }
            let mut events = ctx.ring.events.lock().unwrap();
            let cap = RING_CAP.load(Ordering::Relaxed);
            while events.len() >= cap {
                events.pop_front();
            }
            events.push_back(SpanEvent {
                name: active.name,
                arg: active.arg,
                id: active.id,
                parent: active.parent,
                tid: active.tid,
                start_us: active.start_us,
                dur_us,
            });
        });
    }
}

/// Drop every buffered span (the CLI clears before a traced run so the
/// export covers exactly that run).
pub fn clear() {
    for ring in RINGS.lock().unwrap().iter() {
        ring.events.lock().unwrap().clear();
    }
}

/// Export everything buffered as a Chrome-trace JSON object
/// (`{"traceEvents": [...]}`, loadable in `about://tracing` / Perfetto).
pub fn chrome_trace() -> Json {
    let mut all: Vec<SpanEvent> = Vec::new();
    for ring in RINGS.lock().unwrap().iter() {
        all.extend(ring.events.lock().unwrap().iter().cloned());
    }
    all.sort_by_key(|e| (e.start_us, e.id));
    let events: Vec<Json> = all
        .into_iter()
        .map(|e| {
            let mut args = vec![("id", Json::Num(e.id as f64))];
            if let Some(p) = e.parent {
                args.push(("parent", Json::Num(p as f64)));
            }
            if let Some(a) = e.arg {
                args.push(("arg", Json::Str(a)));
            }
            obj(vec![
                ("name", e.name.into()),
                ("cat", "cloudshapes".into()),
                ("ph", "X".into()),
                ("ts", Json::Num(e.start_us as f64)),
                ("dur", Json::Num(e.dur_us as f64)),
                ("pid", Json::Num(1.0)),
                ("tid", Json::Num(e.tid as f64)),
                ("args", obj(args)),
            ])
        })
        .collect();
    obj(vec![("traceEvents", Json::Arr(events))])
}

/// Serialises tests that mutate process-global trace state (the enabled
/// flag or ring contents, via [`set_enabled`]/[`clear`]) — without it a
/// concurrent test's spans could be torn down mid-assertion.
#[cfg(test)]
pub(crate) fn test_guard() -> std::sync::MutexGuard<'static, ()> {
    static TEST_LOCK: Mutex<()> = Mutex::new(());
    TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;

    // Trace state is process-global; these tests run in one process with
    // the rest of the suite, so they assert on their OWN spans (found by
    // name) rather than on global emptiness — and serialise against each
    // other (and the CLI `trace` test, which clears the rings) through
    // [`test_guard`] because they toggle the global enabled flag.

    #[test]
    fn spans_nest_with_parent_ids() {
        let _g = test_guard();
        set_enabled(true);
        let (outer_id, inner_id);
        {
            let outer = Span::enter("trace_test_outer", None);
            outer_id = outer.0.as_ref().unwrap().id;
            {
                let inner = Span::enter("trace_test_inner", Some("k=v".into()));
                inner_id = inner.0.as_ref().unwrap().id;
                assert_eq!(inner.0.as_ref().unwrap().parent, Some(outer_id));
            }
        }
        let trace = chrome_trace();
        let events = trace.get("traceEvents").unwrap().as_arr().unwrap();
        let inner = events
            .iter()
            .find(|e| {
                e.get("name").and_then(Json::as_str) == Some("trace_test_inner")
                    && e.get("args").and_then(|a| a.get("id")).and_then(Json::as_u64)
                        == Some(inner_id)
            })
            .expect("inner span exported");
        assert_eq!(
            inner.get("args").unwrap().get("parent").and_then(Json::as_u64),
            Some(outer_id)
        );
        assert_eq!(
            inner.get("args").unwrap().get("arg").and_then(Json::as_str),
            Some("k=v")
        );
        assert_eq!(inner.get("ph").and_then(Json::as_str), Some("X"));
    }

    #[test]
    fn disabled_spans_record_nothing() {
        let _g = test_guard();
        set_enabled(false);
        {
            let _s = Span::enter("trace_test_disabled", None);
        }
        set_enabled(true);
        let trace = chrome_trace();
        let events = trace.get("traceEvents").unwrap().as_arr().unwrap();
        assert!(!events
            .iter()
            .any(|e| e.get("name").and_then(Json::as_str) == Some("trace_test_disabled")));
    }

    #[test]
    fn export_is_valid_json() {
        let _g = test_guard();
        set_enabled(true);
        {
            let _s = Span::enter("trace_test_json", Some("quote \"q\"".into()));
        }
        let text = chrome_trace().to_string_pretty();
        assert!(Json::parse(&text).is_ok());
    }
}
