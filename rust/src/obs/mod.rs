//! The telemetry plane: metrics, tracing spans, and profiling hooks.
//!
//! Dependency-free observability for the whole solve → execute → schedule →
//! serve pipeline (see `docs/OBSERVABILITY.md` for the metric catalogue and
//! span taxonomy):
//!
//! - [`MetricsRegistry`] — typed counters, gauges and fixed-bucket
//!   histograms keyed by `(name, label)`, lock-striped with an atomic fast
//!   path, serialised through [`util::json`](crate::util::json). One
//!   registry is process-global ([`global`]) for code with no session in
//!   reach (the B&B solver); every
//!   [`TradeoffSession`](crate::api::TradeoffSession) owns a private one so
//!   concurrent sessions never mix counts. The serve `metrics` op and the
//!   `cloudshapes metrics` command snapshot both, merged.
//! - [`span!`](crate::span) / [`trace`] — RAII tracing spans with parent
//!   ids, ring-buffered per thread and exportable as a Chrome-trace JSON
//!   timeline (`cloudshapes trace --out trace.json`).
//! - [`hooks`] — the [`ExecEvent`](crate::coordinator::ExecEvent) → registry
//!   bridge and the shared per-run [`ExecCounters`] tally.
//!
//! Everything here is observational: hooks read values the engine already
//! computes and never alter control flow, so with `[obs] enabled = false`
//! (or `true`) instrumented paths produce bit-identical results.

pub mod histogram;
pub mod hooks;
pub mod registry;
pub mod trace;

use std::sync::{Arc, Mutex};

use crate::api::error::{CloudshapesError, Result};

pub use histogram::{default_bounds, Histogram};
pub use hooks::{record_exec_event, ExecCounters};
pub use registry::{Counter, Gauge, MetricsRegistry, DEFAULT_HIST_BUCKETS};
pub use trace::Span;

/// `[obs]` config table: session-scoped telemetry controls.
#[derive(Debug, Clone)]
pub struct ObsConfig {
    /// Master switch for the session registry and span tracing. Off, every
    /// instrumented path still runs identically — it just records nothing.
    pub enabled: bool,
    /// Log-spaced histogram bucket count (bounds span 1e-6..1e6).
    pub hist_buckets: usize,
    /// Per-thread completed-span ring capacity.
    pub trace_ring: usize,
}

impl Default for ObsConfig {
    fn default() -> Self {
        ObsConfig { enabled: true, hist_buckets: DEFAULT_HIST_BUCKETS, trace_ring: 4096 }
    }
}

impl ObsConfig {
    pub fn validate(&self) -> Result<()> {
        if !(2..=512).contains(&self.hist_buckets) {
            return Err(CloudshapesError::config(format!(
                "[obs] hist_buckets must be in 2..=512, got {}",
                self.hist_buckets
            )));
        }
        if !(16..=1_048_576).contains(&self.trace_ring) {
            return Err(CloudshapesError::config(format!(
                "[obs] trace_ring must be in 16..=1048576, got {}",
                self.trace_ring
            )));
        }
        Ok(())
    }

    /// Build this config's session registry and apply the process-global
    /// knobs (trace enablement + ring capacity; last session built wins).
    pub fn build_registry(&self) -> Arc<MetricsRegistry> {
        trace::set_enabled(self.enabled);
        trace::set_ring_capacity(self.trace_ring);
        Arc::new(MetricsRegistry::new(self.enabled, default_bounds(self.hist_buckets)))
    }
}

static GLOBAL: Mutex<Option<Arc<MetricsRegistry>>> = Mutex::new(None);

/// The process-global registry — the home of metrics recorded where no
/// session is in reach (e.g. the B&B solver). Enabled by default.
pub fn global() -> Arc<MetricsRegistry> {
    let mut g = GLOBAL.lock().unwrap();
    g.get_or_insert_with(|| Arc::new(MetricsRegistry::default())).clone()
}

/// Open a tracing span: `span!("solve")` or `span!("solve", strategy)`.
/// Returns a [`Span`] guard; the span closes (and is buffered for export)
/// when the guard drops. The argument form stringifies its second operand
/// only when tracing is enabled.
#[macro_export]
macro_rules! span {
    ($name:expr) => {
        $crate::obs::trace::Span::enter($name, None)
    };
    ($name:expr, $arg:expr) => {
        if $crate::obs::trace::enabled() {
            $crate::obs::trace::Span::enter(
                $name,
                Some(::std::string::ToString::to_string(&$arg)),
            )
        } else {
            $crate::obs::trace::Span::disabled()
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_validates_ranges() {
        assert!(ObsConfig::default().validate().is_ok());
        assert!(ObsConfig { hist_buckets: 1, ..Default::default() }.validate().is_err());
        assert!(ObsConfig { hist_buckets: 513, ..Default::default() }.validate().is_err());
        assert!(ObsConfig { trace_ring: 4, ..Default::default() }.validate().is_err());
    }

    #[test]
    fn global_registry_is_shared() {
        let a = global();
        a.counter("obs_mod_test_total", "").add(2);
        assert_eq!(global().counter_value("obs_mod_test_total", ""), 2);
    }

    #[test]
    fn span_macro_compiles_in_both_forms() {
        let _a = crate::span!("obs_mod_test_span");
        let _b = crate::span!("obs_mod_test_span_arg", 42);
    }
}
