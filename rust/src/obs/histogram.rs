//! Fixed-bucket histograms with a lock-free observe path.
//!
//! A [`Histogram`] owns an immutable ladder of upper-bound buckets (shared
//! across every histogram of a registry) plus one atomic counter per bucket,
//! an overflow counter, a total count, and a CAS-maintained f64 sum. The
//! observe path is a binary search over the bounds and two relaxed atomic
//! adds — cheap enough to sit on the executor's per-chunk event path.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::util::json::{obj, Json};

/// Add `v` to an f64 stored as atomic bits (relaxed CAS loop).
fn f64_add(cell: &AtomicU64, v: f64) {
    let mut cur = cell.load(Ordering::Relaxed);
    loop {
        let next = f64::from_bits(cur) + v;
        match cell.compare_exchange_weak(
            cur,
            next.to_bits(),
            Ordering::Relaxed,
            Ordering::Relaxed,
        ) {
            Ok(_) => return,
            Err(c) => cur = c,
        }
    }
}

/// A fixed-bucket histogram: `bounds[k]` is the inclusive upper bound of
/// bucket `k`; one extra overflow bucket catches everything above the last
/// bound. Negative observations land in bucket 0; non-finite observations
/// are dropped (they would poison the sum and can never serialise).
pub struct Histogram {
    bounds: Arc<Vec<f64>>,
    counts: Vec<AtomicU64>,
    count: AtomicU64,
    sum: AtomicU64,
}

impl Histogram {
    pub fn new(bounds: Arc<Vec<f64>>) -> Histogram {
        assert!(!bounds.is_empty(), "histogram needs at least one bucket bound");
        let counts = (0..bounds.len() + 1).map(|_| AtomicU64::new(0)).collect();
        Histogram { bounds, counts, count: AtomicU64::new(0), sum: AtomicU64::new(0) }
    }

    pub fn observe(&self, v: f64) {
        if !v.is_finite() {
            return;
        }
        // First bucket whose upper bound admits v; everything beyond the
        // last bound goes to the overflow slot.
        let idx = self.bounds.partition_point(|b| *b < v);
        self.counts[idx].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        f64_add(&self.sum, v);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn sum(&self) -> f64 {
        f64::from_bits(self.sum.load(Ordering::Relaxed))
    }

    /// Serialise as `{count, sum, le: [bounds...], n: [counts...]}` where
    /// `n` has one more entry than `le` (the trailing overflow bucket).
    pub fn to_json(&self) -> Json {
        let le: Vec<Json> = self.bounds.iter().map(|b| Json::Num(*b)).collect();
        let n: Vec<Json> = self
            .counts
            .iter()
            .map(|c| Json::Num(c.load(Ordering::Relaxed) as f64))
            .collect();
        obj(vec![
            ("count", Json::Num(self.count() as f64)),
            ("sum", Json::Num(self.sum())),
            ("le", Json::Arr(le)),
            ("n", Json::Arr(n)),
        ])
    }
}

/// `n` log-spaced bucket bounds covering 1e-6 .. 1e6 (microseconds to ~11
/// days when observing seconds; also a serviceable ladder for dimensionless
/// ratios like relative model error).
pub fn default_bounds(n: usize) -> Vec<f64> {
    let n = n.max(2);
    let (lo, hi) = (1e-6f64, 1e6f64);
    (0..n)
        .map(|k| lo * (hi / lo).powf(k as f64 / (n - 1) as f64))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buckets_are_inclusive_upper_bounds() {
        let h = Histogram::new(Arc::new(vec![1.0, 10.0, 100.0]));
        h.observe(0.5); // bucket 0
        h.observe(1.0); // bucket 0 (inclusive)
        h.observe(5.0); // bucket 1
        h.observe(1000.0); // overflow
        h.observe(-3.0); // clamps to bucket 0
        assert_eq!(h.count(), 5);
        let counts: Vec<u64> =
            h.counts.iter().map(|c| c.load(Ordering::Relaxed)).collect();
        assert_eq!(counts, vec![3, 1, 0, 1]);
        assert!((h.sum() - 1003.5).abs() < 1e-9);
    }

    #[test]
    fn non_finite_observations_are_dropped() {
        let h = Histogram::new(Arc::new(vec![1.0]));
        h.observe(f64::NAN);
        h.observe(f64::INFINITY);
        h.observe(f64::NEG_INFINITY);
        assert_eq!(h.count(), 0);
        assert_eq!(h.sum(), 0.0);
    }

    #[test]
    fn default_bounds_are_log_spaced_and_sorted() {
        let b = default_bounds(24);
        assert_eq!(b.len(), 24);
        assert!((b[0] - 1e-6).abs() < 1e-18);
        assert!((b[23] - 1e6).abs() < 1e-3);
        assert!(b.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn json_shape_is_stable() {
        let h = Histogram::new(Arc::new(vec![1.0, 2.0]));
        h.observe(1.5);
        let j = h.to_json();
        assert_eq!(j.get("count").and_then(Json::as_u64), Some(1));
        assert_eq!(j.get("le").and_then(Json::as_arr).map(Vec::len), Some(2));
        assert_eq!(j.get("n").and_then(Json::as_arr).map(Vec::len), Some(3));
    }
}
