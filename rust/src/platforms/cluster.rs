//! Cluster assembly: a named collection of [`Platform`] trait objects the
//! coordinator partitions work across.

use std::sync::Arc;

use crate::workload::option::OptionTask;

use super::sim::{SimConfig, SimPlatform};
use super::spec::PlatformSpec;
use super::{ChunkCtx, ExecOutcome, Platform};

/// A heterogeneous cluster. Platforms are shared (`Arc`) so executor worker
/// threads can dispatch concurrently.
#[derive(Clone)]
pub struct Cluster {
    platforms: Vec<Arc<dyn Platform>>,
}

impl Cluster {
    pub fn new(platforms: Vec<Arc<dyn Platform>>) -> Cluster {
        assert!(!platforms.is_empty(), "empty cluster");
        let mut names: Vec<String> =
            platforms.iter().map(|p| p.spec().name.clone()).collect();
        names.sort();
        names.dedup();
        assert_eq!(names.len(), platforms.len(), "duplicate platform names");
        Cluster { platforms }
    }

    /// Build a fully simulated cluster from specs (the Table II testbed).
    pub fn simulated(specs: &[PlatformSpec], cfg: &SimConfig, seed: u64) -> Cluster {
        let platforms = specs
            .iter()
            .enumerate()
            .map(|(i, s)| {
                Arc::new(SimPlatform::new(s.clone(), cfg.clone(), seed.wrapping_add(i as u64)))
                    as Arc<dyn Platform>
            })
            .collect();
        Cluster::new(platforms)
    }

    /// Append a platform (e.g. the native PJRT one).
    pub fn push(&mut self, p: Arc<dyn Platform>) {
        assert!(
            self.platforms.iter().all(|q| q.spec().name != p.spec().name),
            "duplicate platform name {}",
            p.spec().name
        );
        self.platforms.push(p);
    }

    pub fn len(&self) -> usize {
        self.platforms.len()
    }

    pub fn is_empty(&self) -> bool {
        self.platforms.is_empty()
    }

    pub fn platform(&self, i: usize) -> &Arc<dyn Platform> {
        &self.platforms[i]
    }

    pub fn platforms(&self) -> &[Arc<dyn Platform>] {
        &self.platforms
    }

    pub fn specs(&self) -> Vec<PlatformSpec> {
        self.platforms.iter().map(|p| p.spec().clone()).collect()
    }

    /// Execute on platform `i` (convenience passthrough).
    pub fn execute(
        &self,
        i: usize,
        task: &OptionTask,
        n: u64,
        seed: u32,
        ctx: ChunkCtx,
    ) -> ExecOutcome {
        self.platforms[i].execute(task, n, seed, ctx)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platforms::spec::{paper_cluster, small_cluster};
    use crate::workload::{generate, GeneratorConfig};

    #[test]
    fn builds_paper_testbed() {
        let c = Cluster::simulated(&paper_cluster(), &SimConfig::exact(), 1);
        assert_eq!(c.len(), 16);
    }

    #[test]
    fn execute_passthrough_works() {
        let c = Cluster::simulated(&small_cluster(), &SimConfig::exact(), 1);
        let w = generate(&GeneratorConfig::small(1, 0.1, 2));
        let out = c.execute(0, &w.tasks[0], 10_000, 1, ChunkCtx::cold(0));
        assert!(out.error.is_none());
        assert!(out.latency_secs > 0.0);
    }

    #[test]
    #[should_panic(expected = "duplicate platform names")]
    fn duplicate_names_rejected() {
        let spec = small_cluster()[0].clone();
        let a = Arc::new(SimPlatform::new(spec.clone(), SimConfig::exact(), 1)) as Arc<dyn Platform>;
        let b = Arc::new(SimPlatform::new(spec, SimConfig::exact(), 2)) as Arc<dyn Platform>;
        Cluster::new(vec![a, b]);
    }

    #[test]
    #[should_panic(expected = "empty cluster")]
    fn empty_cluster_rejected() {
        Cluster::new(vec![]);
    }
}
