//! Cluster assembly: a named collection of [`Platform`] trait objects the
//! coordinator partitions work across.
//!
//! Clusters are *instantiations* of catalogue compositions: several
//! instances of one platform type are distinct platforms (instance-suffixed
//! names such as `stratix5-gsd8#3`), so names need not be unique and the
//! executor schedules one lane per instance.

use std::sync::Arc;

use crate::api::error::{CloudshapesError, Result};
use crate::workload::option::OptionTask;

use super::sim::{SimConfig, SimPlatform};
use super::spec::PlatformSpec;
use super::{ChunkCtx, ExecOutcome, Platform};

/// A heterogeneous cluster. Platforms are shared (`Arc`) so executor worker
/// threads can dispatch concurrently.
#[derive(Clone)]
pub struct Cluster {
    platforms: Vec<Arc<dyn Platform>>,
}

impl Cluster {
    /// Assemble a cluster, validating every platform's spec. Bad user
    /// config (empty cluster, invalid billing terms) is a typed error.
    pub fn new(platforms: Vec<Arc<dyn Platform>>) -> Result<Cluster> {
        if platforms.is_empty() {
            return Err(CloudshapesError::platform("empty cluster"));
        }
        for p in &platforms {
            p.spec().validate()?;
        }
        Ok(Cluster { platforms })
    }

    /// Build a fully simulated cluster from specs (e.g. a catalogue
    /// composition or the Table II testbed).
    pub fn simulated(specs: &[PlatformSpec], cfg: &SimConfig, seed: u64) -> Result<Cluster> {
        let platforms = specs
            .iter()
            .enumerate()
            .map(|(i, s)| {
                Arc::new(SimPlatform::new(s.clone(), cfg.clone(), seed.wrapping_add(i as u64)))
                    as Arc<dyn Platform>
            })
            .collect();
        Cluster::new(platforms)
    }

    /// Append a platform (e.g. the native PJRT one).
    pub fn push(&mut self, p: Arc<dyn Platform>) -> Result<()> {
        p.spec().validate()?;
        self.platforms.push(p);
        Ok(())
    }

    pub fn len(&self) -> usize {
        self.platforms.len()
    }

    pub fn is_empty(&self) -> bool {
        self.platforms.is_empty()
    }

    pub fn platform(&self, i: usize) -> &Arc<dyn Platform> {
        &self.platforms[i]
    }

    pub fn platforms(&self) -> &[Arc<dyn Platform>] {
        &self.platforms
    }

    pub fn specs(&self) -> Vec<PlatformSpec> {
        self.platforms.iter().map(|p| p.spec().clone()).collect()
    }

    /// The cluster's composition: (type name, instance count) pairs in
    /// first-appearance order — what reports and serve responses print.
    pub fn composition(&self) -> Vec<(String, usize)> {
        let mut out: Vec<(String, usize)> = Vec::new();
        for p in &self.platforms {
            let t = p.spec().type_name().to_string();
            match out.iter_mut().find(|(name, _)| *name == t) {
                Some((_, n)) => *n += 1,
                None => out.push((t, 1)),
            }
        }
        out
    }

    /// Execute on platform `i` (convenience passthrough).
    pub fn execute(
        &self,
        i: usize,
        task: &OptionTask,
        n: u64,
        seed: u32,
        ctx: ChunkCtx,
    ) -> ExecOutcome {
        self.platforms[i].execute(task, n, seed, ctx)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platforms::spec::{paper_cluster, small_cluster};
    use crate::workload::{generate, GeneratorConfig};

    #[test]
    fn builds_paper_testbed() {
        let c = Cluster::simulated(&paper_cluster(), &SimConfig::exact(), 1).unwrap();
        assert_eq!(c.len(), 16);
        assert_eq!(
            c.composition(),
            vec![
                ("virtex6".to_string(), 4),
                ("stratix5-gsd8".to_string(), 8),
                ("stratix5-gsd5".to_string(), 1),
                ("gk104".to_string(), 1),
                ("xeon-e5-2660".to_string(), 1),
                ("xeon-gce".to_string(), 1),
            ]
        );
    }

    #[test]
    fn execute_passthrough_works() {
        let c = Cluster::simulated(&small_cluster(), &SimConfig::exact(), 1).unwrap();
        let w = generate(&GeneratorConfig::small(1, 0.1, 2));
        let out = c.execute(0, &w.tasks[0], 10_000, 1, ChunkCtx::cold(0));
        assert!(out.error.is_none());
        assert!(out.latency_secs > 0.0);
    }

    #[test]
    fn duplicate_instances_of_a_type_are_allowed() {
        // Two instances of the same offer are two platforms — shape search
        // depends on renting several of a type.
        let spec = small_cluster()[0].clone();
        let a = Arc::new(SimPlatform::new(spec.clone(), SimConfig::exact(), 1)) as Arc<dyn Platform>;
        let b = Arc::new(SimPlatform::new(spec, SimConfig::exact(), 2)) as Arc<dyn Platform>;
        let c = Cluster::new(vec![a, b]).unwrap();
        assert_eq!(c.len(), 2);
        assert_eq!(c.composition(), vec![("virtex6".to_string(), 2)]);
    }

    #[test]
    fn empty_cluster_is_a_typed_error() {
        let e = Cluster::new(vec![]).unwrap_err();
        assert_eq!(e.kind(), "platform");
    }

    #[test]
    fn invalid_spec_is_rejected_at_assembly() {
        let mut spec = small_cluster()[0].clone();
        spec.quantum_secs = 0.0;
        let e = Cluster::simulated(&[spec], &SimConfig::exact(), 1).unwrap_err();
        assert_eq!(e.kind(), "config");
    }
}
