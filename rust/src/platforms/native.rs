//! Native platform: really executes the AOT HLO artifacts on the PJRT CPU
//! client and reports measured wall-clock latency. This is the platform that
//! proves the three-layer stack composes end-to-end (examples/end_to_end.rs).

use std::time::Instant;

use crate::runtime::EngineHandle;
use crate::workload::option::OptionTask;

use super::spec::{Category, PlatformSpec};
use super::{ChunkCtx, ExecOutcome, Platform};

/// A platform backed by the local PJRT CPU client (via the engine service
/// thread — the `xla` types themselves are not `Send`).
pub struct NativePlatform {
    spec: PlatformSpec,
    engine: EngineHandle,
}

impl NativePlatform {
    /// Wrap an engine handle. Billing terms default to the Azure CPU row of
    /// Table II (1-minute quantum) unless a spec is supplied.
    pub fn new(engine: EngineHandle) -> NativePlatform {
        NativePlatform {
            spec: PlatformSpec {
                name: "native-pjrt-cpu".to_string(),
                provider: Some("local"),
                device: "PJRT CPU (XLA)",
                standard: "JAX/Pallas AOT (HLO text)",
                category: Category::Cpu,
                resources: None,
                clock_ghz: 0.0, // unknown; irrelevant — latency is measured
                app_gflops: 0.0,
                rate_per_hour: 0.480,
                quantum_secs: 60.0,
                setup_secs: 0.1,
                preemptible: None,
            },
            engine,
        }
    }

    pub fn with_spec(engine: EngineHandle, spec: PlatformSpec) -> NativePlatform {
        NativePlatform { spec, engine }
    }

    pub fn engine(&self) -> &EngineHandle {
        &self.engine
    }
}

impl Platform for NativePlatform {
    fn spec(&self) -> &PlatformSpec {
        &self.spec
    }

    fn execute(&self, task: &OptionTask, n: u64, seed: u32, ctx: ChunkCtx) -> ExecOutcome {
        // The engine's chunk loop starts counters at 0 within a (task, seed)
        // stream; disjoint platform slices are realised by folding `offset`
        // into the seed stream instead (each platform's slice becomes an
        // independent unbiased sample — statistically equivalent to counter
        // slicing for merged estimates). The 64-bit offset is folded to 32
        // bits first; offsets below 2^32 keep the historical seed stream.
        let offset = (ctx.offset ^ (ctx.offset >> 32)) as u32;
        let slice_seed = seed.wrapping_add(offset.rotate_left(16) | (offset & 1));
        let start = Instant::now();
        match self.engine.price(task, n, slice_seed) {
            Ok(stats) => ExecOutcome {
                latency_secs: start.elapsed().as_secs_f64(),
                stats: Some(stats),
                error: None,
            },
            Err(e) => ExecOutcome {
                latency_secs: start.elapsed().as_secs_f64(),
                stats: None,
                error: Some(format!("{}: {e:#}", self.spec.name)),
            },
        }
    }
}
