//! The rentable-platform catalogue — cluster *shape* as data.
//!
//! The paper's testbed (Table II) froze the cluster at fixed instance
//! counts; this module turns those rows into per-type [`PlatformOffer`]s —
//! billing terms, an availability cap, and optional spot terms with a
//! preemption hazard — from which any composition within availability can be
//! instantiated. The Table II testbed is just one pinned instantiation
//! ([`Catalogue::testbed_counts`]); `coordinator::shape` searches over the
//! others.

use crate::api::error::{CloudshapesError, Result};

use super::spec::{instance_name, Category, FpgaResources, PlatformSpec};

/// Spot rental terms of an offer: a discounted rate bought at the risk of
/// preemption. The hazard is expressed per hour of lane uptime; the chunked
/// executor draws each spot lane's preemption time from it.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SpotTerms {
    /// Discounted $/hour rate.
    pub rate_per_hour: f64,
    /// Expected preemptions per hour of uptime (exponential hazard).
    pub preemptions_per_hour: f64,
}

/// One rentable platform type.
#[derive(Debug, Clone, PartialEq)]
pub struct PlatformOffer {
    /// Template spec of a single on-demand instance. Its `name` is the
    /// offer's type name; instantiated instances get `name#k` suffixes.
    pub spec: PlatformSpec,
    /// Cap on rentable instances of this type (the IaaS quota).
    pub available: usize,
    /// Instances in the paper's Table II testbed.
    pub testbed_count: usize,
    /// Optional spot market for this type.
    pub spot: Option<SpotTerms>,
}

/// A set of platform offers the shape optimiser composes clusters from.
#[derive(Debug, Clone)]
pub struct Catalogue {
    offers: Vec<PlatformOffer>,
}

/// Availability cap for the built-in catalogues: a generous per-type cloud
/// quota well above the Table II testbed counts, so shape search has room.
const DEFAULT_AVAILABLE: usize = 16;

impl Catalogue {
    /// Build a catalogue from offers, validating every template spec.
    pub fn new(offers: Vec<PlatformOffer>) -> Result<Catalogue> {
        if offers.is_empty() {
            return Err(CloudshapesError::config("catalogue has no offers"));
        }
        for o in &offers {
            o.spec.validate()?;
            if o.available == 0 {
                return Err(CloudshapesError::config(format!(
                    "offer '{}' has zero availability",
                    o.spec.name
                )));
            }
            if o.testbed_count > o.available {
                return Err(CloudshapesError::config(format!(
                    "offer '{}': testbed count {} exceeds availability {}",
                    o.spec.name, o.testbed_count, o.available
                )));
            }
            if let Some(s) = o.spot {
                if !(s.rate_per_hour >= 0.0 && s.rate_per_hour.is_finite())
                    || !(s.preemptions_per_hour > 0.0 && s.preemptions_per_hour.is_finite())
                {
                    return Err(CloudshapesError::config(format!(
                        "offer '{}': bad spot terms {s:?}",
                        o.spec.name
                    )));
                }
            }
        }
        Ok(Catalogue { offers })
    }

    /// The paper's Table II offers (April-2015 prices), with availability
    /// opened up to a cloud-style quota and spot terms on the IaaS-provided
    /// types (roughly the historical ~70% spot discount, with an hourly-ish
    /// preemption hazard).
    pub fn paper() -> Catalogue {
        Catalogue::new(table2_offers()).expect("paper catalogue is valid")
    }

    /// A reduced catalogue for fast tests: one offer per category (the same
    /// types `small_cluster` picks).
    pub fn small() -> Catalogue {
        let all = table2_offers();
        let mut offers = Vec::new();
        for cat in [Category::Fpga, Category::Gpu, Category::Cpu] {
            let mut o = all.iter().find(|o| o.spec.category == cat).unwrap().clone();
            o.testbed_count = 1;
            offers.push(o);
        }
        Catalogue::new(offers).expect("small catalogue is valid")
    }

    pub fn len(&self) -> usize {
        self.offers.len()
    }

    pub fn is_empty(&self) -> bool {
        self.offers.is_empty()
    }

    pub fn offers(&self) -> &[PlatformOffer] {
        &self.offers
    }

    pub fn offer(&self, t: usize) -> &PlatformOffer {
        &self.offers[t]
    }

    /// Per-offer availability caps.
    pub fn availability(&self) -> Vec<usize> {
        self.offers.iter().map(|o| o.available).collect()
    }

    /// The pinned paper-testbed composition (Table II counts).
    pub fn testbed_counts(&self) -> Vec<usize> {
        self.offers.iter().map(|o| o.testbed_count).collect()
    }

    /// Offer index by type name.
    pub fn find(&self, type_name: &str) -> Option<usize> {
        self.offers.iter().position(|o| o.spec.name == type_name)
    }

    /// Instantiate a composition: `counts[t]` instances of offer `t`, named
    /// `type#k` (bare type name when a single instance is rented). With
    /// `spot` set, offers that have spot terms are rented at the spot rate
    /// and carry the preemption hazard in [`PlatformSpec::preemptible`].
    pub fn instantiate(&self, counts: &[usize], spot: bool) -> Result<Vec<PlatformSpec>> {
        if counts.len() != self.offers.len() {
            return Err(CloudshapesError::config(format!(
                "composition has {} counts for {} catalogue offers",
                counts.len(),
                self.offers.len()
            )));
        }
        if counts.iter().all(|&c| c == 0) {
            return Err(CloudshapesError::config("composition rents no instances"));
        }
        let mut specs = Vec::new();
        for (o, &count) in self.offers.iter().zip(counts) {
            if count > o.available {
                return Err(CloudshapesError::config(format!(
                    "composition rents {count} x '{}' but only {} are available",
                    o.spec.name, o.available
                )));
            }
            for k in 0..count {
                let mut spec = o.spec.clone();
                spec.name = instance_name(&o.spec.name, k, count);
                if spot {
                    if let Some(s) = o.spot {
                        spec.rate_per_hour = s.rate_per_hour;
                        spec.preemptible = Some(s.preemptions_per_hour);
                    }
                }
                specs.push(spec);
            }
        }
        Ok(specs)
    }

    /// Instance index → offer index map for a composition (the layout
    /// [`instantiate`](Self::instantiate) produces).
    pub fn instance_offers(&self, counts: &[usize]) -> Vec<usize> {
        counts
            .iter()
            .enumerate()
            .flat_map(|(t, &c)| std::iter::repeat(t).take(c))
            .collect()
    }

    /// The simulated spot rate of offer `t` at cluster-virtual `t_secs`: a
    /// deterministic daily time series instead of the static quote, so
    /// shape decisions shift across the simulated day (ROADMAP item 5).
    /// Two superposed sinusoids (24 h and 12 h periods) with a stable
    /// per-offer phase swing the quote by up to ±`volatility` (in [0, 1)).
    /// `volatility = 0` reproduces [`SpotTerms::rate_per_hour`] exactly —
    /// every pre-existing static caller is unaffected. `None` for offers
    /// with no spot market or out-of-range `t`.
    pub fn spot_rate_at(&self, t: usize, t_secs: f64, volatility: f64) -> Option<f64> {
        let offer = self.offers.get(t)?;
        let s = offer.spot?;
        Some(s.rate_per_hour * spot_modulation(t_secs, volatility, name_phase(&offer.spec.name)))
    }

    /// As [`instantiate`](Self::instantiate), but spot rentals are billed
    /// at the simulated time-of-day rate ([`spot_rate_at`](Self::spot_rate_at))
    /// instead of the static quote. `volatility = 0` is exactly
    /// `instantiate`.
    pub fn instantiate_at(
        &self,
        counts: &[usize],
        spot: bool,
        t_secs: f64,
        volatility: f64,
    ) -> Result<Vec<PlatformSpec>> {
        let mut specs = self.instantiate(counts, spot)?;
        if spot && volatility != 0.0 {
            for (k, t) in self.instance_offers(counts).iter().enumerate() {
                if let Some(rate) = self.spot_rate_at(*t, t_secs, volatility) {
                    specs[k].rate_per_hour = rate;
                }
            }
        }
        Ok(specs)
    }
}

/// Daily spot-price modulation factor at virtual `t_secs` — deterministic,
/// always positive, identity at zero volatility.
fn spot_modulation(t_secs: f64, volatility: f64, phase: f64) -> f64 {
    if volatility == 0.0 {
        return 1.0;
    }
    let day = t_secs / 86_400.0 * std::f64::consts::TAU;
    let swing = 0.6 * (day + phase).sin() + 0.4 * (2.0 * day + 1.7 * phase).sin();
    (1.0 + volatility * swing).max(0.05)
}

/// Stable per-offer phase in [0, τ) from the type name (FNV-1a), so
/// different spot markets peak at different times of day.
fn name_phase(name: &str) -> f64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in name.as_bytes() {
        h ^= u64::from(*b);
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    (h % 10_000) as f64 / 10_000.0 * std::f64::consts::TAU
}

/// One device-type row of Table II as a catalogue offer.
struct Row {
    count: usize,
    provider: Option<&'static str>,
    device: &'static str,
    short: &'static str,
    standard: &'static str,
    category: Category,
    resources: Option<FpgaResources>,
    clock_ghz: f64,
    app_gflops: f64,
    rate_per_hour: f64,
    quantum_secs: f64,
    setup_secs: f64,
    /// Spot discount factor on the on-demand rate (None = no spot market).
    spot_discount: Option<f64>,
}

fn table2_offers() -> Vec<PlatformOffer> {
    let rows = vec![
        Row {
            count: 4,
            provider: None,
            device: "Xilinx Virtex 6 475T",
            short: "virtex6",
            standard: "OpenSPL (MaxCompiler 2013.2.2)",
            category: Category::Fpga,
            resources: Some(FpgaResources { luts_k: 298, flipflops_k: 595, brams: 1064, dsps: 2016 }),
            clock_ghz: 0.2,
            app_gflops: 111.978,
            rate_per_hour: 0.438,
            // Hypothetical FPGA IaaS billed hourly (DESIGN.md §2).
            quantum_secs: 3600.0,
            setup_secs: 40.0, // full-chip bitstream configuration
            spot_discount: None,
        },
        Row {
            count: 8,
            provider: None,
            device: "Altera Stratix V GSD8",
            short: "stratix5-gsd8",
            standard: "OpenSPL (MaxCompiler 2013.2.2)",
            category: Category::Fpga,
            resources: Some(FpgaResources { luts_k: 695, flipflops_k: 1050, brams: 2567, dsps: 3926 }),
            clock_ghz: 0.18,
            app_gflops: 112.949,
            rate_per_hour: 0.442,
            quantum_secs: 3600.0,
            setup_secs: 40.0,
            spot_discount: None,
        },
        Row {
            count: 1,
            provider: None,
            device: "Altera Stratix V GSD5",
            short: "stratix5-gsd5",
            standard: "OpenCL (Altera SDK 14.0)",
            category: Category::Fpga,
            resources: Some(FpgaResources { luts_k: 457, flipflops_k: 690, brams: 2014, dsps: 3180 }),
            clock_ghz: 0.25,
            app_gflops: 176.871,
            rate_per_hour: 0.692,
            quantum_secs: 3600.0,
            setup_secs: 25.0, // OpenCL runtime reconfiguration
            spot_discount: None,
        },
        Row {
            count: 1,
            provider: Some("AWS"),
            device: "Nvidia Grid GK104",
            short: "gk104",
            standard: "OpenCL (Nvidia SDK 6.0)",
            category: Category::Gpu,
            resources: None,
            clock_ghz: 0.8,
            app_gflops: 556.085,
            rate_per_hour: 0.650,
            quantum_secs: 3600.0, // AWS hourly billing (Table I)
            setup_secs: 2.0,      // context + JIT + transfer
            spot_discount: Some(0.3), // the AWS spot market
        },
        Row {
            count: 1,
            provider: Some("MA"),
            device: "Intel Xeon E5-2660",
            short: "xeon-e5-2660",
            standard: "POSIX (GCC 4.8)",
            category: Category::Cpu,
            resources: None,
            clock_ghz: 2.2,
            app_gflops: 4.160,
            rate_per_hour: 0.480,
            quantum_secs: 60.0, // Azure 1-minute quantum (Table I)
            setup_secs: 0.5,
            spot_discount: Some(0.35),
        },
        Row {
            count: 1,
            provider: Some("GCE"),
            device: "Intel Xeon",
            short: "xeon-gce",
            standard: "POSIX (GCC 4.8)",
            category: Category::Cpu,
            resources: None,
            clock_ghz: 2.0,
            app_gflops: 6.022,
            rate_per_hour: 0.352,
            quantum_secs: 600.0, // GCE 10-minute quantum (Table I)
            setup_secs: 0.5,
            spot_discount: Some(0.3),
        },
    ];
    rows.into_iter()
        .map(|r| PlatformOffer {
            spec: PlatformSpec {
                name: r.short.to_string(),
                provider: r.provider,
                device: r.device,
                standard: r.standard,
                category: r.category,
                resources: r.resources,
                clock_ghz: r.clock_ghz,
                app_gflops: r.app_gflops,
                rate_per_hour: r.rate_per_hour,
                quantum_secs: r.quantum_secs,
                setup_secs: r.setup_secs,
                preemptible: None,
            },
            available: DEFAULT_AVAILABLE.max(r.count),
            testbed_count: r.count,
            spot: r.spot_discount.map(|d| SpotTerms {
                rate_per_hour: r.rate_per_hour * d,
                preemptions_per_hour: 0.5,
            }),
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_catalogue_pins_the_testbed() {
        let c = Catalogue::paper();
        assert_eq!(c.len(), 6);
        assert_eq!(c.testbed_counts(), vec![4, 8, 1, 1, 1, 1]);
        let specs = c.instantiate(&c.testbed_counts(), false).unwrap();
        assert_eq!(specs.len(), 16);
        // Instance-suffixed names for multi-instance types, bare otherwise.
        assert_eq!(specs[0].name, "virtex6#0");
        assert_eq!(specs[3].name, "virtex6#3");
        assert_eq!(specs[4].name, "stratix5-gsd8#0");
        assert_eq!(specs[12].name, "stratix5-gsd5");
        assert_eq!(specs[13].name, "gk104");
        // Duplicated specs differ only in name.
        let mut a = specs[0].clone();
        a.name = specs[1].name.clone();
        assert_eq!(a, specs[1]);
    }

    #[test]
    fn composition_respects_availability() {
        let c = Catalogue::paper();
        let mut counts = c.testbed_counts();
        counts[0] = c.offer(0).available + 1;
        let e = c.instantiate(&counts, false).unwrap_err();
        assert_eq!(e.kind(), "config");
        assert!(e.message().contains("available"), "{e}");
        // Wrong arity and the empty composition are config errors too.
        assert!(c.instantiate(&[1, 2], false).is_err());
        assert!(c.instantiate(&[0; 6], false).is_err());
    }

    #[test]
    fn spot_instances_carry_discount_and_hazard() {
        let c = Catalogue::paper();
        let gpu = c.find("gk104").unwrap();
        let mut counts = vec![0; c.len()];
        counts[gpu] = 2;
        let on_demand = c.instantiate(&counts, false).unwrap();
        let spot = c.instantiate(&counts, true).unwrap();
        assert_eq!(spot.len(), 2);
        assert_eq!(spot[0].name, "gk104#0");
        assert!(spot[0].rate_per_hour < on_demand[0].rate_per_hour);
        assert!(spot[0].preemptible.is_some());
        assert_eq!(on_demand[0].preemptible, None);
        // Types without a spot market are unaffected by the flag.
        let fpga_counts: Vec<usize> =
            (0..c.len()).map(|t| usize::from(t == 0)).collect();
        let fpga = c.instantiate(&fpga_counts, true).unwrap();
        assert_eq!(fpga[0].preemptible, None);
        assert_eq!(fpga[0].rate_per_hour, c.offer(0).spec.rate_per_hour);
    }

    #[test]
    fn instance_offer_map_matches_layout() {
        let c = Catalogue::small();
        assert_eq!(c.instance_offers(&[2, 0, 1]), vec![0, 0, 2]);
        let specs = c.instantiate(&[2, 0, 1], false).unwrap();
        assert_eq!(specs.len(), 3);
        assert_eq!(specs[0].name, "virtex6#0");
        assert_eq!(specs[2].name, "xeon-e5-2660");
    }

    #[test]
    fn small_catalogue_is_heterogeneous() {
        let c = Catalogue::small();
        assert_eq!(c.len(), 3);
        assert_eq!(c.testbed_counts(), vec![1, 1, 1]);
        let cats: Vec<Category> = c.offers().iter().map(|o| o.spec.category).collect();
        assert!(cats.contains(&Category::Fpga));
        assert!(cats.contains(&Category::Gpu));
        assert!(cats.contains(&Category::Cpu));
    }

    #[test]
    fn spot_series_is_deterministic_and_static_at_zero_volatility() {
        let c = Catalogue::paper();
        let gpu = c.find("gk104").unwrap();
        let base = c.offer(gpu).spot.unwrap().rate_per_hour;
        // Zero volatility: the static quote, at any time of day.
        for t_secs in [0.0, 3600.0, 43_200.0] {
            assert_eq!(c.spot_rate_at(gpu, t_secs, 0.0), Some(base));
        }
        // Deterministic: same (offer, time, volatility) -> same price.
        assert_eq!(
            c.spot_rate_at(gpu, 7200.0, 0.5),
            c.spot_rate_at(gpu, 7200.0, 0.5)
        );
        // The price actually moves across the day, positively, within the
        // volatility envelope.
        let mut lo = f64::INFINITY;
        let mut hi = 0.0f64;
        for k in 0..96 {
            let r = c.spot_rate_at(gpu, k as f64 * 900.0, 0.5).unwrap();
            assert!(r > 0.0 && r <= base * 1.5 + 1e-9);
            lo = lo.min(r);
            hi = hi.max(r);
        }
        assert!(hi > lo * 1.1, "flat spot series: [{lo}, {hi}]");
        // Offers without a spot market have no series.
        let fpga = c.find("virtex6").unwrap();
        assert_eq!(c.spot_rate_at(fpga, 0.0, 0.5), None);
        assert_eq!(c.spot_rate_at(99, 0.0, 0.5), None);
    }

    #[test]
    fn instantiate_at_bills_the_time_of_day_rate() {
        let c = Catalogue::paper();
        let gpu = c.find("gk104").unwrap();
        let mut counts = vec![0; c.len()];
        counts[gpu] = 2;
        // volatility 0 == the plain instantiate.
        let static_specs = c.instantiate(&counts, true).unwrap();
        let at_zero = c.instantiate_at(&counts, true, 5000.0, 0.0).unwrap();
        assert_eq!(static_specs, at_zero);
        // Sampled across a day, the composition's spot bill moves.
        let mut rates = Vec::new();
        for k in 0..24 {
            let specs = c.instantiate_at(&counts, true, k as f64 * 3600.0, 0.5).unwrap();
            assert_eq!(specs[0].rate_per_hour, specs[1].rate_per_hour);
            assert!(specs[0].preemptible.is_some());
            rates.push(specs[0].rate_per_hour);
        }
        let lo = rates.iter().cloned().fold(f64::INFINITY, f64::min);
        let hi = rates.iter().cloned().fold(0.0f64, f64::max);
        assert!(hi > lo, "spot bill never moved across the day");
        // On-demand rentals ignore the series entirely.
        let on_demand = c.instantiate_at(&counts, false, 5000.0, 0.5).unwrap();
        assert_eq!(on_demand, c.instantiate(&counts, false).unwrap());
    }

    #[test]
    fn bad_offers_are_rejected() {
        assert!(Catalogue::new(vec![]).is_err());
        let mut bad = table2_offers();
        bad[0].available = 0;
        assert!(Catalogue::new(bad).is_err());
        let mut bad = table2_offers();
        bad[0].spec.quantum_secs = 0.0;
        assert!(Catalogue::new(bad).is_err());
        let mut bad = table2_offers();
        bad[3].spot = Some(SpotTerms { rate_per_hour: 0.2, preemptions_per_hour: 0.0 });
        assert!(Catalogue::new(bad).is_err());
    }
}
