//! Platforms: the heterogeneous compute substrate the coordinator partitions
//! work across — simulated (Table II testbed stand-ins) and native (real
//! PJRT execution of the AOT artifacts).

pub mod cluster;
pub mod native;
pub mod sim;
pub mod spec;

pub use cluster::Cluster;
pub use sim::{SimConfig, SimPlatform};
pub use spec::{paper_cluster, small_cluster, Category, PlatformSpec};

use crate::pricing::mc::PayoffStats;
use crate::workload::option::OptionTask;

/// Result of executing a batch of `n` simulations on a platform.
#[derive(Debug, Clone)]
pub struct ExecOutcome {
    /// Wall-clock (native) or simulated latency, seconds.
    pub latency_secs: f64,
    /// Raw payoff statistics (None when the execution failed).
    pub stats: Option<PayoffStats>,
    /// Failure description, if any.
    pub error: Option<String>,
}

/// A compute platform the coordinator can dispatch Monte Carlo work to.
///
/// `offset` is the starting path counter of this platform's slice of the
/// task's path space; disjoint slices compose to exactly the statistics of
/// a single-platform run (counter-based RNG — see `pricing::mc`).
pub trait Platform: Send + Sync {
    fn spec(&self) -> &PlatformSpec;
    fn execute(&self, task: &OptionTask, n: u64, seed: u32, offset: u32) -> ExecOutcome;

    /// Timing-only execution for the §III.A benchmarking procedure —
    /// platforms that can skip producing payoff statistics (the simulator)
    /// override this; the native platform's pricing IS its latency.
    fn benchmark_execute(&self, task: &OptionTask, n: u64, seed: u32) -> ExecOutcome {
        self.execute(task, n, seed, 0)
    }
}
