//! Platforms: the heterogeneous compute substrate the coordinator partitions
//! work across — simulated (Table II testbed stand-ins) and native (real
//! PJRT execution of the AOT artifacts).

pub mod catalogue;
pub mod cluster;
pub mod native;
pub mod sim;
pub mod spec;

pub use catalogue::{Catalogue, PlatformOffer, SpotTerms};
pub use cluster::Cluster;
pub use sim::{SimConfig, SimPlatform};
pub use spec::{paper_cluster, small_cluster, Category, PlatformSpec};

use crate::pricing::mc::PayoffStats;
use crate::workload::option::OptionTask;

/// Result of executing a batch of `n` simulations on a platform.
#[derive(Debug, Clone)]
pub struct ExecOutcome {
    /// Wall-clock (native) or simulated latency, seconds.
    pub latency_secs: f64,
    /// Raw payoff statistics (None when the execution failed).
    pub stats: Option<PayoffStats>,
    /// Failure description, if any.
    pub error: Option<String>,
}

/// Per-chunk execution context — how the chunked executor tells a platform
/// *where* a chunk sits in a task's path space and *what came before it* on
/// this platform.
///
/// `offset` is the starting path counter of the chunk in the task's global
/// (u64) path space; disjoint chunks compose to exactly the statistics of a
/// single-platform run (counter-based RNG — see `pricing::mc`). Offsets are
/// 64-bit because tasks run up to `1 << 34` simulations: a 32-bit offset
/// would wrap and overlap RNG counter ranges, biasing merged prices.
///
/// `prior_sims` is the number of this task's simulations this platform has
/// already *successfully* executed before this chunk. Platforms use it as a
/// chunk hint: a cold chunk (`prior_sims == 0`) pays the per-task setup
/// cost, a warm continuation does not — which is what makes a chunked run
/// latency-identical to a one-shot slice. The simulator also budgets its
/// capped payoff statistics per (platform, task) stream rather than per
/// call, so chunked and unchunked runs produce identical statistics.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ChunkCtx {
    /// Start of this chunk in the task's global path-counter space.
    pub offset: u64,
    /// Simulations of this task already completed on this platform
    /// (0 = cold start: the platform charges setup).
    pub prior_sims: u64,
}

impl ChunkCtx {
    /// A cold (first-dispatch) chunk starting at `offset`.
    pub fn cold(offset: u64) -> ChunkCtx {
        ChunkCtx { offset, prior_sims: 0 }
    }

    /// Whether this chunk pays the per-task setup cost.
    pub fn is_cold(&self) -> bool {
        self.prior_sims == 0
    }
}

/// A compute platform the coordinator can dispatch Monte Carlo work to.
pub trait Platform: Send + Sync {
    fn spec(&self) -> &PlatformSpec;

    /// Execute `n` simulations of `task` — one chunk of a (platform, task)
    /// slice, located by `ctx` (see [`ChunkCtx`]).
    fn execute(&self, task: &OptionTask, n: u64, seed: u32, ctx: ChunkCtx) -> ExecOutcome;

    /// Timing-only execution for the §III.A benchmarking procedure —
    /// platforms that can skip producing payoff statistics (the simulator)
    /// override this; the native platform's pricing IS its latency.
    fn benchmark_execute(&self, task: &OptionTask, n: u64, seed: u32) -> ExecOutcome {
        self.execute(task, n, seed, ChunkCtx::cold(0))
    }
}
