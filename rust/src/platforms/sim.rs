//! Simulated platforms — the substitution for the paper's physical
//! FPGA/GPU/CPU testbed (DESIGN.md §2).
//!
//! Each simulated platform has a hidden *ground-truth* latency model derived
//! from its Table II application performance: `L(n) = γ_true + β_true(task)·n`
//! with `β_true = flops_per_path / (app_GFLOPS·1e9) · hidden_factor`, where
//! the hidden factor (drawn once per platform, ±12%) models the gap between
//! published benchmark GFLOPS and this workload's achieved throughput.
//! Execution latency is further perturbed by multiplicative log-normal noise
//! (run-to-run variance). The coordinator never sees these internals — it
//! must *benchmark and fit* models exactly as the paper does, which is what
//! makes Fig. 2 (model error) and Fig. 3 (model vs measured) meaningful.
//!
//! Payoff statistics are produced by really simulating up to `stats_cap`
//! paths of the platform's assigned counter range with the native Threefry
//! pricer — unbiased prices without burning hours on 1e9-path tasks. The
//! cap is budgeted per (platform, task) *stream*, not per call: a chunked
//! dispatch (see [`ChunkCtx`]) produces exactly the statistics of a
//! one-shot slice.

use std::sync::Mutex;

use crate::pricing::batch::KernelConfig;
use crate::pricing::mc::PayoffStats;
use crate::util::rng::{Rng, SplitMix64};
use crate::workload::option::{OptionTask, Payoff};

use super::spec::PlatformSpec;
use super::{ChunkCtx, ExecOutcome, Platform};

/// Tuning knobs for the simulation substrate.
#[derive(Debug, Clone)]
pub struct SimConfig {
    /// Log-sigma of the multiplicative latency noise (0 = deterministic).
    pub noise_sigma: f64,
    /// Max paths actually simulated per (platform, task) stream for
    /// statistics.
    pub stats_cap: u32,
    /// Spread of the hidden throughput factor (0.12 = ±12%).
    pub hidden_spread: f64,
    /// Optional failure injection: probability an execute() call fails.
    pub failure_rate: f64,
    /// Which Monte Carlo kernel produces the payoff statistics (batched by
    /// default; bit-identical to the scalar oracle either way).
    pub kernel: KernelConfig,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            noise_sigma: 0.04,
            stats_cap: 1 << 15,
            hidden_spread: 0.12,
            failure_rate: 0.0,
            kernel: KernelConfig::default(),
        }
    }
}

impl SimConfig {
    /// Deterministic variant (exact models, no noise) — used by tests that
    /// need reproducible latencies.
    pub fn exact() -> SimConfig {
        SimConfig { noise_sigma: 0.0, hidden_spread: 0.0, ..SimConfig::default() }
    }
}

/// A simulated heterogeneous platform.
pub struct SimPlatform {
    spec: PlatformSpec,
    cfg: SimConfig,
    /// Hidden per-platform throughput factor (the benchmarker must discover
    /// its effect; it is not exposed).
    hidden_factor: f64,
    /// Hidden *per-payoff-family* throughput multipliers (all 1.0 by
    /// default): how much slower/faster this platform runs each kernel
    /// family relative to its pooled rate. The per-family re-fit harnesses
    /// pin these to make one family cost a known multiple of another.
    family_factors: [f64; Payoff::COUNT],
    /// Hidden setup-time factor.
    gamma_true: f64,
    noise_rng: Mutex<Rng>,
    /// Per-platform salt for the stateless benchmark noise stream.
    bench_salt: u64,
}

impl SimPlatform {
    /// Build from a spec. `seed` individualises the hidden factors.
    pub fn new(spec: PlatformSpec, cfg: SimConfig, seed: u64) -> SimPlatform {
        let mut rng = Rng::new(seed ^ 0x5143_u64.wrapping_mul(0x9E37_79B9));
        let hidden_factor = 1.0 + cfg.hidden_spread * (2.0 * rng.f64() - 1.0);
        let gamma_true = spec.setup_secs * (1.0 + 0.2 * (2.0 * rng.f64() - 1.0));
        let bench_salt = rng.next_u64();
        SimPlatform {
            spec,
            cfg,
            hidden_factor,
            family_factors: [1.0; Payoff::COUNT],
            gamma_true,
            noise_rng: Mutex::new(rng),
            bench_salt,
        }
    }

    /// As [`new`](Self::new), but with the hidden throughput factor pinned —
    /// straggler-injection harnesses use this to make one platform slower
    /// than any model fitted before the drift appeared.
    pub fn with_hidden_factor(
        spec: PlatformSpec,
        cfg: SimConfig,
        seed: u64,
        hidden_factor: f64,
    ) -> SimPlatform {
        assert!(hidden_factor > 0.0 && hidden_factor.is_finite());
        let mut p = SimPlatform::new(spec, cfg, seed);
        p.hidden_factor = hidden_factor;
        p
    }

    /// As [`new`](Self::new), but with hidden per-family throughput
    /// multipliers pinned — the per-family re-fit harnesses use this to
    /// make e.g. basket paths cost a known multiple of barrier paths in a
    /// way no single per-platform line can model.
    pub fn with_family_factors(
        spec: PlatformSpec,
        cfg: SimConfig,
        seed: u64,
        family_factors: [f64; Payoff::COUNT],
    ) -> SimPlatform {
        for (i, f) in family_factors.iter().enumerate() {
            assert!(*f > 0.0 && f.is_finite(), "family {i}: invalid factor {f}");
        }
        let mut p = SimPlatform::new(spec, cfg, seed);
        p.family_factors = family_factors;
        p
    }

    /// Ground-truth β for a task on this platform, seconds per path.
    /// Private to the simulator — exposed only for white-box tests.
    pub(crate) fn beta_true(&self, task: &OptionTask) -> f64 {
        task.flops_per_path() / (self.spec.app_gflops * 1e9)
            * self.hidden_factor
            * self.family_factors[task.payoff.index()]
    }

    #[cfg_attr(not(test), allow(dead_code))]
    pub(crate) fn gamma_true(&self) -> f64 {
        self.gamma_true
    }

    /// Per-task stream budget of really-simulated statistics paths. The cap
    /// is in *path-steps*, not paths: a 512-step Asian slice simulates
    /// proportionally fewer paths than a terminal-value European one, so
    /// per-stream statistics cost is uniform regardless of payoff (§Perf:
    /// this turned the 16×128 execution from step-count-bound to flat).
    fn stats_budget(&self, task: &OptionTask) -> u64 {
        let path_step_budget = self.cfg.stats_cap as u64 * 64;
        let cap = (path_step_budget / task.steps.max(1) as u64).max(64);
        cap.min(self.cfg.stats_cap as u64)
    }
}

impl Platform for SimPlatform {
    fn spec(&self) -> &PlatformSpec {
        &self.spec
    }

    fn execute(&self, task: &OptionTask, n: u64, seed: u32, ctx: ChunkCtx) -> ExecOutcome {
        let (noise, fail_draw) = {
            let mut rng = self.noise_rng.lock().unwrap();
            (rng.lognormal_noise(self.cfg.noise_sigma), rng.f64())
        };
        // Setup is paid once per (platform, task) stream: cold chunks carry
        // it, warm continuations do not — chunked latency therefore sums to
        // exactly the one-shot slice latency.
        let setup = if ctx.is_cold() { self.gamma_true } else { 0.0 };
        if fail_draw < self.cfg.failure_rate {
            return ExecOutcome {
                latency_secs: setup, // failed after (any) setup
                stats: None,
                error: Some(format!("{}: injected platform failure", self.spec.name)),
            };
        }
        let latency = (setup + self.beta_true(task) * n as f64) * noise;
        // Real statistics on a capped prefix of this (platform, task)
        // stream: `prior_sims` chunk-hints how much of the budget earlier
        // chunks already consumed, so successive chunks simulate a
        // contiguous counter range identical to the one-shot path's.
        let budget = self.stats_budget(task);
        let done = ctx.prior_sims.min(budget);
        let sim_n = n.min(budget - done) as u32;
        let stats = if sim_n > 0 {
            self.cfg.kernel.simulate(task, seed, ctx.offset, sim_n)
        } else {
            PayoffStats::default()
        };
        ExecOutcome { latency_secs: latency, stats: Some(stats), error: None }
    }

    fn benchmark_execute(&self, task: &OptionTask, n: u64, seed: u32) -> ExecOutcome {
        // Benchmarking only observes latency; skip the payoff simulation
        // (at paper scale the benchmarker makes ~30k calls). The noise and
        // failure draws are a pure function of (platform, task, n, seed) —
        // repetitions with distinct seeds are honestly independent, and a
        // repeated (n, seed) observation reproduces exactly.
        let mut mix = SplitMix64::new(
            self.bench_salt
                ^ (seed as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
                ^ (task.id as u64).rotate_left(32)
                ^ n,
        );
        let mut rng = Rng::new(mix.next_u64());
        let noise = rng.lognormal_noise(self.cfg.noise_sigma);
        let fail_draw = rng.f64();
        if fail_draw < self.cfg.failure_rate {
            return ExecOutcome {
                latency_secs: self.gamma_true,
                stats: None,
                error: Some(format!("{}: injected platform failure", self.spec.name)),
            };
        }
        let latency = (self.gamma_true + self.beta_true(task) * n as f64) * noise;
        ExecOutcome { latency_secs: latency, stats: None, error: None }
    }
}

/// Convenience: statistics when nothing is simulated.
pub fn empty_stats() -> PayoffStats {
    PayoffStats::default()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platforms::spec::paper_cluster;
    use crate::workload::{generate, GeneratorConfig};

    fn task() -> OptionTask {
        generate(&GeneratorConfig::small(1, 0.05, 1)).tasks[0].clone()
    }

    fn gpu_spec() -> PlatformSpec {
        paper_cluster().into_iter().find(|p| p.name == "gk104").unwrap()
    }

    fn cold(offset: u64) -> ChunkCtx {
        ChunkCtx::cold(offset)
    }

    #[test]
    fn latency_is_affine_in_n_without_noise() {
        let p = SimPlatform::new(gpu_spec(), SimConfig::exact(), 7);
        let t = task();
        let l1 = p.execute(&t, 1_000_000, 1, cold(0)).latency_secs;
        let l2 = p.execute(&t, 2_000_000, 1, cold(0)).latency_secs;
        let l3 = p.execute(&t, 3_000_000, 1, cold(0)).latency_secs;
        // Equal increments: affine.
        assert!(((l2 - l1) - (l3 - l2)).abs() < 1e-9);
        assert!(l1 > p.gamma_true() - 1e-9);
    }

    #[test]
    fn warm_chunks_skip_setup() {
        let p = SimPlatform::new(gpu_spec(), SimConfig::exact(), 7);
        let t = task();
        let whole = p.execute(&t, 2_000_000, 1, cold(0)).latency_secs;
        let a = p.execute(&t, 1_500_000, 1, cold(0)).latency_secs;
        let b = p
            .execute(&t, 500_000, 1, ChunkCtx { offset: 1_500_000, prior_sims: 1_500_000 })
            .latency_secs;
        assert!(
            ((a + b) - whole).abs() < 1e-9 * whole,
            "chunked {a}+{b} vs one-shot {whole}"
        );
    }

    #[test]
    fn chunked_stats_match_one_shot_slice() {
        // The per-stream stats budget: chunk hints make a chunked dispatch
        // produce exactly the one-shot statistics.
        let cfg = SimConfig { stats_cap: 4096, ..SimConfig::exact() };
        let p = SimPlatform::new(gpu_spec(), cfg, 5);
        let t = task();
        let whole = p.execute(&t, 1 << 20, 1, cold(0)).stats.unwrap();
        let c1 = p.execute(&t, 1024, 1, cold(0)).stats.unwrap();
        let c2 = p
            .execute(&t, 4096, 1, ChunkCtx { offset: 1024, prior_sims: 1024 })
            .stats
            .unwrap();
        let c3 = p
            .execute(&t, (1 << 20) - 5120, 1, ChunkCtx { offset: 5120, prior_sims: 5120 })
            .stats
            .unwrap();
        let merged = c1.merge(&c2).merge(&c3);
        assert_eq!(whole.n, merged.n);
        assert!((whole.sum - merged.sum).abs() < 1e-9 * whole.sum.abs().max(1.0));
        assert!((whole.sum_sq - merged.sum_sq).abs() < 1e-9 * whole.sum_sq.abs().max(1.0));
    }

    #[test]
    fn noise_perturbs_but_preserves_scale() {
        let p = SimPlatform::new(gpu_spec(), SimConfig::default(), 7);
        let t = task();
        let ls: Vec<f64> =
            (0..20).map(|_| p.execute(&t, 1 << 20, 1, cold(0)).latency_secs).collect();
        let mean = ls.iter().sum::<f64>() / ls.len() as f64;
        assert!(ls.iter().any(|l| (l - mean).abs() > 1e-12), "no noise observed");
        for l in &ls {
            assert!((l / mean - 1.0).abs() < 0.3, "noise too large: {l} vs {mean}");
        }
    }

    #[test]
    fn hidden_factor_differs_across_seeds() {
        let a = SimPlatform::new(gpu_spec(), SimConfig::default(), 1);
        let b = SimPlatform::new(gpu_spec(), SimConfig::default(), 2);
        let t = task();
        assert_ne!(a.beta_true(&t), b.beta_true(&t));
    }

    #[test]
    fn hidden_factor_override_scales_latency() {
        let base = SimPlatform::new(gpu_spec(), SimConfig::exact(), 3);
        let slow = SimPlatform::with_hidden_factor(gpu_spec(), SimConfig::exact(), 3, 5.0);
        let t = task();
        assert!((slow.beta_true(&t) / base.beta_true(&t) - 5.0).abs() < 1e-9);
    }

    #[test]
    fn family_factors_scale_only_their_family() {
        use crate::workload::option::Payoff;
        let mut factors = [1.0; Payoff::COUNT];
        factors[Payoff::Basket.index()] = 4.0;
        let base = SimPlatform::new(gpu_spec(), SimConfig::exact(), 3);
        let skewed =
            SimPlatform::with_family_factors(gpu_spec(), SimConfig::exact(), 3, factors);
        let mut barrier = task();
        barrier.payoff = Payoff::Barrier;
        barrier.steps = 32;
        let mut basket = barrier.clone();
        basket.payoff = Payoff::Basket;
        basket.assets = 4;
        basket.correlation = 0.5;
        assert_eq!(skewed.beta_true(&barrier), base.beta_true(&barrier));
        assert!((skewed.beta_true(&basket) / base.beta_true(&basket) - 4.0).abs() < 1e-12);
    }

    #[test]
    fn faster_device_has_smaller_beta() {
        let specs = paper_cluster();
        let gpu = SimPlatform::new(specs.iter().find(|s| s.name == "gk104").unwrap().clone(), SimConfig::exact(), 3);
        let cpu = SimPlatform::new(specs.iter().find(|s| s.name == "xeon-gce").unwrap().clone(), SimConfig::exact(), 3);
        let t = task();
        assert!(gpu.beta_true(&t) < cpu.beta_true(&t) / 10.0);
    }

    #[test]
    fn stats_are_unbiased_prices() {
        use crate::pricing::{blackscholes, combine};
        use crate::workload::option::Payoff;
        let p = SimPlatform::new(gpu_spec(), SimConfig::exact(), 5);
        let mut t = task();
        t.payoff = Payoff::European;
        let out = p.execute(&t, 1 << 20, 42, cold(0));
        let est = combine(&out.stats.unwrap(), t.discount());
        let bs = blackscholes::call(t.spot, t.strike, t.rate, t.sigma, t.maturity);
        assert!((est.price - bs).abs() < 5.0 * est.std_error + 0.05, "{est:?} vs {bs}");
    }

    #[test]
    fn stats_capped() {
        let cfg = SimConfig { stats_cap: 1024, ..SimConfig::exact() };
        let p = SimPlatform::new(gpu_spec(), cfg, 5);
        let out = p.execute(&task(), 1 << 22, 1, cold(0));
        assert_eq!(out.stats.unwrap().n, 1024);
    }

    #[test]
    fn kernel_choice_does_not_change_statistics() {
        // The batched kernel is bit-identical to the scalar oracle, so the
        // platform's payoff statistics must not depend on the [kernel]
        // escape hatch.
        let scalar = SimConfig { kernel: KernelConfig::scalar(), ..SimConfig::exact() };
        let batched = SimConfig::exact();
        let t = task();
        let a = SimPlatform::new(gpu_spec(), scalar, 5).execute(&t, 1 << 14, 9, cold(3));
        let b = SimPlatform::new(gpu_spec(), batched, 5).execute(&t, 1 << 14, 9, cold(3));
        assert_eq!(a.stats.unwrap(), b.stats.unwrap());
        assert_eq!(a.latency_secs, b.latency_secs);
    }

    #[test]
    fn failure_injection_fires() {
        let cfg = SimConfig { failure_rate: 1.0, ..SimConfig::exact() };
        let p = SimPlatform::new(gpu_spec(), cfg, 5);
        let out = p.execute(&task(), 1000, 1, cold(0));
        assert!(out.error.is_some());
        assert!(out.stats.is_none());
    }

    #[test]
    fn benchmark_noise_is_seed_reproducible_and_independent() {
        let p = SimPlatform::new(gpu_spec(), SimConfig::default(), 11);
        let t = task();
        // Same (n, seed): identical observation.
        let a = p.benchmark_execute(&t, 1 << 20, 42).latency_secs;
        let b = p.benchmark_execute(&t, 1 << 20, 42).latency_secs;
        assert_eq!(a, b, "benchmark draws must be a pure function of the seed");
        // Distinct seeds: independent noise draws.
        let c = p.benchmark_execute(&t, 1 << 20, 43).latency_secs;
        assert_ne!(a, c, "distinct seeds must decorrelate repetitions");
        // Noise-free: the ground-truth latency regardless of seed.
        let q = SimPlatform::new(gpu_spec(), SimConfig::exact(), 11);
        let d = q.benchmark_execute(&t, 1 << 20, 42).latency_secs;
        let e = q.benchmark_execute(&t, 1 << 20, 7).latency_secs;
        assert!((d - e).abs() < 1e-12);
    }
}
