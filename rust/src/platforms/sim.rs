//! Simulated platforms — the substitution for the paper's physical
//! FPGA/GPU/CPU testbed (DESIGN.md §2).
//!
//! Each simulated platform has a hidden *ground-truth* latency model derived
//! from its Table II application performance: `L(n) = γ_true + β_true(task)·n`
//! with `β_true = flops_per_path / (app_GFLOPS·1e9) · hidden_factor`, where
//! the hidden factor (drawn once per platform, ±12%) models the gap between
//! published benchmark GFLOPS and this workload's achieved throughput.
//! Execution latency is further perturbed by multiplicative log-normal noise
//! (run-to-run variance). The coordinator never sees these internals — it
//! must *benchmark and fit* models exactly as the paper does, which is what
//! makes Fig. 2 (model error) and Fig. 3 (model vs measured) meaningful.
//!
//! Payoff statistics are produced by really simulating up to `stats_cap`
//! paths of the platform's assigned counter range with the native Threefry
//! pricer — unbiased prices without burning hours on 1e9-path tasks.

use std::sync::Mutex;

use crate::pricing::mc::{simulate, PayoffStats};
use crate::util::rng::Rng;
use crate::workload::option::OptionTask;

use super::spec::PlatformSpec;
use super::{ExecOutcome, Platform};

/// Tuning knobs for the simulation substrate.
#[derive(Debug, Clone)]
pub struct SimConfig {
    /// Log-sigma of the multiplicative latency noise (0 = deterministic).
    pub noise_sigma: f64,
    /// Max paths actually simulated per execute() call for statistics.
    pub stats_cap: u32,
    /// Spread of the hidden throughput factor (0.12 = ±12%).
    pub hidden_spread: f64,
    /// Optional failure injection: probability an execute() call fails.
    pub failure_rate: f64,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig { noise_sigma: 0.04, stats_cap: 1 << 15, hidden_spread: 0.12, failure_rate: 0.0 }
    }
}

impl SimConfig {
    /// Deterministic variant (exact models, no noise) — used by tests that
    /// need reproducible latencies.
    pub fn exact() -> SimConfig {
        SimConfig { noise_sigma: 0.0, hidden_spread: 0.0, ..SimConfig::default() }
    }
}

/// A simulated heterogeneous platform.
pub struct SimPlatform {
    spec: PlatformSpec,
    cfg: SimConfig,
    /// Hidden per-platform throughput factor (the benchmarker must discover
    /// its effect; it is not exposed).
    hidden_factor: f64,
    /// Hidden setup-time factor.
    gamma_true: f64,
    noise_rng: Mutex<Rng>,
}

impl SimPlatform {
    /// Build from a spec. `seed` individualises the hidden factors.
    pub fn new(spec: PlatformSpec, cfg: SimConfig, seed: u64) -> SimPlatform {
        let mut rng = Rng::new(seed ^ 0x5143_u64.wrapping_mul(0x9E37_79B9));
        let hidden_factor = 1.0 + cfg.hidden_spread * (2.0 * rng.f64() - 1.0);
        let gamma_true = spec.setup_secs * (1.0 + 0.2 * (2.0 * rng.f64() - 1.0));
        SimPlatform { spec, cfg, hidden_factor, gamma_true, noise_rng: Mutex::new(rng) }
    }

    /// Ground-truth β for a task on this platform, seconds per path.
    /// Private to the simulator — exposed only for white-box tests.
    pub(crate) fn beta_true(&self, task: &OptionTask) -> f64 {
        task.flops_per_path() / (self.spec.app_gflops * 1e9) * self.hidden_factor
    }

    #[cfg_attr(not(test), allow(dead_code))]
    pub(crate) fn gamma_true(&self) -> f64 {
        self.gamma_true
    }
}

impl Platform for SimPlatform {
    fn spec(&self) -> &PlatformSpec {
        &self.spec
    }

    fn execute(&self, task: &OptionTask, n: u64, seed: u32, offset: u32) -> ExecOutcome {
        let (noise, fail_draw) = {
            let mut rng = self.noise_rng.lock().unwrap();
            (rng.lognormal_noise(self.cfg.noise_sigma), rng.f64())
        };
        if fail_draw < self.cfg.failure_rate {
            return ExecOutcome {
                latency_secs: self.gamma_true, // failed after setup
                stats: None,
                error: Some(format!("{}: injected platform failure", self.spec.name)),
            };
        }
        let latency = (self.gamma_true + self.beta_true(task) * n as f64) * noise;
        // Real statistics on a capped prefix of this platform's counter
        // range. The cap is in *path-steps*, not paths: a 512-step Asian
        // slice simulates proportionally fewer paths than a terminal-value
        // European one, so per-slice statistics cost is uniform regardless
        // of payoff (§Perf: this turned the 16×128 execution from
        // step-count-bound to flat).
        let path_step_budget = self.cfg.stats_cap as u64 * 64;
        let cap = (path_step_budget / task.steps.max(1) as u64).max(64);
        let sim_n = n.min(cap).min(self.cfg.stats_cap as u64) as u32;
        let stats = simulate(task, seed, offset, sim_n);
        ExecOutcome { latency_secs: latency, stats: Some(stats), error: None }
    }

    fn benchmark_execute(&self, task: &OptionTask, n: u64, seed: u32) -> ExecOutcome {
        // Benchmarking only observes latency; skip the payoff simulation
        // (at paper scale the benchmarker makes ~30k calls).
        let (noise, fail_draw) = {
            let mut rng = self.noise_rng.lock().unwrap();
            (rng.lognormal_noise(self.cfg.noise_sigma), rng.f64())
        };
        let _ = seed;
        if fail_draw < self.cfg.failure_rate {
            return ExecOutcome {
                latency_secs: self.gamma_true,
                stats: None,
                error: Some(format!("{}: injected platform failure", self.spec.name)),
            };
        }
        let latency = (self.gamma_true + self.beta_true(task) * n as f64) * noise;
        ExecOutcome { latency_secs: latency, stats: None, error: None }
    }
}

/// Convenience: statistics when nothing is simulated.
pub fn empty_stats() -> PayoffStats {
    PayoffStats::default()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platforms::spec::paper_cluster;
    use crate::workload::{generate, GeneratorConfig};

    fn task() -> OptionTask {
        generate(&GeneratorConfig::small(1, 0.05, 1)).tasks[0].clone()
    }

    fn gpu_spec() -> PlatformSpec {
        paper_cluster().into_iter().find(|p| p.name == "gk104").unwrap()
    }

    #[test]
    fn latency_is_affine_in_n_without_noise() {
        let p = SimPlatform::new(gpu_spec(), SimConfig::exact(), 7);
        let t = task();
        let l1 = p.execute(&t, 1_000_000, 1, 0).latency_secs;
        let l2 = p.execute(&t, 2_000_000, 1, 0).latency_secs;
        let l3 = p.execute(&t, 3_000_000, 1, 0).latency_secs;
        // Equal increments: affine.
        assert!(((l2 - l1) - (l3 - l2)).abs() < 1e-9);
        assert!(l1 > p.gamma_true() - 1e-9);
    }

    #[test]
    fn noise_perturbs_but_preserves_scale() {
        let p = SimPlatform::new(gpu_spec(), SimConfig::default(), 7);
        let t = task();
        let ls: Vec<f64> = (0..20).map(|_| p.execute(&t, 1 << 20, 1, 0).latency_secs).collect();
        let mean = ls.iter().sum::<f64>() / ls.len() as f64;
        assert!(ls.iter().any(|l| (l - mean).abs() > 1e-12), "no noise observed");
        for l in &ls {
            assert!((l / mean - 1.0).abs() < 0.3, "noise too large: {l} vs {mean}");
        }
    }

    #[test]
    fn hidden_factor_differs_across_seeds() {
        let a = SimPlatform::new(gpu_spec(), SimConfig::default(), 1);
        let b = SimPlatform::new(gpu_spec(), SimConfig::default(), 2);
        let t = task();
        assert_ne!(a.beta_true(&t), b.beta_true(&t));
    }

    #[test]
    fn faster_device_has_smaller_beta() {
        let specs = paper_cluster();
        let gpu = SimPlatform::new(specs.iter().find(|s| s.name == "gk104").unwrap().clone(), SimConfig::exact(), 3);
        let cpu = SimPlatform::new(specs.iter().find(|s| s.name == "xeon-gce").unwrap().clone(), SimConfig::exact(), 3);
        let t = task();
        assert!(gpu.beta_true(&t) < cpu.beta_true(&t) / 10.0);
    }

    #[test]
    fn stats_are_unbiased_prices() {
        use crate::pricing::{blackscholes, combine};
        use crate::workload::option::Payoff;
        let p = SimPlatform::new(gpu_spec(), SimConfig::exact(), 5);
        let mut t = task();
        t.payoff = Payoff::European;
        let out = p.execute(&t, 1 << 20, 42, 0);
        let est = combine(&out.stats.unwrap(), t.discount());
        let bs = blackscholes::call(t.spot, t.strike, t.rate, t.sigma, t.maturity);
        assert!((est.price - bs).abs() < 5.0 * est.std_error + 0.05, "{est:?} vs {bs}");
    }

    #[test]
    fn stats_capped() {
        let cfg = SimConfig { stats_cap: 1024, ..SimConfig::exact() };
        let p = SimPlatform::new(gpu_spec(), cfg, 5);
        let out = p.execute(&task(), 1 << 22, 1, 0);
        assert_eq!(out.stats.unwrap().n, 1024);
    }

    #[test]
    fn failure_injection_fires() {
        let cfg = SimConfig { failure_rate: 1.0, ..SimConfig::exact() };
        let p = SimPlatform::new(gpu_spec(), cfg, 5);
        let out = p.execute(&task(), 1000, 1, 0);
        assert!(out.error.is_some());
        assert!(out.stats.is_none());
    }
}
