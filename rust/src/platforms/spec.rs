//! Platform specification database — Tables I and II of the paper, embedded
//! verbatim (April-2015 prices).
//!
//! The per-type offer data (rates, quanta, availability, spot terms) lives
//! in [`super::catalogue`]; this module keeps the instance-level
//! [`PlatformSpec`] plus the pinned paper-testbed instantiations.

use crate::api::error::{CloudshapesError, Result};
use crate::models::CostModel;

use super::catalogue::Catalogue;

/// Device category. Pricing correlates with performance *within* a category
/// but not across categories — the market inefficiency the paper exploits.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Category {
    Cpu,
    Gpu,
    Fpga,
}

impl Category {
    pub fn name(&self) -> &'static str {
        match self {
            Category::Cpu => "CPU",
            Category::Gpu => "GPU",
            Category::Fpga => "FPGA",
        }
    }
}

/// FPGA resource counts (Table II columns).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FpgaResources {
    pub luts_k: u32,
    pub flipflops_k: u32,
    pub brams: u32,
    pub dsps: u32,
}

/// One concrete platform instance of a rented cluster.
#[derive(Debug, Clone, PartialEq)]
pub struct PlatformSpec {
    /// Instance name, e.g. `stratix5-gsd8#3` (type name + instance suffix).
    pub name: String,
    /// IaaS provider, if offered by one today ("-" in Table II otherwise).
    pub provider: Option<&'static str>,
    pub device: &'static str,
    /// Programming standard (tool) the paper used on this device.
    pub standard: &'static str,
    pub category: Category,
    pub resources: Option<FpgaResources>,
    pub clock_ghz: f64,
    /// Application performance on the option-pricing benchmark, GFLOPS.
    pub app_gflops: f64,
    /// IaaS rate, $/hour (market rate or Eq. 2-derived for FPGAs; the spot
    /// rate for spot instances).
    pub rate_per_hour: f64,
    /// Billing time quantum, seconds.
    pub quantum_secs: f64,
    /// Nominal task-setup overhead γ, seconds (device configuration,
    /// communication; dominated by bitstream load on FPGAs).
    pub setup_secs: f64,
    /// Spot-instance preemption hazard, preemptions per hour of uptime
    /// (`None` = on-demand, never preempted).
    pub preemptible: Option<f64>,
}

/// The canonical instance name of the `k`-th of `count` rented instances of
/// a type: `type#k`, or the bare type name when a single instance is
/// rented. [`Catalogue::instantiate`] and `ModelSet::replicate` both name
/// through this, so searched compositions always match the names of the
/// cluster the user actually rents.
pub fn instance_name(type_name: &str, k: usize, count: usize) -> String {
    if count > 1 {
        format!("{type_name}#{k}")
    } else {
        type_name.to_string()
    }
}

impl PlatformSpec {
    /// The type name this instance was rented as (the part of `name` before
    /// the `#` instance suffix).
    pub fn type_name(&self) -> &str {
        self.name.split('#').next().unwrap_or(&self.name)
    }

    /// Validate the numeric terms; clusters and catalogues call this so bad
    /// user config surfaces as a typed error instead of a downstream panic.
    pub fn validate(&self) -> Result<()> {
        let bad = |what: &str, v: f64| {
            Err(CloudshapesError::config(format!(
                "platform '{}': {what} is {v}",
                self.name
            )))
        };
        if !(self.quantum_secs > 0.0 && self.quantum_secs.is_finite()) {
            return bad("billing quantum", self.quantum_secs);
        }
        if !(self.rate_per_hour >= 0.0 && self.rate_per_hour.is_finite()) {
            return bad("rate", self.rate_per_hour);
        }
        // Zero is allowed: the native platform measures latency instead of
        // deriving it from published GFLOPS.
        if !(self.app_gflops >= 0.0 && self.app_gflops.is_finite()) {
            return bad("app GFLOPS", self.app_gflops);
        }
        if !(self.setup_secs >= 0.0 && self.setup_secs.is_finite()) {
            return bad("setup time", self.setup_secs);
        }
        if let Some(h) = self.preemptible {
            if !(h > 0.0 && h.is_finite()) {
                return bad("preemption hazard", h);
            }
        }
        Ok(())
    }

    /// Billing terms. Specs are validated at cluster/catalogue construction,
    /// so this is infallible.
    pub fn cost_model(&self) -> CostModel {
        CostModel { quantum_secs: self.quantum_secs, rate_per_hour: self.rate_per_hour }
    }
}

/// The paper's 16-platform experimental cluster: the Table II testbed
/// composition of [`Catalogue::paper`] (4× Virtex-6, 8× GSD8, 1× GSD5,
/// 1× GPU, 2× CPU).
pub fn paper_cluster() -> Vec<PlatformSpec> {
    let c = Catalogue::paper();
    c.instantiate(&c.testbed_counts(), false).expect("paper testbed is instantiable")
}

/// A reduced heterogeneous cluster for fast tests: one of each category.
pub fn small_cluster() -> Vec<PlatformSpec> {
    let c = Catalogue::small();
    c.instantiate(&c.testbed_counts(), false).expect("small testbed is instantiable")
}

/// One row of Table I: IaaS offerings comparison.
#[derive(Debug, Clone)]
pub struct IaasOffering {
    pub provider: &'static str,
    pub instance_type: &'static str,
    pub instance_name: &'static str,
    pub quantum_minutes: u32,
    pub peak_gflops: f64,
    pub rate_per_hour: f64,
}

/// Table I, verbatim (April 2015).
pub fn table1_offerings() -> Vec<IaasOffering> {
    vec![
        IaasOffering {
            provider: "MA",
            instance_type: "CPU",
            instance_name: "A4",
            quantum_minutes: 1,
            peak_gflops: 416.0,
            rate_per_hour: 0.592,
        },
        IaasOffering {
            provider: "GCE",
            instance_type: "CPU",
            instance_name: "n1-highcpu-8",
            quantum_minutes: 10,
            peak_gflops: 400.0,
            rate_per_hour: 0.352,
        },
        IaasOffering {
            provider: "AWS",
            instance_type: "CPU",
            instance_name: "c3.4xlarge",
            quantum_minutes: 60,
            peak_gflops: 883.0,
            rate_per_hour: 0.924,
        },
        IaasOffering {
            provider: "AWS",
            instance_type: "GPU",
            instance_name: "g2.2xlarge",
            quantum_minutes: 60,
            peak_gflops: 2289.0,
            rate_per_hour: 0.650,
        },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cluster_has_sixteen_platforms() {
        let c = paper_cluster();
        assert_eq!(c.len(), 16);
        assert_eq!(c.iter().filter(|p| p.category == Category::Fpga).count(), 13);
        assert_eq!(c.iter().filter(|p| p.category == Category::Gpu).count(), 1);
        assert_eq!(c.iter().filter(|p| p.category == Category::Cpu).count(), 2);
    }

    #[test]
    fn names_are_unique() {
        let c = paper_cluster();
        let mut names: Vec<&str> = c.iter().map(|p| p.name.as_str()).collect();
        names.sort();
        names.dedup();
        assert_eq!(names.len(), 16);
    }

    #[test]
    fn type_names_strip_instance_suffixes() {
        let c = paper_cluster();
        assert_eq!(c[0].name, "virtex6#0");
        assert_eq!(c[0].type_name(), "virtex6");
        assert_eq!(c[13].name, "gk104");
        assert_eq!(c[13].type_name(), "gk104");
    }

    #[test]
    fn fpga_rates_follow_eq2() {
        // rate = 0.46 x RDP with count-weighted mean performance (tco.rs).
        use crate::models::tco::relative_device_performance;
        let pop = [(111.978, 4usize), (112.949, 8), (176.871, 1)];
        let c = paper_cluster();
        for p in c.iter().filter(|p| p.category == Category::Fpga) {
            let expect = 0.46 * relative_device_performance(p.app_gflops, &pop);
            assert!(
                (p.rate_per_hour - expect).abs() < 0.002,
                "{}: {} vs {}",
                p.name,
                p.rate_per_hour,
                expect
            );
        }
    }

    #[test]
    fn gpu_outperforms_cpus_per_dollar() {
        // The Table I/II observation motivating heterogeneity.
        let c = paper_cluster();
        let gpu = c.iter().find(|p| p.category == Category::Gpu).unwrap();
        for cpu in c.iter().filter(|p| p.category == Category::Cpu) {
            assert!(
                gpu.app_gflops / gpu.rate_per_hour > 10.0 * cpu.app_gflops / cpu.rate_per_hour
            );
        }
    }

    #[test]
    fn quanta_match_table1() {
        let c = paper_cluster();
        let ma = c.iter().find(|p| p.provider == Some("MA")).unwrap();
        let gce = c.iter().find(|p| p.provider == Some("GCE")).unwrap();
        let aws = c.iter().find(|p| p.provider == Some("AWS")).unwrap();
        assert_eq!(ma.quantum_secs, 60.0);
        assert_eq!(gce.quantum_secs, 600.0);
        assert_eq!(aws.quantum_secs, 3600.0);
    }

    #[test]
    fn table1_has_four_offerings() {
        assert_eq!(table1_offerings().len(), 4);
    }

    #[test]
    fn small_cluster_is_heterogeneous() {
        let s = small_cluster();
        assert_eq!(s.len(), 3);
        assert!(s.iter().any(|p| p.category == Category::Fpga));
        assert!(s.iter().any(|p| p.category == Category::Gpu));
        assert!(s.iter().any(|p| p.category == Category::Cpu));
    }

    #[test]
    fn bad_specs_fail_validation() {
        let mut s = small_cluster()[0].clone();
        s.quantum_secs = -1.0;
        assert_eq!(s.validate().unwrap_err().kind(), "config");
        let mut s = small_cluster()[0].clone();
        s.preemptible = Some(f64::NAN);
        assert!(s.validate().is_err());
        assert!(small_cluster()[0].validate().is_ok());
    }
}
