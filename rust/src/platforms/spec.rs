//! Platform specification database — Tables I and II of the paper, embedded
//! verbatim (April-2015 prices).

use crate::models::CostModel;

/// Device category. Pricing correlates with performance *within* a category
/// but not across categories — the market inefficiency the paper exploits.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Category {
    Cpu,
    Gpu,
    Fpga,
}

impl Category {
    pub fn name(&self) -> &'static str {
        match self {
            Category::Cpu => "CPU",
            Category::Gpu => "GPU",
            Category::Fpga => "FPGA",
        }
    }
}

/// FPGA resource counts (Table II columns).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FpgaResources {
    pub luts_k: u32,
    pub flipflops_k: u32,
    pub brams: u32,
    pub dsps: u32,
}

/// One concrete platform instance of the experimental cluster.
#[derive(Debug, Clone, PartialEq)]
pub struct PlatformSpec {
    /// Unique instance name, e.g. `virtex6-475t-2`.
    pub name: String,
    /// IaaS provider, if offered by one today ("-" in Table II otherwise).
    pub provider: Option<&'static str>,
    pub device: &'static str,
    /// Programming standard (tool) the paper used on this device.
    pub standard: &'static str,
    pub category: Category,
    pub resources: Option<FpgaResources>,
    pub clock_ghz: f64,
    /// Application performance on the option-pricing benchmark, GFLOPS.
    pub app_gflops: f64,
    /// IaaS rate, $/hour (market rate or Eq. 2-derived for FPGAs).
    pub rate_per_hour: f64,
    /// Billing time quantum, seconds.
    pub quantum_secs: f64,
    /// Nominal task-setup overhead γ, seconds (device configuration,
    /// communication; dominated by bitstream load on FPGAs).
    pub setup_secs: f64,
}

impl PlatformSpec {
    pub fn cost_model(&self) -> CostModel {
        CostModel::new(self.quantum_secs, self.rate_per_hour)
    }
}

/// One device-type row of Table II plus its instance count.
struct Row {
    count: usize,
    provider: Option<&'static str>,
    device: &'static str,
    short: &'static str,
    standard: &'static str,
    category: Category,
    resources: Option<FpgaResources>,
    clock_ghz: f64,
    app_gflops: f64,
    rate_per_hour: f64,
    quantum_secs: f64,
    setup_secs: f64,
}

fn table2_rows() -> Vec<Row> {
    vec![
        Row {
            count: 4,
            provider: None,
            device: "Xilinx Virtex 6 475T",
            short: "virtex6",
            standard: "OpenSPL (MaxCompiler 2013.2.2)",
            category: Category::Fpga,
            resources: Some(FpgaResources { luts_k: 298, flipflops_k: 595, brams: 1064, dsps: 2016 }),
            clock_ghz: 0.2,
            app_gflops: 111.978,
            rate_per_hour: 0.438,
            // Hypothetical FPGA IaaS billed hourly (DESIGN.md §2).
            quantum_secs: 3600.0,
            setup_secs: 40.0, // full-chip bitstream configuration
        },
        Row {
            count: 8,
            provider: None,
            device: "Altera Stratix V GSD8",
            short: "stratix5-gsd8",
            standard: "OpenSPL (MaxCompiler 2013.2.2)",
            category: Category::Fpga,
            resources: Some(FpgaResources { luts_k: 695, flipflops_k: 1050, brams: 2567, dsps: 3926 }),
            clock_ghz: 0.18,
            app_gflops: 112.949,
            rate_per_hour: 0.442,
            quantum_secs: 3600.0,
            setup_secs: 40.0,
        },
        Row {
            count: 1,
            provider: None,
            device: "Altera Stratix V GSD5",
            short: "stratix5-gsd5",
            standard: "OpenCL (Altera SDK 14.0)",
            category: Category::Fpga,
            resources: Some(FpgaResources { luts_k: 457, flipflops_k: 690, brams: 2014, dsps: 3180 }),
            clock_ghz: 0.25,
            app_gflops: 176.871,
            rate_per_hour: 0.692,
            quantum_secs: 3600.0,
            setup_secs: 25.0, // OpenCL runtime reconfiguration
        },
        Row {
            count: 1,
            provider: Some("AWS"),
            device: "Nvidia Grid GK104",
            short: "gk104",
            standard: "OpenCL (Nvidia SDK 6.0)",
            category: Category::Gpu,
            resources: None,
            clock_ghz: 0.8,
            app_gflops: 556.085,
            rate_per_hour: 0.650,
            quantum_secs: 3600.0, // AWS hourly billing (Table I)
            setup_secs: 2.0,      // context + JIT + transfer
        },
        Row {
            count: 1,
            provider: Some("MA"),
            device: "Intel Xeon E5-2660",
            short: "xeon-e5-2660",
            standard: "POSIX (GCC 4.8)",
            category: Category::Cpu,
            resources: None,
            clock_ghz: 2.2,
            app_gflops: 4.160,
            rate_per_hour: 0.480,
            quantum_secs: 60.0, // Azure 1-minute quantum (Table I)
            setup_secs: 0.5,
        },
        Row {
            count: 1,
            provider: Some("GCE"),
            device: "Intel Xeon",
            short: "xeon-gce",
            standard: "POSIX (GCC 4.8)",
            category: Category::Cpu,
            resources: None,
            clock_ghz: 2.0,
            app_gflops: 6.022,
            rate_per_hour: 0.352,
            quantum_secs: 600.0, // GCE 10-minute quantum (Table I)
            setup_secs: 0.5,
        },
    ]
}

/// The paper's 16-platform experimental cluster (Table II), with instance
/// counts expanded (4× Virtex-6, 8× GSD8, 1× GSD5, 1× GPU, 2× CPU).
pub fn paper_cluster() -> Vec<PlatformSpec> {
    let mut out = Vec::new();
    for row in table2_rows() {
        for i in 0..row.count {
            out.push(PlatformSpec {
                name: if row.count > 1 {
                    format!("{}-{}", row.short, i)
                } else {
                    row.short.to_string()
                },
                provider: row.provider,
                device: row.device,
                standard: row.standard,
                category: row.category,
                resources: row.resources,
                clock_ghz: row.clock_ghz,
                app_gflops: row.app_gflops,
                rate_per_hour: row.rate_per_hour,
                quantum_secs: row.quantum_secs,
                setup_secs: row.setup_secs,
            });
        }
    }
    out
}

/// A reduced heterogeneous cluster for fast tests: one of each category.
pub fn small_cluster() -> Vec<PlatformSpec> {
    let all = paper_cluster();
    let mut out = Vec::new();
    for cat in [Category::Fpga, Category::Gpu, Category::Cpu] {
        out.push(all.iter().find(|s| s.category == cat).unwrap().clone());
    }
    out
}

/// One row of Table I: IaaS offerings comparison.
#[derive(Debug, Clone)]
pub struct IaasOffering {
    pub provider: &'static str,
    pub instance_type: &'static str,
    pub instance_name: &'static str,
    pub quantum_minutes: u32,
    pub peak_gflops: f64,
    pub rate_per_hour: f64,
}

/// Table I, verbatim (April 2015).
pub fn table1_offerings() -> Vec<IaasOffering> {
    vec![
        IaasOffering {
            provider: "MA",
            instance_type: "CPU",
            instance_name: "A4",
            quantum_minutes: 1,
            peak_gflops: 416.0,
            rate_per_hour: 0.592,
        },
        IaasOffering {
            provider: "GCE",
            instance_type: "CPU",
            instance_name: "n1-highcpu-8",
            quantum_minutes: 10,
            peak_gflops: 400.0,
            rate_per_hour: 0.352,
        },
        IaasOffering {
            provider: "AWS",
            instance_type: "CPU",
            instance_name: "c3.4xlarge",
            quantum_minutes: 60,
            peak_gflops: 883.0,
            rate_per_hour: 0.924,
        },
        IaasOffering {
            provider: "AWS",
            instance_type: "GPU",
            instance_name: "g2.2xlarge",
            quantum_minutes: 60,
            peak_gflops: 2289.0,
            rate_per_hour: 0.650,
        },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cluster_has_sixteen_platforms() {
        let c = paper_cluster();
        assert_eq!(c.len(), 16);
        assert_eq!(c.iter().filter(|p| p.category == Category::Fpga).count(), 13);
        assert_eq!(c.iter().filter(|p| p.category == Category::Gpu).count(), 1);
        assert_eq!(c.iter().filter(|p| p.category == Category::Cpu).count(), 2);
    }

    #[test]
    fn names_are_unique() {
        let c = paper_cluster();
        let mut names: Vec<&str> = c.iter().map(|p| p.name.as_str()).collect();
        names.sort();
        names.dedup();
        assert_eq!(names.len(), 16);
    }

    #[test]
    fn fpga_rates_follow_eq2() {
        // rate = 0.46 x RDP with count-weighted mean performance (tco.rs).
        use crate::models::tco::relative_device_performance;
        let pop = [(111.978, 4usize), (112.949, 8), (176.871, 1)];
        let c = paper_cluster();
        for p in c.iter().filter(|p| p.category == Category::Fpga) {
            let expect = 0.46 * relative_device_performance(p.app_gflops, &pop);
            assert!(
                (p.rate_per_hour - expect).abs() < 0.002,
                "{}: {} vs {}",
                p.name,
                p.rate_per_hour,
                expect
            );
        }
    }

    #[test]
    fn gpu_outperforms_cpus_per_dollar() {
        // The Table I/II observation motivating heterogeneity.
        let c = paper_cluster();
        let gpu = c.iter().find(|p| p.category == Category::Gpu).unwrap();
        for cpu in c.iter().filter(|p| p.category == Category::Cpu) {
            assert!(
                gpu.app_gflops / gpu.rate_per_hour > 10.0 * cpu.app_gflops / cpu.rate_per_hour
            );
        }
    }

    #[test]
    fn quanta_match_table1() {
        let c = paper_cluster();
        let ma = c.iter().find(|p| p.provider == Some("MA")).unwrap();
        let gce = c.iter().find(|p| p.provider == Some("GCE")).unwrap();
        let aws = c.iter().find(|p| p.provider == Some("AWS")).unwrap();
        assert_eq!(ma.quantum_secs, 60.0);
        assert_eq!(gce.quantum_secs, 600.0);
        assert_eq!(aws.quantum_secs, 3600.0);
    }

    #[test]
    fn table1_has_four_offerings() {
        assert_eq!(table1_offerings().len(), 4);
    }

    #[test]
    fn small_cluster_is_heterogeneous() {
        let s = small_cluster();
        assert_eq!(s.len(), 3);
        assert!(s.iter().any(|p| p.category == Category::Fpga));
        assert!(s.iter().any(|p| p.category == Category::Gpu));
        assert!(s.iter().any(|p| p.category == Category::Cpu));
    }
}
