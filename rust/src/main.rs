//! `cloudshapes` binary — see `cloudshapes help`.

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    std::process::exit(cloudshapes::cli::main(&argv));
}
