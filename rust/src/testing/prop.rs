//! Minimal property-based testing harness (proptest is unavailable offline).
//!
//! Usage:
//! ```ignore
//! prop_check("allocation columns sum to 1", 200, |g| {
//!     let alloc = arbitrary_allocation(g);
//!     prop_assert(alloc.is_valid(), "invalid allocation")
//! });
//! ```
//!
//! Failures report the case index and the seed, so a failing case can be
//! replayed deterministically with [`prop_replay`]. There is no structural
//! shrinking; generators are encouraged to draw "size" parameters first so
//! low case indices are naturally small (the harness runs cases in
//! increasing-size order, which is shrinking-by-construction).

use crate::util::rng::Rng;

/// Generator handle passed to property bodies: an RNG plus a size hint that
/// grows with the case index (like proptest's sizing).
pub struct Gen {
    pub rng: Rng,
    /// Grows from 2 to ~64 across the run; generators should scale their
    /// collection sizes by it.
    pub size: usize,
}

impl Gen {
    /// A vector length scaled to the current size.
    pub fn len(&mut self, max: usize) -> usize {
        let cap = self.size.min(max).max(1);
        self.rng.range_u64(1, cap as u64) as usize
    }

    pub fn f64(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.range_f64(lo, hi)
    }

    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        self.rng.range_u64(lo as u64, hi as u64) as usize
    }

    pub fn bool(&mut self) -> bool {
        self.rng.bool()
    }

    /// Positive float log-uniform in [lo, hi] — good for spanning scales
    /// (latencies from ms to hours).
    pub fn log_uniform(&mut self, lo: f64, hi: f64) -> f64 {
        assert!(lo > 0.0 && hi > lo);
        (self.rng.range_f64(lo.ln(), hi.ln())).exp()
    }
}

/// A property failure message. Converts from anything printable so bodies
/// can use `?` on `format!(...)` strings and typed errors alike.
#[derive(Debug, Clone, PartialEq)]
pub struct PropError(pub String);

impl std::fmt::Display for PropError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        self.0.fmt(f)
    }
}

impl From<String> for PropError {
    fn from(s: String) -> Self {
        PropError(s)
    }
}

impl From<&str> for PropError {
    fn from(s: &str) -> Self {
        PropError(s.to_string())
    }
}

impl From<crate::api::error::CloudshapesError> for PropError {
    fn from(e: crate::api::error::CloudshapesError) -> Self {
        PropError(e.to_string())
    }
}

/// Outcome of one property evaluation.
pub type PropResult = Result<(), PropError>;

/// Assert inside a property body.
pub fn prop_assert(cond: bool, msg: &str) -> PropResult {
    if cond {
        Ok(())
    } else {
        Err(PropError(msg.to_string()))
    }
}

/// Assert two floats are within `tol` (absolute) of each other.
pub fn prop_close(a: f64, b: f64, tol: f64, msg: &str) -> PropResult {
    if (a - b).abs() <= tol {
        Ok(())
    } else {
        Err(PropError(format!("{msg}: |{a} - {b}| > {tol}")))
    }
}

/// Run `cases` random cases of `body`. Panics with seed + case on failure.
pub fn prop_check<F>(name: &str, cases: usize, body: F)
where
    F: Fn(&mut Gen) -> PropResult,
{
    prop_check_seeded(name, cases, 0xC10D_5EED, body)
}

/// As [`prop_check`] with an explicit base seed (for replay).
pub fn prop_check_seeded<F>(name: &str, cases: usize, base_seed: u64, body: F)
where
    F: Fn(&mut Gen) -> PropResult,
{
    for case in 0..cases {
        let seed = base_seed.wrapping_add(case as u64);
        // Size ramps from 2 up to 64 across the run.
        let size = 2 + (case * 62) / cases.max(1);
        let mut g = Gen { rng: Rng::new(seed), size };
        if let Err(msg) = body(&mut g) {
            panic!(
                "property '{name}' failed at case {case}/{cases} \
                 (replay: prop_replay(\"{name}\", {seed:#x}, {size})): {msg}"
            );
        }
    }
}

/// Re-run a single failing case by seed and size.
pub fn prop_replay<F>(name: &str, seed: u64, size: usize, body: F)
where
    F: Fn(&mut Gen) -> PropResult,
{
    let mut g = Gen { rng: Rng::new(seed), size };
    if let Err(msg) = body(&mut g) {
        panic!("property '{name}' failed on replay (seed {seed:#x}): {msg}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        prop_check("reverse-reverse is identity", 50, |g| {
            let n = g.len(32);
            let xs: Vec<u64> = (0..n).map(|_| g.rng.next_u64()).collect();
            let mut ys = xs.clone();
            ys.reverse();
            ys.reverse();
            prop_assert(xs == ys, "double reverse changed data")
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn failing_property_reports_seed() {
        prop_check("always fails", 10, |_| Err("nope".into()));
    }

    #[test]
    fn sizes_ramp_up() {
        let mut max_seen = 0usize;
        let seen = std::sync::Mutex::new(&mut max_seen);
        prop_check("size ramps", 100, |g| {
            let mut m = seen.lock().unwrap();
            **m = (**m).max(g.size);
            Ok(())
        });
        assert!(max_seen >= 60, "size never ramped: {max_seen}");
    }

    #[test]
    fn log_uniform_spans_scales() {
        let mut g = Gen { rng: Rng::new(1), size: 10 };
        let mut lo_seen = false;
        let mut hi_seen = false;
        for _ in 0..1000 {
            let x = g.log_uniform(1e-3, 1e3);
            assert!((1e-3..=1e3).contains(&x));
            if x < 0.1 {
                lo_seen = true;
            }
            if x > 10.0 {
                hi_seen = true;
            }
        }
        assert!(lo_seen && hi_seen);
    }

    #[test]
    fn prop_close_tolerance() {
        assert!(prop_close(1.0, 1.0 + 1e-9, 1e-6, "x").is_ok());
        assert!(prop_close(1.0, 2.0, 0.5, "x").is_err());
    }
}
