//! Test-support code compiled into the library so integration tests and
//! benches can share it (the mini property harness replaces `proptest`,
//! which is unavailable offline).

pub mod prop;
