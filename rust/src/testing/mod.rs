//! Test-support code compiled into the library so integration tests and
//! benches can share it (the mini property harness replaces `proptest`,
//! which is unavailable offline; the golden RNG vectors pin the kernel
//! contract shared with the Python side).

pub mod golden_rng;
pub mod prop;

pub use golden_rng::{GoldenRng, GOLDEN_RNG, GROUPS, Z_TOL};
