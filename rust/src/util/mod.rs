//! Support substrates: the offline build environment only provides the `xla`
//! crate, so JSON/TOML parsing, RNG, statistics, tables/plots and a thread
//! pool are implemented here (see DESIGN.md §2, substitution ledger).

pub mod json;
pub mod plot;
pub mod rng;
pub mod stats;
pub mod table;
pub mod threadpool;
pub mod toml;
