//! Virtual clock for the cluster simulator.
//!
//! The paper's workloads have makespans in the thousands of seconds (Table
//! IV: up to 8 760 s). Running Fig. 3's "execute every partition on the
//! cluster" experiment in real time is absurd; instead simulated platforms
//! *advance* a [`SimClock`] and only the native PJRT platform burns real
//! wall-clock. Each platform advances its own lane; the cluster-level
//! makespan is the max over lanes, matching the paper's definition
//! ("the latency of the platform that takes the longest").

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Monotone virtual clock measured in nanoseconds, shared between platform
/// worker threads. Cheap to clone (Arc inside).
#[derive(Debug, Clone, Default)]
pub struct SimClock {
    /// Global high-water mark across all lanes (the running makespan).
    high_water_ns: Arc<AtomicU64>,
}

impl SimClock {
    pub fn new() -> SimClock {
        SimClock::default()
    }

    /// Create an independent per-platform lane starting at t=0.
    pub fn lane(&self) -> SimLane {
        SimLane { clock: self.clone(), now_ns: 0 }
    }

    /// The furthest any lane has advanced — i.e. the simulated makespan.
    pub fn high_water_secs(&self) -> f64 {
        self.high_water_ns.load(Ordering::SeqCst) as f64 * 1e-9
    }

    fn observe(&self, t_ns: u64) {
        self.high_water_ns.fetch_max(t_ns, Ordering::SeqCst);
    }
}

/// One platform's private timeline.
#[derive(Debug, Clone)]
pub struct SimLane {
    clock: SimClock,
    now_ns: u64,
}

impl SimLane {
    /// Advance this lane by `secs` of simulated work.
    pub fn advance(&mut self, secs: f64) {
        assert!(secs >= 0.0 && secs.is_finite(), "advance({secs})");
        self.now_ns = self.now_ns.saturating_add((secs * 1e9).round() as u64);
        self.clock.observe(self.now_ns);
    }

    /// This lane's current simulated time in seconds.
    pub fn now_secs(&self) -> f64 {
        self.now_ns as f64 * 1e-9
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    #[test]
    fn lanes_are_independent() {
        let clock = SimClock::new();
        let mut a = clock.lane();
        let mut b = clock.lane();
        a.advance(5.0);
        b.advance(2.0);
        assert!((a.now_secs() - 5.0).abs() < 1e-9);
        assert!((b.now_secs() - 2.0).abs() < 1e-9);
    }

    #[test]
    fn high_water_is_max_over_lanes() {
        let clock = SimClock::new();
        let mut a = clock.lane();
        let mut b = clock.lane();
        a.advance(1.0);
        a.advance(2.0); // lane a at 3.0
        b.advance(2.5);
        assert!((clock.high_water_secs() - 3.0).abs() < 1e-9);
    }

    #[test]
    fn concurrent_advances_race_free() {
        let clock = SimClock::new();
        thread::scope(|s| {
            for i in 0..8u64 {
                let mut lane = clock.lane();
                s.spawn(move || {
                    for _ in 0..1000 {
                        lane.advance(0.001 * (i + 1) as f64);
                    }
                });
            }
        });
        // Longest lane: 1000 * 0.008 = 8.0 s
        assert!((clock.high_water_secs() - 8.0).abs() < 1e-6);
    }

    #[test]
    #[should_panic]
    fn negative_advance_panics() {
        let clock = SimClock::new();
        clock.lane().advance(-1.0);
    }
}
