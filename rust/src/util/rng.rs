//! Deterministic RNG substrate (the `rand` crate is unavailable offline).
//!
//! `SplitMix64` seeds `Xoshiro256++`, the workhorse generator used by the
//! simulated platforms (latency noise), workload generation and the property
//! test harness. `Threefry2x32` mirrors the L1 Pallas kernel's counter-based
//! generator bit-for-bit so the native rust Monte Carlo pricer
//! (`pricing::mc`) reproduces artifact results exactly.

/// SplitMix64 — used to expand a single `u64` seed into generator state.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// Xoshiro256++ — fast, high-quality, 2^256-period generator.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self { s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()] }
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = (self.s[0].wrapping_add(self.s[3]))
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Uniform integer in [0, n). Uses rejection to avoid modulo bias.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % n;
            }
        }
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    pub fn bool(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }

    /// Standard normal via Box-Muller (cosine branch).
    pub fn normal(&mut self) -> f64 {
        let u1 = (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
        let u1 = if u1 <= 0.0 { f64::MIN_POSITIVE } else { u1 };
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Log-normal with unit median and the given sigma of log — used for
    /// multiplicative latency noise on simulated platforms.
    pub fn lognormal_noise(&mut self, sigma: f64) -> f64 {
        (self.normal() * sigma).exp()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }
}

/// Threefry-2x32 (20 rounds) — bit-compatible with
/// `python/compile/kernels/rng.py::threefry2x32` (and hence with jax).
pub fn threefry2x32(k0: u32, k1: u32, x0: u32, x1: u32) -> (u32, u32) {
    const ROT: [u32; 8] = [13, 15, 26, 6, 17, 29, 16, 24];
    let ks = [k0, k1, k0 ^ k1 ^ 0x1BD1_1BDA];
    let (mut x0, mut x1) = (x0.wrapping_add(ks[0]), x1.wrapping_add(ks[1]));
    for block in 0..5u32 {
        for r in 0..4 {
            x0 = x0.wrapping_add(x1);
            x1 = x1.rotate_left(ROT[((4 * block + r) % 8) as usize]);
            x1 ^= x0;
        }
        x0 = x0.wrapping_add(ks[((block + 1) % 3) as usize]);
        x1 = x1.wrapping_add(ks[((block + 2) % 3) as usize]).wrapping_add(block + 1);
    }
    (x0, x1)
}

/// U(0,1] pair from one Threefry call — mirrors `rng.py::uniforms`.
pub fn threefry_uniforms(k0: u32, k1: u32, c0: u32, c1: u32) -> (f32, f32) {
    let (r0, r1) = threefry2x32(k0, k1, c0, c1);
    let scale = 1.0f32 / (1 << 24) as f32;
    let half = 0.5f32 / (1 << 24) as f32;
    ((r0 >> 8) as f32 * scale + half, (r1 >> 8) as f32 * scale + half)
}

/// One N(0,1) sample per counter pair — mirrors `rng.py::normal`.
pub fn threefry_normal(k0: u32, k1: u32, c0: u32, c1: u32) -> f32 {
    let (u0, u1) = threefry_uniforms(k0, k1, c0, c1);
    (-2.0 * u0.ln()).sqrt() * (2.0 * std::f32::consts::PI * u1).cos()
}

/// Lane-batched Threefry-2x32: `N` independent counter pairs under one key,
/// advanced through the 20 rounds together. Every arithmetic step is a
/// fixed-size-array loop over the lanes (no data dependence between lanes),
/// which is the shape the autovectoriser turns into SIMD `add`/`rot`/`xor`
/// chains — the generator dominates the Monte Carlo hot loop (paper
/// §IV.A.1), so this is where the batched kernel's speed comes from.
///
/// Each lane is bit-identical to [`threefry2x32`] on the same `(c0, c1)`
/// pair: integer ops are exact, so batching cannot change a single sample.
pub fn threefry2x32_lanes<const N: usize>(
    k0: u32,
    k1: u32,
    x0: [u32; N],
    x1: [u32; N],
) -> ([u32; N], [u32; N]) {
    const ROT: [u32; 8] = [13, 15, 26, 6, 17, 29, 16, 24];
    let ks = [k0, k1, k0 ^ k1 ^ 0x1BD1_1BDA];
    let (mut a, mut b) = (x0, x1);
    for i in 0..N {
        a[i] = a[i].wrapping_add(ks[0]);
        b[i] = b[i].wrapping_add(ks[1]);
    }
    for block in 0..5u32 {
        for r in 0..4 {
            let rot = ROT[((4 * block + r) % 8) as usize];
            for i in 0..N {
                a[i] = a[i].wrapping_add(b[i]);
                b[i] = b[i].rotate_left(rot);
                b[i] ^= a[i];
            }
        }
        let (ka, kb) = (ks[((block + 1) % 3) as usize], ks[((block + 2) % 3) as usize]);
        for i in 0..N {
            a[i] = a[i].wrapping_add(ka);
            b[i] = b[i].wrapping_add(kb).wrapping_add(block + 1);
        }
    }
    (a, b)
}

/// Lane-batched [`threefry_uniforms`]: `N` U(0,1] pairs from one batched
/// Threefry call, each lane bit-identical to the scalar mapping (the
/// top-24-bit scaling is a single exact multiply-add per word).
pub fn threefry_uniforms_lanes<const N: usize>(
    k0: u32,
    k1: u32,
    c0: [u32; N],
    c1: [u32; N],
) -> ([f32; N], [f32; N]) {
    let (r0, r1) = threefry2x32_lanes(k0, k1, c0, c1);
    let scale = 1.0f32 / (1 << 24) as f32;
    let half = 0.5f32 / (1 << 24) as f32;
    let (mut u0, mut u1) = ([0.0f32; N], [0.0f32; N]);
    for i in 0..N {
        u0[i] = (r0[i] >> 8) as f32 * scale + half;
        u1[i] = (r1[i] >> 8) as f32 * scale + half;
    }
    (u0, u1)
}

/// Lane-batched [`threefry_normal`]: one N(0,1) sample per lane. The
/// Box-Muller transform applies the same scalar f32 `ln`/`sqrt`/`cos`
/// operations per lane, so every sample is bit-identical to the scalar
/// path; the win is the vectorised Threefry chain feeding it.
pub fn threefry_normal_lanes<const N: usize>(
    k0: u32,
    k1: u32,
    c0: [u32; N],
    c1: [u32; N],
) -> [f32; N] {
    let (u0, u1) = threefry_uniforms_lanes(k0, k1, c0, c1);
    let mut z = [0.0f32; N];
    for i in 0..N {
        z[i] = (-2.0 * u0[i].ln()).sqrt() * (2.0 * std::f32::consts::PI * u1[i]).cos();
    }
    z
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_known_values() {
        // First outputs for seed 0 (reference values from the published
        // SplitMix64 algorithm).
        let mut sm = SplitMix64::new(0);
        assert_eq!(sm.next_u64(), 0xE220A8397B1DCDAF);
        assert_eq!(sm.next_u64(), 0x6E789E6AA1B965F4);
    }

    #[test]
    fn rng_is_deterministic() {
        let mut a = Rng::new(99);
        let mut b = Rng::new(99);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn rng_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Rng::new(3);
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            counts[r.below(7) as usize] += 1;
        }
        for c in counts {
            assert!((9_000..11_000).contains(&c), "{counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let (mut s, mut s2, mut s4) = (0.0, 0.0, 0.0);
        for _ in 0..n {
            let z = r.normal();
            s += z;
            s2 += z * z;
            s4 += z * z * z * z;
        }
        let nf = n as f64;
        assert!((s / nf).abs() < 0.01);
        assert!((s2 / nf - 1.0).abs() < 0.02);
        assert!((s4 / nf - 3.0).abs() < 0.1);
    }

    #[test]
    fn lognormal_noise_has_unit_median_scale() {
        let mut r = Rng::new(13);
        let mut above = 0;
        for _ in 0..10_000 {
            if r.lognormal_noise(0.05) > 1.0 {
                above += 1;
            }
        }
        assert!((4_500..5_500).contains(&above));
    }

    #[test]
    fn shuffle_is_a_permutation() {
        let mut r = Rng::new(5);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(xs, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn threefry_matches_python_kernel() {
        // The shared golden table (scripts/gen_rng_golden.py mirrors
        // python/compile/kernels/rng.py, which is itself tested bit-for-bit
        // against jax._src.prng.threefry_2x32). Output words and uniforms
        // are exact; normals are a float64 reference (libm `ln`/`cos` are
        // not bit-pinned across languages).
        use crate::testing::golden_rng::{GOLDEN_RNG, Z_TOL};
        for (i, g) in GOLDEN_RNG.iter().enumerate() {
            let (r0, r1) = threefry2x32(g.k0, g.k1, g.c0, g.c1);
            assert_eq!((r0, r1), (g.r0, g.r1), "row {i}: threefry words");
            let (u0, u1) = threefry_uniforms(g.k0, g.k1, g.c0, g.c1);
            assert_eq!(u0.to_bits(), g.u0_bits, "row {i}: u0");
            assert_eq!(u1.to_bits(), g.u1_bits, "row {i}: u1");
            let z = threefry_normal(g.k0, g.k1, g.c0, g.c1) as f64;
            assert!((z - g.z_ref).abs() < Z_TOL, "row {i}: z {z} vs {}", g.z_ref);
        }
    }

    #[test]
    fn threefry_lanes_match_golden_groups() {
        // Whole table groups pushed through the lane-batched generator at
        // once: the batch path must reproduce the pinned words exactly for
        // the lane patterns the kernels actually emit (consecutive path
        // counters, folded high offsets, the step word at its boundary).
        use crate::testing::golden_rng::{GOLDEN_RNG, GROUPS};
        for (name, start, end) in GROUPS {
            let rows = &GOLDEN_RNG[start..end];
            assert_eq!(rows.len() % 4, 0, "{name}: groups tile into 4-lane batches");
            for chunk in rows.chunks_exact(4) {
                let (k0, k1) = (chunk[0].k0, chunk[0].k1);
                let c0 = std::array::from_fn::<u32, 4, _>(|i| chunk[i].c0);
                let c1 = std::array::from_fn::<u32, 4, _>(|i| chunk[i].c1);
                let (r0, r1) = threefry2x32_lanes(k0, k1, c0, c1);
                let (u0, u1) = threefry_uniforms_lanes(k0, k1, c0, c1);
                for i in 0..4 {
                    assert_eq!((r0[i], r1[i]), (chunk[i].r0, chunk[i].r1), "{name} lane {i}");
                    assert_eq!(u0[i].to_bits(), chunk[i].u0_bits, "{name} lane {i}");
                    assert_eq!(u1[i].to_bits(), chunk[i].u1_bits, "{name} lane {i}");
                }
            }
        }
    }

    #[test]
    fn threefry_lanes_are_bitwise_scalar() {
        // Every lane width the batched kernel dispatches must agree with
        // the scalar generator bit-for-bit on arbitrary counters.
        fn check<const N: usize>(seed: u64) {
            let mut r = Rng::new(seed);
            for _ in 0..50 {
                let (k0, k1) = (r.next_u64() as u32, r.next_u64() as u32);
                let c0 = std::array::from_fn::<u32, N, _>(|_| r.next_u64() as u32);
                let c1 = std::array::from_fn::<u32, N, _>(|_| r.next_u64() as u32);
                let (b0, b1) = threefry2x32_lanes(k0, k1, c0, c1);
                let z = threefry_normal_lanes(k0, k1, c0, c1);
                for i in 0..N {
                    assert_eq!((b0[i], b1[i]), threefry2x32(k0, k1, c0[i], c1[i]));
                    assert_eq!(
                        z[i].to_bits(),
                        threefry_normal(k0, k1, c0[i], c1[i]).to_bits(),
                        "lane {i} of {N}"
                    );
                }
            }
        }
        check::<4>(1);
        check::<8>(2);
        check::<16>(3);
        check::<32>(4);
    }

    #[test]
    fn threefry_uniforms_in_open_interval() {
        for c in 0..1000u32 {
            let (u0, u1) = threefry_uniforms(1, 2, c, 0);
            assert!(u0 > 0.0 && u0 <= 1.0);
            assert!(u1 > 0.0 && u1 <= 1.0);
        }
    }

    #[test]
    fn threefry_normal_moments() {
        let n = 100_000u32;
        let (mut s, mut s2) = (0.0f64, 0.0f64);
        for c in 0..n {
            let z = threefry_normal(9, 9, c, 0) as f64;
            s += z;
            s2 += z * z;
        }
        assert!((s / n as f64).abs() < 0.02);
        assert!((s2 / n as f64 - 1.0).abs() < 0.02);
    }
}
