//! Fixed-size thread pool (tokio is unavailable offline; the coordinator's
//! concurrency needs — dispatch one worker per platform, join all — are
//! well served by scoped OS threads with a bounded pool).

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Decrements the pool's pending counter on drop — so a job that panics
/// still retires from `pending()` while its worker unwinds.
struct PendingGuard(Arc<AtomicUsize>);

impl Drop for PendingGuard {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::SeqCst);
    }
}

/// A fixed pool of worker threads consuming a shared job queue.
pub struct ThreadPool {
    sender: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
    queued: Arc<AtomicUsize>,
}

impl ThreadPool {
    /// Create a pool with `size` workers (clamped to at least 1).
    pub fn new(size: usize) -> ThreadPool {
        let size = size.max(1);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let queued = Arc::new(AtomicUsize::new(0));
        let workers = (0..size)
            .map(|i| {
                let rx = Arc::clone(&rx);
                let queued = Arc::clone(&queued);
                thread::Builder::new()
                    .name(format!("cloudshapes-worker-{i}"))
                    .spawn(move || loop {
                        let job = rx.lock().unwrap().recv();
                        match job {
                            Ok(job) => {
                                // A panicking job must neither kill this
                                // worker nor leak the pending counter: the
                                // guard decrements on unwind, catch_unwind
                                // keeps the worker alive for the next job.
                                let _guard = PendingGuard(Arc::clone(&queued));
                                let _ = catch_unwind(AssertUnwindSafe(job));
                            }
                            Err(_) => break, // channel closed: shut down
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { sender: Some(tx), workers, queued }
    }

    /// Submit a job. Panics if the pool is shutting down.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.queued.fetch_add(1, Ordering::SeqCst);
        self.sender
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(f))
            .expect("worker channel closed");
    }

    /// Number of jobs submitted but not yet finished.
    pub fn pending(&self) -> usize {
        self.queued.load(Ordering::SeqCst)
    }

    pub fn workers(&self) -> usize {
        self.workers.len()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.sender.take()); // close the queue
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Apply `f` to every item on up to `threads` OS threads and collect results
/// in input order. Panics in `f` propagate (poisoned results are re-panicked).
pub fn parallel_map<T, R, F>(items: Vec<T>, threads: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let threads = threads.max(1).min(n);
    let results: Vec<Mutex<Option<R>>> = (0..n).map(|_| Mutex::new(None)).collect();
    let work: Mutex<std::vec::IntoIter<(usize, T)>> =
        Mutex::new(items.into_iter().enumerate().collect::<Vec<_>>().into_iter());

    thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let next = work.lock().unwrap().next();
                match next {
                    Some((i, item)) => {
                        let r = f(item);
                        *results[i].lock().unwrap() = Some(r);
                    }
                    None => break,
                }
            });
        }
    });

    results
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("worker panicked before producing a result"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;
    use std::time::Duration;

    #[test]
    fn pool_runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // join
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn pool_is_actually_concurrent() {
        let pool = ThreadPool::new(4);
        let started = Arc::new(AtomicU64::new(0));
        for _ in 0..4 {
            let s = Arc::clone(&started);
            pool.execute(move || {
                s.fetch_add(1, Ordering::SeqCst);
                // Hold the worker so concurrency is observable.
                thread::sleep(Duration::from_millis(100));
            });
        }
        thread::sleep(Duration::from_millis(60));
        assert_eq!(started.load(Ordering::SeqCst), 4, "4 workers should all have started");
        drop(pool);
    }

    #[test]
    fn zero_size_clamps_to_one() {
        let pool = ThreadPool::new(0);
        assert_eq!(pool.workers(), 1);
    }

    #[test]
    fn panicking_job_neither_leaks_pending_nor_kills_worker() {
        // One worker, so the follow-up job can only run if the worker
        // survived the panic.
        let pool = ThreadPool::new(1);
        pool.execute(|| panic!("boom (expected panic in test)"));
        let done = Arc::new(AtomicU64::new(0));
        let d = Arc::clone(&done);
        pool.execute(move || {
            d.fetch_add(1, Ordering::SeqCst);
        });
        for _ in 0..400 {
            if pool.pending() == 0 {
                break;
            }
            thread::sleep(Duration::from_millis(5));
        }
        assert_eq!(pool.pending(), 0, "panicking job leaked the pending counter");
        assert_eq!(done.load(Ordering::SeqCst), 1, "worker died after a panicking job");
        drop(pool);
    }

    #[test]
    fn parallel_map_preserves_order() {
        let out = parallel_map((0..100).collect(), 8, |x: i32| x * 2);
        assert_eq!(out, (0..100).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn parallel_map_empty() {
        let out: Vec<i32> = parallel_map(Vec::<i32>::new(), 4, |x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn parallel_map_single_thread() {
        let out = parallel_map(vec![1, 2, 3], 1, |x| x + 1);
        assert_eq!(out, vec![2, 3, 4]);
    }
}
