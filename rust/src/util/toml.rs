//! Minimal TOML-subset parser for the experiment config system.
//!
//! Supports the subset the `configs/*.toml` files use: top-level key/values,
//! `[table]` and `[[array-of-tables]]` headers, dotted keys inside headers,
//! strings, integers, floats, booleans, and homogeneous inline arrays.
//! Comments (`#`) and blank lines are skipped. Values parse into
//! [`crate::util::json::Json`] so the config layer has a single value model.

use std::collections::BTreeMap;

use super::json::Json;

/// Parse error with 1-based line number.
#[derive(Debug, Clone, PartialEq)]
pub struct TomlError {
    pub msg: String,
    pub line: usize,
}

impl std::fmt::Display for TomlError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "toml error at line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for TomlError {}

/// Parse a TOML document into a JSON object tree.
pub fn parse(text: &str) -> Result<Json, TomlError> {
    let mut root = BTreeMap::new();
    // Path of the currently-open table header.
    let mut current: Vec<String> = Vec::new();

    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        let err = |msg: &str| TomlError { msg: msg.to_string(), line: lineno + 1 };
        // Helpers report line 0; pin the real line number here.
        let at = |e: TomlError| TomlError { line: lineno + 1, ..e };

        if let Some(inner) = line.strip_prefix("[[").and_then(|s| s.strip_suffix("]]")) {
            let path = split_key(inner.trim());
            push_array_table(&mut root, &path).map_err(at)?;
            current = path;
        } else if let Some(inner) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
            let path = split_key(inner.trim());
            open_table(&mut root, &path).map_err(at)?;
            current = path;
        } else if let Some(eq) = find_unquoted(line, '=') {
            let key = line[..eq].trim();
            if key.is_empty() {
                return Err(err("empty key"));
            }
            let val = parse_value(line[eq + 1..].trim()).map_err(at)?;
            let mut path = current.clone();
            path.extend(split_key(key));
            insert(&mut root, &path, val).map_err(at)?;
        } else {
            return Err(err("expected key = value or [table]"));
        }
    }
    Ok(Json::Obj(root))
}

fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn find_unquoted(line: &str, target: char) -> Option<usize> {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            c if c == target && !in_str => return Some(i),
            _ => {}
        }
    }
    None
}

fn split_key(key: &str) -> Vec<String> {
    key.split('.').map(|s| s.trim().trim_matches('"').to_string()).collect()
}

/// A helper-level error (line number pinned by the caller).
fn terr(msg: String) -> TomlError {
    TomlError { msg, line: 0 }
}

/// Navigate to (creating) the table at `path`; error on type conflicts.
fn navigate<'a>(
    root: &'a mut BTreeMap<String, Json>,
    path: &[String],
) -> Result<&'a mut BTreeMap<String, Json>, TomlError> {
    let mut cur = root;
    for part in path {
        let entry = cur
            .entry(part.clone())
            .or_insert_with(|| Json::Obj(BTreeMap::new()));
        cur = match entry {
            Json::Obj(o) => o,
            Json::Arr(a) => match a.last_mut() {
                Some(Json::Obj(o)) => o,
                _ => return Err(terr(format!("'{part}' is not a table"))),
            },
            _ => return Err(terr(format!("'{part}' is not a table"))),
        };
    }
    Ok(cur)
}

fn open_table(root: &mut BTreeMap<String, Json>, path: &[String]) -> Result<(), TomlError> {
    navigate(root, path).map(|_| ())
}

fn push_array_table(root: &mut BTreeMap<String, Json>, path: &[String]) -> Result<(), TomlError> {
    let (last, parents) = path.split_last().ok_or_else(|| terr("empty table name".into()))?;
    let parent = navigate(root, parents)?;
    let entry = parent
        .entry(last.clone())
        .or_insert_with(|| Json::Arr(Vec::new()));
    match entry {
        Json::Arr(a) => {
            a.push(Json::Obj(BTreeMap::new()));
            Ok(())
        }
        _ => Err(terr(format!("'{last}' is not an array of tables"))),
    }
}

fn insert(root: &mut BTreeMap<String, Json>, path: &[String], val: Json) -> Result<(), TomlError> {
    let (last, parents) = path.split_last().ok_or_else(|| terr("empty key".into()))?;
    let parent = navigate(root, parents)?;
    if parent.contains_key(last) {
        return Err(terr(format!("duplicate key '{last}'")));
    }
    parent.insert(last.clone(), val);
    Ok(())
}

fn parse_value(text: &str) -> Result<Json, TomlError> {
    if text.is_empty() {
        return Err(terr("empty value".into()));
    }
    if let Some(s) = text.strip_prefix('"') {
        let s = s.strip_suffix('"').ok_or_else(|| terr("unterminated string".into()))?;
        // Reuse the JSON string unescaper.
        return Json::parse(&format!("\"{s}\"")).map_err(|e| terr(e.msg));
    }
    if text == "true" {
        return Ok(Json::Bool(true));
    }
    if text == "false" {
        return Ok(Json::Bool(false));
    }
    if let Some(inner) = text.strip_prefix('[') {
        let inner = inner
            .strip_suffix(']')
            .ok_or_else(|| terr("unterminated array".into()))?
            .trim();
        if inner.is_empty() {
            return Ok(Json::Arr(vec![]));
        }
        let mut items = Vec::new();
        for part in split_top_level(inner) {
            items.push(parse_value(part.trim())?);
        }
        return Ok(Json::Arr(items));
    }
    // Numbers: TOML allows underscores.
    let cleaned: String = text.chars().filter(|c| *c != '_').collect();
    cleaned
        .parse::<f64>()
        .map(Json::Num)
        .map_err(|_| terr(format!("cannot parse value '{text}'")))
}

/// Split on commas that are not inside strings or nested brackets.
fn split_top_level(s: &str) -> Vec<&str> {
    let mut out = Vec::new();
    let (mut depth, mut in_str, mut start) = (0usize, false, 0usize);
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '[' if !in_str => depth += 1,
            ']' if !in_str => depth = depth.saturating_sub(1),
            ',' if !in_str && depth == 0 => {
                out.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    out.push(&s[start..]);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars_and_tables() {
        let doc = r#"
            # experiment config
            name = "fig1"
            seed = 42
            scale = 1.5
            verbose = true

            [sweep]
            points = 11
        "#;
        let v = parse(doc).unwrap();
        assert_eq!(v.get("name").unwrap().as_str(), Some("fig1"));
        assert_eq!(v.get("seed").unwrap().as_u64(), Some(42));
        assert_eq!(v.get("scale").unwrap().as_f64(), Some(1.5));
        assert_eq!(v.get("verbose").unwrap().as_bool(), Some(true));
        assert_eq!(v.get("sweep").unwrap().get("points").unwrap().as_u64(), Some(11));
    }

    #[test]
    fn parses_arrays() {
        let v = parse("xs = [1, 2, 3]\nnames = [\"a\", \"b\"]\nnested = [[1,2],[3]]").unwrap();
        assert_eq!(v.get("xs").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("names").unwrap().as_arr().unwrap()[1].as_str(), Some("b"));
        assert_eq!(v.get("nested").unwrap().as_arr().unwrap()[0].as_arr().unwrap().len(), 2);
    }

    #[test]
    fn parses_array_of_tables() {
        let doc = r#"
            [[platform]]
            name = "cpu"
            rate = 0.48

            [[platform]]
            name = "gpu"
            rate = 0.65
        "#;
        let v = parse(doc).unwrap();
        let ps = v.get("platform").unwrap().as_arr().unwrap();
        assert_eq!(ps.len(), 2);
        assert_eq!(ps[0].get("name").unwrap().as_str(), Some("cpu"));
        assert_eq!(ps[1].get("rate").unwrap().as_f64(), Some(0.65));
    }

    #[test]
    fn keys_scoped_to_latest_array_table() {
        let doc = "[[p]]\nx = 1\n[[p]]\nx = 2";
        let v = parse(doc).unwrap();
        let ps = v.get("p").unwrap().as_arr().unwrap();
        assert_eq!(ps[0].get("x").unwrap().as_u64(), Some(1));
        assert_eq!(ps[1].get("x").unwrap().as_u64(), Some(2));
    }

    #[test]
    fn dotted_keys() {
        let v = parse("a.b.c = 3").unwrap();
        assert_eq!(v.get("a").unwrap().get("b").unwrap().get("c").unwrap().as_u64(), Some(3));
    }

    #[test]
    fn comments_and_underscore_numbers() {
        let v = parse("n = 1_000_000 # one million").unwrap();
        assert_eq!(v.get("n").unwrap().as_u64(), Some(1_000_000));
    }

    #[test]
    fn hash_inside_string_is_not_comment() {
        let v = parse("s = \"a # b\"").unwrap();
        assert_eq!(v.get("s").unwrap().as_str(), Some("a # b"));
    }

    #[test]
    fn duplicate_key_rejected() {
        let e = parse("a = 1\na = 2").unwrap_err();
        assert!(e.msg.contains("duplicate"));
        assert_eq!(e.line, 2);
    }

    #[test]
    fn garbage_rejected() {
        assert!(parse("just words").is_err());
        assert!(parse("x = ").is_err());
        assert!(parse("x = [1, ").is_err());
        assert!(parse("[a\nx=1").is_err());
    }
}
