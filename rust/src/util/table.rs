//! ASCII table rendering for the report generators (Tables I–IV).

/// Column alignment.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Align {
    Left,
    Right,
}

/// A simple text table builder.
#[derive(Debug, Clone)]
pub struct Table {
    headers: Vec<String>,
    aligns: Vec<Align>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            aligns: headers.iter().map(|_| Align::Right).collect(),
            rows: Vec::new(),
        }
    }

    /// Set per-column alignment (defaults to right-aligned everywhere).
    pub fn aligns(mut self, aligns: &[Align]) -> Table {
        assert_eq!(aligns.len(), self.headers.len());
        self.aligns = aligns.to_vec();
        self
    }

    pub fn row<S: ToString>(&mut self, cells: &[S]) -> &mut Table {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells.iter().map(|c| c.to_string()).collect());
        self
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    /// Render with a header rule, e.g.
    /// ```text
    /// name  | rate
    /// ------+------
    /// cpu   | 0.48
    /// ```
    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.chars().count());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize], aligns: &[Align]| -> String {
            let mut line = String::new();
            for i in 0..ncol {
                if i > 0 {
                    line.push_str(" | ");
                }
                let pad = widths[i] - cells[i].chars().count();
                match aligns[i] {
                    Align::Left => {
                        line.push_str(&cells[i]);
                        line.push_str(&" ".repeat(pad));
                    }
                    Align::Right => {
                        line.push_str(&" ".repeat(pad));
                        line.push_str(&cells[i]);
                    }
                }
            }
            line.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.headers, &widths, &self.aligns));
        out.push('\n');
        let rule: Vec<String> = widths.iter().map(|w| "-".repeat(*w)).collect();
        out.push_str(&rule.join("-+-"));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths, &self.aligns));
            out.push('\n');
        }
        out
    }

    /// Render as CSV (RFC-4180-style quoting where needed).
    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        let esc = |s: &str| -> String {
            if s.contains(',') || s.contains('"') || s.contains('\n') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        out.push_str(&self.headers.iter().map(|h| esc(h)).collect::<Vec<_>>().join(","));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }
}

/// Format a float with `prec` decimals, trimming to at most 12 chars.
pub fn fnum(x: f64, prec: usize) -> String {
    format!("{:.*}", prec, x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(&["name", "rate"]).aligns(&[Align::Left, Align::Right]);
        t.row(&["cpu", "0.48"]);
        t.row(&["fpga-big", "0.442"]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].starts_with("name"));
        assert!(lines[1].contains("-+-"));
        assert!(lines[2].starts_with("cpu"));
        // right-aligned rate column
        assert!(lines[2].ends_with("0.48"));
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn rejects_ragged_rows() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["only-one"]);
    }

    #[test]
    fn csv_quotes_when_needed() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["x,y", "he said \"hi\""]);
        let csv = t.to_csv();
        assert!(csv.contains("\"x,y\""));
        assert!(csv.contains("\"he said \"\"hi\"\"\""));
    }

    #[test]
    fn unicode_width_by_chars() {
        let mut t = Table::new(&["sym"]);
        t.row(&["ρπ"]);
        t.row(&["abc"]);
        let s = t.render();
        assert!(s.lines().count() == 4);
    }

    #[test]
    fn fnum_formats() {
        assert_eq!(fnum(1.23456, 3), "1.235");
        assert_eq!(fnum(2.0, 1), "2.0");
    }
}
