//! ASCII plotting + CSV series emission for the figure reproductions.
//!
//! The paper's figures are scatter/line plots (latency vs cost trade-offs,
//! prediction-error curves). We emit both a terminal-readable ASCII render
//! and a CSV that external tooling can re-plot exactly.

/// One named series of (x, y) points.
#[derive(Debug, Clone)]
pub struct Series {
    pub name: String,
    pub points: Vec<(f64, f64)>,
    /// Glyph used in the ASCII render.
    pub glyph: char,
}

impl Series {
    pub fn new(name: &str, glyph: char) -> Series {
        Series { name: name.to_string(), points: Vec::new(), glyph }
    }

    pub fn push(&mut self, x: f64, y: f64) {
        self.points.push((x, y));
    }
}

/// A 2-D scatter plot with multiple series.
#[derive(Debug, Clone)]
pub struct Plot {
    pub title: String,
    pub x_label: String,
    pub y_label: String,
    pub series: Vec<Series>,
    pub width: usize,
    pub height: usize,
}

impl Plot {
    pub fn new(title: &str, x_label: &str, y_label: &str) -> Plot {
        Plot {
            title: title.to_string(),
            x_label: x_label.to_string(),
            y_label: y_label.to_string(),
            series: Vec::new(),
            width: 72,
            height: 22,
        }
    }

    pub fn add(&mut self, s: Series) -> &mut Plot {
        self.series.push(s);
        self
    }

    fn bounds(&self) -> Option<(f64, f64, f64, f64)> {
        let pts: Vec<(f64, f64)> = self.series.iter().flat_map(|s| s.points.clone()).collect();
        if pts.is_empty() {
            return None;
        }
        let (mut x0, mut x1, mut y0, mut y1) =
            (f64::INFINITY, f64::NEG_INFINITY, f64::INFINITY, f64::NEG_INFINITY);
        for (x, y) in pts {
            x0 = x0.min(x);
            x1 = x1.max(x);
            y0 = y0.min(y);
            y1 = y1.max(y);
        }
        // Avoid zero-width ranges.
        if x0 == x1 {
            x1 = x0 + 1.0;
        }
        if y0 == y1 {
            y1 = y0 + 1.0;
        }
        Some((x0, x1, y0, y1))
    }

    /// Render as ASCII. Later series overwrite earlier ones on collisions.
    pub fn render(&self) -> String {
        let Some((x0, x1, y0, y1)) = self.bounds() else {
            return format!("{} (no data)\n", self.title);
        };
        let (w, h) = (self.width, self.height);
        let mut grid = vec![vec![' '; w]; h];
        for s in &self.series {
            for &(x, y) in &s.points {
                let cx = (((x - x0) / (x1 - x0)) * (w - 1) as f64).round() as usize;
                let cy = (((y - y0) / (y1 - y0)) * (h - 1) as f64).round() as usize;
                grid[h - 1 - cy][cx] = s.glyph;
            }
        }
        let mut out = String::new();
        out.push_str(&format!("{}\n", self.title));
        let legend: Vec<String> =
            self.series.iter().map(|s| format!("{}={}", s.glyph, s.name)).collect();
        out.push_str(&format!("  [{}]   y: {}\n", legend.join("  "), self.y_label));
        out.push_str(&format!("  {:>10.3} ┐\n", y1));
        for row in grid {
            out.push_str("             │");
            out.push_str(&row.iter().collect::<String>());
            out.push('\n');
        }
        out.push_str(&format!("  {:>10.3} └{}\n", y0, "─".repeat(w)));
        out.push_str(&format!(
            "  x: {}   {:.3} … {:.3}\n",
            self.x_label, x0, x1
        ));
        out
    }

    /// CSV with one `(series, x, y)` row per point.
    pub fn to_csv(&self) -> String {
        let mut out = String::from("series,x,y\n");
        for s in &self.series {
            for &(x, y) in &s.points {
                out.push_str(&format!("{},{},{}\n", s.name, x, y));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_plot() -> Plot {
        let mut p = Plot::new("t", "cost", "latency");
        let mut a = Series::new("ilp", 'o');
        a.push(1.0, 10.0);
        a.push(2.0, 5.0);
        let mut b = Series::new("heuristic", 'x');
        b.push(1.5, 12.0);
        p.add(a);
        p.add(b);
        p
    }

    #[test]
    fn renders_with_legend_and_bounds() {
        let s = sample_plot().render();
        assert!(s.contains("o=ilp"));
        assert!(s.contains("x=heuristic"));
        assert!(s.contains("12.000"));
        assert!(s.matches('o').count() >= 2);
        assert!(s.contains('x'));
    }

    #[test]
    fn empty_plot_is_graceful() {
        let p = Plot::new("empty", "x", "y");
        assert!(p.render().contains("no data"));
    }

    #[test]
    fn single_point_does_not_divide_by_zero() {
        let mut p = Plot::new("one", "x", "y");
        let mut s = Series::new("s", '*');
        s.push(3.0, 4.0);
        p.add(s);
        let r = p.render();
        assert!(r.contains('*'));
    }

    #[test]
    fn csv_lists_all_points() {
        let csv = sample_plot().to_csv();
        assert_eq!(csv.lines().count(), 4); // header + 3 points
        assert!(csv.contains("ilp,1,10"));
        assert!(csv.contains("heuristic,1.5,12"));
    }
}
