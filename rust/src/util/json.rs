//! Minimal JSON value model, parser and writer.
//!
//! The offline build environment only caches the `xla` crate's dependency
//! closure, so `serde_json` is unavailable; this module covers everything the
//! project needs: the artifact `manifest.json`, report emission, and the
//! `serve` wire protocol. It is a strict subset of RFC 8259 (no surrogate
//! pair decoding beyond the BMP escape form, numbers parsed as f64/i64).

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value. Objects use a `BTreeMap` so serialization is
/// deterministic (useful for golden tests).
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    /// All JSON numbers; integers are preserved exactly up to 2^53.
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset for diagnostics.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub msg: String,
    pub offset: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // -- typed accessors ----------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 && *n <= 2f64.powi(53) => Some(*n as u64),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// `obj["key"]` lookup that flows through `Option`.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// Serialize compactly (no whitespace).
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Serialize with 2-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => write_num(out, *n),
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                if !a.is_empty() {
                    newline(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !o.is_empty() {
                    newline(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_num(out: &mut String, n: f64) {
    if !n.is_finite() {
        // NaN/Inf have no JSON representation (RFC 8259 §6); emit null
        // rather than corrupt the document. Metric snapshots guard their
        // inputs, but a defence here keeps every writer safe.
        out.push_str("null");
    } else if n.fract() == 0.0 && n.abs() < 2f64.powi(53) {
        out.push_str(&format!("{}", n as i64));
    } else {
        out.push_str(&format!("{}", n));
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), offset: self.i }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{}'", s)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected value")),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let val = self.value()?;
            out.insert(key, val);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek().ok_or_else(|| self.err("unterminated string"))?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.i += 4;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                _ => {
                    // Copy the full UTF-8 sequence this byte starts.
                    let start = self.i - 1;
                    let len = utf8_len(c);
                    self.i = start + len;
                    if self.i > self.b.len() {
                        return Err(self.err("truncated utf-8"));
                    }
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

// Convenience constructors used by the report/serve code.
impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Self {
        Json::Str(s)
    }
}
impl From<f64> for Json {
    fn from(n: f64) -> Self {
        Json::Num(n)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Self {
        Json::Num(n as f64)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Self {
        Json::Bool(b)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Self {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

/// Build a `Json::Obj` from `(key, value)` pairs.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[0].as_f64(), Some(1.0));
        assert_eq!(arr[2].get("b"), Some(&Json::Null));
    }

    #[test]
    fn parses_escapes() {
        let v = Json::parse(r#""a\n\t\"\\ A""#).unwrap();
        assert_eq!(v.as_str(), Some("a\n\t\"\\ A"));
    }

    #[test]
    fn parses_unicode_passthrough() {
        let v = Json::parse("\"héllo ± ≤\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo ± ≤"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let src = r#"{"arr":[1,2.5,"s"],"b":true,"n":null}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(Json::parse(&v.to_string_compact()).unwrap(), v);
        assert_eq!(Json::parse(&v.to_string_pretty()).unwrap(), v);
    }

    #[test]
    fn integers_serialize_without_point() {
        assert_eq!(Json::Num(3.0).to_string_compact(), "3");
        assert_eq!(Json::Num(3.5).to_string_compact(), "3.5");
    }

    #[test]
    fn u64_accessor_bounds() {
        assert_eq!(Json::Num(5.0).as_u64(), Some(5));
        assert_eq!(Json::Num(-5.0).as_u64(), None);
        assert_eq!(Json::Num(5.5).as_u64(), None);
    }

    #[test]
    fn obj_builder() {
        let v = obj(vec![("x", 1.0.into()), ("y", "z".into())]);
        assert_eq!(v.get("x").unwrap().as_f64(), Some(1.0));
        assert_eq!(v.get("y").unwrap().as_str(), Some("z"));
    }

    #[test]
    fn error_offsets_are_reported() {
        let e = Json::parse("[1, x]").unwrap_err();
        assert_eq!(e.offset, 4);
    }

    #[test]
    fn non_finite_numbers_serialize_as_null() {
        // The metrics snapshot path must never emit `NaN`/`inf` tokens —
        // they are not JSON. Non-finite values degrade to null.
        assert_eq!(Json::Num(f64::NAN).to_string_compact(), "null");
        assert_eq!(Json::Num(f64::INFINITY).to_string_compact(), "null");
        assert_eq!(Json::Num(f64::NEG_INFINITY).to_string_compact(), "null");
        let v = obj(vec![("x", Json::Num(f64::NAN))]);
        assert_eq!(Json::parse(&v.to_string_compact()).unwrap().get("x"), Some(&Json::Null));
    }

    #[test]
    fn nan_and_infinity_tokens_are_rejected_on_parse() {
        assert!(Json::parse("NaN").is_err());
        assert!(Json::parse("Infinity").is_err());
        assert!(Json::parse("-Infinity").is_err());
        assert!(Json::parse("{\"x\": NaN}").is_err());
    }

    #[test]
    fn u64_counters_above_2_pow_53_stay_valid_json() {
        // Counter cells are u64; above 2^53 the f64 carrier loses exactness
        // but serialization must stay a plain decimal JSON number that
        // round-trips through the parser.
        let big = (1u64 << 60) as f64;
        let s = Json::Num(big).to_string_compact();
        assert!(!s.contains('e') && !s.contains('E'), "no exponent form: {s}");
        assert!(s.chars().all(|c| c.is_ascii_digit()), "plain decimal: {s}");
        assert_eq!(Json::parse(&s).unwrap(), Json::Num(big));
        // The checked accessor refuses values past exact-integer range...
        assert_eq!(Json::Num(big).as_u64(), None);
        // ...and admits the boundary itself.
        assert_eq!(Json::Num(2f64.powi(53)).as_u64(), Some(1u64 << 53));
    }

    #[test]
    fn empty_containers_roundtrip() {
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(Vec::new()));
        assert_eq!(Json::Obj(BTreeMap::new()).to_string_compact(), "{}");
        assert_eq!(Json::Obj(BTreeMap::new()).to_string_pretty(), "{}");
        assert_eq!(Json::Arr(Vec::new()).to_string_compact(), "[]");
        let v = Json::parse(r#"{"empty": {}, "arr": []}"#).unwrap();
        assert_eq!(Json::parse(&v.to_string_pretty()).unwrap(), v);
    }

    #[test]
    fn deeply_nested_objects_roundtrip() {
        // A labelled registry snapshot nests name -> labels -> histogram
        // fields; make sure depth is limited only by input, not the writer.
        let mut v = Json::Num(1.0);
        for i in 0..64 {
            let mut m = BTreeMap::new();
            m.insert(format!("k{i}"), v);
            v = Json::Obj(m);
        }
        let compact = v.to_string_compact();
        assert_eq!(Json::parse(&compact).unwrap(), v);
        let pretty = v.to_string_pretty();
        assert_eq!(Json::parse(&pretty).unwrap(), v);
        let mut cur = &v;
        for i in (0..64).rev() {
            cur = cur.get(&format!("k{i}")).expect("nesting level present");
        }
        assert_eq!(cur, &Json::Num(1.0));
    }
}
