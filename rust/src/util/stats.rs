//! Statistics substrate: summaries and the weighted least squares regression
//! the paper's latency-model fitting procedure relies on (§III.A).

/// Summary statistics of a sample.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    /// Unbiased sample variance (n-1 denominator); 0 for n < 2.
    pub var: f64,
    pub min: f64,
    pub max: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        assert!(!xs.is_empty(), "Summary::of(empty)");
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        let min = xs.iter().cloned().fold(f64::INFINITY, f64::min);
        let max = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        Summary { n, mean, var, min, max }
    }

    pub fn std(&self) -> f64 {
        self.var.sqrt()
    }

    pub fn stderr(&self) -> f64 {
        (self.var / self.n as f64).sqrt()
    }
}

/// Percentile via linear interpolation on the sorted copy; p in [0, 100].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!(!xs.is_empty());
    assert!((0.0..=100.0).contains(&p));
    let mut s: Vec<f64> = xs.to_vec();
    s.sort_by(|a, b| a.total_cmp(b));
    let rank = p / 100.0 * (s.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        s[lo]
    } else {
        s[lo] + (rank - lo as f64) * (s[hi] - s[lo])
    }
}

/// Result of a (weighted) simple linear regression `y = slope*x + intercept`.
#[derive(Debug, Clone, Copy)]
pub struct LinearFit {
    pub slope: f64,
    pub intercept: f64,
    /// Coefficient of determination on the weighted data.
    pub r_squared: f64,
}

/// Weighted least squares for `y = a*x + b`.
///
/// This is the paper's model-fitting procedure: latency samples at small `N`
/// are fitted with WLS; weights `1/y²` (relative-error weighting) are what
/// `coordinator::benchmarker` passes so that the short-runtime samples —
/// which the 10-minute benchmarking budget mostly consists of — don't drown
/// the γ (setup-time) estimate.
pub fn weighted_least_squares(xs: &[f64], ys: &[f64], ws: &[f64]) -> Option<LinearFit> {
    assert_eq!(xs.len(), ys.len());
    assert_eq!(xs.len(), ws.len());
    if xs.len() < 2 {
        return None;
    }
    let sw: f64 = ws.iter().sum();
    if sw <= 0.0 {
        return None;
    }
    let mx = xs.iter().zip(ws).map(|(x, w)| x * w).sum::<f64>() / sw;
    let my = ys.iter().zip(ws).map(|(y, w)| y * w).sum::<f64>() / sw;
    let sxx: f64 = xs.iter().zip(ws).map(|(x, w)| w * (x - mx).powi(2)).sum();
    if sxx <= 0.0 {
        return None; // all x identical: slope unidentifiable
    }
    let sxy: f64 = xs
        .iter()
        .zip(ys)
        .zip(ws)
        .map(|((x, y), w)| w * (x - mx) * (y - my))
        .sum();
    let slope = sxy / sxx;
    let intercept = my - slope * mx;
    let ss_tot: f64 = ys.iter().zip(ws).map(|(y, w)| w * (y - my).powi(2)).sum();
    let ss_res: f64 = xs
        .iter()
        .zip(ys)
        .zip(ws)
        .map(|((x, y), w)| w * (y - (slope * x + intercept)).powi(2))
        .sum();
    let r_squared = if ss_tot > 0.0 { 1.0 - ss_res / ss_tot } else { 1.0 };
    Some(LinearFit { slope, intercept, r_squared })
}

/// Ordinary least squares (unit weights).
pub fn least_squares(xs: &[f64], ys: &[f64]) -> Option<LinearFit> {
    weighted_least_squares(xs, ys, &vec![1.0; xs.len()])
}

/// Relative error |pred - actual| / actual.
pub fn relative_error(pred: f64, actual: f64) -> f64 {
    ((pred - actual) / actual).abs()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.n, 4);
        assert!((s.mean - 2.5).abs() < 1e-12);
        assert!((s.var - 5.0 / 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
    }

    #[test]
    fn summary_single_point() {
        let s = Summary::of(&[7.0]);
        assert_eq!(s.var, 0.0);
        assert_eq!(s.mean, 7.0);
    }

    #[test]
    fn percentiles() {
        let xs = [5.0, 1.0, 3.0, 2.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
        assert!((percentile(&xs, 25.0) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn ols_recovers_exact_line() {
        let xs: Vec<f64> = (0..20).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 3.0 * x + 5.0).collect();
        let fit = least_squares(&xs, &ys).unwrap();
        assert!((fit.slope - 3.0).abs() < 1e-10);
        assert!((fit.intercept - 5.0).abs() < 1e-10);
        assert!((fit.r_squared - 1.0).abs() < 1e-12);
    }

    #[test]
    fn wls_downweights_outliers() {
        // Exact line y = 2x + 1 with one wild point that gets weight ~0.
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        let ys = [3.0, 5.0, 7.0, 9.0, 1000.0];
        let ws = [1.0, 1.0, 1.0, 1.0, 1e-9];
        let fit = weighted_least_squares(&xs, &ys, &ws).unwrap();
        assert!((fit.slope - 2.0).abs() < 1e-3);
        assert!((fit.intercept - 1.0).abs() < 1e-2);
    }

    #[test]
    fn wls_relative_weighting_changes_fit() {
        // With 1/y^2 weights, small-y points dominate.
        let xs = [1.0, 10.0, 100.0, 1000.0];
        let ys = [2.1, 11.0, 105.0, 1300.0]; // slope drifts upward at scale
        let w_rel: Vec<f64> = ys.iter().map(|y| 1.0 / (y * y)).collect();
        let rel = weighted_least_squares(&xs, &ys, &w_rel).unwrap();
        let ols = least_squares(&xs, &ys).unwrap();
        assert!(rel.slope < ols.slope);
    }

    #[test]
    fn degenerate_inputs_rejected() {
        assert!(least_squares(&[1.0], &[2.0]).is_none());
        assert!(least_squares(&[2.0, 2.0], &[1.0, 3.0]).is_none());
        assert!(weighted_least_squares(&[1.0, 2.0], &[1.0, 2.0], &[0.0, 0.0]).is_none());
    }

    #[test]
    fn relative_error_is_symmetric_in_magnitude() {
        assert!((relative_error(110.0, 100.0) - 0.1).abs() < 1e-12);
        assert!((relative_error(90.0, 100.0) - 0.1).abs() < 1e-12);
    }
}
