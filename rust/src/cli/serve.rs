//! JSON-over-TCP coordinator service.
//!
//! Newline-delimited JSON requests; one JSON response per line:
//!
//! ```text
//! {"op":"ping"}
//! {"op":"specs"}
//! {"op":"partition","budget":2.5,"partitioner":"milp"}
//! {"op":"evaluate","budget":2.5}            # partition + execute
//! {"op":"shutdown"}
//! ```
//!
//! Used by `examples/cluster_serve.rs` (client mode) to demonstrate the
//! coordinator as a long-running service: rust owns the event loop; each
//! connection gets a worker thread.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use crate::config::ExperimentConfig;
use crate::coordinator::executor::execute;
use crate::coordinator::{HeuristicPartitioner, MilpPartitioner, Partitioner};
use crate::report::Experiment;
use crate::util::json::{obj, Json};

use super::args::Args;

/// `cloudshapes serve --port P` entry point. Blocks until a shutdown
/// request arrives.
pub fn cmd_serve(args: &Args, cfg: ExperimentConfig) -> Result<(), String> {
    let port = args.flag_usize("port")?.unwrap_or(7741) as u16;
    let experiment = Arc::new(Experiment::build(cfg)?);
    let listener =
        TcpListener::bind(("127.0.0.1", port)).map_err(|e| format!("bind 127.0.0.1:{port}: {e}"))?;
    println!("cloudshapes coordinator listening on 127.0.0.1:{port}");
    serve_until_shutdown(listener, experiment)
}

/// Serve an already-bound listener (test/entry-point shared path).
pub fn serve_until_shutdown(
    listener: TcpListener,
    experiment: Arc<Experiment>,
) -> Result<(), String> {
    let stop = Arc::new(AtomicBool::new(false));
    for stream in listener.incoming() {
        if stop.load(Ordering::SeqCst) {
            break;
        }
        let Ok(stream) = stream else { continue };
        let e = Arc::clone(&experiment);
        let stop_conn = Arc::clone(&stop);
        std::thread::spawn(move || {
            let _ = handle_connection(stream, &e, &stop_conn);
        });
        if stop.load(Ordering::SeqCst) {
            break;
        }
    }
    Ok(())
}

fn handle_connection(
    stream: TcpStream,
    e: &Experiment,
    stop: &AtomicBool,
) -> std::io::Result<()> {
    // The accepted socket's local address IS the listener's address — used
    // to poke the blocked accept loop after a shutdown request.
    let listener_addr = stream.local_addr()?;
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let response = handle_request(&line, e, stop);
        writer.write_all(response.to_string_compact().as_bytes())?;
        writer.write_all(b"\n")?;
        if stop.load(Ordering::SeqCst) {
            // Poke the listener so the accept loop notices shutdown.
            let _ = TcpStream::connect(listener_addr);
            break;
        }
    }
    Ok(())
}

/// Handle one request line; always returns a JSON object.
pub fn handle_request(line: &str, e: &Experiment, stop: &AtomicBool) -> Json {
    let err = |msg: String| obj(vec![("ok", false.into()), ("error", msg.into())]);
    let req = match Json::parse(line) {
        Ok(j) => j,
        Err(e) => return err(format!("bad json: {e}")),
    };
    let Some(op) = req.get("op").and_then(Json::as_str) else {
        return err("missing 'op'".into());
    };
    match op {
        "ping" => obj(vec![("ok", true.into()), ("pong", true.into())]),
        "specs" => {
            let specs: Vec<Json> = e
                .cluster
                .specs()
                .iter()
                .map(|s| {
                    obj(vec![
                        ("name", s.name.as_str().into()),
                        ("category", s.category.name().into()),
                        ("rate_per_hour", s.rate_per_hour.into()),
                        ("quantum_secs", s.quantum_secs.into()),
                        ("app_gflops", s.app_gflops.into()),
                    ])
                })
                .collect();
            obj(vec![("ok", true.into()), ("specs", Json::Arr(specs))])
        }
        "partition" | "evaluate" => {
            let budget = req.get("budget").and_then(Json::as_f64);
            let pname = req.get("partitioner").and_then(Json::as_str).unwrap_or("milp");
            let milp = MilpPartitioner::new(e.config.milp.clone());
            let heuristic = HeuristicPartitioner::default();
            let part: &dyn Partitioner = match pname {
                "milp" => &milp,
                "heuristic" => &heuristic,
                other => return err(format!("unknown partitioner '{other}'")),
            };
            let alloc = match part.partition(e.models(), budget) {
                Ok(a) => a,
                Err(msg) => return err(msg),
            };
            let (lat, cost) = e.models().evaluate(&alloc);
            let mut fields = vec![
                ("ok", true.into()),
                ("partitioner", pname.into()),
                ("predicted_latency_s", lat.into()),
                ("predicted_cost", cost.into()),
                ("platforms_used", alloc.used_platforms().len().into()),
            ];
            if op == "evaluate" {
                match execute(&e.cluster, &e.workload, &alloc, &e.config.executor) {
                    Ok(rep) => {
                        fields.push(("measured_latency_s", rep.makespan_secs.into()));
                        fields.push(("measured_cost", rep.cost.into()));
                        fields.push(("failures", rep.failures.into()));
                    }
                    Err(msg) => return err(msg),
                }
            }
            obj(fields)
        }
        "shutdown" => {
            stop.store(true, Ordering::SeqCst);
            obj(vec![("ok", true.into()), ("shutdown", true.into())])
        }
        other => err(format!("unknown op '{other}'")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentConfig;

    fn experiment() -> Experiment {
        let mut cfg = ExperimentConfig::quick();
        cfg.milp.time_limit_secs = 2.0;
        Experiment::build(cfg).unwrap()
    }

    #[test]
    fn ping_and_specs() {
        let e = experiment();
        let stop = AtomicBool::new(false);
        let r = handle_request(r#"{"op":"ping"}"#, &e, &stop);
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)));
        let r = handle_request(r#"{"op":"specs"}"#, &e, &stop);
        assert_eq!(r.get("specs").unwrap().as_arr().unwrap().len(), 3);
    }

    #[test]
    fn partition_request_roundtrips() {
        let e = experiment();
        let stop = AtomicBool::new(false);
        let r = handle_request(r#"{"op":"partition","partitioner":"heuristic"}"#, &e, &stop);
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{r:?}");
        assert!(r.get("predicted_latency_s").unwrap().as_f64().unwrap() > 0.0);
    }

    #[test]
    fn errors_are_json() {
        let e = experiment();
        let stop = AtomicBool::new(false);
        for bad in ["not json", r#"{"no_op":1}"#, r#"{"op":"explode"}"#] {
            let r = handle_request(bad, &e, &stop);
            assert_eq!(r.get("ok"), Some(&Json::Bool(false)), "{bad}");
        }
    }

    #[test]
    fn shutdown_sets_flag() {
        let e = experiment();
        let stop = AtomicBool::new(false);
        handle_request(r#"{"op":"shutdown"}"#, &e, &stop);
        assert!(stop.load(Ordering::SeqCst));
    }
}
