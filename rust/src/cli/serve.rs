//! JSON-over-TCP coordinator service speaking protocol **v1**
//! (see [`crate::api::protocol`] for the wire format and `docs/PROTOCOL.md`
//! for the complete op reference).
//!
//! Newline-delimited JSON requests by default (length-prefixed `lp1`
//! framing is negotiable per connection — see [`crate::serve`]); one JSON
//! response per request:
//!
//! ```text
//! {"v":1,"op":"ping"}                          # liveness + cache/scheduler stats
//! {"v":1,"op":"specs"}
//! {"v":1,"op":"partition","budget":2.5,"partitioner":"milp"}
//! {"v":1,"op":"partition","budget":null}       # null = unconstrained
//! {"v":1,"op":"evaluate","budget":2.5}         # partition + execute
//! {"v":1,"op":"pareto"}                        # trade-off curve
//! {"v":1,"op":"shape","deadline":3600}         # optimise the composition
//! {"v":1,"op":"batch","budgets":[1,2.5,null]}  # one partition per budget
//! {"v":1,"op":"run","budget":2.5}              # background execution
//! {"v":1,"op":"status","run_id":3}             # poll a background run
//! {"v":1,"op":"submit","tasks":4,"deadline":3600}  # scheduler job
//! {"v":1,"op":"submit_batch","jobs":[{"tasks":2,"deadline":3600},...]}
//! {"v":1,"op":"jobs"}                          # job statuses
//! {"v":1,"op":"cancel","job_id":3}
//! {"v":1,"op":"metrics"}                       # telemetry snapshot
//! {"v":1,"op":"metrics","filter":"exec_"}      # name-filtered subset
//! {"v":1,"op":"shutdown"}
//! ```
//!
//! Malformed requests never drop the connection: every failure maps to a
//! structured `{"v":1,"ok":false,"error":{"kind":...,"message":...}}`
//! payload. Used by `examples/cluster_serve.rs` (client mode) to demonstrate
//! the coordinator as a long-running service.
//!
//! Connection handling lives in [`crate::serve`]: one readiness-driven
//! event loop owns every socket, and decoded requests are dispatched to
//! worker shards aligned with the session's solution-cache slices. This
//! module owns the *semantics* of each op — [`execute_request`] is the
//! single entry point the shard workers call, and [`handle_request`] is
//! its line-oriented twin for tests and embedding.
//!
//! All connections share one [`TradeoffSession`], so its solution cache
//! serves repeated and concurrent `partition`/`evaluate`/`pareto`/`batch`
//! requests without re-solving; `ping` reports the cache counters. With
//! `serve --scheduler` the session also runs the online job scheduler:
//! `submit`/`jobs`/`cancel` manage continuously-arriving pricing jobs, and
//! a `submit` with `"stream":true` holds the connection, emitting
//! `{"v":1,"event":"job",...}` lines until the job is terminal.

use std::net::TcpListener;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Instant;

use crate::api::error::{CloudshapesError, Result};
use crate::api::protocol::{error_response, ok_response, Request};
use crate::api::session::{RunState, RunStatus, ShapeSummary};
use crate::api::TradeoffSession;
use crate::coordinator::scheduler::{JobSpec, JobState, JobStatus, Slo};
use crate::coordinator::{ExecEvent, ShapeObjective};
use crate::util::json::{obj, Json};
use crate::workload::Payoff;

use super::args::Args;

/// `cloudshapes serve --port P` entry point. Blocks until a shutdown
/// request arrives. Takes a session *factory* so bad ports and occupied
/// addresses fail fast, before the expensive benchmarking step runs.
pub fn cmd_serve(
    args: &Args,
    build_session: impl FnOnce() -> Result<TradeoffSession>,
) -> Result<()> {
    let port = args.flag_usize("port")?.unwrap_or(7741) as u16;
    let listener = TcpListener::bind(("127.0.0.1", port))
        .map_err(|e| CloudshapesError::runtime(format!("bind 127.0.0.1:{port}: {e}")))?;
    let session = Arc::new(build_session()?);
    println!("cloudshapes coordinator listening on 127.0.0.1:{port} (protocol v1)");
    serve_until_shutdown(listener, session)
}

/// Serve an already-bound listener (test/entry-point shared path) on the
/// event loop configured by the session's `[serve]` section. Blocks until
/// a `shutdown` request arrives and every in-flight response has flushed.
pub fn serve_until_shutdown(listener: TcpListener, session: Arc<TradeoffSession>) -> Result<()> {
    let cfg = session.config().serve.clone();
    crate::serve::serve(listener, session, &cfg)
}

/// Execute one decoded request, emitting any interim streaming lines
/// through `emit` and returning the final response object. This is the
/// single semantic entry point: the serve plane's shard workers call it
/// for every dispatched request, and the event loop calls it inline for
/// `shutdown` (emit is then a no-op — shutdown never streams).
pub(crate) fn execute_request(
    session: &TradeoffSession,
    req: Request,
    stop: &AtomicBool,
    emit: &mut dyn FnMut(String),
) -> Json {
    match req {
        Request::Run { partitioner, budget, stream: true } => {
            let _timer = OpTimer::start(session, "run");
            stream_run_lines(session, partitioner.as_deref(), budget, emit)
        }
        Request::Submit { tasks, payoff, accuracy, seed, deadline, budget, stream: true } => {
            let _timer = OpTimer::start(session, "submit");
            stream_job_lines(
                session,
                tasks,
                payoff.as_deref(),
                accuracy,
                seed,
                deadline,
                budget,
                stop,
                emit,
            )
        }
        req => match dispatch(req, session, stop) {
            Ok(response) => response,
            Err(e) => error_response(&e),
        },
    }
}

/// Handle one request line; always returns a JSON object (success envelope
/// or structured error payload).
pub fn handle_request(line: &str, session: &TradeoffSession, stop: &AtomicBool) -> Json {
    match Request::parse(line).and_then(|req| dispatch(req, session, stop)) {
        Ok(response) => response,
        Err(e) => error_response(&e),
    }
}

/// Counts one request into `serve_requests_total{op=}` immediately and, on
/// drop, its wall-clock latency into `serve_op_latency_secs{op=}` — so
/// error paths and streaming ops are measured exactly like successes. Also
/// holds the request's tracing span open for its whole lifetime.
struct OpTimer<'a> {
    session: &'a TradeoffSession,
    label: String,
    started: Instant,
    _span: crate::obs::Span,
}

impl<'a> OpTimer<'a> {
    fn start(session: &'a TradeoffSession, op: &str) -> Self {
        let label = format!("op={op}");
        session.metrics_registry().inc("serve_requests_total", &label, 1);
        OpTimer {
            session,
            label,
            started: Instant::now(),
            _span: crate::span!("serve_request", op),
        }
    }
}

impl Drop for OpTimer<'_> {
    fn drop(&mut self) {
        self.session.metrics_registry().observe(
            "serve_op_latency_secs",
            &self.label,
            self.started.elapsed().as_secs_f64(),
        );
    }
}

fn dispatch(req: Request, session: &TradeoffSession, stop: &AtomicBool) -> Result<Json> {
    let _timer = OpTimer::start(session, req.op());
    dispatch_inner(req, session, stop)
}

fn dispatch_inner(req: Request, session: &TradeoffSession, stop: &AtomicBool) -> Result<Json> {
    match req {
        Request::Ping => {
            let stats = session.cache_stats();
            let mut fields = vec![
                ("pong", true.into()),
                (
                    "cache",
                    obj(vec![
                        ("hits", Json::Num(stats.hits as f64)),
                        ("misses", Json::Num(stats.misses as f64)),
                        ("partition_entries", stats.partition_entries.into()),
                        ("pareto_entries", stats.pareto_entries.into()),
                    ]),
                ),
            ];
            // Scheduler counters when the session runs one. The values come
            // from the metrics registry — the scheduler mirrors every stats
            // update into it at the same site — so `ping` and the `metrics`
            // op can never disagree. The response shape is unchanged.
            if session.scheduler_stats().is_ok() {
                let reg = session.metrics_registry();
                let c = |name: &str| Json::Num(reg.counter_value(name, "") as f64);
                let g = |label: &str| {
                    reg.gauge_value("scheduler_model_error", label)
                        .map(Json::Num)
                        .unwrap_or(Json::Null)
                };
                fields.push((
                    "scheduler",
                    obj(vec![
                        ("submitted", c("scheduler_submitted_total")),
                        ("completed", c("scheduler_completed_total")),
                        ("cancelled", c("scheduler_cancelled_total")),
                        ("failed", c("scheduler_failed_total")),
                        ("epochs", c("scheduler_epochs_total")),
                        ("resolves", c("scheduler_resolves_total")),
                        ("warm_reuses", c("scheduler_warm_reuses_total")),
                        ("model_error_first", g("stage=first")),
                        ("model_error_last", g("stage=last")),
                    ]),
                ));
            }
            Ok(ok_response(fields))
        }
        Request::Specs => {
            let specs: Vec<Json> = session
                .experiment()
                .cluster
                .specs()
                .iter()
                .map(|s| {
                    obj(vec![
                        ("name", s.name.as_str().into()),
                        ("category", s.category.name().into()),
                        ("rate_per_hour", s.rate_per_hour.into()),
                        ("quantum_secs", s.quantum_secs.into()),
                        ("app_gflops", s.app_gflops.into()),
                    ])
                })
                .collect();
            Ok(ok_response(vec![("specs", Json::Arr(specs))]))
        }
        Request::Partition { partitioner, budget } => {
            let p = session.partition_with(partitioner.as_deref(), budget)?;
            Ok(ok_response(partition_fields(&p)))
        }
        Request::Evaluate { partitioner, budget } => {
            let ev = session.evaluate_with(partitioner.as_deref(), budget)?;
            let mut fields = partition_fields(&ev.partition);
            fields.extend(execution_fields(&ev.execution));
            fields.push(("shape", composition_json(session.composition())));
            Ok(ok_response(fields))
        }
        Request::Shape { partitioner, deadline, budget } => {
            let objective = match (deadline, budget) {
                (Some(d), None) => ShapeObjective::Deadline(d),
                (None, Some(b)) => ShapeObjective::Budget(b),
                _ => unreachable!("protocol parse enforces exactly one"),
            };
            let s = session.optimize_shape(partitioner.as_deref(), objective)?;
            Ok(ok_response(shape_fields(&s)))
        }
        Request::Run { partitioner, budget, .. } => {
            // stream:true is intercepted at the connection layer; reaching
            // here (including direct handle_request calls) means a
            // background run polled via `status`.
            let run_id = session.start_run(partitioner.as_deref(), budget)?;
            Ok(ok_response(vec![
                ("run_id", Json::Num(run_id as f64)),
                ("status", "running".into()),
            ]))
        }
        Request::Status { run_id } => {
            let status = session.run_status(run_id).ok_or_else(|| {
                CloudshapesError::protocol(format!(
                    "unknown run_id {run_id} (finished runs are evicted eventually)"
                ))
            })?;
            Ok(ok_response(status_fields(&status)))
        }
        Request::Submit { tasks, payoff, accuracy, seed, deadline, budget, .. } => {
            // stream:true is intercepted at the connection layer (like
            // `run`); reaching here means a plain background submit.
            let spec = build_job_spec(tasks, payoff.as_deref(), accuracy, seed, deadline, budget)?;
            let job_id = session.submit_job(spec)?;
            Ok(ok_response(vec![
                ("job_id", Json::Num(job_id as f64)),
                ("status", "queued".into()),
            ]))
        }
        Request::SubmitBatch { jobs } => {
            // Entries are independent, mirroring `batch`: a bad book entry
            // (unknown payoff) or a shed admission (overload) yields an
            // inline error object, never a failed storm. A *disabled*
            // scheduler still fails the request as a whole, like `submit`.
            let built: Vec<Result<JobSpec>> = jobs
                .iter()
                .map(|e| {
                    build_job_spec(
                        e.tasks,
                        e.payoff.as_deref(),
                        e.accuracy,
                        e.seed,
                        e.deadline,
                        e.budget,
                    )
                })
                .collect();
            // One scheduler handle lookup for the whole storm.
            let mut submitted = session
                .submit_jobs(built.iter().filter_map(|r| r.as_ref().ok()).cloned().collect())?
                .into_iter();
            let results: Vec<Json> = built
                .into_iter()
                .map(|b| {
                    match b.and_then(|_| submitted.next().expect("one submit per built spec")) {
                        Ok(id) => obj(vec![
                            ("ok", Json::Bool(true)),
                            ("job_id", Json::Num(id as f64)),
                        ]),
                        Err(e) => obj(vec![
                            ("ok", Json::Bool(false)),
                            (
                                "error",
                                obj(vec![
                                    ("kind", e.kind().into()),
                                    ("message", e.message().into()),
                                ]),
                            ),
                        ]),
                    }
                })
                .collect();
            Ok(ok_response(vec![("results", Json::Arr(results))]))
        }
        Request::Jobs { job_id: None } => {
            let jobs: Vec<Json> =
                session.jobs()?.iter().map(|j| obj(job_fields(j))).collect();
            Ok(ok_response(vec![("jobs", Json::Arr(jobs))]))
        }
        Request::Jobs { job_id: Some(id) } => {
            let status = session.job_status(id)?.ok_or_else(|| {
                CloudshapesError::protocol(format!("unknown job_id {id}"))
            })?;
            Ok(ok_response(job_fields(&status)))
        }
        Request::Cancel { job_id } => {
            let cancelled = session.cancel_job(job_id)?.ok_or_else(|| {
                CloudshapesError::protocol(format!("unknown job_id {job_id}"))
            })?;
            Ok(ok_response(vec![
                ("job_id", Json::Num(job_id as f64)),
                ("cancelled", Json::Bool(cancelled)),
            ]))
        }
        Request::Pareto { partitioner } => {
            let curve = session.pareto_frontier_with(partitioner.as_deref())?;
            let points: Vec<Json> = curve
                .points
                .iter()
                .map(|p| {
                    obj(vec![
                        (
                            "budget",
                            p.budget.map(Json::Num).unwrap_or(Json::Null),
                        ),
                        ("latency_s", p.latency.into()),
                        ("cost", p.cost.into()),
                    ])
                })
                .collect();
            Ok(ok_response(vec![
                ("partitioner", curve.partitioner.as_str().into()),
                ("c_lower", curve.c_lower.into()),
                ("c_upper", curve.c_upper.into()),
                ("shape", composition_json(session.composition())),
                ("points", Json::Arr(points)),
            ]))
        }
        Request::Batch { partitioner, budgets } => {
            // Entries are independent: an infeasible budget yields an
            // inline error object, never a failed batch.
            let results: Vec<Json> = budgets
                .iter()
                .map(|&budget| match session.partition_with(partitioner.as_deref(), budget) {
                    Ok(p) => {
                        let mut fields = vec![("ok", Json::Bool(true))];
                        fields.extend(partition_fields(&p));
                        obj(fields)
                    }
                    Err(e) => obj(vec![
                        ("ok", Json::Bool(false)),
                        (
                            "error",
                            obj(vec![
                                ("kind", e.kind().into()),
                                ("message", e.message().into()),
                            ]),
                        ),
                    ]),
                })
                .collect();
            Ok(ok_response(vec![("results", Json::Arr(results))]))
        }
        Request::Metrics { filter } => {
            Ok(ok_response(vec![("metrics", session.metrics(filter.as_deref()))]))
        }
        Request::Shutdown => {
            stop.store(true, Ordering::SeqCst);
            Ok(ok_response(vec![("shutdown", true.into())]))
        }
    }
}

fn partition_fields(p: &crate::api::PartitionSummary) -> Vec<(&'static str, Json)> {
    vec![
        ("partitioner", p.partitioner.as_str().into()),
        (
            "budget",
            p.budget.map(Json::Num).unwrap_or(Json::Null),
        ),
        ("predicted_latency_s", p.predicted_latency_s.into()),
        ("predicted_cost", p.predicted_cost.into()),
        ("platforms_used", p.alloc.used_platforms().len().into()),
    ]
}

fn execution_fields(
    rep: &crate::coordinator::ExecutionReport,
) -> Vec<(&'static str, Json)> {
    vec![
        ("measured_latency_s", rep.makespan_secs.into()),
        ("measured_cost", rep.cost.into()),
        ("failures", rep.failures.into()),
        ("chunks", rep.chunks.into()),
        ("retries", rep.retries.into()),
        ("migrations", rep.migrations.into()),
        ("preemptions", rep.preemptions.into()),
    ]
}

/// `{"type": count, ...}` — the wire form of a cluster composition.
fn composition_json(composition: Vec<(String, usize)>) -> Json {
    Json::Obj(
        composition
            .into_iter()
            .map(|(name, count)| (name, Json::Num(count as f64)))
            .collect(),
    )
}

fn shape_fields(s: &ShapeSummary) -> Vec<(&'static str, Json)> {
    let point = &s.outcome.point;
    let mut fields = vec![
        ("partitioner", s.partitioner.as_str().into()),
        (
            "shape",
            composition_json(s.composition()),
        ),
        ("instances", point.counts.iter().sum::<usize>().into()),
        ("predicted_latency_s", point.latency.into()),
        ("predicted_cost", point.cost.into()),
        ("outer_bound", s.outcome.outer_bound.into()),
        ("nodes", s.outcome.nodes.into()),
    ];
    match s.objective {
        ShapeObjective::Deadline(d) => fields.push(("deadline", d.into())),
        ShapeObjective::Budget(b) => fields.push(("budget", b.into())),
    }
    fields
}

fn status_fields(s: &RunStatus) -> Vec<(&'static str, Json)> {
    let mut fields = vec![
        ("run_id", Json::Num(s.id as f64)),
        (
            "status",
            match &s.state {
                RunState::Running => "running".into(),
                RunState::Done => "done".into(),
                RunState::Failed(_) => "failed".into(),
            },
        ),
        ("partitioner", s.partitioner.as_str().into()),
        ("budget", s.budget.map(Json::Num).unwrap_or(Json::Null)),
        ("chunks_done", s.chunks_done.into()),
        ("chunks_total", s.chunks_total.into()),
        ("tasks_priced", s.tasks_priced.into()),
        ("tasks_total", s.tasks_total.into()),
        ("failures", s.failures.into()),
        ("retries", s.retries.into()),
        ("migrations", s.migrations.into()),
        ("preemptions", s.preemptions.into()),
    ];
    if let Some(m) = s.makespan_secs {
        fields.push(("measured_latency_s", m.into()));
    }
    if let Some(c) = s.cost {
        fields.push(("measured_cost", c.into()));
    }
    if let RunState::Failed(msg) = &s.state {
        fields.push(("error", msg.as_str().into()));
    }
    fields
}

/// Build a scheduler [`JobSpec`] from the `submit` op's wire fields. The
/// payoff name resolves through [`Payoff::parse`], so an unknown family is
/// a typed workload error listing the valid names.
fn build_job_spec(
    tasks: usize,
    payoff: Option<&str>,
    accuracy: Option<f64>,
    seed: Option<u64>,
    deadline: Option<f64>,
    budget: Option<f64>,
) -> Result<JobSpec> {
    let payoff = payoff.map(Payoff::parse).transpose()?;
    let slo = match (deadline, budget) {
        (Some(d), None) => Slo::Deadline(d),
        (None, Some(b)) => Slo::Budget(b),
        _ => unreachable!("protocol parse enforces exactly one SLO"),
    };
    // A service-friendly default accuracy: coarse enough that a job is
    // seconds of virtual work, not hours (clients price tighter on demand).
    JobSpec::generate(payoff, tasks, accuracy.unwrap_or(0.05), seed.unwrap_or(1), slo)
}

/// Wire form of one job status (the `jobs` op and job event lines).
fn job_fields(j: &JobStatus) -> Vec<(&'static str, Json)> {
    let mut fields = vec![
        ("job_id", Json::Num(j.id as f64)),
        ("status", j.state.name().into()),
        ("tasks", j.tasks_total.into()),
        ("sims_total", Json::Num(j.sims_total as f64)),
        ("sims_done", Json::Num(j.sims_done as f64)),
        ("epochs", j.epochs.into()),
        ("cost", j.cost.into()),
        ("arrival_s", j.arrival_s.into()),
        ("prices", j.prices.iter().flatten().count().into()),
    ];
    match j.slo {
        Slo::Deadline(d) => fields.push(("deadline", d.into())),
        Slo::Budget(b) => fields.push(("slo_budget", b.into())),
    }
    if let Some(f) = j.finished_s {
        fields.push(("finished_s", f.into()));
    }
    if let Some(p) = j.predicted_finish_s {
        fields.push(("predicted_finish_s", p.into()));
    }
    fields.push((
        "slo_met",
        j.slo_met.map(Json::Bool).unwrap_or(Json::Null),
    ));
    if let JobState::Failed(msg) = &j.state {
        fields.push(("error", msg.as_str().into()));
    }
    fields
}

/// Serve a `{"op":"submit","stream":true}` request: submit, then emit one
/// `{"v":1,"event":"job",...}` line per observed progress change until the
/// job is terminal, then return the final `{"v":1,"ok":...}` response
/// carrying the job's full status. Polls the shutdown flag between
/// progress checks so a draining server answers a typed error instead of
/// holding the stream open forever (the job itself keeps running in the
/// scheduler and stays pollable via `jobs`).
#[allow(clippy::too_many_arguments)]
fn stream_job_lines(
    session: &TradeoffSession,
    tasks: usize,
    payoff: Option<&str>,
    accuracy: Option<f64>,
    seed: Option<u64>,
    deadline: Option<f64>,
    budget: Option<f64>,
    stop: &AtomicBool,
    emit: &mut dyn FnMut(String),
) -> Json {
    let submitted = build_job_spec(tasks, payoff, accuracy, seed, deadline, budget)
        .and_then(|spec| session.submit_job(spec));
    let job_id = match submitted {
        Ok(id) => id,
        Err(e) => return error_response(&e),
    };
    let mut last: Option<(JobState, u64, usize)> = None;
    loop {
        if stop.load(Ordering::SeqCst) {
            return error_response(&CloudshapesError::runtime(format!(
                "server shutting down while streaming job {job_id}; the job keeps \
                 running — poll it with the `jobs` op"
            )));
        }
        let status = match session.job_status(job_id) {
            Ok(Some(s)) => s,
            // Only *terminal* jobs are ever evicted (under submission
            // pressure at the tracked-jobs cap), so a vanished id means
            // the job finished between polls but its final snapshot was
            // lost to eviction — rare, and worth an honest error over a
            // fabricated result.
            Ok(None) | Err(_) => {
                return error_response(&CloudshapesError::runtime(format!(
                    "job {job_id} finished but was evicted under submission pressure \
                     before its final status could be streamed (poll `jobs` sooner, \
                     or submit less aggressively)"
                )));
            }
        };
        if status.state.is_terminal() {
            return ok_response(job_fields(&status));
        }
        let key = (status.state.clone(), status.sims_done, status.epochs);
        if last.as_ref() != Some(&key) {
            let mut fields = vec![
                ("v", Json::Num(crate::api::PROTOCOL_VERSION as f64)),
                ("event", "job".into()),
            ];
            fields.extend(job_fields(&status));
            emit(obj(fields).to_string_compact());
            last = Some(key);
        }
        std::thread::sleep(std::time::Duration::from_millis(5));
    }
}

/// Serve a `{"op":"run","stream":true}` request: interim `{"v":1,"event":
/// ...}` lines (progress at ~5% strides, failures, migrations, task prices)
/// through `emit`, then return the final `{"v":1,"ok":...}` response.
fn stream_run_lines(
    session: &TradeoffSession,
    partitioner: Option<&str>,
    budget: Option<f64>,
    emit: &mut dyn FnMut(String),
) -> Json {
    let mut next_pct = 0u64;
    let result = session.evaluate_with_events(partitioner, budget, &mut |ev| {
        if let Some(json) = stream_event_json(ev, &mut next_pct) {
            emit(json.to_string_compact());
        }
    });
    match result {
        Ok(ev) => {
            let mut fields = partition_fields(&ev.partition);
            fields.extend(execution_fields(&ev.execution));
            ok_response(fields)
        }
        Err(e) => error_response(&e),
    }
}

/// Wire form of one executor event; None for events the stream elides
/// (per-chunk completions between progress strides, the final `Finished` —
/// the response line carries those numbers).
fn stream_event_json(ev: &ExecEvent, next_pct: &mut u64) -> Option<Json> {
    let e = |name: &str, mut fields: Vec<(&str, Json)>| {
        let mut all = vec![
            ("v", Json::Num(crate::api::PROTOCOL_VERSION as f64)),
            ("event", name.into()),
        ];
        all.append(&mut fields);
        Some(obj(all))
    };
    match ev {
        ExecEvent::Started { chunks, tasks } => {
            *next_pct = 5;
            e("started", vec![("chunks", (*chunks).into()), ("tasks", (*tasks).into())])
        }
        ExecEvent::ChunkDone { done, total, .. } => {
            let pct = (*done as u64 * 100) / (*total).max(1) as u64;
            if pct < *next_pct && *done != *total {
                return None;
            }
            *next_pct = pct + 5;
            e("progress", vec![("done", (*done).into()), ("total", (*total).into())])
        }
        ExecEvent::ChunkFailed { platform, task, attempt, will_retry, rehomed_to, error, .. } => {
            e(
                "chunk_failed",
                vec![
                    ("platform", (*platform).into()),
                    ("task", (*task).into()),
                    ("attempt", Json::Num(*attempt as f64)),
                    ("will_retry", Json::Bool(*will_retry)),
                    (
                        "rehomed_to",
                        rehomed_to.map(|p| p.into()).unwrap_or(Json::Null),
                    ),
                    ("error", error.as_str().into()),
                ],
            )
        }
        ExecEvent::ChunkMigrated { from, to, task, .. } => e(
            "chunk_migrated",
            vec![("from", (*from).into()), ("to", (*to).into()), ("task", (*task).into())],
        ),
        ExecEvent::LanePreempted { platform, at_secs, drained } => e(
            "lane_preempted",
            vec![
                ("platform", (*platform).into()),
                ("at_secs", (*at_secs).into()),
                ("drained", (*drained).into()),
            ],
        ),
        ExecEvent::TaskPriced { task, estimate, partial } => e(
            "task_priced",
            vec![
                ("task", (*task).into()),
                ("price", estimate.price.into()),
                ("std_error", estimate.std_error.into()),
                ("n", Json::Num(estimate.n as f64)),
                ("partial", Json::Bool(*partial)),
            ],
        ),
        ExecEvent::Finished { .. } => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::SessionBuilder;
    use crate::coordinator::partitioner::MilpConfig;

    fn session() -> TradeoffSession {
        SessionBuilder::quick()
            .milp(MilpConfig { time_limit_secs: 2.0, ..Default::default() })
            .build()
            .unwrap()
    }

    #[test]
    fn ping_and_specs() {
        let s = session();
        let stop = AtomicBool::new(false);
        let r = handle_request(r#"{"v":1,"op":"ping"}"#, &s, &stop);
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)));
        assert_eq!(r.get("v").unwrap().as_u64(), Some(1));
        let r = handle_request(r#"{"v":1,"op":"specs"}"#, &s, &stop);
        assert_eq!(r.get("specs").unwrap().as_arr().unwrap().len(), 3);
    }

    #[test]
    fn partition_request_roundtrips() {
        let s = session();
        let stop = AtomicBool::new(false);
        let r = handle_request(
            r#"{"v":1,"op":"partition","partitioner":"heuristic","budget":null}"#,
            &s,
            &stop,
        );
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{r:?}");
        assert!(r.get("predicted_latency_s").unwrap().as_f64().unwrap() > 0.0);
    }

    #[test]
    fn errors_are_structured() {
        let s = session();
        let stop = AtomicBool::new(false);
        for (bad, kind) in [
            ("not json", "protocol"),
            (r#"{"no_op":1}"#, "protocol"),
            (r#"{"op":"ping"}"#, "protocol"),          // unversioned
            (r#"{"v":1,"op":"explode"}"#, "protocol"), // unknown op
            (r#"{"v":1,"op":"partition"}"#, "protocol"), // missing budget
            (
                // registered? no — config error from the registry
                r#"{"v":1,"op":"partition","partitioner":"nope","budget":null}"#,
                "config",
            ),
        ] {
            let r = handle_request(bad, &s, &stop);
            assert_eq!(r.get("ok"), Some(&Json::Bool(false)), "{bad}");
            assert_eq!(
                r.get("error").and_then(|e| e.get("kind")).and_then(Json::as_str),
                Some(kind),
                "{bad}"
            );
        }
    }

    #[test]
    fn ping_reports_cache_stats() {
        let s = session();
        let stop = AtomicBool::new(false);
        let r = handle_request(r#"{"v":1,"op":"ping"}"#, &s, &stop);
        let cache = r.get("cache").unwrap();
        assert_eq!(cache.get("hits").unwrap().as_u64(), Some(0));
        assert_eq!(cache.get("misses").unwrap().as_u64(), Some(0));
        // One solve, then a cached repeat, through the wire ops.
        let req = r#"{"v":1,"op":"partition","partitioner":"heuristic","budget":null}"#;
        let a = handle_request(req, &s, &stop);
        let b = handle_request(req, &s, &stop);
        assert_eq!(a, b, "cached repeat must serve the identical response");
        let r = handle_request(r#"{"v":1,"op":"ping"}"#, &s, &stop);
        let cache = r.get("cache").unwrap();
        assert_eq!(cache.get("hits").unwrap().as_u64(), Some(1));
        assert_eq!(cache.get("misses").unwrap().as_u64(), Some(1));
        assert_eq!(cache.get("partition_entries").unwrap().as_u64(), Some(1));
    }

    #[test]
    fn batch_partitions_per_budget_with_inline_errors() {
        let s = session();
        let stop = AtomicBool::new(false);
        let r = handle_request(
            r#"{"v":1,"op":"batch","partitioner":"milp","budgets":[null,1e-9]}"#,
            &s,
            &stop,
        );
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{}", r.to_string_compact());
        let results = r.get("results").unwrap().as_arr().unwrap();
        assert_eq!(results.len(), 2);
        assert_eq!(results[0].get("ok"), Some(&Json::Bool(true)));
        assert!(results[0].get("predicted_latency_s").unwrap().as_f64().unwrap() > 0.0);
        assert_eq!(results[0].get("budget"), Some(&Json::Null));
        // The impossible budget fails inline without failing the batch.
        assert_eq!(results[1].get("ok"), Some(&Json::Bool(false)));
        assert_eq!(
            results[1].get("error").and_then(|e| e.get("kind")).and_then(Json::as_str),
            Some("solver")
        );
        // Malformed batches are protocol errors.
        let r = handle_request(r#"{"v":1,"op":"batch","budgets":[]}"#, &s, &stop);
        assert_eq!(r.get("ok"), Some(&Json::Bool(false)));
    }

    #[test]
    fn run_then_status_roundtrip() {
        let s = session();
        let stop = AtomicBool::new(false);
        let r = handle_request(
            r#"{"v":1,"op":"run","partitioner":"heuristic","budget":null}"#,
            &s,
            &stop,
        );
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{}", r.to_string_compact());
        assert_eq!(r.get("status").unwrap().as_str(), Some("running"));
        let id = r.get("run_id").unwrap().as_u64().unwrap();

        // Poll until the background executor finishes.
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(30);
        loop {
            let st =
                handle_request(&format!(r#"{{"v":1,"op":"status","run_id":{id}}}"#), &s, &stop);
            assert_eq!(st.get("ok"), Some(&Json::Bool(true)));
            match st.get("status").unwrap().as_str() {
                Some("running") => {
                    assert!(std::time::Instant::now() < deadline, "run never finished");
                    std::thread::sleep(std::time::Duration::from_millis(10));
                }
                Some("done") => {
                    assert!(st.get("measured_latency_s").unwrap().as_f64().unwrap() > 0.0);
                    assert_eq!(
                        st.get("chunks_done").unwrap().as_u64(),
                        st.get("chunks_total").unwrap().as_u64()
                    );
                    assert_eq!(st.get("tasks_priced").unwrap().as_u64(), Some(8));
                    break;
                }
                other => panic!("unexpected run state {other:?}"),
            }
        }

        // Unknown run ids are protocol errors; a run without budget is too.
        let r = handle_request(r#"{"v":1,"op":"status","run_id":424242}"#, &s, &stop);
        assert_eq!(
            r.get("error").and_then(|e| e.get("kind")).and_then(Json::as_str),
            Some("protocol")
        );
        let r = handle_request(r#"{"v":1,"op":"run"}"#, &s, &stop);
        assert_eq!(r.get("ok"), Some(&Json::Bool(false)));
    }

    #[test]
    fn shape_op_reports_the_winning_composition() {
        let s = session();
        let stop = AtomicBool::new(false);
        // A generous deadline (an hour of virtual time) is trivially
        // satisfiable on the quick cluster.
        let r = handle_request(
            r#"{"v":1,"op":"shape","deadline":3600,"partitioner":"heuristic"}"#,
            &s,
            &stop,
        );
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{}", r.to_string_compact());
        assert!(r.get("instances").unwrap().as_u64().unwrap() >= 1);
        assert!(r.get("predicted_cost").unwrap().as_f64().unwrap() > 0.0);
        assert_eq!(r.get("deadline").unwrap().as_f64(), Some(3600.0));
        let shape = r.get("shape").unwrap().as_obj().unwrap();
        assert!(!shape.is_empty());
        // Malformed shape requests are protocol errors.
        let r = handle_request(r#"{"v":1,"op":"shape"}"#, &s, &stop);
        assert_eq!(r.get("ok"), Some(&Json::Bool(false)));
    }

    #[test]
    fn evaluate_and_pareto_report_the_session_composition() {
        let s = session();
        let stop = AtomicBool::new(false);
        let r = handle_request(
            r#"{"v":1,"op":"evaluate","partitioner":"heuristic","budget":null}"#,
            &s,
            &stop,
        );
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{}", r.to_string_compact());
        let shape = r.get("shape").unwrap().as_obj().unwrap();
        assert_eq!(shape.len(), 3, "quick cluster has one instance per type");
        assert!(r.get("preemptions").unwrap().as_u64().is_some());
        let r = handle_request(r#"{"v":1,"op":"pareto","partitioner":"heuristic"}"#, &s, &stop);
        assert!(r.get("shape").unwrap().as_obj().is_some());
    }

    #[test]
    fn job_ops_error_without_the_scheduler() {
        let s = session();
        let stop = AtomicBool::new(false);
        for req in [
            r#"{"v":1,"op":"submit","tasks":1,"budget":5}"#,
            r#"{"v":1,"op":"jobs"}"#,
            r#"{"v":1,"op":"cancel","job_id":1}"#,
        ] {
            let r = handle_request(req, &s, &stop);
            assert_eq!(r.get("ok"), Some(&Json::Bool(false)), "{req}");
            assert_eq!(
                r.get("error").and_then(|e| e.get("kind")).and_then(Json::as_str),
                Some("config"),
                "{req}"
            );
        }
        // Without the scheduler, ping carries no scheduler block.
        let r = handle_request(r#"{"v":1,"op":"ping"}"#, &s, &stop);
        assert!(r.get("scheduler").is_none());
    }

    #[test]
    fn submit_jobs_cancel_roundtrip() {
        use crate::coordinator::scheduler::SchedulerConfig;
        let s = SessionBuilder::quick()
            .partitioner("heuristic")
            .scheduler(SchedulerConfig { enabled: true, ..Default::default() })
            .build()
            .unwrap();
        let stop = AtomicBool::new(false);
        // Unknown payoff names are typed workload errors listing families.
        let r = handle_request(
            r#"{"v":1,"op":"submit","tasks":1,"budget":5,"payoff":"swaption"}"#,
            &s,
            &stop,
        );
        assert_eq!(
            r.get("error").and_then(|e| e.get("kind")).and_then(Json::as_str),
            Some("workload")
        );
        assert!(r
            .get("error")
            .and_then(|e| e.get("message"))
            .and_then(Json::as_str)
            .unwrap()
            .contains("asian"));
        // A good submit is accepted and tracked.
        let r = handle_request(
            r#"{"v":1,"op":"submit","tasks":2,"payoff":"european","budget":1000}"#,
            &s,
            &stop,
        );
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{}", r.to_string_compact());
        let id = r.get("job_id").unwrap().as_u64().unwrap();
        // Poll the jobs op until terminal.
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(60);
        loop {
            let r =
                handle_request(&format!(r#"{{"v":1,"op":"jobs","job_id":{id}}}"#), &s, &stop);
            assert_eq!(r.get("ok"), Some(&Json::Bool(true)));
            match r.get("status").unwrap().as_str() {
                Some("queued") | Some("running") => {
                    assert!(std::time::Instant::now() < deadline, "job never finished");
                    std::thread::sleep(std::time::Duration::from_millis(5));
                }
                Some("done") => {
                    assert_eq!(r.get("slo_met"), Some(&Json::Bool(true)));
                    assert!(r.get("cost").unwrap().as_f64().unwrap() > 0.0);
                    assert_eq!(r.get("prices").unwrap().as_u64(), Some(2));
                    break;
                }
                other => panic!("unexpected job state {other:?}"),
            }
        }
        // The jobs listing covers it; cancelling a done job reports false.
        let r = handle_request(r#"{"v":1,"op":"jobs"}"#, &s, &stop);
        assert_eq!(r.get("jobs").unwrap().as_arr().unwrap().len(), 1);
        let r = handle_request(&format!(r#"{{"v":1,"op":"cancel","job_id":{id}}}"#), &s, &stop);
        assert_eq!(r.get("cancelled"), Some(&Json::Bool(false)));
        // Unknown ids are protocol errors; ping now reports scheduler stats.
        let r = handle_request(r#"{"v":1,"op":"cancel","job_id":424242}"#, &s, &stop);
        assert_eq!(
            r.get("error").and_then(|e| e.get("kind")).and_then(Json::as_str),
            Some("protocol")
        );
        let r = handle_request(r#"{"v":1,"op":"ping"}"#, &s, &stop);
        let sched = r.get("scheduler").unwrap();
        assert_eq!(sched.get("submitted").unwrap().as_u64(), Some(1));
        assert_eq!(sched.get("completed").unwrap().as_u64(), Some(1));
        assert!(sched.get("epochs").unwrap().as_u64().unwrap() >= 1);
    }

    #[test]
    fn submit_batch_mixes_inline_results() {
        use crate::coordinator::scheduler::SchedulerConfig;
        let s = SessionBuilder::quick()
            .partitioner("heuristic")
            .scheduler(SchedulerConfig { enabled: true, ..Default::default() })
            .build()
            .unwrap();
        let stop = AtomicBool::new(false);
        // Good, bad-payoff, good: the bad entry errors inline, its
        // neighbours get job ids, order is preserved.
        let r = handle_request(
            r#"{"v":1,"op":"submit_batch","jobs":[
                {"tasks":1,"payoff":"european","budget":1000},
                {"tasks":1,"payoff":"swaption","budget":1000},
                {"tasks":1,"payoff":"asian","budget":1000}]}"#,
            &s,
            &stop,
        );
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{}", r.to_string_compact());
        let results = r.get("results").unwrap().as_arr().unwrap();
        assert_eq!(results.len(), 3);
        assert_eq!(results[0].get("ok"), Some(&Json::Bool(true)));
        assert_eq!(results[2].get("ok"), Some(&Json::Bool(true)));
        let id0 = results[0].get("job_id").unwrap().as_u64().unwrap();
        let id2 = results[2].get("job_id").unwrap().as_u64().unwrap();
        assert!(id2 > id0, "ids assigned in entry order");
        assert_eq!(results[1].get("ok"), Some(&Json::Bool(false)));
        assert_eq!(
            results[1].get("error").and_then(|e| e.get("kind")).and_then(Json::as_str),
            Some("workload")
        );
        // Both accepted jobs are tracked.
        let r = handle_request(r#"{"v":1,"op":"jobs"}"#, &s, &stop);
        assert_eq!(r.get("jobs").unwrap().as_arr().unwrap().len(), 2);
        // Without the scheduler the whole request is a typed config error.
        let plain = session();
        let r = handle_request(
            r#"{"v":1,"op":"submit_batch","jobs":[{"tasks":1,"deadline":10}]}"#,
            &plain,
            &stop,
        );
        assert_eq!(
            r.get("error").and_then(|e| e.get("kind")).and_then(Json::as_str),
            Some("config")
        );
    }

    #[test]
    fn metrics_op_snapshots_the_session_registry() {
        let s = session();
        let stop = AtomicBool::new(false);
        // One solve populates the solve-latency histogram + cache counters.
        let r = handle_request(
            r#"{"v":1,"op":"partition","partitioner":"heuristic","budget":null}"#,
            &s,
            &stop,
        );
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{}", r.to_string_compact());
        let r = handle_request(r#"{"v":1,"op":"metrics"}"#, &s, &stop);
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{}", r.to_string_compact());
        let m = r.get("metrics").unwrap();
        let solve = m.get("solve_latency_secs").unwrap();
        assert_eq!(solve.get("type").unwrap().as_str(), Some("histogram"));
        assert!(solve.get("values").unwrap().get("strategy=heuristic").is_some());
        // Serve's own per-op counters ride the same snapshot.
        let reqs = m.get("serve_requests_total").unwrap().get("values").unwrap();
        assert!(reqs.get("op=partition").unwrap().as_u64().unwrap() >= 1);
        // A filter restricts by name substring; cache counters mirror ping's.
        let r = handle_request(r#"{"v":1,"op":"metrics","filter":"cache_"}"#, &s, &stop);
        let m = r.get("metrics").unwrap().as_obj().unwrap();
        assert!(!m.is_empty() && m.keys().all(|k| k.contains("cache_")));
        let misses = m["cache_misses_total"].get("values").unwrap();
        assert_eq!(misses.get("").unwrap().as_u64(), Some(1));
        // Bad filter types are protocol errors.
        let r = handle_request(r#"{"v":1,"op":"metrics","filter":7}"#, &s, &stop);
        assert_eq!(r.get("ok"), Some(&Json::Bool(false)));
    }

    #[test]
    fn shutdown_sets_flag() {
        let s = session();
        let stop = AtomicBool::new(false);
        let r = handle_request(r#"{"v":1,"op":"shutdown"}"#, &s, &stop);
        assert_eq!(r.get("shutdown"), Some(&Json::Bool(true)));
        assert!(stop.load(Ordering::SeqCst));
    }
}
