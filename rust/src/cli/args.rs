//! Minimal CLI argument parser (clap is unavailable offline).
//!
//! Grammar: `cloudshapes <subcommand> [positionals] [--flag [value]] ...`
//! Flags without a following value (or followed by another flag) are
//! booleans.

use std::collections::BTreeMap;

use crate::api::error::{CloudshapesError, Result};

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positionals: Vec<String>,
    flags: BTreeMap<String, String>,
}

impl Args {
    pub fn parse(argv: &[String]) -> Args {
        let mut out = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(name) = a.strip_prefix("--") {
                // --key=value or --key value or boolean --key
                if let Some((k, v)) = name.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    out.flags.insert(name.to_string(), argv[i + 1].clone());
                    i += 1;
                } else {
                    out.flags.insert(name.to_string(), "true".to_string());
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(a.clone());
            } else {
                out.positionals.push(a.clone());
            }
            i += 1;
        }
        out
    }

    pub fn flag(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(String::as_str)
    }

    pub fn flag_bool(&self, name: &str) -> bool {
        matches!(self.flag(name), Some("true") | Some("1") | Some("yes"))
    }

    pub fn flag_f64(&self, name: &str) -> Result<Option<f64>> {
        self.flag(name)
            .map(|v| {
                v.parse::<f64>().map_err(|_| {
                    CloudshapesError::config(format!("--{name} expects a number, got '{v}'"))
                })
            })
            .transpose()
    }

    pub fn flag_usize(&self, name: &str) -> Result<Option<usize>> {
        self.flag(name)
            .map(|v| {
                v.parse::<usize>().map_err(|_| {
                    CloudshapesError::config(format!("--{name} expects an integer, got '{v}'"))
                })
            })
            .transpose()
    }

    /// As [`flag_usize`](Self::flag_usize) but rejects 0 — for counts where
    /// zero is meaningless (`--workers`, `--levels`).
    pub fn flag_positive_usize(&self, name: &str) -> Result<Option<usize>> {
        match self.flag_usize(name)? {
            Some(0) => Err(CloudshapesError::config(format!("--{name} must be >= 1"))),
            other => Ok(other),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(&s.split_whitespace().map(String::from).collect::<Vec<_>>())
    }

    #[test]
    fn parses_subcommand_and_positionals() {
        let a = parse("table 4 extra");
        assert_eq!(a.subcommand.as_deref(), Some("table"));
        assert_eq!(a.positionals, vec!["4", "extra"]);
    }

    #[test]
    fn parses_flags_all_styles() {
        let a = parse("run --budget 2.5 --levels=7 --quick");
        assert_eq!(a.flag_f64("budget").unwrap(), Some(2.5));
        assert_eq!(a.flag_usize("levels").unwrap(), Some(7));
        assert!(a.flag_bool("quick"));
        assert!(!a.flag_bool("missing"));
    }

    #[test]
    fn bad_numbers_error() {
        let a = parse("run --budget lots");
        assert!(a.flag_f64("budget").is_err());
    }

    #[test]
    fn positive_usize_rejects_zero() {
        let a = parse("run --workers 0");
        assert!(a.flag_positive_usize("workers").is_err());
        let a = parse("run --workers 4");
        assert_eq!(a.flag_positive_usize("workers").unwrap(), Some(4));
        let a = parse("run");
        assert_eq!(a.flag_positive_usize("workers").unwrap(), None);
    }

    #[test]
    fn empty_argv() {
        let a = Args::parse(&[]);
        assert!(a.subcommand.is_none());
    }
}
