//! Command-line interface: the launcher every deliverable runs through.
//!
//! Every subcommand builds an [`api::TradeoffSession`](crate::api) from the
//! experiment config and works through it — the CLI owns flag parsing and
//! printing, nothing else.

pub mod args;
pub mod serve;

use std::path::Path;

use crate::api::error::{CloudshapesError, Result};
use crate::api::{SessionBuilder, TradeoffSession};
use crate::config::ExperimentConfig;
use crate::report::{self, Experiment};
use crate::util::table::fnum;

use args::Args;

const USAGE: &str = "\
cloudshapes — Pareto-optimal performance-cost partitioning for heterogeneous IaaS
(reproduction of Inggs et al., 'Seeing Shapes in Clouds', 2015)

USAGE: cloudshapes <command> [options]

COMMANDS
  info                     Print cluster + workload summary
  bench                    Run the benchmarking procedure; report model fits
  partition                Partition the workload at a budget
      --partitioner NAME   milp | heuristic | olb|met|mct|min-min|max-min|sufferage
      --budget DOLLARS     Cost constraint C_k (omit for unconstrained)
  pareto                   Generate the latency-cost trade-off curve
      --partitioner NAME   (default milp)
      --levels N           Budget levels (default from config)
      --csv PATH           Also write the curve as CSV
  shape                    Optimise the cluster COMPOSITION (catalogue ->
                           instance counts -> allocation); prints the
                           winning shape and its predicted objectives
      --deadline SECS      Minimise billed cost within a deadline, or
      --budget DOLLARS     minimise makespan within a budget (exactly one)
      --partitioner NAME   Inner per-composition strategy (default milp)
  run                      Partition AND execute on the cluster
      --budget DOLLARS
      --partitioner NAME
      --watch              Live progress view of the chunked executor
                           (chunks done, retries, migrations, task prices)
  jobs                     Online-scheduler demo: submit jobs with SLOs to
                           this session's scheduler and watch them complete
      --count N            Jobs to submit (default 4; SLOs alternate
                           deadline/budget, payoff families rotate)
      --deadline SECS      Deadline SLO value (virtual secs, default 1e6)
      --job-budget DOLLARS Budget SLO value (default 1000)
      --tasks N            Tasks per job (default 2)
      --accuracy DOLLARS   Per-task CI half-width (default 0.05)
  table <1|2|3|4>          Regenerate a paper table
  fig <1|2|3>              Regenerate a paper figure (ASCII + optional CSV)
      --csv PATH
  serve                    JSON-over-TCP coordinator, protocol v1 (see --port)
      --port PORT          (default 7741)
      --scheduler          Accept online pricing jobs (submit/jobs/cancel
                           ops; see docs/PROTOCOL.md)
  metrics                  Print the telemetry snapshot as pretty JSON
                           (metric catalogue: docs/OBSERVABILITY.md)
      --evaluate           Partition + execute first, so the snapshot holds
                           solve/chunk latency histograms, not just zeros
      --budget DOLLARS     Budget for that evaluate (omit for unconstrained)
      --filter SUB         Only metrics whose name contains SUB
  trace                    Record one partition + execute as tracing spans
                           and export them as Chrome-trace JSON (loadable
                           in about://tracing or Perfetto)
      --out PATH           Write the trace there (default: print to stdout)
      --budget DOLLARS

COMMON OPTIONS
  --config PATH            TOML experiment config (configs/*.toml)
  --quick                  Small cluster + small workload preset
  --workers N              Worker threads for BOTH the MILP solver (node LPs
                           per round) and the chunked executor (chunk
                           dispatch); default from config, 1 = sequential
";

/// Entry point; returns the process exit code.
pub fn main(argv: &[String]) -> i32 {
    let args = Args::parse(argv);
    match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    }
}

fn load_config(args: &Args) -> Result<ExperimentConfig> {
    let mut cfg = if args.flag_bool("quick") {
        ExperimentConfig::quick()
    } else if let Some(path) = args.flag("config") {
        ExperimentConfig::load(Path::new(path))?
    } else {
        ExperimentConfig::default()
    };
    if let Some(levels) = args.flag_usize("levels")? {
        cfg.sweep.levels = levels;
    }
    if let Some(workers) = args.flag_positive_usize("workers")? {
        // One knob governs solver and executor parallelism.
        cfg.milp.workers = workers;
        cfg.executor.workers = workers;
    }
    if args.flag_bool("native") {
        cfg.cluster.with_native = true;
    }
    if args.flag_bool("scheduler") {
        // `serve --scheduler` (and anything else that wants job ops).
        cfg.scheduler.enabled = true;
    }
    Ok(cfg)
}

/// Build the session every subcommand works through. The `--partitioner`
/// flag picks the default strategy; unknown names fail here, before the
/// (expensive) benchmarking step.
fn session(args: &Args) -> Result<TradeoffSession> {
    let name = args.flag("partitioner").unwrap_or("milp").to_string();
    SessionBuilder::from_config(load_config(args)?).partitioner(&name).build()
}

fn run(args: &Args) -> Result<()> {
    let Some(cmd) = args.subcommand.as_deref() else {
        println!("{USAGE}");
        return Ok(());
    };
    match cmd {
        "help" | "--help" => {
            println!("{USAGE}");
            Ok(())
        }
        "info" => cmd_info(args),
        "bench" => cmd_bench(args),
        "partition" => cmd_partition(args),
        "pareto" => cmd_pareto(args),
        "shape" => cmd_shape(args),
        "run" => cmd_run(args),
        "jobs" => cmd_jobs(args),
        "table" => cmd_table(args),
        "fig" => cmd_fig(args),
        "metrics" => cmd_metrics(args),
        "trace" => cmd_trace(args),
        "serve" => serve::cmd_serve(args, || session(args)),
        other => Err(CloudshapesError::config(format!(
            "unknown command '{other}' (try `cloudshapes help`)"
        ))),
    }
}

fn cmd_info(args: &Args) -> Result<()> {
    let s = session(args)?;
    let e = s.experiment();
    println!("cluster: {} platforms", e.cluster.len());
    for (cat, n) in report::tables::category_counts(&e.cluster) {
        println!("  {:>4} x{}", cat.name(), n);
    }
    println!("shape: {}", composition_str(&s.composition()));
    println!(
        "workload: {} tasks, {} total simulations, {:.3e} total FLOPs",
        e.workload.len(),
        e.workload.total_sims(),
        e.workload.total_flops()
    );
    println!("partitioners: {}", s.partitioner_names().join(", "));
    let m = s.models();
    for i in 0..m.mu {
        println!(
            "  solo {:>16}: {:>12.1} s  ${:>8.3}",
            m.platform_names[i],
            m.solo_latency(i),
            m.solo_cost(i)
        );
    }
    Ok(())
}

fn cmd_bench(args: &Args) -> Result<()> {
    let s = session(args)?;
    let m = s.models();
    println!("fitted {} (platform, task) latency models", m.mu * m.tau);
    let mut r2_min: f64 = 1.0;
    for i in 0..m.mu {
        for j in 0..m.tau {
            r2_min = r2_min.min(m.model(i, j).r_squared);
        }
    }
    println!("worst fit R² = {r2_min:.6}");
    println!("{}", report::tables::table2_for(s.experiment()).render());
    Ok(())
}

fn cmd_partition(args: &Args) -> Result<()> {
    let s = session(args)?;
    let p = s.partition(args.flag_f64("budget")?)?;
    let m = s.models();
    println!("partitioner: {}", p.partitioner);
    println!("cluster shape: {}", composition_str(&s.composition()));
    println!("budget: {:?}", p.budget);
    println!("predicted makespan: {} s", fnum(p.predicted_latency_s, 1));
    println!("predicted cost:     ${}", fnum(p.predicted_cost, 3));
    println!("platforms used: {}", p.alloc.used_platforms().len());
    for i in p.alloc.used_platforms() {
        let share: f64 = (0..m.tau).map(|j| p.alloc.get(i, j)).sum::<f64>() / m.tau as f64;
        println!(
            "  {:>16}: mean share {:>5.1}%  latency {:>10.1}s  cost ${:.3}",
            m.platform_names[i],
            share * 100.0,
            m.platform_latency(&p.alloc, i),
            m.platform_cost(&p.alloc, i),
        );
    }
    Ok(())
}

fn cmd_pareto(args: &Args) -> Result<()> {
    let s = session(args)?;
    let curve = s.pareto_frontier()?;
    println!(
        "{}: C_L = ${}, C_U = ${}",
        curve.partitioner,
        fnum(curve.c_lower, 3),
        fnum(curve.c_upper, 3)
    );
    println!("{:>12} {:>14} {:>10}", "budget", "latency (s)", "cost ($)");
    for p in &curve.points {
        println!(
            "{:>12} {:>14} {:>10}",
            p.budget.map(|b| fnum(b, 3)).unwrap_or_else(|| "uncon".into()),
            fnum(p.latency, 1),
            fnum(p.cost, 3)
        );
    }
    if let Some(path) = args.flag("csv") {
        let mut csv = String::from("budget,latency_s,cost\n");
        for p in &curve.points {
            csv.push_str(&format!(
                "{},{},{}\n",
                p.budget.map(|b| b.to_string()).unwrap_or_else(|| "unconstrained".into()),
                p.latency,
                p.cost
            ));
        }
        std::fs::write(path, csv)
            .map_err(|e| CloudshapesError::config(format!("writing {path}: {e}")))?;
        println!("wrote {path}");
    }
    Ok(())
}

/// `4x virtex6 + 8x stratix5-gsd8 + ...` — the human form of a composition.
fn composition_str(composition: &[(String, usize)]) -> String {
    composition
        .iter()
        .map(|(name, count)| format!("{count}x {name}"))
        .collect::<Vec<_>>()
        .join(" + ")
}

fn cmd_shape(args: &Args) -> Result<()> {
    use crate::coordinator::ShapeObjective;
    let s = session(args)?;
    let objective = match (args.flag_f64("deadline")?, args.flag_f64("budget")?) {
        (Some(d), None) => ShapeObjective::Deadline(d),
        (None, Some(b)) => ShapeObjective::Budget(b),
        _ => {
            return Err(CloudshapesError::config(
                "shape needs exactly one of --deadline SECS or --budget DOLLARS",
            ))
        }
    };
    let shape = s.optimize_shape(None, objective)?;
    println!("inner partitioner: {}", shape.partitioner);
    match shape.objective {
        ShapeObjective::Deadline(d) => println!("objective: min cost, deadline {d} s"),
        ShapeObjective::Budget(b) => println!("objective: min makespan, budget ${b}"),
    }
    let point = &shape.outcome.point;
    println!("winning shape: {}", composition_str(&shape.composition()));
    println!(
        "  {} instances, predicted makespan {} s, predicted cost ${}",
        point.counts.iter().sum::<usize>(),
        fnum(point.latency, 1),
        fnum(point.cost, 3)
    );
    println!(
        "  outer bound ${} ({} outer nodes)",
        fnum(shape.outcome.outer_bound, 3),
        shape.outcome.nodes
    );
    let m = s.experiment().type_models().replicate(&point.counts)?;
    for i in point.alloc.used_platforms() {
        println!(
            "  {:>20}: latency {:>10.1}s  cost ${:.3}",
            point.instance_names[i],
            m.platform_latency(&point.alloc, i),
            m.platform_cost(&point.alloc, i),
        );
    }
    println!(
        "(current session shape: {} — rebuild with [catalogue] counts to rent the \
         winning one)",
        composition_str(&s.composition())
    );
    Ok(())
}

fn cmd_run(args: &Args) -> Result<()> {
    let s = session(args)?;
    let budget = args.flag_f64("budget")?;
    let ev = if args.flag_bool("watch") {
        let mut watch = WatchView::default();
        s.evaluate_with_events(None, budget, &mut |e| watch.on(e))?
    } else {
        s.evaluate(budget)?
    };
    let (p, rep) = (&ev.partition, &ev.execution);
    println!("partitioner: {}  budget: {:?}", p.partitioner, p.budget);
    println!(
        "makespan: predicted {} s, measured {} s ({:+.1}%)",
        fnum(p.predicted_latency_s, 1),
        fnum(rep.makespan_secs, 1),
        (rep.makespan_secs / p.predicted_latency_s - 1.0) * 100.0
    );
    println!(
        "cost:     predicted ${}, measured ${} ({:+.1}%)",
        fnum(p.predicted_cost, 3),
        fnum(rep.cost, 3),
        (rep.cost / p.predicted_cost - 1.0) * 100.0
    );
    println!(
        "chunks: {}  retries: {}  migrations: {}  preemptions: {}  failures: {}",
        rep.chunks, rep.retries, rep.migrations, rep.preemptions, rep.failures
    );
    let priced = rep.prices.iter().flatten().count();
    println!("tasks priced: {priced}/{}", s.workload().len());
    Ok(())
}

/// `cloudshapes jobs`: the online-scheduler demo. Submits `--count` jobs
/// with alternating deadline/budget SLOs (payoff families rotating) to this
/// session's scheduler, then watches them to completion, printing state
/// transitions and the re-fit trajectory.
fn cmd_jobs(args: &Args) -> Result<()> {
    use crate::coordinator::scheduler::{JobSpec, JobState, Slo};
    use crate::workload::Payoff;

    let mut cfg = load_config(args)?;
    cfg.scheduler.enabled = true;
    let name = args.flag("partitioner").unwrap_or("milp").to_string();
    let s = SessionBuilder::from_config(cfg).partitioner(&name).build()?;

    let count = args.flag_positive_usize("count")?.unwrap_or(4);
    let tasks = args.flag_positive_usize("tasks")?.unwrap_or(2);
    let accuracy = args.flag_f64("accuracy")?.unwrap_or(0.05);
    let deadline = args.flag_f64("deadline")?.unwrap_or(1e6);
    let job_budget = args.flag_f64("job-budget")?.unwrap_or(1000.0);
    // A mixed job first, then one single-family job per payoff family —
    // derived from Payoff::ALL so new families rotate in automatically.
    let families: Vec<Option<Payoff>> =
        std::iter::once(None).chain(Payoff::ALL.into_iter().map(Some)).collect();

    // Build the whole book first, then submit it as one batch — the same
    // path the serve plane's `submit_batch` op takes, so a shed entry
    // (overload) is reported per job instead of aborting the demo.
    let mut specs = Vec::with_capacity(count);
    let mut slos = Vec::with_capacity(count);
    for k in 0..count {
        let slo = if k % 2 == 0 { Slo::Deadline(deadline) } else { Slo::Budget(job_budget) };
        specs.push(JobSpec::generate(
            families[k % families.len()],
            tasks,
            accuracy,
            1 + k as u64,
            slo,
        )?);
        slos.push(slo);
    }
    let mut ids = Vec::with_capacity(count);
    for (slo, outcome) in slos.iter().zip(s.submit_jobs(specs)?) {
        match outcome {
            Ok(id) => {
                println!("submitted job {id}: {tasks} tasks, SLO {slo:?}");
                ids.push(id);
            }
            Err(e) => println!("submit refused ({}): {}", e.kind(), e.message()),
        }
    }

    let mut last: Vec<Option<String>> = vec![None; ids.len()];
    loop {
        let mut all_terminal = true;
        for (k, &id) in ids.iter().enumerate() {
            let Some(st) = s.job_status(id)? else { continue };
            let line = format!(
                "job {id}: {:<9} {:>3}% of {} sims, {} epochs, ${:.3}",
                st.state.name(),
                if st.sims_total > 0 { st.sims_done * 100 / st.sims_total } else { 0 },
                st.sims_total,
                st.epochs,
                st.cost
            );
            if last[k].as_deref() != Some(line.as_str()) {
                println!("{line}");
                last[k] = Some(line);
            }
            all_terminal &= st.state.is_terminal();
        }
        if all_terminal {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(20));
    }

    println!("--- summary ---");
    for &id in &ids {
        let st = s.job_status(id)?.expect("job tracked");
        let met = match st.slo_met {
            Some(true) => "SLO met",
            Some(false) => "SLO MISSED",
            None => "SLO unknown",
        };
        let failed = match &st.state {
            JobState::Failed(msg) => format!(" ({msg})"),
            _ => String::new(),
        };
        println!(
            "job {id}: {} — {met}, finished at {:.1}s virtual, ${:.3} attributed{failed}",
            st.state.name(),
            st.finished_s.unwrap_or(f64::NAN),
            st.cost
        );
    }
    let stats = s.scheduler_stats()?;
    println!(
        "scheduler: {} epochs ({} solves, {} warm reuses), model error {} -> {}",
        stats.epochs,
        stats.resolves,
        stats.warm_reuses,
        stats.first_model_error.map(|e| format!("{e:.3}")).unwrap_or_else(|| "-".into()),
        stats.last_model_error.map(|e| format!("{e:.3}")).unwrap_or_else(|| "-".into()),
    );
    Ok(())
}

/// `run --watch`: a line-oriented progress view over the executor's event
/// stream (progress at ~10% strides; every failure, migration and task
/// price as it lands).
#[derive(Default)]
struct WatchView {
    next_pct: u64,
}

impl WatchView {
    fn on(&mut self, ev: &crate::coordinator::ExecEvent) {
        use crate::coordinator::ExecEvent as E;
        match ev {
            E::Started { chunks, tasks } => {
                self.next_pct = 10;
                println!("watch: {chunks} chunks across {tasks} tasks");
            }
            E::ChunkDone { done, total, .. } => {
                let pct = (*done as u64 * 100) / (*total).max(1) as u64;
                if pct >= self.next_pct || done == total {
                    self.next_pct = pct + 10;
                    println!("watch: {pct:>3}%  ({done}/{total} chunks)");
                }
            }
            E::ChunkFailed { platform, task, attempt, will_retry, rehomed_to, .. } => {
                let retry = match (will_retry, rehomed_to) {
                    (false, _) => "giving up".to_string(),
                    (true, Some(p)) => format!("retrying on platform {p}"),
                    (true, None) => "retrying".to_string(),
                };
                println!(
                    "watch: chunk of task {task} failed on platform {platform} \
                     (attempt {attempt}) — {retry}"
                );
            }
            E::ChunkMigrated { from, to, task, .. } => {
                println!("watch: rebalanced a task-{task} chunk: platform {from} -> {to}");
            }
            E::LanePreempted { platform, at_secs, drained } => {
                println!(
                    "watch: spot lane {platform} preempted at {at_secs:.1}s — \
                     {drained} queued chunks re-homed"
                );
            }
            E::TaskPriced { task, estimate, partial } => {
                let tag = if *partial { " (partial)" } else { "" };
                println!(
                    "watch: task {task} priced {:.4} ± {:.4}{tag}",
                    estimate.price, estimate.std_error
                );
            }
            E::Finished { makespan_secs, cost, failures } => {
                println!(
                    "watch: finished — makespan {:.1}s, cost ${:.3}, {failures} failures",
                    makespan_secs, cost
                );
            }
        }
    }
}

/// `cloudshapes metrics`: snapshot the session's metrics registry (merged
/// over the process-global one) as pretty JSON. With `--evaluate` a
/// partition + execute runs first so the histograms carry real samples.
fn cmd_metrics(args: &Args) -> Result<()> {
    let s = session(args)?;
    if args.flag_bool("evaluate") {
        s.evaluate(args.flag_f64("budget")?)?;
    }
    println!("{}", s.metrics(args.flag("filter")).to_string_pretty());
    Ok(())
}

/// `cloudshapes trace --out PATH`: clear the span rings, run one partition
/// + execute, and export exactly that run's spans as Chrome-trace JSON.
fn cmd_trace(args: &Args) -> Result<()> {
    use crate::obs::trace;
    let s = session(args)?;
    trace::clear();
    s.evaluate(args.flag_f64("budget")?)?;
    let trace_json = trace::chrome_trace();
    let spans =
        trace_json.get("traceEvents").and_then(|e| e.as_arr()).map(Vec::len).unwrap_or(0);
    match args.flag("out") {
        Some(path) => {
            std::fs::write(path, trace_json.to_string_pretty())
                .map_err(|e| CloudshapesError::config(format!("writing {path}: {e}")))?;
            println!("wrote {path} ({spans} spans)");
        }
        None => println!("{}", trace_json.to_string_pretty()),
    }
    Ok(())
}

fn cmd_table(args: &Args) -> Result<()> {
    let which = args
        .positionals
        .first()
        .ok_or_else(|| CloudshapesError::config("table needs a number: 1..4"))?
        .as_str();
    match which {
        "1" => println!("{}", report::table1().render()),
        "3" => println!("{}", report::table3().render()),
        "2" => {
            let s = session(args)?;
            println!("{}", report::tables::table2_for(s.experiment()).render());
        }
        "4" => {
            let s = session(args)?;
            println!("{}", report::table4(s.models(), &s.config().milp)?.render());
        }
        other => return Err(CloudshapesError::config(format!("unknown table '{other}'"))),
    }
    Ok(())
}

fn cmd_fig(args: &Args) -> Result<()> {
    let which = args
        .positionals
        .first()
        .ok_or_else(|| CloudshapesError::config("fig needs a number: 1..3"))?
        .as_str();
    let s = session(args)?;
    let e: &Experiment = s.experiment();
    let csv: Option<String> = match which {
        "1" => {
            let (plot, _) = report::fig1(e)?;
            println!("{}", plot.render());
            Some(plot.to_csv())
        }
        "2" => {
            let (plot, _) = report::fig2(e, &[1.0, 2.0, 5.0, 10.0, 20.0, 50.0]);
            println!("{}", plot.render());
            Some(plot.to_csv())
        }
        "3" => {
            let (plot, points) = report::fig3(e)?;
            println!("{}", plot.render());
            Some(report::fig3_csv(&points))
        }
        other => return Err(CloudshapesError::config(format!("unknown fig '{other}'"))),
    };
    if let (Some(path), Some(csv)) = (args.flag("csv"), csv) {
        std::fs::write(path, csv)
            .map_err(|e| CloudshapesError::config(format!("writing {path}: {e}")))?;
        println!("wrote {path}");
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(String::from).collect()
    }

    #[test]
    fn help_and_empty_succeed() {
        assert_eq!(main(&argv("help")), 0);
        assert_eq!(main(&[]), 0);
    }

    #[test]
    fn unknown_command_fails() {
        assert_eq!(main(&argv("frobnicate")), 1);
    }

    #[test]
    fn static_tables_render() {
        assert_eq!(main(&argv("table 1")), 0);
        assert_eq!(main(&argv("table 3")), 0);
        assert_eq!(main(&argv("table 99")), 1);
    }

    #[test]
    fn quick_info_and_partition() {
        assert_eq!(main(&argv("info --quick")), 0);
        assert_eq!(main(&argv("partition --quick --partitioner heuristic")), 0);
        assert_eq!(main(&argv("partition --quick --partitioner nope")), 1);
    }

    #[test]
    fn workers_flag_is_wired_and_validated() {
        assert_eq!(main(&argv("partition --quick --partitioner heuristic --workers 2")), 0);
        assert_eq!(main(&argv("partition --quick --workers 0")), 1);
    }

    #[test]
    fn run_watch_streams_progress() {
        assert_eq!(main(&argv("run --quick --partitioner heuristic --watch")), 0);
    }

    #[test]
    fn jobs_command_submits_and_completes() {
        assert_eq!(
            main(&argv("jobs --quick --partitioner heuristic --count 2 --tasks 1")),
            0
        );
        assert_eq!(main(&argv("jobs --quick --count 0")), 1);
    }

    #[test]
    fn metrics_command_prints_snapshot() {
        assert_eq!(main(&argv("metrics --quick --partitioner heuristic --evaluate")), 0);
        assert_eq!(main(&argv("metrics --quick --partitioner heuristic --filter cache_")), 0);
    }

    #[test]
    fn trace_command_writes_chrome_json() {
        use crate::util::json::Json;
        // cmd_trace clears the process-global span rings — serialise with
        // the trace unit tests, which assert on their own buffered spans.
        let _g = crate::obs::trace::test_guard();
        let path = std::env::temp_dir().join("cloudshapes_cli_trace.json");
        let arg = format!("trace --quick --partitioner heuristic --out {}", path.display());
        assert_eq!(main(&argv(&arg)), 0);
        let text = std::fs::read_to_string(&path).unwrap();
        let trace = Json::parse(&text).expect("well-formed chrome trace");
        let events = trace.get("traceEvents").unwrap().as_arr().unwrap();
        assert!(
            events.iter().any(|e| e.get("name").and_then(Json::as_str) == Some("solve")),
            "traced run exports its solve span"
        );
        assert!(
            events.iter().any(|e| e.get("name").and_then(Json::as_str) == Some("execute")),
            "traced run exports its execute span"
        );
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn shape_command_optimises_composition() {
        assert_eq!(
            main(&argv("shape --quick --partitioner heuristic --deadline 36000")),
            0
        );
        // Exactly one constraint is required.
        assert_eq!(main(&argv("shape --quick")), 1);
        assert_eq!(main(&argv("shape --quick --deadline 10 --budget 1")), 1);
    }
}
