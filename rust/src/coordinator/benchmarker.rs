//! The paper's benchmarking procedure (§III.A): run every task on every
//! platform at a ladder of small-N sizes within a wall-clock budget, then
//! fit `L(N) = βN + γ` per (task, platform) with weighted least squares.
//!
//! The fitted [`ModelSet`] — not the simulator's hidden ground truth — is
//! what the partitioners consume; Fig. 2 measures how well these fits
//! extrapolate, Fig. 3 how well partitions built on them predict reality.

use crate::models::LatencyModel;
use crate::platforms::Cluster;
use crate::util::threadpool::parallel_map;
use crate::workload::Workload;

use super::objectives::ModelSet;

/// Benchmarking controls.
#[derive(Debug, Clone)]
pub struct BenchmarkConfig {
    /// N ladder per (task, platform); sizes are fractions of the task's N.
    pub ladder_fracs: Vec<f64>,
    /// Repetitions per ladder rung (averaged).
    pub reps: usize,
    /// Per-(task, platform) wall-clock budget in *platform* seconds: rungs
    /// whose predicted latency would exceed it are skipped (the paper
    /// benchmarks for "10 minutes" total on real hardware; simulated
    /// platforms are free, native ones are not).
    pub rung_budget_secs: f64,
    /// RNG seed for the benchmark executions.
    pub seed: u32,
    /// OS threads used to benchmark platforms concurrently.
    pub threads: usize,
}

impl Default for BenchmarkConfig {
    fn default() -> Self {
        BenchmarkConfig {
            // Top rung at 0.2·N caps model extrapolation at 5×: benchmark
            // noise on γ-dominated (tiny) tasks otherwise inflates the
            // fitted β arbitrarily (see the noisy-benchmark test).
            ladder_fracs: vec![1e-4, 1e-3, 1e-2, 0.05, 0.2],
            reps: 3,
            rung_budget_secs: 120.0,
            seed: 0xBEEF,
            threads: 8,
        }
    }
}

/// Raw benchmark samples for one (platform, task) pair.
#[derive(Debug, Clone)]
pub struct BenchSamples {
    pub platform: usize,
    pub task: usize,
    /// (n, observed latency secs).
    pub samples: Vec<(u64, f64)>,
}

/// Benchmark result: fitted models plus the raw samples (for Fig. 2).
#[derive(Debug)]
pub struct BenchmarkReport {
    pub models: ModelSet,
    pub samples: Vec<BenchSamples>,
}

/// Run the §III.A procedure over a cluster and workload.
pub fn benchmark(cluster: &Cluster, workload: &Workload, cfg: &BenchmarkConfig) -> BenchmarkReport {
    let mu = cluster.len();
    let tau = workload.len();
    // Benchmark platforms in parallel (each platform's runs are sequential,
    // matching how a real benchmarking pass would own the device).
    let per_platform: Vec<(Vec<LatencyModel>, Vec<BenchSamples>)> = parallel_map(
        (0..mu).collect(),
        cfg.threads,
        |i| {
            let platform = cluster.platform(i);
            let mut fits = Vec::with_capacity(tau);
            let mut all_samples = Vec::with_capacity(tau);
            for (j, task) in workload.tasks.iter().enumerate() {
                let mut samples: Vec<(u64, f64)> = Vec::new();
                for frac in &cfg.ladder_fracs {
                    let n = ((task.n_sims as f64 * frac).round() as u64).max(256);
                    // Respect the rung budget using the samples so far.
                    if let Some(fit) = LatencyModel::fit(&samples) {
                        if fit.predict(n) > cfg.rung_budget_secs {
                            break;
                        }
                    }
                    let mut lat_sum = 0.0;
                    let mut ok = 0usize;
                    for rep in 0..cfg.reps {
                        let out = platform.benchmark_execute(
                            task,
                            n,
                            cfg.seed.wrapping_add(rep as u32),
                        );
                        if out.error.is_none() {
                            lat_sum += out.latency_secs;
                            ok += 1;
                        }
                    }
                    if ok > 0 {
                        samples.push((n, lat_sum / ok as f64));
                    }
                }
                let fit = LatencyModel::fit(&samples).unwrap_or_else(|| {
                    // Degenerate benchmark (e.g. all rungs failed): fall
                    // back to a pessimistic placeholder so the partitioners
                    // steer clear of the platform.
                    LatencyModel::new(1.0, 3600.0)
                });
                fits.push(fit);
                all_samples.push(BenchSamples { platform: i, task: j, samples });
            }
            (fits, all_samples)
        },
    );

    let mut latency = Vec::with_capacity(mu * tau);
    let mut samples = Vec::with_capacity(mu * tau);
    for (fits, ss) in per_platform {
        latency.extend(fits);
        samples.extend(ss);
    }
    let specs = cluster.specs();
    let models = ModelSet::new(
        latency,
        specs.iter().map(|s| s.cost_model()).collect(),
        workload.tasks.iter().map(|t| t.n_sims).collect(),
        specs.iter().map(|s| s.name.clone()).collect(),
    )
    .with_task_families(workload.tasks.iter().map(|t| t.payoff).collect());
    BenchmarkReport { models, samples }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platforms::sim::SimConfig;
    use crate::platforms::spec::small_cluster;
    use crate::workload::{generate, GeneratorConfig};

    fn setup() -> (Cluster, Workload) {
        let cluster = Cluster::simulated(&small_cluster(), &SimConfig::exact(), 42).unwrap();
        let workload = generate(&GeneratorConfig::small(4, 0.01, 7));
        (cluster, workload)
    }

    #[test]
    fn fits_recover_exact_sim_models() {
        // With exact (noise-free) simulation, the WLS fit must recover the
        // hidden ground truth almost perfectly.
        let (cluster, workload) = setup();
        let report = benchmark(&cluster, &workload, &BenchmarkConfig::default());
        assert_eq!(report.models.mu, 3);
        assert_eq!(report.models.tau, 4);
        for i in 0..3 {
            for j in 0..4 {
                let m = report.models.model(i, j);
                // Verify against a fresh execution at full N.
                let n = workload.tasks[j].n_sims;
                let truth = cluster.platform(i).benchmark_execute(&workload.tasks[j], n, 1);
                let err = m.relative_error(n, truth.latency_secs);
                assert!(err < 0.02, "platform {i} task {j}: err {err}");
            }
        }
    }

    #[test]
    fn noisy_benchmarks_still_within_10pct() {
        // Fig. 2's claim, against a noisy simulator.
        let specs = small_cluster();
        let cluster = Cluster::simulated(&specs, &SimConfig::default(), 9).unwrap();
        let workload = generate(&GeneratorConfig::small(3, 0.01, 5));
        let cfg = BenchmarkConfig { reps: 3, ..BenchmarkConfig::default() };
        let report = benchmark(&cluster, &workload, &cfg);
        let mut errs: Vec<f64> = Vec::new();
        for i in 0..cluster.len() {
            for j in 0..workload.len() {
                let m = report.models.model(i, j);
                let n = workload.tasks[j].n_sims;
                // Average several noisy observations for the "actual".
                let mut lat = 0.0;
                for r in 0..5 {
                    lat += cluster
                        .platform(i)
                        .benchmark_execute(&workload.tasks[j], n, r)
                        .latency_secs;
                }
                lat /= 5.0;
                errs.push(m.relative_error(n, lat));
            }
        }
        // Fig. 2's ~10% bound applies to work-dominated predictions; the
        // γ-dominated corner cases are noise-limited (documented in
        // benchmarker docs) but must stay bounded.
        let median = crate::util::stats::percentile(&errs, 50.0);
        let worst = crate::util::stats::percentile(&errs, 100.0);
        assert!(median < 0.10, "median extrapolation error {median}");
        assert!(worst < 0.60, "worst extrapolation error {worst}");
    }

    #[test]
    fn samples_are_recorded_for_fig2() {
        let (cluster, workload) = setup();
        let report = benchmark(&cluster, &workload, &BenchmarkConfig::default());
        assert_eq!(report.samples.len(), 3 * 4);
        for s in &report.samples {
            assert!(s.samples.len() >= 2, "not enough rungs for ({}, {})", s.platform, s.task);
        }
    }

    #[test]
    fn failed_platform_gets_pessimistic_model() {
        let specs = small_cluster();
        let sim_cfg = SimConfig { failure_rate: 1.0, ..SimConfig::exact() };
        let cluster = Cluster::simulated(&specs, &sim_cfg, 3).unwrap();
        let workload = generate(&GeneratorConfig::small(2, 0.05, 5));
        let report = benchmark(&cluster, &workload, &BenchmarkConfig::default());
        // Pessimistic fallback: enormous beta/gamma.
        for i in 0..cluster.len() {
            for j in 0..workload.len() {
                assert!(report.models.model(i, j).gamma >= 3600.0);
            }
        }
    }
}
