//! The coordinator — the paper's L3 contribution: benchmark the cluster,
//! fit predictive models, partition the workload (heuristics vs MILP),
//! generate the ε-constraint Pareto trade-off, and execute allocations.

pub mod allocation;
pub mod benchmarker;
pub mod executor;
pub mod objectives;
pub mod pareto;
pub mod partitioner;
pub mod shape;

pub use allocation::Allocation;
pub use benchmarker::{benchmark, BenchmarkConfig, BenchmarkReport};
pub use executor::{
    execute, execute_static, execute_with, ExecEvent, ExecutionReport, ExecutorConfig,
    RebalanceConfig, RetryConfig,
};
pub use objectives::ModelSet;
pub use pareto::{sweep, SweepConfig, TradeoffCurve, TradeoffPoint};
pub use partitioner::{HeuristicPartitioner, MilpConfig, MilpPartitioner, Partitioner};
pub use shape::{ShapeObjective, ShapeOutcome, ShapePoint, ShapeSearch};
