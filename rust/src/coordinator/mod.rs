//! The coordinator — the paper's L3 contribution: benchmark the cluster,
//! fit predictive models, partition the workload (heuristics vs MILP),
//! generate the ε-constraint Pareto trade-off, execute allocations, and
//! keep doing all of it online as jobs arrive ([`scheduler`]).

pub mod allocation;
pub mod benchmarker;
pub mod executor;
pub mod objectives;
pub mod pareto;
pub mod partitioner;
pub mod scheduler;
pub mod shape;

pub use allocation::Allocation;
pub use benchmarker::{benchmark, BenchmarkConfig, BenchmarkReport};
pub use executor::{
    execute, execute_epoch, execute_shared, execute_static, execute_with, EpochCtx, EpochReport,
    ExecEvent, ExecutionReport, ExecutorConfig, RebalanceConfig, RetryConfig,
};
pub use objectives::ModelSet;
pub use pareto::{sweep, SweepConfig, TradeoffCurve, TradeoffPoint};
pub use partitioner::{HeuristicPartitioner, MilpConfig, MilpPartitioner, Partitioner};
pub use scheduler::{
    EpochRecord, JobSpec, JobState, JobStatus, OnlineScheduler, SchedulerConfig,
    SchedulerStats, Slo,
};
pub use shape::{ShapeObjective, ShapeOutcome, ShapePoint, ShapeSearch};
