//! Cluster-shape optimisation: catalogue → composition → allocation.
//!
//! The paper froze the cluster at the Table II testbed; this module makes
//! the *composition* — how many instances of each catalogue type to rent —
//! an optimisation variable. The search is a two-level decomposition:
//!
//! * **Outer**: a branch & bound over per-type instance-count vectors,
//!   solved with the generic worker-pool search in
//!   [`crate::milp::branch_bound`]. The outer MILP is a sharp relaxation of
//!   the true problem: work is fluid across instances (the LP-relaxed
//!   per-type throughput bound that prunes the count space), setup γ is
//!   ignored, and billing quanta are aggregated per type —
//!
//!   ```text
//!   min  Σ_t π_ρ,t · q_t                      (per-quantum rates)
//!   s.t. Σ_t x_tj = 1                          ∀j   (coverage)
//!        Σ_j β_tj N_j x_tj ≤ ρ_t · q_t         ∀t   (quanta cover work)
//!        Σ_j β_tj N_j x_tj ≤ D · c_t           ∀t   (deadline capacity)
//!        q_t ≤ ⌈D/ρ_t⌉ · c_t                   ∀t   (quanta within deadline)
//!        c_t ∈ {0..available_t},  q_t ∈ ℤ₊
//!   ```
//!
//!   so its optimum is a valid lower bound on any composition's true billed
//!   cost at deadline `D`, and its incumbent counts already anticipate
//!   quantum-boundary effects (renting a second instance to finish inside
//!   one billed hour instead of spilling into two).
//!
//! * **Inner**: the incumbent composition is instantiated
//!   ([`ModelSet::replicate`]) and handed to an ordinary [`Partitioner`]
//!   (MILP or heuristic) under a small ε-constraint budget sweep; the true
//!   ceiling-semantics evaluation picks the best (shape, allocation) pair.
//!   A greedy escalation (add the fastest type) repairs compositions whose
//!   true makespan overshoots the fluid deadline, and a trim pass drops
//!   instances the inner sweep left idle.
//!
//! [`ShapeSearch::frontier`] sweeps deadlines to produce a Pareto frontier
//! over (shape, allocation) pairs instead of allocations alone.

use crate::api::error::{CloudshapesError, Result};
use crate::milp::branch_bound::{self, BnbLimits, MilpStatus};
use crate::milp::lp::{Cmp, Problem};

use super::allocation::Allocation;
use super::objectives::ModelSet;
use super::partitioner::{lower_cost_bound, Partitioner};

/// What to optimise the composition for.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ShapeObjective {
    /// Minimise total billed cost subject to makespan ≤ deadline (seconds).
    Deadline(f64),
    /// Minimise makespan subject to total billed cost ≤ budget ($).
    Budget(f64),
}

/// One (shape, allocation) pair with its true ceiling-semantics objectives.
#[derive(Debug, Clone)]
pub struct ShapePoint {
    /// Instances rented per catalogue type.
    pub counts: Vec<usize>,
    /// Instantiated instance names (`type#k`).
    pub instance_names: Vec<String>,
    /// Per-instance allocation over the instantiated shape.
    pub alloc: Allocation,
    /// Predicted makespan of the pair, seconds.
    pub latency: f64,
    /// Predicted total billed cost, $.
    pub cost: f64,
}

/// A completed shape optimisation.
#[derive(Debug, Clone)]
pub struct ShapeOutcome {
    pub point: ShapePoint,
    /// The outer MILP's bound: within the ε count tie-break of a true lower
    /// bound on any composition's billed cost at the solved deadline (setup
    /// and per-instance packing relaxed away).
    pub outer_bound: f64,
    /// Outer branch & bound nodes explored (summed over probes in budget
    /// mode).
    pub nodes: usize,
}

/// Shape search over a catalogue of platform types.
///
/// `types` is a *per-type* [`ModelSet`] (one row-set per catalogue offer,
/// fitted or nominal); `avail` caps the instances per type; `inner` solves
/// each instantiated composition.
pub struct ShapeSearch<'a> {
    types: &'a ModelSet,
    avail: Vec<usize>,
    inner: &'a dyn Partitioner,
    limits: BnbLimits,
    /// Budget levels of the inner ε-constraint sweep per composition.
    pub sweep_levels: usize,
    /// Known-good composition evaluated alongside the searched ones (e.g.
    /// the pinned paper testbed): the result is then never worse than the
    /// best pair this composition admits under the same inner sweep.
    baseline: Option<Vec<usize>>,
}

/// Bisection iterations for budget mode.
const BUDGET_PROBES: usize = 20;
/// Relative deadline gap at which budget-mode bisection stops.
const BUDGET_REL_TOL: f64 = 0.01;
/// Cap on trim-pass improvement rounds.
const TRIM_ROUNDS: usize = 8;
/// Cost-floor bisection probes on the winning composition.
const REFINE_PROBES: usize = 16;

impl<'a> ShapeSearch<'a> {
    pub fn new(
        types: &'a ModelSet,
        avail: &[usize],
        inner: &'a dyn Partitioner,
        limits: BnbLimits,
    ) -> Result<ShapeSearch<'a>> {
        if avail.len() != types.mu {
            return Err(CloudshapesError::config(format!(
                "availability has {} entries for {} platform types",
                avail.len(),
                types.mu
            )));
        }
        if avail.iter().all(|&a| a == 0) {
            return Err(CloudshapesError::config("catalogue has no available instances"));
        }
        Ok(ShapeSearch {
            types,
            avail: avail.to_vec(),
            inner,
            limits,
            sweep_levels: 7,
            baseline: None,
        })
    }

    /// Register a baseline composition (must fit the availability caps).
    pub fn with_baseline(mut self, counts: Vec<usize>) -> Result<ShapeSearch<'a>> {
        if counts.len() != self.types.mu {
            return Err(CloudshapesError::config(format!(
                "baseline has {} counts for {} platform types",
                counts.len(),
                self.types.mu
            )));
        }
        if counts.iter().zip(&self.avail).any(|(c, a)| c > a) {
            return Err(CloudshapesError::config(
                "baseline composition exceeds availability",
            ));
        }
        self.baseline = Some(counts);
        Ok(self)
    }

    /// Fluid lower bound on any composition's makespan: every simulation on
    /// its fastest type, all available instances busy.
    pub fn fluid_min_makespan(&self) -> f64 {
        let m = self.types;
        let total_avail: usize = self.avail.iter().sum();
        let min_work: f64 = (0..m.tau)
            .map(|j| {
                (0..m.mu)
                    .filter(|&t| self.avail[t] > 0)
                    .map(|t| m.work_secs(t, j))
                    .fold(f64::INFINITY, f64::min)
            })
            .sum();
        min_work / total_avail.max(1) as f64
    }

    /// Slowest single-instance composition — a generous upper deadline.
    fn max_solo_latency(&self) -> f64 {
        (0..self.types.mu)
            .filter(|&t| self.avail[t] > 0)
            .map(|t| self.types.solo_latency(t))
            .fold(0.0, f64::max)
    }

    /// Solve the outer composition MILP at `deadline`; returns the
    /// incumbent counts, the cost lower bound, and nodes explored.
    fn outer_milp(&self, deadline: f64) -> Result<(Vec<usize>, f64, usize)> {
        let m = self.types;
        let (mu, tau) = (m.mu, m.tau);
        let mut p = Problem::new();
        // Instance counts first (extraction below indexes on this layout).
        let c_vars: Vec<_> = (0..mu)
            .map(|t| p.int(&format!("c_{t}"), 0.0, self.avail[t] as f64))
            .collect();
        // Per-type aggregated billed quanta within the deadline.
        let quanta_cap: Vec<f64> =
            (0..mu).map(|t| (deadline / m.cost[t].quantum_secs).ceil().max(1.0)).collect();
        let q_vars: Vec<_> = (0..mu)
            .map(|t| {
                p.int(&format!("q_{t}"), 0.0, quanta_cap[t] * self.avail[t] as f64)
            })
            .collect();
        let x_vars: Vec<_> = (0..mu * tau)
            .map(|k| p.cont(&format!("x_{}_{}", k / tau, k % tau), 0.0, 1.0))
            .collect();
        // Coverage rows.
        for j in 0..tau {
            let terms: Vec<_> = (0..mu).map(|t| (x_vars[t * tau + j], 1.0)).collect();
            p.constrain(terms, Cmp::Eq, 1.0);
        }
        for t in 0..mu {
            let work_terms: Vec<_> =
                (0..tau).map(|j| (x_vars[t * tau + j], m.work_secs(t, j))).collect();
            // Work covered by billed quanta: w_t - rho_t q_t <= 0.
            let mut q_row = work_terms.clone();
            q_row.push((q_vars[t], -m.cost[t].quantum_secs));
            p.constrain(q_row, Cmp::Le, 0.0);
            // Fluid deadline capacity: w_t - D c_t <= 0.
            let mut d_row = work_terms;
            d_row.push((c_vars[t], -deadline));
            p.constrain(d_row, Cmp::Le, 0.0);
            // Quanta rentable within the deadline: q_t - ceil(D/rho) c_t <= 0.
            p.constrain(
                vec![(q_vars[t], 1.0), (c_vars[t], -quanta_cap[t])],
                Cmp::Le,
                0.0,
            );
        }
        // Objective: billed quanta at per-quantum rates, plus an ε count
        // tie-break — counts have no cost of their own, so without it the
        // LP vertex may rent idle instances the trim pass then has to shed.
        let mut obj: Vec<_> =
            (0..mu).map(|t| (q_vars[t], m.cost[t].rate_per_quantum())).collect();
        obj.extend((0..mu).map(|t| (c_vars[t], m.cost[t].rate_per_quantum() * 1e-6)));
        p.minimize(obj);

        let sol = branch_bound::solve(&p, &self.limits);
        match sol.status {
            MilpStatus::Optimal | MilpStatus::Feasible => {
                let counts: Vec<usize> = (0..mu)
                    .map(|t| (sol.x[t].round().max(0.0) as usize).min(self.avail[t]))
                    .collect();
                Ok((counts, sol.bound.max(0.0), sol.nodes))
            }
            // Node/time budget exhausted with no incumbent: fall back to a
            // *small* known composition — the baseline if registered, else
            // a minimal single-type rental (escalation repairs any
            // under-shoot). Renting full availability here would make the
            // budget-miss path the most expensive composition to evaluate,
            // breaking the `[milp]`-budgets-cap-solver-work contract.
            MilpStatus::Unknown => {
                let counts = self
                    .baseline
                    .clone()
                    .unwrap_or_else(|| self.fallback_counts(deadline));
                Ok((counts, sol.bound.max(0.0), sol.nodes))
            }
            MilpStatus::Infeasible | MilpStatus::Unbounded => {
                Err(CloudshapesError::solver(format!(
                    "shape: no composition meets deadline {deadline:.1}s within availability \
                     {:?} (outer MILP {:?})",
                    self.avail, sol.status
                )))
            }
        }
    }

    /// Minimal single-type fallback composition when the outer MILP ran out
    /// of budget without an incumbent: enough instances of the cheapest
    /// (fluid-rate) type to cover the deadline capacity, clamped to
    /// availability.
    fn fallback_counts(&self, deadline: f64) -> Vec<usize> {
        let m = self.types;
        let pick = (0..m.mu)
            .filter(|&t| self.avail[t] > 0)
            .min_by(|&a, &b| {
                let ca: f64 =
                    (0..m.tau).map(|j| m.work_secs(a, j)).sum::<f64>() * m.cost[a].rate_per_hour;
                let cb: f64 =
                    (0..m.tau).map(|j| m.work_secs(b, j)).sum::<f64>() * m.cost[b].rate_per_hour;
                ca.total_cmp(&cb).then(a.cmp(&b))
            })
            .expect("constructor guarantees some availability");
        let work: f64 = (0..m.tau).map(|j| m.work_secs(pick, j)).sum();
        let mut counts = vec![0; m.mu];
        counts[pick] = ((work / deadline).ceil().max(1.0) as usize).min(self.avail[pick]);
        counts
    }

    /// Inner evaluation of one composition: instantiate, run the inner
    /// partitioner unconstrained plus a small budget sweep (and any
    /// `extra_budgets`, e.g. the exact budget of a budget-mode probe), and
    /// return all true-semantics points found.
    ///
    /// The sweep's lower anchor is the *relaxed* minimum cost, not the
    /// cheapest-single-platform C_L: with heterogeneous billing quanta a
    /// multi-instance allocation can undercut every solo run (finishing a
    /// big-quantum instance exactly at its boundary and pushing the
    /// residual onto a fine-quantum one), so C_L is not a cost floor here.
    fn composition_points(
        &self,
        counts: &[usize],
        extra_budgets: &[f64],
    ) -> Result<Vec<ShapePoint>> {
        let replica = self.types.replicate(counts)?;
        let names = replica.platform_names.clone();
        let mut points = Vec::new();
        let mut push = |alloc: Allocation, replica: &ModelSet| {
            if alloc.validate().is_ok() {
                let (latency, cost) = replica.evaluate(&alloc);
                points.push(ShapePoint {
                    counts: counts.to_vec(),
                    instance_names: names.clone(),
                    alloc,
                    latency,
                    cost,
                });
            }
        };
        let fast = self.inner.partition(&replica, None)?;
        let (_, c_upper) = replica.evaluate(&fast);
        push(fast, &replica);
        push(lower_cost_bound(&replica).1, &replica);
        let c_floor = relaxed_min_cost(&replica);
        let levels = self.sweep_levels.max(2);
        let budgets = (0..levels)
            .map(|k| c_floor + (c_upper - c_floor) * k as f64 / (levels - 1) as f64)
            .chain(extra_budgets.iter().copied());
        for budget in budgets {
            if let Ok(alloc) = self.inner.partition(&replica, Some(budget)) {
                push(alloc, &replica);
            }
        }
        Ok(points)
    }

    /// Bisect the cost floor of `counts` at `deadline`: the smallest budget
    /// whose budget-constrained inner solve still makes the deadline. This
    /// is what actually lands on quantum boundaries (e.g. the exact budget
    /// where a big-quantum instance bills one quantum, not two).
    fn refine_cheapest(
        &self,
        best: ShapePoint,
        deadline: f64,
    ) -> Result<ShapePoint> {
        let replica = self.types.replicate(&best.counts)?;
        let names = replica.platform_names.clone();
        let counts = best.counts.clone();
        let mut lo = relaxed_min_cost(&replica);
        let mut best = best;
        for _ in 0..REFINE_PROBES {
            if best.cost - lo <= 1e-6 * best.cost.max(1e-9) {
                break;
            }
            let mid = 0.5 * (lo + best.cost);
            let feasible = self
                .inner
                .partition(&replica, Some(mid))
                .ok()
                .filter(|a| a.validate().is_ok())
                .map(|alloc| {
                    let (latency, cost) = replica.evaluate(&alloc);
                    ShapePoint {
                        counts: counts.clone(),
                        instance_names: names.clone(),
                        alloc,
                        latency,
                        cost,
                    }
                })
                .filter(|p| p.latency <= deadline + 1e-9);
            match feasible {
                Some(p) if p.cost < best.cost => best = p,
                // Feasible but no cheaper: the floor is above mid too.
                _ => lo = mid,
            }
        }
        Ok(best)
    }

    /// The fastest type (smallest mean work seconds) with headroom left —
    /// the escalation step when a composition misses its deadline.
    fn escalation_type(&self, counts: &[usize]) -> Option<usize> {
        (0..self.types.mu)
            .filter(|&t| counts[t] < self.avail[t])
            .min_by(|&a, &b| {
                let wa: f64 = (0..self.types.tau).map(|j| self.types.work_secs(a, j)).sum();
                let wb: f64 = (0..self.types.tau).map(|j| self.types.work_secs(b, j)).sum();
                wa.total_cmp(&wb).then(a.cmp(&b))
            })
    }

    /// All (shape, allocation) points meeting `deadline`, starting from the
    /// outer MILP's incumbent composition and escalating while the true
    /// makespan overshoots the fluid relaxation.
    fn deadline_candidates(
        &self,
        deadline: f64,
        extra_budgets: &[f64],
    ) -> Result<(Vec<ShapePoint>, f64, usize)> {
        if !(deadline > 0.0 && deadline.is_finite()) {
            return Err(CloudshapesError::config(format!(
                "deadline must be positive and finite, got {deadline}"
            )));
        }
        let (mut counts, bound, nodes) = self.outer_milp(deadline)?;
        let baseline_points: Vec<ShapePoint> = match &self.baseline {
            Some(b) => self
                .composition_points(b, extra_budgets)?
                .into_iter()
                .filter(|pt| pt.latency <= deadline + 1e-9)
                .collect(),
            None => Vec::new(),
        };
        loop {
            let mut feasible: Vec<ShapePoint> = self
                .composition_points(&counts, extra_budgets)?
                .into_iter()
                .filter(|pt| pt.latency <= deadline + 1e-9)
                .collect();
            if !feasible.is_empty() {
                feasible.extend(baseline_points);
                return Ok((feasible, bound, nodes));
            }
            // True makespan (setup, integrality) overshot the fluid bound:
            // rent one more of the fastest type and retry.
            match self.escalation_type(&counts) {
                Some(t) => counts[t] += 1,
                None if !baseline_points.is_empty() => {
                    return Ok((baseline_points, bound, nodes))
                }
                None => {
                    return Err(CloudshapesError::solver(format!(
                        "shape: deadline {deadline:.1}s unreachable even at full \
                         availability {:?}",
                        self.avail
                    )))
                }
            }
        }
    }

    /// Minimise billed cost subject to the deadline.
    fn optimize_deadline(&self, deadline: f64) -> Result<ShapeOutcome> {
        let (points, outer_bound, nodes) = self.deadline_candidates(deadline, &[])?;
        let mut best = cheapest(points).expect("deadline_candidates returns non-empty");
        // Trim pass: drop instances whose removal still meets the deadline
        // at strictly lower cost (the inner sweep may leave rentals idle).
        // Evaluated compositions are memoized — successive rounds revisit
        // the same trimmed vectors, and inner sweeps are not free.
        let mut seen: std::collections::HashMap<Vec<usize>, Option<ShapePoint>> =
            std::collections::HashMap::new();
        for _ in 0..TRIM_ROUNDS {
            let mut improved = false;
            for t in 0..self.types.mu {
                if best.counts[t] == 0 {
                    continue;
                }
                let mut trimmed = best.counts.clone();
                trimmed[t] -= 1;
                if trimmed.iter().all(|&c| c == 0) {
                    continue;
                }
                let cand = seen
                    .entry(trimmed.clone())
                    .or_insert_with(|| {
                        let points = self.composition_points(&trimmed, &[]).ok()?;
                        cheapest(
                            points
                                .into_iter()
                                .filter(|p| p.latency <= deadline + 1e-9)
                                .collect(),
                        )
                    })
                    .clone();
                if let Some(cand) = cand {
                    if cand.cost < best.cost - 1e-12 {
                        best = cand;
                        improved = true;
                    }
                }
            }
            if !improved {
                break;
            }
        }
        let best = self.refine_cheapest(best, deadline)?;
        Ok(ShapeOutcome { point: best, outer_bound, nodes })
    }

    /// Minimise makespan subject to the budget, by bisecting deadlines.
    fn optimize_budget(&self, budget: f64) -> Result<ShapeOutcome> {
        if !(budget > 0.0 && budget.is_finite()) {
            return Err(CloudshapesError::config(format!(
                "budget must be positive and finite, got {budget}"
            )));
        }
        let mut nodes = 0usize;
        let mut best: Option<(ShapePoint, f64)> = None; // (point, outer bound)
        let mut take = |points: Vec<ShapePoint>, bound: f64| -> Option<ShapePoint> {
            let within: Vec<ShapePoint> =
                points.into_iter().filter(|p| p.cost <= budget + 1e-9).collect();
            let pt = within.into_iter().min_by(|a, b| {
                a.latency.total_cmp(&b.latency).then(a.cost.total_cmp(&b.cost))
            })?;
            if best.as_ref().map(|(b, _)| pt.latency < b.latency).unwrap_or(true) {
                best = Some((pt.clone(), bound));
            }
            Some(pt)
        };

        // The initial probe at the loosest deadline propagates genuine
        // failures (bad inputs, outer-MILP limits) instead of blaming the
        // budget; only a truly-too-small budget maps to the solver error.
        let mut hi = self.max_solo_latency();
        let (points, bound, n) = self.deadline_candidates(hi, &[budget])?;
        nodes += n;
        if take(points, bound).is_none() {
            return Err(CloudshapesError::solver(format!(
                "shape: no composition within budget ${budget:.3} \
                 (cheapest achievable exceeds it)"
            )));
        }
        // Bisection probes at tighter deadlines may legitimately fail —
        // treat any failure there as "deadline too tight".
        let mut probe = |deadline: f64, nodes: &mut usize| -> Option<ShapePoint> {
            let (points, bound, n) = self.deadline_candidates(deadline, &[budget]).ok()?;
            *nodes += n;
            take(points, bound)
        };
        let mut lo = self.fluid_min_makespan().max(hi * 1e-6).min(hi);
        for _ in 0..BUDGET_PROBES {
            if hi - lo <= BUDGET_REL_TOL * hi {
                break;
            }
            let mid = (lo * hi).sqrt();
            match probe(mid, &mut nodes) {
                Some(_) => hi = mid,
                None => lo = mid,
            }
        }
        let (point, outer_bound) = best.expect("initial probe succeeded");
        Ok(ShapeOutcome { point, outer_bound, nodes })
    }

    /// Optimise the composition for `objective`.
    pub fn optimize(&self, objective: ShapeObjective) -> Result<ShapeOutcome> {
        match objective {
            ShapeObjective::Deadline(d) => self.optimize_deadline(d),
            ShapeObjective::Budget(b) => self.optimize_budget(b),
        }
    }

    /// Pareto frontier over (shape, allocation) pairs: optimise a geometric
    /// grid of `levels` deadlines between the fluid minimum and the slowest
    /// solo composition, then keep the non-dominated points cheapest-first.
    pub fn frontier(&self, levels: usize) -> Result<Vec<ShapeOutcome>> {
        let levels = levels.max(2);
        let hi = self.max_solo_latency();
        let lo = self.fluid_min_makespan().max(hi * 1e-4).min(hi);
        let mut outcomes: Vec<ShapeOutcome> = Vec::new();
        for k in 0..levels {
            let d = lo * (hi / lo).powf(k as f64 / (levels - 1) as f64);
            if let Ok(out) = self.optimize_deadline(d) {
                outcomes.push(out);
            }
        }
        if outcomes.is_empty() {
            return Err(CloudshapesError::solver(
                "shape: no deadline level produced a composition",
            ));
        }
        // Non-dominated filter, cheapest first.
        outcomes.sort_by(|a, b| {
            a.point
                .cost
                .total_cmp(&b.point.cost)
                .then(a.point.latency.total_cmp(&b.point.latency))
        });
        let mut front: Vec<ShapeOutcome> = Vec::new();
        let mut best_latency = f64::INFINITY;
        for o in outcomes {
            if o.point.latency < best_latency - 1e-12 {
                best_latency = o.point.latency;
                front.push(o);
            }
        }
        Ok(front)
    }
}

/// Cheapest point, ties broken toward the lower latency.
fn cheapest(points: Vec<ShapePoint>) -> Option<ShapePoint> {
    points
        .into_iter()
        .min_by(|a, b| a.cost.total_cmp(&b.cost).then(a.latency.total_cmp(&b.latency)))
}

/// Relaxed (un-quantised, setup-free) minimum cost of a model set: every
/// task billed at its cheapest per-second rate. A true lower bound on any
/// allocation's billed cost — unlike the cheapest-single-platform C_L.
fn relaxed_min_cost(m: &ModelSet) -> f64 {
    (0..m.tau)
        .map(|j| {
            (0..m.mu)
                .map(|i| m.work_secs(i, j) * m.cost[i].rate_per_hour / 3600.0)
                .fold(f64::INFINITY, f64::min)
        })
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::partitioner::{HeuristicPartitioner, MilpPartitioner};
    use crate::models::{CostModel, LatencyModel};

    /// Two rentable types sized so quantum boundaries matter: `hourly` is a
    /// fast device billed in 3600-s quanta, `minutely` prices the same
    /// throughput at a 60-s quantum and a slightly higher rate. The single
    /// task is 4500 s of work on either type.
    fn quantum_types() -> ModelSet {
        ModelSet::new(
            vec![LatencyModel::new(1.0, 0.0), LatencyModel::new(1.0, 0.0)],
            vec![
                CostModel::new(3600.0, 1.0).unwrap(),
                CostModel::new(60.0, 1.2).unwrap(),
            ],
            vec![4500],
            vec!["hourly".into(), "minutely".into()],
        )
    }

    #[test]
    fn golden_quantum_boundary_rents_a_second_instance() {
        // One hourly instance takes 4500 s: it misses a 3600-s deadline and
        // would spill into a second billed hour ($2). Renting a second
        // (minutely) instance for the 900-s residual finishes inside one
        // billed hour: $1 + 15 minutely quanta = $1.30.
        let types = quantum_types();
        let inner = MilpPartitioner::default();
        let search = ShapeSearch::new(&types, &[2, 2], &inner, BnbLimits::default()).unwrap();
        let out = search.optimize(ShapeObjective::Deadline(3600.0)).unwrap();
        assert!(out.point.latency <= 3600.0 + 1e-9, "{:?}", out.point);
        assert!(
            out.point.counts.iter().sum::<usize>() >= 2,
            "must rent a second instance: {:?}",
            out.point.counts
        );
        assert!(
            out.point.cost <= 1.30 + 1e-9,
            "expected the $1.30 quantum-boundary composition, got ${}",
            out.point.cost
        );
        // Strictly cheaper than one instance across two billed hours.
        assert!(out.point.cost < 2.0 - 1e-9);
        // The outer MILP bound stays below the billed cost (up to the ε
        // count tie-break in its objective).
        assert!(out.outer_bound <= out.point.cost + 1e-3);
        assert!(out.nodes >= 1);
    }

    #[test]
    fn budget_mode_minimises_latency_within_budget() {
        let types = quantum_types();
        let inner = MilpPartitioner::default();
        let search = ShapeSearch::new(&types, &[2, 2], &inner, BnbLimits::default()).unwrap();
        let out = search.optimize(ShapeObjective::Budget(1.31)).unwrap();
        assert!(out.point.cost <= 1.31 + 1e-9, "{:?}", out.point);
        // $1.31 affords the two-instance composition, so the makespan must
        // beat the 4500-s solo runs.
        assert!(out.point.latency <= 3600.0 + 1e-6, "{:?}", out.point);
        // An impossible budget is a typed solver error.
        let e = search.optimize(ShapeObjective::Budget(1e-6)).unwrap_err();
        assert_eq!(e.kind(), "solver");
    }

    #[test]
    fn loose_deadline_rents_the_cheapest_single_instance() {
        let types = quantum_types();
        let inner = HeuristicPartitioner::default();
        let search = ShapeSearch::new(&types, &[2, 2], &inner, BnbLimits::default()).unwrap();
        // At a 2-hour deadline the solo hourly run (2 quanta, $2) fits, but
        // 75 minutely quanta at $1.2/h ($1.50) and the hourly+minutely mix
        // ($1.30) are cheaper — any of the multi-quantum shapes wins over $2.
        let out = search.optimize(ShapeObjective::Deadline(7200.0)).unwrap();
        assert!(out.point.latency <= 7200.0 + 1e-9);
        assert!(out.point.cost <= 1.5 + 1e-9, "{:?}", out.point);
    }

    #[test]
    fn unreachable_deadline_is_a_solver_error() {
        let types = quantum_types();
        let inner = HeuristicPartitioner::default();
        let search = ShapeSearch::new(&types, &[1, 1], &inner, BnbLimits::default()).unwrap();
        // 4500 s of fluid work over 2 instances needs >= 2250 s.
        let e = search.optimize(ShapeObjective::Deadline(100.0)).unwrap_err();
        assert_eq!(e.kind(), "solver");
        // Bad inputs are config errors.
        assert_eq!(
            search.optimize(ShapeObjective::Deadline(-1.0)).unwrap_err().kind(),
            "config"
        );
        assert!(ShapeSearch::new(&types, &[1], &inner, BnbLimits::default()).is_err());
        assert!(ShapeSearch::new(&types, &[0, 0], &inner, BnbLimits::default()).is_err());
    }

    #[test]
    fn frontier_is_pareto_and_spans_shapes() {
        let types = quantum_types();
        let inner = HeuristicPartitioner::default();
        let search = ShapeSearch::new(&types, &[3, 3], &inner, BnbLimits::default()).unwrap();
        let front = search.frontier(6).unwrap();
        assert!(!front.is_empty());
        for w in front.windows(2) {
            assert!(w[0].point.cost <= w[1].point.cost + 1e-12);
            assert!(w[0].point.latency >= w[1].point.latency - 1e-12);
        }
        // Tight deadlines must rent more instances than loose ones.
        let max_instances =
            front.iter().map(|o| o.point.counts.iter().sum::<usize>()).max().unwrap();
        assert!(max_instances >= 2, "frontier never scaled the shape");
    }

    #[test]
    fn fluid_bound_is_below_any_outcome() {
        let types = quantum_types();
        let inner = HeuristicPartitioner::default();
        let search = ShapeSearch::new(&types, &[2, 2], &inner, BnbLimits::default()).unwrap();
        let lb = search.fluid_min_makespan();
        assert!((lb - 4500.0 / 4.0).abs() < 1e-9);
        let out = search.optimize(ShapeObjective::Deadline(3600.0)).unwrap();
        assert!(out.point.latency >= lb - 1e-9);
    }
}
