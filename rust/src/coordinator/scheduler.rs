//! Online multi-tenant pricing scheduler: continuous job arrivals,
//! epoch-based incremental re-optimisation, SLO tracking.
//!
//! The paper prices one batch of 128 options once. Its own pitch — FPGAs
//! "available by the hour" as IaaS — implies a *service*: clients keep
//! submitting pricing jobs, each with a service-level objective (a deadline
//! in cluster-virtual seconds or a dollar budget), and the task→platform
//! allocation must stay Pareto-optimal as the mix of in-flight work
//! changes. [`OnlineScheduler`] is that layer:
//!
//! 1. **Admit** — arrivals queue; at each epoch boundary up to
//!    `max_in_flight` jobs are admitted and batched into one combined
//!    workload of their *remaining* work.
//! 2. **Plan** — the batch is partitioned by an ordinary [`Partitioner`]
//!    over models rebuilt from the current per-platform throughput
//!    estimates. The previous epoch's incumbent allocation is reused
//!    verbatim while the job set is unchanged and the models have drifted
//!    less than `resolve_drift` (the same quantize-and-reuse discipline as
//!    the session solution cache); otherwise the solver runs again.
//!    Deadline jobs buy speed (tight slack forces the unconstrained
//!    minimum-makespan solve); an all-budget batch is solved under the sum
//!    of remaining budgets.
//! 3. **Execute one epoch** — [`execute_epoch`] runs the allocation until
//!    lane clocks cross `epoch_secs`; still-queued chunks are deferred, so
//!    a re-plan at the boundary effectively preempts and re-homes them
//!    under the refreshed allocation. Per-task path-counter cursors keep
//!    epochs Monte-Carlo-disjoint.
//! 4. **Observe** — measured chunk latencies feed the
//!    [`OnlineLatencyFit`] re-fit (window `refit_window`), so the next
//!    epoch solves against refreshed models; each epoch's mean relative
//!    model error is recorded in [`EpochRecord`].
//!
//! Jobs complete when every task has simulated its required paths; prices
//! merge the per-epoch payoff statistics in epoch order (deterministic).
//! [`JobStatus::slo_met`] reports whether the deadline (virtual time from
//! submission) or budget (attributed cost) held.
//!
//! The serve protocol's `submit`/`jobs`/`cancel` ops and the CLI `jobs`
//! command drive this through
//! [`TradeoffSession::submit_job`](crate::api::TradeoffSession::submit_job):
//!
//! ```no_run
//! use cloudshapes::api::SessionBuilder;
//! use cloudshapes::coordinator::scheduler::{JobSpec, SchedulerConfig, Slo};
//!
//! let session = SessionBuilder::quick()
//!     .partitioner("heuristic")
//!     .scheduler(SchedulerConfig { enabled: true, ..Default::default() })
//!     .build()?;
//! let job = JobSpec::generate(None, 2, 0.05, 7, Slo::Deadline(3600.0))?;
//! let id = session.submit_job(job)?;
//! while let Some(status) = session.job_status(id)? {
//!     if status.state.is_terminal() {
//!         println!("job {id}: {} (SLO met: {:?})", status.state.name(), status.slo_met);
//!         break;
//!     }
//! }
//! # Ok::<(), cloudshapes::api::CloudshapesError>(())
//! ```

use std::collections::BTreeMap;
use std::sync::{Arc, Condvar, Mutex};

use crate::api::error::{CloudshapesError, Result};
use crate::coordinator::executor::{execute_epoch, EpochCtx, ExecEvent, ExecutorConfig};
use crate::coordinator::objectives::ModelSet;
use crate::coordinator::partitioner::Partitioner;
use crate::coordinator::Allocation;
use crate::models::online::{OnlineLatencyFit, PlatformPrior};
use crate::models::CostModel;
use crate::obs::{Counter, Gauge, Histogram, MetricsRegistry};
use crate::platforms::Cluster;
use crate::pricing::mc::{combine, PayoffStats, PriceEstimate};
use crate::workload::{try_generate, GeneratorConfig, OptionTask, Payoff, Workload};

/// `[scheduler]` configuration keys (see `docs/CONFIG.md`).
#[derive(Debug, Clone)]
pub struct SchedulerConfig {
    /// Whether the session accepts jobs at all (`serve --scheduler` or
    /// `[scheduler] enabled = true`). Disabled sessions answer job ops with
    /// a typed config error instead of silently spawning a thread.
    pub enabled: bool,
    /// Cluster-virtual seconds per scheduling epoch — the re-plan cadence.
    pub epoch_secs: f64,
    /// Jobs optimised concurrently; arrivals beyond this wait queued.
    pub max_in_flight: usize,
    /// Observed chunk-latency samples kept per platform for the
    /// incremental re-fit; 0 disables re-fitting.
    pub refit_window: usize,
    /// Relative throughput drift (vs the models of the last solve) that
    /// forces a re-solve at the next epoch boundary.
    pub resolve_drift: f64,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig {
            enabled: false,
            epoch_secs: 600.0,
            max_in_flight: 8,
            refit_window: 64,
            resolve_drift: 0.15,
        }
    }
}

impl SchedulerConfig {
    /// Validate the knobs (the config parser and [`OnlineScheduler::start`]
    /// both route through this).
    pub fn validate(&self) -> Result<()> {
        if !(self.epoch_secs > 0.0 && self.epoch_secs.is_finite()) {
            return Err(CloudshapesError::config(format!(
                "scheduler.epoch_secs must be positive and finite, got {}",
                self.epoch_secs
            )));
        }
        if self.max_in_flight == 0 {
            return Err(CloudshapesError::config("scheduler.max_in_flight must be >= 1"));
        }
        if !(self.resolve_drift > 0.0 && self.resolve_drift.is_finite()) {
            return Err(CloudshapesError::config(format!(
                "scheduler.resolve_drift must be positive, got {}",
                self.resolve_drift
            )));
        }
        Ok(())
    }
}

/// A job's service-level objective.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Slo {
    /// Finish within this many cluster-virtual seconds of submission.
    Deadline(f64),
    /// Finish within this attributed spend, $.
    Budget(f64),
}

impl Slo {
    fn validate(&self) -> Result<()> {
        let (name, v) = match self {
            Slo::Deadline(v) => ("deadline", *v),
            Slo::Budget(v) => ("budget", *v),
        };
        if !(v > 0.0 && v.is_finite()) {
            return Err(CloudshapesError::workload(format!(
                "job {name} must be positive and finite, got {v}"
            )));
        }
        Ok(())
    }
}

/// A pricing job: tasks to price plus the SLO to price them under.
#[derive(Debug, Clone)]
pub struct JobSpec {
    pub tasks: Vec<OptionTask>,
    pub slo: Slo,
}

impl JobSpec {
    /// Most tasks one job may carry (also the task-id stride that keeps
    /// every job's RNG streams disjoint from every other job's).
    pub const MAX_TASKS: usize = 256;

    /// Validate and build a job from explicit tasks.
    pub fn new(tasks: Vec<OptionTask>, slo: Slo) -> Result<JobSpec> {
        if tasks.is_empty() {
            return Err(CloudshapesError::workload("job has no tasks"));
        }
        if tasks.len() > JobSpec::MAX_TASKS {
            return Err(CloudshapesError::workload(format!(
                "job has {} tasks (max {})",
                tasks.len(),
                JobSpec::MAX_TASKS
            )));
        }
        for t in &tasks {
            t.validate()?;
        }
        slo.validate()?;
        Ok(JobSpec { tasks, slo })
    }

    /// Generate a job's tasks Kaiserslautern-style: `n_tasks` options at
    /// `accuracy`, drawn from `seed`, restricted to one payoff family when
    /// `payoff` is given (the serve `submit` op's path).
    pub fn generate(
        payoff: Option<Payoff>,
        n_tasks: usize,
        accuracy: f64,
        seed: u64,
        slo: Slo,
    ) -> Result<JobSpec> {
        let payoff_mix = match payoff {
            None => GeneratorConfig::default().payoff_mix,
            Some(p) => p.one_hot_mix(),
        };
        let cfg = GeneratorConfig {
            n_tasks,
            seed,
            accuracy,
            payoff_mix,
            step_choices: vec![64],
        };
        let workload = try_generate(&cfg)?;
        JobSpec::new(workload.tasks, slo)
    }
}

/// Lifecycle of a submitted job.
#[derive(Debug, Clone, PartialEq)]
pub enum JobState {
    /// Waiting for an in-flight slot.
    Queued,
    /// Admitted: participating in epochs.
    Running,
    /// Every task priced.
    Done,
    /// Cancelled by the client; capacity returned to the queue.
    Cancelled,
    /// The scheduler gave up on it; the message says why.
    Failed(String),
}

impl JobState {
    pub fn is_terminal(&self) -> bool {
        !matches!(self, JobState::Queued | JobState::Running)
    }

    /// Stable lowercase tag (the wire `status` field).
    pub fn name(&self) -> &'static str {
        match self {
            JobState::Queued => "queued",
            JobState::Running => "running",
            JobState::Done => "done",
            JobState::Cancelled => "cancelled",
            JobState::Failed(_) => "failed",
        }
    }
}

/// Snapshot of one job (the serve `jobs` op's payload).
#[derive(Debug, Clone)]
pub struct JobStatus {
    pub id: u64,
    pub state: JobState,
    pub slo: Slo,
    pub tasks_total: usize,
    pub sims_total: u64,
    pub sims_done: u64,
    /// Epochs this job participated in.
    pub epochs: usize,
    /// Cost attributed to this job so far (epoch cost split by executed
    /// work), $.
    pub cost: f64,
    /// Cluster-virtual clock at submission.
    pub arrival_s: f64,
    /// Cluster-virtual clock when the job reached a terminal state.
    pub finished_s: Option<f64>,
    /// Conservative predicted completion (virtual): the latest epoch
    /// plan's full-remaining-work makespan from the clock at that plan.
    pub predicted_finish_s: Option<f64>,
    /// Whether the SLO held, known once terminal (`None` while running;
    /// cancelled/failed jobs report `Some(false)`).
    pub slo_met: Option<bool>,
    /// Per-task discounted price estimates (populated as tasks finish).
    pub prices: Vec<Option<PriceEstimate>>,
}

/// One epoch's planning/execution record (diagnostics + tests).
#[derive(Debug, Clone)]
pub struct EpochRecord {
    pub epoch: usize,
    /// Jobs and tasks in this epoch's batch.
    pub jobs: usize,
    pub tasks: usize,
    /// Whether the solver ran (false = the warm incumbent was reused).
    pub resolved: bool,
    /// Budget the solve ran under (None = unconstrained).
    pub budget: Option<f64>,
    /// Predicted full-remaining makespan of the *previous* incumbent under
    /// this epoch's refreshed models (present whenever one existed).
    pub warm_makespan_s: Option<f64>,
    /// Predicted full-remaining makespan of the chosen allocation.
    pub predicted_makespan_s: f64,
    /// Measured virtual seconds this epoch actually ran.
    pub measured_epoch_s: f64,
    pub epoch_cost: f64,
    /// Mean relative |predicted − measured| over this epoch's chunks.
    pub model_error: f64,
}

/// Aggregate scheduler counters (the serve `ping` op reports a summary).
#[derive(Debug, Clone, Default)]
pub struct SchedulerStats {
    pub submitted: u64,
    pub completed: u64,
    pub cancelled: u64,
    pub failed: u64,
    pub epochs: usize,
    /// Epochs that ran the solver.
    pub resolves: usize,
    /// Epochs that reused the warm incumbent.
    pub warm_reuses: usize,
    /// Model error of the first / most recent epoch — the re-fit
    /// tightening metric.
    pub first_model_error: Option<f64>,
    pub last_model_error: Option<f64>,
    /// Recent epoch records (oldest evicted past a cap; the first/last
    /// error fields above survive eviction).
    pub records: Vec<EpochRecord>,
}

/// Records kept in [`SchedulerStats::records`].
const MAX_EPOCH_RECORDS: usize = 1024;

/// Upper bound on tracked jobs (queued/running ones are never evicted). A
/// continuously-admitting service must not grow without bound: past the
/// cap, the oldest *terminal* job is evicted on submit; with every tracked
/// job still live, new submits are refused — the same backpressure
/// discipline as the session's run registry.
const MAX_TRACKED_JOBS: usize = 1024;

/// Give up on jobs after this many consecutive epochs of zero progress
/// (every lane failing/preempted): keeps a doomed cluster from spinning.
const MAX_STALLED_EPOCHS: usize = 3;

/// Per-task state inside a job.
#[derive(Debug, Clone)]
struct JobTask {
    /// The task with its id remapped into the job's private id range
    /// (stable across epochs: it keys the RNG streams).
    task: OptionTask,
    /// Simulations still needed.
    remaining: u64,
    /// Next fresh path-counter base; advances by the *requested* sims each
    /// epoch so ranges never overlap even when chunks fail or defer.
    cursor: u64,
    /// Payoff statistics accumulated across epochs.
    stats: PayoffStats,
}

#[derive(Debug)]
struct Job {
    id: u64,
    state: JobState,
    slo: Slo,
    tasks: Vec<JobTask>,
    sims_total: u64,
    sims_done: u64,
    epochs: usize,
    cost: f64,
    arrival_s: f64,
    finished_s: Option<f64>,
    predicted_finish_s: Option<f64>,
    slo_met: Option<bool>,
}

impl Job {
    fn status(&self) -> JobStatus {
        JobStatus {
            id: self.id,
            state: self.state.clone(),
            slo: self.slo,
            tasks_total: self.tasks.len(),
            sims_total: self.sims_total,
            sims_done: self.sims_done,
            epochs: self.epochs,
            cost: self.cost,
            arrival_s: self.arrival_s,
            finished_s: self.finished_s,
            predicted_finish_s: self.predicted_finish_s,
            slo_met: self.slo_met,
            prices: self
                .tasks
                .iter()
                .map(|t| {
                    if t.remaining == 0 && t.stats.n > 0 {
                        Some(combine(&t.stats, t.task.discount()))
                    } else {
                        None
                    }
                })
                .collect(),
        }
    }
}

struct SchedState {
    jobs: BTreeMap<u64, Job>,
    next_id: u64,
    /// Cluster-virtual clock: the sum of epoch makespans so far.
    clock: f64,
    shutdown: bool,
    stats: SchedulerStats,
    /// Set when the partitioner factory failed on the epoch thread.
    fatal: Option<CloudshapesError>,
}

/// Registry handles the scheduler updates at the very same sites as its own
/// [`SchedulerStats`] fields (under the same lock), so the serve `ping` op —
/// which reads these registry cells — and [`OnlineScheduler::stats`] can
/// never disagree. Handle-addressed metrics count even when `[obs]` is
/// disabled, mirroring the session cache-stats discipline; only the
/// name-addressed per-chunk observations respect the enabled flag.
struct SchedMetrics {
    submitted: Arc<Counter>,
    completed: Arc<Counter>,
    cancelled: Arc<Counter>,
    failed: Arc<Counter>,
    epochs: Arc<Counter>,
    resolves: Arc<Counter>,
    warm_reuses: Arc<Counter>,
    model_error_first: Arc<Gauge>,
    model_error_last: Arc<Gauge>,
    epoch_model_error: Arc<Histogram>,
}

impl SchedMetrics {
    fn new(reg: &MetricsRegistry) -> SchedMetrics {
        SchedMetrics {
            submitted: reg.counter("scheduler_submitted_total", ""),
            completed: reg.counter("scheduler_completed_total", ""),
            cancelled: reg.counter("scheduler_cancelled_total", ""),
            failed: reg.counter("scheduler_failed_total", ""),
            epochs: reg.counter("scheduler_epochs_total", ""),
            resolves: reg.counter("scheduler_resolves_total", ""),
            warm_reuses: reg.counter("scheduler_warm_reuses_total", ""),
            model_error_first: reg.gauge("scheduler_model_error", "stage=first"),
            model_error_last: reg.gauge("scheduler_model_error", "stage=last"),
            epoch_model_error: reg.histogram("scheduler_epoch_model_error", ""),
        }
    }
}

struct Inner {
    cluster: Cluster,
    exec: ExecutorConfig,
    cfg: SchedulerConfig,
    priors: Vec<PlatformPrior>,
    /// Counter/gauge handles into `reg` (see [`SchedMetrics`]).
    metrics: Option<SchedMetrics>,
    /// The owning session's registry, for per-chunk latency/model-error
    /// observations on the epoch thread.
    reg: Option<Arc<MetricsRegistry>>,
    state: Mutex<SchedState>,
    wake: Condvar,
}

/// The online scheduler: submit jobs, poll their status, cancel them. One
/// background thread runs the epoch loop; dropping the handle (or calling
/// [`shutdown`](Self::shutdown)) stops it at the next boundary.
pub struct OnlineScheduler {
    inner: Arc<Inner>,
}

impl Drop for OnlineScheduler {
    fn drop(&mut self) {
        self.shutdown();
    }
}

impl OnlineScheduler {
    /// Start the epoch thread over `cluster`. `priors` seed the per-platform
    /// throughput estimates (one per platform, usually from benchmark
    /// fits); `make_partitioner` builds the per-epoch solver on the
    /// scheduler thread.
    pub fn start<F>(
        cluster: Cluster,
        priors: Vec<PlatformPrior>,
        exec: ExecutorConfig,
        cfg: SchedulerConfig,
        make_partitioner: F,
    ) -> Result<OnlineScheduler>
    where
        F: FnOnce() -> Result<Box<dyn Partitioner>> + Send + 'static,
    {
        Self::start_instrumented(cluster, priors, exec, cfg, None, make_partitioner)
    }

    /// As [`start`](Self::start), additionally recording scheduler counters,
    /// model-error gauges and per-chunk observations into `registry` (the
    /// owning session's) — the path
    /// [`TradeoffSession`](crate::api::TradeoffSession) takes.
    pub fn start_instrumented<F>(
        cluster: Cluster,
        priors: Vec<PlatformPrior>,
        exec: ExecutorConfig,
        cfg: SchedulerConfig,
        registry: Option<Arc<MetricsRegistry>>,
        make_partitioner: F,
    ) -> Result<OnlineScheduler>
    where
        F: FnOnce() -> Result<Box<dyn Partitioner>> + Send + 'static,
    {
        cfg.validate()?;
        if cluster.is_empty() {
            return Err(CloudshapesError::config("scheduler needs a non-empty cluster"));
        }
        if priors.len() != cluster.len() {
            return Err(CloudshapesError::config(format!(
                "scheduler has {} platform priors for {} platforms",
                priors.len(),
                cluster.len()
            )));
        }
        let inner = Arc::new(Inner {
            cluster,
            exec,
            cfg,
            priors,
            metrics: registry.as_deref().map(SchedMetrics::new),
            reg: registry,
            state: Mutex::new(SchedState {
                jobs: BTreeMap::new(),
                next_id: 1,
                clock: 0.0,
                shutdown: false,
                stats: SchedulerStats::default(),
                fatal: None,
            }),
            wake: Condvar::new(),
        });
        let thread_inner = Arc::clone(&inner);
        std::thread::Builder::new()
            .name("cloudshapes-scheduler".to_string())
            .spawn(move || epoch_loop(thread_inner, make_partitioner))
            .map_err(|e| {
                CloudshapesError::runtime(format!("spawning scheduler thread: {e}"))
            })?;
        Ok(OnlineScheduler { inner })
    }

    /// Submit a job; returns its id. The job starts `Queued` and is
    /// admitted at the next epoch boundary with a free in-flight slot.
    pub fn submit(&self, spec: JobSpec) -> Result<u64> {
        // Re-validate: specs can be hand-built.
        let spec = JobSpec::new(spec.tasks, spec.slo)?;
        let mut st = self.inner.state.lock().unwrap();
        if st.shutdown {
            return Err(CloudshapesError::runtime("scheduler is shut down"));
        }
        if let Some(e) = &st.fatal {
            return Err(e.clone());
        }
        if st.jobs.len() >= MAX_TRACKED_JOBS {
            // Evict the oldest finished job (ids are monotone); with
            // nothing terminal the cap is a hard admission limit.
            let victim = st
                .jobs
                .iter()
                .filter(|(_, j)| j.state.is_terminal())
                .map(|(id, _)| *id)
                .min();
            match victim {
                Some(v) => {
                    st.jobs.remove(&v);
                }
                None => {
                    return Err(CloudshapesError::runtime(format!(
                        "too many live jobs (max {MAX_TRACKED_JOBS}): wait for completions \
                         or cancel before submitting more"
                    )))
                }
            }
        }
        let id = st.next_id;
        st.next_id += 1;
        let tasks: Vec<JobTask> = spec
            .tasks
            .into_iter()
            .enumerate()
            .map(|(k, mut task)| {
                // Remap into the job's private id range so RNG streams never
                // collide across tenants (ids key the counter-based RNG).
                task.id = (id as usize) * JobSpec::MAX_TASKS + k;
                JobTask {
                    remaining: task.n_sims,
                    cursor: 0,
                    stats: PayoffStats::default(),
                    task,
                }
            })
            .collect();
        let sims_total = tasks.iter().map(|t| t.task.n_sims).sum();
        let arrival_s = st.clock;
        st.jobs.insert(
            id,
            Job {
                id,
                state: JobState::Queued,
                slo: spec.slo,
                tasks,
                sims_total,
                sims_done: 0,
                epochs: 0,
                cost: 0.0,
                arrival_s,
                finished_s: None,
                predicted_finish_s: None,
                slo_met: None,
            },
        );
        st.stats.submitted += 1;
        if let Some(m) = &self.inner.metrics {
            m.submitted.inc();
        }
        drop(st);
        self.inner.wake.notify_all();
        Ok(id)
    }

    /// Cancel a job: `Some(true)` if it transitioned to `Cancelled` (its
    /// remaining work is dropped at the next boundary and the in-flight
    /// slot returns to the queue), `Some(false)` if it was already
    /// terminal, `None` for unknown ids.
    pub fn cancel(&self, id: u64) -> Option<bool> {
        let mut st = self.inner.state.lock().unwrap();
        let clock = st.clock;
        let job = st.jobs.get_mut(&id)?;
        if job.state.is_terminal() {
            return Some(false);
        }
        job.state = JobState::Cancelled;
        job.finished_s = Some(clock);
        job.slo_met = Some(false);
        st.stats.cancelled += 1;
        if let Some(m) = &self.inner.metrics {
            m.cancelled.inc();
        }
        drop(st);
        self.inner.wake.notify_all();
        Some(true)
    }

    /// Snapshot one job.
    pub fn job_status(&self, id: u64) -> Option<JobStatus> {
        self.inner.state.lock().unwrap().jobs.get(&id).map(Job::status)
    }

    /// Snapshot every tracked job, in submission order.
    pub fn jobs(&self) -> Vec<JobStatus> {
        self.inner.state.lock().unwrap().jobs.values().map(Job::status).collect()
    }

    /// Aggregate counters and recent epoch records (clones the full record
    /// ring — use [`counters`](Self::counters) on hot paths).
    pub fn stats(&self) -> SchedulerStats {
        self.inner.state.lock().unwrap().stats.clone()
    }

    /// The counters alone, with the epoch-record ring left empty — what
    /// liveness probes (the serve `ping` op) need, without cloning up to
    /// 1024 records under the scheduler lock per call.
    pub fn counters(&self) -> SchedulerStats {
        let st = self.inner.state.lock().unwrap();
        let s = &st.stats;
        SchedulerStats {
            submitted: s.submitted,
            completed: s.completed,
            cancelled: s.cancelled,
            failed: s.failed,
            epochs: s.epochs,
            resolves: s.resolves,
            warm_reuses: s.warm_reuses,
            first_model_error: s.first_model_error,
            last_model_error: s.last_model_error,
            records: Vec::new(),
        }
    }

    /// The cluster-virtual clock (sum of epoch makespans so far).
    pub fn clock(&self) -> f64 {
        self.inner.state.lock().unwrap().clock
    }

    /// Stop the epoch thread at the next boundary. Queued/running jobs stay
    /// in their current state; further submits fail.
    pub fn shutdown(&self) {
        self.inner.state.lock().unwrap().shutdown = true;
        self.inner.wake.notify_all();
    }
}

/// What the epoch thread pulls out of the shared state to plan one epoch.
struct PlanInput {
    /// `(job id, task index)` aligned with `tasks`/`bases`.
    keys: Vec<(u64, usize)>,
    /// Remaining work as a workload (n_sims = remaining per task).
    tasks: Vec<OptionTask>,
    bases: Vec<u64>,
    /// Tightest remaining deadline slack across admitted deadline jobs.
    deadline_slack: Option<f64>,
    /// Sum of remaining budgets when EVERY admitted job is budget-SLO'd.
    budget_cap: Option<f64>,
}

/// The warm incumbent carried across epochs.
struct Warm {
    keys: Vec<(u64, usize)>,
    alloc: Allocation,
    /// Throughput snapshot of the solve that produced `alloc`.
    throughput: Vec<f64>,
    /// The batch budget cap the solve saw (None = unconstrained batch).
    budget_cap: Option<f64>,
}

/// Whether the warm incumbent's budget context still covers the batch:
/// unconstrained stays unconstrained, and a depleting all-budget cap may
/// shrink by at most `tolerance` (relative) before a re-solve under the
/// current remaining budgets is forced.
fn budget_still_covered(warm: Option<f64>, current: Option<f64>, tolerance: f64) -> bool {
    match (warm, current) {
        (None, None) => true,
        (Some(w), Some(c)) => c >= w * (1.0 - tolerance),
        _ => false,
    }
}

fn epoch_loop<F>(inner: Arc<Inner>, make_partitioner: F)
where
    F: FnOnce() -> Result<Box<dyn Partitioner>>,
{
    let partitioner = match make_partitioner() {
        Ok(p) => p,
        Err(e) => {
            // Record the fatal error for future submits AND fail any job
            // that slipped in while the factory was still running — nothing
            // will ever execute them, so leaving them Queued would hang
            // every status poller.
            let msg = format!("scheduler partitioner failed to build: {e}");
            let mut st = inner.state.lock().unwrap();
            let clock = st.clock;
            let mut failed = 0u64;
            for job in st.jobs.values_mut() {
                if !job.state.is_terminal() {
                    job.state = JobState::Failed(msg.clone());
                    job.finished_s = Some(clock);
                    job.slo_met = Some(false);
                    failed += 1;
                }
            }
            st.stats.failed += failed;
            if let Some(m) = &inner.metrics {
                m.failed.add(failed);
            }
            st.fatal = Some(e);
            return;
        }
    };
    let specs = inner.cluster.specs();
    let cost_models: Vec<CostModel> = specs.iter().map(|s| s.cost_model()).collect();
    let platform_names: Vec<String> = specs.iter().map(|s| s.name.clone()).collect();
    let mut fit = OnlineLatencyFit::new(inner.priors.clone(), inner.cfg.refit_window);
    let mut warm: Option<Warm> = None;
    let mut stalled = 0usize;

    loop {
        // ── Phase 1: wait for runnable work, admit arrivals. ────────────
        let input = {
            let mut st = inner.state.lock().unwrap();
            loop {
                if st.shutdown {
                    return;
                }
                admit(&mut st, inner.cfg.max_in_flight);
                let runnable = st.jobs.values().any(|j| {
                    j.state == JobState::Running && j.tasks.iter().any(|t| t.remaining > 0)
                });
                if runnable {
                    break;
                }
                st = inner.wake.wait(st).unwrap();
            }
            collect_plan_input(&st)
        };
        if input.tasks.is_empty() {
            continue;
        }
        // One span per epoch: plan → execute → apply.
        let _span = crate::span!("scheduler_epoch");

        // ── Phase 2: refreshed models for the batch. ────────────────────
        let tau = input.tasks.len();
        let mu = inner.cluster.len();
        let mut latency = Vec::with_capacity(mu * tau);
        for i in 0..mu {
            for t in &input.tasks {
                latency.push(fit.model(i, t.flops_per_path()));
            }
        }
        let models = ModelSet::new(
            latency,
            cost_models.clone(),
            input.tasks.iter().map(|t| t.n_sims).collect(),
            platform_names.clone(),
        );

        // ── Phase 3: warm-reuse or re-solve. ────────────────────────────
        let snapshot = fit.snapshot();
        // The incumbent survives task completions (its columns project
        // onto the surviving keys) but not new arrivals.
        let projected = warm.as_ref().and_then(|w| project_warm(w, &input.keys));
        let warm_pred = projected.as_ref().map(|a| models.makespan(a));
        let reuse_ok = warm
            .as_ref()
            .map(|w| {
                fit.drift(&w.throughput) <= inner.cfg.resolve_drift
                    && budget_still_covered(
                        w.budget_cap,
                        input.budget_cap,
                        inner.cfg.resolve_drift,
                    )
            })
            .unwrap_or(false);
        let (alloc, budget, resolved, predicted) = match (projected, warm_pred, reuse_ok) {
            (Some(a), Some(pred), true) => {
                let budget = warm.as_ref().and_then(|w| w.budget_cap);
                (a, budget, false, pred)
            }
            _ => match plan_allocation(partitioner.as_ref(), &models, &input) {
                Ok((alloc, budget)) => {
                    let pred = models.makespan(&alloc);
                    warm = Some(Warm {
                        keys: input.keys.clone(),
                        alloc: alloc.clone(),
                        throughput: snapshot,
                        budget_cap: input.budget_cap,
                    });
                    (alloc, budget, true, pred)
                }
                Err(e) => {
                    fail_running_jobs(&inner, &format!("epoch solve failed: {e}"));
                    warm = None;
                    continue;
                }
            },
        };

        // ── Phase 4: execute one epoch. ─────────────────────────────────
        let workload = Workload::new(input.tasks.clone());
        let mut exec_cfg = inner.exec.clone();
        exec_cfg.chunk_sims = epoch_chunk_cap(&inner.exec, &models, inner.cfg.epoch_secs);
        let mut err_sum = 0.0f64;
        let mut err_n = 0usize;
        let outcome = {
            let fit = &mut fit;
            let models_ref = &models;
            let workload_ref = &workload;
            let reg = &inner.reg;
            let platform_names = &platform_names;
            execute_epoch(
                &inner.cluster,
                workload_ref,
                &alloc,
                &exec_cfg,
                Some(models_ref),
                EpochCtx { halt_secs: inner.cfg.epoch_secs, base_offsets: &input.bases },
                &mut |ev| {
                    if let ExecEvent::ChunkDone {
                        platform, task, n, latency_secs, cold, ..
                    } = ev
                    {
                        let m = models_ref.model(*platform, *task);
                        let setup = if *cold { m.gamma } else { 0.0 };
                        let predicted = m.beta * *n as f64 + setup;
                        if *latency_secs > 0.0 {
                            err_sum += (predicted - latency_secs).abs() / latency_secs;
                            err_n += 1;
                        }
                        // Work-only throughput sample. A cold chunk whose
                        // measured latency is below the *modelled* setup
                        // carries no usable work signal (the true setup is
                        // itself noisy) — observe() drops the non-positive
                        // sample instead of us clamping it into a bogus
                        // near-infinite throughput.
                        let flops = workload_ref.tasks[*task].flops_per_path() * *n as f64;
                        fit.observe(*platform, flops, latency_secs - setup);
                        if let Some(reg) = reg {
                            reg.observe(
                                "exec_chunk_latency_secs",
                                &format!("platform={}", platform_names[*platform]),
                                *latency_secs,
                            );
                            if *latency_secs > 0.0 {
                                reg.observe(
                                    "exec_model_error_rel",
                                    &format!(
                                        "platform={},task={task}",
                                        platform_names[*platform]
                                    ),
                                    (predicted - latency_secs).abs() / latency_secs,
                                );
                            }
                        }
                    }
                },
            )
        };
        let outcome = match outcome {
            Ok(o) => o,
            Err(e) => {
                fail_running_jobs(&inner, &format!("epoch execution failed: {e}"));
                warm = None;
                continue;
            }
        };

        // ── Phase 5: apply the epoch's results. ─────────────────────────
        let epoch_done: u64 = outcome.done_sims.iter().sum();
        let model_error = if err_n > 0 { err_sum / err_n as f64 } else { 0.0 };
        let mut st = inner.state.lock().unwrap();
        let clock_before = st.clock;
        st.clock += outcome.exec.makespan_secs;
        let clock_after = st.clock;

        // Attribute the epoch's bill by executed work.
        let total_flops: f64 = outcome
            .done_sims
            .iter()
            .zip(&input.tasks)
            .map(|(&d, t)| d as f64 * t.flops_per_path())
            .sum();
        for (slot, (&(job_id, task_idx), &done)) in
            input.keys.iter().zip(&outcome.done_sims).enumerate()
        {
            let requested = input.tasks[slot].n_sims;
            let share = if total_flops > 0.0 {
                done as f64 * input.tasks[slot].flops_per_path() / total_flops
            } else {
                0.0
            };
            let Some(job) = st.jobs.get_mut(&job_id) else { continue };
            if job.state != JobState::Running {
                continue; // cancelled (or failed) mid-epoch: drop the results
            }
            let jt = &mut job.tasks[task_idx];
            jt.remaining = jt.remaining.saturating_sub(done);
            jt.cursor += requested;
            jt.stats = jt.stats.merge(&outcome.stats[slot]);
            job.sims_done += done;
            job.cost += outcome.exec.cost * share;
        }
        // Per-job bookkeeping: epochs, predictions, completion, SLOs.
        // Keys are grouped per job (collect_plan_input walks jobs in id
        // order), so dedup over the consecutive run is exact.
        let mut participant_ids: Vec<u64> =
            input.keys.iter().map(|&(id, _)| id).collect();
        participant_ids.dedup();
        for id in &participant_ids {
            let Some(job) = st.jobs.get_mut(id) else { continue };
            if job.state != JobState::Running {
                continue;
            }
            job.epochs += 1;
            job.predicted_finish_s = Some(clock_before + predicted);
            if job.tasks.iter().all(|t| t.remaining == 0) {
                job.state = JobState::Done;
                job.finished_s = Some(clock_after);
                job.slo_met = Some(match job.slo {
                    Slo::Deadline(d) => clock_after - job.arrival_s <= d + 1e-9,
                    Slo::Budget(b) => job.cost <= b + 1e-9,
                });
                st.stats.completed += 1;
                if let Some(m) = &inner.metrics {
                    m.completed.inc();
                }
            }
        }
        // Stall guard: epochs that complete nothing, repeatedly, mean the
        // cluster cannot make progress (e.g. everything preempted).
        if epoch_done == 0 {
            stalled += 1;
        } else {
            stalled = 0;
        }
        if stalled >= MAX_STALLED_EPOCHS {
            let msg = format!("no progress in {MAX_STALLED_EPOCHS} consecutive epochs");
            let clock = st.clock;
            let mut failed = 0u64;
            for job in st.jobs.values_mut() {
                if job.state == JobState::Running {
                    job.state = JobState::Failed(msg.clone());
                    job.finished_s = Some(clock);
                    job.slo_met = Some(false);
                    failed += 1;
                }
            }
            st.stats.failed += failed;
            if let Some(m) = &inner.metrics {
                m.failed.add(failed);
            }
            stalled = 0;
            warm = None;
        }
        // Epoch record + counters.
        st.stats.epochs += 1;
        if resolved {
            st.stats.resolves += 1;
        } else {
            st.stats.warm_reuses += 1;
        }
        let first_error = st.stats.first_model_error.is_none() && err_n > 0;
        if first_error {
            st.stats.first_model_error = Some(model_error);
        }
        if err_n > 0 {
            st.stats.last_model_error = Some(model_error);
        }
        if let Some(m) = &inner.metrics {
            m.epochs.inc();
            if resolved {
                m.resolves.inc();
            } else {
                m.warm_reuses.inc();
            }
            if first_error {
                m.model_error_first.set(model_error);
            }
            if err_n > 0 {
                m.model_error_last.set(model_error);
                m.epoch_model_error.observe(model_error);
            }
        }
        let record = EpochRecord {
            epoch: st.stats.epochs,
            jobs: participant_ids.len(),
            tasks: tau,
            resolved,
            budget,
            warm_makespan_s: warm_pred,
            predicted_makespan_s: predicted,
            measured_epoch_s: outcome.exec.makespan_secs,
            epoch_cost: outcome.exec.cost,
            model_error,
        };
        st.stats.records.push(record);
        if st.stats.records.len() > MAX_EPOCH_RECORDS {
            st.stats.records.remove(0);
        }
    }
}

/// Admit queued jobs while in-flight slots are free (submission order).
fn admit(st: &mut SchedState, max_in_flight: usize) {
    let mut running =
        st.jobs.values().filter(|j| j.state == JobState::Running).count();
    let queued: Vec<u64> = st
        .jobs
        .values()
        .filter(|j| j.state == JobState::Queued)
        .map(|j| j.id)
        .collect();
    for id in queued {
        if running >= max_in_flight {
            break;
        }
        st.jobs.get_mut(&id).unwrap().state = JobState::Running;
        running += 1;
    }
}

/// Gather the epoch batch: every running job's remaining tasks, plus the
/// SLO aggregates the budget policy needs.
fn collect_plan_input(st: &SchedState) -> PlanInput {
    let mut keys = Vec::new();
    let mut tasks = Vec::new();
    let mut bases = Vec::new();
    let mut deadline_slack: Option<f64> = None;
    let mut budget_cap = Some(0.0f64);
    for job in st.jobs.values() {
        if job.state != JobState::Running {
            continue;
        }
        match job.slo {
            Slo::Deadline(d) => {
                let slack = d - (st.clock - job.arrival_s);
                deadline_slack =
                    Some(deadline_slack.map_or(slack, |s: f64| s.min(slack)));
                budget_cap = None; // mixed batch: budgets no longer cover it
            }
            Slo::Budget(b) => {
                if let Some(cap) = budget_cap.as_mut() {
                    *cap += (b - job.cost).max(0.0);
                }
            }
        }
        for (k, jt) in job.tasks.iter().enumerate() {
            if jt.remaining == 0 {
                continue;
            }
            let mut task = jt.task.clone();
            task.n_sims = jt.remaining;
            keys.push((job.id, k));
            tasks.push(task);
            bases.push(jt.cursor);
        }
    }
    PlanInput { keys, tasks, bases, deadline_slack, budget_cap }
}

/// Project the warm incumbent onto the current key set: identical key
/// lists reuse the allocation verbatim; a *shrunken* set (tasks completed)
/// keeps the surviving columns (each still sums to 1); any new key means
/// the incumbent cannot cover the batch (`None` ⇒ re-solve).
fn project_warm(w: &Warm, keys: &[(u64, usize)]) -> Option<Allocation> {
    if w.keys == keys {
        return Some(w.alloc.clone());
    }
    let cols: Option<Vec<usize>> = keys
        .iter()
        .map(|k| w.keys.iter().position(|wk| wk == k))
        .collect();
    let cols = cols?;
    let mu = w.alloc.n_platforms();
    let mut a = Allocation::zero(mu, cols.len());
    for (j_new, &j_old) in cols.iter().enumerate() {
        for i in 0..mu {
            a.set(i, j_new, w.alloc.get(i, j_old));
        }
    }
    Some(a)
}

/// The epoch budget policy: deadline jobs buy speed, budget jobs buy
/// thrift.
///
/// - Any deadline job with slack under twice the unconstrained remaining
///   makespan ⇒ run unconstrained (minimum makespan);
/// - an all-budget batch ⇒ solve under the sum of remaining budgets
///   (falling back to unconstrained when that is infeasible);
/// - otherwise unconstrained.
fn plan_allocation(
    partitioner: &dyn Partitioner,
    models: &ModelSet,
    input: &PlanInput,
) -> Result<(Allocation, Option<f64>)> {
    let alloc_u = partitioner.partition(models, None)?;
    let makespan_u = models.makespan(&alloc_u);
    let tight = input
        .deadline_slack
        .map(|s| s < 2.0 * makespan_u)
        .unwrap_or(false);
    if !tight {
        if let Some(cap) = input.budget_cap {
            if cap > 0.0 {
                if let Ok(a) = partitioner.partition(models, Some(cap)) {
                    return Ok((a, Some(cap)));
                }
            }
        }
    }
    Ok((alloc_u, None))
}

/// Mark every running job failed (epoch-level solver/executor breakdowns).
fn fail_running_jobs(inner: &Inner, msg: &str) {
    let mut st = inner.state.lock().unwrap();
    let clock = st.clock;
    let mut failed = 0u64;
    for job in st.jobs.values_mut() {
        if job.state == JobState::Running {
            job.state = JobState::Failed(msg.to_string());
            job.finished_s = Some(clock);
            job.slo_met = Some(false);
            failed += 1;
        }
    }
    st.stats.failed += failed;
    if let Some(m) = &inner.metrics {
        m.failed.add(failed);
    }
}

/// Chunks must be fine enough for the epoch boundary to bite on EVERY
/// lane: cap one chunk at ~1/8 of the epoch on the *slowest* (platform,
/// task) pairing, inside the configured `chunk_sims`. Sizing from the
/// fastest pairing instead would let a single chunk occupy a slow lane for
/// many whole epochs (Table II throughputs span two orders of magnitude),
/// making the boundary — and with it cancellation and re-planning —
/// unenforceable on exactly the lanes that need it most.
fn epoch_chunk_cap(exec: &ExecutorConfig, models: &ModelSet, epoch_secs: f64) -> u64 {
    let mut max_beta = 0.0f64;
    for i in 0..models.mu {
        for j in 0..models.tau {
            max_beta = max_beta.max(models.model(i, j).beta);
        }
    }
    let cap = if max_beta.is_finite() && max_beta > 0.0 {
        ((epoch_secs / 8.0) / max_beta).max(1.0).min(u64::MAX as f64) as u64
    } else {
        u64::MAX
    };
    let base = if exec.chunk_sims == 0 { u64::MAX } else { exec.chunk_sims };
    base.min(cap).max(1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::partitioner::HeuristicPartitioner;
    use crate::models::online::PlatformPrior;
    use crate::platforms::sim::SimConfig;
    use crate::platforms::spec::small_cluster;
    use std::time::{Duration, Instant};

    fn cluster() -> Cluster {
        Cluster::simulated(&small_cluster(), &SimConfig::exact(), 21).unwrap()
    }

    fn priors(cluster: &Cluster) -> Vec<PlatformPrior> {
        cluster
            .specs()
            .iter()
            .map(|s| PlatformPrior {
                throughput_flops: s.app_gflops.max(1e-9) * 1e9,
                setup_secs: s.setup_secs,
            })
            .collect()
    }

    fn start(cfg: SchedulerConfig) -> OnlineScheduler {
        let c = cluster();
        let p = priors(&c);
        OnlineScheduler::start(c, p, ExecutorConfig::default(), cfg, || {
            Ok(Box::new(HeuristicPartitioner::default()))
        })
        .unwrap()
    }

    fn wait_terminal(s: &OnlineScheduler, id: u64) -> JobStatus {
        let deadline = Instant::now() + Duration::from_secs(60);
        loop {
            let st = s.job_status(id).expect("job tracked");
            if st.state.is_terminal() {
                return st;
            }
            assert!(Instant::now() < deadline, "job {id} never finished: {st:?}");
            std::thread::sleep(Duration::from_millis(5));
        }
    }

    #[test]
    fn job_spec_validation() {
        assert!(JobSpec::new(vec![], Slo::Deadline(10.0)).is_err());
        let ok = JobSpec::generate(Some(Payoff::Asian), 2, 0.05, 3, Slo::Budget(5.0)).unwrap();
        assert_eq!(ok.tasks.len(), 2);
        assert!(ok.tasks.iter().all(|t| t.payoff == Payoff::Asian));
        // Bad SLOs are workload errors.
        let e = JobSpec::generate(None, 1, 0.05, 3, Slo::Deadline(-1.0)).unwrap_err();
        assert_eq!(e.kind(), "workload");
        let e = JobSpec::generate(None, 1, 0.05, 3, Slo::Budget(f64::NAN)).unwrap_err();
        assert_eq!(e.kind(), "workload");
        // Bad generator parameters surface too.
        assert!(JobSpec::generate(None, 0, 0.05, 3, Slo::Budget(1.0)).is_err());
    }

    #[test]
    fn scheduler_config_validation() {
        assert!(SchedulerConfig::default().validate().is_ok());
        let bad = SchedulerConfig { epoch_secs: 0.0, ..Default::default() };
        assert!(bad.validate().is_err());
        let bad = SchedulerConfig { max_in_flight: 0, ..Default::default() };
        assert!(bad.validate().is_err());
        let bad = SchedulerConfig { resolve_drift: -1.0, ..Default::default() };
        assert!(bad.validate().is_err());
    }

    #[test]
    fn single_job_completes_and_prices() {
        let s = start(SchedulerConfig { enabled: true, ..Default::default() });
        let job = JobSpec::generate(None, 3, 0.05, 11, Slo::Deadline(1e9)).unwrap();
        let id = s.submit(job).unwrap();
        let st = wait_terminal(&s, id);
        assert_eq!(st.state, JobState::Done);
        assert_eq!(st.slo_met, Some(true));
        assert_eq!(st.sims_done, st.sims_total);
        assert!(st.cost > 0.0);
        assert!(st.finished_s.unwrap() > 0.0);
        assert!(st.prices.iter().all(Option::is_some));
        let stats = s.stats();
        assert!(stats.epochs >= 1);
        assert_eq!(stats.completed, 1);
        // Unknown ids are None; cancel after completion is Some(false).
        assert!(s.job_status(999).is_none());
        assert_eq!(s.cancel(id), Some(false));
        assert_eq!(s.cancel(999), None);
        s.shutdown();
        assert!(s.submit(JobSpec::generate(None, 1, 0.05, 1, Slo::Budget(1.0)).unwrap())
            .is_err());
    }

    #[test]
    fn epoch_chunk_cap_scales_with_models() {
        let c = cluster();
        let w = crate::workload::generate(&crate::workload::GeneratorConfig::small(2, 0.05, 1));
        let m = crate::coordinator::ModelSet::from_specs(&c.specs(), &w);
        let exec = ExecutorConfig::default();
        let cap = epoch_chunk_cap(&exec, &m, 100.0);
        assert!(cap >= 1);
        assert!(cap <= exec.chunk_sims);
        // A tiny epoch forces tiny chunks.
        let tiny = epoch_chunk_cap(&exec, &m, 1e-6);
        assert!(tiny < cap);
    }
}
